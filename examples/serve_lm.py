"""Serving example: batched requests through the continuous-batching engine
with a reduced hymba (hybrid attention+SSM) model — exercises the rolling
window KV cache + recurrent state decode path.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.models import build_model, get_config, reduced_config
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = reduced_config(get_config("hymba-1.5b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=4, max_seq=64)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab, size=6).astype(np.int32),
                    max_new_tokens=12)
            for i in range(8)]
    for r in reqs:
        engine.submit(r)

    t0 = time.time()
    tokens = 0
    while engine.waiting or engine.n_active:
        tokens += engine.step()
    print(f"served {len(reqs)} requests / {tokens} tokens "
          f"in {time.time() - t0:.1f}s")
    for r in reqs[:4]:
        print(f"  req{r.rid}: {r.prompt.tolist()} -> {r.out_tokens}")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
