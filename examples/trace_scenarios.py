"""Trace-driven workloads in 60 seconds: generate -> fit -> replay -> ingest.

Four short acts:

  1. synthesize an Azure-like workload trace from the paper's Table-1
     priors and refit the priors from it (the generate->fit loop);
  2. replay two scenarios — the stationary baseline and a flash crowd —
     through the same admission policy via the simulator's pluggable
     ArrivalSource;
  3. replay the *same* trace under richer information models (§6 pseudo
     observations vs the GLOBAL prior): arrivals identical, beliefs
     better, utilization up — the paper's headline, trace-driven;
  4. ingest a real Cortez/Azure-format VM table (the checked-in sample),
     fit priors from its observables, and replay it.

  PYTHONPATH=src python examples/trace_scenarios.py

Set REPRO_SMOKE=1 (the CI docs job does) to shrink everything so the
script finishes in seconds.
"""
import os

import jax
import numpy as np

from repro.core import AZURE_PRIORS, SECOND, geometric_grid, make_policy
from repro.sim import PSEUDO, make_config, make_run
from repro.traces import (TraceArrivalSource, TraceSpec, fit_priors,
                          ingest_cortez_csv, n_deployments,
                          prior_relative_errors, synthesize_scenario)

SMOKE = os.environ.get("REPRO_SMOKE") == "1"
SAMPLE_CSV = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                          "azure_cortez_sample.csv")


def main():
    days = 90 if SMOKE else 180
    n_runs = 2 if SMOKE else 4
    cfg = make_config(capacity=1_000.0, arrival_rate=0.05,
                      horizon_hours=days * 24.0, dt=24.0, max_slots=256,
                      max_arrivals=8)
    grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3, 24)
    spec = TraceSpec(horizon_hours=cfg.horizon_hours,
                     arrival_rate=cfg.arrival_rate,
                     max_deployments=1024, max_events=8)
    pol = make_policy(SECOND, rho=0.15, capacity=cfg.capacity)
    keys = jax.random.split(jax.random.PRNGKey(2), n_runs)

    # 1. generate -> fit: recover Table 1 from a synthetic trace
    fit_spec = spec._replace(arrival_rate=0.25 if SMOKE else 0.5,
                             max_deployments=4096 if SMOKE else 8192)
    trace = synthesize_scenario(jax.random.PRNGKey(0), "baseline", fit_spec)
    fitted, _ = fit_priors(trace, source="latent")
    errs = prior_relative_errors(fitted, AZURE_PRIORS)
    print(f"fit round-trip on {n_deployments(trace)} deployments: "
          f"max relative error {max(errs.values()):.1%} "
          f"(nu {fitted.nu:.3f} vs {AZURE_PRIORS.nu})")

    # 2. replay scenarios through one tuned policy
    for scen in ("baseline", "flash_crowd"):
        tr = synthesize_scenario(jax.random.PRNGKey(1), scen, spec)
        run = make_run(cfg, grid, SECOND,
                       arrival_source=TraceArrivalSource(tr))
        m = jax.vmap(lambda k: run(k, pol))(keys)
        print(f"{scen:12s} utilization={float(np.mean(m.utilization)):.3f} "
              f"failures={int(np.asarray(m.failed_requests).sum())}"
              f"/{int(np.asarray(m.total_requests).sum())}")

    # 3. same arrivals, richer information: GLOBAL vs §6 pseudo observations
    tr = synthesize_scenario(jax.random.PRNGKey(1), "baseline", spec)
    for label, mode_cfg in (
            ("global", cfg),
            ("pseudo(k=5)", cfg._replace(prior_mode=PSEUDO, n_pseudo_obs=5))):
        run = make_run(mode_cfg, grid, SECOND,
                       arrival_source=TraceArrivalSource(tr))
        m = jax.vmap(lambda k: run(k, pol))(keys)
        print(f"info {label:12s} utilization="
              f"{float(np.mean(m.utilization)):.3f}")

    # 4. real data: ingest the Cortez-format sample, fit, replay
    real, diag = ingest_cortez_csv(SAMPLE_CSV)
    real_fit, _ = fit_priors(real, source="observed")
    print(f"ingested {diag['n_vms']} VM rows -> "
          f"{diag['n_deployments']} deployments "
          f"({diag['n_malformed']} malformed), "
          f"horizon {diag['horizon_hours']:.0f}h; "
          f"fitted E[mu]={real_fit.mu_shape / real_fit.mu_rate:.4f}/h")
    horizon = float(np.asarray(real.horizon_hours))
    n_steps = max(int(horizon // 24.0), 1)
    # n_pseudo_obs is ignored by observed-trace replay (the logged history
    # defines the information content); >= 1 satisfies the PSEUDO validation
    real_cfg = make_config(capacity=200.0, arrival_rate=0.05,
                           horizon_hours=n_steps * 24.0, dt=24.0,
                           max_slots=64, max_arrivals=8, d_points=8,
                           prior_mode=PSEUDO, n_pseudo_obs=1)
    real_run = make_run(real_cfg, geometric_grid(24.0, 3 * horizon, 16),
                        SECOND,
                        arrival_source=TraceArrivalSource(real))
    real_pol = make_policy(SECOND, rho=0.15, capacity=real_cfg.capacity)
    m = real_run(jax.random.PRNGKey(3), real_pol)
    print(f"real-trace replay (observed pseudo beliefs): "
          f"utilization={float(m.utilization):.3f}")


if __name__ == "__main__":
    main()
