"""Trace-driven workloads in 60 seconds: generate -> fit -> replay.

Synthesizes an Azure-like workload trace from the paper's Table-1 priors,
refits the priors from the trace (closing the generate->fit loop), then
replays two scenarios — the stationary baseline and a flash crowd — through
the same admission policy via the simulator's pluggable ArrivalSource.

  PYTHONPATH=src python examples/trace_scenarios.py
"""
import jax
import numpy as np

from repro.core import AZURE_PRIORS, SECOND, geometric_grid, make_policy
from repro.sim import make_config, make_run
from repro.traces import (TraceArrivalSource, TraceSpec, fit_priors,
                          n_deployments, prior_relative_errors,
                          synthesize_scenario)


def main():
    cfg = make_config(capacity=1_000.0, arrival_rate=0.05,
                      horizon_hours=180 * 24.0, dt=24.0, max_slots=256,
                      max_arrivals=8)
    grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3, 24)
    spec = TraceSpec(horizon_hours=cfg.horizon_hours,
                     arrival_rate=cfg.arrival_rate,
                     max_deployments=1024, max_events=8)

    # generate -> fit: recover Table 1 from a synthetic trace
    fit_spec = spec._replace(arrival_rate=0.5, max_deployments=8192)
    trace = synthesize_scenario(jax.random.PRNGKey(0), "baseline", fit_spec)
    fitted, _ = fit_priors(trace, source="latent")
    errs = prior_relative_errors(fitted, AZURE_PRIORS)
    print(f"fit round-trip on {n_deployments(trace)} deployments: "
          f"max relative error {max(errs.values()):.1%} "
          f"(nu {fitted.nu:.3f} vs {AZURE_PRIORS.nu})")

    # replay scenarios through one tuned policy
    pol = make_policy(SECOND, rho=0.15, capacity=cfg.capacity)
    for scen in ("baseline", "flash_crowd"):
        tr = synthesize_scenario(jax.random.PRNGKey(1), scen, spec)
        run = make_run(cfg, grid, SECOND,
                       arrival_source=TraceArrivalSource(tr))
        m = jax.vmap(lambda k: run(k, pol))(
            jax.random.split(jax.random.PRNGKey(2), 4))
        print(f"{scen:12s} utilization={float(np.mean(m.utilization)):.3f} "
              f"failures={int(np.asarray(m.failed_requests).sum())}"
              f"/{int(np.asarray(m.total_requests).sum())}")


if __name__ == "__main__":
    main()
