"""Telemetry dashboard: what the admission controller sees about itself.

Drives the online ``OnlineAdmissionEngine`` with the device telemetry rider
enabled (``SimConfig(telemetry=True)``) and a ``DecisionTracer`` attached,
serves its live ``/metrics`` endpoint, scrapes it mid-run like Prometheus
would, and finally renders the device-side counters — admissions by reason,
the occupancy histogram, aggregate staleness at decision time — as an ASCII
dashboard next to the host-side latency percentiles and a few structured
decision-trace records.

  PYTHONPATH=src python examples/telemetry_dashboard.py
  REPRO_SMOKE=1 PYTHONPATH=src python examples/telemetry_dashboard.py  # CI
"""
import json
import os
import tempfile
import urllib.request

import jax
import numpy as np

from repro.core import AZURE_PRIORS, SECOND, geometric_grid, make_policy
from repro.obs import DecisionTracer, MetricsServer, snapshot_to_prometheus
from repro.serve import Arrival, OnlineAdmissionEngine
from repro.sim import SimConfig, draw_arrival_stream

SMOKE = os.environ.get("REPRO_SMOKE") == "1"


def bar(count, total, width=32):
    n = int(round(width * count / total)) if total else 0
    return "#" * n + "." * (width - n)


def main():
    days = 10 if SMOKE else 90
    cfg = SimConfig(capacity=500.0, arrival_rate=0.1,
                    horizon_hours=days * 24.0, dt=24.0, max_slots=96,
                    max_arrivals=4, priors=AZURE_PRIORS,
                    agg_refresh_steps=2, telemetry=True)
    grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3, 16)
    pol = make_policy(SECOND, rho=0.1, capacity=cfg.capacity)

    trace_path = os.path.join(tempfile.mkdtemp(prefix="repro_obs_"),
                              "decisions.jsonl")
    tracer = DecisionTracer(trace_path)
    engine = OnlineAdmissionEngine(cfg, grid, SECOND, pol, tracer=tracer)
    server = MetricsServer(
        lambda: snapshot_to_prometheus(engine.metrics_snapshot()), port=0)
    url = f"http://127.0.0.1:{server.port}/metrics"
    print(f"live metrics at {url}")

    key = jax.random.PRNGKey(0)
    k_stream, k_scan = jax.random.split(key)
    stream = draw_arrival_stream(k_stream, cfg)
    keys = jax.random.split(k_scan, cfg.n_steps)
    n_arr = np.asarray(stream.n_arrivals)
    n_lanes = stream.c0.shape[1]
    for t in range(cfg.n_steps):
        engine.tick(keys[t])
        futs = [engine.submit(Arrival.from_stream(stream, t, a))
                for a in range(min(int(n_arr[t]), n_lanes))]
        engine.flush()
        for f in futs:
            f.result()
        if t == cfg.n_steps // 2:  # a mid-run Prometheus scrape, live
            body = urllib.request.urlopen(url, timeout=10).read().decode()
            wanted = ("repro_admission_requests_total",
                      "repro_admission_admitted_total",
                      "repro_admission_ticks_total")
            print(f"\n-- mid-run scrape (t={t}) " + "-" * 28)
            for line in body.splitlines():
                if line.split("{")[0].split(" ")[0] in wanted:
                    print("  " + line)

    snap = engine.metrics_snapshot()
    tracer.close()
    server.close()

    eng, tel = snap["engine"], snap["telemetry"]
    print("\n== decisions " + "=" * 35)
    for label, n in (("admitted", tel["n_admit"]),
                     ("rejected (capacity)", tel["n_reject_capacity"]),
                     ("rejected (policy)", tel["n_reject_policy"])):
        print(f"  {label:<22} {int(n):>5}  {bar(n, tel['n_routed'])}")

    print("\n== occupancy (fraction of capacity, per window) ==")
    occ = tel["occupancy_hist"]
    for i, n in enumerate(occ):
        if n:
            lo, hi = i / len(occ), (i + 1) / len(occ)
            print(f"  [{lo:4.2f},{hi:4.2f}) {int(n):>4}  {bar(n, sum(occ))}")

    print("\n== aggregate staleness at decision time (windows) ==")
    for i, n in enumerate(tel["staleness_hist"]):
        if n:
            print(f"  {i:>2} stale {int(n):>5}  "
                  f"{bar(n, sum(tel['staleness_hist']))}")

    lat = eng["decision_latency_seconds"]
    print("\n== engine ==")
    print(f"  requests={eng['n_requests']} flushes={eng['n_flushes']} "
          f"refreshes={eng['n_refreshes']} ticks={eng['n_ticks']}")
    print(f"  decision latency p50={lat.percentile(0.5) * 1e3:.2f}ms "
          f"p99={lat.percentile(0.99) * 1e3:.2f}ms")
    print(f"  observed departures={tel['obs']['departed']:.0f} "
          f"scale-outs={tel['obs']['n_scaleouts']:.0f}")

    with open(trace_path, encoding="utf-8") as f:
        records = [json.loads(line) for line in f]
    print(f"\n== decision trace ({len(records)} records at {trace_path}) ==")
    for rec in records[:3]:
        print("  " + json.dumps(rec))
    assert len(records) == eng["n_requests"]


if __name__ == "__main__":
    main()
