"""Quickstart: the paper's admission policies in 60 seconds.

Builds a small simulated cluster, runs the industry-baseline threshold policy
(zeroth moment) against the paper's second-moment (Cantelli) policy at the
same SLA target, and prints the utilization gap — the paper's headline result.

  PYTHONPATH=src python examples/quickstart.py

Set REPRO_SMOKE=1 (the CI docs job does) to shrink the horizon and run
count so the script finishes in seconds.
"""
import os

import jax
import numpy as np

from repro.core import (AZURE_PRIORS, SECOND, ZEROTH, geometric_grid,
                        make_policy)
from repro.sim import SimConfig, make_run

SMOKE = os.environ.get("REPRO_SMOKE") == "1"


def main():
    days, n_runs = (60, 2) if SMOKE else (180, 4)
    cfg = SimConfig(capacity=1_000.0, arrival_rate=0.05,
                    horizon_hours=days * 24.0, dt=24.0, max_slots=256,
                    max_arrivals=4, priors=AZURE_PRIORS)
    grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3, 24)
    keys = jax.random.split(jax.random.PRNGKey(0), n_runs)

    results = {}
    for name, kind, pol in [
        ("zeroth(baseline)", ZEROTH,
         make_policy(ZEROTH, threshold=450.0, capacity=cfg.capacity)),
        ("second(paper)", SECOND,
         make_policy(SECOND, rho=0.15, capacity=cfg.capacity)),
    ]:
        run = make_run(cfg, grid, kind)
        m = jax.vmap(lambda k: run(k, pol))(keys)
        util = float(np.mean(np.asarray(m.utilization)))
        fails = int(np.asarray(m.failed_requests).sum())
        reqs = int(np.asarray(m.total_requests).sum())
        results[name] = util
        print(f"{name:18s} utilization={util:.3f} "
              f"scale-out failures={fails}/{reqs}")

    gain = results["second(paper)"] / results["zeroth(baseline)"] - 1
    print(f"\nsecond-moment policy lifts utilization by {100 * gain:.0f}% "
          f"relative (paper: ~30% at full scale)")


if __name__ == "__main__":
    main()
