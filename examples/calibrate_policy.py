"""Calibration in 60 seconds: tune a policy to the SLA, re-tune per scenario.

Three short acts (see docs/tuning.md for the full guide):

  1. calibrate the second-moment policy's Cantelli rho to an SLA target
     with ``repro.tuning.calibrate`` — the whole candidate grid in one
     batched pass, CI-aware stopping;
  2. re-tune the same policy against a flash-crowd scenario's own replayed
     arrivals and print the robustness gap (stationary-tuned vs re-tuned
     utilization at matched SLA);
  3. read the agg_refresh K-curve selection the benchmarks consume
     (``pick_agg_refresh`` over the committed BENCH artifact).

  PYTHONPATH=src python examples/calibrate_policy.py

Set REPRO_SMOKE=1 (the CI docs job does) to shrink everything so the
script finishes in seconds.
"""
import os

import jax

from repro.core import SECOND, geometric_grid
from repro.sim import make_config, make_run
from repro.traces import TraceSpec
from repro.tuning import (calibrate, calibrate_scenario, pick_agg_refresh,
                          replay_stream_batch)

SMOKE = os.environ.get("REPRO_SMOKE") == "1"


def main():
    days, n_runs, n_grid = (30, 2, 4) if SMOKE else (120, 4, 6)
    tau = 5e-3
    cfg = make_config(capacity=500.0, arrival_rate=0.08,
                      horizon_hours=days * 24.0, dt=24.0, max_slots=128,
                      max_arrivals=4, d_points=8)
    grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3, 12)
    run_fn = make_run(cfg, grid, SECOND)
    keys = jax.random.split(jax.random.PRNGKey(0), n_runs)

    # 1. calibrate to the SLA: one batched pass per stage, stop on CI
    res = calibrate(run_fn, SECOND, keys, capacity=cfg.capacity, tau=tau,
                    n_grid=n_grid, max_stages=2)
    print(f"calibrated rho={res.theta:.4g} util={res.utilization:.3f} "
          f"sla={res.sla_fail:.1e} (ci {res.sla_lo:.1e}..{res.sla_hi:.1e}) "
          f"<= tau={tau:.0e} [{len(res.stages)} stage(s), {res.n_sims} sims]")

    # 2. the same policy under a flash crowd: robustness vs re-tuned
    replay_cfg = cfg._replace(max_arrivals=8)
    spec = TraceSpec(horizon_hours=cfg.horizon_hours,
                     arrival_rate=cfg.arrival_rate,
                     max_deployments=256, max_events=8)
    streams, run_keys, _ = replay_stream_batch(
        jax.random.PRNGKey(1), jax.random.PRNGKey(2), "flash_crowd",
        spec, replay_cfg, n_runs)
    cal = calibrate_scenario(
        make_run(replay_cfg, grid, SECOND), SECOND, "flash_crowd",
        streams, run_keys, capacity=cfg.capacity, tau=tau,
        stationary_theta=res.theta, n_grid=n_grid, max_stages=1)
    print(f"flash_crowd: stationary-tuned util={cal.stationary_util:.3f} "
          f"(sla={cal.stationary_sla:.1e}) -> re-tuned "
          f"util={cal.retuned.utilization:.3f} "
          f"(rho={cal.retuned.theta:.4g}, sla={cal.retuned.sla_fail:.1e}); "
          f"gap={cal.util_gap:+.3f}")

    # 3. per-scale agg_refresh from the measured K-curve (hand-picked value
    # is only the fallback when no curve is recorded for the scale)
    for scale, hand in (("tiny", 4), ("quick", 8), ("full", 12)):
        recorded = pick_agg_refresh(scale, fallback=-1) != -1
        k = pick_agg_refresh(scale, fallback=hand)
        src = "measured K-curve" if recorded else "hand-picked fallback"
        print(f"agg_refresh[{scale}] = {k}  ({src})")


if __name__ == "__main__":
    main()
