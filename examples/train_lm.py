"""End-to-end training driver example: train a reduced llama3.2 config for a
few hundred steps on CPU with checkpointing and an injected failure +
automatic restart (the fault-tolerance path).

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import shutil

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-1b")
    args_outer = ap.parse_args()

    ns = argparse.Namespace(
        arch=args_outer.arch, reduced=True, steps=args_outer.steps, batch=8,
        seq_len=128, microbatches=2, lr=1e-3, ckpt_dir="/tmp/repro_example_ckpt",
        ckpt_every=50, log_every=25, resume=False, compress=False,
        fail_at=[args_outer.steps // 2],  # inject one failure mid-run
        seed=0)
    shutil.rmtree(ns.ckpt_dir, ignore_errors=True)
    final = train(ns)
    assert final == args_outer.steps
    print(f"trained to step {final} (through 1 injected failure + restart)")


if __name__ == "__main__":
    main()
