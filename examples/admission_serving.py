"""The integrated story: the paper's admission controller gating elastic
model-serving jobs on a TPU cluster.

Jobs (deployments) are serving fleets of the assigned architectures; their
chip usage scales stochastically (replica scale-outs). A cluster using the
baseline threshold policy must hold large idle reserves; the second-moment
policy admits more jobs at the same scale-out SLA. Also demonstrates the §7
variance-based pricing rule: labeled workloads are cheaper for the user AND
better for utilization (Prop. 4).

The final section closes the loop *live*: a real continuous-batching
``ServeEngine`` (reduced llama3.2-1b) sits behind the online
``OnlineAdmissionEngine`` — jobs the policy admits submit their inference
requests into the shared decode loop, rejected jobs never touch it.

  PYTHONPATH=src python examples/admission_serving.py
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AZURE_PRIORS, SECOND, ZEROTH, belief_from_prior,
                        geometric_grid, make_policy)
from repro.core.pricing import mixture_moments, payment, variance_estimate
from repro.core.moments import MomentCurves, moment_curves
from repro.core.processes import sample_params, sample_pseudo_observations
from repro.core.belief import apply_pseudo_observations
from repro.sim import MIX_LABELED, MIX_UNLABELED, SimConfig, make_run


SMOKE = os.environ.get("REPRO_SMOKE") == "1"


def utilization(prior_mode, rho, seed=0):
    days = 30 if SMOKE else 120
    cfg = SimConfig(capacity=1_000.0, arrival_rate=0.05,
                    horizon_hours=days * 24.0, dt=24.0, max_slots=256,
                    max_arrivals=4, priors=AZURE_PRIORS,
                    prior_mode=prior_mode, n_pseudo_obs=5)
    grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3, 24)
    pol = make_policy(SECOND, rho=rho, capacity=cfg.capacity, marginal=True)
    run = make_run(cfg, grid, SECOND)
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    m = jax.vmap(lambda k: run(k, pol))(keys)
    return float(np.mean(np.asarray(m.utilization)))


def serve_live(seed=0):
    """Gate a real decode loop end-to-end: the online admission engine
    decides which jobs may enter the continuous-batching ServeEngine."""
    from repro.models import build_model, get_config, reduced_config
    from repro.serve import (Arrival, OnlineAdmissionEngine, Request,
                             ServeEngine, default_policy_param)

    n_ticks = 4 if SMOKE else 12
    cfg = SimConfig(capacity=64.0, arrival_rate=0.2,
                    horizon_hours=n_ticks * 12.0, dt=12.0, max_slots=32,
                    max_arrivals=4, priors=AZURE_PRIORS)
    grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3, 16)
    rho = default_policy_param("second", cfg.capacity)
    adm = OnlineAdmissionEngine(
        cfg, grid, SECOND, make_policy(SECOND, rho=rho,
                                       capacity=cfg.capacity))

    mcfg = reduced_config(get_config("llama3.2-1b"))
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(seed))
    srv = ServeEngine(model, params, max_batch=4, max_seq=48)

    rng = np.random.default_rng(seed)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_ticks)
    admitted = rejected = tokens = rid = 0
    for t in range(n_ticks):
        adm.tick(keys[t])
        n_new = int(rng.poisson(cfg.arrival_rate * cfg.dt))
        futs = [adm.submit(Arrival.draw(jax.random.fold_in(keys[t], 100 + i),
                                        cfg))
                for i in range(min(n_new, cfg.max_arrivals))]
        adm.flush()
        for fut in futs:
            if fut.result():
                admitted += 1
                prompt = rng.integers(2, mcfg.vocab, 5).astype(np.int32)
                srv.submit(Request(rid=rid, prompt=prompt, max_new_tokens=6))
                rid += 1
            else:
                rejected += 1
        tokens += sum(len(r.out_tokens) for r in srv.run_until_drained())
    m = adm.metrics()
    print(f"{n_ticks} windows: admitted={admitted} rejected={rejected} "
          f"decode_tokens={tokens}")
    print(f"cluster util={float(m.utilization):.3f} "
          f"scaleout_failures={int(m.failed_requests)}"
          f"/{int(m.total_requests)}")


def main():
    print("== live: online admission gating a ServeEngine decode loop ==")
    serve_live()

    print("\n== admission control for an elastic serving fleet ==")
    u_lab = utilization(MIX_LABELED, rho=0.15)
    u_unl = utilization(MIX_UNLABELED, rho=0.15)
    print(f"second-moment policy, labeled job types:   util={u_lab:.3f}")
    print(f"second-moment policy, unlabeled (mixture): util={u_unl:.3f}")

    print("\n== §7 variance-based pricing: why users label ==")
    key = jax.random.PRNGKey(1)
    grid = geometric_grid(24.0, 8760.0, 24)
    prior = belief_from_prior(AZURE_PRIORS, (2,))
    types = sample_params(key, AZURE_PRIORS, (2,))
    obs = sample_pseudo_observations(key, types, AZURE_PRIORS, 5)
    bels = apply_pseudo_observations(prior, obs, AZURE_PRIORS)
    cores = jnp.asarray([4.0, 4.0])
    per_type = moment_curves(bels, cores, grid, AZURE_PRIORS)
    mix = mixture_moments(jnp.asarray([0.5, 0.5]), per_type)

    var_labeled = variance_estimate(per_type)        # [2]
    var_mix = variance_estimate(MomentCurves(mix.EL[None], mix.VL[None]))[0]
    pay_labeled = 0.5 * (payment(cores[0], var_labeled[0])
                         + payment(cores[1], var_labeled[1]))
    pay_mix = payment(cores[0], var_mix)
    print(f"avg hourly fee labeled:  {float(pay_labeled):.2f}")
    print(f"hourly fee unlabeled:    {float(pay_mix):.2f}")
    print("labeling is the dominant strategy (Prop. 4): "
          f"{float(pay_labeled) <= float(pay_mix) + 1e-6}")


if __name__ == "__main__":
    main()
