"""Drift-aware recalibration, end to end (paper §5.2's "re-tune whenever
the environment changes", made operational).

Four stages, each printing what it measured:

  1. **Streaming prior fits** — window a trace with
     ``traces.window_stats``, merge the windows, and show the merged fit is
     the batch fit (the sufficient-statistics layer is exact).
  2. **Calibrated drift detection** — Monte-Carlo-calibrate the CUSUM null
     on stationary replays, then watch the detector fire on a mid-trace
     lifetime drift (``drift_step``: mean lifetimes jump 2.5x).
  3. **Live detector** — the same detector riding the online admission
     engine's telemetry: every ``metrics_snapshot()`` scrape is one
     detector window.
  4. **Regret of re-tuning** — never / triggered-warm / oracle arms on the
     post-drift regime: what the detector + warm re-tune actually buy.

  PYTHONPATH=src python examples/drift_recalibration.py
"""
import os

import jax
import numpy as np

from repro.core import SECOND, geometric_grid, make_policy
from repro.sim import make_config
from repro.traces import (TraceSpec, fit_priors, merge_stats,
                          stats_to_priors, synthesize_scenario, window_stats)
from repro.tuning import (DriftDetector, calibrate_drift_detector,
                          detect_drift, run_drift_protocol,
                          window_channel_values)

SMOKE = os.environ.get("REPRO_SMOKE") == "1"

SPEC = TraceSpec(horizon_hours=240 * 24.0, arrival_rate=0.12,
                 max_deployments=2048, max_events=8)
WINDOW = 20 * 24.0   # 12 windows; drift_step onset at window 6


def streaming_fit():
    print("== 1. streaming prior fit ==")
    trace = synthesize_scenario(jax.random.PRNGKey(3), "baseline", SPEC)
    edges = np.linspace(0.0, float(SPEC.horizon_hours), 5)
    windows = [window_stats(trace, a, b)
               for a, b in zip(edges[:-1], edges[1:])]
    merged, _ = stats_to_priors(merge_stats(*windows))
    batch, _ = fit_priors(trace, source="observed")
    print(f"  4 windows merged:  mu=({merged.mu_shape:.4f}, "
          f"{merged.mu_rate:.4f}) nu={merged.nu:.2f}")
    print(f"  whole-trace batch: mu=({batch.mu_shape:.4f}, "
          f"{batch.mu_rate:.4f}) nu={batch.nu:.2f}")
    return trace


def offline_detection():
    print("== 2. calibrated drift detection ==")
    null = calibrate_drift_detector(jax.random.PRNGKey(7), SPEC,
                                    window_hours=WINDOW,
                                    n_reps=4 if SMOKE else 8, alpha=0.1)
    print(f"  null: threshold={null.threshold:.2f} (alpha={null.alpha}, "
          f"{null.n_reps} stationary replays)")
    for scen in ("baseline", "drift_step"):
        tr = synthesize_scenario(jax.random.PRNGKey(11), scen, SPEC)
        rep = detect_drift(tr, null, window_hours=WINDOW)
        tail = " ".join(f"{s:.1f}" for s in rep.stats)
        print(f"  {scen:11s}: fired={rep.fired} window={rep.fired_window} "
              f"stats=[{tail}]")
    return null


def live_detector():
    print("== 3. detector riding the online engine ==")
    from repro.serve import OnlineAdmissionEngine
    from repro.serve.admission import Arrival
    from repro.tuning import DriftNull, channels_from_obs

    cfg = make_config(capacity=300.0, arrival_rate=0.1,
                      horizon_hours=8 * 24.0, dt=24.0, max_slots=64,
                      max_arrivals=4, telemetry=True)
    grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3, 12)
    pol = make_policy(SECOND, rho=0.3, capacity=cfg.capacity)

    # live channels are time-sliced telemetry *ratio rates*, a different
    # scale than the offline per-deployment means — a live deployment
    # calibrates its null on stationary scrape replays (same recipe as
    # calibrate_drift_detector, scrapes in place of trace windows). For
    # the demo we seed a rough null from the first scrape of a warmup
    # engine and just watch the statistic stay quiet under steady load.
    warm = OnlineAdmissionEngine(cfg, grid, SECOND, pol)
    key = jax.random.PRNGKey(1)
    key, k1 = jax.random.split(key)
    warm.tick(k1)
    obs0 = warm.metrics_snapshot()["telemetry"]["obs"]
    mean = channels_from_obs(obs0)
    null = DriftNull(
        mean=mean,
        std={c: max(abs(v), 1e-3) for c, v in mean.items()},
        threshold=8.0, alpha=0.1, slack=0.5, n_reps=1, n_windows=1)

    eng = OnlineAdmissionEngine(cfg, grid, SECOND, pol,
                                drift_detector=DriftDetector(null))
    for _ in range(cfg.n_steps):
        key, k1, k2 = jax.random.split(key, 3)
        eng.tick(k1)
        eng.submit(Arrival.draw(k2, cfg))
        eng.flush()
        d = eng.metrics_snapshot()["drift"]   # one scrape = one window
    print(f"  after {d['n_windows']} scrapes: stat={d['stat']:.2f} "
          f"threshold={d['threshold']:.2f} fired={bool(d['fired'])}")


def regret():
    print("== 4. regret of re-tuning (never / triggered / oracle) ==")
    # hot enough that the 2.5x post-drift load pushes the stationary theta
    # past the SLA — never-re-tuning must actually lose its credit here
    cfg = make_config(capacity=800.0, arrival_rate=0.08,
                      horizon_hours=60 * 24.0, dt=24.0, max_slots=128,
                      max_arrivals=5, agg_refresh_steps=1)
    grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3.0, 16)
    res = run_drift_protocol(
        jax.random.PRNGKey(0), kind=SECOND, cfg=cfg, grid=grid, spec=SPEC,
        tau=5e-3, window_hours=WINDOW, n_runs=3 if SMOKE else 4,
        n_grid=4 if SMOKE else 5, n_null_reps=4 if SMOKE else 8)
    print(f"  detector: fired_window={res.report.fired_window} "
          f"(onset {res.onset_window}, delay {res.delay_windows} windows)")
    for arm in (res.never, res.triggered, res.oracle):
        print(f"  {arm.name:9s}: theta={arm.theta:.4g} "
              f"feasible={arm.feasible} sla={arm.sla_fail:.1e} "
              f"credited_util={arm.util:.4f} regret={arm.regret:.4f}")
    print(f"  triggered within oracle CI "
          f"[{res.oracle_ci[0]:.4f}, {res.oracle_ci[1]:.4f}]: "
          f"{res.within_ci}")


if __name__ == "__main__":
    streaming_fit()
    offline_detection()
    live_detector()
    regret()
