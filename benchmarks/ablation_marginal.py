"""Paper Appendix E (Fig. 9): marginal-heuristic ablation — second-moment
policy with/without Def. 4 at 5 and 50 pseudo-observations. Paper: >3%
utilization gain from the heuristic at good priors; no effect at 0 obs."""
from __future__ import annotations

import time

from repro.core import SECOND
from repro.sim import PSEUDO

from .common import SCALES, csv_row, sim_config, tune_and_eval


def run(scale_name: str = "tiny", seed: int = 0) -> list:
    scale = SCALES[scale_name]
    rows = []
    levels = (5,) if scale_name == "tiny" else (5, 50)
    for n_obs in levels:
        for marginal in (True, False):
            cfg = sim_config(scale, prior_mode=PSEUDO, n_pseudo_obs=n_obs)
            t0 = time.time()
            res = tune_and_eval(scale, SECOND, cfg, marginal=marginal,
                                seed=seed + n_obs)
            tag = "with" if marginal else "without"
            rows.append(csv_row(
                f"ablation_marginal/obs{n_obs}_{tag}",
                (time.time() - t0) * 1e6,
                f"util={res['utilization']:.4f}"
                f"(ci {res['ci_lo']:.4f}:{res['ci_hi']:.4f})"
                f" param={res['param']:.4g} sla={res['sla_fail']:.2e}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
