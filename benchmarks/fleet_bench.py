"""Fleet routing benchmark: routers compared at matched fleet SLA.

A thin registration shim over ``scenarios.fleet_rows`` so the fleet rows run
independently of the (much more expensive) full scenario × policy sweep —
``python -m benchmarks.run --only fleet`` is what the CI smoke job and the
BENCH artifact refreshes use. Row names land under ``scenarios/fleet/``:
one per router (utilization / SLA / tuned rho / rejected-by-all at the
calibrated operating point) plus a trace-replayed fleet row.
"""
from __future__ import annotations

from . import scenarios


def run(scale_name: str = "tiny", seed: int = 0) -> list:
    return scenarios.fleet_rows(scale_name, seed)
