"""Shared benchmark machinery: scale presets, policy tuning, CSV rows.

The paper's experiments (c=20,000, 3 years, 500 runs, SLA 1e-4) need cluster
compute; the presets scale the system down while preserving the phenomena
(heavy-tailed deployment mix, tail-risk admissions). Utilizations are
comparable across policies within a preset; the paper-scale preset exists for
the full reproduction on bigger hardware.

Tuning follows the paper (§5.2 binary search subject to the SLA) as a
two-stage vmapped parameter sweep: evaluate all candidate thresholds in
parallel (PolicyParams is a traced pytree, so one compile serves every
candidate), pick the largest parameter whose *aggregate* failure rate meets
the scale-adjusted SLA, then refine once around it.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AZURE_PRIORS, FIRST, SECOND, ZEROTH, geometric_grid,
                        make_policy)
from repro.sim import SimConfig, bca_ci, make_run, sla_failure_rate


@dataclasses.dataclass(frozen=True)
class Scale:
    name: str
    capacity: float
    arrival_rate: float
    horizon_hours: float
    dt: float
    max_slots: int
    n_runs: int
    n_thresholds: int
    grid_points: int
    tau: float            # scale-adjusted SLA
    agg_refresh: int = 1  # aggregate-curve refresh interval (steps)


SCALES = {
    # calibrated so the paper's regime (cluster >> single deployment, tail
    # risk from early heavy arrivals) appears at CPU-runnable cost.
    # Horizons are chosen so agg_refresh divides the step count (456d / 548d
    # / 3y); the aggregate-refresh interval stays <= 4 days of sim time.
    "tiny": Scale("tiny", 2_500.0, 0.125, 456 * 24.0, 12.0, 768, 4, 4,
                  24, 1e-3, agg_refresh=4),
    "quick": Scale("quick", 5_000.0, 0.25, 548 * 24.0, 12.0, 1536, 8, 6,
                   32, 5e-4, agg_refresh=8),
    "full": Scale("full", 20_000.0, 1.0, 3.0 * 365 * 24, 6.0, 8192, 24, 8,
                  48, 1e-4, agg_refresh=12),
}


def sim_config(scale: Scale, **over) -> SimConfig:
    base = dict(capacity=scale.capacity, arrival_rate=scale.arrival_rate,
                horizon_hours=scale.horizon_hours, dt=scale.dt,
                max_slots=scale.max_slots, max_arrivals=5,
                priors=AZURE_PRIORS,
                agg_refresh_steps=scale.agg_refresh)
    base.update(over)
    return SimConfig(**base)


def grid_for(scale: Scale, cfg: SimConfig):
    return geometric_grid(cfg.dt, cfg.horizon_hours * 3.0, scale.grid_points)


def _isotonic(y: np.ndarray) -> np.ndarray:
    """Pool-adjacent-violators isotonic regression (nondecreasing fit)."""
    y = np.asarray(y, dtype=np.float64).copy()
    w = np.ones_like(y)
    blocks = [[i] for i in range(len(y))]
    vals = list(y)
    ws = list(w)
    i = 0
    while i < len(vals) - 1:
        if vals[i] > vals[i + 1] + 1e-18:
            tot = ws[i] + ws[i + 1]
            vals[i] = (vals[i] * ws[i] + vals[i + 1] * ws[i + 1]) / tot
            ws[i] = tot
            blocks[i].extend(blocks[i + 1])
            del vals[i + 1], ws[i + 1], blocks[i + 1]
            i = max(i - 1, 0)
        else:
            i += 1
    out = np.empty_like(y)
    for v, b in zip(vals, blocks):
        out[b] = v
    return out


def _eval_param_batch(run_fn, kind, params_vec, keys, capacity, marginal):
    """[T] params × [R] runs -> dict of [T, R] metrics arrays."""

    def one_param(p):
        pol = make_policy(int(kind), threshold=p, rho=p, capacity=capacity,
                          marginal=marginal)
        return jax.vmap(lambda k: run_fn(k, pol))(keys)

    metrics = jax.vmap(one_param)(params_vec)
    return metrics


def tune_and_eval(scale: Scale, kind: int, cfg: SimConfig, *,
                  marginal: bool = False, seed: int = 0,
                  lo: float = None, hi: float = None) -> dict:
    """Two-stage parallel sweep; returns tuned param + utilization CI."""
    grid = grid_for(scale, cfg)
    run_fn = make_run(cfg, grid, kind)
    keys = jax.random.split(jax.random.PRNGKey(seed), scale.n_runs)
    c = cfg.capacity
    if kind == SECOND:
        lo = np.log10(2e-4) if lo is None else lo
        hi = np.log10(0.9) if hi is None else hi
        to_param = lambda x: 10.0 ** x
    else:
        lo = 0.2 * c if lo is None else lo
        hi = (1.0 if kind == ZEROTH else 1.05) * c if hi is None else hi
        to_param = lambda x: x

    best = None
    t0 = time.time()
    n_pts = scale.n_thresholds + (2 if kind == SECOND else 0)
    for stage in range(2):
        xs = np.linspace(lo, hi, n_pts)
        params_vec = jnp.asarray([to_param(x) for x in xs], jnp.float32)
        m = _eval_param_batch(run_fn, kind, params_vec, keys, c, marginal)
        fails = np.asarray(m.failed_requests)     # [T, R]
        reqs = np.asarray(m.total_requests)
        utils = np.asarray(m.utilization)
        agg_fail = fails.sum(1) / np.maximum(reqs.sum(1), 1.0)
        # NOTE: we experimented with isotonic (PAV) smoothing of the
        # empirical failure curve here; at 4 runs it pools single-run flukes
        # into neighboring good parameters and is net harmful (see
        # EXPERIMENTS.md §Paper). The raw max-feasible rule + the paper's
        # importance sampling at --scale full is the statistically sound path.
        feasible = agg_fail <= scale.tau
        if feasible.any():
            idx = int(np.max(np.nonzero(feasible)[0]))
        else:
            idx = 0
        best = {
            "param": float(to_param(xs[idx])),
            "util": utils[idx],
            "agg_fail": float(agg_fail[idx]),
        }
        # refine around the chosen index
        span = (hi - lo) / (scale.n_thresholds - 1)
        lo, hi = xs[idx] - span, xs[idx] + span
    ci = bca_ci(best["util"], n_resamples=2_000)
    return {
        "kind": kind, "param": best["param"],
        "utilization": ci.estimate, "ci_lo": ci.lo, "ci_hi": ci.hi,
        "sla_fail": best["agg_fail"], "tau": scale.tau,
        "seconds": round(time.time() - t0, 1),
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
