"""Shared benchmark machinery: scale presets, policy tuning, CSV rows.

The paper's experiments (c=20,000, 3 years, 500 runs, SLA 1e-4) need cluster
compute; the presets scale the system down while preserving the phenomena
(heavy-tailed deployment mix, tail-risk admissions). Utilizations are
comparable across policies within a preset; the paper-scale preset exists for
the full reproduction on bigger hardware.

Tuning (paper §5.2: search subject to the SLA) lives in ``repro.tuning``:
``tune_and_eval`` here is a thin preset-aware wrapper around
``tuning.calibrate`` (whole-theta-grid batched pass, CI-aware stage
stopping) that adds the BCa utilization interval benchmarks report.

Each preset's ``agg_refresh`` is only the *hand-picked fallback* for the
aggregate-refresh interval: ``sim_config`` asks
``tuning.pick_agg_refresh`` first, which selects K from the measured
utilization/SLA-slack K-curve recorded in BENCH_<scale>.json (see
``benchmarks/tuning_bench.py``).
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.core import SECOND, AZURE_PRIORS, geometric_grid
from repro.sim import SimConfig, bca_ci, make_run
from repro.tuning import calibrate, pick_agg_refresh


@dataclasses.dataclass(frozen=True)
class Scale:
    name: str
    capacity: float
    arrival_rate: float
    horizon_hours: float
    dt: float
    max_slots: int
    n_runs: int
    n_thresholds: int
    grid_points: int
    tau: float            # scale-adjusted SLA
    agg_refresh: int = 1  # hand-picked refresh-interval fallback; the
                          # measured K-curve wins when recorded (sim_config)


SCALES = {
    # calibrated so the paper's regime (cluster >> single deployment, tail
    # risk from early heavy arrivals) appears at CPU-runnable cost.
    # Horizons are chosen so agg_refresh divides the step count (456d / 548d
    # / 3y); the aggregate-refresh interval stays <= 4 days of sim time.
    "tiny": Scale("tiny", 2_500.0, 0.125, 456 * 24.0, 12.0, 768, 4, 4,
                  24, 1e-3, agg_refresh=4),
    "quick": Scale("quick", 5_000.0, 0.25, 548 * 24.0, 12.0, 1536, 8, 6,
                   32, 5e-4, agg_refresh=8),
    "full": Scale("full", 20_000.0, 1.0, 3.0 * 365 * 24, 6.0, 8192, 24, 8,
                  48, 1e-4, agg_refresh=12),
}


def sim_config(scale: Scale, **over) -> SimConfig:
    """Preset -> SimConfig. ``agg_refresh_steps`` comes from the measured
    K-curve when one is recorded for this scale (``tuning.pick_agg_refresh``
    over the committed BENCH artifact); the preset's hand-picked value is
    only the fallback — and the safety net when overrides change the horizon
    so the recorded K no longer divides the step count."""
    base = dict(capacity=scale.capacity, arrival_rate=scale.arrival_rate,
                horizon_hours=scale.horizon_hours, dt=scale.dt,
                max_slots=scale.max_slots, max_arrivals=5,
                priors=AZURE_PRIORS)
    base.update(over)
    if "agg_refresh_steps" not in over:
        probe = SimConfig(**base)
        base["agg_refresh_steps"] = pick_agg_refresh(
            scale.name, fallback=scale.agg_refresh, n_steps=probe.n_steps)
    return SimConfig(**base)


def grid_for(scale: Scale, cfg: SimConfig):
    return geometric_grid(cfg.dt, cfg.horizon_hours * 3.0, scale.grid_points)


def tune_and_eval(scale: Scale, kind: int, cfg: SimConfig, *,
                  marginal: bool = False, seed: int = 0,
                  lo: float = None, hi: float = None) -> dict:
    """Preset-aware ``tuning.calibrate`` + the BCa utilization interval.

    One compile serves every candidate (PolicyParams is traced); the whole
    theta grid runs as a single device-sharded batch, and refinement stops
    once the SLA estimate's CI separates from the scale's tau. Raw
    max-feasible selection on purpose — isotonic (PAV) smoothing of the
    empirical failure curve pools single-run flukes into neighboring good
    parameters at small run counts and is net harmful; the paper's
    importance sampling at --scale full is the statistically sound path.
    """
    grid = grid_for(scale, cfg)
    run_fn = make_run(cfg, grid, kind)
    keys = jax.random.split(jax.random.PRNGKey(seed), scale.n_runs)
    t0 = time.time()
    res = calibrate(
        run_fn, kind, keys, capacity=cfg.capacity, tau=scale.tau,
        lo=lo, hi=hi,
        n_grid=scale.n_thresholds + (2 if kind == SECOND else 0),
        max_stages=2, marginal=marginal)
    ci = bca_ci(res.util_runs, n_resamples=2_000)
    return {
        "kind": kind, "param": res.theta,
        "utilization": ci.estimate, "ci_lo": ci.lo, "ci_hi": ci.hi,
        "sla_fail": res.sla_fail, "tau": scale.tau,
        "seconds": round(time.time() - t0, 1),
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
