"""Online admission serving rows: decisions/sec and decision latency, live.

Measures ``serve.admission.OnlineAdmissionEngine`` — the long-lived jitted
engine with donated state and a micro-batching front-end — against the naive
per-request path (full aggregate recompute + width-1 decision per arrival,
i.e. admission without the incrementally-maintained aggregate):

  * ``serve/<scale>/engine`` / ``serve/<scale>/naive`` — decisions/sec and
    p50/p99 per-micro-batch decision latency at the reference offered load,
    with the occupied-slot count (cluster state size) recorded.
  * ``serve/<scale>/speedup`` — the micro-batched-over-naive ratio (the
    acceptance bar is >= 2x at the quick preset).
  * ``serve/<scale>/load=...`` — engine throughput vs offered load (arrivals
    per ``dt`` window).
  * ``serve/<scale>/engine|naive/slots=...`` — the same measurement at a
    quarter of the preset's slot table: the naive path's per-decision cost
    scales with cluster state size, the micro-batched path's does not.
  * ``serve/<scale>/sharded`` — the same engine with the slot table sharded
    over 8 virtual devices (``shards=8``, run in a subprocess under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``); decisions are
    bit-for-bit the unsharded engine's, so this row measures pure sharding
    overhead at one-device scale (the win is capacity, not speed, on CPU).
  * ``serve/<scale>/deadline_flush`` — the SLO-aware flush scheduler under
    nominal (paced, sub-width) load: recorded p50/p99 submit→decision
    latency from the engine's own histogram, which must meet the configured
    SLO with zero deadline-miss counter increments.
  * ``serve/<scale>/operating_point/<kind>`` — the tuned (theta, capacity,
    tau) operating point re-published from the artifact's own
    ``tuning/calibrate/<kind>`` rows; these rows are what
    ``launch/admission_daemon.py`` reads for its default thresholds
    (``serve.admission.load_operating_point``).

Under ``REPRO_SMOKE=1`` everything shrinks to a seconds-scale synthetic
preset so CI exercises the full row machinery on every PR.
"""
from __future__ import annotations

import json
import os
import re
import time

import jax
import numpy as np

from repro.core import SECOND, make_policy
from repro.serve import (OnlineAdmissionEngine, format_operating_derived,
                         operating_row_name)
from repro.sim import draw_arrival_stream

from .common import SCALES, Scale, csv_row, grid_for, sim_config

SMOKE_SCALE = Scale("smoke", 800.0, 0.05, 60 * 24.0, 24.0, 128, 2, 3,
                    16, 5e-3, agg_refresh=1)

_THETA_RE = re.compile(r"theta=(?P<th>[-\d.e+]+)")

#: fallback rho when the artifact has no tuned second-moment row yet
FALLBACK_RHO = 0.15


def _scale_for(scale_name: str) -> Scale:
    if os.environ.get("REPRO_SMOKE") == "1":
        return SMOKE_SCALE
    return SCALES[scale_name]


def _calibrated_thetas(scale_name: str) -> dict:
    """theta per policy kind from the committed artifact's own
    ``tuning/calibrate/<kind>`` rows (no simulation here)."""
    path = os.environ.get("REPRO_BENCH_JSON") or os.path.join(
        os.path.dirname(__file__), "..", f"BENCH_{scale_name}.json")
    try:
        with open(path, encoding="utf-8") as f:
            rows = json.load(f).get("rows", [])
    except (OSError, ValueError):
        return {}
    out = {}
    for row in rows:
        name = row.get("name", "")
        if not name.startswith("tuning/calibrate/"):
            continue
        m = _THETA_RE.match(row.get("derived", ""))
        if m:
            out[name.rsplit("/", 1)[1]] = float(m["th"])
    return out


def _offered_stream(cfg, width: int, n_slices: int, seed: int):
    """Pre-draw ``n_slices`` saturated width-``width`` arrival slices (the
    offered load; arrival_rate pushed high so every lane is occupied)."""
    stream_cfg = cfg._replace(max_arrivals=width,
                              horizon_hours=n_slices * cfg.dt,
                              arrival_rate=10.0 * width / cfg.dt,
                              agg_refresh_steps=1)
    stream = draw_arrival_stream(jax.random.PRNGKey(seed + 7), stream_cfg)
    return [jax.tree.map(lambda x: x[t], stream) for t in range(n_slices)]


def _measure(cfg, grid, pol, *, naive: bool, width: int, n_ticks: int,
             per_tick: int, seed: int, shards: int = 1) -> dict:
    """Drive the engine ``n_ticks`` windows at ``per_tick`` offered arrivals
    each; time every decision call (micro-batch of ``width``, or width-1 on
    the naive path). Returns decisions/sec, latency quantiles, occupancy."""
    eng = OnlineAdmissionEngine(cfg, grid, SECOND, pol, naive=naive,
                                micro_batch=width,
                                shards=shards if shards > 1 else None)
    bw = 1 if naive else width
    batches_per_tick = max(per_tick // bw, 1)
    slices = _offered_stream(cfg, bw, (n_ticks + 1) * batches_per_tick, seed)
    valid = np.ones(bw, bool)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_ticks + 1)

    # warmup window: compile tick/refresh/decide outside the timed region
    eng.tick(keys[0])
    eng.decide_slice(slices[0], valid)

    it = iter(slices[1:])
    lat = []
    for t in range(n_ticks):
        eng.tick(keys[t + 1])
        for _ in range(batches_per_tick):
            sl = next(it)
            t0 = time.perf_counter()
            eng.decide_slice(sl, valid)      # np accept => device sync
            lat.append(time.perf_counter() - t0)
    lat_s = np.asarray(lat)
    n_dec = lat_s.size * bw
    occupied = int(np.sum(np.asarray(eng._cs.slots.alive)))
    return {
        "decisions_per_s": n_dec / float(np.sum(lat_s)),
        "p50_ms": float(np.percentile(lat_s, 50) * 1e3),
        "p99_ms": float(np.percentile(lat_s, 99) * 1e3),
        "us_per_decision": float(np.sum(lat_s)) * 1e6 / n_dec,
        "occupied": occupied,
        "n_decisions": int(n_dec),
    }


def _measure_telemetry_pair(cfg, grid, pol, *, width: int, n_ticks: int,
                            per_tick: int, seed: int) -> tuple[float, float]:
    """Per-decision p50 microseconds with the telemetry rider off vs on.

    The two engines are driven in lockstep over the *same* ticks and
    arrival slices, with the timing order alternating per batch, so clock
    drift and allocator noise hit both sides equally — two sequential
    ``_measure`` passes cannot resolve a few-percent rider cost. Medians,
    not means: the overhead budget is about the steady-state decision path,
    not stray tail events.
    """
    engines = [
        OnlineAdmissionEngine(cfg._replace(telemetry=tel), grid, SECOND, pol,
                              naive=False, micro_batch=width)
        for tel in (False, True)]
    batches_per_tick = max(per_tick // width, 1)
    slices = _offered_stream(cfg, width, (n_ticks + 1) * batches_per_tick,
                             seed)
    valid = np.ones(width, bool)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_ticks + 1)
    for eng in engines:                    # compile outside the timed region
        eng.tick(keys[0])
        eng.decide_slice(slices[0], valid)
    lat = [[], []]
    it = iter(slices[1:])
    for t in range(n_ticks):
        for eng in engines:
            eng.tick(keys[t + 1])
        for b in range(batches_per_tick):
            sl = next(it)
            order = (0, 1) if (t * batches_per_tick + b) % 2 == 0 else (1, 0)
            for i in order:
                t0 = time.perf_counter()
                engines[i].decide_slice(sl, valid)
                lat[i].append(time.perf_counter() - t0)
    return tuple(float(np.median(lat[i]) * 1e6 / width) for i in (0, 1))


def _sharded_entry(scale_name: str, seed: int, width: int, n_ticks: int,
                   per_tick: int, shards: int) -> dict:
    """Subprocess body for the sharded row: rebuild the preset's config and
    run ``_measure`` with the slot table sharded over ``shards`` devices.
    Must run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (the parent drives it via ``_measure_sharded``)."""
    scale = _scale_for(scale_name)
    cfg = sim_config(scale)
    grid = grid_for(scale, cfg)
    rho = _calibrated_thetas(scale.name).get("second", FALLBACK_RHO)
    pol = make_policy(SECOND, rho=rho, capacity=cfg.capacity)
    return _measure(cfg, grid, pol, naive=False, width=width,
                    n_ticks=n_ticks, per_tick=per_tick, seed=seed,
                    shards=shards)


def _measure_sharded(scale_name: str, *, seed: int, width: int, n_ticks: int,
                     per_tick: int, shards: int = 8) -> dict:
    """Run ``_sharded_entry`` in a subprocess with ``shards`` virtual CPU
    devices (the parent process already initialized jax with one device, so
    the device count cannot be changed in-process)."""
    import subprocess
    import sys

    repo_root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={shards}")
    env["PYTHONPATH"] = os.pathsep.join(
        [repo_root, os.path.join(repo_root, "src"),
         env.get("PYTHONPATH", "")])
    code = ("import json, sys\n"
            "from benchmarks.serve_bench import _sharded_entry\n"
            "a = json.loads(sys.argv[1])\n"
            "print(json.dumps(_sharded_entry(**a)))\n")
    args = dict(scale_name=scale_name, seed=seed, width=width,
                n_ticks=n_ticks, per_tick=per_tick, shards=shards)
    out = subprocess.run([sys.executable, "-c", code, json.dumps(args)],
                         env=env, capture_output=True, text=True,
                         timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def _measure_deadline(cfg, grid, pol, *, width: int, slo_ms: float,
                      n_requests: int, seed: int) -> dict:
    """Drive the deadline scheduler at nominal load: paced sub-width
    ``submit()``s (so the deadline trigger — not the width trigger — fires)
    and the engine's own recorded submit→decision latency histogram as the
    measurement. Misses are the engine's counter, not a recomputation."""
    from repro.serve import Arrival

    eng = OnlineAdmissionEngine(cfg, grid, SECOND, pol, micro_batch=width,
                                flush_slo_ms=slo_ms)
    stream_cfg = cfg._replace(max_arrivals=1,
                              horizon_hours=(n_requests + 1) * cfg.dt,
                              arrival_rate=10.0 / cfg.dt,
                              agg_refresh_steps=1)
    stream = draw_arrival_stream(jax.random.PRNGKey(seed + 7), stream_cfg)
    arrivals = [Arrival.from_stream(stream, t, 0)
                for t in range(n_requests + 1)]
    eng.tick(jax.random.PRNGKey(seed))
    # compile the decide path outside the recorded region (decide_slice via
    # _decide does not touch the latency histogram or the miss counter)
    eng._decide([arrivals[0]])
    pace_s = (slo_ms / 1e3) / (2.0 * width)   # nominal: sub-width per SLO
    eng.start()
    futs = []
    for a in arrivals[1:]:
        futs.append(eng.submit(a))
        time.sleep(pace_s)
    for f in futs:
        f.result(timeout=60)
    eng.stop()
    snap = eng.metrics_snapshot()["engine"]
    hist = snap["decision_latency_seconds"]
    return {
        "p50_ms": hist.percentile(0.5) * 1e3,
        "p99_ms": hist.percentile(0.99) * 1e3,
        "mean_us": hist.sum / max(hist.total, 1) * 1e6,
        "misses": int(snap["deadline_misses"]),
        "n_flushes": int(snap["n_flushes"]),
        "n_decisions": int(hist.total),
    }


def _derived(m: dict, width: int, slots: int) -> str:
    return (f"decisions_per_s={m['decisions_per_s']:.0f}"
            f" p50_ms={m['p50_ms']:.3f} p99_ms={m['p99_ms']:.3f}"
            f" occupied={m['occupied']} width={width} slots={slots}"
            f" n={m['n_decisions']}")


def run(scale_name: str = "tiny", seed: int = 0) -> list:
    scale = _scale_for(scale_name)
    smoke = scale.name == "smoke"
    width = 4 if smoke else 16
    n_ticks = 3 if smoke else 8
    per_tick = 4 * width                  # reference offered load
    cfg = sim_config(scale)
    grid = grid_for(scale, cfg)
    thetas = _calibrated_thetas(scale.name)
    rho = thetas.get("second", FALLBACK_RHO)
    pol = make_policy(SECOND, rho=rho, capacity=cfg.capacity)
    rows = []

    # -- headline: micro-batched engine vs naive per-request recompute ------
    m_eng = _measure(cfg, grid, pol, naive=False, width=width,
                     n_ticks=n_ticks, per_tick=per_tick, seed=seed)
    rows.append(csv_row(f"serve/{scale.name}/engine", m_eng["us_per_decision"],
                        _derived(m_eng, width, cfg.max_slots)))
    m_nv = _measure(cfg, grid, pol, naive=True, width=width,
                    n_ticks=n_ticks, per_tick=per_tick, seed=seed)
    rows.append(csv_row(f"serve/{scale.name}/naive", m_nv["us_per_decision"],
                        _derived(m_nv, 1, cfg.max_slots)))
    speedup = m_eng["decisions_per_s"] / m_nv["decisions_per_s"]
    rows.append(csv_row(f"serve/{scale.name}/speedup", 0.0,
                        f"x={speedup:.2f} engine={m_eng['decisions_per_s']:.0f}"
                        f" naive={m_nv['decisions_per_s']:.0f}"
                        f" target_x=2"))

    # -- telemetry overhead: the device rider must be ~free -----------------
    us_off, us_on = _measure_telemetry_pair(cfg, grid, pol, width=width,
                                            n_ticks=2 * n_ticks,
                                            per_tick=per_tick, seed=seed)
    overhead = (us_on / us_off - 1.0) * 100
    rows.append(csv_row(
        f"serve/{scale.name}/telemetry=on", us_on,
        f"p50_us={us_on:.1f} width={width} slots={cfg.max_slots}"
        f" overhead_pct={overhead:.1f} target_pct=3"))
    rows.append(csv_row(
        f"serve/{scale.name}/telemetry=off", us_off,
        "overhead_pct=0.0 rider_compiled_out=true"))

    # -- throughput vs offered load -----------------------------------------
    for mult, label in ((1, "light"), (16, "heavy")):
        m = _measure(cfg, grid, pol, naive=False, width=width,
                     n_ticks=n_ticks, per_tick=mult * width, seed=seed)
        rows.append(csv_row(
            f"serve/{scale.name}/load={mult * width}",
            m["us_per_decision"], _derived(m, width, cfg.max_slots)))

    # -- cluster state size: a quarter of the slot table --------------------
    small = cfg._replace(max_slots=max(cfg.max_slots // 4, width))
    for naive, tag in ((False, "engine"), (True, "naive")):
        m = _measure(small, grid, pol, naive=naive, width=width,
                     n_ticks=n_ticks, per_tick=per_tick, seed=seed)
        rows.append(csv_row(
            f"serve/{scale.name}/{tag}/slots={small.max_slots}",
            m["us_per_decision"],
            _derived(m, 1 if naive else width, small.max_slots)))

    # -- device-sharded slot table (8 virtual devices, subprocess) ----------
    m_sh = _measure_sharded(scale.name, seed=seed, width=width,
                            n_ticks=n_ticks, per_tick=per_tick, shards=8)
    rows.append(csv_row(
        f"serve/{scale.name}/sharded", m_sh["us_per_decision"],
        _derived(m_sh, width, cfg.max_slots) + " shards=8"))

    # -- deadline-aware flush scheduler at nominal load ---------------------
    slo_ms = 200.0 if smoke else 250.0
    m_dl = _measure_deadline(cfg, grid, pol, width=width, slo_ms=slo_ms,
                             n_requests=6 * width, seed=seed)
    rows.append(csv_row(
        f"serve/{scale.name}/deadline_flush", m_dl["mean_us"],
        f"p50_ms={m_dl['p50_ms']:.3f} p99_ms={m_dl['p99_ms']:.3f}"
        f" slo_ms={slo_ms:.0f} misses={m_dl['misses']}"
        f" n_flushes={m_dl['n_flushes']} n={m_dl['n_decisions']}"
        f" target_misses=0"))

    # -- tuned operating points for the daemon ------------------------------
    for kind_name, theta in sorted(thetas.items()):
        rows.append(csv_row(
            operating_row_name(scale.name, kind_name), 0.0,
            format_operating_derived(theta, cfg.capacity, scale.tau)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
