"""Paper Table 2: zeroth vs first vs second moment policy utilization
(thresholds tuned to the SLA via ``repro.tuning.calibrate`` — one batched
device-sharded theta-grid pass per stage, CI-aware stopping — through the
``common.tune_and_eval`` preset wrapper; 95% BCa CIs). Paper values at full
scale: 50.45% / 66.19% / 67.32% (+31.2% / +33.4% relative)."""
from __future__ import annotations

import time

from repro.core import FIRST, SECOND, ZEROTH

from .common import SCALES, csv_row, sim_config, tune_and_eval

NAMES = {ZEROTH: "zeroth", FIRST: "first", SECOND: "second"}


def run(scale_name: str = "tiny", seed: int = 0) -> list:
    scale = SCALES[scale_name]
    cfg = sim_config(scale)
    rows, results = [], {}
    for kind in (ZEROTH, FIRST, SECOND):
        t0 = time.time()
        res = tune_and_eval(scale, kind, cfg, seed=seed)
        results[kind] = res
        us = (time.time() - t0) * 1e6
        rel = ""
        if kind != ZEROTH and results[ZEROTH]["utilization"] > 0:
            gain = (res["utilization"] / results[ZEROTH]["utilization"] - 1.0)
            rel = f"+{100 * gain:.1f}%_vs_zeroth"
        rows.append(csv_row(
            f"table2/{NAMES[kind]}", us,
            f"util={res['utilization']:.4f}"
            f"(ci {res['ci_lo']:.4f}:{res['ci_hi']:.4f})"
            f" param={res['param']:.4g} sla={res['sla_fail']:.2e}"
            f"<=tau={res['tau']:.0e} {rel}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
