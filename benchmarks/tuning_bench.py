"""Calibration subsystem rows: batched SLA tuning + the agg_refresh K-curve.

Two row families, both consumed programmatically (not just read by humans):

  * ``tuning/calibrate/<kind>`` — ``repro.tuning.calibrate`` on the preset's
    prior-sampled arrivals for every policy kind: tuned theta, utilization,
    measured SLA with its cluster-robust CI, and how many stages/simulations
    the CI-aware stopping actually spent.
  * ``tuning/kcurve/<scale>/K=<k>`` — utilization and SLA-slack vs the
    aggregate-refresh interval K, at the K=min reference theta (fixed) and
    re-tuned per K. These rows ARE the persistence format for
    ``tuning.pick_agg_refresh``: once recorded in BENCH_<scale>.json (or
    BENCH_quick.json), ``benchmarks/common.sim_config`` selects the
    preset's ``agg_refresh_steps`` from them instead of the hand-picked
    value. ``tuning/pick_agg_refresh/<scale>`` reports the selection made
    from the freshly measured curve.

Under ``REPRO_SMOKE=1`` (the CI docs job) everything shrinks to a
seconds-scale synthetic preset named ``smoke`` — the row *machinery*
(sweep, serialization, selection round-trip) is exercised on every PR
without the quick preset's minutes; smoke rows are written to a throwaway
JSON and never consulted by ``pick_agg_refresh`` for real scales.
"""
from __future__ import annotations

import os
import time

import jax

from repro.core import FIRST, SECOND, ZEROTH
from repro.sim import make_run
from repro.tuning import (calibrate, format_kcurve_derived, kcurve_divisors,
                          kcurve_row_name, parse_kcurve_rows, pick_from_curve,
                          sweep_kcurve)

from .common import SCALES, Scale, csv_row, grid_for, sim_config

NAMES = {ZEROTH: "zeroth", FIRST: "first", SECOND: "second"}

#: K-curve cost scales with (1 + n_grid * stages) * n_runs sims per K; the
#: second-moment policy is the paper's headline, so the curve is measured on
#: it (threshold-policy curves respond to K the same way through tuning).
KCURVE_KIND = SECOND

SMOKE_SCALE = Scale("smoke", 800.0, 0.05, 60 * 24.0, 24.0, 128, 2, 3,
                    16, 5e-3, agg_refresh=1)


def _scale_for(scale_name: str) -> Scale:
    if os.environ.get("REPRO_SMOKE") == "1":
        return SMOKE_SCALE
    return SCALES[scale_name]


def run(scale_name: str = "tiny", seed: int = 0) -> list:
    scale = _scale_for(scale_name)
    smoke = scale.name == "smoke"
    cfg = sim_config(scale)
    grid = grid_for(scale, cfg)
    keys = jax.random.split(jax.random.PRNGKey(seed), scale.n_runs)
    rows = []

    # -- calibrate every policy kind on the preset ---------------------------
    for kind in (ZEROTH, FIRST, SECOND):
        t0 = time.time()
        res = calibrate(make_run(cfg, grid, kind), kind, keys,
                        capacity=cfg.capacity, tau=scale.tau,
                        n_grid=scale.n_thresholds + (2 if kind == SECOND
                                                     else 0),
                        max_stages=2)
        rows.append(csv_row(
            f"tuning/calibrate/{NAMES[kind]}", (time.time() - t0) * 1e6,
            f"theta={res.theta:.6g} util={res.utilization:.4f}"
            f" sla={res.sla_fail:.2e}(ci {res.sla_lo:.1e}:{res.sla_hi:.1e})"
            f"<=tau={res.tau:.0e} stages={len(res.stages)}"
            f" sims={res.n_sims} separated={int(res.separated)}"))

    # -- the agg_refresh K-curve --------------------------------------------
    # each K re-jits the blocked scan, so smoke keeps the candidate set tiny
    ks = kcurve_divisors(cfg.n_steps, k_max=4 if smoke else 16)
    t0 = time.time()
    points = sweep_kcurve(cfg, grid, KCURVE_KIND, keys, tau=scale.tau, ks=ks,
                          n_grid=scale.n_thresholds, max_stages=1)
    us_total = (time.time() - t0) * 1e6
    for p in points:
        rows.append(csv_row(kcurve_row_name(scale.name, p.k),
                            us_total / max(len(points), 1),
                            format_kcurve_derived(p)))
    # selection round-trip through the row serialization — exactly what
    # pick_agg_refresh will read back from the committed artifact
    parsed = parse_kcurve_rows(
        [{"name": r.split(",", 2)[0], "derived": r.split(",", 2)[2]}
         for r in rows], scale.name)
    chosen = pick_from_curve(parsed)
    rows.append(csv_row(
        f"tuning/pick_agg_refresh/{scale.name}", 0.0,
        f"K={chosen} candidates={ks} hand_picked={scale.agg_refresh}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
