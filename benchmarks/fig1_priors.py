"""Paper Fig. 1: value of deployment-specific priors — first/second moment
policies (with marginal heuristic) at 0/1/5/50 pseudo-observations. Paper:
1 obs lifts second-moment utilization to ~79.5%, 50 obs to ~83.8%."""
from __future__ import annotations

import time

from repro.core import FIRST, SECOND
from repro.sim import GLOBAL, PSEUDO

from .common import SCALES, csv_row, sim_config, tune_and_eval

OBS_LEVELS = (0, 1, 5, 50)


def run(scale_name: str = "tiny", seed: int = 0,
        obs_levels=None) -> list:
    scale = SCALES[scale_name]
    if obs_levels is None:  # CPU preset trims the costliest levels
        obs_levels = (0, 1, 5) if scale_name == "tiny" else OBS_LEVELS
    rows = []
    for kind, kname in ((FIRST, "first"), (SECOND, "second")):
        for n_obs in obs_levels:
            # the 0-observation point IS the global-prior baseline; say so
            # explicitly (PSEUDO with 0 obs is rejected by _validate_config)
            mode = PSEUDO if n_obs > 0 else GLOBAL
            cfg = sim_config(scale, prior_mode=mode, n_pseudo_obs=n_obs)
            t0 = time.time()
            res = tune_and_eval(scale, kind, cfg, marginal=True,
                                seed=seed + n_obs)
            rows.append(csv_row(
                f"fig1/{kname}_obs{n_obs}", (time.time() - t0) * 1e6,
                f"util={res['utilization']:.4f}"
                f"(ci {res['ci_lo']:.4f}:{res['ci_hi']:.4f})"
                f" param={res['param']:.4g} sla={res['sla_fail']:.2e}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
