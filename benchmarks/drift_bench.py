"""Drift-recalibration rows: detector operating point + the regret ledger.

Row family ``tuning/drift/<scale>/*`` (see ``repro.tuning.drift``):

  * ``.../detector`` — the Monte-Carlo-calibrated CUSUM pass over one
    drifting replay: threshold at the calibrated alpha, the window it fired
    in, and the detection delay past the drift onset.
  * ``.../regret/{never,triggered,oracle}`` — the three re-tuning arms
    evaluated on the post-drift regime under common random numbers: tuned
    theta, measured SLA, raw and *credited* utilization (infeasible arms
    earn zero; the triggered arm pays the detection delay at the
    incumbent's credit), and regret against the oracle. The oracle row
    carries its utilization CI; the acceptance claim — triggered regret
    below never-re-tune regret, triggered utilization within the oracle's
    CI — is readable straight off the committed rows.

The drift presets run *hotter* than the headline scales (higher arrival
rate per core of capacity): the shipped drift direction (mu down →
lifetimes up → load up) must actually push the stationary-tuned operating
point past the SLA, otherwise never-re-tuning loses nothing and the rows
claim nothing. Under ``REPRO_SMOKE=1`` (the CI docs job) everything shrinks
to a seconds-scale preset — same protocol, same row shapes, throwaway JSON.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax

from repro.core import SECOND, geometric_grid
from repro.traces import TraceSpec
from repro.tuning import run_drift_protocol

from .common import Scale, csv_row, sim_config

#: the paper's headline policy is the one worth re-tuning
DRIFT_KIND = SECOND

#: loaded variants of the scale presets (n_thresholds doubles as the cold
#: calibration grid; agg_refresh pinned to 1 so overridden horizons always
#: divide). tau is looser than the headline scales: the post-drift regime
#: is meant to *violate* it for the stationary theta, not be unreachable
#: for the re-tuned ones.
DRIFT_SCALES = {
    "tiny": Scale("tiny", 1_200.0, 0.15, 120 * 24.0, 24.0, 256, 4, 5,
                  24, 2e-3, agg_refresh=1),
    "quick": Scale("quick", 2_500.0, 0.3, 240 * 24.0, 12.0, 768, 8, 6,
                   32, 1e-3, agg_refresh=1),
    "full": Scale("full", 10_000.0, 1.0, 365 * 24.0, 6.0, 4096, 16, 8,
                  48, 5e-4, agg_refresh=1),
}

SMOKE_SCALE = Scale("smoke", 800.0, 0.08, 60 * 24.0, 24.0, 128, 3, 4,
                    16, 5e-3, agg_refresh=1)

#: drifting-workload replay the detector watches, per scale: 12 windows,
#: drift_step onset at window 6
DRIFT_SPECS = {
    "smoke": (TraceSpec(horizon_hours=240 * 24.0, arrival_rate=0.12,
                        max_deployments=2048, max_events=8), 20 * 24.0, 6),
    "tiny": (TraceSpec(horizon_hours=240 * 24.0, arrival_rate=0.12,
                       max_deployments=2048, max_events=8), 20 * 24.0, 6),
    "quick": (TraceSpec(horizon_hours=360 * 24.0, arrival_rate=0.2,
                        max_deployments=4096, max_events=8), 30 * 24.0, 8),
    "full": (TraceSpec(horizon_hours=360 * 24.0, arrival_rate=0.5,
                       max_deployments=16384, max_events=8), 30 * 24.0, 16),
}


def _preset(scale_name: str) -> tuple[Scale, TraceSpec, float, int]:
    if os.environ.get("REPRO_SMOKE") == "1":
        scale_name = "smoke"
        scale = SMOKE_SCALE
    else:
        scale = DRIFT_SCALES[scale_name]
    spec, window, n_null = DRIFT_SPECS[scale_name]
    return scale, spec, window, n_null


def run(scale_name: str = "tiny", seed: int = 0) -> list:
    scale, spec, window, n_null = _preset(scale_name)
    cfg = sim_config(scale, agg_refresh_steps=scale.agg_refresh)
    grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3.0, scale.grid_points)

    t0 = time.time()
    res = run_drift_protocol(
        jax.random.PRNGKey(seed), kind=DRIFT_KIND, cfg=cfg, grid=grid,
        spec=spec, tau=scale.tau, window_hours=window,
        n_runs=scale.n_runs, n_grid=scale.n_thresholds,
        n_null_reps=n_null)
    us_total = (time.time() - t0) * 1e6

    fired_w = -1 if res.report.fired_window is None else res.report.fired_window
    rows = [csv_row(
        f"tuning/drift/{scale.name}/detector", us_total,
        f"fired={int(res.report.fired)} fired_window={fired_w}"
        f" onset={res.onset_window} delay={res.delay_windows}"
        f" delay_frac={res.delay_frac:.3f}"
        f" threshold={res.null.threshold:.3f} alpha={res.null.alpha:g}"
        f" windows={res.report.n_windows} scenario={res.scenario}")]
    extra = {
        "never": f" theta0={res.theta0:.6g}",
        "triggered": f" within_oracle_ci={int(res.within_ci)}",
        "oracle": (f" ci={res.oracle_ci[0]:.4f}:{res.oracle_ci[1]:.4f}"
                   f" tau={scale.tau:.0e}"),
    }
    for arm in (res.never, res.triggered, res.oracle):
        rows.append(csv_row(
            f"tuning/drift/{scale.name}/regret/{arm.name}",
            us_total * arm.n_sims / max(res.n_sims, 1),
            f"theta={arm.theta:.6g} feasible={int(arm.feasible)}"
            f" sla={arm.sla_fail:.2e} util_raw={arm.util_raw:.4f}"
            f" util={arm.util:.4f} regret={arm.regret:.4f}"
            f" sims={arm.n_sims}" + extra[arm.name]))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
