"""Scenario × policy sweep over synthetic traces: robustness *and* the cost
of honoring the SLA.

For every registered trace scenario (diurnal modulation, flash crowds,
heavy-tail lifetime inflation, correlated batches) and every policy kind,
two operating points run on the **same** replay streams and run keys:

  * *stationary-tuned* — parameters fixed at the stationary regime's values
    (by default the paper's full-scale Table-2 tuned values as capacity
    fractions; ``tune=True`` re-tunes them per scale): how robust is a
    policy to non-stationary arrivals it was never tuned for?
  * *re-tuned* — ``repro.tuning.calibrate_scenario`` re-calibrates the
    parameter against the scenario's own arrivals at the matched
    scale-adjusted SLA: what utilization is actually available there, and
    what does closing the robustness gap cost?

Both land in one row per (scenario, kind): ``util_stat``/``sla_stat`` vs
``util_ret``/``sla_ret`` plus the re-tuned theta. Also reports the
generate→fit prior round-trip error, an information-model comparison (the
same baseline trace ensemble replayed under GLOBAL / §6 PSEUDO / §7 labeled
beliefs via the trace-level stratified importance plan), and the key-level
importance-sampling plan routed through the sharded ``run_keyed_batch``.

Cost: the re-tuned point multiplies the replay count by the theta grid
(scenarios x policies x (1 + n_thresholds * stages) x n_runs full replays)
— tens of minutes at the quick scale; use ``--only`` to skip it when
iterating on the cheap kernel benchmarks.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.core import (AZURE_PRIORS, FIRST, SECOND, ZEROTH, fleet_policy,
                        make_policy)
from repro.sim import (GLOBAL, MIX_LABELED, PSEUDO, ROUTERS, FleetConfig,
                       estimate_from_plan, make_fleet_run,
                       make_importance_plan, make_run,
                       make_trace_ensemble_plan, run_keyed_batch,
                       simulate_plan, simulate_trace_plan, sla_failure_rate)
from repro.traces import (TraceArrivalSource, TraceSpec, fit_priors,
                          prior_relative_errors, scenario_names,
                          synthesize_scenario, trace_to_stream)
from repro.tuning import calibrate, calibrate_scenario, replay_stream_batch

from .common import SCALES, csv_row, grid_for, sim_config, tune_and_eval

NAMES = {ZEROTH: "zeroth", FIRST: "first", SECOND: "second"}

#: replay caps per-step arrivals well above the prior-sampled preset so that
#: flash-crowd bursts stress the *policy*, not the columnar buffer
REPLAY_MAX_ARRIVALS = 16

#: stationary-regime policy parameters as fractions of capacity (zeroth and
#: first thresholds) / the Cantelli rho, from the paper's full-scale tuned
#: Table-2 values (8864/20000, 14223/20000, 0.112). The sweep holds these
#: fixed across scenarios so it measures robustness, not tuning.
PAPER_RATIO_PARAMS = {ZEROTH: 8864.0 / 20000.0, FIRST: 14223.0 / 20000.0,
                      SECOND: 0.112}


def trace_spec_for(cfg) -> TraceSpec:
    expected = cfg.arrival_rate * cfg.horizon_hours
    cap = 1 << max(int(np.ceil(np.log2(max(expected * 2.0, 64.0)))), 6)
    return TraceSpec(horizon_hours=cfg.horizon_hours,
                     arrival_rate=cfg.arrival_rate,
                     max_deployments=int(cap), max_events=16,
                     priors=AZURE_PRIORS)


#: heterogeneous fleet split of the preset capacity (a big, two mid, a small
#: cluster) — heterogeneity is what separates capacity-aware routers from
#: the random baseline
FLEET_FRACS = (0.4, 0.3, 0.2, 0.1)
FLEET_ROUTERS = ("least_utilized", "power_of_two", "random", "cascade")


def fleet_rows(scale_name: str = "tiny", seed: int = 0) -> list:
    """Fleet router comparison at matched fleet SLA (+ trace replay).

    The preset capacity is split into a heterogeneous fleet
    (``FLEET_FRACS``); for every router the shared second-moment policy is
    calibrated against the *fleet* SLA target in one flattened
    device-sharded pass (``tuning.calibrate`` with a ``fleet_policy``
    closure — per-cluster thresholds stay capacity-proportional), so the
    reported utilizations compare routers at the same risk budget. A final
    row replays a synthesized baseline trace into the fleet: arrivals come
    from the trace, the router still decides the cluster.

    Under ``REPRO_SMOKE=1`` (the CI docs job) everything shrinks to a
    two-cluster fleet on a short horizon so the rows land in seconds.
    """
    scale = SCALES[scale_name]
    smoke = os.environ.get("REPRO_SMOKE") == "1"
    if smoke:
        cfg = sim_config(scale, horizon_hours=60 * 24.0, dt=24.0,
                         max_slots=128)
        n_runs, n_grid = 2, 3
        fracs = (0.6, 0.4)
    else:
        cfg = sim_config(scale)
        n_runs, n_grid = scale.n_runs, scale.n_thresholds
        fracs = FLEET_FRACS
    caps = tuple(round(f * scale.capacity, 1) for f in fracs)
    base = cfg._replace(max_slots=max(cfg.max_slots // 2, 64))
    fcfg = FleetConfig(base=base, capacities=caps)
    grid = grid_for(scale, cfg)
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, n_runs)
    # one closure for every router: keeps tuning's compiled-wrapper cache hot
    policy_fn = lambda th: fleet_policy(SECOND, capacities=caps, rho=th)

    rows = []
    thetas = {}
    for rname in FLEET_ROUTERS:
        t0 = time.time()
        run_fn = make_fleet_run(fcfg, grid, SECOND, router=ROUTERS[rname]())
        cal = calibrate(run_fn, SECOND, keys,
                        capacity=fcfg.total_capacity, tau=scale.tau,
                        n_grid=n_grid, max_stages=1, policy_fn=policy_fn)
        thetas[rname] = cal.theta
        # one extra pass at the winner for the routing diagnostics the
        # CalibrationResult does not carry (rejected-by-all, spread)
        m = run_keyed_batch(run_fn, keys, policy_fn(cal.theta))
        rej_all = float(np.mean(np.asarray(m.rejected_by_all)))
        spread = np.asarray(m.per_cluster.utilization).mean(axis=0)
        rows.append(csv_row(
            f"scenarios/fleet/{rname}", (time.time() - t0) * 1e6,
            f"util={cal.utilization:.4f} sla={cal.sla_fail:.2e}"
            f" rho={cal.theta:.4g} feasible={cal.feasible}"
            f" rej_all={rej_all:.1f}"
            f" util_spread={spread.max() - spread.min():.3f}"
            f" n_clusters={len(caps)} tau={scale.tau:g}"))

    # -- a recorded trace replayed INTO the fleet (arrivals routed live) -----
    t0 = time.time()
    spec = trace_spec_for(cfg)
    trace = synthesize_scenario(jax.random.fold_in(key, 7), "baseline", spec)
    source = TraceArrivalSource(trace)
    # replay widens the per-step arrival cap like the scenario sweep does:
    # trace bursts should stress the router+policy, not the columnar buffer
    rcfg = FleetConfig(base=base._replace(max_arrivals=REPLAY_MAX_ARRIVALS),
                       capacities=caps)
    run_fn = make_fleet_run(rcfg, grid, SECOND,
                            router=ROUTERS["least_utilized"](),
                            arrival_source=source)
    theta = thetas["least_utilized"]
    m = run_keyed_batch(run_fn, keys, policy_fn(theta))
    util = float(np.mean(np.asarray(m.utilization)))
    sla = sla_failure_rate(np.asarray(m.failed_requests),
                           np.asarray(m.total_requests))
    rows.append(csv_row(
        "scenarios/fleet/replay_least_utilized", (time.time() - t0) * 1e6,
        f"util={util:.4f} sla={sla:.2e} rho={theta:.4g}"
        f" dropped={source.n_dropped(rcfg)}"))
    return rows


def run(scale_name: str = "tiny", seed: int = 0, tune: bool = False) -> list:
    scale = SCALES[scale_name]
    cfg = sim_config(scale)
    grid = grid_for(scale, cfg)
    spec = trace_spec_for(cfg)
    key = jax.random.PRNGKey(seed)
    rows = []

    # -- generate -> fit -> Table-1 round-trip ------------------------------
    big = spec._replace(max_deployments=max(spec.max_deployments, 8192),
                        arrival_rate=max(
                            spec.arrival_rate,
                            8192.0 / (2.0 * spec.horizon_hours)))
    trace = synthesize_scenario(key, "baseline", big)
    for source in ("latent", "observed"):
        t0 = time.time()
        fitted, _ = fit_priors(trace, source=source)
        errs = prior_relative_errors(fitted, AZURE_PRIORS)
        worst = max(errs, key=errs.get)
        rows.append(csv_row(
            f"scenarios/fit_roundtrip_{source}",
            (time.time() - t0) * 1e6,
            f"max_relerr={errs[worst]:.3f}({worst})"
            f" nu={fitted.nu:.3f} delta={fitted.delta:.4f}"))

    # -- fixed stationary-regime policy parameters ---------------------------
    if tune:
        tuned = {kind: tune_and_eval(scale, kind, cfg, seed=seed)["param"]
                 for kind in (ZEROTH, FIRST, SECOND)}
    else:
        tuned = {ZEROTH: PAPER_RATIO_PARAMS[ZEROTH] * cfg.capacity,
                 FIRST: PAPER_RATIO_PARAMS[FIRST] * cfg.capacity,
                 SECOND: PAPER_RATIO_PARAMS[SECOND]}

    # -- replay every scenario: stationary-tuned vs re-tuned at matched SLA --
    replay_cfg = cfg._replace(max_arrivals=REPLAY_MAX_ARRIVALS)
    runs = {kind: make_run(replay_cfg, grid, kind)
            for kind in (ZEROTH, FIRST, SECOND)}
    base_util = {}
    for si, scen in enumerate(scenario_names()):
        # trace keys and run keys from distinct roots: a shared root would
        # make the scan key equal to the trace-synthesis key (split shares
        # its prefix), correlating within-run events with replayed arrivals
        streams, run_keys, dropped = replay_stream_batch(
            jax.random.fold_in(key, 100 + si),
            jax.random.fold_in(key, 500 + si),
            scen, spec, replay_cfg, scale.n_runs)
        for kind in (ZEROTH, FIRST, SECOND):
            t0 = time.time()
            cal = calibrate_scenario(
                runs[kind], kind, scen, streams, run_keys,
                capacity=replay_cfg.capacity, tau=scale.tau,
                stationary_theta=tuned[kind],
                n_grid=scale.n_thresholds, max_stages=1)
            if scen == "baseline":
                base_util[kind] = cal.stationary_util
                rel = ""
            else:
                rel = (" vs_baseline="
                       f"{cal.stationary_util / base_util[kind] - 1.0:+.1%}"
                       if base_util.get(kind) else "")
            rows.append(csv_row(
                f"scenarios/{scen}/{NAMES[kind]}",
                (time.time() - t0) * 1e6,
                f"util_stat={cal.stationary_util:.4f}"
                f" sla_stat={cal.stationary_sla:.2e}"
                f" util_ret={cal.retuned.utilization:.4f}"
                f" sla_ret={cal.retuned.sla_fail:.2e}"
                f" theta_ret={cal.retuned.theta:.4g}"
                f" dropped={dropped}{rel}"))

    # -- information-model replay: GLOBAL vs PSEUDO vs labeled ---------------
    # The paper's headline (§6-§7): richer provider information about the
    # same arrivals buys utilization at the same policy. Replay one baseline
    # trace ensemble under each information model (arrivals identical;
    # beliefs differ) through the trace-level stratified importance plan, so
    # the comparison oversamples the arrival-side tail instead of averaging
    # it away.
    n_ens = max(scale.n_runs, 4)
    traces = [synthesize_scenario(tk, "baseline", spec)
              for tk in jax.random.split(jax.random.fold_in(key, 900), n_ens)]
    pol2 = make_policy(SECOND, rho=tuned[SECOND], capacity=cfg.capacity)
    for mode, mname in ((GLOBAL, "global"), (PSEUDO, "pseudo"),
                        (MIX_LABELED, "labeled")):
        t0 = time.time()
        mcfg = replay_cfg._replace(prior_mode=mode, n_pseudo_obs=5)
        streams = [trace_to_stream(tr, mcfg,
                                   key=jax.random.fold_in(key, 910 + ti))[0]
                   for ti, tr in enumerate(traces)]
        plan = make_trace_ensemble_plan(jax.random.fold_in(key, 920), mcfg,
                                        grid, streams, quotas=(4, 2, 2),
                                        runs_per_trace=2)
        metrics = simulate_trace_plan(make_run(mcfg, grid, SECOND), plan,
                                      streams, pol2)
        est = estimate_from_plan(plan, metrics)
        rows.append(csv_row(
            f"scenarios/info_model/{mname}", (time.time() - t0) * 1e6,
            f"util={est['utilization']:.4f} sla={est['sla_fail']:.2e}"
            f" n_runs={est['n_runs']} ensemble={n_ens}"))

    # -- importance plan routed through the sharded keyed batch --------------
    t0 = time.time()
    plan = make_importance_plan(jax.random.fold_in(key, 17), cfg, grid,
                                quotas=(4, 4, 4), n_probe=128, probe_batch=64)
    pol = make_policy(ZEROTH, threshold=tuned[ZEROTH], capacity=cfg.capacity)
    metrics = simulate_plan(make_run(cfg, grid, ZEROTH), plan, pol)
    est = estimate_from_plan(plan, metrics)
    rows.append(csv_row(
        "scenarios/importance_routed", (time.time() - t0) * 1e6,
        f"sla={est['sla_fail']:.2e} util={est['utilization']:.4f}"
        f" n_runs={est['n_runs']} sharded=run_keyed_batch"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
