"""Kernel/algorithm microbenchmarks (CPU wall time; TPU numbers come from the
roofline analysis of the dry-run artifacts).

Measures the beyond-paper algorithmic wins that are observable on CPU:
  * continuous O(N) moment curves vs the paper's 5x600-step discrete cascade
  * vectorized policy evaluation throughput (deployments x horizon per sec)
Plus interpret-mode correctness timing of each Pallas kernel (not a perf
number on CPU; recorded so regressions in kernel complexity show up).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AZURE_PRIORS, belief_from_prior, geometric_grid
from repro.core.moments import (aggregate_moment_curves, moment_curves,
                                moment_curves_discrete)

from .common import SCALES, csv_row, grid_for, sim_config


def _timeit(fn, *args, n=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6  # us


def _sim_loop_rows(n_steps: int = 96, reps: int = 5) -> list:
    """Steps/sec of the 'quick'-preset simulator hot loop, second-moment
    policy — an *aggregation ablation*: per-slot aggregate recomputed from
    all slots every step (agg_backend=reference, refresh=1, the seed's
    aggregation strategy) vs the fused-aggregate fast path (blocked refresh
    + incremental candidate folding). Both lanes share the rest of this
    codebase's loop (hybrid samplers, vectorized placement), so the ratio
    isolates the aggregation/refresh change; the seed loop was additionally
    slower in those shared parts. The horizon is truncated to ``n_steps``
    steps so the benchmark stays CPU-friendly; per-step shapes (slot array,
    grid, arrival stream) are exactly the preset's.
    """
    from repro.core import SECOND, make_policy
    from repro.sim import AGG_REFERENCE, make_run

    scale = SCALES["quick"]
    base = sim_config(scale, horizon_hours=n_steps * scale.dt)
    grid = grid_for(scale, base)
    pol = make_policy(SECOND, rho=0.1, capacity=base.capacity)

    def steps_per_sec(cfg):
        run_fn = make_run(cfg, grid, SECOND)
        jax.block_until_ready(run_fn(jax.random.PRNGKey(0), pol))  # compile
        best = float("inf")
        for i in range(reps):
            t0 = time.time()
            jax.block_until_ready(run_fn(jax.random.PRNGKey(1 + i), pol))
            best = min(best, time.time() - t0)  # ambient load only slows runs
        return cfg.n_steps / best

    sps_ref = steps_per_sec(base._replace(agg_backend=AGG_REFERENCE,
                                          agg_refresh_steps=1))
    sps_fast = steps_per_sec(base)
    return [
        csv_row("sim/quick_loop_per_slot_recompute", 1e6 / sps_ref,
                f"steps_per_s={sps_ref:.1f} agg=reference refresh=1 "
                "(aggregation ablation baseline)"),
        csv_row("sim/quick_loop_fused_aggregate", 1e6 / sps_fast,
                f"steps_per_s={sps_fast:.1f} agg=fused "
                f"refresh={base.agg_refresh_steps} "
                f"speedup_vs_per_slot_recompute={sps_fast / sps_ref:.2f}x"),
    ]


def run(scale_name: str = "tiny", seed: int = 0) -> list:
    rows = []
    d = 1024
    bel = belief_from_prior(AZURE_PRIORS, (d,))
    cores = jnp.full((d,), 5.0)
    grid = geometric_grid(6.0, 3 * 365 * 24.0, 48)

    cont = jax.jit(lambda b, c: moment_curves(b, c, grid, AZURE_PRIORS,
                                              d_points=32))
    us_cont = _timeit(cont, bel, cores)
    rows.append(csv_row("kernels/moment_curves_continuous_jnp", us_cont,
                        f"D={d} N=48 curves_per_s={d / (us_cont/1e6):.3g}"))

    # paper-faithful cascade: 5 horizons x 600 uniform steps
    disc = jax.jit(lambda b, c: [
        moment_curves_discrete(b, c, 600, h / 600, AZURE_PRIORS)
        for h in (24.0, 168.0, 720.0, 8760.0, 26280.0)])
    us_disc = _timeit(disc, bel, cores, n=2)
    rows.append(csv_row("kernels/moment_curves_paper_cascade", us_disc,
                        f"D={d} 5x600steps speedup_vs_continuous="
                        f"{us_disc / us_cont:.1f}x"))

    from repro.kernels.moment_curves.ops import moment_curves_kernel
    kern = jax.jit(lambda b, c: moment_curves_kernel(
        b, c, grid, AZURE_PRIORS, d_points=32, interpret=True))
    us_kern = _timeit(kern, bel, cores, n=2)
    rows.append(csv_row("kernels/moment_curves_pallas_interpret", us_kern,
                        "correctness-path; TPU perf in roofline"))

    # fused-aggregate curves: masked sum over alive slots, no [S, N]
    # intermediate, vs the per-slot reference path summed outside
    alive = jax.random.bernoulli(jax.random.PRNGKey(seed), 0.5, (d,))
    ref_agg = jax.jit(lambda b, c, al: jax.tree.map(
        lambda x: jnp.sum(x * al.astype(jnp.float32)[:, None], 0),
        moment_curves(b, c, grid, AZURE_PRIORS, d_points=32)))
    us_ref_agg = _timeit(ref_agg, bel, cores, alive)
    fus_agg = jax.jit(lambda b, c, al: aggregate_moment_curves(
        b, c, al, grid, AZURE_PRIORS, d_points=32))
    us_fus_agg = _timeit(fus_agg, bel, cores, alive)
    rows.append(csv_row("kernels/aggregate_moment_curves_fused", us_fus_agg,
                        f"D={d} N=48 vs_per_slot_reference="
                        f"{us_ref_agg / us_fus_agg:.2f}x"))

    rows.extend(_sim_loop_rows())

    from repro.kernels.flash_attention.ref import attention_ref
    b, s, h, kvh, dh = 1, 1024, 8, 2, 128
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, kvh, dh), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, kvh, dh), jnp.bfloat16)
    ref = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us_ref = _timeit(ref, q, k, v, n=3)
    flops = 4 * b * h * s * s * dh / 2
    rows.append(csv_row("kernels/attention_ref_cpu", us_ref,
                        f"s={s} gflops={flops/1e9:.1f} "
                        f"cpu_gflops_s={flops / (us_ref/1e6) / 1e9:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
