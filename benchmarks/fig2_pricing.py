"""Paper Fig. 2: variance-based pricing — second-moment policy with users
holding two deployment types (5 observations each): labeled (users declare
the type => per-type posterior) vs unlabeled (provider evaluates the
mixture). Paper: 83% vs 77% utilization."""
from __future__ import annotations

import time

from repro.core import SECOND
from repro.sim import MIX_LABELED, MIX_UNLABELED

from .common import SCALES, csv_row, sim_config, tune_and_eval


def run(scale_name: str = "tiny", seed: int = 0) -> list:
    scale = SCALES[scale_name]
    rows = []
    for mode, mname in ((MIX_LABELED, "labeled"), (MIX_UNLABELED, "unlabeled")):
        cfg = sim_config(scale, prior_mode=mode, n_pseudo_obs=5)
        t0 = time.time()
        res = tune_and_eval(scale, SECOND, cfg, marginal=True, seed=seed)
        rows.append(csv_row(
            f"fig2/{mname}", (time.time() - t0) * 1e6,
            f"util={res['utilization']:.4f}"
            f"(ci {res['ci_lo']:.4f}:{res['ci_hi']:.4f})"
            f" param={res['param']:.4g} sla={res['sla_fail']:.2e}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
