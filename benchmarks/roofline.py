"""Roofline analysis (§g): three terms per (arch × shape × mesh) cell from
the dry-run artifacts.

    compute_s    = HLO_FLOPs/device   / 197e12  (bf16 peak per v5e chip)
    memory_s     = HLO_bytes/device   / 819e9   (HBM bandwidth)
    collective_s = wire_bytes/device  / 50e9    (per-link ICI)

HLO quantities use the depth-extrapolated values (launch/dryrun.py probes fix
XLA's count-while-bodies-once behavior). MODEL_FLOPS = 6·N_active·tokens
(train) / 2·N_active·tokens (inference). The reported fraction is
ideal_time / max(term)s — the MFU the cell could reach if it hit its binding
roofline exactly.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def _inner_scan_flops_correction(rec: dict) -> float:
    """Analytic per-device FLOPs that XLA's body-once counting misses inside
    *sequence* scans (SSD chunk loops, sLSTM time loop, chunked attention).

    Returns extra FLOPs/device to add to the extrapolated HLO count. Uses the
    arch config; train counts fwd+bwd (x3 with remat ~ x4 of fwd is folded
    into the multiplier below conservatively at 3x fwd).
    """
    from repro.models import get_config

    try:
        cfg = get_config(rec["arch"])
    except Exception:
        return 0.0
    n_dev = rec["n_devices"]
    tokens = (rec["global_batch"] * rec["seq_len"]
              if rec["kind"] in ("train", "prefill") else rec["global_batch"])
    mult = 3.0 if rec["kind"] == "train" else 1.0
    extra = 0.0
    q = 128  # SSD chunk
    if cfg.family == "hybrid" and rec["kind"] != "decode":
        # ssd intra-chunk: per token ~ Q*(2N + 2Dh) + state update 2*N*Dh
        n, dh = cfg.ssm_state, cfg.d_model // cfg.n_heads
        per_tok = cfg.n_heads * (q * (2 * n + 2 * dh) + 2 * n * dh)
        nc = max(rec["seq_len"] // q, 1)
        extra += cfg.n_layers * tokens * per_tok * (nc - 1) / nc * mult
    if cfg.family == "ssm" and rec["kind"] != "decode":
        dh = cfg.d_model // cfg.n_heads
        per_tok_m = cfg.n_heads * (q * 4 * dh + 2 * dh * (dh + 1))  # mLSTM
        per_tok_s = cfg.n_heads * 2 * dh * 4 * dh                   # sLSTM rec
        extra += (cfg.n_layers / 2) * tokens * (per_tok_m + per_tok_s) * mult
    if cfg.attn_chunk and rec["kind"] != "decode":
        # chunked attention scan: probes count one q-block of the S² term
        dh = cfg.resolved_head_dim
        att = 4 * tokens * rec["seq_len"] * cfg.n_heads * dh * 0.5
        nc = max(rec["seq_len"] // cfg.attn_chunk, 1)
        extra += cfg.n_layers * att * (nc - 1) / nc * mult
    return extra / n_dev


def analyze(rec: dict) -> dict:
    n_dev = rec["n_devices"]
    ex = rec.get("extrapolated") or {}
    flops = ex.get("flops") or rec["cost"].get("flops", 0.0)
    flops += _inner_scan_flops_correction(rec)
    bts = ex.get("bytes") or rec["cost"].get("bytes accessed", 0.0)
    wire = (ex.get("wire_bytes")
            if ex.get("wire_bytes") is not None
            else rec["collectives"]["total_wire_bytes"])
    compute_s = flops / PEAK_FLOPS
    memory_s = bts / HBM_BW
    collective_s = wire / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    tokens = (rec["global_batch"] * rec["seq_len"]
              if rec["kind"] in ("train", "prefill") else rec["global_batch"])
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * rec["n_active_params"] * tokens
    ideal_s = model_flops / (n_dev * PEAK_FLOPS)
    bound_s = max(terms.values())
    frac = ideal_s / bound_s if bound_s > 0 else 0.0
    useful = model_flops / (flops * n_dev) if flops else 0.0

    hints = {
        "compute": "compute-bound: cut redundant HLO flops (remat policy, "
                   "fused attention kernel) or raise per-chip utilization",
        "memory": "HBM-bound: shrink activation traffic (bf16 logits, fused "
                  "kernels, bigger blocks) or raise arithmetic intensity",
        "collective": "ICI-bound: reduce gather/reduce volume (2D sharding "
                      "balance, overlap, gradient compression, fewer "
                      "per-layer weight regathers)",
    }
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": model_flops, "hlo_flops_total": flops * n_dev,
        "useful_flops_ratio": useful, "roofline_fraction": frac,
        "bound_s": bound_s, "hint": hints[dominant],
        "hbm_gib_per_dev": rec["memory"].get("temp_size_in_bytes", 0) / 2**30,
    }


def load_records(art_dir: str = ART_DIR, mesh: str = None) -> list:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        mesh_name = "multi_pod" if "multi_pod" in path else "single_pod"
        if mesh and mesh_name != mesh:
            continue
        rec["mesh_name"] = mesh_name
        out.append(rec)
    return out


def table(mesh: str = "single_pod", art_dir: str = ART_DIR) -> str:
    recs = load_records(art_dir, mesh)
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPS | useful% | roofline_frac | temp GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        a = analyze(rec)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {a['compute_s']:.3e} | "
            f"{a['memory_s']:.3e} | {a['collective_s']:.3e} | "
            f"{a['dominant']} | {a['model_flops']:.3g} | "
            f"{100*a['useful_flops_ratio']:.0f}% | "
            f"{a['roofline_fraction']:.3f} | {a['hbm_gib_per_dev']:.1f} |")
    return "\n".join(lines)


def run(scale_name: str = "tiny", seed: int = 0) -> list:
    from .common import csv_row
    rows = []
    for rec in load_records():
        a = analyze(rec)
        rows.append(csv_row(
            f"roofline/{rec['mesh_name']}/{rec['arch']}/{rec['shape']}",
            a["bound_s"] * 1e6,
            f"dom={a['dominant']} frac={a['roofline_fraction']:.3f} "
            f"useful={a['useful_flops_ratio']:.2f} "
            f"c/m/x={a['compute_s']:.2e}/{a['memory_s']:.2e}/"
            f"{a['collective_s']:.2e}"))
    if not rows:
        rows.append(csv_row("roofline/no_artifacts", 0.0,
                            "run launch/dryrun.py first"))
    return rows


if __name__ == "__main__":
    import sys
    print(table(sys.argv[1] if len(sys.argv) > 1 else "single_pod"))
