"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Scale presets (see common.SCALES):
  tiny  (default) laptop-class, minutes
  quick           small-server, tens of minutes
  full            the paper's c=20,000 / 3-year / SLA 1e-4 setting

Usage: PYTHONPATH=src python -m benchmarks.run [--scale tiny] [--only table2]
                                               [--json BENCH_tiny.json]

``--json`` additionally records the rows (plus scale/seed metadata) to a
JSON file, so speedups land in a committable BENCH_<scale>.json artifact.
When the file already exists *for the same scale*, rows are merged by name
(matching rows replaced, new rows appended, everything else kept) — a
``--only`` subset run refreshes just its own rows instead of clobbering the
artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from . import (ablation_marginal, drift_bench, fig1_priors, fig2_pricing,
               fleet_bench, kernels_bench, roofline, scenarios, serve_bench,
               table2_policies, tuning_bench)

MODULES = {
    "kernels": kernels_bench,
    "roofline": roofline,
    "table2": table2_policies,
    "fig1": fig1_priors,
    "fig2": fig2_pricing,
    "ablation_marginal": ablation_marginal,
    "scenarios": scenarios,
    "fleet": fleet_bench,
    "tuning": tuning_bench,
    "serve": serve_bench,
    "drift": drift_bench,
}


def merge_records(path: str, scale: str, seed: int, total: float,
                  records: list):
    """Merge fresh rows into an existing artifact by name (same scale only —
    a different scale's artifact is simply replaced).

    Provenance stays honest across subset merges: rows carried over keep
    their own recorded ``seed``, the artifact-level ``seed`` degrades to
    ``"mixed"`` when runs disagree, and ``total_seconds`` accumulates the
    compute recorded in the artifact rather than pretending the last subset
    run measured everything."""
    try:
        with open(path, encoding="utf-8") as f:
            old = json.load(f)
    except (OSError, ValueError):
        return seed, round(total, 1), records
    if old.get("scale") != scale:
        return seed, round(total, 1), records
    fresh = {r["name"]: r for r in records}
    carried = sum(1 for r in old.get("rows", []) if r["name"] not in fresh)
    merged = [fresh.pop(r["name"], r) for r in old.get("rows", [])]
    merged += list(fresh.values())
    if carried == 0:
        # nothing survived from the old artifact: this run's provenance IS
        # the artifact's provenance
        return seed, round(total, 1), merged
    # rows vote with their own seed; legacy rows (no per-row field) carry
    # the old artifact header's seed
    seeds = {r.get("seed", old.get("seed")) for r in merged}
    seeds.discard(None)
    merged_seed = seeds.pop() if len(seeds) == 1 else "mixed"
    merged_total = round(float(old.get("total_seconds", 0.0)) + total, 1)
    return merged_seed, merged_total, merged


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=["tiny", "quick", "full"])
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset: " + ",".join(MODULES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to a BENCH_<scale>.json artifact")
    args = ap.parse_args()

    names = list(MODULES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    t0 = time.time()
    records = []
    for name in names:
        mod = MODULES[name]
        try:
            for row in mod.run(args.scale, args.seed):
                print(row, flush=True)
                bench, us, derived = row.split(",", 2)
                records.append({"name": bench, "us_per_call": float(us),
                                "derived": derived, "seed": args.seed})
        except Exception as e:  # pragma: no cover
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            raise
    total = time.time() - t0
    if args.json:
        seed, total_s, rows = args.seed, round(total, 1), records
        if os.path.exists(args.json):
            seed, total_s, rows = merge_records(args.json, args.scale,
                                                args.seed, total, records)
        with open(args.json, "w") as f:
            json.dump({"scale": args.scale, "seed": seed,
                       "total_seconds": total_s, "rows": rows}, f, indent=2)
        print(f"# wrote {args.json} ({len(rows)} rows)", file=sys.stderr)
    print(f"# total_seconds={total:.0f}", file=sys.stderr)


if __name__ == "__main__":
    main()
