"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Scale presets (see common.SCALES):
  tiny  (default) laptop-class, minutes
  quick           small-server, tens of minutes
  full            the paper's c=20,000 / 3-year / SLA 1e-4 setting

Usage: PYTHONPATH=src python -m benchmarks.run [--scale tiny] [--only table2]
                                               [--json BENCH_tiny.json]

``--json`` additionally records the rows (plus scale/seed metadata) to a
JSON file, so speedups land in a committable BENCH_<scale>.json artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from . import (ablation_marginal, fig1_priors, fig2_pricing, kernels_bench,
               roofline, scenarios, table2_policies)

MODULES = {
    "kernels": kernels_bench,
    "roofline": roofline,
    "table2": table2_policies,
    "fig1": fig1_priors,
    "fig2": fig2_pricing,
    "ablation_marginal": ablation_marginal,
    "scenarios": scenarios,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=["tiny", "quick", "full"])
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset: " + ",".join(MODULES))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows to a BENCH_<scale>.json artifact")
    args = ap.parse_args()

    names = list(MODULES) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    t0 = time.time()
    records = []
    for name in names:
        mod = MODULES[name]
        try:
            for row in mod.run(args.scale, args.seed):
                print(row, flush=True)
                bench, us, derived = row.split(",", 2)
                records.append({"name": bench, "us_per_call": float(us),
                                "derived": derived})
        except Exception as e:  # pragma: no cover
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            raise
    total = time.time() - t0
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"scale": args.scale, "seed": args.seed,
                       "total_seconds": round(total, 1), "rows": records},
                      f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    print(f"# total_seconds={total:.0f}", file=sys.stderr)


if __name__ == "__main__":
    main()
