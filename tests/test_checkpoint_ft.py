"""Checkpointing (atomic, async, resharding restore) + fault tolerance +
data pipeline + optimizer + compression tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer
from repro.data.pipeline import PipelineConfig, Prefetcher, make_batch
from repro.models import build_model, get_config, reduced_config
from repro.optim.adamw import AdamWConfig, init_opt_state, apply_updates
from repro.optim.compression import (compress_with_feedback,
                                     init_error_feedback)
from repro.runtime.fault_tolerance import (FailureInjector, StragglerWatchdog,
                                           run_with_restarts)
from repro.train.step import init_train_state, make_train_step


@pytest.fixture()
def small_state():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "b": {"x": jnp.ones(5), "step": jnp.asarray(7)}}


class TestCheckpointer:
    def test_roundtrip(self, tmp_path, small_state):
        checkpointer.save(str(tmp_path), 3, small_state)
        assert checkpointer.latest_step(str(tmp_path)) == 3
        out = checkpointer.restore(str(tmp_path), 3, small_state)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(small_state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_latest_pointer(self, tmp_path, small_state):
        checkpointer.save(str(tmp_path), 1, small_state)
        checkpointer.save(str(tmp_path), 2, small_state)
        assert checkpointer.latest_step(str(tmp_path)) == 2
        assert os.path.isdir(tmp_path / "step_1")  # older kept

    def test_async_save(self, tmp_path, small_state):
        ck = checkpointer.AsyncCheckpointer(str(tmp_path))
        ck.save_async(5, small_state)
        ck.wait()
        assert checkpointer.latest_step(str(tmp_path)) == 5

    def test_resharding_restore_to_host_mesh(self, tmp_path):
        """Save an unsharded state, restore against explicit shardings —
        the elastic-downsize path (mesh change = new shardings)."""
        from jax.sharding import NamedSharding, PartitionSpec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        state = {"w": jnp.ones((8, 8))}
        checkpointer.save(str(tmp_path), 1, state)
        sh = {"w": NamedSharding(mesh, PartitionSpec("data", "model"))}
        out = checkpointer.restore(str(tmp_path), 1, state, sh)
        assert out["w"].sharding == sh["w"]


class TestFaultTolerance:
    def test_run_with_restarts_resumes(self, tmp_path):
        """A loop that dies twice and resumes from its 'checkpoint'."""
        progress = {"step": 0, "restarts": 0}
        inj = FailureInjector(fail_at=(3, 7))

        def loop(_):
            for step in range(progress["step"], 10):
                inj.maybe_fail(step)
                progress["step"] = step + 1
            return progress["step"]

        final = run_with_restarts(
            loop, max_restarts=3,
            on_restart=lambda i, e: progress.__setitem__(
                "restarts", progress["restarts"] + 1))
        assert final == 10 and progress["restarts"] == 2

    def test_injector_exhausts(self):
        inj = FailureInjector(fail_at=(1,))
        with pytest.raises(RuntimeError):
            inj.maybe_fail(1)
        inj.maybe_fail(1)  # second time: already fired

    def test_straggler_watchdog(self):
        wd = StragglerWatchdog(warmup_steps=2, straggler_factor=2.0)
        for s in range(5):
            assert not wd.observe(s, 1.0)
        assert wd.observe(5, 5.0)
        assert len(wd.events) == 1
        assert not wd.observe(6, 1.0)  # ewma not polluted by the spike

    def test_end_to_end_training_restart(self, tmp_path):
        """Integration: train, crash, resume from checkpoint, finish —
        final params identical to an uninterrupted run (data is a pure
        function of step, checkpoint at the crash boundary)."""
        cfg = reduced_config(get_config("llama3.2-1b"))
        model = build_model(cfg)
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=8)
        step_fn = jax.jit(make_train_step(model, opt_cfg, None))
        pipe_cfg = PipelineConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)

        def run_to(state, start, end, ckpt_every=4):
            for step in range(start, end):
                state, _ = step_fn(state, make_batch(pipe_cfg, step))
                if (step + 1) % ckpt_every == 0:
                    checkpointer.save(str(tmp_path), step + 1, state)
            return state

        # uninterrupted
        s0 = init_train_state(model, jax.random.PRNGKey(0))
        ref = run_to(s0, 0, 8)
        # interrupted at step 5 -> resume from checkpoint 4
        s1 = init_train_state(model, jax.random.PRNGKey(0))
        s1 = run_to(s1, 0, 5)
        latest = checkpointer.latest_step(str(tmp_path))
        assert latest == 4
        s2 = checkpointer.restore(str(tmp_path), latest, ref)
        s2 = run_to(s2, latest, 8)
        for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(s2.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


class TestPipeline:
    def test_batch_deterministic_by_step(self):
        cfg = PipelineConfig(vocab=100, seq_len=16, global_batch=2)
        b1, b2 = make_batch(cfg, 3), make_batch(cfg, 3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = make_batch(cfg, 4)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_are_next_tokens(self):
        cfg = PipelineConfig(vocab=100, seq_len=16, global_batch=2)
        b = make_batch(cfg, 0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_prefetcher_produces_batches(self):
        cfg = PipelineConfig(vocab=50, seq_len=8, global_batch=2)
        pipe = Prefetcher(cfg)
        b = next(pipe)
        assert b["tokens"].shape == (2, 8)
        pipe.close()


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = init_opt_state(params)
        for _ in range(60):
            grads = {"w": 2.0 * params["w"]}
            params, state, _ = apply_updates(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 1.0

    def test_grad_clipping(self):
        cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(3)}
        state = init_opt_state(params)
        _, _, metrics = apply_updates(cfg, params, {"w": jnp.full(3, 1e6)},
                                      state)
        assert float(metrics["grad_norm"]) > 1e5  # norm reported pre-clip

    def test_compression_error_feedback_preserves_sum(self):
        """int8 quantization error is carried, not lost: across steps the
        cumulative compressed gradient tracks the cumulative true gradient."""
        g = {"w": jnp.linspace(-1.0, 1.0, 1000)}
        ef = init_error_feedback(g)
        total_c = jnp.zeros(1000)
        for _ in range(20):
            c, ef = compress_with_feedback(g, ef)
            total_c = total_c + c["w"]
        total_true = 20.0 * g["w"]
        err = jnp.max(jnp.abs(total_c + ef.residual["w"] - total_true))
        assert float(err) < 1e-3
