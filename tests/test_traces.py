"""Trace subsystem: schema IO, scenario generators, prior-fit round-trip,
ArrivalSource replay equivalence, and the routed importance plan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AZURE_PRIORS, SECOND, ZEROTH, geometric_grid, make_policy
from repro.sim import (MIX_LABELED, MIX_UNLABELED, PSEUDO, draw_arrival_stream,
                       estimate_from_plan, make_config, make_importance_plan,
                       make_run, make_trace_ensemble_plan, run_keyed_batch,
                       simulate_plan, simulate_trace_plan, stream_badness)
from repro.traces import (TraceArrivalSource, TraceSpec, fit_gamma_mle,
                          fit_priors, get_scenario, has_latents, load_csv,
                          load_npz, n_deployments, prior_relative_errors,
                          register_scenario, save_csv, save_npz,
                          scenario_names, synthesize_scenario,
                          trace_to_stream, validate_trace)

SMALL_SPEC = TraceSpec(horizon_hours=60 * 24.0, arrival_rate=0.08,
                       max_deployments=512, max_events=8)
CFG = make_config(capacity=500.0, arrival_rate=0.08, horizon_hours=60 * 24.0,
                  dt=24.0, max_slots=128, max_arrivals=6, d_points=8)
GRID = geometric_grid(24.0, 3 * 60 * 24.0, 12)


@pytest.fixture(scope="module")
def baseline_trace():
    return synthesize_scenario(jax.random.PRNGKey(7), "baseline", SMALL_SPEC)


@pytest.fixture(scope="module")
def second_run():
    return make_run(CFG, GRID, SECOND)


class TestSchema:
    def test_npz_roundtrip_lossless(self, baseline_trace, tmp_path):
        p = str(tmp_path / "trace.npz")
        save_npz(baseline_trace, p)
        back = load_npz(p)
        for a, b in zip(jax.tree.leaves(baseline_trace), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_csv_roundtrip_compacts_valid_rows(self, baseline_trace, tmp_path):
        p = str(tmp_path / "trace.csv")
        save_csv(baseline_trace, p)
        back = load_csv(p)
        v = np.asarray(baseline_trace.valid)
        assert n_deployments(back) == int(v.sum())
        np.testing.assert_allclose(np.asarray(back.arrival_hours),
                                   np.asarray(baseline_trace.arrival_hours)[v],
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(back.c0),
                                   np.asarray(baseline_trace.c0)[v], rtol=1e-6)
        # the event stream survives: totals of buffered events match
        want = np.asarray(baseline_trace.events.valid)[v].sum()
        assert np.asarray(back.events.valid).sum() == want

    def test_validate_rejects_unsorted(self, baseline_trace):
        t = np.asarray(baseline_trace.arrival_hours).copy()
        t[:2] = t[1::-1] + np.asarray([0.0, -1.0])  # force a descent
        bad = baseline_trace._replace(arrival_hours=jnp.asarray(t))
        with pytest.raises(ValueError, match="sorted"):
            validate_trace(bad)


class TestScenarios:
    def test_required_scenarios_registered(self):
        names = scenario_names()
        for required in ("baseline", "diurnal", "flash_crowd", "heavy_tail"):
            assert required in names
        assert len(names) >= 4

    def test_unknown_scenario_raises(self):
        with pytest.raises(KeyError, match="unknown scenario"):
            get_scenario("bogus")

    def test_duplicate_registration_raises(self):
        with pytest.raises(ValueError, match="already registered"):
            register_scenario("baseline")(lambda k, s: None)

    @pytest.mark.parametrize("name", ["baseline", "diurnal", "flash_crowd",
                                      "heavy_tail", "batched"])
    def test_scenarios_produce_valid_traces(self, name):
        tr = validate_trace(
            synthesize_scenario(jax.random.PRNGKey(1), name, SMALL_SPEC))
        assert n_deployments(tr) > 0
        assert has_latents(tr)
        v = np.asarray(tr.valid)
        assert np.all(np.asarray(tr.c0)[v] >= 1.0)
        assert np.all(np.asarray(tr.obs_window)[v] >= 0.0)

    def test_diurnal_modulates_and_flash_bursts(self):
        spec = TraceSpec(horizon_hours=365 * 24.0, arrival_rate=0.3,
                         max_deployments=8192)
        base = synthesize_scenario(jax.random.PRNGKey(3), "baseline", spec)
        diu = synthesize_scenario(jax.random.PRNGKey(3), "diurnal", spec)
        fla = synthesize_scenario(jax.random.PRNGKey(3), "flash_crowd", spec)
        t_of = lambda tr: np.asarray(tr.arrival_hours)[np.asarray(tr.valid)]
        # diurnal: arrivals correlate with the sine phase; baseline doesn't
        phase = lambda t: np.mean(np.sin(2 * np.pi * t / 24.0))
        assert phase(t_of(diu)) > phase(t_of(base)) + 0.2
        # flash crowd: burst window density is several x the baseline's
        t0 = 0.30 * spec.horizon_hours
        in_burst = lambda t: ((t >= t0) & (t < t0 + 24.0)).sum()
        assert in_burst(t_of(fla)) > 3 * max(in_burst(t_of(base)), 1)

    def test_heavy_tail_inflates_lifetimes(self):
        spec = TraceSpec(horizon_hours=365 * 24.0, arrival_rate=0.3,
                         max_deployments=8192)
        base = synthesize_scenario(jax.random.PRNGKey(4), "baseline", spec)
        hvy = synthesize_scenario(jax.random.PRNGKey(4), "heavy_tail", spec)
        mu_of = lambda tr: np.asarray(tr.mu)[np.asarray(tr.valid)]
        assert mu_of(hvy).mean() < mu_of(base).mean()

    def test_batched_shares_arrival_instants(self):
        tr = synthesize_scenario(jax.random.PRNGKey(5), "batched", SMALL_SPEC)
        t = np.asarray(tr.arrival_hours)[np.asarray(tr.valid)]
        assert len(np.unique(t)) < 0.5 * len(t)


class TestPresets:
    def test_trace_presets_mirror_sim_presets(self):
        """TRACE_FULL/TRACE_CPU stay in lockstep with the paper presets and
        construct (guards against silent TraceSpec signature drift)."""
        from repro.configs.paper_cluster import (PAPER_CPU, PAPER_FULL,
                                                 TRACE_CPU, TRACE_FULL)
        for trace_spec, sim_cfg in ((TRACE_FULL, PAPER_FULL),
                                    (TRACE_CPU, PAPER_CPU)):
            assert trace_spec.horizon_hours == sim_cfg.horizon_hours
            assert trace_spec.arrival_rate == sim_cfg.arrival_rate
            assert trace_spec.priors == AZURE_PRIORS
            # capacity covers ~2x the expected arrivals (burst headroom)
            expected = sim_cfg.arrival_rate * sim_cfg.horizon_hours
            assert trace_spec.max_deployments >= 1.5 * expected


class TestFitRoundtrip:
    SPEC = TraceSpec(horizon_hours=365 * 24.0, arrival_rate=0.6,
                     max_deployments=8192, max_events=16)

    def test_gamma_mle_recovers_known_gamma(self):
        x = np.asarray(jax.random.gamma(jax.random.PRNGKey(0), 0.31,
                                        (20_000,))) / 0.58
        shape, rate = fit_gamma_mle(x)
        assert shape == pytest.approx(0.31, rel=0.05)
        assert rate == pytest.approx(0.58, rel=0.05)

    def test_latent_fit_recovers_azure_priors(self):
        tr = synthesize_scenario(jax.random.PRNGKey(0), "baseline", self.SPEC)
        fitted, diag = fit_priors(tr, source="latent")
        errs = prior_relative_errors(fitted, AZURE_PRIORS)
        assert max(errs.values()) < 0.15, errs
        assert diag["source"] == "latent"

    def test_observed_fit_recovers_within_loose_tolerance(self):
        tr = synthesize_scenario(jax.random.PRNGKey(0), "baseline", self.SPEC)
        fitted, diag = fit_priors(tr, source="observed")
        errs = prior_relative_errors(fitted, AZURE_PRIORS)
        assert max(errs.values()) < 0.5, errs
        # implied population means are much tighter than raw hyperparameters
        for p in ("mu", "lam", "sig"):
            want = getattr(AZURE_PRIORS, f"{p}_shape") / getattr(
                AZURE_PRIORS, f"{p}_rate")
            got = getattr(fitted, f"{p}_shape") / getattr(fitted, f"{p}_rate")
            assert got == pytest.approx(want, rel=0.25), p

    def test_auto_prefers_latents_and_falls_back(self, baseline_trace):
        fitted, diag = fit_priors(baseline_trace)
        assert diag["source"] == "latent"
        nolat = baseline_trace._replace(
            lam=jnp.full_like(baseline_trace.lam, jnp.nan),
            mu=jnp.full_like(baseline_trace.mu, jnp.nan),
            sig=jnp.full_like(baseline_trace.sig, jnp.nan))
        _, diag = fit_priors(nolat)
        assert diag["source"] == "observed"


class TestReplay:
    def test_trace_source_smoke_and_deterministic(self, baseline_trace,
                                                  second_run):
        """Tier-1 trace-replay smoke test (CI): a replayed run produces sane,
        reproducible metrics through the unchanged scan body."""
        src = TraceArrivalSource(baseline_trace)
        run = make_run(CFG, GRID, SECOND, arrival_source=src)
        pol = make_policy(SECOND, rho=0.2, capacity=CFG.capacity)
        m1 = run(jax.random.PRNGKey(0), pol)
        m2 = run(jax.random.PRNGKey(0), pol)
        assert float(m1.utilization) == float(m2.utilization)
        assert 0.0 < float(m1.utilization) <= 1.0
        assert float(m1.arrivals_accepted) <= n_deployments(baseline_trace)

    def test_stream_shapes_and_counts(self, baseline_trace):
        stream, dropped = trace_to_stream(baseline_trace, CFG)
        assert stream.c0.shape == (CFG.n_steps, CFG.max_arrivals)
        assert int(jnp.sum(stream.n_arrivals)) + int(dropped) == \
            n_deployments(baseline_trace)

    def test_overflow_arrivals_are_counted(self, baseline_trace):
        tight = CFG._replace(max_arrivals=1)
        stream, dropped = trace_to_stream(baseline_trace, tight)
        assert int(dropped) > 0
        assert int(jnp.max(stream.n_arrivals)) == 1

    def test_pseudo_latent_requires_key(self, baseline_trace):
        cfg = CFG._replace(prior_mode=PSEUDO, n_pseudo_obs=5)
        with pytest.raises(ValueError, match="key"):
            trace_to_stream(baseline_trace, cfg, pseudo_source="latent")

    def test_mix_mode_requires_key(self, baseline_trace):
        cfg = CFG._replace(prior_mode=MIX_LABELED, n_pseudo_obs=5)
        with pytest.raises(ValueError, match="key"):
            trace_to_stream(baseline_trace, cfg, pseudo_source="observed")

    def test_unknown_pseudo_source_rejected(self, baseline_trace):
        cfg = CFG._replace(prior_mode=PSEUDO, n_pseudo_obs=5)
        with pytest.raises(ValueError, match="pseudo_source"):
            trace_to_stream(baseline_trace, cfg, pseudo_source="bogus")

    def test_replay_matches_prior_sampling(self, second_run):
        """Matched-priors equivalence: replaying synthesized traces must
        reproduce the prior-sampled utilization (same config, same policy)
        within MC noise at this scale."""
        pol = make_policy(SECOND, rho=0.2, capacity=CFG.capacity)
        keys = jax.random.split(jax.random.PRNGKey(0), 8)
        u_prior = float(jnp.mean(
            jax.vmap(lambda k: second_run(k, pol))(keys).utilization))
        streams = [
            trace_to_stream(synthesize_scenario(
                jax.random.fold_in(jax.random.PRNGKey(5), i), "baseline",
                SMALL_SPEC), CFG)[0]
            for i in range(8)]
        batch = jax.tree.map(lambda *xs: np.stack(xs), *streams)
        u_rep = float(jnp.mean(jax.vmap(second_run, in_axes=(0, None, 0))(
            keys, pol, batch).utilization))
        assert u_rep == pytest.approx(u_prior, rel=0.25)


class TestInformationModels:
    """PSEUDO/§7 beliefs built on replay (the PR-3 tentpole)."""

    def test_pseudo_latent_matches_prior_sampled_statistics(
            self, baseline_trace):
        """Replayed PSEUDO-latent beliefs carry the same information
        strength as draw_arrival_stream's PSEUDO path: the mu posterior
        shape gains exactly k counts in expectation over placed arrivals,
        and the per-arrival increments match the prior-sampled moments."""
        k = 5
        cfg = CFG._replace(prior_mode=PSEUDO, n_pseudo_obs=k)
        stream, _ = trace_to_stream(baseline_trace, cfg,
                                    key=jax.random.PRNGKey(1),
                                    pseudo_source="latent")
        occurs = np.asarray(
            jnp.arange(cfg.max_arrivals)[None, :] <
            stream.n_arrivals[:, None])
        # mu_a = prior shape + n_lifetimes (== k, deterministic given k)
        mu_gain = np.asarray(stream.bel.mu_a) - AZURE_PRIORS.mu_shape
        np.testing.assert_allclose(mu_gain[occurs], k, rtol=1e-5)
        assert np.allclose(mu_gain[~occurs], 0.0, atol=1e-5)
        # lam_a gains the Poisson scale-out counts; their raw means are
        # heavy-tailed (lam * mu**nu), so compare the robust statistic:
        # the fraction of arrivals whose k windows observed any scale-out
        # must match the prior-sampled construction on matched arrivals
        prior_stream = draw_arrival_stream(jax.random.PRNGKey(2), cfg)
        p_occ = np.asarray(
            jnp.arange(cfg.max_arrivals)[None, :] <
            prior_stream.n_arrivals[:, None])
        lam_gain = (np.asarray(stream.bel.lam_a)
                    - AZURE_PRIORS.lam_shape)[occurs]
        lam_gain_prior = (np.asarray(prior_stream.bel.lam_a)
                         - AZURE_PRIORS.lam_shape)[p_occ]
        assert (lam_gain > 0).mean() == pytest.approx(
            (lam_gain_prior > 0).mean(), abs=0.15)

    def test_pseudo_observed_is_deterministic_conjugate_update(
            self, baseline_trace):
        """The observables path needs no key and reproduces the conjugate
        posterior counts of the trace's own logged history."""
        cfg = CFG._replace(prior_mode=PSEUDO, n_pseudo_obs=5)
        s1, _ = trace_to_stream(baseline_trace, cfg, pseudo_source="observed")
        s2, _ = trace_to_stream(baseline_trace, cfg, pseudo_source="observed")
        np.testing.assert_array_equal(np.asarray(s1.bel.mu_a),
                                      np.asarray(s2.bel.mu_a))
        # first placed arrival: mu belief = prior + (deaths, core-hours)
        v = np.asarray(baseline_trace.valid)
        first = np.nonzero(v)[0][0]
        deaths = float(np.asarray(baseline_trace.n_core_deaths)[first])
        hours = float(np.asarray(baseline_trace.core_hours)[first])
        t_step = int(np.asarray(baseline_trace.arrival_hours)[first] // CFG.dt)
        assert float(s1.bel.mu_a[t_step, 0]) == pytest.approx(
            AZURE_PRIORS.mu_shape + deaths, rel=1e-5)
        assert float(s1.bel.mu_b[t_step, 0]) == pytest.approx(
            AZURE_PRIORS.mu_rate + hours, rel=1e-5)

    def test_auto_resolves_by_latents(self, baseline_trace):
        assert TraceArrivalSource(baseline_trace).pseudo_source == "latent"
        nolat = baseline_trace._replace(
            lam=jnp.full_like(baseline_trace.lam, jnp.nan),
            mu=jnp.full_like(baseline_trace.mu, jnp.nan),
            sig=jnp.full_like(baseline_trace.sig, jnp.nan))
        assert TraceArrivalSource(nolat).pseudo_source == "observed"

    @pytest.mark.parametrize("mode", [PSEUDO, MIX_LABELED, MIX_UNLABELED])
    def test_replay_runs_under_every_information_model(self, baseline_trace,
                                                       mode):
        cfg = CFG._replace(prior_mode=mode, n_pseudo_obs=2)
        run = make_run(cfg, GRID, SECOND,
                       arrival_source=TraceArrivalSource(baseline_trace))
        pol = make_policy(SECOND, rho=0.2, capacity=cfg.capacity)
        m = run(jax.random.PRNGKey(0), pol)
        assert 0.0 < float(m.utilization) <= 1.0

    def test_mix_alt_belief_differs_from_own(self, baseline_trace):
        cfg = CFG._replace(prior_mode=MIX_UNLABELED, n_pseudo_obs=5)
        stream, _ = trace_to_stream(baseline_trace, cfg,
                                    key=jax.random.PRNGKey(3))
        assert not np.allclose(np.asarray(stream.bel.mu_a),
                               np.asarray(stream.bel_alt.mu_a))


class TestTraceEnsemble:
    """Trace-level stratified importance sampling (arrival-side tail lives
    across traces, not run keys)."""

    @pytest.fixture(scope="class")
    def streams(self):
        return [trace_to_stream(synthesize_scenario(
            jax.random.fold_in(jax.random.PRNGKey(11), i), "baseline",
            SMALL_SPEC), CFG)[0] for i in range(6)]

    def test_plan_weights_sum_to_probed_mass(self, streams):
        plan = make_trace_ensemble_plan(jax.random.PRNGKey(0), CFG, GRID,
                                        streams, quotas=(3, 2, 2),
                                        runs_per_trace=2)
        assert plan.bm_trace.shape == (6,)
        covered = plan.p_bucket[np.unique(plan.buckets)].sum()
        assert plan.weights.sum() == pytest.approx(covered)
        assert len(plan.keys) == len(plan.weights) == len(plan.trace_idx)

    def test_simulate_trace_plan_matches_direct_runs(self, streams,
                                                     second_run):
        plan = make_trace_ensemble_plan(jax.random.PRNGKey(1), CFG, GRID,
                                        streams, quotas=(2, 2, 2))
        pol = make_policy(SECOND, rho=0.2, capacity=CFG.capacity)
        batched = simulate_trace_plan(second_run, plan, streams, pol)
        for i in (0, len(plan.weights) - 1):
            direct = second_run(jnp.asarray(plan.keys[i]), pol,
                                streams[int(plan.trace_idx[i])])
            assert float(batched.utilization[i]) == pytest.approx(
                float(direct.utilization))
        est = estimate_from_plan(plan, batched)
        assert 0.0 <= est["utilization"] <= 1.0

    def test_stream_badness_is_arrival_side_only(self, streams):
        """Same stream, different keys: BM varies only through the lifetime
        clocks, not the arrivals — and a fixed key is deterministic."""
        bm1 = float(stream_badness(jax.random.PRNGKey(0), streams[0], CFG,
                                   GRID))
        bm2 = float(stream_badness(jax.random.PRNGKey(0), streams[0], CFG,
                                   GRID))
        assert bm1 == bm2
        assert bm1 > 0.0

    def test_run_keyed_batch_streams_matches_vmap(self, streams, second_run):
        pol = make_policy(SECOND, rho=0.2, capacity=CFG.capacity)
        keys = jax.random.split(jax.random.PRNGKey(2), 3)
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *streams[:3])
        m1 = run_keyed_batch(second_run, keys, pol, streams=batch)
        m2 = jax.vmap(second_run, in_axes=(0, None, 0))(keys, pol, batch)
        np.testing.assert_allclose(np.asarray(m1.utilization),
                                   np.asarray(m2.utilization))


class TestImportanceRouting:
    def test_simulate_plan_matches_serial_runs(self):
        run = make_run(CFG, GRID, ZEROTH)
        pol = make_policy(ZEROTH, threshold=400.0, capacity=CFG.capacity)
        plan = make_importance_plan(jax.random.PRNGKey(0), CFG, GRID,
                                    quotas=(3, 3, 3), n_probe=32,
                                    probe_batch=32)
        batched = simulate_plan(run, plan, pol)
        for i in (0, len(plan.weights) - 1):
            serial = run(jnp.asarray(plan.keys[i]), pol)
            assert float(batched.utilization[i]) == pytest.approx(
                float(serial.utilization))
        est = estimate_from_plan(plan, batched)
        assert 0.0 <= est["utilization"] <= 1.0
        assert est["n_runs"] == len(plan.weights)

    def test_run_keyed_batch_matches_vmap(self):
        run = make_run(CFG, GRID, ZEROTH)
        pol = make_policy(ZEROTH, threshold=400.0, capacity=CFG.capacity)
        keys = jax.random.split(jax.random.PRNGKey(1), 3)
        m1 = run_keyed_batch(run, keys, pol)
        m2 = jax.vmap(run, in_axes=(0, None))(keys, pol)
        np.testing.assert_allclose(np.asarray(m1.utilization),
                                   np.asarray(m2.utilization))


@pytest.mark.slow
class TestQuickPresetEquivalence:
    """The satellite acceptance check at the quick benchmark preset."""

    def test_quick_preset_replay_equivalence(self):
        from benchmarks.common import SCALES, grid_for, sim_config
        from benchmarks.scenarios import trace_spec_for

        scale = SCALES["quick"]
        cfg = sim_config(scale)
        grid = grid_for(scale, cfg)
        spec = trace_spec_for(cfg)
        run = make_run(cfg, grid, SECOND)
        pol = make_policy(SECOND, rho=0.112, capacity=cfg.capacity)
        keys = jax.random.split(jax.random.PRNGKey(0), 4)
        u_prior = float(jnp.mean(
            jax.vmap(lambda k: run(k, pol))(keys).utilization))
        streams = [
            trace_to_stream(synthesize_scenario(
                jax.random.fold_in(jax.random.PRNGKey(9), i), "baseline",
                spec), cfg)[0]
            for i in range(4)]
        batch = jax.tree.map(lambda *xs: np.stack(xs), *streams)
        u_rep = float(jnp.mean(jax.vmap(run, in_axes=(0, None, 0))(
            keys, pol, batch).utilization))
        assert u_rep == pytest.approx(u_prior, rel=0.2)

    def test_quick_preset_pseudo_replay_equivalence(self):
        """PR-3 acceptance: replaying a synthetic trace with PSEUDO beliefs
        reproduces prior_mode=PSEUDO utilization/SLA within sampling error
        on the quick preset (same policy, matched arrival statistics)."""
        from benchmarks.common import SCALES, grid_for, sim_config
        from benchmarks.scenarios import trace_spec_for

        scale = SCALES["quick"]
        cfg = sim_config(scale, prior_mode=PSEUDO, n_pseudo_obs=5)
        grid = grid_for(scale, cfg)
        spec = trace_spec_for(cfg)
        run = make_run(cfg, grid, SECOND)
        pol = make_policy(SECOND, rho=0.112, capacity=cfg.capacity)
        keys = jax.random.split(jax.random.PRNGKey(0), 4)
        m_prior = jax.vmap(lambda k: run(k, pol))(keys)
        u_prior = float(jnp.mean(m_prior.utilization))
        streams = [
            trace_to_stream(
                synthesize_scenario(
                    jax.random.fold_in(jax.random.PRNGKey(9), i), "baseline",
                    spec), cfg,
                key=jax.random.fold_in(jax.random.PRNGKey(21), i),
                pseudo_source="latent")[0]
            for i in range(4)]
        batch = jax.tree.map(lambda *xs: np.stack(xs), *streams)
        m_rep = jax.vmap(run, in_axes=(0, None, 0))(keys, pol, batch)
        u_rep = float(jnp.mean(m_rep.utilization))
        assert u_rep == pytest.approx(u_prior, rel=0.2)
        # SLA failures are clustered in rare bad runs, so at 4 runs the
        # rates cannot be magnitude-matched (zero counts are likely);
        # equivalence here means both land in the same tail regime —
        # within an order of magnitude of the preset's SLA target
        f_prior = float(jnp.sum(m_prior.failed_requests)) / max(
            float(jnp.sum(m_prior.total_requests)), 1.0)
        f_rep = float(jnp.sum(m_rep.failed_requests)) / max(
            float(jnp.sum(m_rep.total_requests)), 1.0)
        assert f_prior < 10 * scale.tau
        assert f_rep < 10 * scale.tau


@pytest.mark.slow
def test_scenario_policy_sweep_runs():
    """Full scenario x policy sweep through the benchmark entry point."""
    from benchmarks import scenarios

    rows = scenarios.run("tiny", seed=0)
    names = [r.split(",", 1)[0] for r in rows]
    for scen in ("baseline", "diurnal", "flash_crowd", "heavy_tail",
                 "batched"):
        for pol in ("zeroth", "first", "second"):
            assert f"scenarios/{scen}/{pol}" in names
    assert "scenarios/importance_routed" in names
    assert any(n.startswith("scenarios/fit_roundtrip") for n in names)
