"""Drift-aware streaming recalibration (tuning.drift + traces.fit streaming).

Covers the PR's hardening satellites: streaming-fit == batch-fit
equivalence (bit-for-bit on one window; merge associativity and window-
order invariance; merged windows == concatenated trace), the drift
detector's calibrated false-alarm rate and step-change detection delay,
the golden pin on ``pseudo_counts_from_observables``, empty-window
warn-and-continue, and the engine's live ``metrics_snapshot()`` export.

Compile/runtime budget: everything shares one trace spec; the module-scope
``drift_null`` fixture pays the stationary Monte-Carlo calibration once and
every detector test reuses it. The full never/triggered/oracle regret
protocol is slow-marked (it spends ~80 simulations).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.core import SECOND, ZEROTH, geometric_grid, make_policy
from repro.core.belief import pseudo_counts_from_observables
from repro.sim import make_config
from repro.traces import (DRIFT_MU_SCALE, FitStats, TraceSpec, drifted_priors,
                          fit_priors, merge_stats, stats_to_priors,
                          synthesize_scenario, window_stats)
from repro.tuning import (DRIFT_CHANNELS, DriftDetector, DriftNull,
                          calibrate_drift_detector, channels_from_obs,
                          channels_from_stats, detect_drift, run_drift_protocol,
                          theta_space, warm_theta_bounds,
                          window_channel_values)

#: one spec for the whole module: 12 windows of 20 days, enough arrivals per
#: window (~70) for stable channel means at CPU-runnable synthesis cost
SPEC = TraceSpec(horizon_hours=240 * 24.0, arrival_rate=0.12,
                 max_deployments=2048, max_events=8)
WINDOW = 20 * 24.0
ONSET_W = 6            # drift_step flips at DRIFT_STEP_FRAC=0.5 -> window 6
ALPHA = 0.1

PRIOR_FIELDS = ("mu_shape", "mu_rate", "lam_shape", "lam_rate",
                "sig_shape", "sig_rate", "delta", "nu")


@pytest.fixture(scope="module")
def base_trace():
    return synthesize_scenario(jax.random.PRNGKey(3), "baseline", SPEC)


@pytest.fixture(scope="module")
def drift_null():
    return calibrate_drift_detector(jax.random.PRNGKey(7), SPEC,
                                    window_hours=WINDOW, n_reps=8,
                                    alpha=ALPHA)


def _split_stats(trace, edges):
    return [window_stats(trace, a, b) for a, b in zip(edges[:-1], edges[1:])]


def _assert_stats_close(a: FitStats, b: FitStats, rtol=1e-12):
    for f in FitStats._fields:
        if f in ("t0", "t1"):
            continue
        np.testing.assert_allclose(getattr(a, f), getattr(b, f), rtol=rtol,
                                   atol=1e-12, err_msg=f)


class TestStreamingFit:
    """Satellite: sufficient-statistics layer == batch fit, exactly."""

    def test_one_window_equals_batch_bitforbit(self, base_trace):
        stats = window_stats(base_trace, 0.0, np.inf)
        p_stream, d_stream = stats_to_priors(stats)
        p_batch, d_batch = fit_priors(base_trace, source="observed")
        for f in PRIOR_FIELDS:
            assert getattr(p_stream, f) == getattr(p_batch, f), f
        assert d_stream["n_deployments"] == d_batch["n_deployments"]

    @settings(max_examples=6, deadline=None)
    @given(n_windows=st.integers(2, 8), seed=st.integers(0, 1_000))
    def test_merged_windows_equal_concatenated_trace(self, base_trace,
                                                     n_windows, seed):
        """Priors from merged disjoint windows == batch priors over the
        whole trace (windows partition the deployments by arrival, so the
        merge is exact up to float summation order)."""
        rng = np.random.default_rng(seed)
        horizon = float(SPEC.horizon_hours)
        cuts = np.sort(rng.uniform(0.0, horizon, n_windows - 1))
        edges = [0.0, *cuts.tolist(), np.inf]
        merged = merge_stats(*_split_stats(base_trace, edges))
        batch = window_stats(base_trace, 0.0, np.inf)
        _assert_stats_close(merged, batch)
        p_m, _ = stats_to_priors(merged)
        p_b, _ = stats_to_priors(batch)
        for f in PRIOR_FIELDS:
            np.testing.assert_allclose(getattr(p_m, f), getattr(p_b, f),
                                       rtol=1e-9, err_msg=f)

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 1_000))
    def test_merge_associative_and_order_invariant(self, base_trace, seed):
        rng = np.random.default_rng(seed)
        cuts = np.sort(rng.uniform(0.0, float(SPEC.horizon_hours), 3))
        parts = _split_stats(base_trace, [0.0, *cuts.tolist(), np.inf])
        a, b, c, d = parts
        left = merge_stats(merge_stats(a, b), merge_stats(c, d))
        right = merge_stats(a, merge_stats(b, merge_stats(c, d)))
        _assert_stats_close(left, right)
        perm = [parts[i] for i in rng.permutation(4)]
        _assert_stats_close(merge_stats(*perm), left)

    def test_merge_rejects_mismatched_min_deaths(self, base_trace):
        a = window_stats(base_trace, 0.0, 1000.0, min_deaths=2)
        b = window_stats(base_trace, 1000.0, np.inf, min_deaths=3)
        with pytest.raises(ValueError, match="min_deaths"):
            merge_stats(a, b)

    def test_observables_keys_mirror_telemetry(self, base_trace):
        from repro.obs.counters import WindowStats

        obs = window_stats(base_trace, 0.0, np.inf).observables()
        # every key the telemetry rider sums (except the slot-table-derived
        # departures) appears under the same name
        assert set(obs) == set(WindowStats._fields) - {"departed"}


class TestEmptyWindows:
    """Satellite: the observables path warns-and-continues on quiet data."""

    def test_empty_window_warns_and_falls_back(self, base_trace):
        stats = window_stats(base_trace, 1e9, 2e9)   # no arrivals out there
        assert stats.n == 0.0
        with pytest.warns(RuntimeWarning, match="informative samples"):
            priors, diag = stats_to_priors(stats)
        assert {"mu", "sig", "lam"} <= set(diag["degenerate"])
        for f in PRIOR_FIELDS:
            assert np.isfinite(getattr(priors, f)), f

    def test_fit_priors_observed_all_invalid_warns_not_raises(self,
                                                              base_trace):
        dead = base_trace._replace(
            valid=jnp.zeros_like(base_trace.valid))
        with pytest.warns(RuntimeWarning):
            priors, diag = fit_priors(dead, source="observed")
        assert diag["n_deployments"] == 0
        assert np.isfinite(priors.mu_shape)

    def test_small_window_still_merges_into_batch(self, base_trace):
        # an empty window is the additive identity: merging it changes
        # nothing (the regression the property tests' edge generators found)
        empty = window_stats(base_trace, 1e9, 2e9)
        full = window_stats(base_trace, 0.0, np.inf)
        _assert_stats_close(merge_stats(full, empty), full)


class TestGoldenPseudoCounts:
    """Satellite: pin the observed-fit path's conjugate-update inputs so the
    sufficient-statistics refactor can't silently change them."""

    def test_golden_values(self):
        pc = pseudo_counts_from_observables(
            core_deaths=jnp.asarray(3.0),
            exposure_core_hours=jnp.asarray(120.5),
            n_scaleouts=jnp.asarray(4.0),
            scaleout_cores=jnp.asarray(10.0),
            window_hours=jnp.asarray(48.0))
        golden = {"n_lifetimes": 3.0, "sum_lifetimes": 120.5,
                  "n_windows": 48.0, "n_scaleouts": 4.0, "n_sizes": 4.0,
                  "sum_size_minus1": 6.0}
        for k, want in golden.items():
            assert float(getattr(pc, k)) == want, k

    def test_malformed_rows_clip_to_no_information(self):
        pc = pseudo_counts_from_observables(
            core_deaths=jnp.asarray(-2.0),
            exposure_core_hours=jnp.asarray(-1.0),
            n_scaleouts=jnp.asarray(5.0),
            scaleout_cores=jnp.asarray(2.0),   # fewer cores than events
            window_hours=jnp.asarray(-3.0))
        assert float(pc.n_lifetimes) == 0.0
        assert float(pc.sum_lifetimes) == 0.0
        assert float(pc.n_windows) == 0.0
        assert float(pc.sum_size_minus1) == 0.0


class TestDetector:
    """Satellite: calibrated false-alarm rate and step-change delay."""

    def test_false_alarm_rate_bounded(self, drift_null):
        """Fired fraction on FRESH stationary replays <= nominal alpha plus
        a 3-sigma binomial allowance (seeded, so deterministic)."""
        n = 12
        fired = 0
        for s in range(100, 100 + n):
            tr = synthesize_scenario(jax.random.PRNGKey(s), "baseline", SPEC)
            fired += int(detect_drift(tr, drift_null,
                                      window_hours=WINDOW).fired)
        bound = ALPHA + 3.0 * np.sqrt(ALPHA * (1 - ALPHA) / n)
        assert fired / n <= bound, (fired, n)

    @pytest.mark.parametrize("seed", [3, 42])
    def test_step_change_detected_with_bounded_delay(self, drift_null, seed):
        tr = synthesize_scenario(jax.random.PRNGKey(seed), "drift_step", SPEC)
        rep = detect_drift(tr, drift_null, window_hours=WINDOW)
        assert rep.fired
        assert ONSET_W <= rep.fired_window <= ONSET_W + 3, rep.fired_window
        # the decision statistic is nondecreasing after the onset fires it
        assert rep.stats[-1] >= rep.stats[rep.fired_window]

    def test_ramp_detected(self, drift_null):
        tr = synthesize_scenario(jax.random.PRNGKey(5), "drift_ramp", SPEC)
        assert detect_drift(tr, drift_null, window_hours=WINDOW).fired

    def test_null_absorbs_window_layout(self, drift_null):
        assert np.isfinite(drift_null.threshold)
        assert drift_null.threshold > 0
        for c in DRIFT_CHANNELS:
            assert drift_null.std[c] > 0
        assert drift_null.n_windows == 12

    def test_channels_flat_on_stationary_windows(self, base_trace):
        """The censoring-robust channels do NOT trend across windows of a
        stationary trace (the pooled death rate deaths/core-hours does —
        that artifact is why the channels are per-deployment means)."""
        vals = window_channel_values(base_trace, WINDOW)
        mu = np.asarray([v["mu"] for v in vals])
        assert np.isfinite(mu).all()
        # last-quarter mean within 3x the across-window spread of the first
        lo, hi = mu[:9].mean(), mu[9:].mean()
        assert abs(hi - lo) <= 3.0 * mu[:9].std() + 1e-9

    def test_nan_channels_hold_cusum(self):
        null = DriftNull(mean={"mu": 1.0}, std={"mu": 0.5}, threshold=5.0,
                         alpha=0.1, slack=0.5, n_reps=0, n_windows=0)
        det = DriftDetector(null)
        det.update({"mu": 2.0})
        s = det.stat
        upd = det.update({"mu": float("nan")})
        assert upd.stat == s          # quiet window: statistic held
        assert det.n_windows == 2

    def test_detector_fires_and_latches(self):
        null = DriftNull(mean={"mu": 0.0}, std={"mu": 1.0}, threshold=2.0,
                         alpha=0.1, slack=0.5, n_reps=0, n_windows=0)
        det = DriftDetector(null)
        assert not det.update({"mu": 0.0}).fired
        assert det.update({"mu": 4.0}).fired
        assert det.fired_window == 1
        upd = det.update({"mu": -10.0})
        assert upd.fired and upd.fired_window == 1   # latched
        det.reset()
        assert det.stat == 0.0 and not det.fired


class TestChannels:
    def test_stats_and_obs_channels_share_keys(self, base_trace):
        st_vals = channels_from_stats(window_stats(base_trace, 0.0, np.inf))
        obs_vals = channels_from_obs(
            window_stats(base_trace, 0.0, np.inf).observables())
        assert set(st_vals) == set(obs_vals) == set(DRIFT_CHANNELS)

    def test_obs_channels_arithmetic(self):
        vals = channels_from_obs({"core_deaths": 6.0,
                                  "exposure_core_hours": 300.0,
                                  "n_scaleouts": 4.0, "alive_hours": 200.0,
                                  "scaleout_cores": 14.0})
        assert vals["mu"] == pytest.approx(0.02)
        assert vals["scaleout"] == pytest.approx(0.02)
        assert vals["size"] == pytest.approx(2.5)
        quiet = channels_from_obs({})
        assert all(np.isnan(v) for v in quiet.values())


class TestWarmRetune:
    @pytest.mark.parametrize("kind", [ZEROTH, SECOND])
    def test_warm_bounds_contain_incumbent_and_shrink(self, kind):
        capacity = 500.0
        x_lo, x_hi, space = theta_space(kind, capacity)
        theta0 = 0.1 if kind == SECOND else 0.6 * capacity
        lo, hi = warm_theta_bounds(kind, theta0, capacity, frac=0.25)
        assert x_lo <= lo < hi <= x_hi
        assert hi - lo < 0.75 * (x_hi - x_lo)
        from repro.tuning import from_param

        assert lo <= from_param(theta0, space) <= hi

    def test_warm_bounds_clip_at_cold_edges(self):
        capacity = 500.0
        x_lo, _, _ = theta_space(SECOND, capacity)
        lo, _ = warm_theta_bounds(SECOND, 10 ** x_lo, capacity, frac=0.25)
        assert lo == x_lo


class TestEngineExport:
    """Tentpole: the detector surfaces live via metrics_snapshot()."""

    def test_snapshot_exports_drift_and_requires_telemetry(self):
        from repro.serve import OnlineAdmissionEngine
        from repro.serve.admission import Arrival

        cfg = make_config(capacity=300.0, arrival_rate=0.1,
                          horizon_hours=6 * 24.0, dt=24.0, max_slots=64,
                          max_arrivals=4, telemetry=True)
        grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3, 12)
        null = DriftNull(
            mean={"mu": 0.004, "scaleout": 0.02, "size": 4.0},
            std={"mu": 0.002, "scaleout": 0.01, "size": 1.0},
            threshold=50.0, alpha=0.1, slack=0.5, n_reps=0, n_windows=0)
        pol = make_policy(SECOND, rho=0.3, capacity=cfg.capacity)
        eng = OnlineAdmissionEngine(cfg, grid, SECOND, pol,
                                    drift_detector=DriftDetector(null))
        key = jax.random.PRNGKey(0)
        for _ in range(3):
            key, k1, k2 = jax.random.split(key, 3)
            eng.tick(k1)
            eng.submit(Arrival.draw(k2, cfg))
            eng.flush()
            snap = eng.metrics_snapshot()
        drift = snap["drift"]
        assert drift["n_windows"] == 3       # one window per scrape
        assert drift["threshold"] == 50.0
        assert set(drift["channel_stats"]) == set(DRIFT_CHANNELS)
        assert np.isfinite(drift["stat"])

        with pytest.raises(ValueError, match="telemetry"):
            OnlineAdmissionEngine(cfg._replace(telemetry=False), grid,
                                  SECOND, pol,
                                  drift_detector=DriftDetector(null))


class TestDriftProtocol:
    """Tentpole acceptance: triggered warm re-tuning beats never re-tuning
    on the drifting scenario and lands within CI of the oracle."""

    @pytest.mark.slow
    def test_regret_ordering_and_oracle_ci(self):
        cfg = make_config(capacity=800.0, arrival_rate=0.05,
                          horizon_hours=60 * 24.0, dt=24.0, max_slots=128,
                          max_arrivals=5, agg_refresh_steps=1)
        grid = geometric_grid(cfg.dt, cfg.horizon_hours * 3.0, 16)
        res = run_drift_protocol(
            jax.random.PRNGKey(0), kind=SECOND, cfg=cfg, grid=grid,
            spec=SPEC, tau=5e-3, window_hours=WINDOW, n_runs=4, n_grid=5,
            n_null_reps=6)
        assert res.report.fired
        assert res.delay_windows >= 0
        assert 0.0 <= res.delay_frac <= 1.0
        # the drifted regime really is drifted (mu slowed by the scale)
        drifted = drifted_priors(cfg.priors, DRIFT_MU_SCALE)
        assert drifted.mu_rate == pytest.approx(
            cfg.priors.mu_rate / DRIFT_MU_SCALE)
        # acceptance: regret(triggered) <= regret(never), within oracle CI
        assert res.triggered.regret <= res.never.regret + 1e-9
        assert res.within_ci
        # the warm re-tune spends fewer simulations than the cold oracle
        assert res.triggered.n_sims <= res.oracle.n_sims
        assert dataclasses.asdict(res.never)["name"] == "never"
