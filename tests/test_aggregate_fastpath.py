"""Fused-aggregate fast path: equivalence with the per-slot reference, the
Pallas aggregated-output kernel variant, the hybrid event samplers, and the
restructured simulator loop (blocked refresh + incremental folding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AZURE_PRIORS, SECOND, ZEROTH, geometric_grid,
                        make_policy, moment_curves)
from repro.core.belief import GammaBelief
from repro.core.moments import aggregate_moment_curves, moment_curves_fused
from repro.core.processes import fast_binomial, fast_poisson
from repro.kernels.moment_curves.ops import aggregate_moment_curves_kernel
from repro.sim import SimConfig, make_config, make_run, run_batch

PRIORS = AZURE_PRIORS


def _rand_belief(key, s):
    ks = jax.random.split(key, 6)
    e = lambda k, base: base * jnp.exp(0.5 * jax.random.normal(k, (s,)))
    return GammaBelief(
        mu_a=e(ks[0], 0.31), mu_b=e(ks[1], 0.58), lam_a=e(ks[2], 0.49),
        lam_b=e(ks[3], 0.45), sig_a=e(ks[4], 0.26), sig_b=e(ks[5], 0.055))


def _case(s, seed=0):
    key = jax.random.PRNGKey(seed)
    bel = _rand_belief(key, s)
    cores = (1.0 + jax.random.poisson(key, 5.0, (s,))).astype(jnp.float32)
    alive = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.6, (s,))
    return bel, cores, alive


class TestAggregateEquivalence:
    """Acceptance: fast-path aggregates == per-slot reference summed over
    alive slots, within rtol 1e-5."""

    @pytest.mark.parametrize("s,n,nd", [(64, 16, 8), (600, 24, 16)])
    def test_fused_matches_per_slot_reference(self, s, n, nd):
        bel, cores, alive = _case(s, seed=s)
        grid = geometric_grid(6.0, 26_280.0, n)
        ref = moment_curves(bel, cores, grid, PRIORS, d_points=nd)
        m = alive.astype(jnp.float32)
        want_el = jnp.sum(ref.EL * m[:, None], axis=0)
        want_vl = jnp.sum(ref.VL * m[:, None], axis=0)
        got = aggregate_moment_curves(bel, cores, alive, grid, PRIORS,
                                      d_points=nd)
        np.testing.assert_allclose(got.EL, want_el, rtol=1e-5)
        np.testing.assert_allclose(got.VL, want_vl, rtol=1e-5)

    def test_fused_per_slot_matches_reference(self):
        bel, cores, _ = _case(128)
        grid = geometric_grid(6.0, 26_280.0, 16)
        ref = moment_curves(bel, cores, grid, PRIORS, d_points=8)
        got = moment_curves_fused(bel, cores, grid, PRIORS, d_points=8)
        np.testing.assert_allclose(got.EL, ref.EL, rtol=1e-5)
        np.testing.assert_allclose(got.VL, ref.VL, rtol=1e-5, atol=1e-8)

    def test_blocked_reduction_matches_single_block(self):
        """The block_size chunking (scan accumulation) changes nothing."""
        bel, cores, alive = _case(700)
        grid = geometric_grid(6.0, 26_280.0, 12)
        one = aggregate_moment_curves(bel, cores, alive, grid, PRIORS,
                                      d_points=8, block_size=4096)
        blk = aggregate_moment_curves(bel, cores, alive, grid, PRIORS,
                                      d_points=8, block_size=128)
        np.testing.assert_allclose(blk.EL, one.EL, rtol=2e-6)
        np.testing.assert_allclose(blk.VL, one.VL, rtol=2e-6)

    @pytest.mark.parametrize("s", [64, 300])
    def test_kernel_aggregate_matches_reference(self, s):
        """Pallas aggregated-output variant (interpret mode = first-class
        CPU fallback path) vs the per-slot reference."""
        bel, cores, alive = _case(s, seed=s + 7)
        grid = geometric_grid(6.0, 26_280.0, 16)
        ref = moment_curves(bel, cores, grid, PRIORS, d_points=8)
        m = alive.astype(jnp.float32)
        want_el = jnp.sum(ref.EL * m[:, None], axis=0)
        want_vl = jnp.sum(ref.VL * m[:, None], axis=0)
        got = aggregate_moment_curves_kernel(bel, cores, alive, grid, PRIORS,
                                             d_points=8, interpret=True)
        np.testing.assert_allclose(got.EL, want_el, rtol=2e-4)
        np.testing.assert_allclose(got.VL, want_vl, rtol=2e-3)

    def test_all_dead_is_zero(self):
        bel, cores, _ = _case(32)
        grid = geometric_grid(6.0, 26_280.0, 8)
        got = aggregate_moment_curves(bel, cores, jnp.zeros(32, bool), grid,
                                      PRIORS, d_points=8)
        assert float(jnp.max(jnp.abs(got.EL))) == 0.0
        assert float(jnp.max(jnp.abs(got.VL))) == 0.0


class TestFastSamplers:
    @pytest.mark.parametrize("lam", [0.0, 0.4, 3.0, 9.9, 10.1, 45.0, 250.0])
    def test_poisson_moments(self, lam):
        keys = jax.random.split(jax.random.PRNGKey(int(lam * 10) + 1), 100)
        f = jax.jit(jax.vmap(lambda k: fast_poisson(k, jnp.full((400,), lam))))
        d = np.asarray(f(keys)).ravel()
        se = max(np.sqrt(lam / d.size), 1e-9)
        assert d.mean() == pytest.approx(lam, abs=6 * se + 1e-9)
        if lam > 0:
            assert d.var() == pytest.approx(lam, rel=0.1)
        else:
            assert d.max() == 0.0

    # (32, 0.94) regression: pmf(0) underflows float32 inside the inversion
    # gate — must fall through to the library sampler, not return n
    @pytest.mark.parametrize("n,p", [(0.0, 0.3), (5.0, 0.2), (30.0, 0.8),
                                     (32.0, 0.94), (30.0, 0.99), (500.0, 0.1)])
    def test_binomial_moments(self, n, p):
        keys = jax.random.split(jax.random.PRNGKey(int(n) + 1), 100)
        f = jax.jit(jax.vmap(lambda k: fast_binomial(
            k, jnp.full((400,), n), jnp.full((400,), p))))
        d = np.asarray(f(keys)).ravel()
        mean, var = n * p, n * p * (1 - p)
        se = max(np.sqrt(var / d.size), 1e-9)
        assert d.mean() == pytest.approx(mean, abs=6 * se + 1e-9)
        assert d.max() <= n
        assert d.min() >= 0.0

    def test_compact_ptrs_matches_dense_distribution(self):
        """The rank-compacted heavy-lane path (size >= _PTRS_COMPACT_MIN)
        draws from the same distribution as the dense loop."""
        from repro.core.processes import (_poisson_ptrs,
                                          _poisson_ptrs_compact)
        lam = jnp.zeros(2048).at[::100].set(75.0) + 0.5
        act = lam > 10.0
        keys = jax.random.split(jax.random.PRNGKey(11), 150)
        comp = np.asarray(jax.jit(jax.vmap(
            lambda k: _poisson_ptrs_compact(k, lam, act)))(keys))
        dense = np.asarray(jax.jit(jax.vmap(
            lambda k: _poisson_ptrs(k, lam, act)))(keys))
        heavy = np.asarray(act)
        for d in (comp, dense):
            x = d[:, heavy].ravel()
            se = np.sqrt(75.0 / x.size)
            assert x.mean() == pytest.approx(75.0, abs=6 * se)
            assert (d[:, ~heavy] == 0.0).all()  # inactive lanes untouched

    def test_compact_ptrs_overflow_lanes_exact(self):
        """More heavy lanes than the compact buffer: the overflow full-width
        pass must keep the distribution exact (forced: 1500 heavy lanes vs a
        2048/8=256 buffer)."""
        lam = jnp.concatenate([jnp.full((1500,), 45.0),
                               jnp.full((548,), 0.2)])
        keys = jax.random.split(jax.random.PRNGKey(12), 60)
        d = np.asarray(jax.jit(jax.vmap(
            lambda k: fast_poisson(k, lam)))(keys))
        x = d[:, :1500].ravel()
        se = np.sqrt(45.0 / x.size)
        assert x.mean() == pytest.approx(45.0, abs=6 * se)
        assert x.var() == pytest.approx(45.0, rel=0.1)

    def test_heterogeneous_rates_exact_group_means(self):
        """A heavy-tailed rate vector (the simulator's regime): both hybrid
        branches produce the analytic mean within MC error, per rate group."""
        groups = [(0.2, 200), (5.0, 200), (30.0, 80), (200.0, 32)]
        rate = jnp.concatenate([jnp.full((n,), lam) for lam, n in groups])
        keys = jax.random.split(jax.random.PRNGKey(3), 60)
        ours = np.asarray(jax.jit(jax.vmap(
            lambda k: fast_poisson(k, rate)))(keys))
        start = 0
        for lam, n in groups:
            d = ours[:, start:start + n].ravel()
            start += n
            se = np.sqrt(lam / d.size)
            assert d.mean() == pytest.approx(lam, abs=6 * se), f"lam={lam}"


class TestPtrsCompactCrossover:
    """Regression: exactness at the ``_PTRS_COMPACT_MIN`` crossover itself.

    The existing distribution tests exercise the compact path far from the
    guard (2048 lanes); these pin the boundary: which branch runs on each
    side of the guard, and exact behavior when the heavy-lane count sits
    exactly at / one past the compact buffer."""

    def test_guard_selects_branch_on_each_side(self, monkeypatch):
        """lam.size == _PTRS_COMPACT_MIN routes through the compact path;
        one lane fewer stays on the dense loop."""
        from repro.core import processes

        calls = []
        real = processes._poisson_ptrs_compact
        monkeypatch.setattr(
            processes, "_poisson_ptrs_compact",
            lambda key, lam, act: calls.append(lam.size) or real(key, lam,
                                                                 act))
        n_min = processes._PTRS_COMPACT_MIN
        key = jax.random.PRNGKey(0)
        below = processes.fast_poisson(key, jnp.full((n_min - 1,), 50.0))
        assert calls == []
        above = processes.fast_poisson(key, jnp.full((n_min,), 50.0))
        assert calls == [n_min]
        assert below.shape == (n_min - 1,) and above.shape == (n_min,)

    def test_boundary_sizes_match_poisson_moments(self):
        """Both sides of the guard draw from the same distribution: the
        heavy-lane mean/variance are exact at sizes min-1 and min."""
        from repro.core.processes import _PTRS_COMPACT_MIN

        lam_val = 60.0
        keys = jax.random.split(jax.random.PRNGKey(21), 80)
        for n in (_PTRS_COMPACT_MIN - 1, _PTRS_COMPACT_MIN):
            # a realistic mix: mostly small lanes, a sprinkle of heavy ones
            lam = jnp.full((n,), 0.4).at[::37].set(lam_val)
            d = np.asarray(jax.jit(jax.vmap(
                lambda k: fast_poisson(k, lam)))(keys))
            heavy = d[:, ::37].ravel()
            se = np.sqrt(lam_val / heavy.size)
            assert heavy.mean() == pytest.approx(lam_val, abs=6 * se), n
            assert heavy.var() == pytest.approx(lam_val, rel=0.15), n

    def test_buffer_exactly_full_and_one_over(self):
        """Heavy-lane count == compact buffer (every rank fits, none spare)
        and == buffer + 1 (exactly one overflow lane): all heavy lanes get
        real draws, inactive lanes stay zero, and the overflow lane — the
        lane with the highest rank, parked at the array's end — is exact."""
        from repro.core.processes import (_PTRS_BUF_DIV, _PTRS_COMPACT_MIN,
                                          _poisson_ptrs_compact)

        n = _PTRS_COMPACT_MIN
        buf = n // _PTRS_BUF_DIV
        lam_val = 35.0
        keys = jax.random.split(jax.random.PRNGKey(5), 100)
        for n_heavy in (buf, buf + 1):
            # heavy lanes spread over the array, the last one at index n-1
            idx = np.linspace(0, n - 1, n_heavy).round().astype(int)
            lam = jnp.zeros(n).at[idx].set(lam_val)
            act = lam > 0.0
            d = np.asarray(jax.jit(jax.vmap(
                lambda k: _poisson_ptrs_compact(k, lam, act)))(keys))
            assert (d[:, np.asarray(~act)] == 0.0).all(), n_heavy
            heavy = d[:, idx]
            # every heavy lane is actually sampled (P[all 100 draws = 0]
            # at lam=35 is ~0), including the rank-(buf) overflow lane
            assert (heavy.max(axis=0) > 0.0).all(), n_heavy
            flat = heavy.ravel()
            se = np.sqrt(lam_val / flat.size)
            assert flat.mean() == pytest.approx(lam_val, abs=6 * se), n_heavy
            assert flat.var() == pytest.approx(lam_val, rel=0.15), n_heavy
            if n_heavy == buf + 1:
                last = heavy[:, -1]
                se1 = np.sqrt(lam_val / last.size)
                assert last.mean() == pytest.approx(lam_val, abs=6 * se1)


class TestSimConfigConstruction:
    def test_make_config_defaults_priors(self):
        cfg = make_config(capacity=100.0)
        assert cfg.priors == AZURE_PRIORS

    def test_none_priors_raises_clearly(self):
        with pytest.raises(ValueError, match="priors"):
            make_run(SimConfig(), jnp.ones(4), ZEROTH)

    def test_bad_refresh_raises(self):
        with pytest.raises(ValueError, match="agg_refresh_steps"):
            make_config(horizon_hours=240.0, dt=24.0, agg_refresh_steps=3)

    def test_bad_mode_raises(self):
        with pytest.raises(ValueError, match="prior_mode"):
            make_config(prior_mode="bogus")


class TestSimulatorFastPath:
    CFG = make_config(capacity=500.0, arrival_rate=0.1, horizon_hours=20 * 24.0,
                      dt=24.0, max_slots=64, max_arrivals=4, d_points=8)
    GRID = geometric_grid(24.0, 3 * 20 * 24.0, 8)

    @pytest.mark.slow
    def test_fused_equals_reference_backend_exactly(self):
        """With identical refresh cadence the two backends may differ only in
        float round-off, so whole-run metrics stay statistically identical."""
        pol = make_policy(SECOND, rho=0.15, capacity=self.CFG.capacity)
        runs = {}
        for backend in ("fused", "reference"):
            cfg = self.CFG._replace(agg_backend=backend)
            m = make_run(cfg, self.GRID, SECOND)(jax.random.PRNGKey(2), pol)
            runs[backend] = m
        assert float(runs["fused"].arrivals_accepted) == pytest.approx(
            float(runs["reference"].arrivals_accepted), abs=1.0)
        assert float(runs["fused"].utilization) == pytest.approx(
            float(runs["reference"].utilization), rel=0.05)

    @pytest.mark.slow
    def test_refresh_staleness_is_bounded(self):
        """Refresh staleness perturbs admission both ways (missed deaths
        overstate the aggregate, missed scale-out growth understates it) —
        the residual bias is absorbed by SLA-constrained threshold tuning at
        the same K. The magnitude here is exaggerated by the test's dt=24h
        (K=4 -> 4 stale days on a 20-day run; production presets run
        dt=12h/6h with K*dt <= 4 days on year-plus horizons), so only a
        loose utilization band is asserted."""
        pol = make_policy(SECOND, rho=0.15, capacity=self.CFG.capacity)
        utils = {}
        for k in (1, 4):
            cfg = self.CFG._replace(agg_refresh_steps=k)
            m = run_batch(make_run(cfg, self.GRID, SECOND),
                          jax.random.PRNGKey(0), pol, 4)
            utils[k] = float(jnp.mean(m.utilization))
        assert 0.5 * utils[1] <= utils[4] <= 1.5 * utils[1]

    def test_placement_overflow_and_capacity_invariants(self):
        cfg = self.CFG._replace(max_slots=8)
        run = make_run(cfg, self.GRID, ZEROTH)
        pol = make_policy(ZEROTH, threshold=1e9, capacity=cfg.capacity)
        m = run(jax.random.PRNGKey(0), pol)
        assert float(m.arrivals_accepted) > 0.0
        assert float(m.slot_overflow) >= 0.0
        assert float(jnp.max(m.util_trace)) <= cfg.capacity + 1e-6

    def test_run_batch_sharded_matches_shape(self):
        cfg = self.CFG._replace(horizon_hours=10 * 24.0, max_slots=48)
        pol = make_policy(ZEROTH, threshold=300.0, capacity=cfg.capacity)
        run = make_run(cfg, self.GRID, ZEROTH)
        m = run_batch(run, jax.random.PRNGKey(0), pol, 2)
        assert m.utilization.shape == (2,)
        assert bool(jnp.all(jnp.isfinite(m.utilization)))
