"""Distribution semantics on a real multi-device (virtual) mesh.

These tests need >1 XLA device, so they re-exec python with
``--xla_force_host_platform_device_count=8`` (device count locks at first jax
init; the main test process must stay at 1 device for the smoke tests).
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(snippet: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """Loss on a (2,4) data×model mesh == loss on one device."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import build_model, get_config, reduced_config
from repro.train.step import (abstract_train_state, batch_shardings,
                              init_train_state, make_train_step,
                              state_shardings)
from repro.optim.adamw import AdamWConfig
from repro.data.pipeline import PipelineConfig, make_batch

cfg = reduced_config(get_config('llama3.2-1b'))
model = build_model(cfg)
mesh = jax.make_mesh((2, 4), ('data', 'model'))
state = init_train_state(model, jax.random.PRNGKey(0))
batch = make_batch(PipelineConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=4), 0)
opt = AdamWConfig(warmup_steps=0)
step_plain = jax.jit(make_train_step(model, opt, None))
_, m_plain = step_plain(state, batch)

st_sh = state_shardings(model, mesh)
state_sharded = jax.device_put(state, st_sh)
b_sh = batch_shardings(
    {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()},
    mesh)
batch_sharded = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
step_fn = jax.jit(make_train_step(model, opt, mesh),
                  in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))
_, m_sharded = step_fn(state_sharded, batch_sharded)
np.testing.assert_allclose(float(m_plain['loss']), float(m_sharded['loss']),
                           rtol=2e-4)
print('OK', float(m_plain['loss']), float(m_sharded['loss']))
""")


@pytest.mark.slow
def test_moe_local_dispatch_matches_global():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.moe import MoEConfig, moe, moe_local, moe_params
from repro.models.spec import init_params

mesh = jax.make_mesh((2, 4), ('data', 'model'))
cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2,
                capacity_factor=8.0)
params = init_params(jax.random.PRNGKey(0), moe_params(cfg))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
xs = jax.device_put(x, NamedSharding(mesh, P('data', None, None)))
ref = moe(params, cfg, x)
loc = jax.jit(lambda p, xx: moe_local(p, cfg, xx, mesh))(params, xs)
np.testing.assert_allclose(np.asarray(ref.y), np.asarray(loc.y),
                           rtol=1e-5, atol=1e-5)
print('OK')
""")


def test_sharded_cache_update_matches_plain():
    """Owner-rank shard_map cache write == plain dynamic_update_slice."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.layers import _cache_update

mesh = jax.make_mesh((2, 4), ('data', 'model'))
cache = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 2, 8))
new = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 2, 8))
for slot in (0, 7, 13, 31):
    want = jax.lax.dynamic_update_slice(cache, new, (0, slot, 0, 0))
    cs = jax.device_put(cache, NamedSharding(mesh, P('data', 'model', None, None)))
    got = jax.jit(lambda c, n, s: _cache_update(c, n, s, mesh))(
        cs, new, jnp.asarray(slot, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
print('OK')
""")


@pytest.mark.slow
def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint on a (2,4) mesh, restore onto (1,8) and (8,1) — elastic."""
    _run(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.models import build_model, get_config, reduced_config
from repro.train.step import init_train_state
from repro.checkpoint import checkpointer
from repro.runtime.elastic import reshard_restore, reshard_in_memory

cfg = reduced_config(get_config('llama3.2-1b'))
model = build_model(cfg)
state = init_train_state(model, jax.random.PRNGKey(0))
checkpointer.save({str(tmp_path)!r}, 7, state)
for shape in ((1, 8), (8, 1), (2, 4)):
    mesh = jax.make_mesh(shape, ('data', 'model'))
    restored, step = reshard_restore(model, {str(tmp_path)!r}, mesh)
    assert step == 7
    for a, b in zip(jax.tree.leaves(restored.params),
                    jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    live = reshard_in_memory(restored, model, mesh)
print('OK')
""")
