"""Policy semantics (paper §4 definitions) + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.core import (AZURE_PRIORS, FIRST, SECOND, ZEROTH,
                        belief_from_prior, decide, geometric_grid,
                        admit_sequential, is_safe, make_policy,
                        moment_curves, tune_threshold)
from repro.core.moments import MomentCurves
from repro.core.pomdp import cantelli_bound, failure_bound, markov_bound

GRID_N = 8


def _cand(el=5.0, vl=10.0):
    return MomentCurves(EL=jnp.full((GRID_N,), el),
                        VL=jnp.full((GRID_N,), vl))


class TestDecide:
    def test_zeroth_threshold_semantics(self):
        pol = make_policy(ZEROTH, threshold=100.0, capacity=1000.0)
        z = jnp.zeros(GRID_N)
        ok = decide(pol, z, z, jnp.asarray(90.0), _cand(), jnp.asarray(9.0))
        assert bool(ok)  # 99 < 100
        ok = decide(pol, z, z, jnp.asarray(91.0), _cand(), jnp.asarray(9.0))
        assert not bool(ok)  # 100 !< 100

    def test_first_moment_checks_every_horizon_point(self):
        pol = make_policy(FIRST, threshold=50.0, capacity=1000.0)
        agg = jnp.zeros(GRID_N).at[3].set(48.0)
        ok = decide(pol, agg, jnp.zeros(GRID_N), jnp.asarray(0.0),
                    _cand(el=1.0), jnp.asarray(1.0))
        assert bool(ok)
        ok = decide(pol, agg, jnp.zeros(GRID_N), jnp.asarray(0.0),
                    _cand(el=3.0), jnp.asarray(1.0))
        assert not bool(ok)  # 48 + 3 > 50 at point 3

    def test_second_moment_variance_sensitivity(self):
        """Same mean, higher variance -> rejected (the paper's motivation
        for the second-moment policy)."""
        pol = make_policy(SECOND, rho=0.05, capacity=100.0)
        agg_el = jnp.full((GRID_N,), 50.0)
        lo = decide(pol, agg_el, jnp.full((GRID_N,), 10.0), jnp.asarray(50.0),
                    _cand(el=5.0, vl=1.0), jnp.asarray(5.0))
        hi = decide(pol, agg_el, jnp.full((GRID_N,), 10.0), jnp.asarray(50.0),
                    _cand(el=5.0, vl=500.0), jnp.asarray(5.0))
        assert bool(lo) and not bool(hi)

    def test_capacity_is_hard_constraint(self):
        pol = make_policy(ZEROTH, threshold=1e9, capacity=100.0)
        z = jnp.zeros(GRID_N)
        ok = decide(pol, z, z, jnp.asarray(95.0), _cand(), jnp.asarray(6.0))
        assert not bool(ok)  # request itself does not fit

    def test_marginal_heuristic_def4(self):
        """A marginal candidate (E[L_n] < 1e-5 everywhere) is admitted even
        when the base condition fails."""
        agg = jnp.full((GRID_N,), 1e6)  # wildly unsafe
        base = make_policy(SECOND, rho=0.01, capacity=1000.0)
        marg = make_policy(SECOND, rho=0.01, capacity=1000.0, marginal=True)
        cand = _cand(el=1e-6, vl=1e-9)
        assert not bool(decide(base, agg, agg, jnp.asarray(10.0), cand,
                               jnp.asarray(1.0)))
        assert bool(decide(marg, agg, agg, jnp.asarray(10.0), cand,
                           jnp.asarray(1.0)))


class TestAdmitSequential:
    def test_greedy_order_and_aggregate_update(self):
        pol = make_policy(FIRST, threshold=10.0, capacity=100.0)
        cands = MomentCurves(EL=jnp.full((3, GRID_N), 4.0),
                             VL=jnp.zeros((3, GRID_N)))
        res = admit_sequential(pol, jnp.zeros(GRID_N), jnp.zeros(GRID_N),
                               jnp.asarray(0.0), cands,
                               jnp.asarray([1.0, 1.0, 1.0]),
                               jnp.asarray([True, True, True]))
        # 4 + 4 <= 10 but 12 > 10: first two admitted
        assert res.accept.tolist() == [True, True, False]
        assert float(res.agg_el[0]) == pytest.approx(8.0)
        assert float(res.util) == pytest.approx(2.0)

    def test_invalid_slots_skipped(self):
        pol = make_policy(FIRST, threshold=10.0, capacity=100.0)
        cands = MomentCurves(EL=jnp.full((2, GRID_N), 4.0),
                             VL=jnp.zeros((2, GRID_N)))
        res = admit_sequential(pol, jnp.zeros(GRID_N), jnp.zeros(GRID_N),
                               jnp.asarray(0.0), cands,
                               jnp.asarray([1.0, 1.0]),
                               jnp.asarray([False, True]))
        assert res.accept.tolist() == [False, True]


class TestBounds:
    @settings(max_examples=50, deadline=None)
    @given(el=st.floats(0.1, 500.0), vl=st.floats(0.0, 1e4),
           c=st.floats(1.0, 1e3))
    def test_bounds_are_probabilities_and_ordered(self, el, vl, c):
        m = float(markov_bound(jnp.asarray(el), c))
        ca = float(cantelli_bound(jnp.asarray(el), jnp.asarray(vl), c))
        f = float(failure_bound(jnp.asarray(el), jnp.asarray(vl), c))
        assert 0.0 <= ca <= 1.0
        assert f <= m + 1e-9 and f <= ca + 1e-9

    def test_cantelli_tightens_with_slack(self):
        vl = jnp.asarray(100.0)
        b1 = float(cantelli_bound(jnp.asarray(50.0), vl, 100.0))
        b2 = float(cantelli_bound(jnp.asarray(90.0), vl, 100.0))
        assert b1 < b2


class TestSafety:
    def test_is_safe_matches_policy_condition(self):
        pol = make_policy(SECOND, rho=0.1, capacity=100.0)
        safe = is_safe(pol, jnp.full((GRID_N,), 10.0), jnp.full((GRID_N,), 1.0))
        unsafe = is_safe(pol, jnp.full((GRID_N,), 99.0),
                         jnp.full((GRID_N,), 500.0))
        assert bool(safe) and not bool(unsafe)


class TestTuning:
    def test_binary_search_monotone_target(self):
        # failure rate monotone in threshold: recover the crossing point
        crossing = 0.37
        f = lambda t: 0.0 if t <= crossing else (t - crossing)
        t = tune_threshold(f, 0.0, 1.0, target_sla=0.01, iters=20)
        assert t == pytest.approx(crossing + 0.01, abs=1e-3)


class TestPolicyOnRealCurves:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_monotonicity_more_load_never_more_admission(self, seed):
        """Property: if a candidate is rejected at aggregate load X it stays
        rejected at any aggregate load >= X (same shape)."""
        key = jax.random.PRNGKey(seed)
        bel = belief_from_prior(AZURE_PRIORS, (4,))
        cores = 1.0 + jax.random.poisson(key, 10.0, (4,)).astype(jnp.float32)
        grid = geometric_grid(6.0, 26_280.0, GRID_N)
        curves = moment_curves(bel, cores, grid, AZURE_PRIORS)
        agg_el = jnp.sum(curves.EL, 0)
        agg_vl = jnp.sum(curves.VL, 0)
        pol = make_policy(SECOND, rho=0.1, capacity=200.0)
        cand = MomentCurves(curves.EL[0], curves.VL[0])
        c0 = cores[0]
        low = decide(pol, agg_el, agg_vl, jnp.asarray(10.0), cand, c0)
        high = decide(pol, agg_el * 2.0, agg_vl * 2.0, jnp.asarray(10.0),
                      cand, c0)
        assert bool(high) <= bool(low)
