"""Online/offline equivalence and the micro-batching front-end.

The ``OnlineAdmissionEngine`` is built from the *same*
``make_admission_core`` functions the offline drivers scan — so feeding it
the exact event keys and arrival stream a ``make_run`` call draws must
reproduce the offline decisions and final metrics bit-for-bit. These tests
assert exactly that (single cluster tier-1; the quick-preset fleet variant
is slow-marked), plus the submit/flush future contract, the background
pump, observed-event ingestion, and the tuned-operating-point loader the
daemon depends on.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import (AZURE_PRIORS, SECOND, ZEROTH, fleet_policy,
                        geometric_grid, make_policy)
from repro.serve import (Arrival, ExternalEvents, OnlineAdmissionEngine,
                         default_policy_param, format_operating_derived,
                         load_operating_point, operating_row_name)
from repro.sim import (FleetConfig, LeastUtilizedRouter, SimConfig,
                       draw_arrival_stream, make_fleet_run, make_run)

CFG = SimConfig(capacity=500.0, arrival_rate=0.08, horizon_hours=30 * 24.0,
                dt=24.0, max_slots=96, max_arrivals=4, d_points=8,
                priors=AZURE_PRIORS, agg_refresh_steps=3)
GRID = geometric_grid(24.0, 3 * 30 * 24.0, 12)

SMALL = CFG._replace(horizon_hours=6 * 24.0, max_slots=32,
                     agg_refresh_steps=1)


def _offline_inputs(key, cfg):
    """Replicate make_run's key discipline: one stream draw, then one event
    key per step."""
    k_stream, k_scan = jax.random.split(key)
    stream = draw_arrival_stream(k_stream, cfg)
    keys = jax.random.split(k_scan, cfg.n_steps)
    return stream, keys


def _drive(engine, stream, keys):
    n_arr = np.asarray(stream.n_arrivals)
    n_lanes = stream.c0.shape[1]
    accepts = []
    for t in range(keys.shape[0]):
        engine.tick(keys[t])
        slice_t = jax.tree.map(lambda x: x[t], stream)
        accepts.append(engine.decide_slice(
            slice_t, np.arange(n_lanes) < n_arr[t]))
    return np.stack(accepts)


def _assert_metrics_equal(off, on):
    for name, val in off._asdict().items():
        got = getattr(on, name)
        if hasattr(val, "_asdict"):
            _assert_metrics_equal(val, got)
        else:
            np.testing.assert_array_equal(np.asarray(val), np.asarray(got),
                                          err_msg=name)


def test_single_cluster_matches_offline_bit_for_bit():
    pol = make_policy(SECOND, rho=0.05, capacity=CFG.capacity)
    key = jax.random.PRNGKey(1)
    m_off, acc_off = make_run(CFG, GRID, SECOND,
                              record_decisions=True)(key, pol)
    stream, keys = _offline_inputs(key, CFG)
    eng = OnlineAdmissionEngine(CFG, GRID, SECOND, pol)
    acc_on = _drive(eng, stream, keys)
    np.testing.assert_array_equal(acc_on, np.asarray(acc_off))
    _assert_metrics_equal(m_off, eng.metrics())


@pytest.mark.slow
def test_fleet_quick_preset_matches_offline_bit_for_bit():
    # the quick preset's shapes (1536 slots, 32-point grid, K=8) over a
    # shortened horizon — heavy enough to exercise the vmapped fleet path
    # at production state size
    base = SimConfig(capacity=5_000.0, arrival_rate=0.25,
                     horizon_hours=40 * 12.0, dt=12.0, max_slots=1536,
                     max_arrivals=5, priors=AZURE_PRIORS,
                     agg_refresh_steps=8)
    fleet = FleetConfig(base=base, capacities=(3_000.0, 2_000.0))
    grid = geometric_grid(12.0, 3 * 40 * 12.0, 32)
    pol = fleet_policy(SECOND, capacities=fleet.capacities, rho=0.08)
    key = jax.random.PRNGKey(2)
    m_off, acc_off, _ = make_fleet_run(
        fleet, grid, SECOND, router=LeastUtilizedRouter(),
        record_decisions=True)(key, pol)
    stream, keys = _offline_inputs(key, base)
    eng = OnlineAdmissionEngine(fleet, grid, SECOND, pol,
                                router=LeastUtilizedRouter())
    acc_on = _drive(eng, stream, keys)
    np.testing.assert_array_equal(acc_on, np.any(np.asarray(acc_off), axis=1))
    _assert_metrics_equal(m_off, eng.metrics())


def test_submit_flush_matches_decide_slice():
    """The micro-batching front-end stacks submitted tickets onto exactly
    the decide_slice path: same arrivals, same decisions."""
    pol = make_policy(SECOND, rho=0.05, capacity=SMALL.capacity)
    key = jax.random.PRNGKey(3)
    stream, keys = _offline_inputs(key, SMALL)
    n_arr = np.asarray(stream.n_arrivals)
    n_lanes = stream.c0.shape[1]

    ref = OnlineAdmissionEngine(SMALL, GRID, SECOND, pol)
    acc_ref = _drive(ref, stream, keys)

    eng = OnlineAdmissionEngine(SMALL, GRID, SECOND, pol)
    for t in range(SMALL.n_steps):
        eng.tick(keys[t])
        futs = [eng.submit(Arrival.from_stream(stream, t, a))
                for a in range(min(int(n_arr[t]), n_lanes))]
        assert eng.n_pending == len(futs)
        eng.flush()
        got = [f.result() for f in futs]
        want = list(acc_ref[t][:len(futs)])
        assert got == [bool(w) for w in want]
    assert eng.decisions == int(np.minimum(n_arr, n_lanes).sum())


def test_background_pump_resolves_futures():
    pol = make_policy(ZEROTH, threshold=SMALL.capacity,
                      capacity=SMALL.capacity)
    eng = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, micro_batch=4)
    eng.tick(jax.random.PRNGKey(0))
    eng.start(interval_s=0.001)
    try:
        keys = jax.random.split(jax.random.PRNGKey(4), 10)
        futs = [eng.submit(Arrival.draw(k, SMALL)) for k in keys]
        results = [f.result(timeout=30) for f in futs]
    finally:
        eng.stop()
    assert all(isinstance(r, bool) for r in results)
    assert eng.decisions == len(futs)


def test_external_event_ingestion():
    """Production path: observed departures/scale-outs via tick(events=)."""
    pol = make_policy(ZEROTH, threshold=SMALL.capacity,
                      capacity=SMALL.capacity)
    eng = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, micro_batch=4)
    eng.tick(jax.random.PRNGKey(0))
    fut = eng.submit(Arrival.draw(jax.random.PRNGKey(5), SMALL))
    eng.flush()
    assert fut.result() is True  # empty cluster, threshold = capacity

    s = SMALL.max_slots
    zeros = np.zeros(s, np.float32)
    no_deaths = np.zeros(s, bool)
    scaleout = zeros.copy()
    scaleout[0] = 5.0          # sequential placement: first slot
    n_req = zeros.copy()
    n_req[0] = 1.0
    eng.tick(events=ExternalEvents(core_deaths=zeros, spont_death=no_deaths,
                                   scaleout_cores=scaleout,
                                   n_scaleouts=n_req))
    m = eng.metrics()
    assert int(m.total_requests) == 1
    assert int(m.failed_requests) == 0
    assert int(m.alive_end) == 1

    kill = no_deaths.copy()
    kill[0] = True
    eng.tick(events=ExternalEvents(core_deaths=zeros, spont_death=kill,
                                   scaleout_cores=zeros, n_scaleouts=zeros))
    m = eng.metrics()
    assert int(m.alive_end) == 0
    assert int(m.n_departed) == 1


def test_tick_and_flush_protocol_errors():
    pol = make_policy(SECOND, rho=0.05, capacity=SMALL.capacity)
    eng = OnlineAdmissionEngine(SMALL, GRID, SECOND, pol)
    with pytest.raises(RuntimeError):
        eng.flush()
    with pytest.raises(ValueError):
        eng.tick()
    with pytest.raises(ValueError):
        eng.tick(jax.random.PRNGKey(0),
                 events=ExternalEvents(*[np.zeros(SMALL.max_slots)] * 4))


def test_operating_point_roundtrip(tmp_path):
    rows = [
        {"name": operating_row_name("quick", "second"), "us_per_call": 0.0,
         "derived": format_operating_derived(0.08, 5_000.0, 5e-4)},
        {"name": operating_row_name("quick", "first"), "us_per_call": 0.0,
         "derived": format_operating_derived(1_850.0, 5_000.0, 5e-4)},
    ]
    path = tmp_path / "BENCH_quick.json"
    path.write_text(json.dumps({"scale": "quick", "rows": rows}))

    op = load_operating_point("second", "quick", bench_path=str(path))
    assert op.theta == 0.08 and op.capacity == 5_000.0 and op.tau == 5e-4
    # rho is scale-free; thresholds rescale linearly with capacity
    assert op.theta_for(1_000.0) == 0.08
    first = load_operating_point("first", "quick", bench_path=str(path))
    assert first.theta_for(1_000.0) == pytest.approx(370.0)

    assert default_policy_param("second", 1_000.0,
                                bench_path=str(path)) == 0.08
    missing = tmp_path / "nope.json"
    with pytest.warns(UserWarning, match="falling back"):
        param = default_policy_param("second", 1_000.0,
                                     bench_path=str(missing))
    assert param == 0.15
    with pytest.warns(UserWarning):
        param = default_policy_param("zeroth", 1_000.0,
                                     bench_path=str(missing))
    assert param == 700.0
