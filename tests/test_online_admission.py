"""Online/offline equivalence and the micro-batching front-end.

The ``OnlineAdmissionEngine`` is built from the *same*
``make_admission_core`` functions the offline drivers scan — so feeding it
the exact event keys and arrival stream a ``make_run`` call draws must
reproduce the offline decisions and final metrics bit-for-bit. These tests
assert exactly that (single cluster tier-1; the quick-preset fleet variant
is slow-marked), plus the submit/flush future contract, the background
pump, observed-event ingestion, and the tuned-operating-point loader the
daemon depends on.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import (AZURE_PRIORS, SECOND, ZEROTH, fleet_policy,
                        geometric_grid, make_policy)
from repro.serve import (Arrival, ExternalEvents, OnlineAdmissionEngine,
                         default_policy_param, format_operating_derived,
                         load_operating_point, operating_row_name)
from repro.sim import (FleetConfig, LeastUtilizedRouter, SimConfig,
                       draw_arrival_stream, make_fleet_run, make_run)

CFG = SimConfig(capacity=500.0, arrival_rate=0.08, horizon_hours=30 * 24.0,
                dt=24.0, max_slots=96, max_arrivals=4, d_points=8,
                priors=AZURE_PRIORS, agg_refresh_steps=3)
GRID = geometric_grid(24.0, 3 * 30 * 24.0, 12)

SMALL = CFG._replace(horizon_hours=6 * 24.0, max_slots=32,
                     agg_refresh_steps=1)


def _offline_inputs(key, cfg):
    """Replicate make_run's key discipline: one stream draw, then one event
    key per step."""
    k_stream, k_scan = jax.random.split(key)
    stream = draw_arrival_stream(k_stream, cfg)
    keys = jax.random.split(k_scan, cfg.n_steps)
    return stream, keys


def _drive(engine, stream, keys):
    n_arr = np.asarray(stream.n_arrivals)
    n_lanes = stream.c0.shape[1]
    accepts = []
    for t in range(keys.shape[0]):
        engine.tick(keys[t])
        slice_t = jax.tree.map(lambda x: x[t], stream)
        accepts.append(engine.decide_slice(
            slice_t, np.arange(n_lanes) < n_arr[t]))
    return np.stack(accepts)


def _assert_metrics_equal(off, on):
    for name, val in off._asdict().items():
        got = getattr(on, name)
        if hasattr(val, "_asdict"):
            _assert_metrics_equal(val, got)
        else:
            np.testing.assert_array_equal(np.asarray(val), np.asarray(got),
                                          err_msg=name)


def test_single_cluster_matches_offline_bit_for_bit():
    pol = make_policy(SECOND, rho=0.05, capacity=CFG.capacity)
    key = jax.random.PRNGKey(1)
    m_off, acc_off = make_run(CFG, GRID, SECOND,
                              record_decisions=True)(key, pol)
    stream, keys = _offline_inputs(key, CFG)
    eng = OnlineAdmissionEngine(CFG, GRID, SECOND, pol)
    acc_on = _drive(eng, stream, keys)
    np.testing.assert_array_equal(acc_on, np.asarray(acc_off))
    _assert_metrics_equal(m_off, eng.metrics())


@pytest.mark.slow
def test_fleet_quick_preset_matches_offline_bit_for_bit():
    # the quick preset's shapes (1536 slots, 32-point grid, K=8) over a
    # shortened horizon — heavy enough to exercise the vmapped fleet path
    # at production state size
    base = SimConfig(capacity=5_000.0, arrival_rate=0.25,
                     horizon_hours=40 * 12.0, dt=12.0, max_slots=1536,
                     max_arrivals=5, priors=AZURE_PRIORS,
                     agg_refresh_steps=8)
    fleet = FleetConfig(base=base, capacities=(3_000.0, 2_000.0))
    grid = geometric_grid(12.0, 3 * 40 * 12.0, 32)
    pol = fleet_policy(SECOND, capacities=fleet.capacities, rho=0.08)
    key = jax.random.PRNGKey(2)
    m_off, acc_off, _ = make_fleet_run(
        fleet, grid, SECOND, router=LeastUtilizedRouter(),
        record_decisions=True)(key, pol)
    stream, keys = _offline_inputs(key, base)
    eng = OnlineAdmissionEngine(fleet, grid, SECOND, pol,
                                router=LeastUtilizedRouter())
    acc_on = _drive(eng, stream, keys)
    np.testing.assert_array_equal(acc_on, np.any(np.asarray(acc_off), axis=1))
    _assert_metrics_equal(m_off, eng.metrics())


def test_submit_flush_matches_decide_slice():
    """The micro-batching front-end stacks submitted tickets onto exactly
    the decide_slice path: same arrivals, same decisions."""
    pol = make_policy(SECOND, rho=0.05, capacity=SMALL.capacity)
    key = jax.random.PRNGKey(3)
    stream, keys = _offline_inputs(key, SMALL)
    n_arr = np.asarray(stream.n_arrivals)
    n_lanes = stream.c0.shape[1]

    ref = OnlineAdmissionEngine(SMALL, GRID, SECOND, pol)
    acc_ref = _drive(ref, stream, keys)

    eng = OnlineAdmissionEngine(SMALL, GRID, SECOND, pol)
    for t in range(SMALL.n_steps):
        eng.tick(keys[t])
        futs = [eng.submit(Arrival.from_stream(stream, t, a))
                for a in range(min(int(n_arr[t]), n_lanes))]
        assert eng.n_pending == len(futs)
        eng.flush()
        got = [f.result() for f in futs]
        want = list(acc_ref[t][:len(futs)])
        assert got == [bool(w) for w in want]
    assert eng.decisions == int(np.minimum(n_arr, n_lanes).sum())


def test_background_pump_resolves_futures():
    pol = make_policy(ZEROTH, threshold=SMALL.capacity,
                      capacity=SMALL.capacity)
    eng = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, micro_batch=4)
    eng.tick(jax.random.PRNGKey(0))
    eng.start(interval_s=0.001)
    try:
        keys = jax.random.split(jax.random.PRNGKey(4), 10)
        futs = [eng.submit(Arrival.draw(k, SMALL)) for k in keys]
        results = [f.result(timeout=30) for f in futs]
    finally:
        eng.stop()
    assert all(isinstance(r, bool) for r in results)
    assert eng.decisions == len(futs)


def test_external_event_ingestion():
    """Production path: observed departures/scale-outs via tick(events=)."""
    pol = make_policy(ZEROTH, threshold=SMALL.capacity,
                      capacity=SMALL.capacity)
    eng = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, micro_batch=4)
    eng.tick(jax.random.PRNGKey(0))
    fut = eng.submit(Arrival.draw(jax.random.PRNGKey(5), SMALL))
    eng.flush()
    assert fut.result() is True  # empty cluster, threshold = capacity

    s = SMALL.max_slots
    zeros = np.zeros(s, np.float32)
    no_deaths = np.zeros(s, bool)
    scaleout = zeros.copy()
    scaleout[0] = 5.0          # sequential placement: first slot
    n_req = zeros.copy()
    n_req[0] = 1.0
    eng.tick(events=ExternalEvents(core_deaths=zeros, spont_death=no_deaths,
                                   scaleout_cores=scaleout,
                                   n_scaleouts=n_req))
    m = eng.metrics()
    assert int(m.total_requests) == 1
    assert int(m.failed_requests) == 0
    assert int(m.alive_end) == 1

    kill = no_deaths.copy()
    kill[0] = True
    eng.tick(events=ExternalEvents(core_deaths=zeros, spont_death=kill,
                                   scaleout_cores=zeros, n_scaleouts=zeros))
    m = eng.metrics()
    assert int(m.alive_end) == 0
    assert int(m.n_departed) == 1


def test_tick_and_flush_protocol_errors():
    pol = make_policy(SECOND, rho=0.05, capacity=SMALL.capacity)
    eng = OnlineAdmissionEngine(SMALL, GRID, SECOND, pol)
    with pytest.raises(RuntimeError):
        eng.flush()
    with pytest.raises(ValueError):
        eng.tick()
    with pytest.raises(ValueError):
        eng.tick(jax.random.PRNGKey(0),
                 events=ExternalEvents(*[np.zeros(SMALL.max_slots)] * 4))


def test_operating_point_roundtrip(tmp_path):
    rows = [
        {"name": operating_row_name("quick", "second"), "us_per_call": 0.0,
         "derived": format_operating_derived(0.08, 5_000.0, 5e-4)},
        {"name": operating_row_name("quick", "first"), "us_per_call": 0.0,
         "derived": format_operating_derived(1_850.0, 5_000.0, 5e-4)},
    ]
    path = tmp_path / "BENCH_quick.json"
    path.write_text(json.dumps({"scale": "quick", "rows": rows}))

    op = load_operating_point("second", "quick", bench_path=str(path))
    assert op.theta == 0.08 and op.capacity == 5_000.0 and op.tau == 5e-4
    # rho is scale-free; thresholds rescale linearly with capacity
    assert op.theta_for(1_000.0) == 0.08
    first = load_operating_point("first", "quick", bench_path=str(path))
    assert first.theta_for(1_000.0) == pytest.approx(370.0)

    assert default_policy_param("second", 1_000.0,
                                bench_path=str(path)) == 0.08
    missing = tmp_path / "nope.json"
    with pytest.warns(UserWarning, match="falling back"):
        param = default_policy_param("second", 1_000.0,
                                     bench_path=str(missing))
    assert param == 0.15
    with pytest.warns(UserWarning):
        param = default_policy_param("zeroth", 1_000.0,
                                     bench_path=str(missing))
    assert param == 700.0


# ---------------------------------------------------------------------------
# PR 9: sharded slot table, deadline-aware flush, concurrency bugfix pass
# ---------------------------------------------------------------------------


def _zero_events(cfg):
    s = cfg.max_slots
    return ExternalEvents(core_deaths=np.zeros(s, np.float32),
                          spont_death=np.zeros(s, bool),
                          scaleout_cores=np.zeros(s, np.float32),
                          n_scaleouts=np.zeros(s, np.float32))


def test_shards_validation_errors():
    from repro.sim import slot_mesh

    pol = make_policy(SECOND, rho=0.05, capacity=SMALL.capacity)
    # more shards than visible devices: actionable XLA_FLAGS guidance
    with pytest.raises(ValueError, match="host_platform_device_count"):
        OnlineAdmissionEngine(SMALL, GRID, SECOND, pol,
                              shards=jax.device_count() + 1)
    with pytest.raises(ValueError, match="n_shards"):
        slot_mesh(0)
    # fleet engines spread state over the cluster axis, not slot shards
    fleet = FleetConfig(base=SMALL, capacities=(300.0, 200.0))
    fpol = fleet_policy(SECOND, capacities=fleet.capacities, rho=0.05)
    with pytest.raises(ValueError, match="fleet"):
        OnlineAdmissionEngine(fleet, GRID, SECOND, fpol, shards=2)


def test_event_path_keys_derive_from_seed_chain():
    """Regression (PR 9): the observed-events tick path used to reseed with
    PRNGKey(self.ticks) — identical across engines and restarts. The key
    must now derive from the engine's seed chain: same seed => same chain,
    different seeds => diverging chains, and the chain advances per tick."""
    pol = make_policy(ZEROTH, threshold=SMALL.capacity,
                      capacity=SMALL.capacity)
    ev = _zero_events(SMALL)
    e_a = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, seed=0)
    e_b = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, seed=1)
    e_a2 = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, seed=0)
    for e in (e_a, e_b, e_a2):
        e.tick(events=ev)
    k_a, k_b, k_a2 = (np.asarray(e._step_key) for e in (e_a, e_b, e_a2))
    assert np.array_equal(k_a, k_a2)          # restart-reproducible
    assert not np.array_equal(k_a, k_b)       # engines decorrelate
    e_a.tick(events=ev)
    assert not np.array_equal(np.asarray(e_a._step_key), k_a)  # advances


def test_close_window_counter_idempotence():
    """Regression (PR 9): _close_window now zeroes the window accept/reject
    accumulators after folding them, so metrics() twice in a row (or
    metrics() followed by tick()) cannot double-count decisions."""
    pol = make_policy(ZEROTH, threshold=SMALL.capacity,
                      capacity=SMALL.capacity)
    key = jax.random.PRNGKey(11)
    eng = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, micro_batch=4)
    eng.tick(jax.random.PRNGKey(0))
    futs = [eng.submit(Arrival.draw(k, SMALL))
            for k in jax.random.split(key, 3)]
    eng.flush()
    assert all(f.result() for f in futs)      # empty cluster, thr=capacity
    m1 = eng.metrics()
    m2 = eng.metrics()                        # second close: no-op
    assert int(m1.arrivals_accepted) == int(m2.arrivals_accepted) == 3
    eng.tick(jax.random.PRNGKey(1))
    m3 = eng.metrics()
    assert int(m3.arrivals_accepted) == 3     # tick didn't re-fold them


def test_flush_failure_resolves_futures_with_exception():
    """A decide chunk that raises must fail the queued futures instead of
    leaving callers blocked forever."""
    pol = make_policy(ZEROTH, threshold=SMALL.capacity,
                      capacity=SMALL.capacity)
    eng = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, micro_batch=2)
    eng.tick(jax.random.PRNGKey(0))
    futs = [eng.submit(Arrival.draw(k, SMALL))
            for k in jax.random.split(jax.random.PRNGKey(1), 3)]
    boom = RuntimeError("decide exploded")

    def bad_decide(arrivals):
        raise boom

    eng._decide = bad_decide
    with pytest.raises(RuntimeError, match="decide exploded"):
        eng.flush()
    for f in futs:
        assert f.done()
        with pytest.raises(RuntimeError, match="decide exploded"):
            f.result(timeout=0)


def test_deadline_scheduler_fires_partial_and_full_batches():
    """flush_slo_ms switches start() to the deadline scheduler: paced
    sub-width load resolves via the deadline trigger within the SLO (zero
    recorded misses after warmup), and a width-sized burst fires on the
    width trigger without waiting for any deadline."""
    import time as _time

    pol = make_policy(ZEROTH, threshold=SMALL.capacity,
                      capacity=SMALL.capacity)
    eng = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, micro_batch=4,
                                flush_slo_ms=500.0)
    eng.tick(jax.random.PRNGKey(0))
    eng._decide([Arrival.draw(jax.random.PRNGKey(1), SMALL)])  # compile
    eng.start()
    try:
        # deadline trigger: 2 < width requests, nothing else arrives
        futs = [eng.submit(Arrival.draw(k, SMALL))
                for k in jax.random.split(jax.random.PRNGKey(2), 2)]
        t0 = _time.monotonic()
        assert all(f.result(timeout=10) for f in futs)
        assert _time.monotonic() - t0 <= 0.5 + 5.0   # resolved near the SLO
        # width trigger: a full batch goes immediately
        futs = [eng.submit(Arrival.draw(k, SMALL))
                for k in jax.random.split(jax.random.PRNGKey(3), 4)]
        assert all(isinstance(f.result(timeout=10), bool) for f in futs)
    finally:
        eng.stop()
    snap = eng.metrics_snapshot()["engine"]
    assert snap["deadline_misses"] == 0
    assert snap["flush_slo_ms"] == 500.0
    assert snap["n_shards"] == 1
    assert snap["decision_latency_seconds"].total == 6
    with pytest.raises(ValueError, match="flush_slo_ms"):
        OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, flush_slo_ms=-1.0)


def test_concurrency_stress_ticker_pump_submitters():
    """Ticker thread + background pump + N submitter threads: no exception
    anywhere, every future resolves, and the decisions equal a serial
    replay (deterministic zero-event dynamics + threshold=capacity make the
    outcome interleaving-invariant: everything fits, everything admits)."""
    import threading

    pol = make_policy(ZEROTH, threshold=SMALL.capacity,
                      capacity=SMALL.capacity)
    eng = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, micro_batch=4)
    ev = _zero_events(SMALL)
    eng.tick(events=ev)
    n_sub, per_sub = 3, 8
    arrivals = [[Arrival.draw(jax.random.PRNGKey(100 * i + j), SMALL)
                 for j in range(per_sub)] for i in range(n_sub)]
    results: dict = {}
    errors: list = []
    stop_ticks = threading.Event()

    def ticker():
        try:
            while not stop_ticks.is_set():
                eng.tick(events=ev)
                eng.metrics_snapshot()        # scrape racing the pump
        except Exception as exc:              # pragma: no cover
            errors.append(exc)

    def submitter(i):
        try:
            futs = [eng.submit(a) for a in arrivals[i]]
            results[i] = [f.result(timeout=60) for f in futs]
        except Exception as exc:              # pragma: no cover
            errors.append(exc)

    eng.start(interval_s=0.0)
    threads = [threading.Thread(target=ticker)]
    threads += [threading.Thread(target=submitter, args=(i,))
                for i in range(n_sub)]
    for t in threads:
        t.start()
    for t in threads[1:]:
        t.join(timeout=120)
    stop_ticks.set()
    threads[0].join(timeout=120)
    eng.stop()
    assert not errors, errors
    assert not any(t.is_alive() for t in threads)
    assert sorted(results) == list(range(n_sub))
    # serial replay: fresh engine, same arrivals, single thread
    ref = OnlineAdmissionEngine(SMALL, GRID, ZEROTH, pol, micro_batch=4)
    ref.tick(events=ev)
    for i in range(n_sub):
        futs = [ref.submit(a) for a in arrivals[i]]
        ref.flush()
        assert results[i] == [f.result() for f in futs]
    assert eng.decisions == n_sub * per_sub


@pytest.mark.slow
def test_sharded_engine_matches_unsharded_on_virtual_devices():
    """Tentpole acceptance (PR 9): on 8 virtual CPU devices, a shards=8
    engine is decision- and metric-equivalent — bit-for-bit — to the
    unsharded engine over the same stream, including the telemetry rider."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", """
import jax, numpy as np
from repro.core import AZURE_PRIORS, SECOND, geometric_grid, make_policy
from repro.serve import OnlineAdmissionEngine
from repro.sim import SimConfig, draw_arrival_stream

cfg = SimConfig(capacity=500.0, arrival_rate=0.08, horizon_hours=6*24.0,
                dt=24.0, max_slots=32, max_arrivals=4, d_points=8,
                priors=AZURE_PRIORS, agg_refresh_steps=1, telemetry=True)
grid = geometric_grid(24.0, 3*30*24.0, 12)
pol = make_policy(SECOND, rho=0.05, capacity=cfg.capacity)
k_stream, k_scan = jax.random.split(jax.random.PRNGKey(1))
stream = draw_arrival_stream(k_stream, cfg)
keys = jax.random.split(k_scan, cfg.n_steps)
n_arr = np.asarray(stream.n_arrivals)
n_lanes = stream.c0.shape[1]

def drive(engine):
    acc = []
    for t in range(keys.shape[0]):
        engine.tick(keys[t])
        sl = jax.tree.map(lambda x: x[t], stream)
        acc.append(engine.decide_slice(sl, np.arange(n_lanes) < n_arr[t]))
    return np.stack(acc), engine.metrics(), engine.metrics_snapshot()

assert jax.device_count() == 8
a1, m1, s1 = drive(OnlineAdmissionEngine(cfg, grid, SECOND, pol))
a8, m8, s8 = drive(OnlineAdmissionEngine(cfg, grid, SECOND, pol, shards=8))
np.testing.assert_array_equal(a1, a8)
for name in m1._fields:
    np.testing.assert_array_equal(np.asarray(getattr(m1, name)),
                                  np.asarray(getattr(m8, name)),
                                  err_msg=name)
assert s1['telemetry'] == s8['telemetry']
assert s8['engine']['n_shards'] == 8
print('OK', int(np.sum(a8)))
"""], env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr
    assert out.stdout.startswith("OK")
