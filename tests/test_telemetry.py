"""Telemetry layer: device counters, tracing, export, and the obs plumbing.

The observability contract is "free when off, invisible when on":

  * ``SimConfig(telemetry=False)`` (the default) compiles the rider out —
    the goldens pinned by ``tests/test_admission_core.py`` keep passing
    unchanged, which is the off-side proof.
  * ``telemetry=True`` must leave every decision and metric **bit-for-bit**
    identical to the committed goldens (asserted here against
    ``tests/data/golden_sim_metrics.npz``) while the rider's counters obey
    exact conservation laws (admits + rejects == routed == decided;
    histogram mass == event count).

Also covered: the online engine's non-blocking ``metrics_snapshot`` and its
offline equivalence, JSONL decision tracing, Prometheus text exposition
validity, the ``/metrics`` HTTP server, the daemon's SIGTERM graceful
shutdown (subprocess), the shared ``repro.obs.log`` logger, and the
vectorized ``bca_ci`` fast path (satellite of the same PR).
"""
import functools
import json
import logging
import os
import re
import signal
import subprocess
import sys
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.core import (AZURE_PRIORS, SECOND, ZEROTH, fleet_policy,
                        geometric_grid, make_policy)
from repro.obs import (DecisionTracer, HostHistogram, Metric, MetricsServer,
                       get_logger, render_prometheus, snapshot_to_prometheus,
                       telemetry_summary)
from repro.serve import Arrival, OnlineAdmissionEngine
from repro.sim import (FleetConfig, LeastUtilizedRouter, SimConfig,
                       draw_arrival_stream, make_fleet_run, make_run)
from repro.sim.metrics import bca_ci, weighted_mean
from repro.testing import given, settings, strategies

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_sim_metrics.npz")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the golden configs of tests/test_admission_core.py, telemetry switched on
CFG = SimConfig(capacity=500.0, arrival_rate=0.08, horizon_hours=30 * 24.0,
                dt=24.0, max_slots=96, max_arrivals=4, d_points=8,
                priors=AZURE_PRIORS)
GRID = geometric_grid(24.0, 3 * 30 * 24.0, 12)
FLEET2 = FleetConfig(base=CFG._replace(telemetry=True),
                     capacities=(300.0, 200.0))

SMALL = CFG._replace(horizon_hours=6 * 24.0, max_slots=32,
                     agg_refresh_steps=3, telemetry=True)


def _flat(prefix, metrics):
    out = {}
    for name, val in metrics._asdict().items():
        if hasattr(val, "_asdict"):
            out.update(_flat(f"{prefix}/{name}", val))
        else:
            out[f"{prefix}/{name}"] = np.asarray(val)
    return out


def _assert_conservation(s, m, *, n_windows, n_refreshes=None):
    """The exact counting laws every telemetry summary must satisfy."""
    decided = s["n_admit"] + s["n_reject_capacity"] + s["n_reject_policy"]
    assert decided == s["n_routed"]
    assert s["n_admit"] == float(np.sum(m.arrivals_accepted))
    assert decided == float(np.sum(m.arrivals_accepted)
                            + np.sum(m.arrivals_rejected))
    assert sum(s["staleness_hist"]) == s["n_routed"]
    assert s["n_windows"] == n_windows
    assert sum(s["occupancy_hist"]) == n_windows
    assert sum(s["headroom_hist"]) == n_windows
    if n_refreshes is not None:
        assert s["n_refreshes"] == n_refreshes
    assert 0 < s["arr_placed"] <= s["n_admit"]
    assert s["arr_c0_mean"] > 0 and s["arr_c0_var"] >= 0


# ---------------------------------------------------------------------------
# telemetry on == goldens, bit for bit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tel_runs():
    """The two single-cluster golden runs, rerun with telemetry enabled."""
    cfg0 = CFG._replace(telemetry=True)
    m0, tel0 = make_run(cfg0, GRID, ZEROTH)(
        jax.random.PRNGKey(0),
        make_policy(ZEROTH, threshold=300.0, capacity=CFG.capacity))
    cfg3 = CFG._replace(agg_refresh_steps=3, telemetry=True)
    m3, tel3 = make_run(cfg3, GRID, SECOND)(
        jax.random.PRNGKey(1),
        make_policy(SECOND, rho=0.05, capacity=CFG.capacity))
    return (m0, tel0), (m3, tel3)


def test_single_cluster_golden_bit_for_bit_with_telemetry(tel_runs):
    (m0, _), (m3, _) = tel_runs
    arrays = {}
    arrays.update(_flat("single/zeroth", m0))
    arrays.update(_flat("single/second_k3", m3))
    gold = np.load(GOLDEN)
    checked = 0
    for name in gold.files:
        if name.startswith("single/"):
            np.testing.assert_array_equal(gold[name], arrays[name],
                                          err_msg=name)
            checked += 1
    assert checked >= 20


def test_counter_conservation_on_golden_runs(tel_runs):
    (m0, tel0), (m3, tel3) = tel_runs
    s0 = telemetry_summary(tel0)
    _assert_conservation(s0, m0, n_windows=CFG.n_steps,
                         n_refreshes=CFG.n_steps)  # K=1: refresh every step
    s3 = telemetry_summary(tel3)
    _assert_conservation(s3, m3, n_windows=CFG.n_steps,
                         n_refreshes=CFG.n_steps // 3)


def test_decisions_identical_on_off():
    cfg = CFG._replace(agg_refresh_steps=3)
    pol = make_policy(SECOND, rho=0.05, capacity=cfg.capacity)
    key = jax.random.PRNGKey(1)
    m_off, acc_off = make_run(cfg, GRID, SECOND,
                              record_decisions=True)(key, pol)
    m_on, acc_on, tel = make_run(cfg._replace(telemetry=True), GRID, SECOND,
                                 record_decisions=True)(key, pol)
    np.testing.assert_array_equal(np.asarray(acc_off), np.asarray(acc_on))
    for name, val in m_off._asdict().items():
        np.testing.assert_array_equal(np.asarray(val),
                                      np.asarray(getattr(m_on, name)),
                                      err_msg=name)
    assert telemetry_summary(tel)["n_admit"] == float(
        np.sum(np.asarray(acc_on)))


@functools.lru_cache(maxsize=1)
def _tel_run():
    cfg = CFG._replace(agg_refresh_steps=3, telemetry=True)
    return cfg, make_run(cfg, GRID, SECOND), make_policy(
        SECOND, rho=0.05, capacity=cfg.capacity)


@settings(max_examples=6, deadline=None)
@given(seed=strategies.integers(min_value=0, max_value=255))
def test_counter_conservation_property(seed):
    """Conservation holds at any seed, not just the golden keys (one
    compile, reused across examples)."""
    cfg, run, pol = _tel_run()
    m, tel = run(jax.random.PRNGKey(seed), pol)
    _assert_conservation(telemetry_summary(tel), m, n_windows=cfg.n_steps,
                         n_refreshes=cfg.n_steps // 3)


@pytest.mark.slow
def test_fleet_golden_bit_for_bit_with_telemetry():
    m, tel = make_fleet_run(FLEET2, GRID, SECOND,
                            router=LeastUtilizedRouter())(
        jax.random.PRNGKey(2),
        fleet_policy(SECOND, capacities=FLEET2.capacities, rho=0.05))
    arrays = _flat("fleet2/second", m)
    gold = np.load(GOLDEN)
    for name in gold.files:
        if name.startswith("fleet2/"):
            np.testing.assert_array_equal(gold[name], arrays[name],
                                          err_msg=name)
    s = telemetry_summary(tel)
    _assert_conservation(s, m.per_cluster,
                         n_windows=CFG.n_steps * FLEET2.n_clusters)
    pc = s["per_cluster"]
    assert sum(pc["n_routed"]) == s["n_routed"]
    assert sum(pc["n_admit"]) == s["n_admit"]


# ---------------------------------------------------------------------------
# online engine: snapshot, offline equivalence, tracing
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_run(tmp_path_factory):
    """Drive the engine over make_run's exact stream/keys with telemetry and
    a tracer attached; return everything the assertions below pick over."""
    pol = make_policy(SECOND, rho=0.05, capacity=SMALL.capacity)
    key = jax.random.PRNGKey(11)
    m_off, tel_off = make_run(SMALL, GRID, SECOND)(key, pol)
    k_stream, k_scan = jax.random.split(key)
    stream = draw_arrival_stream(k_stream, SMALL)
    keys = jax.random.split(k_scan, SMALL.n_steps)

    trace_path = tmp_path_factory.mktemp("obs") / "decisions.jsonl"
    tracer = DecisionTracer(trace_path)
    eng = OnlineAdmissionEngine(SMALL, GRID, SECOND, pol, tracer=tracer)
    n_arr = np.asarray(stream.n_arrivals)
    n_lanes = stream.c0.shape[1]
    for t in range(SMALL.n_steps):
        eng.tick(keys[t])
        futs = [eng.submit(Arrival.from_stream(stream, t, a))
                for a in range(min(int(n_arr[t]), n_lanes))]
        eng.flush()
        for f in futs:
            f.result()
    snap = eng.metrics_snapshot()
    tracer.close()
    return eng, m_off, tel_off, snap, trace_path


def test_engine_telemetry_matches_offline_bit_for_bit(engine_run):
    eng, m_off, tel_off, snap, _ = engine_run
    m_on = eng.metrics()
    for name, val in m_off._asdict().items():
        np.testing.assert_array_equal(np.asarray(val),
                                      np.asarray(getattr(m_on, name)),
                                      err_msg=name)
    off_leaves = jax.tree.leaves(tel_off)
    on_leaves = jax.tree.leaves(eng._cs.tel)
    for a, b in zip(off_leaves, on_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_snapshot_counters(engine_run):
    eng, m_off, tel_off, snap, _ = engine_run
    e = snap["engine"]
    assert e["n_ticks"] == SMALL.n_steps
    assert e["n_requests"] == eng.decisions
    assert e["n_refreshes"] == SMALL.n_steps // SMALL.agg_refresh_steps
    assert e["queue_depth"] == 0
    lat = e["decision_latency_seconds"]
    assert lat.total == eng.decisions
    assert lat.sum > 0 and lat.percentile(0.99) >= lat.percentile(0.5) >= 0
    batch = e["flush_batch_size"]
    assert batch.sum == lat.total  # sum of batch sizes == total decisions
    s = snap["telemetry"]
    _assert_conservation(s, m_off, n_windows=SMALL.n_steps)
    assert s == telemetry_summary(tel_off)


def test_engine_tracer_writes_jsonl(engine_run):
    eng, _, _, _, trace_path = engine_run
    lines = trace_path.read_text().splitlines()
    assert len(lines) == eng.decisions
    recs = [json.loads(ln) for ln in lines]
    for r in recs:
        assert set(r) >= {"step", "req_id", "policy_kind", "verdict",
                          "latency_s", "batch_size", "threshold", "score"}
        assert isinstance(r["verdict"], bool)
        assert r["latency_s"] >= 0.0
    assert [r["req_id"] for r in recs] == list(range(1, len(recs) + 1))
    n_admit = sum(r["verdict"] for r in recs)
    assert n_admit == float(np.sum(eng.metrics().arrivals_accepted))


def test_tracer_diag_materialized_once_per_chunk():
    """Regression (PR 9): ``_trace_part`` materializes the decision diag to
    numpy once per chunk before the record loop. Asserted structurally (the
    tracer receives numpy scalars, never device arrays — each device-array
    index is one device->host sync) and by timing (the chunk-level
    materialization is cheaper than per-record device reads)."""
    recorded = []

    class SpyTracer:
        def record(self, **fields):
            recorded.append(fields)

    width = 64
    cfg = SMALL._replace(max_arrivals=width)
    pol = make_policy(SECOND, rho=0.05, capacity=cfg.capacity)
    eng = OnlineAdmissionEngine(cfg, GRID, SECOND, pol, micro_batch=width,
                                tracer=SpyTracer())
    eng.tick(jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), width)
    for k in keys:
        eng.submit(Arrival.draw(k, cfg))
    eng.flush()
    assert len(recorded) == width
    for rec in recorded:
        for field in ("score", "threshold", "fits"):
            assert not isinstance(rec[field], jax.Array), field

    diag = eng._last_diag
    assert diag is not None
    n_rep = 10
    t0 = time.perf_counter()
    for _ in range(n_rep):
        d = jax.tree.map(np.asarray, diag)    # what _trace_part does
        [float(d.score[j]) for j in range(width)]
    once_per_chunk = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n_rep):
        [float(diag.score[j]) for j in range(width)]   # the old per-record
    per_record = time.perf_counter() - t0              # device reads
    assert once_per_chunk < per_record, (once_per_chunk, per_record)


def test_snapshot_off_has_no_telemetry_key():
    cfg = SMALL._replace(telemetry=False, horizon_hours=2 * 24.0,
                         agg_refresh_steps=1)
    pol = make_policy(ZEROTH, threshold=cfg.capacity, capacity=cfg.capacity)
    eng = OnlineAdmissionEngine(cfg, GRID, ZEROTH, pol)
    eng.tick(jax.random.PRNGKey(0))
    snap = eng.metrics_snapshot()
    assert "telemetry" not in snap
    # and the renderer still produces valid engine-only exposition
    _check_prometheus_text(snapshot_to_prometheus(snap))


# ---------------------------------------------------------------------------
# Prometheus exposition + /metrics HTTP
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?P<labels>\{[^{}]*\})? '
    r'(?P<value>[-+]?(?:[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?|Inf)|NaN)$')
_LABEL_RE = re.compile(r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"$')


def _check_prometheus_text(text):
    """Hand validator of the text exposition format (version 0.0.4): every
    line is # HELP / # TYPE or a well-formed sample; every sample belongs to
    a declared family; histogram buckets are cumulative with le=+Inf equal
    to _count. Returns {family: type}."""
    assert text.endswith("\n")
    families = {}
    hist_buckets = {}  # family -> list of (le, cum)
    for line in text.rstrip("\n").split("\n"):
        assert line == line.strip() and line, f"bad line {line!r}"
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            _, kind, name, rest = line.split(" ", 3)
            if kind == "TYPE":
                assert rest in ("counter", "gauge", "histogram"), line
                families[name] = rest
            continue
        m = _SAMPLE_RE.match(line)
        assert m, f"malformed sample line {line!r}"
        name, labels = m.group("name"), m.group("labels")
        if labels:
            for pair in labels[1:-1].split(","):
                assert _LABEL_RE.match(pair), f"bad label {pair!r} in {line!r}"
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in families:
                base = name[: -len(suffix)]
        assert base in families, f"sample {name!r} has no # TYPE"
        if families[base] == "histogram":
            assert base != name, \
                f"histogram family {base!r} has a bare sample"
        if name.endswith("_bucket"):
            le = dict(p.split("=", 1) for p in labels[1:-1].split(","))["le"]
            hist_buckets.setdefault(base, []).append(
                (float(le.strip('"').replace("+Inf", "inf")),
                 float(m.group("value"))))
        if name.endswith("_count") and base in hist_buckets:
            buckets = hist_buckets[base]
            cums = [c for _, c in buckets]
            assert cums == sorted(cums), f"{base}: non-cumulative buckets"
            assert buckets[-1][0] == float("inf")
            assert buckets[-1][1] == float(m.group("value"))
    assert families
    return families


def test_snapshot_prometheus_exposition_valid(engine_run):
    _, _, _, snap, _ = engine_run
    text = snapshot_to_prometheus(snap)
    fams = _check_prometheus_text(text)
    for want in ("repro_admission_requests_total",
                 "repro_admission_admitted_total",
                 "repro_admission_decision_latency_seconds",
                 "repro_admission_occupancy_window_count"):
        assert want in fams, want
    assert fams["repro_admission_decision_latency_seconds"] == "histogram"
    # counters agree with the snapshot they were rendered from
    n_req = snap["engine"]["n_requests"]
    assert f"repro_admission_requests_total {n_req}\n" in text


def test_render_prometheus_escaping_and_types():
    h = HostHistogram((0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    text = render_prometheus([
        Metric("t_counter", "counter", "a counter",
               [({"q": 'sa"y\nhi\\'}, 3.0)]),
        Metric("t_hist", "histogram", "a histogram", [({}, h)]),
    ])
    _check_prometheus_text(text)
    assert r't_counter{q="sa\"y\nhi\\"} 3' in text
    assert 't_hist_bucket{le="+Inf"} 3' in text
    assert "t_hist_count 3" in text
    with pytest.raises(ValueError):
        render_prometheus([Metric("x", "summary", "bad type", [({}, 1)])])


def test_metrics_server_serves_and_404s():
    srv = MetricsServer(lambda: render_prometheus(
        [Metric("t_up", "gauge", "up", [({}, 1)])]), port=0)
    try:
        url = f"http://127.0.0.1:{srv.port}/metrics"
        with urllib.request.urlopen(url, timeout=10) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith(
                "text/plain; version=0.0.4")
            body = resp.read().decode()
        assert "t_up 1" in body
        with pytest.raises(urllib.error.HTTPError) as err:
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/nope", timeout=10)
        assert err.value.code == 404
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# tracer + logger units
# ---------------------------------------------------------------------------

def test_decision_tracer_buffers_and_drains(tmp_path):
    path = tmp_path / "t.jsonl"
    with DecisionTracer(path, capacity=3) as tr:
        tr.record(step=0, score=jax.numpy.float32(1.5), verdict=True)
        tr.record(step=1, score=np.float64(2.25), verdict=False)
        assert tr.n_recorded == 2 and tr.n_written == 0  # still buffered
        tr.record(step=2, score=0.5, verdict=True)       # hits capacity
        assert tr.n_written == 3
        tr.record(step=3, arr=np.arange(2.0), verdict=True)
    recs = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [r["step"] for r in recs] == [0, 1, 2, 3]
    assert recs[0]["score"] == 1.5 and recs[1]["score"] == 2.25
    assert recs[3]["arr"] == [0.0, 1.0]
    assert all(isinstance(r["verdict"], bool) for r in recs)


def test_logger_rooted_and_level_controls(monkeypatch):
    assert get_logger("foo.bar").name == "repro.foo.bar"
    assert get_logger("repro.sim.importance").name == "repro.sim.importance"
    root = logging.getLogger("repro")
    old_level = root.level
    try:
        from repro.obs.log import set_level
        set_level("WARNING")
        assert not get_logger("x").isEnabledFor(logging.INFO)
        set_level("DEBUG")
        assert get_logger("x").isEnabledFor(logging.DEBUG)
        # env var configures the root on (re)initialization
        monkeypatch.setenv("REPRO_LOG_LEVEL", "INFO")
        monkeypatch.setattr(root, "_repro_obs_configured", False,
                            raising=False)
        assert get_logger("y").isEnabledFor(logging.INFO)
        assert not get_logger("y").isEnabledFor(logging.DEBUG)
        with pytest.raises(ValueError):
            set_level("NOT_A_LEVEL")
    finally:
        root.setLevel(old_level)
        root._repro_obs_configured = True


# ---------------------------------------------------------------------------
# daemon graceful shutdown (subprocess)
# ---------------------------------------------------------------------------

def test_daemon_sigterm_graceful_with_live_metrics():
    env = dict(os.environ, PYTHONPATH="src",
               JAX_COMPILATION_CACHE_DIR=os.path.join(REPO, ".jax_cache"))
    cmd = [sys.executable, "-m", "repro.launch.admission_daemon",
           "--capacity", "500", "--hours", "720", "--dt", "24",
           "--max-slots", "96", "--micro-batch", "4",
           "--arrival-rate", "0.08", "--param", "0.05",
           "--metrics-port", "0", "--throttle", "0.25"]
    proc = subprocess.Popen(cmd, cwd=REPO, env=env, text=True,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT)
    head, port = [], None
    try:
        for line in proc.stdout:  # closes on daemon exit, so no hang
            head.append(line)
            m = re.search(r"metrics: http://127\.0\.0\.1:(\d+)/metrics", line)
            if m:
                port = int(m.group(1))
                break
        assert port, "daemon never announced /metrics:\n" + "".join(head)
        body, deadline = "", time.time() + 120
        while time.time() < deadline:
            try:
                body = urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/metrics", timeout=5
                ).read().decode()
                if "repro_admission_ticks_total" in body:
                    break
            except (urllib.error.URLError, ConnectionError):
                pass
            time.sleep(0.25)
        _check_prometheus_text(body)
        assert "repro_admission_requests_total" in body
        assert "repro_admission_admitted_total" in body  # telemetry enabled
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=180)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    full = "".join(head) + out
    assert proc.returncode == 0, full
    assert "shutting down gracefully" in full
    assert "final snapshot" in full
    snap_line = full.rsplit("final snapshot ", 1)[1].splitlines()[0]
    snap = json.loads(snap_line)
    assert snap["engine"]["n_ticks"] >= 1
    assert "telemetry" in snap


# ---------------------------------------------------------------------------
# bca_ci fast path (satellite)
# ---------------------------------------------------------------------------

def test_bca_ci_vectorized_identical_to_loop():
    rng = np.random.default_rng(5)
    vals = rng.gamma(2.0, 1.0, size=60)
    w = rng.uniform(0.5, 2.0, size=60)

    def loop_stat(v, wt):  # not `is weighted_mean` -> general loop path
        return weighted_mean(v, wt)

    for weights in (None, w):
        fast = bca_ci(vals, weights, n_resamples=2_000, seed=3)
        slow = bca_ci(vals, weights, stat=loop_stat, n_resamples=2_000,
                      seed=3)
        assert fast == slow  # bit-identical CI, not approximately


def test_bca_ci_vectorized_is_faster():
    rng = np.random.default_rng(6)
    vals = rng.gamma(2.0, 1.0, size=200)

    def loop_stat(v, wt):
        return weighted_mean(v, wt)

    t0 = time.perf_counter()
    bca_ci(vals, n_resamples=10_000, seed=0)
    t_fast = time.perf_counter() - t0
    t0 = time.perf_counter()
    bca_ci(vals, stat=loop_stat, n_resamples=10_000, seed=0)
    t_loop = time.perf_counter() - t0
    assert t_fast < t_loop, (t_fast, t_loop)
