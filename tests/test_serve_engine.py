"""Continuous-batching engine behaviour."""
import jax
import numpy as np
import pytest

from repro.models import build_model, get_config, reduced_config
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced_config(get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_requests_complete_and_respect_max_new(engine_setup):
    cfg, model, params = engine_setup
    engine = ServeEngine(model, params, max_batch=3, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(2, cfg.vocab, 5).astype(np.int32),
                    max_new_tokens=7) for i in range(7)]
    for r in reqs:
        engine.submit(r)
    steps = 0
    while (engine.waiting or engine.n_active) and steps < 500:
        engine.step()
        steps += 1
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) <= 7 for r in reqs)
    assert all(len(r.out_tokens) >= 1 for r in reqs)


def test_continuous_batching_overlaps_requests(engine_setup):
    """More requests than slots: engine must reuse freed slots."""
    cfg, model, params = engine_setup
    engine = ServeEngine(model, params, max_batch=2, max_seq=32)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i, prompt=rng.integers(2, cfg.vocab, 4).astype(np.int32),
                    max_new_tokens=4) for i in range(5)]
    for r in reqs:
        engine.submit(r)
    peak_active = 0
    steps = 0
    while (engine.waiting or engine.n_active) and steps < 500:
        engine.step()
        peak_active = max(peak_active, engine.n_active)
        steps += 1
    assert all(r.done for r in reqs)
    assert peak_active <= 2  # never exceeds slot budget


def test_fused_prefill_matches_token_by_token(engine_setup):
    """The fused lax.scan prefill must reproduce the token-by-token loop
    exactly — same outputs for every request, including requests prefilled
    while other slots are mid-decode (the loop advances their cache too)."""
    cfg, model, params = engine_setup
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab, n).astype(np.int32)
               for n in (5, 1, 7, 3, 6)]
    outs = {}
    for mode in ("loop", "fused"):
        engine = ServeEngine(model, params, max_batch=2, max_seq=48,
                             prefill_mode=mode)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            engine.submit(r)
        done = engine.run_until_drained()
        assert sorted(r.rid for r in done) == list(range(len(reqs)))
        outs[mode] = [tuple(r.out_tokens) for r in reqs]
    assert outs["fused"] == outs["loop"]


def test_run_until_drained_returns_completed(engine_setup):
    cfg, model, params = engine_setup
    engine = ServeEngine(model, params, max_batch=2, max_seq=32)
    reqs = [Request(rid=i, prompt=np.asarray([4 + i, 11], np.int32),
                    max_new_tokens=3) for i in range(3)]
    for r in reqs:
        engine.submit(r)
    done = engine.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(r.done for r in done)
    # a second drain has nothing new to report
    assert engine.run_until_drained() == []


def test_greedy_decode_is_deterministic(engine_setup):
    cfg, model, params = engine_setup
    outs = []
    for _ in range(2):
        engine = ServeEngine(model, params, max_batch=1, max_seq=32)
        req = Request(rid=0, prompt=np.asarray([5, 9, 12], np.int32),
                      max_new_tokens=6)
        engine.submit(req)
        steps = 0
        while (engine.waiting or engine.n_active) and steps < 100:
            engine.step()
            steps += 1
        outs.append(tuple(req.out_tokens))
    assert outs[0] == outs[1]
