"""Validation of the closed-form moment curves (paper Props. 2/3/5).

Three layers of evidence:
  1. the discrete prefix-sum implementation == the naive O(N²) transcription
  2. the Gamma-marginal integrals (_g/_h/_k incl. analytic continuation for
     a+p < 0) == scipy quadrature
  3. the conditional (fixed-parameter) process moments == event-level MC of
     the true continuous-time process
  4. point-mass beliefs reduce the marginal formulas to the conditional ones
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.integrate as si

from repro.core import (AZURE_PRIORS, GammaBelief, belief_from_prior,
                        moment_curves, moment_curves_discrete)
from repro.core.moments import (_g, _h, _k, moment_curves_discrete_naive)

PRIORS = AZURE_PRIORS


@pytest.fixture(scope="module", autouse=True)
def _x64():
    """x64 for the quadrature-grade checks, contained to this module so the
    int32 paths of the rest of the suite are unaffected."""
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def _quad_gamma(f, a, b):
    """Integrate f(mu) * Gamma(a,b)-pdf with the x = mu^a substitution to tame
    the mu^(a-1) singularity at 0."""
    from math import gamma as G

    def integrand(x):
        mu = x ** (1.0 / a)
        return f(mu) * b**a / G(a) * np.exp(-b * mu) / a

    hi = (200.0 / b) ** a
    val, _ = si.quad(integrand, 0.0, hi, limit=400)
    return val


class TestGammaIntegrals:
    a, b = 0.3107, 0.5778

    @pytest.mark.parametrize("p,t", [(0.0, 5.0), (0.673, 24.0), (1.346, 100.0)])
    def test_g(self, p, t):
        want = _quad_gamma(lambda mu: mu**p * np.exp(-t * mu), self.a, self.b)
        got = float(_g(jnp.float64(self.a), jnp.float64(self.b), p, jnp.float64(t)))
        assert got == pytest.approx(want, rel=1e-6)

    @pytest.mark.parametrize("p,t", [(-0.327, 24.0), (-0.327, 480.0), (0.2, 6.0)])
    def test_h_analytic_continuation(self, p, t):
        # a + p = -0.0163 < 0 for p = nu - 1: the continuation case
        want = _quad_gamma(lambda mu: mu**p * -np.expm1(-t * mu), self.a, self.b)
        got = float(_h(jnp.float64(self.a), jnp.float64(self.b), p, jnp.float64(t)))
        assert got == pytest.approx(want, rel=1e-6)

    @pytest.mark.parametrize("p,t", [(-0.654, 24.0), (-0.654, 480.0), (0.1, 6.0)])
    def test_k_analytic_continuation(self, p, t):
        want = _quad_gamma(lambda mu: mu**p * np.expm1(-t * mu) ** 2, self.a, self.b)
        got = float(_k(jnp.float64(self.a), jnp.float64(self.b), p, jnp.float64(t)))
        assert got == pytest.approx(want, rel=1e-6)


class TestPrefixSumVsNaive:
    @pytest.mark.parametrize("n_steps,dt", [(8, 1.0), (24, 2.0), (50, 12.0)])
    def test_discrete_matches_naive(self, n_steps, dt):
        bel = belief_from_prior(PRIORS)
        got = moment_curves_discrete(bel, jnp.asarray(5.0), n_steps, dt, PRIORS,
                                     d_stride=1)
        want = moment_curves_discrete_naive(bel, 5.0, n_steps, dt, PRIORS)
        np.testing.assert_allclose(got.EL, want.EL, rtol=1e-5)
        np.testing.assert_allclose(got.VL, want.VL, rtol=1e-5)

    def test_posterior_belief_also_matches(self):
        bel = GammaBelief(
            mu_a=jnp.asarray(2.31), mu_b=jnp.asarray(40.0),
            lam_a=jnp.asarray(3.49), lam_b=jnp.asarray(9.4),
            sig_a=jnp.asarray(4.26), sig_b=jnp.asarray(3.05),
        )
        got = moment_curves_discrete(bel, jnp.asarray(17.0), 20, 4.0, PRIORS,
                                     d_stride=1)
        want = moment_curves_discrete_naive(bel, 17.0, 20, 4.0, PRIORS)
        np.testing.assert_allclose(got.EL, want.EL, rtol=1e-5)
        np.testing.assert_allclose(got.VL, want.VL, rtol=1e-5)


def _point_mass_belief(lam, mu, sig, k=1e7):
    """Gamma posteriors concentrated at the true parameters."""
    arr = lambda v: jnp.asarray(v, jnp.float64)
    return GammaBelief(mu_a=arr(mu * k), mu_b=arr(k),
                       lam_a=arr(lam * k), lam_b=arr(k),
                       sig_a=arr(sig * k), sig_b=arr(k))


@pytest.mark.slow
class TestConditionalProcessVsMC:
    """Event-level MC of the continuous-time process at fixed parameters.

    Marked ``slow`` (hundreds of thousands of MC draws per check): these are
    the oracle-grade validations, run in CI on push and locally via
    ``pytest -m slow``."""

    lam, mu, sig = 0.5, 0.2, 2.0

    def _mc(self, t, c0, lam=None, mu=None, sig=None, n_mc=400_000, seed=0):
        lam = self.lam if lam is None else lam
        mu = self.mu if mu is None else mu
        sig = self.sig if sig is None else sig
        nu, delta = PRIORS.nu, PRIORS.delta
        rate = lam * mu**nu * t
        key = jax.random.PRNGKey(seed)
        k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
        n_ev = jax.random.poisson(k1, rate, (n_mc,))
        max_ev = int(np.percentile(np.asarray(n_ev), 100)) + 1
        times = jax.random.uniform(k2, (n_mc, max_ev)) * t
        sizes = 1 + jax.random.poisson(k3, sig, (n_mc, max_ev))
        p = jnp.exp(-(t - times) * mu)
        surv = jax.random.binomial(k4, sizes.astype(jnp.float64), p)
        mask = jnp.arange(max_ev)[None, :] < n_ev[:, None]
        q = jnp.sum(jnp.where(mask, surv, 0.0), axis=1)
        b = jax.random.binomial(k5, float(c0), np.exp(-mu * t), (n_mc,))
        m = jax.random.bernoulli(k6, np.exp(-delta * mu * t), (n_mc,))
        return np.asarray(q), np.asarray(b), np.asarray(m)

    def test_q_b_m_moments(self):
        t, c0 = 24.0, 5
        q, b, m = self._mc(t, c0)
        nu = PRIORS.nu
        eq_want = self.lam * self.mu**nu * (self.sig + 1) * -np.expm1(-t * self.mu) / self.mu
        vq_want = self.lam * self.mu**nu * (
            (self.sig + 1) * -np.expm1(-t * self.mu) / self.mu
            + self.sig * (self.sig + 2) * -np.expm1(-2 * t * self.mu) / (2 * self.mu)
        )
        se = q.std() / np.sqrt(len(q))
        assert q.mean() == pytest.approx(eq_want, abs=4 * se)
        assert q.var() == pytest.approx(vq_want, rel=0.02)
        p1 = np.exp(-self.mu * t)
        assert b.mean() == pytest.approx(c0 * p1, rel=0.01)
        assert b.var() == pytest.approx(c0 * p1 * (1 - p1), rel=0.03)
        assert m.mean() == pytest.approx(np.exp(-PRIORS.delta * self.mu * t), rel=0.01)

    def test_point_mass_belief_recovers_conditional(self):
        """moment_curves at a point-mass belief == conditional closed forms.

        Uses parameters with a large standing crop (lam(sig+1)mu^nu/mu ~ 50
        cores) so the true zero-core death probability ~ 0 and the D-term is
        ~ 1 — isolating the Q/B/M math from the D approximation.
        """
        lam, mu, sig = 5.0, 0.1, 4.0
        t = jnp.asarray([6.0, 24.0, 96.0])
        bel = _point_mass_belief(lam, mu, sig)
        mc = moment_curves(bel, jnp.asarray(20.0), t, PRIORS, d_points=64)
        nu, delta = PRIORS.nu, PRIORS.delta
        tt = np.asarray(t)
        eq = lam * mu**nu * (sig + 1) * -np.expm1(-tt * mu) / mu
        eb = 20.0 * np.exp(-mu * tt)
        em = np.exp(-delta * mu * tt)
        el_want = em * (eq + eb)
        np.testing.assert_allclose(np.asarray(mc.EL), el_want, rtol=0.05)

    def test_full_l_against_mc(self):
        """E[L]/V[L] of the full composed formula vs event-level MC at fixed
        high-crop parameters (D ~ 1, isolating composition + Q/B/M)."""
        lam, mu, sig = 5.0, 0.1, 4.0
        t, c0 = 48.0, 20
        q, b, m = self._mc(t, c0, lam=lam, mu=mu, sig=sig, n_mc=150_000)
        l = m * (q + b)
        bel = _point_mass_belief(lam, mu, sig)
        mc = moment_curves(bel, jnp.asarray(float(c0)), jnp.asarray([t]), PRIORS,
                           d_points=64)
        assert float(mc.EL[0]) == pytest.approx(l.mean(), rel=0.10)
        assert float(mc.VL[0]) == pytest.approx(l.var(), rel=0.25)

    def test_d_term_behaviour(self):
        """D-term sanity: in [0,1], decreasing, smaller for smaller/slower
        deployments, ~1 for high-standing-crop deployments."""
        from repro.core.moments import _d_curve_uniform

        big = _d_curve_uniform(jnp.float64(1e7 * 0.1), jnp.float64(1e7),
                               jnp.float64(25.0), jnp.float64(0.1**PRIORS.nu),
                               jnp.float64(20.0), jnp.float64(4.0), 32,
                               midpoint=True)
        small = _d_curve_uniform(jnp.float64(1e7 * 0.5), jnp.float64(1e7),
                                 jnp.float64(0.2), jnp.float64(0.5**PRIORS.nu),
                                 jnp.float64(1.0), jnp.float64(4.0), 32,
                                 midpoint=True)
        for d in (big, small):
            assert bool(jnp.all((d >= 0.0) & (d <= 1.0)))
            assert bool(jnp.all(jnp.diff(d) <= 1e-12))
        assert float(big[-1]) > 0.99
        assert float(small[-1]) < 0.5


class TestCurveShapeInvariants:
    def test_batched_shapes_and_finiteness(self):
        bel = belief_from_prior(PRIORS, (7,))
        cores = jnp.arange(1.0, 8.0)
        grid = jnp.asarray([1.0, 10.0, 100.0, 1000.0])
        mc = moment_curves(bel, cores, grid, PRIORS, d_stride=2)
        assert mc.EL.shape == (7, 4) and mc.VL.shape == (7, 4)
        assert bool(jnp.all(jnp.isfinite(mc.EL))) and bool(jnp.all(jnp.isfinite(mc.VL)))
        assert bool(jnp.all(mc.EL >= 0.0)) and bool(jnp.all(mc.VL >= 0.0))

    def test_el_eventually_decays(self):
        """Deployments die (M-process) so E[L_t] -> 0 for large t."""
        bel = belief_from_prior(PRIORS)
        grid = jnp.asarray([1.0, 24.0, 24.0 * 365 * 30])
        mc = moment_curves(bel, jnp.asarray(100.0), grid, PRIORS, d_stride=1)
        assert float(mc.EL[-1]) < 0.05 * float(mc.EL[0])

    def test_d_stride_is_mild_approximation(self):
        bel = belief_from_prior(PRIORS, (3,))
        cores = jnp.asarray([1.0, 10.0, 100.0])
        grid = jnp.exp(jnp.linspace(np.log(1.0), np.log(26_000.0), 32))
        exact = moment_curves(bel, cores, grid, PRIORS, d_stride=1)
        approx = moment_curves(bel, cores, grid, PRIORS, d_stride=4)
        np.testing.assert_allclose(approx.EL, exact.EL, rtol=0.15, atol=1e-4)
