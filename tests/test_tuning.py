"""Calibration subsystem: property tests, the serial-bisection oracle, the
K-curve machinery, and the BENCH row round-trip.

All simulation-backed tests share the session-scoped ``sim_cache`` fixture
(conftest.py): one small config, one compiled simulator per policy kind,
memoized theta-grid evaluations — the tier-1 compile count stays flat no
matter how many calibration properties accumulate here.
"""
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

import jax

from repro.core import FIRST, SECOND, ZEROTH, tune_threshold
from repro.tuning import (KPoint, calibrate, format_kcurve_derived,
                          from_param, kcurve_divisors, kcurve_row_name,
                          parse_kcurve_rows, pick_agg_refresh,
                          pick_from_curve, sla_ci, theta_space, to_param)

KINDS = (ZEROTH, FIRST, SECOND)

#: shared probe ladders (parameter space), memoized per kind in sim_cache
LADDERS = {
    ZEROTH: tuple(np.linspace(100.0, 500.0, 9)),
    FIRST: tuple(np.linspace(100.0, 525.0, 9)),
    SECOND: tuple(10.0 ** np.linspace(-3.7, -0.05, 9)),
}

#: empirical curves wiggle by a run-level fluke at most this large (the
#: aggregate rate moves by whole failed requests over ~6 runs' totals)
MONOTONE_TOL = 1.5e-3


class TestCalibrationProperties:
    @pytest.mark.parametrize("kind", KINDS, ids=["zeroth", "first", "second"])
    @settings(max_examples=12, deadline=None)
    @given(i=st.integers(min_value=0, max_value=8),
           j=st.integers(min_value=0, max_value=8))
    def test_sla_failure_monotone_in_theta(self, sim_cache, kind, i, j):
        """Larger theta admits more -> the aggregate SLA failure rate is
        nondecreasing in theta (up to trajectory-divergence flukes)."""
        lo, hi = min(i, j), max(i, j)
        agg, _ = sim_cache.curve(kind, LADDERS[kind])
        assert agg[lo] <= agg[hi] + MONOTONE_TOL, (
            f"kind={kind}: fail({LADDERS[kind][lo]:.4g})={agg[lo]:.4f} > "
            f"fail({LADDERS[kind][hi]:.4g})={agg[hi]:.4f}")

    @pytest.mark.parametrize("kind", KINDS, ids=["zeroth", "first", "second"])
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_calibrate_invariant_to_grid_permutation(self, sim_cache, kind,
                                                     seed):
        """Selection is by candidate value, not grid position: any
        permutation of the theta grid produces the identical result."""
        thetas = list(LADDERS[kind])
        perm = list(np.random.default_rng(seed).permutation(thetas))
        r1 = calibrate(sim_cache.run(kind), kind, sim_cache.keys,
                       capacity=sim_cache.cfg.capacity, tau=sim_cache.tau,
                       thetas=thetas)
        r2 = calibrate(sim_cache.run(kind), kind, sim_cache.keys,
                       capacity=sim_cache.cfg.capacity, tau=sim_cache.tau,
                       thetas=perm)
        assert r1.theta == r2.theta
        assert r1.feasible == r2.feasible
        assert r1.sla_fail == pytest.approx(r2.sla_fail, abs=1e-12)
        assert r1.utilization == pytest.approx(r2.utilization, rel=1e-6)

    def test_policy_fn_reproduces_default_construction(self, sim_cache):
        """The policy_fn hook (the fleet calibration path) with a closure
        equivalent to the default scalar make_policy yields identical
        metrics — same keys, same thetas, same simulator."""
        from repro.core import make_policy
        from repro.tuning import eval_theta_grid

        cap = sim_cache.cfg.capacity
        thetas = list(LADDERS[ZEROTH])[:4]
        m_default = eval_theta_grid(sim_cache.run(ZEROTH), ZEROTH, thetas,
                                    sim_cache.keys, capacity=cap)
        pf = lambda th: make_policy(ZEROTH, threshold=th, rho=th, capacity=cap)
        m_hook = eval_theta_grid(sim_cache.run(ZEROTH), ZEROTH, thetas,
                                 sim_cache.keys, capacity=cap, policy_fn=pf)
        np.testing.assert_array_equal(np.asarray(m_default.failed_requests),
                                      np.asarray(m_hook.failed_requests))
        np.testing.assert_array_equal(np.asarray(m_default.utilization),
                                      np.asarray(m_hook.utilization))

    @pytest.mark.parametrize("kind", KINDS, ids=["zeroth", "first", "second"])
    def test_calibrate_invariant_to_key_order(self, sim_cache, kind):
        """Runs are exchangeable: permuting the key batch permutes per-run
        metrics but cannot change the selected theta."""
        r1 = calibrate(sim_cache.run(kind), kind, sim_cache.keys,
                       capacity=sim_cache.cfg.capacity, tau=sim_cache.tau,
                       thetas=list(LADDERS[kind]))
        r2 = calibrate(sim_cache.run(kind), kind, sim_cache.keys[::-1],
                       capacity=sim_cache.cfg.capacity, tau=sim_cache.tau,
                       thetas=list(LADDERS[kind]))
        assert r1.theta == r2.theta
        assert r1.sla_fail == pytest.approx(r2.sla_fail, abs=1e-12)

    @pytest.mark.parametrize("kind", KINDS, ids=["zeroth", "first", "second"])
    def test_calibrated_theta_meets_measured_sla(self, sim_cache, kind):
        """The returned theta always satisfies the measured SLA constraint
        (when any candidate does)."""
        res = calibrate(sim_cache.run(kind), kind, sim_cache.keys,
                        capacity=sim_cache.cfg.capacity, tau=sim_cache.tau,
                        n_grid=6, max_stages=2)
        assert res.feasible
        assert res.sla_fail <= sim_cache.tau
        assert res.sla_lo <= res.sla_fail <= res.sla_hi
        # and the evidence trail agrees: every probed stage marked the
        # winner's failure rate feasible at its theta
        final = res.stages[-1]
        at = np.argmin(np.abs(final.thetas - res.theta))
        assert final.agg_fail[at] <= sim_cache.tau

    def test_infeasible_everywhere_flags_and_returns_min(self, sim_cache):
        """tau below every measured rate: feasible=False, smallest (most
        conservative) candidate returned."""
        thetas = list(LADDERS[ZEROTH][5:])  # all in the failing regime
        res = calibrate(sim_cache.run(ZEROTH), ZEROTH, sim_cache.keys,
                        capacity=sim_cache.cfg.capacity, tau=1e-9,
                        thetas=thetas)
        assert not res.feasible
        assert res.theta == min(thetas)


class TestSerialOracle:
    @pytest.mark.parametrize("kind", KINDS, ids=["zeroth", "first", "second"])
    def test_batched_calibrate_matches_serial_bisection(self, sim_cache,
                                                        kind):
        """``tuning.calibrate`` agrees with the serial
        ``core.policies.tune_threshold`` bisection reference within one grid
        step, for the threshold policy and both moment policies — same keys,
        same simulator, same empirical SLA curve."""
        cfg, keys, tau = sim_cache.cfg, sim_cache.keys, sim_cache.tau
        x_lo, x_hi, space = theta_space(kind, cfg.capacity)

        def run_sla(x):
            agg, _ = sim_cache.curve(kind, [to_param(x, space)])
            return float(agg[0])

        x_serial = tune_threshold(run_sla, x_lo, x_hi, target_sla=tau,
                                  iters=9)
        res = calibrate(sim_cache.run(kind), kind, keys,
                        capacity=cfg.capacity, tau=tau, n_grid=9,
                        max_stages=2)
        assert res.space == space
        x_batched = from_param(res.theta, space)
        assert abs(x_batched - x_serial) <= res.grid_step + 1e-9, (
            f"kind={kind}: batched {x_batched:.4g} vs serial "
            f"{x_serial:.4g}, final grid step {res.grid_step:.4g}")


class TestSlaCi:
    def test_zero_failures_degenerate_interval(self):
        rate, lo, hi = sla_ci(np.zeros(8), np.full(8, 100.0))
        assert rate == lo == hi == 0.0

    def test_covers_rate_and_orders(self):
        f = np.array([0.0, 2.0, 0.0, 7.0])
        r = np.array([100.0, 120.0, 90.0, 110.0])
        rate, lo, hi = sla_ci(f, r)
        assert lo <= rate <= hi
        assert rate == pytest.approx(9.0 / 420.0)

    def test_concentrated_failures_widen_interval(self):
        """Same totals, tail-concentrated failures -> wider cluster-robust
        interval than evenly spread ones."""
        r = np.full(8, 100.0)
        even = np.full(8, 1.0)
        lumpy = np.zeros(8)
        lumpy[0] = 8.0
        _, lo_e, hi_e = sla_ci(even, r)
        _, lo_l, hi_l = sla_ci(lumpy, r)
        assert hi_l - lo_l > hi_e - lo_e


class TestKCurve:
    def test_divisors(self):
        assert kcurve_divisors(1096, 16) == [1, 2, 4, 8]
        assert kcurve_divisors(912, 16) == [1, 2, 3, 4, 6, 8, 12, 16]
        assert kcurve_divisors(7, 4) == [1]

    def _points(self):
        mk = lambda k, ur, sr, feas=True: KPoint(
            k=k, theta_fixed=0.1, util_fixed=ur - 0.01, slack_fixed=sr,
            theta_retuned=0.1, util_retuned=ur, slack_retuned=sr,
            retuned_feasible=feas)
        return [mk(1, 0.650, 2e-4), mk(2, 0.649, 2e-4), mk(4, 0.647, 1e-4),
                mk(8, 0.610, -1e-4)]

    def test_pick_prefers_largest_free_k(self):
        # K=2 within tol of best; K=4 gives up 3e-3 > tol=1e-3; K=8 violates
        assert pick_from_curve(self._points(), util_tol=1e-3) == 2
        # looser tolerance buys the larger refresh interval
        assert pick_from_curve(self._points(), util_tol=5e-3) == 4

    def test_pick_falls_back_to_min_k_when_nothing_feasible(self):
        pts = [p for p in self._points() if p.k >= 8]
        assert pick_from_curve(pts) == 8  # only K, infeasible -> smallest

    def test_row_round_trip(self):
        rows = [{"name": kcurve_row_name("quick", p.k),
                 "derived": format_kcurve_derived(p)}
                for p in self._points()]
        back = parse_kcurve_rows(rows, "quick")
        assert [p.k for p in back] == [1, 2, 4, 8]
        for a, b in zip(self._points(), back):
            assert b.util_retuned == pytest.approx(a.util_retuned, abs=1e-4)
            assert b.slack_retuned == pytest.approx(a.slack_retuned,
                                                    rel=1e-2)
            assert b.retuned_feasible == a.retuned_feasible
        assert parse_kcurve_rows(rows, "tiny") == []

    def test_pick_agg_refresh_from_bench_artifact(self, tmp_path):
        import json

        rows = [{"name": kcurve_row_name("quick", p.k), "us_per_call": 1.0,
                 "derived": format_kcurve_derived(p)}
                for p in self._points()]
        path = tmp_path / "BENCH_quick.json"
        path.write_text(json.dumps({"scale": "quick", "rows": rows}))
        assert pick_agg_refresh("quick", fallback=99, bench_path=str(path),
                                util_tol=1e-3) == 2
        # n_steps must be divisible by the choice or the fallback wins
        assert pick_agg_refresh("quick", fallback=99, bench_path=str(path),
                                util_tol=1e-3, n_steps=9) == 1
        # unrecorded scale -> hand-picked fallback
        assert pick_agg_refresh("tiny", fallback=4,
                                bench_path=str(path)) == 4

    def test_pick_agg_refresh_missing_file_falls_back(self, tmp_path):
        assert pick_agg_refresh("quick", fallback=8,
                                bench_path=str(tmp_path / "nope.json")) == 8


class TestReplayCalibration:
    @pytest.fixture(scope="class")
    def scenario_setup(self, sim_cache):
        from repro.sim import make_run
        from repro.traces import TraceSpec
        from repro.tuning import replay_stream_batch

        cfg = sim_cache.cfg._replace(max_arrivals=8)
        spec = TraceSpec(horizon_hours=cfg.horizon_hours,
                         arrival_rate=cfg.arrival_rate, max_deployments=128,
                         max_events=8)
        streams, run_keys, dropped = replay_stream_batch(
            jax.random.PRNGKey(11), jax.random.PRNGKey(13), "flash_crowd",
            spec, cfg, 4)
        return {"cfg": cfg, "streams": streams, "run_keys": run_keys,
                "dropped": dropped,
                "run": make_run(cfg, sim_cache.grid, ZEROTH)}

    def test_stream_batch_shapes(self, scenario_setup, sim_cache):
        s = scenario_setup
        assert s["streams"].c0.shape == (4, s["cfg"].n_steps, 8)
        assert s["run_keys"].shape[0] == 4
        assert s["dropped"] >= 0

    def test_calibrate_scenario_reports_both_operating_points(
            self, scenario_setup, sim_cache):
        from repro.tuning import calibrate_scenario

        s = scenario_setup
        cal = calibrate_scenario(
            s["run"], ZEROTH, "flash_crowd", s["streams"], s["run_keys"],
            capacity=s["cfg"].capacity, tau=sim_cache.tau,
            stationary_theta=300.0, n_grid=5, max_stages=1)
        assert cal.stationary_theta == 300.0
        assert 0.0 <= cal.stationary_util <= 1.0
        assert cal.retuned.sla_fail <= sim_cache.tau or not cal.retuned.feasible
        assert cal.util_gap == pytest.approx(
            cal.retuned.utilization - cal.stationary_util)


class TestBenchArtifactMerge:
    @pytest.fixture()
    def merge_records(self):
        import os
        import sys

        root = os.path.join(os.path.dirname(__file__), "..")
        sys.path.insert(0, root)
        try:
            from benchmarks.run import merge_records as fn
        finally:
            sys.path.remove(root)
        return fn

    def test_merge_replaces_by_name_and_tracks_provenance(self, merge_records,
                                                          tmp_path):
        import json

        path = tmp_path / "BENCH_quick.json"
        path.write_text(json.dumps({
            "scale": "quick", "seed": 0, "total_seconds": 10.0,
            "rows": [{"name": "a", "us_per_call": 1.0, "derived": "old",
                      "seed": 0},
                     {"name": "b", "us_per_call": 2.0, "derived": "old",
                      "seed": 0}]}))
        fresh = [{"name": "b", "us_per_call": 3.0, "derived": "new",
                  "seed": 1},
                 {"name": "c", "us_per_call": 4.0, "derived": "new",
                  "seed": 1}]
        seed, total, rows = merge_records(str(path), "quick", 1, 5.0, fresh)
        assert seed == "mixed"          # rows measured under two seeds
        assert total == 15.0            # compute accumulates across merges
        assert [r["name"] for r in rows] == ["a", "b", "c"]
        by = {r["name"]: r for r in rows}
        assert by["b"]["derived"] == "new" and by["b"]["seed"] == 1
        assert by["a"]["seed"] == 0     # carried rows keep their provenance

    def test_full_replacement_uses_fresh_provenance(self, merge_records,
                                                    tmp_path):
        """Every old row replaced: the artifact's seed/total are this run's
        alone — no mixed-seed claim, no double-counted compute."""
        import json

        path = tmp_path / "BENCH_quick.json"
        path.write_text(json.dumps({
            "scale": "quick", "seed": 0, "total_seconds": 10.0,
            "rows": [{"name": "a", "us_per_call": 1.0, "derived": "old",
                      "seed": 0}]}))
        fresh = [{"name": "a", "us_per_call": 2.0, "derived": "new",
                  "seed": 1}]
        seed, total, rows = merge_records(str(path), "quick", 1, 5.0, fresh)
        assert seed == 1 and total == 5.0
        assert rows == fresh

    def test_merge_same_seed_keeps_seed(self, merge_records, tmp_path):
        import json

        path = tmp_path / "BENCH_quick.json"
        path.write_text(json.dumps({
            "scale": "quick", "seed": 0, "total_seconds": 1.0,
            "rows": [{"name": "kept", "us_per_call": 1.0, "derived": "d",
                      "seed": 0}]}))
        seed, total, rows = merge_records(str(path), "quick", 0, 2.0,
                                          [{"name": "x", "us_per_call": 1.0,
                                            "derived": "d", "seed": 0}])
        assert seed == 0 and total == 3.0 and len(rows) == 2

    def test_different_scale_replaces_wholesale(self, merge_records,
                                                tmp_path):
        import json

        path = tmp_path / "BENCH_quick.json"
        path.write_text(json.dumps({"scale": "tiny", "seed": 0,
                                    "total_seconds": 9.0,
                                    "rows": [{"name": "a"}]}))
        fresh = [{"name": "z", "us_per_call": 1.0, "derived": "d", "seed": 2}]
        seed, total, rows = merge_records(str(path), "quick", 2, 4.0, fresh)
        assert seed == 2 and total == 4.0 and rows == fresh


@pytest.mark.slow
def test_calibrate_sharding_invariant_on_virtual_devices():
    """The device-sharded theta-grid pass picks the same theta as the
    single-device path (8 virtual CPU devices; selection is by value and
    every candidate sees the identical key batch)."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", """
import jax, numpy as np
from repro.core import ZEROTH, geometric_grid
from repro.sim import make_config, make_run
from repro.tuning import calibrate

cfg = make_config(capacity=500.0, arrival_rate=0.08, horizon_hours=30*24.0,
                  dt=24.0, max_slots=96, max_arrivals=4, d_points=8)
grid = geometric_grid(24.0, 3*30*24.0, 12)
run = make_run(cfg, grid, ZEROTH)
keys = jax.random.split(jax.random.PRNGKey(7), 8)
thetas = list(np.linspace(100.0, 500.0, 8))
r_multi = calibrate(run, ZEROTH, keys, capacity=cfg.capacity, tau=5e-3,
                    thetas=thetas, devices=jax.devices())
r_single = calibrate(run, ZEROTH, keys, capacity=cfg.capacity, tau=5e-3,
                     thetas=thetas, devices=jax.devices()[:1])
assert len(jax.devices()) == 8
assert r_multi.theta == r_single.theta, (r_multi.theta, r_single.theta)
np.testing.assert_allclose(r_multi.sla_fail, r_single.sla_fail, atol=1e-12)
np.testing.assert_allclose(r_multi.utilization, r_single.utilization,
                           rtol=1e-6)
# ragged flat batch (3 thetas x 7 keys = 21 on 8 devices): padded and
# sliced, never silently un-sharded — must still match single-device
r_rag_m = calibrate(run, ZEROTH, keys[:7], capacity=cfg.capacity, tau=5e-3,
                    thetas=thetas[:3], devices=jax.devices())
r_rag_s = calibrate(run, ZEROTH, keys[:7], capacity=cfg.capacity, tau=5e-3,
                    thetas=thetas[:3], devices=jax.devices()[:1])
assert r_rag_m.theta == r_rag_s.theta, (r_rag_m.theta, r_rag_s.theta)
np.testing.assert_allclose(r_rag_m.utilization, r_rag_s.utilization,
                           rtol=1e-6)
print('OK', r_multi.theta)
"""], env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
