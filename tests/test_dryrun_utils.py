"""Unit tests for the dry-run analysis utilities (no 512-device init)."""
import pytest

from repro.launch.dryrun import (_group_size, _shape_bytes, VARIANTS,
                                 parse_collectives)
from repro.launch import mesh as mesh_mod


class TestShapeBytes:
    @pytest.mark.parametrize("s,want", [
        ("f32[8,128]{1,0}", 8 * 128 * 4),
        ("bf16[2,4,8]", 64 * 2),
        ("pred[16]", 16),
        ("(f32[4], bf16[8])", 16 + 16),
        ("f32[]", 0),  # scalars: dims empty => treated as 1*4? no: n=1*4
    ])
    def test_cases(self, s, want):
        got = _shape_bytes(s)
        if s == "f32[]":
            assert got == 4
        else:
            assert got == want


class TestGroupSize:
    def test_explicit_groups(self):
        assert _group_size("all-reduce(...), replica_groups={{0,1,2,3}}", 8) == 4

    def test_iota_groups(self):
        assert _group_size("replica_groups=[32,16]<=[512]", 512) == 16

    def test_fallback(self):
        assert _group_size("no groups here", 256) == 256


class TestParseCollectives:
    HLO = """
  %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dims={0}
  %ar = bf16[1024]{0} all-reduce(%y), replica_groups={{0,1}}, to_apply=%add
  %rs = f32[4,4]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3}}
  %cp = f32[8]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %mm = f32[64,64]{1,0} dot(%a, %b)
"""

    def test_counts_and_bytes(self):
        out = parse_collectives(self.HLO, 8)
        assert out["all-gather"]["count"] == 1
        assert out["all-reduce"]["count"] == 1
        assert out["reduce-scatter"]["count"] == 1
        assert out["collective-permute"]["count"] == 1
        assert out["all-to-all"]["count"] == 0
        # all-gather: 16*128*4 * (4-1)/4
        assert out["all-gather"]["wire_bytes"] == pytest.approx(
            16 * 128 * 4 * 3 / 4)
        # all-reduce: 2 * 1024*2 * (2-1)/2
        assert out["all-reduce"]["wire_bytes"] == pytest.approx(1024 * 2)
        # reduce-scatter: result bytes * (g-1)
        assert out["reduce-scatter"]["wire_bytes"] == pytest.approx(
            4 * 4 * 4 * 3)
        assert out["total_wire_bytes"] > 0

    def test_ignores_non_collectives(self):
        out = parse_collectives("%mm = f32[64,64] dot(%a, %b)", 8)
        assert out["total_wire_bytes"] == 0


class TestVariants:
    def test_baseline_is_empty(self):
        assert VARIANTS["baseline"] == {}

    def test_opt_variants_reference_real_config_fields(self):
        import dataclasses
        from repro.models.lm import ModelConfig
        field_names = {f.name for f in dataclasses.fields(ModelConfig)}
        for name, over in VARIANTS.items():
            for key in over:
                if not key.startswith("_"):
                    assert key in field_names, (name, key)


class TestMeshFactory:
    def test_make_production_mesh_is_function_not_constant(self):
        import inspect
        assert inspect.isfunction(mesh_mod.make_production_mesh)
        src = inspect.getsource(mesh_mod)
        # importing mesh.py must not touch device state at module level
        assert "jax.devices()" not in src.split("def ")[0]
