"""Shared test configuration.

Enables jax's persistent compilation cache (repo-local, gitignored): the
suite is compile-dominated on CPU, so warm reruns — the common local dev
loop — skip most XLA work. Cold CI runs are unaffected.
"""
import os

import jax

jax.config.update("jax_compilation_cache_dir",
                  os.path.join(os.path.dirname(__file__), "..", ".jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
