"""Shared test configuration.

Enables jax's persistent compilation cache (repo-local, gitignored): the
suite is compile-dominated on CPU, so warm reruns — the common local dev
loop — skip most XLA work. Cold CI runs are unaffected.

Also hosts two tier-1 runtime guards:

  * ``sim_cache`` — a session-scoped compiled-simulator cache. The tuning
    tests (property, oracle, invariance) all drive the same small config;
    building ``make_run`` once per policy kind for the whole session keeps
    the suite's XLA compile count flat as calibration tests accumulate.
  * a session-scoped time budget (``tests/time_budget.json``): in CI, the
    default (non-slow) suite must finish inside the recorded budget, so
    compile-heavy tests cannot creep the tier-1 wall time unnoticed.
"""
import json
import os
import time

import jax
import pytest

jax.config.update("jax_compilation_cache_dir",
                  os.path.join(os.path.dirname(__file__), "..", ".jax_cache"))
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)

_BUDGET_FILE = os.path.join(os.path.dirname(__file__), "time_budget.json")


class SimCache:
    """Compiled-simulator cache: one small config + grid + key batch, with
    ``run(kind)`` building (and memoizing) the jitted simulator per policy
    kind and ``curve(kind, thetas)`` memoizing whole evaluated theta grids
    so property tests can share measurements."""

    def __init__(self):
        from repro.core import geometric_grid
        from repro.sim import make_config

        # small on purpose (mirrors test_sim.CFG): invariant checks, not
        # statistics; 30 steps / 96 slots / 12 grid points keep each
        # make_run compile a few seconds on CPU
        self.cfg = make_config(capacity=500.0, arrival_rate=0.08,
                               horizon_hours=30 * 24.0, dt=24.0,
                               max_slots=96, max_arrivals=4, d_points=8)
        self.grid = geometric_grid(24.0, 3 * 30 * 24.0, 12)
        self.keys = jax.random.split(jax.random.PRNGKey(7), 6)
        self.tau = 5e-3
        self._runs = {}
        self._curves = {}

    def run(self, kind: int):
        if kind not in self._runs:
            from repro.sim import make_run

            self._runs[kind] = make_run(self.cfg, self.grid, kind)
        return self._runs[kind]

    def curve(self, kind: int, thetas):
        """(agg_fail [T], util [T, R]) at ``thetas``, memoized."""
        import numpy as np

        key = (kind, tuple(float(t) for t in thetas))
        if key not in self._curves:
            from repro.tuning import eval_theta_grid

            m = eval_theta_grid(self.run(kind), kind, list(thetas), self.keys,
                                capacity=self.cfg.capacity)
            fails = np.asarray(m.failed_requests)
            reqs = np.asarray(m.total_requests)
            agg = fails.sum(1) / np.maximum(reqs.sum(1), 1.0)
            self._curves[key] = (agg, np.asarray(m.utilization))
        return self._curves[key]


@pytest.fixture(scope="session")
def sim_cache():
    return SimCache()


@pytest.fixture(scope="session", autouse=True)
def _tier1_time_budget(request):
    """CI-only guard: the default non-slow suite must finish within the
    budget recorded in tests/time_budget.json (generous — it catches
    order-of-magnitude creep, not noise). Local runs and explicit slow/-k
    selections are exempt."""
    t0 = time.time()
    yield
    if not os.environ.get("CI"):
        return
    opts = request.config.option
    if opts.markexpr != "not slow" or opts.keyword:
        return
    with open(_BUDGET_FILE, encoding="utf-8") as f:
        budget = json.load(f)["non_slow_seconds"]
    elapsed = time.time() - t0
    if elapsed > budget:
        raise RuntimeError(
            f"tier-1 (non-slow) suite took {elapsed:.0f}s, over the "
            f"{budget}s budget in {os.path.relpath(_BUDGET_FILE)}; either a "
            "test got much slower or the budget needs a deliberate bump")
