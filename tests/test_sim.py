"""Simulator invariants + importance sampling + pricing + metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.core import (AZURE_PRIORS, FIRST, SECOND, ZEROTH, geometric_grid,
                        make_policy)
from repro.core.moments import MomentCurves
from repro.core.pricing import (mixture_moments, mixture_variance_excess,
                                payment)
from repro.sim import (MIX_UNLABELED, PSEUDO, SimConfig, badness_measure,
                       bca_ci, make_importance_plan, make_run, rejection_q,
                       sla_failure_rate)

# small on purpose: these are invariant checks, not statistics; d_points=8
# and the 12-point grid keep each make_run compile a few seconds on CPU
CFG = SimConfig(capacity=500.0, arrival_rate=0.08, horizon_hours=30 * 24.0,
                dt=24.0, max_slots=96, max_arrivals=4, d_points=8,
                priors=AZURE_PRIORS)
GRID = geometric_grid(24.0, 3 * 30 * 24.0, 12)


@pytest.fixture(scope="module")
def zeroth_run():
    return make_run(CFG, GRID, ZEROTH)


class TestSimulatorInvariants:
    def test_capacity_never_exceeded(self, zeroth_run):
        pol = make_policy(ZEROTH, threshold=1e9, capacity=CFG.capacity)
        m = zeroth_run(jax.random.PRNGKey(0), pol)
        assert float(jnp.max(m.util_trace)) <= CFG.capacity + 1e-6

    def test_deterministic_given_seed(self, zeroth_run):
        pol = make_policy(ZEROTH, threshold=300.0, capacity=CFG.capacity)
        m1 = zeroth_run(jax.random.PRNGKey(3), pol)
        m2 = zeroth_run(jax.random.PRNGKey(3), pol)
        assert float(m1.utilization) == float(m2.utilization)
        assert float(m1.failed_requests) == float(m2.failed_requests)

    def test_zero_threshold_admits_nothing(self, zeroth_run):
        pol = make_policy(ZEROTH, threshold=0.0, capacity=CFG.capacity)
        m = zeroth_run(jax.random.PRNGKey(1), pol)
        assert float(m.utilization) == 0.0
        assert float(m.arrivals_accepted) == 0.0

    def test_failure_accounting_consistent(self, zeroth_run):
        pol = make_policy(ZEROTH, threshold=1e9, capacity=CFG.capacity)
        m = zeroth_run(jax.random.PRNGKey(4), pol)
        assert float(m.failed_requests) <= float(m.total_requests)
        assert float(m.failure_rate) <= 1.0
        assert float(jnp.sum(m.fail_trace)) == pytest.approx(
            float(m.failed_requests))

    def test_threshold_monotone_in_utilization(self, zeroth_run):
        utils = []
        for t in (100.0, 500.0):
            pol = make_policy(ZEROTH, threshold=t, capacity=CFG.capacity)
            m = jax.vmap(lambda k: zeroth_run(k, pol))(
                jax.random.split(jax.random.PRNGKey(0), 4))
            utils.append(float(jnp.mean(m.utilization)))
        assert utils[0] <= utils[1]

    def test_moment_policy_runs_with_pseudo_obs(self):
        cfg = CFG._replace(prior_mode=PSEUDO, n_pseudo_obs=5)
        run = make_run(cfg, GRID, SECOND)
        pol = make_policy(SECOND, rho=0.2, capacity=cfg.capacity,
                          marginal=True)
        m = run(jax.random.PRNGKey(0), pol)
        assert 0.0 <= float(m.utilization) <= 1.0

    @pytest.mark.slow
    def test_mixture_mode_runs(self):
        cfg = CFG._replace(prior_mode=MIX_UNLABELED, n_pseudo_obs=5)
        run = make_run(cfg, GRID, SECOND)
        pol = make_policy(SECOND, rho=0.2, capacity=cfg.capacity)
        m = run(jax.random.PRNGKey(0), pol)
        assert 0.0 <= float(m.utilization) <= 1.0


class TestImportanceSampling:
    def test_rejection_q_is_distribution_paper_params(self):
        q = rejection_q([0.5699, 0.4121, 0.018], [0.5369, 0.8816, 0.0])
        assert q.sum() == pytest.approx(1.0, abs=1e-9)
        assert (q >= 0).all()
        # oversamples the bad tail: bucket-3 mass rises from 1.8% to ~17%
        assert q[2] > 0.018 * 5

    def test_rejection_q_no_redraw_is_identity(self):
        p = [0.7, 0.2, 0.1]
        q = rejection_q(p, [0.0, 0.0, 0.0])
        np.testing.assert_allclose(q, p, atol=1e-12)

    def test_badness_measure_finite_and_reproducible(self):
        bm1 = badness_measure(jax.random.PRNGKey(5), CFG, GRID)
        bm2 = badness_measure(jax.random.PRNGKey(5), CFG, GRID)
        assert float(bm1) == float(bm2) and np.isfinite(float(bm1))

    def test_plan_weights_sum_to_one(self):
        plan = make_importance_plan(jax.random.PRNGKey(0), CFG, GRID,
                                    quotas=(4, 4, 4), n_probe=64,
                                    probe_batch=32)
        assert plan.weights.sum() == pytest.approx(plan.p_bucket[
            np.unique(plan.buckets)].sum(), abs=1e-6)
        assert len(plan.keys) == len(plan.weights)


class TestPricing:
    @settings(max_examples=50, deadline=None)
    @given(e1=st.floats(0.0, 100.0), e2=st.floats(0.0, 100.0),
           v1=st.floats(0.0, 100.0), v2=st.floats(0.0, 100.0),
           p=st.floats(0.01, 0.99))
    def test_prop4_mixture_variance_excess_nonneg(self, e1, e2, v1, v2, p):
        """Prop. 4 / law of total variance: Var(mix) >= weighted Var."""
        w = jnp.asarray([p, 1 - p])
        excess = mixture_variance_excess(w, jnp.asarray([e1, e2]),
                                         jnp.asarray([v1, v2]))
        assert float(excess) >= -1e-6

    def test_mixture_moments_exact(self):
        curves = MomentCurves(EL=jnp.asarray([[2.0], [6.0]]),
                              VL=jnp.asarray([[1.0], [3.0]]))
        mix = mixture_moments(jnp.asarray([0.5, 0.5]), curves)
        assert float(mix.EL[0]) == pytest.approx(4.0)
        # E[V] + V[E] = 2 + 4 = 6
        assert float(mix.VL[0]) == pytest.approx(6.0)

    def test_labeling_lowers_payment(self):
        # two types with different variances: mixture pays more (Cor. 2)
        v = jnp.asarray([1.0, 9.0])
        e = jnp.asarray([2.0, 10.0])
        w = jnp.asarray([0.5, 0.5])
        mix_var = float(jnp.sum(w * (v + e**2)) - jnp.sum(w * e) ** 2)
        labeled = float(jnp.sum(w * jax.vmap(
            lambda vv: payment(jnp.asarray(5.0), vv))(v)))
        unlabeled = float(payment(jnp.asarray(5.0), jnp.asarray(mix_var)))
        assert labeled < unlabeled


class TestMetrics:
    def test_bca_ci_covers_mean(self):
        rng = np.random.default_rng(0)
        x = rng.normal(10.0, 2.0, size=100)
        ci = bca_ci(x, n_resamples=2_000)
        assert ci.lo < x.mean() < ci.hi
        assert ci.estimate == pytest.approx(x.mean())

    def test_weighted_sla_rate(self):
        rate = sla_failure_rate(np.asarray([0.0, 10.0]),
                                np.asarray([100.0, 100.0]),
                                weights=np.asarray([0.9, 0.1]))
        assert rate == pytest.approx(1.0 / 110.0 * ... if False else
                                     (0.1 * 10) / (0.9 * 100 + 0.1 * 100))
