"""GPipe pipeline parallelism: forward + gradient equivalence vs the
sequential stack (runs in a subprocess with 8 virtual devices)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(snippet: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", snippet], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"


@pytest.mark.slow
def test_pipeline_forward_and_grad_match_sequential():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline import make_pipeline_forward, sequential_reference

S, DATA = 4, 2
mesh = jax.make_mesh((S, DATA), ('stage', 'data'))
L, D, MB, M, T = 8, 16, 4, 6, 8   # 8 layers -> 2 per stage

params = {
    'w': jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.2,
    'b': jax.random.normal(jax.random.PRNGKey(1), (L, D)) * 0.1,
}

def stage_fn(p, x):  # apply this stage's layer chunk sequentially
    def layer(carry, wb):
        w, b = wb
        return jnp.tanh(carry @ w + b), None
    y, _ = jax.lax.scan(layer, x, (p['w'], p['b']))
    return y

x_mb = jax.random.normal(jax.random.PRNGKey(2), (M, MB, T, D))
pipe = make_pipeline_forward(stage_fn, S, mesh)
got = jax.jit(pipe)(params, x_mb)
want = sequential_reference(stage_fn, S, params, x_mb)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=2e-5, atol=2e-5)

# gradient equivalence (backward streams through ppermute transposes)
g1 = jax.grad(lambda p: jnp.sum(pipe(p, x_mb) ** 2))(params)
g2 = jax.grad(lambda p: jnp.sum(
    sequential_reference(stage_fn, S, p, x_mb) ** 2))(params)
for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-4, atol=5e-5)
print('OK pipeline fwd+grad')
""")
