"""Cortez/Azure-format ingestion: fixture round-trip, schema mapping,
unit normalization, dt re-bucketing, and malformed-row accounting."""
import os

import jax
import numpy as np
import pytest

from repro.core import make_policy, SECOND, geometric_grid
from repro.sim import make_config, make_run, PSEUDO
from repro.traces import (AZURE_2017_POSITIONAL, CortezSchema,
                          TraceArrivalSource, fit_priors, has_latents,
                          ingest_cortez_csv, n_deployments,
                          parse_core_bucket, validate_trace)

FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "azure_cortez_sample.csv")


def write_csv(path, rows, header=("vmid", "subscriptionid", "deploymentid",
                                  "vmcreated", "vmdeleted", "maxcpu",
                                  "avgcpu", "p95maxcpu", "vmcategory",
                                  "vmcorecountbucket", "vmmemorybucket")):
    import csv

    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        if header is not None:
            w.writerow(header)
        w.writerows(rows)
    return str(path)


def vm(vmid, dep, created, deleted, cores, sub="sub-x"):
    return [vmid, sub, dep, str(created),
            "" if deleted is None else str(deleted), "50.0", "25.0", "45.0",
            "Unknown", str(cores), "4"]


class TestFixtureRoundtrip:
    """PR-3 acceptance: the checked-in Cortez-format sample ingests into a
    WorkloadTrace that fit_priors(source="observed") accepts."""

    def test_fixture_ingests_and_fits(self):
        trace, diag = ingest_cortez_csv(FIXTURE)
        validate_trace(trace)
        assert diag["n_malformed"] == 0
        assert diag["has_header"] is True
        assert n_deployments(trace) >= 8
        assert not has_latents(trace)  # real traces carry observables only
        fitted, fdiag = fit_priors(trace, source="observed")
        assert fdiag["source"] == "observed"
        for f in fitted._fields:
            assert np.isfinite(getattr(fitted, f)), f
        for f in ("mu_shape", "mu_rate", "lam_shape", "lam_rate",
                  "sig_shape", "sig_rate", "delta"):
            assert getattr(fitted, f) > 0.0, f

    def test_fixture_replays_with_observed_pseudo_beliefs(self):
        """Real-trace replay under the §6 information model end to end."""
        trace, _ = ingest_cortez_csv(FIXTURE)
        horizon = float(np.asarray(trace.horizon_hours))
        dt = 24.0
        n_steps = int(horizon // dt)
        # n_pseudo_obs is ignored by the observed path (the trace's logged
        # history defines the information content); it must be >= 1 only to
        # satisfy the PSEUDO/0 footgun validation in _validate_config
        cfg = make_config(capacity=200.0, arrival_rate=0.05,
                          horizon_hours=n_steps * dt, dt=dt, max_slots=64,
                          max_arrivals=8, d_points=8, prior_mode=PSEUDO,
                          n_pseudo_obs=1)
        src = TraceArrivalSource(trace)
        assert src.pseudo_source == "observed"
        grid = geometric_grid(dt, 3 * horizon, 8)
        run = make_run(cfg, grid, SECOND, arrival_source=src)
        pol = make_policy(SECOND, rho=0.2, capacity=cfg.capacity)
        m = run(jax.random.PRNGKey(0), pol)
        assert 0.0 < float(m.utilization) <= 1.0


class TestMalformedRows:
    def test_malformed_rows_counted_not_kept(self, tmp_path):
        rows = [
            vm("vm-1", "dep-a", 0, 7200, 2),
            vm("vm-2", "dep-a", 3600, 10800, 1),
            ["vm-short", "sub-x", "dep-a"],               # too few columns
            vm("vm-3", "dep-b", "notanumber", 7200, 2),   # unparsable time
            vm("vm-4", "dep-b", 7200, 3600, 2),           # deleted < created
            vm("vm-5", "dep-b", -100, 7200, 2),           # negative created
            vm("vm-6", "dep-b", 0, 7200, 0),              # nonpositive cores
            vm("vm-7", "", 0, 7200, 2),                   # missing dep id
            vm("vm-8", "dep-b", 0, 7200, "??"),           # unparsable cores
            vm("vm-9", "dep-b", 0, 7200, "nan"),          # non-finite cores
            vm("vm-10", "dep-b", 0, 7200, "inf"),         # non-finite cores
            vm("vm-11", "dep-b", 7200, None, 4),          # good (censored)
        ]
        p = write_csv(tmp_path / "bad.csv", rows)
        trace, diag = ingest_cortez_csv(p)
        assert diag["n_malformed"] == 9
        assert diag["n_vms"] == 3
        assert n_deployments(trace) == 2

    def test_all_rows_malformed_raises(self, tmp_path):
        p = write_csv(tmp_path / "allbad.csv",
                      [vm("vm-1", "dep-a", "x", 1, 1)])
        with pytest.raises(ValueError, match="no well-formed"):
            ingest_cortez_csv(p)

    def test_missing_header_column_raises(self, tmp_path):
        p = write_csv(tmp_path / "nohdr.csv", [vm("vm-1", "dep-a", 0, 1, 1)],
                      header=("a", "b", "c"))
        with pytest.raises(ValueError, match="not found in"):
            ingest_cortez_csv(p)


class TestUnitsAndSchema:
    def test_seconds_to_hours_and_origin_shift(self, tmp_path):
        # first creation at 3600s becomes t=0; the second deployment
        # arrives 2h later; a 7200s lifetime is 2 core-hours per core
        rows = [vm("vm-1", "dep-a", 3600, 10800, 1),
                vm("vm-2", "dep-b", 10800, 18000, 2)]
        p = write_csv(tmp_path / "units.csv", rows)
        trace, diag = ingest_cortez_csv(p)
        v = np.asarray(trace.valid)
        t = np.asarray(trace.arrival_hours)[v]
        np.testing.assert_allclose(t, [0.0, 2.0])
        np.testing.assert_allclose(np.asarray(trace.obs_window)[v],
                                   [2.0, 2.0])
        np.testing.assert_allclose(np.asarray(trace.core_hours)[v],
                                   [2.0, 4.0])
        assert diag["horizon_hours"] == pytest.approx(4.0)

    def test_custom_time_unit(self, tmp_path):
        # timestamps already in hours: time_unit_seconds=3600
        rows = [vm("vm-1", "dep-a", 0, 5, 1)]
        p = write_csv(tmp_path / "hours.csv", rows)
        trace, _ = ingest_cortez_csv(
            p, schema=CortezSchema(time_unit_seconds=3600.0))
        assert float(np.asarray(trace.obs_window)[0]) == pytest.approx(5.0)

    def test_open_core_bucket(self):
        assert parse_core_bucket("4") == 4.0
        assert parse_core_bucket(" >24 ") == 24.0
        assert parse_core_bucket(">24", open_bucket_scale=1.25) == 30.0
        with pytest.raises(ValueError):
            parse_core_bucket("many")

    def test_headerless_positional_schema(self, tmp_path):
        rows = [vm("vm-1", "dep-a", 0, 7200, 2),
                vm("vm-2", "dep-a", 0, None, 4)]
        p = write_csv(tmp_path / "raw.csv", rows, header=None)
        trace, diag = ingest_cortez_csv(p, schema=AZURE_2017_POSITIONAL)
        assert diag["has_header"] is False
        assert diag["n_vms"] == 2
        assert float(np.asarray(trace.c0)[0]) == 6.0


class TestModelMapping:
    def test_scaleouts_deaths_and_censoring(self, tmp_path):
        # dep-a: 2 initial cores; +4 cores at t=1h (scale-out); the initial
        # VM dies at t=2h (core death); the scale-out VM survives to the
        # horizon set by dep-b (censored => no spontaneous death).
        # dep-b: all VMs gone before horizon => spontaneous shutdown, and
        # its early deletion is a death while the final one is not.
        rows = [vm("vm-1", "dep-a", 0, 7200, 2),
                vm("vm-2", "dep-a", 3600, None, 4),
                vm("vm-3", "dep-b", 0, 3600, 1),
                vm("vm-4", "dep-b", 0, 14400, 8)]
        p = write_csv(tmp_path / "model.csv", rows,)
        trace, _ = ingest_cortez_csv(p, horizon_hours=6.0)
        v = np.asarray(trace.valid)
        assert v.sum() == 2
        c0 = np.asarray(trace.c0)[v]
        n_so = np.asarray(trace.n_scaleouts)[v]
        so_cores = np.asarray(trace.scaleout_cores)[v]
        deaths = np.asarray(trace.n_core_deaths)[v]
        spont = np.asarray(trace.spont_death)[v]
        ev_valid = np.asarray(trace.events.valid)[v]
        np.testing.assert_allclose(c0, [2.0, 9.0])
        np.testing.assert_allclose(n_so, [1.0, 0.0])
        np.testing.assert_allclose(so_cores, [4.0, 0.0])
        np.testing.assert_allclose(deaths, [2.0, 1.0])
        np.testing.assert_array_equal(spont, [False, True])
        assert ev_valid.sum() == 1
        # censored scale-out VM accrues exposure to the horizon
        np.testing.assert_allclose(np.asarray(trace.core_hours)[v][0],
                                   2 * 2.0 + 4 * 5.0)

    def test_rebucket_folds_near_arrivals_into_c0(self, tmp_path):
        # 10-minute stagger: without re-bucketing it is a scale-out, with
        # 1h re-bucketing it folds into the initial request
        rows = [vm("vm-1", "dep-a", 0, None, 2),
                vm("vm-2", "dep-a", 600, None, 4),
                vm("vm-3", "dep-a", 7200, None, 1)]
        p = write_csv(tmp_path / "rebucket.csv", rows)
        fine, _ = ingest_cortez_csv(p, horizon_hours=4.0)
        assert float(np.asarray(fine.c0)[0]) == 2.0
        assert float(np.asarray(fine.n_scaleouts)[0]) == 2.0
        coarse, _ = ingest_cortez_csv(p, rebucket_dt_hours=1.0,
                                      horizon_hours=4.0)
        assert float(np.asarray(coarse.c0)[0]) == 6.0
        assert float(np.asarray(coarse.n_scaleouts)[0]) == 1.0

    def test_event_buffer_overflow_counted_in_totals(self, tmp_path):
        rows = [vm("vm-0", "dep-a", 0, None, 1)] + [
            vm(f"vm-{i}", "dep-a", 3600 * i, None, 1) for i in range(1, 6)]
        p = write_csv(tmp_path / "overflow.csv", rows)
        trace, diag = ingest_cortez_csv(p, max_events=2, horizon_hours=6.0)
        assert diag["n_events_beyond_buffer"] == 3
        assert float(np.asarray(trace.n_scaleouts)[0]) == 5.0
        assert int(np.asarray(trace.events.valid)[0].sum()) == 2

    def test_max_deployments_cap_counted(self, tmp_path):
        rows = [vm(f"vm-{i}", f"dep-{i}", 3600 * i, None, 1)
                for i in range(5)]
        p = write_csv(tmp_path / "cap.csv", rows)
        trace, diag = ingest_cortez_csv(p, max_deployments=3,
                                        horizon_hours=6.0)
        assert n_deployments(trace) == 3
        assert diag["n_deployments_dropped"] == 2
