"""Per-kernel validation: shape/dtype sweeps against the ref.py pure-jnp
oracles (interpret=True executes the kernel bodies on CPU), plus hypothesis
property tests on the kernels' invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.core import AZURE_PRIORS
from repro.core.belief import GammaBelief
from repro.core.moments import moment_curves
from repro.kernels.decode_gqa.ops import decode_gqa
from repro.kernels.decode_gqa.ref import decode_gqa_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.moment_curves.ops import moment_curves_kernel

PRIORS = AZURE_PRIORS


def _rand_belief(key, d):
    ks = jax.random.split(key, 6)
    e = lambda k, base: base * jnp.exp(jax.random.normal(k, (d,)))
    return GammaBelief(
        mu_a=e(ks[0], 0.31), mu_b=e(ks[1], 0.58), lam_a=e(ks[2], 0.49),
        lam_b=e(ks[3], 0.45), sig_a=e(ks[4], 0.26), sig_b=e(ks[5], 0.055))


class TestMomentCurvesKernel:
    @pytest.mark.parametrize("d,n,nd", [(1, 8, 8), (37, 48, 32), (300, 33, 16),
                                        (512, 64, 32)])
    def test_matches_reference(self, d, n, nd):
        key = jax.random.PRNGKey(d + n)
        bel = _rand_belief(key, d)
        cores = (1.0 + jax.random.poisson(key, 5.0, (d,))).astype(jnp.float32)
        grid = jnp.exp(jnp.linspace(np.log(1.0), np.log(26_000.0), n)
                       ).astype(jnp.float32)
        ref = moment_curves(bel, cores, grid, PRIORS, d_points=nd)
        got = moment_curves_kernel(bel, cores, grid, PRIORS, d_points=nd,
                                   interpret=True)
        np.testing.assert_allclose(got.EL, ref.EL, rtol=2e-4, atol=1e-5)
        np.testing.assert_allclose(got.VL, ref.VL, rtol=2e-3, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           cores=st.integers(1, 500))
    def test_property_nonnegative_finite(self, seed, cores):
        key = jax.random.PRNGKey(seed)
        bel = _rand_belief(key, 8)
        c = jnp.full((8,), float(cores))
        grid = jnp.asarray([1.0, 24.0, 720.0, 8760.0], jnp.float32)
        out = moment_curves_kernel(bel, c, grid, PRIORS, d_points=8,
                                   interpret=True)
        assert bool(jnp.all(jnp.isfinite(out.EL)))
        assert bool(jnp.all(jnp.isfinite(out.VL)))
        assert bool(jnp.all(out.EL >= 0.0))
        assert bool(jnp.all(out.VL >= -1e-5))


class TestFlashAttention:
    @pytest.mark.parametrize("b,s,h,kvh,dh", [
        (1, 128, 4, 4, 64), (2, 256, 8, 2, 64), (1, 512, 8, 8, 128),
        (2, 384, 4, 1, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_matches_ref(self, b, s, h, kvh, dh, dtype):
        ks = jax.random.split(jax.random.PRNGKey(s + h), 3)
        q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
        k = jax.random.normal(ks[1], (b, s, kvh, dh), dtype)
        v = jax.random.normal(ks[2], (b, s, kvh, dh), dtype)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("window", [64, 256])
    def test_sliding_window(self, window):
        ks = jax.random.split(jax.random.PRNGKey(window), 3)
        q = jax.random.normal(ks[0], (1, 384, 4, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 384, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 384, 2, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_property_rows_are_convex_combos(self, seed):
        """Attention output lies in the convex hull of V rows: max |out| <=
        max |V| per head (softmax weights sum to 1)."""
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4


class TestDecodeGQA:
    @pytest.mark.parametrize("b,s,h,kvh,dh,length", [
        (1, 128, 4, 4, 64, 128), (2, 300, 8, 4, 64, 250),
        (1, 2048, 8, 1, 128, 1500), (4, 77, 4, 2, 64, 60),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, s, h, kvh, dh, length, dtype):
        ks = jax.random.split(jax.random.PRNGKey(s + length), 3)
        q = jax.random.normal(ks[0], (b, h, dh), dtype)
        k = jax.random.normal(ks[1], (b, s, kvh, dh), dtype)
        v = jax.random.normal(ks[2], (b, s, kvh, dh), dtype)
        out = decode_gqa(q, k, v, length, interpret=True)
        ref = decode_gqa_ref(q, k, v, length)
        tol = 3e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol)

    def test_per_batch_lengths(self):
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        b, s = 3, 256
        q = jax.random.normal(ks[0], (b, 4, 64), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, 2, 64), jnp.float32)
        lengths = jnp.asarray([10, 200, 256], jnp.int32)
        out = decode_gqa(q, k, v, lengths, interpret=True)
        ref = decode_gqa_ref(q, k, v, lengths)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), length=st.integers(1, 256))
    def test_property_padding_invariance(self, seed, length):
        """Keys beyond `length` never affect the output."""
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = jax.random.normal(ks[0], (1, 4, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
        out1 = decode_gqa(q, k, v, length, interpret=True)
        noise = jax.random.normal(ks[3], (1, 256, 2, 64)) * 100.0
        tail = jnp.arange(256)[None, :, None, None] >= length
        k2 = jnp.where(tail, noise, k)
        v2 = jnp.where(tail, noise, v)
        out2 = decode_gqa(q, k2, v2, length, interpret=True)
        np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)
