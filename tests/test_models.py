"""Per-arch smoke tests on REDUCED configs (assignment requirement): one
forward/train step on CPU asserting output shapes + no NaNs, plus the
serving-correctness property: decode-with-cache logits == full-forward logits
at every position."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.shapes import SHAPES, applicable
from repro.models import (ARCH_NAMES, build_model, get_config, input_specs,
                          reduced_config)

B, S = 2, 32

# default lane: one representative per family (dense / MoE / audio / xlstm
# recurrent / hybrid); the remaining zoo runs under -m slow (CI push lane)
_DEFAULT_ARCHS = {"llama3.2-1b", "dbrx-132b", "whisper-small", "xlstm-125m",
                  "hymba-1.5b"}
ARCH_PARAMS = [
    n if n in _DEFAULT_ARCHS else pytest.param(n, marks=pytest.mark.slow)
    for n in ARCH_NAMES
]


def _batch(cfg, key):
    kt, kf = jax.random.split(key)
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens,
             "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(kf, (B, cfg.enc_seq, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def models():
    """Lazy per-arch init (deselected archs must cost nothing)."""
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced_config(get_config(name))
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_forward_shapes_and_finite(models, name):
    cfg, model, params = models(name)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    if cfg.family == "audio":
        logits = model.forward(params, batch)
    else:
        logits = model.forward(params, batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_train_step_loss_finite_and_decreases(models, name):
    cfg, model, params = models(name)
    batch = _batch(cfg, jax.random.PRNGKey(2))

    loss_fn = lambda p: model.loss(p, batch)[0]
    loss0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss0))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0
    # one SGD step reduces the loss on the same batch
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                           params, grads)
    loss1 = loss_fn(params2)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("name", ARCH_PARAMS)
def test_decode_matches_forward(models, name):
    """Teacher-forced decode through the cache reproduces full-forward logits."""
    cfg, model, params = models(name)
    batch = _batch(cfg, jax.random.PRNGKey(3))
    tokens = batch["tokens"]
    if cfg.family == "audio":
        full = model.forward(params, batch)
    else:
        full = model.forward(params, tokens)

    cache = model.init_cache(B, S, dtype=jnp.float32)
    step_fn = jax.jit(model.decode_step)  # 32 eager dispatches -> 1 compile
    logits_steps = []
    for t in range(S):
        if cfg.family == "audio" and t == 0:
            # encoder K/V enter the cache via prefill of the first token
            step_logits, cache = model.prefill(params, tokens[:, :1],
                                               frames=batch["frames"])
            # re-pad self kv to S for subsequent decode steps
            def pad(kv):
                pad_len = S - kv.k.shape[1]
                z = jnp.zeros((B, pad_len, *kv.k.shape[2:]), kv.k.dtype)
                return kv._replace(k=jnp.concatenate([kv.k, z], 1),
                                   v=jnp.concatenate([kv.v, z], 1))
            cache = cache._replace(self_kv=[pad(kv) for kv in cache.self_kv])
        else:
            step_logits, cache = step_fn(params, tokens[:, t], cache)
        logits_steps.append(step_logits)
    dec = jnp.stack(logits_steps, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full, dtype=np.float32),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("name", ["hymba-1.5b", "xlstm-125m"])
def test_prefill_then_decode_continues(models, name):
    """prefill(prompt) + decode(next) == forward(prompt+next) at the last pos
    for the sub-quadratic archs (cache = recurrent state + rolling window)."""
    cfg, model, params = models(name)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    full = model.forward(params, tokens)
    logits_p, cache = model.prefill(params, tokens[:, : S - 1])
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full[:, S - 2], dtype=np.float32),
                               rtol=2e-2, atol=2e-2)
    logits_d, _ = model.decode_step(params, tokens[:, S - 1], cache)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full[:, S - 1], dtype=np.float32),
                               rtol=2e-2, atol=2e-2)


def test_long_500k_applicability():
    runnable = [n for n in ARCH_NAMES
                if applicable(get_config(n), SHAPES["long_500k"])[0]]
    assert set(runnable) == {"hymba-1.5b", "xlstm-125m"}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_input_specs_cover_all_shapes(name):
    cfg = get_config(name)
    for shape in SHAPES.values():
        ok, _ = applicable(cfg, shape)
        if not ok:
            continue
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "train":
            assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
        if cfg.family == "audio" and shape.kind != "decode":
            assert specs["frames"].shape[1] == cfg.enc_seq
