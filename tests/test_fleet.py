"""Fleet simulator: routing, n_clusters=1 equivalence, conservation laws.

Compile budget: the module builds a handful of jitted simulators (module
fixtures) and every property test re-uses them — ZEROTH for the equivalence
and cascade checks (cheap), one SECOND fleet for the moment-policy paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing import given, settings, strategies as st

from repro.core import (AZURE_PRIORS, SECOND, ZEROTH, fleet_policy,
                        geometric_grid, make_policy)
from repro.core.moments import MomentCurves
from repro.sim import (FleetConfig, LeastUtilizedRouter, PowerOfTwoRouter,
                       RandomRouter, RouteContext, ThresholdCascadeRouter,
                       broadcast_policy, fleet_sla_failure_rate,
                       fleet_utilization, make_config, make_fleet_config,
                       make_fleet_run, make_run, stream_config)
from repro.sim.simulator import _pad_batch

CFG = make_config(capacity=500.0, arrival_rate=0.08, horizon_hours=30 * 24.0,
                  dt=24.0, max_slots=96, max_arrivals=4, d_points=8)
GRID = geometric_grid(24.0, 3 * 30 * 24.0, 12)
CAPS2 = (300.0, 200.0)
FLEET2 = FleetConfig(base=CFG, capacities=CAPS2)

METRIC_FIELDS = ("utilization", "failure_rate", "total_requests",
                 "failed_requests", "arrivals_accepted", "arrivals_rejected",
                 "slot_overflow", "n_departed", "alive_end", "util_trace",
                 "fail_trace")


@pytest.fixture(scope="module")
def single_zeroth():
    return make_run(CFG, GRID, ZEROTH)


@pytest.fixture(scope="module")
def fleet1_zeroth():
    fcfg = FleetConfig(base=CFG, capacities=(CFG.capacity,))
    return make_fleet_run(fcfg, GRID, ZEROTH, router=LeastUtilizedRouter())


@pytest.fixture(scope="module")
def fleet2_second():
    return make_fleet_run(FLEET2, GRID, SECOND, router=LeastUtilizedRouter())


@pytest.fixture(scope="module")
def fleet2_cascade():
    return make_fleet_run(FLEET2, GRID, ZEROTH,
                          router=ThresholdCascadeRouter())


def _ctx(agg_el, util, caps, policy, c0, valid, agg_vl=None):
    agg_el = jnp.asarray(agg_el, jnp.float32)
    return RouteContext(
        cand=MomentCurves(EL=jnp.zeros((len(c0), agg_el.shape[1])),
                          VL=jnp.zeros((len(c0), agg_el.shape[1]))),
        c0=jnp.asarray(c0, jnp.float32),
        valid=jnp.asarray(valid, bool),
        agg_el=agg_el,
        agg_vl=agg_el * 0.0 if agg_vl is None else jnp.asarray(agg_vl),
        util=jnp.asarray(util, jnp.float32),
        capacities=jnp.asarray(caps, jnp.float32),
        policy=policy,
    )


class TestRouters:
    def test_random_in_range(self):
        pol = broadcast_policy(
            make_policy(ZEROTH, threshold=90.0, capacity=100.0), 3)
        ctx = _ctx(jnp.zeros((3, 2)), [0.0, 0.0, 0.0], [100.0] * 3, pol,
                   c0=[1.0] * 32, valid=[True] * 32)
        assign = RandomRouter().route(jax.random.PRNGKey(0), ctx)
        assert assign.shape == (32,)
        assert bool(jnp.all((assign >= 0) & (assign < 3)))

    def test_least_utilized_folds_same_step_arrivals(self):
        pol = broadcast_policy(
            make_policy(ZEROTH, threshold=90.0, capacity=100.0), 2)
        ctx = _ctx(jnp.zeros((2, 2)), [10.0, 0.0], [100.0, 100.0], pol,
                   c0=[5.0, 5.0, 5.0], valid=[True] * 3)
        assign = LeastUtilizedRouter().route(jax.random.PRNGKey(0), ctx)
        # 1st and 2nd go to the emptier cluster 1 (0 -> 5 cores); the 3rd
        # sees a tie (10 vs 10) and argmin takes cluster 0 — the fold is
        # what keeps a burst from dogpiling the pre-step argmin
        np.testing.assert_array_equal(np.asarray(assign), [1, 1, 0])

    def test_power_of_two_prefers_lower_curve_score(self):
        pol = broadcast_policy(
            make_policy(SECOND, rho=0.2, capacity=100.0), 2)
        agg_el = jnp.stack([jnp.full((4,), 80.0), jnp.full((4,), 5.0)])
        ctx = _ctx(agg_el, [80.0, 5.0], [100.0, 100.0], pol,
                   c0=[1.0] * 256, valid=[True] * 256)
        assign = np.asarray(
            PowerOfTwoRouter().route(jax.random.PRNGKey(1), ctx))
        # the two sampled choices are DISTINCT, so with C=2 every arrival
        # compares both clusters and must take the lightly-loaded one
        np.testing.assert_array_equal(assign, np.ones(256))

    def test_power_of_two_single_cluster_degenerates(self):
        pol = broadcast_policy(
            make_policy(SECOND, rho=0.2, capacity=100.0), 1)
        ctx = _ctx(jnp.zeros((1, 4)), [0.0], [100.0], pol,
                   c0=[1.0] * 8, valid=[True] * 8)
        assign = PowerOfTwoRouter().route(jax.random.PRNGKey(0), ctx)
        np.testing.assert_array_equal(np.asarray(assign), np.zeros(8))

    def test_cascade_first_accepting_cluster_and_sentinel(self):
        pol = fleet_policy(ZEROTH, capacities=[100.0, 100.0],
                           threshold=60.0)  # per-cluster thresholds 30/30
        ctx = _ctx(jnp.zeros((2, 2)), [28.0, 0.0], [100.0, 100.0], pol,
                   c0=[5.0, 40.0], valid=[True, True])
        assign = np.asarray(
            ThresholdCascadeRouter().route(jax.random.PRNGKey(0), ctx))
        # c0=5: 28+5 > 30 at cluster 0, 0+5 < 30 at cluster 1 -> 1
        # c0=40: exceeds both thresholds -> rejected-by-all sentinel 2
        np.testing.assert_array_equal(assign, [1, 2])

    def test_cascade_folds_same_step_arrivals(self):
        pol = fleet_policy(ZEROTH, capacities=[100.0, 100.0],
                           threshold=60.0)  # per-cluster thresholds 30/30
        ctx = _ctx(jnp.zeros((2, 2)), [0.0, 0.0], [100.0, 100.0], pol,
                   c0=[20.0, 20.0, 20.0], valid=[True] * 3)
        assign = np.asarray(
            ThresholdCascadeRouter().route(jax.random.PRNGKey(0), ctx))
        # the fold makes the 2nd arrival see cluster 0 at 20 cores
        # (20+20 > 30 -> cascade to 1) and the 3rd see both at 20 ->
        # sentinel; the stateless router would have sent all three to 0
        np.testing.assert_array_equal(assign, [0, 1, 2])

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1_000_000))
    def test_cascade_routed_implies_admit_sequential_accepts(self, seed):
        """PR 5 carry-over: with the fold, a cascade-routed arrival is
        accepted by its target cluster's ``admit_sequential`` run on the
        same pre-step aggregates — routing and admission agree exactly."""
        from repro.core.policies import admit_sequential

        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
        n_c, n_a, n_n = 3, 6, 5
        caps = [60.0, 50.0, 40.0]
        pol = fleet_policy(SECOND, capacities=caps, rho=0.3)
        cand = MomentCurves(
            EL=jax.random.uniform(k1, (n_a, n_n), maxval=25.0),
            VL=jax.random.uniform(k2, (n_a, n_n), maxval=40.0))
        agg_el = jax.random.uniform(k3, (n_c, n_n), maxval=30.0)
        valid = np.ones(n_a, bool)
        valid[-1] = False
        ctx = RouteContext(
            cand=cand, c0=jax.random.uniform(k4, (n_a,), minval=1.0,
                                             maxval=10.0),
            valid=jnp.asarray(valid), agg_el=agg_el, agg_vl=agg_el * 0.5,
            util=jnp.asarray([10.0, 5.0, 0.0]),
            capacities=jnp.asarray(caps, jnp.float32), policy=pol)
        assign = np.asarray(
            ThresholdCascadeRouter().route(jax.random.PRNGKey(0), ctx))
        assert ((assign >= 0) & (assign <= n_c)).all()
        for c in range(n_c):
            mask = jnp.asarray((assign == c) & valid)
            pol_c = jax.tree.map(lambda x: x[c], pol)
            res = admit_sequential(pol_c, ctx.agg_el[c], ctx.agg_vl[c],
                                   ctx.util[c], cand, ctx.c0, mask)
            np.testing.assert_array_equal(np.asarray(res.accept),
                                          np.asarray(mask))


class TestOneClusterEquivalence:
    def test_fleet_of_one_reproduces_single_cluster(self, single_zeroth,
                                                    fleet1_zeroth):
        pol = make_policy(ZEROTH, threshold=300.0, capacity=CFG.capacity)
        for seed in (0, 3):
            key = jax.random.PRNGKey(seed)
            m1 = single_zeroth(key, pol)
            mf = fleet1_zeroth(key, pol)
            for f in METRIC_FIELDS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(m1, f)),
                    np.asarray(getattr(mf.per_cluster, f))[..., 0, :]
                    if getattr(m1, f).ndim else
                    np.asarray(getattr(mf.per_cluster, f))[0],
                    err_msg=f)
            # fleet-level reductions collapse to the same run
            np.testing.assert_array_equal(np.asarray(m1.utilization),
                                          np.asarray(mf.utilization))
            assert float(mf.rejected_by_all) == 0.0

    @pytest.mark.slow
    def test_fleet_of_one_quick_preset(self):
        """Acceptance: the one-cluster fleet reproduces the pre-refactor
        single-cluster RunMetrics key-for-key at the quick preset."""
        from benchmarks.common import SCALES, grid_for, sim_config

        scale = SCALES["quick"]
        cfg = sim_config(scale)
        grid = grid_for(scale, cfg)
        run1 = make_run(cfg, grid, SECOND)
        frun = make_fleet_run(FleetConfig(base=cfg, capacities=(cfg.capacity,)),
                              grid, SECOND, router=LeastUtilizedRouter())
        key = jax.random.PRNGKey(0)
        pol = make_policy(SECOND, rho=0.112, capacity=cfg.capacity)
        fpol = fleet_policy(SECOND, capacities=(cfg.capacity,), rho=0.112)
        m1 = run1(key, pol)
        mf = frun(key, fpol)
        for f in ("utilization", "failure_rate", "total_requests",
                  "failed_requests", "arrivals_accepted", "arrivals_rejected",
                  "slot_overflow", "n_departed", "alive_end"):
            np.testing.assert_array_equal(
                np.asarray(getattr(m1, f)),
                np.asarray(getattr(mf.per_cluster, f))[0], err_msg=f)
        np.testing.assert_array_equal(np.asarray(m1.util_trace),
                                      np.asarray(mf.per_cluster.util_trace)[0])


class TestFleetConservation:
    """Satellite: conservation invariants, property-tested via repro.testing."""

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 1_000_000))
    def test_no_cluster_exceeds_its_capacity(self, fleet2_second, seed):
        pol = fleet_policy(SECOND, capacities=CAPS2, rho=0.5)
        m = fleet2_second(jax.random.PRNGKey(seed), pol)
        peaks = np.asarray(m.per_cluster.util_trace).max(axis=1)
        assert (peaks <= np.asarray(CAPS2) + 1e-3).all(), peaks

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 1_000_000))
    def test_alive_equals_admitted_minus_departed(self, fleet2_second, seed):
        pol = fleet_policy(SECOND, capacities=CAPS2, rho=0.5)
        m = fleet2_second(jax.random.PRNGKey(seed), pol).per_cluster
        placed = np.asarray(m.arrivals_accepted) - np.asarray(m.slot_overflow)
        np.testing.assert_array_equal(
            np.asarray(m.alive_end), placed - np.asarray(m.n_departed))

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 1_000_000))
    def test_fleet_metrics_reduce_per_cluster(self, fleet2_second, seed):
        pol = fleet_policy(SECOND, capacities=CAPS2, rho=0.5)
        m = fleet2_second(jax.random.PRNGKey(seed), pol)
        pc = m.per_cluster
        np.testing.assert_allclose(
            float(m.utilization),
            fleet_utilization(np.asarray(pc.utilization), CAPS2), rtol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(m.failed_requests),
            np.asarray(pc.failed_requests).sum())
        np.testing.assert_array_equal(
            np.asarray(m.total_requests),
            np.asarray(pc.total_requests).sum())
        assert float(m.failure_rate) == pytest.approx(fleet_sla_failure_rate(
            np.asarray(pc.failed_requests)[None],
            np.asarray(pc.total_requests)[None]))
        np.testing.assert_array_equal(
            np.asarray(m.arrivals_rejected),
            np.asarray(pc.arrivals_rejected).sum()
            + np.asarray(m.rejected_by_all))
        np.testing.assert_allclose(
            np.asarray(m.util_trace),
            np.asarray(pc.util_trace).sum(axis=0), rtol=1e-6)

    def test_cascade_rejected_by_all_accounting(self, fleet2_cascade):
        # a tight fleet threshold forces cascade rejections; every valid
        # arrival is either admitted somewhere, rejected by its target
        # cluster, or rejected-by-all — nothing is lost
        pol = fleet_policy(ZEROTH, capacities=CAPS2, threshold=20.0)
        m = fleet2_cascade(jax.random.PRNGKey(2), pol)
        assert float(m.rejected_by_all) > 0.0
        total_seen = (float(m.arrivals_accepted)
                      + float(m.arrivals_rejected))
        pc = m.per_cluster
        assert total_seen == pytest.approx(
            float(np.asarray(pc.arrivals_accepted).sum())
            + float(np.asarray(pc.arrivals_rejected).sum())
            + float(m.rejected_by_all))


class TestFleetReplay:
    def test_trace_replays_into_fleet_routed(self, fleet2_second):
        """A trace replays into the fleet as ONE fleet-wide stream whose
        arrivals the router then spreads over clusters."""
        from repro.traces import TraceSpec, synthesize_scenario, trace_to_stream

        spec = TraceSpec(horizon_hours=CFG.horizon_hours,
                         arrival_rate=CFG.arrival_rate * 4,
                         max_deployments=256, max_events=8,
                         priors=AZURE_PRIORS)
        trace = synthesize_scenario(jax.random.PRNGKey(5), "baseline", spec)
        stream, n_dropped = trace_to_stream(trace, FLEET2)
        assert stream.c0.shape == (CFG.n_steps, CFG.max_arrivals)
        pol = fleet_policy(SECOND, capacities=CAPS2, rho=0.5)
        m = fleet2_second(jax.random.PRNGKey(0), pol, stream)
        acc = np.asarray(m.per_cluster.arrivals_accepted)
        assert acc.sum() > 0
        # least-utilized routing spreads the trace over both clusters
        assert (acc > 0).sum() == 2, acc
        peaks = np.asarray(m.per_cluster.util_trace).max(axis=1)
        assert (peaks <= np.asarray(CAPS2) + 1e-3).all()

    def test_stream_config_reduces_fleet(self):
        sc = stream_config(FLEET2)
        assert sc.capacity == pytest.approx(sum(CAPS2))
        assert sc.max_arrivals == CFG.max_arrivals
        assert stream_config(CFG) is CFG


class TestConfigValidation:
    """Satellite: the PSEUDO/n_pseudo_obs footgun fails fast."""

    def test_pseudo_with_zero_obs_rejected(self):
        with pytest.raises(ValueError, match="degenerates to"):
            make_config(prior_mode="pseudo", n_pseudo_obs=0)

    def test_negative_pseudo_obs_rejected(self):
        with pytest.raises(ValueError, match="n_pseudo_obs"):
            make_config(n_pseudo_obs=-1)

    def test_mixture_modes_with_zero_obs_rejected(self):
        # §7 mixtures with 0 pseudo observations leave both components at
        # the population prior — the same silent GLOBAL degeneration
        for mode in ("labeled", "unlabeled"):
            with pytest.raises(ValueError, match="degenerates to"):
                make_config(prior_mode=mode, n_pseudo_obs=0)

    def test_valid_pseudo_accepted(self):
        cfg = make_config(prior_mode="pseudo", n_pseudo_obs=5)
        assert cfg.n_pseudo_obs == 5

    def test_global_with_zero_obs_still_fine(self):
        assert make_config(n_pseudo_obs=0).prior_mode == "global"

    def test_fleet_config_rejects_bad_capacities(self):
        with pytest.raises(ValueError, match="capacities"):
            make_fleet_config(())
        with pytest.raises(ValueError, match="positive"):
            make_fleet_config((100.0, -1.0))

    def test_broadcast_policy_shape_checked(self):
        pol = fleet_policy(ZEROTH, capacities=(1.0, 2.0, 3.0))
        with pytest.raises(ValueError, match="per cluster"):
            broadcast_policy(pol, 2)

    def test_fleet_total_capacity_policy_fails_fast(self, fleet2_second):
        """A scalar fleet-TOTAL capacity tiled per cluster would let every
        cluster admit against the whole fleet's budget — run() rejects it."""
        bad = make_policy(SECOND, rho=0.5, capacity=sum(CAPS2))
        with pytest.raises(ValueError, match="FleetConfig.capacities"):
            fleet2_second(jax.random.PRNGKey(0), bad)


class TestBatchPadding:
    """Satellite: ragged batches pad to the device multiple (and the padded
    lanes never reach callers)."""

    def test_pad_batch_repeats_last_row(self):
        keys = jnp.arange(10).reshape(5, 2)
        policy = {"replicated": jnp.zeros(3)}
        padded = _pad_batch((keys, policy), 1, 3)
        assert padded[0].shape == (8, 2)
        np.testing.assert_array_equal(np.asarray(padded[0][:5]),
                                      np.asarray(keys))
        for row in np.asarray(padded[0][5:]):
            np.testing.assert_array_equal(row, np.asarray(keys[-1]))
        assert padded[1] is policy

    def test_pad_batch_noop_when_aligned(self):
        keys = jnp.arange(8).reshape(4, 2)
        args = (keys, "policy")
        assert _pad_batch(args, 1, 0) is args

    def test_sharded_ragged_batch_matches_vmap_on_virtual_devices(self):
        """Regression: a key batch that does not divide the device count used
        to silently fall back to single-device vmap; now it pads, shards,
        and slices — with identical metrics."""
        import os
        import subprocess
        import sys

        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        out = subprocess.run([sys.executable, "-c", """
import jax, numpy as np
from repro.core import ZEROTH, geometric_grid, make_policy
from repro.sim import make_config, make_run, run_keyed_batch

cfg = make_config(capacity=300.0, arrival_rate=0.1, horizon_hours=10*24.0,
                  dt=24.0, max_slots=48, max_arrivals=4, d_points=8)
grid = geometric_grid(24.0, 30*24.0, 8)
run = make_run(cfg, grid, ZEROTH)
pol = make_policy(ZEROTH, threshold=200.0, capacity=cfg.capacity)
keys = jax.random.split(jax.random.PRNGKey(0), 6)   # 6 % 8 != 0 -> pads to 8
assert len(jax.devices()) == 8
m_shard = run_keyed_batch(run, keys, pol)
m_vmap = run_keyed_batch(run, keys, pol, devices=jax.devices()[:1])
assert m_shard.utilization.shape == (6,), m_shard.utilization.shape
np.testing.assert_allclose(np.asarray(m_shard.utilization),
                           np.asarray(m_vmap.utilization), rtol=1e-6)
np.testing.assert_array_equal(np.asarray(m_shard.failed_requests),
                              np.asarray(m_vmap.failed_requests))
print('OK')
"""], env=env, capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
