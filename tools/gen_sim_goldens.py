"""Regenerate tests/data/golden_sim_metrics.npz — the bit-for-bit anchor for
the AdmissionCore extraction.

The goldens were captured from the pre-extraction simulator (PR 5 state) on
the reference CPU box; the core-extraction tests assert today's
``make_run``/``make_fleet_run`` reproduce them exactly. Regenerate ONLY when
a deliberate semantic change to the simulator lands (and say so in the PR):

  PYTHONPATH=src python tools/gen_sim_goldens.py
"""
import os

import numpy as np

import jax

from repro.core import (AZURE_PRIORS, SECOND, ZEROTH, fleet_policy,
                        geometric_grid, make_policy)
from repro.sim import (FleetConfig, LeastUtilizedRouter, SimConfig,
                       make_fleet_run, make_run)

OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                   "golden_sim_metrics.npz")

CFG = SimConfig(capacity=500.0, arrival_rate=0.08, horizon_hours=30 * 24.0,
                dt=24.0, max_slots=96, max_arrivals=4, d_points=8,
                priors=AZURE_PRIORS)
GRID = geometric_grid(24.0, 3 * 30 * 24.0, 12)
CFG_K3 = CFG._replace(agg_refresh_steps=3)
FLEET2 = FleetConfig(base=CFG, capacities=(300.0, 200.0))


def flat(prefix: str, metrics) -> dict:
    out = {}
    for name, val in metrics._asdict().items():
        if hasattr(val, "_asdict"):  # FleetMetrics.per_cluster
            out.update(flat(f"{prefix}/{name}", val))
        else:
            out[f"{prefix}/{name}"] = np.asarray(val)
    return out


def main():
    arrays = {}

    run_z = make_run(CFG, GRID, ZEROTH)
    pol_z = make_policy(ZEROTH, threshold=300.0, capacity=CFG.capacity)
    arrays.update(flat("single/zeroth",
                       run_z(jax.random.PRNGKey(0), pol_z)))

    run_s = make_run(CFG_K3, GRID, SECOND)
    pol_s = make_policy(SECOND, rho=0.05, capacity=CFG.capacity)
    arrays.update(flat("single/second_k3",
                       run_s(jax.random.PRNGKey(1), pol_s)))

    frun = make_fleet_run(FLEET2, GRID, SECOND, router=LeastUtilizedRouter())
    fpol = fleet_policy(SECOND, capacities=FLEET2.capacities, rho=0.05)
    arrays.update(flat("fleet2/second",
                       frun(jax.random.PRNGKey(2), fpol)))

    np.savez(os.path.abspath(OUT), **arrays)
    print(f"wrote {os.path.abspath(OUT)} ({len(arrays)} arrays)")


if __name__ == "__main__":
    main()
