#!/usr/bin/env python
"""Link/anchor checker for the repo docs (CI docs job).

Scans README.md and docs/**/*.md for markdown links and verifies that

  * relative file targets exist (anchors stripped),
  * intra-repo anchors (``#section`` or ``file.md#section``) resolve to a
    heading in the target file under GitHub's slugification,
  * reference-style definitions are not silently broken.

External http(s)/mailto links are skipped — CI runs offline. Exits
nonzero listing every broken link so the docs cannot rot silently.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
IMAGE_RE = re.compile(r"\!\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def doc_files() -> list[str]:
    files = []
    readme = os.path.join(REPO, "README.md")
    if os.path.exists(readme):
        files.append(readme)
    docs = os.path.join(REPO, "docs")
    for root, _, names in os.walk(docs):
        files.extend(os.path.join(root, n) for n in sorted(names)
                     if n.endswith(".md"))
    return files


def github_slug(heading: str) -> str:
    """GitHub's heading → anchor slugification (close enough for ASCII)."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        body = CODE_FENCE_RE.sub("", f.read())
    return {github_slug(m.group(1)) for m in HEADING_RE.finditer(body)}


def check_file(path: str) -> list[str]:
    with open(path, encoding="utf-8") as f:
        raw = f.read()
    body = CODE_FENCE_RE.sub("", raw)
    rel = os.path.relpath(path, REPO)
    errors = []
    targets = [m.group(1) for m in LINK_RE.finditer(body)]
    targets += [m.group(1) for m in IMAGE_RE.finditer(body)]
    for target in targets:
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        file_part, _, anchor = target.partition("#")
        if file_part:
            dest = os.path.normpath(
                os.path.join(os.path.dirname(path), file_part))
            if not os.path.exists(dest):
                errors.append(f"{rel}: broken link -> {target}"
                              f" (no such file {os.path.relpath(dest, REPO)})")
                continue
        else:
            dest = path
        if anchor and dest.endswith(".md"):
            if github_slug(anchor) not in anchors_of(dest):
                errors.append(f"{rel}: broken anchor -> {target}")
    return errors


def main() -> int:
    files = doc_files()
    if not files:
        print("check_docs_links: no README.md or docs/*.md found",
              file=sys.stderr)
        return 1
    errors = []
    for path in files:
        errors.extend(check_file(path))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_docs_links: {len(files)} files, "
          f"{len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
