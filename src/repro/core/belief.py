"""Conjugate Gamma belief state over deployment scaling processes (paper §2.2).

The provider cannot observe (lam, mu, sig); it maintains, per deployment slot,
Gamma posteriors that start at the population prior and are updated from the
observable events (core deaths + exposure, scale-out counts, scale-out sizes):

  * mu  | data ~ Gamma(a  + #deaths,      b  + total core-hours observed)
        (exponential lifetimes, right-censored cores contribute exposure only)
  * sig | data ~ Gamma(as + sum(size-1),  bs + #size observations)
        (size - 1 ~ Poisson(sig); the arrival size C0 counts as one observation)
  * lam | data ~ Gamma(al + #scale-outs,  bl + E[mu**nu] * alive-hours)
        (scale-outs ~ Poisson(lam * mu**nu * t); mu is latent, so the exposure
        uses the posterior mean of mu**nu — an E-step approximation, documented
        in DESIGN.md §4. This keeps the update conjugate and O(1).)

All fields are arrays over deployment slots so the whole belief state is a jit
friendly pytree.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

from .processes import PopulationPriors, PseudoObservations


class GammaBelief(NamedTuple):
    """Per-slot Gamma(shape, rate) posteriors for (mu, lam, sig)."""

    mu_a: jax.Array
    mu_b: jax.Array
    lam_a: jax.Array
    lam_b: jax.Array
    sig_a: jax.Array
    sig_b: jax.Array

    def expected_mu_pow(self, p) -> jax.Array:
        """E[mu**p] = Gamma(a+p)/Gamma(a) / b**p under mu ~ Gamma(a, b)."""
        return jnp.exp(gammaln(self.mu_a + p) - gammaln(self.mu_a) - p * jnp.log(self.mu_b))


def belief_from_prior(priors: PopulationPriors, shape=()) -> GammaBelief:
    """Fresh belief equal to the population prior for every slot."""
    full = lambda v: jnp.full(shape, v, dtype=jnp.float32)
    return GammaBelief(
        mu_a=full(priors.mu_shape), mu_b=full(priors.mu_rate),
        lam_a=full(priors.lam_shape), lam_b=full(priors.lam_rate),
        sig_a=full(priors.sig_shape), sig_b=full(priors.sig_rate),
    )


def update_on_events(
    bel: GammaBelief,
    *,
    core_deaths: jax.Array,
    exposure_core_hours: jax.Array,
    n_scaleouts: jax.Array,
    scaleout_cores: jax.Array,
    alive_hours: jax.Array,
    priors: PopulationPriors,
) -> GammaBelief:
    """One observation step. All args are per-slot arrays (zeros for no-ops).

    ``exposure_core_hours`` is the total core-hours lived this step (both the
    cores that died and the survivors — right-censored observations add
    exposure to the rate parameter but no count to the shape).
    ``scaleout_cores`` is the total cores requested, so sizes-minus-one sum to
    ``scaleout_cores - n_scaleouts``.
    """
    mu_a = bel.mu_a + core_deaths
    mu_b = bel.mu_b + exposure_core_hours
    # E-step exposure for lam uses the *updated* mu posterior.
    e_mu_nu = jnp.exp(gammaln(mu_a + priors.nu) - gammaln(mu_a) - priors.nu * jnp.log(mu_b))
    lam_a = bel.lam_a + n_scaleouts
    lam_b = bel.lam_b + e_mu_nu * alive_hours
    sig_a = bel.sig_a + (scaleout_cores - n_scaleouts)
    sig_b = bel.sig_b + n_scaleouts
    return GammaBelief(mu_a, mu_b, lam_a, lam_b, sig_a, sig_b)


def apply_pseudo_observations(bel: GammaBelief, obs: PseudoObservations,
                              priors: PopulationPriors) -> GammaBelief:
    """Fold paper-§6 pseudo observations into the belief (deployment-specific prior)."""
    mu_a = bel.mu_a + obs.n_lifetimes
    mu_b = bel.mu_b + obs.sum_lifetimes
    e_mu_nu = jnp.exp(gammaln(mu_a + priors.nu) - gammaln(mu_a) - priors.nu * jnp.log(mu_b))
    lam_a = bel.lam_a + obs.n_scaleouts
    lam_b = bel.lam_b + e_mu_nu * obs.n_windows
    sig_a = bel.sig_a + obs.sum_size_minus1
    sig_b = bel.sig_b + obs.n_sizes
    return GammaBelief(mu_a, mu_b, lam_a, lam_b, sig_a, sig_b)


def observe_initial_size(bel: GammaBelief, c0: jax.Array) -> GammaBelief:
    """The arrival request C0 ~ 1 + Poisson(sig) is itself a size observation."""
    return bel._replace(sig_a=bel.sig_a + (c0 - 1), sig_b=bel.sig_b + 1.0)


def pseudo_counts_from_observables(
    *,
    core_deaths: jax.Array,
    exposure_core_hours: jax.Array,
    n_scaleouts: jax.Array,
    scaleout_cores: jax.Array,
    window_hours: jax.Array,
) -> PseudoObservations:
    """Provider-side pseudo-counts from a deployment's *observed* history.

    The paper's §6 pseudo observations are k draws from each true scaling
    process; a recorded trace carries the real thing — the death/scale-out
    counts and exposures a provider would have logged while the deployment
    ran. Packing those observables into a ``PseudoObservations`` and folding
    them through ``apply_pseudo_observations`` yields exactly the conjugate
    posterior the provider would hold after watching that history:

      * each observed core death is one (censored-exponential) lifetime
        observation; the core-hour exposure is the Gamma rate increment,
        so survivors inform mu through exposure alone;
      * the observation window plays the role of the §6 unit-time windows
        (``n_windows`` is *hours* here, not a count — the conjugate update
        only ever uses it as exposure);
      * each scale-out contributes one size observation with
        size - 1 summing to ``scaleout_cores - n_scaleouts``.

    Inputs may be malformed real-trace columns; counts are clipped at zero
    so a bad row degrades to "no information" rather than an improper
    posterior.
    """
    deaths = jnp.maximum(core_deaths, 0.0)
    n_so = jnp.maximum(n_scaleouts, 0.0)
    return PseudoObservations(
        n_lifetimes=deaths,
        sum_lifetimes=jnp.maximum(exposure_core_hours, 0.0),
        n_windows=jnp.maximum(window_hours, 0.0),
        n_scaleouts=n_so,
        n_sizes=n_so,
        sum_size_minus1=jnp.maximum(
            jnp.maximum(scaleout_cores, 0.0) - n_so, 0.0),
    )
