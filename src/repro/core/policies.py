"""Admission policies (paper §4): zeroth / first / second moment (+ marginal).

All policies are expressed over *aggregate* moment curves of the cluster
(sum over admitted deployments of E[L_n] and V[L_n]) plus the candidate's own
curves, so a decision is O(N) on the horizon grid:

  * Zeroth (Def. 1, industry baseline): admit iff util_after < t.
  * First (Def. 2, Markov's inequality):  admit iff sum E[L_n] <= t  for all n.
  * Second (Def. 3, Cantelli):            admit iff sum E[L_n] <= c  and
        sum V[L_n] / (sum V[L_n] + (c - sum E[L_n])²) <= rho  for all n.
  * Marginal heuristic (Def. 4): per-n OR with E[L_n^cand] < eps (1e-5).

Batched arrivals within one simulator step are admitted greedily in arrival
order via ``admit_sequential`` (a lax.scan that folds accepted candidates'
curves into the running aggregate), matching the paper's one-at-a-time
semantics under Assumption 3.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .moments import MomentCurves

ZEROTH, FIRST, SECOND = 0, 1, 2


class PolicyParams(NamedTuple):
    """Runtime parameters of an admission policy (jit-friendly)."""

    kind: jax.Array          # int32: ZEROTH / FIRST / SECOND
    threshold: jax.Array     # t  (zeroth/first)  -- cores
    rho: jax.Array           # Cantelli bound     (second)
    capacity: jax.Array      # c  -- cluster cores
    marginal_eps: jax.Array  # 0.0 disables Def. 4


def make_policy(kind: int, *, threshold: float = 0.0, rho: float = 0.0,
                capacity: float, marginal: bool = False) -> PolicyParams:
    return PolicyParams(
        kind=jnp.asarray(kind, jnp.int32),
        threshold=jnp.asarray(threshold, jnp.float32),
        rho=jnp.asarray(rho, jnp.float32),
        capacity=jnp.asarray(capacity, jnp.float32),
        marginal_eps=jnp.asarray(1e-5 if marginal else 0.0, jnp.float32),
    )


def fleet_policy(kind: int, *, capacities, threshold: float = 0.0,
                 rho: float = 0.0, marginal: bool = False) -> PolicyParams:
    """PolicyParams broadcast over the cluster axis of a heterogeneous fleet.

    Every field gets a leading ``[C]`` axis so the fleet simulator can vmap
    admission per cluster. ``threshold`` is a *fleet-total* core budget split
    across clusters proportional to capacity — one scalar therefore tunes
    heterogeneous per-cluster thresholds, which is what lets the flattened
    device-sharded calibration pass (``tuning.calibrate`` with a
    ``policy_fn``) search fleet policies on the same scalar grid as
    single-cluster ones. ``rho`` (the Cantelli bound, scale-free) and the
    marginal flag are shared across clusters.
    """
    caps = jnp.asarray(capacities, jnp.float32)
    n_c = caps.shape[0]
    frac = caps / jnp.sum(caps)
    return PolicyParams(
        kind=jnp.full((n_c,), kind, jnp.int32),
        threshold=jnp.asarray(threshold, jnp.float32) * frac,
        rho=jnp.full((n_c,), rho, jnp.float32),
        capacity=caps,
        marginal_eps=jnp.full((n_c,), 1e-5 if marginal else 0.0, jnp.float32),
    )


def geometric_grid(t_min: float = 1.0, t_max: float = 3 * 365 * 24.0, n: int = 48):
    """Geometric horizon grid (hours). Beyond-paper: replaces the 5-subpolicy
    cascade with one log-spaced grid covering 1h..3y."""
    return jnp.asarray(
        jnp.exp(jnp.linspace(math.log(t_min), math.log(t_max), n)), jnp.float32
    )


def paper_cascade(n_per: int = 600) -> jax.Array:
    """The paper's §5.2 subpolicy cascade: 24h / 1w / 1mo / 1y / 3y horizons,
    each discretized into ``n_per`` uniform steps; returned as one sorted grid
    (accept iff the condition holds at every point = all subpolicies accept)."""
    horizons = [24.0, 7 * 24.0, 30 * 24.0, 365 * 24.0, 3 * 365 * 24.0]
    grids = [jnp.linspace(h / n_per, h, n_per) for h in horizons]
    return jnp.unique(jnp.concatenate(grids))


# ---------------------------------------------------------------------------
# Decision rules. agg_el/agg_vl: [N] aggregate curves of already-admitted
# deployments; cand: the candidate's curves [N]; util: current active cores.
# ---------------------------------------------------------------------------

class DecisionDiag(NamedTuple):
    """Per-candidate decision diagnostics from ``decide_scored`` (telemetry
    and tracing inputs; dead-code-eliminated by XLA when unused)."""

    fits: jax.Array       # physical capacity fit at the decision point
    score: jax.Array      # the policy's scalar score (kind-dependent)
    threshold: jax.Array  # the bound the score was compared against


def decide_scored(params: PolicyParams, agg_el: jax.Array, agg_vl: jax.Array,
                  util: jax.Array, cand: MomentCurves, cand_c0: jax.Array
                  ) -> tuple[jax.Array, DecisionDiag]:
    """Boolean admission decision plus its diagnostics for one candidate.

    The boolean is exactly ``decide``'s; ``DecisionDiag`` additionally
    reports the physical-fit flag and the kind's scalar score — worst-case
    ``util + c0`` (zeroth), max aggregate ``E[L_n]`` after admission
    (first), or max Cantelli mass (second) — against its bound. Telemetry
    counters and decision tracing consume the diagnostics; callers that
    ignore them compile to the same program as ``decide``.
    """
    el_after = agg_el + cand.EL
    vl_after = agg_vl + cand.VL
    fits = util + cand_c0 <= params.capacity  # physical: the request must fit

    zeroth_ok = util + cand_c0 < params.threshold

    first_pt = el_after <= params.threshold
    slack = jnp.maximum(params.capacity - el_after, 0.0)
    cantelli = vl_after / (vl_after + slack**2 + 1e-30)
    second_pt = (el_after <= params.capacity) & (cantelli <= params.rho)

    marginal_pt = cand.EL < params.marginal_eps  # Def. 4, per horizon point
    first_ok = jnp.all(first_pt | marginal_pt)
    second_ok = jnp.all(second_pt | marginal_pt)

    ok = jnp.where(
        params.kind == ZEROTH, zeroth_ok,
        jnp.where(params.kind == FIRST, first_ok, second_ok),
    )
    score = jnp.where(
        params.kind == ZEROTH, util + cand_c0,
        jnp.where(params.kind == FIRST, jnp.max(el_after),
                  jnp.max(cantelli)),
    )
    bound = jnp.where(params.kind == SECOND, params.rho, params.threshold)
    return ok & fits, DecisionDiag(fits=fits, score=score, threshold=bound)


def decide(params: PolicyParams, agg_el: jax.Array, agg_vl: jax.Array,
           util: jax.Array, cand: MomentCurves, cand_c0: jax.Array) -> jax.Array:
    """Boolean admission decision for a single candidate."""
    return decide_scored(params, agg_el, agg_vl, util, cand, cand_c0)[0]


def is_safe(params: PolicyParams, agg_el: jax.Array, agg_vl: jax.Array) -> jax.Array:
    """Problem 1 safety check: does the reject-all policy satisfy the
    constraint from the current belief state? (Equation (4), evaluated through
    the same moment approximation the policy uses.)"""
    slack = jnp.maximum(params.capacity - agg_el, 0.0)
    cantelli = agg_vl / (agg_vl + slack**2 + 1e-30)
    first_safe = jnp.all(agg_el <= params.threshold)
    second_safe = jnp.all((agg_el <= params.capacity) & (cantelli <= params.rho))
    return jnp.where(params.kind == FIRST, first_safe,
                     jnp.where(params.kind == SECOND, second_safe, True))


class AdmitResult(NamedTuple):
    accept: jax.Array   # [A] bool
    agg_el: jax.Array   # [N] updated aggregate
    agg_vl: jax.Array   # [N]
    util: jax.Array     # scalar


def admit_sequential_verbose(
        params: PolicyParams, agg_el: jax.Array, agg_vl: jax.Array,
        util: jax.Array, cands: MomentCurves, cand_c0: jax.Array,
        valid: jax.Array) -> tuple[AdmitResult, DecisionDiag]:
    """``admit_sequential`` plus the per-candidate ``DecisionDiag`` (leading
    ``[A]`` axis) captured *at each candidate's decision point* — the fit
    flag and score reflect the running aggregate after the candidates
    admitted before it, which is what telemetry reason counters and decision
    traces need. Decisions are identical to ``admit_sequential`` (same scan,
    same arithmetic); ignoring the diagnostics compiles them away."""

    def step(carry, x):
        el, vl, u = carry
        c_el, c_vl, c0, ok_slot = x
        acc, diag = decide_scored(params, el, vl, u,
                                  MomentCurves(c_el, c_vl), c0)
        acc = acc & ok_slot
        el = jnp.where(acc, el + c_el, el)
        vl = jnp.where(acc, vl + c_vl, vl)
        u = jnp.where(acc, u + c0, u)
        return (el, vl, u), (acc, diag)

    (agg_el, agg_vl, util), (accept, diag) = jax.lax.scan(
        step, (agg_el, agg_vl, util), (cands.EL, cands.VL, cand_c0, valid)
    )
    return AdmitResult(accept, agg_el, agg_vl, util), diag


def admit_sequential(params: PolicyParams, agg_el: jax.Array, agg_vl: jax.Array,
                     util: jax.Array, cands: MomentCurves, cand_c0: jax.Array,
                     valid: jax.Array) -> AdmitResult:
    """Greedy first-come-first-served admission of a batch of A candidates.

    cands.EL/VL: [A, N]; cand_c0, valid: [A]. Invalid slots are skipped.
    """
    res, _ = admit_sequential_verbose(params, agg_el, agg_vl, util, cands,
                                      cand_c0, valid)
    return res


# ---------------------------------------------------------------------------
# Threshold calibration (paper §5.2: binary search subject to the SLA).
# ---------------------------------------------------------------------------

def tune_threshold(
    run_sla: Callable[[float], float],
    lo: float,
    hi: float,
    target_sla: float,
    iters: int = 12,
) -> float:
    """Binary-search the policy parameter so the measured SLA failure rate is
    just below ``target_sla``. ``run_sla(theta)`` returns the failure rate of a
    simulation batch at parameter theta (monotone increasing in theta).

    This is the paper-literal *serial reference oracle*: one full simulation
    batch per probe, kept deliberately simple so tests can compare against
    it. Production calibration lives in ``repro.tuning.calibrate``, which
    evaluates whole candidate grids in one device-sharded batched pass with
    CI-aware stopping (and is oracle-tested against this function)."""
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if run_sla(mid) <= target_sla:
            lo = mid
        else:
            hi = mid
    return lo
