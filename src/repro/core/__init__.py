"""The paper's primary contribution: moment-based cluster admission control.

Public API:
  processes  — deployment stochastic processes + fitted Azure priors
  belief     — conjugate Gamma belief state over scaling parameters
  moments    — closed-form E[L_t]/V[L_t] curves (continuous + paper-discrete)
  policies   — zeroth/first/second moment policies, marginal heuristic, tuning
  pomdp      — the constrained-POMDP statement and tail bounds
  pricing    — variance-based payment rule / elicitation (Prop. 4)
"""
from .processes import (AZURE_PRIORS, DeploymentParams, PopulationPriors,
                        sample_params, sample_step_events, scaleout_rate,
                        sample_pseudo_observations, sample_initial_size)
from .belief import (GammaBelief, belief_from_prior, update_on_events,
                     apply_pseudo_observations, observe_initial_size,
                     pseudo_counts_from_observables)
from .moments import (MomentCurves, aggregate_moment_curves, moment_curves,
                      moment_curves_discrete, moment_curves_fused)
from .policies import (ZEROTH, FIRST, SECOND, DecisionDiag, PolicyParams,
                       fleet_policy, make_policy, geometric_grid,
                       paper_cascade, decide, decide_scored,
                       admit_sequential, admit_sequential_verbose, is_safe,
                       tune_threshold)
from . import pomdp, pricing

__all__ = [
    "AZURE_PRIORS", "DeploymentParams", "PopulationPriors", "sample_params",
    "sample_step_events", "scaleout_rate", "sample_pseudo_observations",
    "sample_initial_size", "GammaBelief", "belief_from_prior",
    "update_on_events", "apply_pseudo_observations", "observe_initial_size",
    "pseudo_counts_from_observables",
    "MomentCurves", "aggregate_moment_curves", "moment_curves",
    "moment_curves_discrete", "moment_curves_fused", "ZEROTH",
    "FIRST", "SECOND", "PolicyParams", "fleet_policy", "make_policy",
    "geometric_grid",
    "paper_cascade", "decide", "decide_scored", "DecisionDiag",
    "admit_sequential", "admit_sequential_verbose", "is_safe",
    "tune_threshold", "pomdp", "pricing",
]
