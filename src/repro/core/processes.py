"""Deployment stochastic processes (paper §2.1) and the fitted Azure priors.

A deployment x is described by latent parameters (lam, mu, sig):
  * core lifetime            ~ Exp(mu)               (rate, per hour)
  * max deployment lifetime  ~ Exp(delta * mu)       (spontaneous shutdown)
  * scale-out events         ~ Poisson(lam * mu**nu) (per hour)
  * scale-out size           ~ 1 + Poisson(sig)
  * initial size C0          ~ 1 + Poisson(sig)      (the arrival request)

Population priors are Gamma(shape, rate) fitted to the Azure trace of
Cortez et al. [2017] (paper Table 1). ``delta`` and ``nu`` are population-wide
constants. Time unit throughout the package: one hour.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PopulationPriors(NamedTuple):
    """Gamma(shape, rate) hyperparameters for (mu, lam, sig) + global constants."""

    mu_shape: float
    mu_rate: float
    lam_shape: float
    lam_rate: float
    sig_shape: float
    sig_rate: float
    delta: float  # max-lifetime rate multiplier
    nu: float     # scale-out-rate power-law exponent


#: Paper Table 1 — fitted to the Azure internal-jobs trace.
AZURE_PRIORS = PopulationPriors(
    mu_shape=0.3107, mu_rate=0.5778,
    lam_shape=0.4907, lam_rate=0.4496,
    sig_shape=0.2616, sig_rate=0.0552,
    delta=0.119, nu=0.673,
)


class DeploymentParams(NamedTuple):
    """True latent parameters of a batch of deployments. All fields [...]-shaped."""

    lam: jax.Array
    mu: jax.Array
    sig: jax.Array

    @property
    def scaleout_rate(self) -> jax.Array:
        """Poisson rate of scale-out events per hour (lam * mu**nu needs nu)."""
        raise AttributeError("use scaleout_rate(params, priors)")


def scaleout_rate(params: DeploymentParams, priors: PopulationPriors) -> jax.Array:
    """Scale-out events per hour: lam * mu**nu (paper §2.1)."""
    return params.lam * params.mu ** priors.nu


def sample_params(key: jax.Array, priors: PopulationPriors, shape=()) -> DeploymentParams:
    """Draw deployment parameters from the population priors.

    jax.random.gamma samples with unit rate; divide by the rate parameter.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    lam = jax.random.gamma(k1, priors.lam_shape, shape) / priors.lam_rate
    mu = jax.random.gamma(k2, priors.mu_shape, shape) / priors.mu_rate
    sig = jax.random.gamma(k3, priors.sig_shape, shape) / priors.sig_rate
    return DeploymentParams(lam=lam, mu=mu, sig=sig)


def sample_initial_size(key: jax.Array, params: DeploymentParams) -> jax.Array:
    """Initial core count C0 ~ 1 + Poisson(sig)."""
    return 1 + jax.random.poisson(key, params.sig)


def sample_scaleout_size(key: jax.Array, params: DeploymentParams) -> jax.Array:
    """Scale-out size ~ 1 + Poisson(sig)."""
    return 1 + jax.random.poisson(key, params.sig)


# ---------------------------------------------------------------------------
# Fast hybrid samplers for the simulator hot loop.
#
# jax.random.poisson / binomial run Knuth/rejection while-loops whose cost is
# set by the *slowest lane*; with heavy-tailed rates (lam * mu^nu spans 5+
# orders of magnitude across slots) nearly every step pays the worst case.
# The hybrids below draw the small-parameter lanes by CDF inversion from a
# single uniform (exact up to a < 1e-9 tail truncation) and route only the
# heavy lanes through the library sampler, whose loops then terminate in a
# few iterations because the small lanes are masked to zero.
# ---------------------------------------------------------------------------

_POIS_RMAX = 10.0   # inversion below jax's Knuth/rejection switch-over, so
                    # the library call's Knuth loop sees only zero lanes and
                    # exits immediately; P(Pois(10) > 42) ~ 6e-13
_POIS_KMAX = 42
_BIN_NMAX = 32.0    # inversion for n <= NMAX and p bounded away from 1
_BIN_PMAX = 0.95


def _poisson_ptrs(key: jax.Array, lam: jax.Array, active: jax.Array,
                  max_iters: int = 64) -> jax.Array:
    """Hörmann's transformed rejection (PTRS) for lam > 10.

    Lanes with ``active=False`` start accepted at 0, so the while-loop count
    is driven by the (typically few) genuinely heavy lanes — unlike the
    library sampler, which runs its rejection loop with a fake large rate for
    every small lane.
    """
    lam_s = jnp.where(active, lam, 100.0)
    log_lam = jnp.log(lam_s)
    b = 0.931 + 2.53 * jnp.sqrt(lam_s)
    a = -0.059 + 0.02483 * b
    inv_alpha = 1.1239 + 1.1328 / (b - 3.4)
    v_r = 0.9277 - 3.6224 / (b - 2.0)

    def body(carry):
        i, k_out, accepted, rng = carry
        rng, k0, k1 = jax.random.split(rng, 3)
        u = jax.random.uniform(k0, lam.shape) - 0.5
        v = jax.random.uniform(k1, lam.shape)
        us = 0.5 - jnp.abs(u)
        k = jnp.floor((2.0 * a / us + b) * u + lam_s + 0.43)
        s = jnp.log(v * inv_alpha / (a / (us * us) + b))
        t = -lam_s + k * log_lam - jax.lax.lgamma(k + 1.0)
        accept1 = (us >= 0.07) & (v <= v_r)
        reject = (k < 0.0) | ((us < 0.013) & (v > us))
        accept = accept1 | (~reject & (s <= t))
        k_out = jnp.where(~accepted & accept, k, k_out)
        return i + 1, k_out, accepted | accept, rng

    def cond(carry):
        i, _, accepted, _ = carry
        return jnp.any(~accepted) & (i < max_iters)

    init = (0, jnp.zeros_like(lam), ~active, key)
    return jax.lax.while_loop(cond, body, init)[1]


# Below this lane count the compact gather is pure overhead (measured
# crossover ~1-1.5k lanes single-run on CPU; batched/vmapped runs win from a
# few hundred); above it, the PTRS while-loop body runs on an 8x smaller
# buffer. Heavy lanes beyond the buffer (astronomically rare in the
# simulator's regime, where only a few slots have lam > 10) fall through to
# a full-width loop that exits after zero iterations when the mask is empty.
_PTRS_COMPACT_MIN = 1024
_PTRS_BUF_DIV = 8
_PTRS_BUF_MIN = 32


def _poisson_ptrs_compact(key: jax.Array, lam: jax.Array,
                          active: jax.Array) -> jax.Array:
    """Heavy-lane PTRS with rank-compaction (ROADMAP item).

    The rejection loop's per-iteration cost is O(lanes) even though only the
    few ``active`` (heavy) lanes matter; gathering them into a static
    ``n/_PTRS_BUF_DIV`` buffer first makes the loop body ~8x cheaper at
    large ``max_slots``. Scatter by cumulative rank (not ``jnp.nonzero``)
    keeps every op vmap/shard_map-friendly. Exact: overflow lanes — active
    lanes whose rank exceeds the buffer — run through the full-width loop,
    which starts fully-accepted and exits immediately when there are none.
    """
    n = lam.size
    buf = max(_PTRS_BUF_MIN, n // _PTRS_BUF_DIV)
    k_c, k_of = jax.random.split(key)
    flat_lam = lam.ravel()
    flat_act = active.ravel()
    cum = jnp.cumsum(flat_act.astype(jnp.int32))          # inclusive
    rank = cum - 1                                        # 0-based among active
    # gather-only compaction (XLA scatters serialize on CPU): the j-th active
    # lane's position is the first index where the running count reaches j
    idx_c = jnp.searchsorted(cum, jnp.arange(1, buf + 1, dtype=cum.dtype))
    lam_c = flat_lam[jnp.minimum(idx_c, n - 1)]
    n_active = cum[-1]
    act_c = jnp.arange(buf) < jnp.minimum(n_active, buf)
    out_c = _poisson_ptrs(k_c, lam_c, act_c)
    in_buf = flat_act & (rank < buf)
    res = jnp.where(in_buf, out_c[jnp.clip(rank, 0, buf - 1)], 0.0)
    overflow = flat_act & (rank >= buf)
    res = res + _poisson_ptrs(k_of, flat_lam, overflow)
    return res.reshape(lam.shape)


def fast_poisson(key: jax.Array, lam: jax.Array) -> jax.Array:
    """Poisson(lam) draws, float32; exact hybrid inversion/PTRS sampler."""
    k1, k2 = jax.random.split(key)
    small = lam <= _POIS_RMAX
    lam_s = jnp.where(small, lam, 0.0)
    u = jax.random.uniform(k1, lam.shape)
    pmf = jnp.exp(-lam_s)
    cdf = pmf
    k = jnp.zeros_like(lam)
    for j in range(1, _POIS_KMAX + 1):
        pmf = pmf * (lam_s / j)
        k = jnp.where(u > cdf, k + 1.0, k)
        cdf = cdf + pmf
    if lam.size >= _PTRS_COMPACT_MIN:
        big = _poisson_ptrs_compact(k2, lam, ~small)
    else:
        big = _poisson_ptrs(k2, lam, ~small)
    return jnp.where(small, k, big)


def fast_binomial(key: jax.Array, n: jax.Array, p: jax.Array) -> jax.Array:
    """Binomial(n, p) draws, float32; exact hybrid inversion/library sampler.

    Inversion iterates the pmf recurrence p_{j+1} = p_j (n-j)/(j+1) p/(1-p),
    so lanes with p ~ 1 (or large n) go through the library sampler instead.
    """
    k1, k2 = jax.random.split(key)
    n = n.astype(jnp.float32)
    # the inversion starts from pmf(0) = (1-p)^n; lanes where that would
    # underflow float32 (n log1p(-p) < ~-87, e.g. n~32 with p~0.95) would
    # deterministically return n — route them through the library sampler
    small = ((n <= _BIN_NMAX) & (p <= _BIN_PMAX)
             & (n * jnp.log1p(-jnp.minimum(p, _BIN_PMAX)) > -80.0))
    n_s = jnp.where(small, n, 0.0)
    p_s = jnp.where(small, p, 0.0)
    odds = p_s / (1.0 - p_s)
    u = jax.random.uniform(k1, jnp.broadcast_shapes(n.shape, p.shape))
    pmf = jnp.exp(n_s * jnp.log1p(-p_s))
    cdf = pmf
    k = jnp.zeros_like(n_s)
    kmax = int(_BIN_NMAX)
    for j in range(kmax):
        pmf = pmf * ((n_s - j) / (j + 1.0) * odds)
        pmf = jnp.maximum(pmf, 0.0)  # (n-j) < 0 once j >= n: pmf stays 0
        k = jnp.where(u > cdf, k + 1.0, k)
        cdf = cdf + pmf
    big = jax.random.binomial(k2, jnp.where(small, 0.0, n), p)
    return jnp.where(small, jnp.minimum(k, n_s), big.astype(jnp.float32))


class StepEvents(NamedTuple):
    """Events for one discretized step of length dt hours (per deployment)."""

    core_deaths: jax.Array     # cores shut down this step
    spont_death: jax.Array     # bool: deployment spontaneously shut down
    n_scaleouts: jax.Array     # number of scale-out requests
    scaleout_cores: jax.Array  # total cores requested across those scale-outs


def sample_step_events(
    key: jax.Array,
    params: DeploymentParams,
    cores: jax.Array,
    priors: PopulationPriors,
    dt: float,
    alive: jax.Array | None = None,
) -> StepEvents:
    """Sample one simulator step of the memoryless processes.

    * each active core dies w.p. 1 - exp(-mu*dt)            (exact thinning)
    * spontaneous death w.p.   1 - exp(-delta*mu*dt)        (memoryless => exact)
    * scale-outs ~ Poisson(lam * mu**nu * dt); total size = k + Poisson(k*sig)
      (a sum of k iid (1 + Poisson(sig)) draws).

    ``alive`` (optional bool mask) zeroes the event *rates* of dead slots
    before sampling. The simulator discards dead slots' events anyway, so
    this changes nothing downstream — but it keeps stale heavy-tailed
    parameters in dead slots from driving the samplers' worst-case cost.
    """
    kd, ks, ko, kz = jax.random.split(key, 4)
    alive_f = 1.0 if alive is None else alive.astype(jnp.float32)
    p_die = -jnp.expm1(-params.mu * dt)
    core_deaths = fast_binomial(kd, cores.astype(jnp.float32) * alive_f,
                                p_die).astype(cores.dtype)
    spont_death = jax.random.bernoulli(ks, -jnp.expm1(-priors.delta * params.mu * dt))
    n_scaleouts = fast_poisson(ko, scaleout_rate(params, priors) * dt * alive_f)
    extra = fast_poisson(kz, n_scaleouts * params.sig)
    scaleout_cores = n_scaleouts + extra
    return StepEvents(core_deaths, spont_death, n_scaleouts, scaleout_cores)


class PseudoObservations(NamedTuple):
    """k observations of each true scaling process (paper §6 "pseudo observations")."""

    n_lifetimes: jax.Array       # number of observed core lifetimes (== k)
    sum_lifetimes: jax.Array     # total observed lifetime hours
    n_windows: jax.Array         # unit-time windows observed for scale-outs (== k)
    n_scaleouts: jax.Array       # scale-outs observed in those windows
    n_sizes: jax.Array           # scale-out size observations
    sum_size_minus1: jax.Array   # sum of (size - 1)


def sample_pseudo_observations(
    key: jax.Array, params: DeploymentParams, priors: PopulationPriors, k: int
) -> PseudoObservations:
    """Draw k observations from each true process of each deployment.

    Matches the paper's "pseudo observation" interpretation of conjugate-prior
    posteriors: k exponential core lifetimes, k unit-window Poisson scale-out
    counts, and k scale-out sizes. ``params`` fields are [...]-shaped; outputs
    share that batch shape. k == 0 yields the uninformative update.
    """
    shape = params.mu.shape
    if k == 0:
        z = jnp.zeros(shape)
        return PseudoObservations(z, z, z, z, z, z)
    k1, k2, k3 = jax.random.split(key, 3)
    life = jax.random.exponential(k1, (k, *shape)) / params.mu
    counts = jax.random.poisson(k2, jnp.broadcast_to(scaleout_rate(params, priors), (k, *shape)))
    sizes_m1 = jax.random.poisson(k3, jnp.broadcast_to(params.sig, (k, *shape)))
    kf = jnp.full(shape, float(k))
    return PseudoObservations(
        n_lifetimes=kf,
        sum_lifetimes=life.sum(0),
        n_windows=kf,
        n_scaleouts=counts.sum(0).astype(jnp.float32),
        n_sizes=kf,
        sum_size_minus1=sizes_m1.sum(0).astype(jnp.float32),
    )
