"""Deployment stochastic processes (paper §2.1) and the fitted Azure priors.

A deployment x is described by latent parameters (lam, mu, sig):
  * core lifetime            ~ Exp(mu)               (rate, per hour)
  * max deployment lifetime  ~ Exp(delta * mu)       (spontaneous shutdown)
  * scale-out events         ~ Poisson(lam * mu**nu) (per hour)
  * scale-out size           ~ 1 + Poisson(sig)
  * initial size C0          ~ 1 + Poisson(sig)      (the arrival request)

Population priors are Gamma(shape, rate) fitted to the Azure trace of
Cortez et al. [2017] (paper Table 1). ``delta`` and ``nu`` are population-wide
constants. Time unit throughout the package: one hour.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class PopulationPriors(NamedTuple):
    """Gamma(shape, rate) hyperparameters for (mu, lam, sig) + global constants."""

    mu_shape: float
    mu_rate: float
    lam_shape: float
    lam_rate: float
    sig_shape: float
    sig_rate: float
    delta: float  # max-lifetime rate multiplier
    nu: float     # scale-out-rate power-law exponent


#: Paper Table 1 — fitted to the Azure internal-jobs trace.
AZURE_PRIORS = PopulationPriors(
    mu_shape=0.3107, mu_rate=0.5778,
    lam_shape=0.4907, lam_rate=0.4496,
    sig_shape=0.2616, sig_rate=0.0552,
    delta=0.119, nu=0.673,
)


class DeploymentParams(NamedTuple):
    """True latent parameters of a batch of deployments. All fields [...]-shaped."""

    lam: jax.Array
    mu: jax.Array
    sig: jax.Array

    @property
    def scaleout_rate(self) -> jax.Array:
        """Poisson rate of scale-out events per hour (lam * mu**nu needs nu)."""
        raise AttributeError("use scaleout_rate(params, priors)")


def scaleout_rate(params: DeploymentParams, priors: PopulationPriors) -> jax.Array:
    """Scale-out events per hour: lam * mu**nu (paper §2.1)."""
    return params.lam * params.mu ** priors.nu


def sample_params(key: jax.Array, priors: PopulationPriors, shape=()) -> DeploymentParams:
    """Draw deployment parameters from the population priors.

    jax.random.gamma samples with unit rate; divide by the rate parameter.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    lam = jax.random.gamma(k1, priors.lam_shape, shape) / priors.lam_rate
    mu = jax.random.gamma(k2, priors.mu_shape, shape) / priors.mu_rate
    sig = jax.random.gamma(k3, priors.sig_shape, shape) / priors.sig_rate
    return DeploymentParams(lam=lam, mu=mu, sig=sig)


def sample_initial_size(key: jax.Array, params: DeploymentParams) -> jax.Array:
    """Initial core count C0 ~ 1 + Poisson(sig)."""
    return 1 + jax.random.poisson(key, params.sig)


def sample_scaleout_size(key: jax.Array, params: DeploymentParams) -> jax.Array:
    """Scale-out size ~ 1 + Poisson(sig)."""
    return 1 + jax.random.poisson(key, params.sig)


class StepEvents(NamedTuple):
    """Events for one discretized step of length dt hours (per deployment)."""

    core_deaths: jax.Array     # cores shut down this step
    spont_death: jax.Array     # bool: deployment spontaneously shut down
    n_scaleouts: jax.Array     # number of scale-out requests
    scaleout_cores: jax.Array  # total cores requested across those scale-outs


def sample_step_events(
    key: jax.Array,
    params: DeploymentParams,
    cores: jax.Array,
    priors: PopulationPriors,
    dt: float,
) -> StepEvents:
    """Sample one simulator step of the memoryless processes.

    * each active core dies w.p. 1 - exp(-mu*dt)            (exact thinning)
    * spontaneous death w.p.   1 - exp(-delta*mu*dt)        (memoryless => exact)
    * scale-outs ~ Poisson(lam * mu**nu * dt); total size = k + Poisson(k*sig)
      (a sum of k iid (1 + Poisson(sig)) draws).
    """
    kd, ks, ko, kz = jax.random.split(key, 4)
    p_die = -jnp.expm1(-params.mu * dt)
    core_deaths = jax.random.binomial(kd, cores.astype(jnp.float32), p_die).astype(cores.dtype)
    spont_death = jax.random.bernoulli(ks, -jnp.expm1(-priors.delta * params.mu * dt))
    n_scaleouts = jax.random.poisson(ko, scaleout_rate(params, priors) * dt)
    extra = jax.random.poisson(kz, n_scaleouts * params.sig)
    scaleout_cores = n_scaleouts + extra
    return StepEvents(core_deaths, spont_death, n_scaleouts, scaleout_cores)


class PseudoObservations(NamedTuple):
    """k observations of each true scaling process (paper §6 "pseudo observations")."""

    n_lifetimes: jax.Array       # number of observed core lifetimes (== k)
    sum_lifetimes: jax.Array     # total observed lifetime hours
    n_windows: jax.Array         # unit-time windows observed for scale-outs (== k)
    n_scaleouts: jax.Array       # scale-outs observed in those windows
    n_sizes: jax.Array           # scale-out size observations
    sum_size_minus1: jax.Array   # sum of (size - 1)


def sample_pseudo_observations(
    key: jax.Array, params: DeploymentParams, priors: PopulationPriors, k: int
) -> PseudoObservations:
    """Draw k observations from each true process of each deployment.

    Matches the paper's "pseudo observation" interpretation of conjugate-prior
    posteriors: k exponential core lifetimes, k unit-window Poisson scale-out
    counts, and k scale-out sizes. ``params`` fields are [...]-shaped; outputs
    share that batch shape. k == 0 yields the uninformative update.
    """
    shape = params.mu.shape
    if k == 0:
        z = jnp.zeros(shape)
        return PseudoObservations(z, z, z, z, z, z)
    k1, k2, k3 = jax.random.split(key, 3)
    life = jax.random.exponential(k1, (k, *shape)) / params.mu
    counts = jax.random.poisson(k2, jnp.broadcast_to(scaleout_rate(params, priors), (k, *shape)))
    sizes_m1 = jax.random.poisson(k3, jnp.broadcast_to(params.sig, (k, *shape)))
    kf = jnp.full(shape, float(k))
    return PseudoObservations(
        n_lifetimes=kf,
        sum_lifetimes=life.sum(0),
        n_windows=kf,
        n_scaleouts=counts.sum(0).astype(jnp.float32),
        n_sizes=kf,
        sum_size_minus1=sizes_m1.sum(0).astype(jnp.float32),
    )
