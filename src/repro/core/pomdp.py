"""The constrained POMDP statement of the cluster admission problem (paper §2.2).

This module keeps the *formal* objects so the rest of the package can be read
against the paper:

  POMDP (S, A, R, T, Omega, O):
    * state s: all active deployments with true (C, lam, mu, sig) + arrivals
      -> in code: ``sim.simulator.SimState`` (slot arrays of true params)
    * action a: accept/reject each arrival  -> ``policies.admit_sequential``
    * reward R(s) = sum_x C^x               -> ``sim.metrics`` utilization
    * transition T: the processes of ``core.processes``
    * observation O: deployment sizes only (deterministic, many-to-one)
      -> the belief state ``core.belief.GammaBelief`` (conjugate posteriors)
    * constraint: expected scale-out failure fraction <= tau in every safe
      belief state (Problem 1, Eqs. (2)-(4)); in unsafe states the policy must
      reject all arrivals (Eq. (3)) -- the moment policies implement this
      implicitly because their admission condition already fails, and Def. 4's
      marginal heuristic is the sanctioned carve-out.

Under Assumptions 1-3 the constraint reduces (Prop. 1 / Cor. 1) to

    Pr( sum_x L_n^x > c ) <= tau  for all horizon points n,

which the moment policies bound via Markov / Cantelli. ``failure_bound`` below
exposes that reduced quantity for analysis and tests.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SLAConfig(NamedTuple):
    tau: float = 1e-4          # paper §5.2: SLA of 0.01%
    capacity: int = 20_000     # paper §5.2 cluster size


def markov_bound(agg_el: jax.Array, capacity) -> jax.Array:
    """Markov's inequality (11): Pr(L >= c) <= E[L]/c, per horizon point."""
    return agg_el / capacity


def cantelli_bound(agg_el: jax.Array, agg_vl: jax.Array, capacity) -> jax.Array:
    """Cantelli's inequality (18) at eps = c - E[L] (paper §4.3); 1 when the
    mean already exceeds capacity."""
    slack = capacity - agg_el
    bound = agg_vl / (agg_vl + jnp.maximum(slack, 0.0) ** 2 + 1e-30)
    return jnp.where(slack > 0.0, bound, 1.0)


def failure_bound(agg_el: jax.Array, agg_vl: jax.Array, capacity) -> jax.Array:
    """Best available upper bound on Pr(sum L_n > c) per horizon point —
    min of the Markov and Cantelli bounds (both are valid)."""
    return jnp.minimum(markov_bound(agg_el, capacity),
                       cantelli_bound(agg_el, agg_vl, capacity))
