"""Closed-form moment curves E[L_t], V[L_t] of a deployment's future size.

This is the computational heart of the paper (Props. 2, 3, 5): under the
provider's Gamma belief (a,b)=(mu_a,mu_b), (al,bl)=(lam_a,lam_b),
(as,bs)=(sig_a,sig_b) for a deployment with C active cores, the future size is

    L_t = M_t * D_t * (Q_t + B_t)

with (paper §4) B_t = surviving initial cores, Q_t = surviving scale-out cores,
M_t = max-lifetime survival, D_t = "has not died from zero cores". Factors are
treated as uncorrelated (the paper's stated approximation).

Two evaluation paths are provided:

* ``moment_curves`` — **continuous-time closed forms** (re-derived; DESIGN.md
  §4). Every horizon point costs O(1) (no inner sum over past steps), so a full
  curve over an *arbitrary* (e.g. geometric) grid is O(N). This is the
  optimized, beyond-paper formulation and the oracle for the Pallas kernel.

* ``moment_curves_discrete`` — the **paper-faithful** uniform-grid formulation
  (Poisson counts per step, Prop. 5 sums), evaluated for all n=1..N at once in
  O(N) total via prefix sums (the paper evaluates each n in O(n), i.e. O(N²)
  per curve). ``moment_curves_discrete_naive`` is the direct O(N²)/O(N³)
  transcription used as a test oracle for the prefix-sum indexing.

Key Gamma integrals (mu ~ Gamma(a, b), rate parameterization):

    g(p, t) = E[mu^p e^(-t mu)]        = R(p) b^-p (1 + t/b)^-(a+p)
    H(p, t) = E[mu^p (1 - e^(-t mu))]  = R(p) b^-p (1 - (1+t/b)^-(a+p))
    K(p, t) = E[mu^p (1 - e^(-t mu))²] = R(p) b^-p (1 - 2(1+t/b)^-(a+p)
                                                      + (1+2t/b)^-(a+p))
    R(p)    = Gamma(a+p)/Gamma(a)

H and K stay valid by analytic continuation for a+p < 0 (the case for the
fitted Azure priors, where a + nu - 1 = -0.0163): we evaluate them through
``exp(gammaln(a+p+1) - gammaln(a)) / (a+p)`` and ``expm1`` so the removable
singularity at a+p = 0 never produces a NaN.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import gammaln

from .belief import GammaBelief
from .processes import PopulationPriors

_EPS = 1e-12


class MomentCurves(NamedTuple):
    """E and V of L over the horizon grid; shapes [..., N]."""

    EL: jax.Array
    VL: jax.Array


# ---------------------------------------------------------------------------
# Gamma-integral helpers. All take a, b with trailing broadcast vs t.
# ---------------------------------------------------------------------------

def _g(a, b, p, t):
    """E[mu^p e^(-t mu)]; requires a + p > 0 (true for p in {0, nu, 2nu})."""
    logr = gammaln(a + p) - gammaln(a)
    return jnp.exp(logr - p * jnp.log(b) - (a + p) * jnp.log1p(t / b))


def _h(a, b, p, t):
    """E[mu^p (1 - e^(-t mu))], valid for a + p > -1 (analytic continuation)."""
    z = a + p
    z = jnp.where(jnp.abs(z) < _EPS, _EPS, z)
    logr1 = gammaln(z + 1.0) - gammaln(a)  # log Gamma(a+p+1)/Gamma(a), arg > 0
    bracket = -jnp.expm1(-z * jnp.log1p(t / b))
    return jnp.exp(logr1 - p * jnp.log(b)) * bracket / z


def _k(a, b, p, t):
    """E[mu^p (1 - e^(-t mu))²], valid for a + p > -2 if a + 2p' terms converge."""
    z = a + p
    z = jnp.where(jnp.abs(z) < _EPS, _EPS, z)
    logr1 = gammaln(z + 1.0) - gammaln(a)
    l1 = jnp.log1p(t / b)
    l2 = jnp.log1p(2.0 * t / b)
    bracket = -2.0 * jnp.expm1(-z * l1) + jnp.expm1(-z * l2)
    return jnp.exp(logr1 - p * jnp.log(b)) * bracket / z


def _sigma_moments(bel: GammaBelief):
    """E[sigma+1], E[(sigma+1)^2], E[sigma(sigma+2)] under Gamma(as, bs)."""
    es = bel.sig_a / bel.sig_b
    es2 = bel.sig_a * (bel.sig_a + 1.0) / bel.sig_b**2
    e_s1 = es + 1.0
    e_s1_sq = es2 + 2.0 * es + 1.0
    e_ss2 = es2 + 2.0 * es
    return e_s1, e_s1_sq, e_ss2


def _lam_moments(bel: GammaBelief):
    el = bel.lam_a / bel.lam_b
    el2 = bel.lam_a * (bel.lam_a + 1.0) / bel.lam_b**2
    return el, el2


def _product_var(ex, vx, ey, vy):
    """V[XY] for independent X, Y."""
    return vx * vy + vx * ey**2 + ex**2 * vy


# ---------------------------------------------------------------------------
# D-term: probability the deployment has not hit zero cores (paper Prop. 2).
#
# The paper's recursion (16)-(17) multiplies, per step j, the probability that
# not every core is dead:  1 - (1-P(t_j))^C * prod_{i<j} (1-P(t_j-t_i))^{q_i}
# with P(t) = E[e^(-t mu)] (Lomax survival) and q_i the expected cores added
# in window i. On a *uniform* checkpoint grid the elapsed time t_j - t_i
# depends only on the lag j-i, so the inner product is a single cumulative sum
# over lags — O(Nd) for the whole curve instead of the paper's O(Nd²).
# ---------------------------------------------------------------------------

def _d_curve_uniform(a, b, eu, e_mu_nu, cores, w, nd: int, *, midpoint: bool):
    """E[D] at uniform checkpoints t_j = w*j, j=1..nd. Leading dims broadcast.

    midpoint=False reproduces the paper exactly (windows i < j, elapsed
    (j-i)*w). midpoint=True also counts the current window at half-window
    elapsed time — the midpoint-rule variant used by the continuous path so a
    coarse checkpoint grid does not spuriously kill young deployments.
    """
    q = eu * e_mu_nu  # expected cores added per hour
    lags = jnp.arange(nd, dtype=w.dtype if hasattr(w, "dtype") else jnp.float32)
    if midpoint:
        tau = w * (lags + 0.5)              # l = 0..nd-1
    else:
        tau = w * (lags + 1.0)              # l = 1..nd-1 used (see shift below)
    p_lag = jnp.exp(-a[..., None] * jnp.log1p(tau / b[..., None]))
    s = (q * w)[..., None] * jnp.log1p(-jnp.clip(p_lag, None, 1.0 - 1e-7))
    cums = jnp.cumsum(s, axis=-1)
    if midpoint:
        # sum over lags 0..j-1 -> cums[j-1]
        window_sum = cums
    else:
        # sum over lags 1..j-1 -> shift right by one (0 for j=1)
        window_sum = jnp.concatenate(
            [jnp.zeros_like(cums[..., :1]), cums[..., :-1]], axis=-1
        )
    tc = w * jnp.arange(1, nd + 1)
    p_self = jnp.exp(-a[..., None] * jnp.log1p(tc / b[..., None]))
    log_dead = (
        cores[..., None] * jnp.log1p(-jnp.clip(p_self, None, 1.0 - 1e-7))
        + window_sum
    )
    factor = -jnp.expm1(log_dead)  # 1 - Pr(all cores dead at t_j)
    return jnp.cumprod(factor, axis=-1)


def _interp_rows(t_full, ts, ys):
    """Piecewise-linear interp of per-slot curves ys [..., Nd] from grid ts [Nd]
    (with implicit (0, 1) left anchor) onto t_full [N]."""
    ts0 = jnp.concatenate([jnp.zeros((1,), ts.dtype), ts])
    ones = jnp.ones(ys.shape[:-1] + (1,), ys.dtype)
    ys0 = jnp.concatenate([ones, ys], axis=-1)
    flat = ys0.reshape((-1, ys0.shape[-1]))
    out = jax.vmap(lambda row: jnp.interp(t_full, ts0, row))(flat)
    return out.reshape(ys.shape[:-1] + (t_full.shape[-1],))


# ---------------------------------------------------------------------------
# Continuous-time closed forms (optimized path; DESIGN.md §4).
# ---------------------------------------------------------------------------

def moment_curves(
    bel: GammaBelief,
    cores: jax.Array,
    t_grid: jax.Array,
    priors: PopulationPriors,
    *,
    d_points: int = 32,
    d_stride: int | None = None,  # legacy alias: d_points = N // d_stride
) -> MomentCurves:
    """E[L_t], V[L_t] at horizon times ``t_grid`` [N] (hours from now).

    ``bel`` fields and ``cores`` share a batch shape [...]; output [..., N].
    ``d_points``: the D-term (zero-core death) runs on a uniform checkpoint
    grid of this many points spanning (0, max(t_grid)] and is linearly
    interpolated onto ``t_grid``.
    """
    nu = priors.nu
    a, b = bel.mu_a[..., None], bel.mu_b[..., None]
    el, el2 = _lam_moments(bel)
    e_s1, e_s1_sq, e_ss2 = _sigma_moments(bel)
    eu = el * e_s1
    eu2 = el2 * e_s1_sq
    t = t_grid
    c = cores[..., None].astype(t_grid.dtype)

    # --- Q: scale-out cores still alive -----------------------------------
    h1 = _h(a, b, nu - 1.0, t)
    eq = eu[..., None] * h1
    evq = el[..., None] * (e_s1[..., None] * h1 + 0.5 * e_ss2[..., None] * _h(a, b, nu - 1.0, 2.0 * t))
    veq = eu2[..., None] * _k(a, b, 2.0 * nu - 2.0, t) - eq**2
    vq = evq + jnp.maximum(veq, 0.0)

    # --- B: initial cores still alive --------------------------------------
    p1 = _g(a, b, 0.0, t)
    p2 = _g(a, b, 0.0, 2.0 * t)
    ebn = c * p1
    vb = c * (p1 - p2) + c**2 * jnp.maximum(p2 - p1**2, 0.0)

    # --- M: max-lifetime survival ------------------------------------------
    em = jnp.exp(-a * jnp.log1p(priors.delta * t / b))
    vm = em * (1.0 - em)

    # --- D: zero-core death ------------------------------------------------
    if d_stride is not None:
        d_points = max(4, t_grid.shape[-1] // d_stride)
    e_mu_nu = bel.expected_mu_pow(nu)
    w = t_grid[-1] / d_points
    ed_sub = _d_curve_uniform(bel.mu_a, bel.mu_b, eu, e_mu_nu,
                              cores.astype(t_grid.dtype), w, d_points,
                              midpoint=True)
    tc = w * jnp.arange(1, d_points + 1)
    ed = _interp_rows(t_grid, tc, ed_sub)
    vd = ed * (1.0 - ed)

    # --- compose L = M * D * (Q + B) ---------------------------------------
    er = eq + ebn
    vr = vq + vb
    edr = ed * er
    vdr = _product_var(ed, vd, er, vr)
    elc = em * edr
    vl = _product_var(em, vm, edr, vdr)
    return MomentCurves(EL=elc, VL=vl)


# ---------------------------------------------------------------------------
# Paper-faithful discrete formulation (Prop. 5 sums via prefix sums).
# ---------------------------------------------------------------------------

def moment_curves_discrete(
    bel: GammaBelief,
    cores: jax.Array,
    n_steps: int,
    dt: float,
    priors: PopulationPriors,
    **_legacy,
) -> MomentCurves:
    """Uniform-grid curves at t = dt*(1..n_steps), per the paper's Prop. 5.

    Scale-outs are Poisson *per step* (count ~ Pois(lam mu^nu dt)); a core
    added in step i survives to step n w.p. e^(-(n-i) dt mu). All n evaluated
    simultaneously with prefix sums (O(N) total instead of the paper's O(N²)).
    """
    nu = priors.nu
    a, b = bel.mu_a[..., None], bel.mu_b[..., None]
    el, el2 = _lam_moments(bel)
    e_s1, e_s1_sq, e_ss2 = _sigma_moments(bel)
    eu, eu2 = el * e_s1, el2 * e_s1_sq

    n = n_steps
    d = jnp.arange(n, dtype=jnp.float32)       # elapsed steps n - i = 0..n-1
    s = jnp.arange(2 * n - 1, dtype=jnp.float32)
    g1 = _g(a, b, nu, d * dt)                  # [..., n]
    g2 = _g(a, b, nu, 2.0 * d * dt)
    g3 = _g(a, b, 2.0 * nu, s * dt)            # [..., 2n-1]

    cs1 = jnp.cumsum(g1, axis=-1)              # sum_{d=0}^{m} g1
    cs2 = jnp.cumsum(g2, axis=-1)
    a3 = jnp.cumsum(g3, axis=-1)
    b3 = jnp.cumsum(s * g3, axis=-1)

    nn = jnp.arange(1, n + 1, dtype=jnp.float32)
    i_nm1 = jnp.arange(0, n)                   # index n-1
    i_2nm2 = jnp.arange(0, 2 * n, 2)           # index 2n-2

    ew = jnp.take(cs1, i_nm1, axis=-1)
    eq = eu[..., None] * dt * ew
    evq = el[..., None] * dt * (
        e_s1[..., None] * jnp.take(cs1, i_nm1, axis=-1)
        + e_ss2[..., None] * jnp.take(cs2, i_nm1, axis=-1)
    )
    # E[W_n^2] = sum_{s=0}^{2n-2} min(s+1, 2n-1-s) g3(s)
    a_n = jnp.take(a3, i_nm1, axis=-1)
    b_n = jnp.take(b3, i_nm1, axis=-1)
    a_2n = jnp.take(a3, i_2nm2, axis=-1)
    b_2n = jnp.take(b3, i_2nm2, axis=-1)
    ew2 = (b_n + a_n) + ((2.0 * nn - 1.0) * (a_2n - a_n) - (b_2n - b_n))
    veq = eu2[..., None] * dt**2 * ew2 - (eu[..., None] * dt * ew) ** 2
    vq = evq + jnp.maximum(veq, 0.0)

    t = nn * dt
    c = cores[..., None].astype(jnp.float32)
    p1 = _g(a, b, 0.0, t)
    p2 = _g(a, b, 0.0, 2.0 * t)
    ebn = c * p1
    vb = c * (p1 - p2) + c**2 * jnp.maximum(p2 - p1**2, 0.0)
    em = jnp.exp(-a * jnp.log1p(priors.delta * t / b))
    vm = em * (1.0 - em)

    # Paper-exact D recursion on the uniform step grid (lag-cumsum, O(N)).
    e_mu_nu = bel.expected_mu_pow(nu)
    ed = _d_curve_uniform(bel.mu_a, bel.mu_b, eu, e_mu_nu,
                          cores.astype(jnp.float32), jnp.float32(dt), n,
                          midpoint=False)
    vd = ed * (1.0 - ed)

    er = eq + ebn
    vr = vq + vb
    edr = ed * er
    vdr = _product_var(ed, vd, er, vr)
    elc = em * edr
    vl = _product_var(em, vm, edr, vdr)
    return MomentCurves(EL=elc, VL=vl)


def moment_curves_discrete_naive(
    bel_np, cores, n_steps: int, dt: float, priors: PopulationPriors
) -> MomentCurves:
    """Direct O(N²) numpy transcription of the discrete sums — test oracle.

    ``bel_np``: GammaBelief of scalar floats; ``cores``: scalar.
    """
    from math import lgamma

    a, b = float(bel_np.mu_a), float(bel_np.mu_b)
    al, bl = float(bel_np.lam_a), float(bel_np.lam_b)
    asg, bsg = float(bel_np.sig_a), float(bel_np.sig_b)
    nu, delta = priors.nu, priors.delta

    def g(p, tau):
        return np.exp(lgamma(a + p) - lgamma(a) - p * np.log(b) - (a + p) * np.log1p(tau / b))

    el = al / bl
    el2 = al * (al + 1) / bl**2
    es = asg / bsg
    es2 = asg * (asg + 1) / bsg**2
    e_s1, e_s1_sq, e_ss2 = es + 1, es2 + 2 * es + 1, es2 + 2 * es
    eu, eu2 = el * e_s1, el2 * e_s1_sq
    e_mu_nu = g(nu, 0.0)

    n_arr = np.arange(1, n_steps + 1)
    eq = np.zeros(n_steps); vq = np.zeros(n_steps)
    ebv = np.zeros(n_steps); vb = np.zeros(n_steps)
    em = np.zeros(n_steps); ed = np.zeros(n_steps)
    for ni, n in enumerate(n_arr):
        ew = sum(g(nu, (n - i) * dt) for i in range(1, n + 1))
        eq[ni] = eu * dt * ew
        evq = el * dt * sum(
            e_s1 * g(nu, (n - i) * dt) + e_ss2 * g(nu, 2 * (n - i) * dt)
            for i in range(1, n + 1)
        )
        ew2 = sum(
            g(2 * nu, (2 * n - i - j) * dt)
            for i in range(1, n + 1) for j in range(1, n + 1)
        )
        veq = eu2 * dt**2 * ew2 - (eu * dt * ew) ** 2
        vq[ni] = evq + max(veq, 0.0)
        t = n * dt
        p1, p2 = g(0.0, t), g(0.0, 2 * t)
        ebv[ni] = cores * p1
        vb[ni] = cores * (p1 - p2) + cores**2 * max(p2 - p1**2, 0.0)
        em[ni] = np.exp(-a * np.log1p(delta * t / b))

    # D recursion, paper (16)-(17) on the uniform grid
    ed_prev = 1.0
    q_step = eu * e_mu_nu * dt
    for ni, n in enumerate(n_arr):
        p_self = g(0.0, n * dt)
        log_dead = cores * np.log1p(-min(p_self, 1 - 1e-7))
        for i in range(1, n):
            pij = g(0.0, (n - i) * dt)
            log_dead += q_step * np.log1p(-min(pij, 1 - 1e-7))
        factor = -np.expm1(log_dead)
        ed[ni] = (ed_prev if ni else 1.0) * factor
        ed_prev = ed[ni]

    vm = em * (1 - em)
    vd = ed * (1 - ed)
    er, vr = eq + ebv, vq + vb
    edr = ed * er
    vdr = vd * vr + vd * er**2 + ed**2 * vr
    elc = em * edr
    vl = vm * vdr + vm * edr**2 + em**2 * vdr
    return MomentCurves(EL=elc, VL=vl)
