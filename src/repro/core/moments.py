"""Closed-form moment curves E[L_t], V[L_t] of a deployment's future size.

This is the computational heart of the paper (Props. 2, 3, 5): under the
provider's Gamma belief (a,b)=(mu_a,mu_b), (al,bl)=(lam_a,lam_b),
(as,bs)=(sig_a,sig_b) for a deployment with C active cores, the future size is

    L_t = M_t * D_t * (Q_t + B_t)

with (paper §4) B_t = surviving initial cores, Q_t = surviving scale-out cores,
M_t = max-lifetime survival, D_t = "has not died from zero cores". Factors are
treated as uncorrelated (the paper's stated approximation).

Two evaluation paths are provided:

* ``moment_curves`` — **continuous-time closed forms** (re-derived; DESIGN.md
  §4). Every horizon point costs O(1) (no inner sum over past steps), so a full
  curve over an *arbitrary* (e.g. geometric) grid is O(N). This is the
  optimized, beyond-paper formulation and the oracle for the Pallas kernel.

* ``moment_curves_discrete`` — the **paper-faithful** uniform-grid formulation
  (Poisson counts per step, Prop. 5 sums), evaluated for all n=1..N at once in
  O(N) total via prefix sums (the paper evaluates each n in O(n), i.e. O(N²)
  per curve). ``moment_curves_discrete_naive`` is the direct O(N²)/O(N³)
  transcription used as a test oracle for the prefix-sum indexing.

Key Gamma integrals (mu ~ Gamma(a, b), rate parameterization):

    g(p, t) = E[mu^p e^(-t mu)]        = R(p) b^-p (1 + t/b)^-(a+p)
    H(p, t) = E[mu^p (1 - e^(-t mu))]  = R(p) b^-p (1 - (1+t/b)^-(a+p))
    K(p, t) = E[mu^p (1 - e^(-t mu))²] = R(p) b^-p (1 - 2(1+t/b)^-(a+p)
                                                      + (1+2t/b)^-(a+p))
    R(p)    = Gamma(a+p)/Gamma(a)

H and K stay valid by analytic continuation for a+p < 0 (the case for the
fitted Azure priors, where a + nu - 1 = -0.0163): we evaluate them through
``exp(gammaln(a+p+1) - gammaln(a)) / (a+p)`` and ``expm1`` so the removable
singularity at a+p = 0 never produces a NaN.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.special import gammaln

from .belief import GammaBelief
from .processes import PopulationPriors

_EPS = 1e-12


class MomentCurves(NamedTuple):
    """E and V of L over the horizon grid; shapes [..., N]."""

    EL: jax.Array
    VL: jax.Array


# ---------------------------------------------------------------------------
# Gamma-integral helpers. All take a, b with trailing broadcast vs t.
# ---------------------------------------------------------------------------

def _g(a, b, p, t):
    """E[mu^p e^(-t mu)]; requires a + p > 0 (true for p in {0, nu, 2nu})."""
    logr = gammaln(a + p) - gammaln(a)
    return jnp.exp(logr - p * jnp.log(b) - (a + p) * jnp.log1p(t / b))


def _h(a, b, p, t):
    """E[mu^p (1 - e^(-t mu))], valid for a + p > -1 (analytic continuation)."""
    z = a + p
    z = jnp.where(jnp.abs(z) < _EPS, _EPS, z)
    logr1 = gammaln(z + 1.0) - gammaln(a)  # log Gamma(a+p+1)/Gamma(a), arg > 0
    bracket = -jnp.expm1(-z * jnp.log1p(t / b))
    return jnp.exp(logr1 - p * jnp.log(b)) * bracket / z


def _k(a, b, p, t):
    """E[mu^p (1 - e^(-t mu))²], valid for a + p > -2 if a + 2p' terms converge."""
    z = a + p
    z = jnp.where(jnp.abs(z) < _EPS, _EPS, z)
    logr1 = gammaln(z + 1.0) - gammaln(a)
    l1 = jnp.log1p(t / b)
    l2 = jnp.log1p(2.0 * t / b)
    bracket = -2.0 * jnp.expm1(-z * l1) + jnp.expm1(-z * l2)
    return jnp.exp(logr1 - p * jnp.log(b)) * bracket / z


def _sigma_moments(bel: GammaBelief):
    """E[sigma+1], E[(sigma+1)^2], E[sigma(sigma+2)] under Gamma(as, bs)."""
    es = bel.sig_a / bel.sig_b
    es2 = bel.sig_a * (bel.sig_a + 1.0) / bel.sig_b**2
    e_s1 = es + 1.0
    e_s1_sq = es2 + 2.0 * es + 1.0
    e_ss2 = es2 + 2.0 * es
    return e_s1, e_s1_sq, e_ss2


def _lam_moments(bel: GammaBelief):
    el = bel.lam_a / bel.lam_b
    el2 = bel.lam_a * (bel.lam_a + 1.0) / bel.lam_b**2
    return el, el2


def _product_var(ex, vx, ey, vy):
    """V[XY] for independent X, Y."""
    return vx * vy + vx * ey**2 + ex**2 * vy


# ---------------------------------------------------------------------------
# D-term: probability the deployment has not hit zero cores (paper Prop. 2).
#
# The paper's recursion (16)-(17) multiplies, per step j, the probability that
# not every core is dead:  1 - (1-P(t_j))^C * prod_{i<j} (1-P(t_j-t_i))^{q_i}
# with P(t) = E[e^(-t mu)] (Lomax survival) and q_i the expected cores added
# in window i. On a *uniform* checkpoint grid the elapsed time t_j - t_i
# depends only on the lag j-i, so the inner product is a single cumulative sum
# over lags — O(Nd) for the whole curve instead of the paper's O(Nd²).
# ---------------------------------------------------------------------------

def _d_curve_uniform(a, b, eu, e_mu_nu, cores, w, nd: int, *, midpoint: bool):
    """E[D] at uniform checkpoints t_j = w*j, j=1..nd. Leading dims broadcast.

    midpoint=False reproduces the paper exactly (windows i < j, elapsed
    (j-i)*w). midpoint=True also counts the current window at half-window
    elapsed time — the midpoint-rule variant used by the continuous path so a
    coarse checkpoint grid does not spuriously kill young deployments.
    """
    q = eu * e_mu_nu  # expected cores added per hour
    lags = jnp.arange(nd, dtype=w.dtype if hasattr(w, "dtype") else jnp.float32)
    if midpoint:
        tau = w * (lags + 0.5)              # l = 0..nd-1
    else:
        tau = w * (lags + 1.0)              # l = 1..nd-1 used (see shift below)
    p_lag = jnp.exp(-a[..., None] * jnp.log1p(tau / b[..., None]))
    s = (q * w)[..., None] * jnp.log1p(-jnp.clip(p_lag, None, 1.0 - 1e-7))
    cums = jnp.cumsum(s, axis=-1)
    if midpoint:
        # sum over lags 0..j-1 -> cums[j-1]
        window_sum = cums
    else:
        # sum over lags 1..j-1 -> shift right by one (0 for j=1)
        window_sum = jnp.concatenate(
            [jnp.zeros_like(cums[..., :1]), cums[..., :-1]], axis=-1
        )
    tc = w * jnp.arange(1, nd + 1)
    p_self = jnp.exp(-a[..., None] * jnp.log1p(tc / b[..., None]))
    log_dead = (
        cores[..., None] * jnp.log1p(-jnp.clip(p_self, None, 1.0 - 1e-7))
        + window_sum
    )
    factor = -jnp.expm1(log_dead)  # 1 - Pr(all cores dead at t_j)
    return jnp.cumprod(factor, axis=-1)


def _interp_rows(t_full, ts, ys):
    """Piecewise-linear interp of per-slot curves ys [..., Nd] from grid ts [Nd]
    (with implicit (0, 1) left anchor) onto t_full [N]."""
    ts0 = jnp.concatenate([jnp.zeros((1,), ts.dtype), ts])
    ones = jnp.ones(ys.shape[:-1] + (1,), ys.dtype)
    ys0 = jnp.concatenate([ones, ys], axis=-1)
    flat = ys0.reshape((-1, ys0.shape[-1]))
    out = jax.vmap(lambda row: jnp.interp(t_full, ts0, row))(flat)
    return out.reshape(ys.shape[:-1] + (t_full.shape[-1],))


# ---------------------------------------------------------------------------
# Continuous-time closed forms (optimized path; DESIGN.md §4).
# ---------------------------------------------------------------------------

def moment_curves(
    bel: GammaBelief,
    cores: jax.Array,
    t_grid: jax.Array,
    priors: PopulationPriors,
    *,
    d_points: int = 32,
    d_stride: int | None = None,  # legacy alias: d_points = N // d_stride
) -> MomentCurves:
    """E[L_t], V[L_t] at horizon times ``t_grid`` [N] (hours from now).

    ``bel`` fields and ``cores`` share a batch shape [...]; output [..., N].
    ``d_points``: the D-term (zero-core death) runs on a uniform checkpoint
    grid of this many points spanning (0, max(t_grid)] and is linearly
    interpolated onto ``t_grid``.
    """
    nu = priors.nu
    a, b = bel.mu_a[..., None], bel.mu_b[..., None]
    el, el2 = _lam_moments(bel)
    e_s1, e_s1_sq, e_ss2 = _sigma_moments(bel)
    eu = el * e_s1
    eu2 = el2 * e_s1_sq
    t = t_grid
    c = cores[..., None].astype(t_grid.dtype)

    # --- Q: scale-out cores still alive -----------------------------------
    h1 = _h(a, b, nu - 1.0, t)
    eq = eu[..., None] * h1
    evq = el[..., None] * (e_s1[..., None] * h1 + 0.5 * e_ss2[..., None] * _h(a, b, nu - 1.0, 2.0 * t))
    veq = eu2[..., None] * _k(a, b, 2.0 * nu - 2.0, t) - eq**2
    vq = evq + jnp.maximum(veq, 0.0)

    # --- B: initial cores still alive --------------------------------------
    p1 = _g(a, b, 0.0, t)
    p2 = _g(a, b, 0.0, 2.0 * t)
    ebn = c * p1
    vb = c * (p1 - p2) + c**2 * jnp.maximum(p2 - p1**2, 0.0)

    # --- M: max-lifetime survival ------------------------------------------
    em = jnp.exp(-a * jnp.log1p(priors.delta * t / b))
    vm = em * (1.0 - em)

    # --- D: zero-core death ------------------------------------------------
    if d_stride is not None:
        d_points = max(4, t_grid.shape[-1] // d_stride)
    e_mu_nu = bel.expected_mu_pow(nu)
    w = t_grid[-1] / d_points
    ed_sub = _d_curve_uniform(bel.mu_a, bel.mu_b, eu, e_mu_nu,
                              cores.astype(t_grid.dtype), w, d_points,
                              midpoint=True)
    tc = w * jnp.arange(1, d_points + 1)
    ed = _interp_rows(t_grid, tc, ed_sub)
    vd = ed * (1.0 - ed)

    # --- compose L = M * D * (Q + B) ---------------------------------------
    er = eq + ebn
    vr = vq + vb
    edr = ed * er
    vdr = _product_var(ed, vd, er, vr)
    elc = em * edr
    vl = _product_var(em, vm, edr, vdr)
    return MomentCurves(EL=elc, VL=vl)


# ---------------------------------------------------------------------------
# Paper-faithful discrete formulation (Prop. 5 sums via prefix sums).
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# Fused-aggregate fast path (beyond-paper; the simulator's per-step hot loop).
#
# The admission policies only consume the cluster-wide sums over alive slots,
# sum_s E[L^s_t] and sum_s V[L^s_t] — the per-slot [S, N] curves are an
# intermediate. ``aggregate_moment_curves`` computes the masked sums directly:
# per-slot Gamma-continuation factors are packed once (the gammaln-heavy part,
# shared with the Pallas kernel's packing in kernels/moment_curves/ops.py),
# curve blocks of ``block_size`` slots are evaluated with shared log1p
# subexpressions and matmul interpolation, and each block is reduced into the
# [N] accumulator inside a lax.scan — peak memory is [block_size, N], never
# [S, N]. The same packed math is exposed per-slot as ``moment_curves_fused``
# so the aggregate can be equivalence-tested against the per-slot reference.
# ---------------------------------------------------------------------------

class PackedBelief(NamedTuple):
    """Per-slot scalar factors of the moment-curve closed forms.

    Everything that needs gammaln (no Pallas lowering, and the costliest
    per-slot scalar work) is precomputed here; curve evaluation from a
    PackedBelief touches only log1p/expm1/exp.
    """

    a: jax.Array        # mu posterior shape
    b: jax.Array        # mu posterior rate
    cores: jax.Array    # current active cores C
    eu: jax.Array       # E[lam] E[sig+1]
    eu2: jax.Array      # E[lam^2] E[(sig+1)^2]
    el: jax.Array       # E[lam]
    es1: jax.Array      # E[sig+1]
    ess2: jax.Array     # E[sig(sig+2)]
    rh1: jax.Array      # H-integral continuation factor at p = nu-1
    z1: jax.Array       # a + nu - 1 (clamped away from 0)
    rk: jax.Array       # K-integral continuation factor at p = 2nu-2
    z2: jax.Array       # a + 2nu - 2 (clamped away from 0)
    e_mu_nu: jax.Array  # E[mu^nu]


def pack_belief(bel: GammaBelief, cores: jax.Array,
                priors: PopulationPriors) -> PackedBelief:
    """Precompute the per-slot factors; shapes follow ``bel`` fields."""
    nu = priors.nu
    a, b = bel.mu_a, bel.mu_b
    el, el2 = _lam_moments(bel)
    e_s1, e_s1_sq, e_ss2 = _sigma_moments(bel)

    z1 = a + nu - 1.0
    z1 = jnp.where(jnp.abs(z1) < _EPS, _EPS, z1)
    rh1 = jnp.exp(gammaln(z1 + 1.0) - gammaln(a)
                  - (nu - 1.0) * jnp.log(b)) / z1
    z2 = a + 2.0 * nu - 2.0
    z2 = jnp.where(jnp.abs(z2) < _EPS, _EPS, z2)
    rk = jnp.exp(gammaln(z2 + 1.0) - gammaln(a)
                 - (2.0 * nu - 2.0) * jnp.log(b)) / z2
    e_mu_nu = jnp.exp(gammaln(a + nu) - gammaln(a) - nu * jnp.log(b))
    return PackedBelief(
        a=a, b=b, cores=cores.astype(a.dtype), eu=el * e_s1,
        eu2=el2 * e_s1_sq, el=el, es1=e_s1, ess2=e_ss2, rh1=rh1, z1=z1,
        rk=rk, z2=z2, e_mu_nu=e_mu_nu,
    )


def interp_matrix(t_grid: jax.Array, nd: int):
    """D-term checkpoint grids + linear-interp weights as one matmul.

    Returns (tc [ND] checkpoint times, tau [ND] midpoint lags,
    w_mat [ND+1, N] hat-function weights with the implicit (0, 1) anchor in
    row 0) such that ``ed_ext @ w_mat == interp(t_grid)`` for piecewise-linear
    interpolation from the uniform checkpoint grid.
    """
    t_max = t_grid[-1]
    w = t_max / nd
    x = jnp.arange(nd + 1, dtype=jnp.float32) * w
    idx = jnp.clip(jnp.searchsorted(x, t_grid, side="right") - 1, 0, nd - 1)
    frac = (t_grid - x[idx]) / w
    w_mat = (
        jax.nn.one_hot(idx, nd + 1, axis=0) * (1.0 - frac)[None, :]
        + jax.nn.one_hot(idx + 1, nd + 1, axis=0) * frac[None, :]
    )
    tc = x[1:]
    tau = w * (jnp.arange(nd, dtype=jnp.float32) + 0.5)
    return tc, tau, w_mat.astype(jnp.float32)


def _curves_from_packed(p: PackedBelief, t_grid: jax.Array,
                        w_mat: jax.Array, priors: PopulationPriors,
                        nd: int) -> MomentCurves:
    """Curves [..., N] from packed factors; log1p(t/b) / log1p(2t/b) shared
    across the Q/B/M factors, D-term interpolated via one matmul."""
    t = t_grid
    a, b, c = p.a[..., None], p.b[..., None], p.cores[..., None]
    l1 = jnp.log1p(t / b)
    l2 = jnp.log1p(2.0 * t / b)

    h1 = p.rh1[..., None] * -jnp.expm1(-p.z1[..., None] * l1)
    h2 = p.rh1[..., None] * -jnp.expm1(-p.z1[..., None] * l2)
    eq = p.eu[..., None] * h1
    evq = p.el[..., None] * (p.es1[..., None] * h1
                             + 0.5 * p.ess2[..., None] * h2)
    kk = p.rk[..., None] * (-2.0 * jnp.expm1(-p.z2[..., None] * l1)
                            + jnp.expm1(-p.z2[..., None] * l2))
    veq = p.eu2[..., None] * kk - eq**2
    vq = evq + jnp.maximum(veq, 0.0)

    p1 = jnp.exp(-a * l1)
    p2 = jnp.exp(-a * l2)
    ebn = c * p1
    vb = c * (p1 - p2) + c**2 * jnp.maximum(p2 - p1**2, 0.0)
    em = jnp.exp(-a * jnp.log1p(priors.delta * t / b))
    vm = em * (1.0 - em)

    w = t_grid[-1] / nd
    ed_sub = _d_curve_uniform(p.a, p.b, p.eu, p.e_mu_nu, p.cores, w, nd,
                              midpoint=True)
    ones = jnp.ones(ed_sub.shape[:-1] + (1,), ed_sub.dtype)
    ed = jnp.concatenate([ones, ed_sub], axis=-1) @ w_mat
    vd = ed * (1.0 - ed)

    er = eq + ebn
    vr = vq + vb
    edr = ed * er
    vdr = _product_var(ed, vd, er, vr)
    elc = em * edr
    vl = _product_var(em, vm, edr, vdr)
    return MomentCurves(EL=elc, VL=vl)


def moment_curves_fused(
    bel: GammaBelief,
    cores: jax.Array,
    t_grid: jax.Array,
    priors: PopulationPriors,
    *,
    d_points: int = 32,
) -> MomentCurves:
    """Per-slot curves via the packed fast path — same closed forms and
    midpoint D-term as ``moment_curves``; only subexpression sharing and the
    matmul interpolation differ (agreement to ~1e-6 relative)."""
    packed = pack_belief(bel, cores, priors)
    _, _, w_mat = interp_matrix(t_grid.astype(jnp.float32), d_points)
    return _curves_from_packed(packed, t_grid, w_mat, priors, d_points)


def aggregate_moment_curves(
    bel: GammaBelief,
    cores: jax.Array,
    alive: jax.Array,
    t_grid: jax.Array,
    priors: PopulationPriors,
    *,
    d_points: int = 32,
    block_size: int = 512,
) -> MomentCurves:
    """Cluster-wide (sum over alive slots) E[L_t] and V[L_t], shapes [N].

    Dead slots are masked inside the block reduction; the full [S, N] curve
    matrix is never materialized (peak intermediate: [block_size, N]).
    Equivalent to ``moment_curves(...)`` summed over ``alive`` slots.
    """
    s = cores.shape[-1]
    packed = pack_belief(bel, cores, priors)
    mask = alive.astype(t_grid.dtype)
    _, _, w_mat = interp_matrix(t_grid.astype(jnp.float32), d_points)

    if s <= block_size:
        cur = _curves_from_packed(packed, t_grid, w_mat, priors, d_points)
        return MomentCurves(EL=jnp.einsum("...sn,...s->...n", cur.EL, mask),
                            VL=jnp.einsum("...sn,...s->...n", cur.VL, mask))

    pad = (-s) % block_size
    if pad:
        # filler slots: benign parameters, masked out of the reduction
        packed = jax.tree.map(
            lambda x: jnp.concatenate(
                [x, jnp.ones(x.shape[:-1] + (pad,), x.dtype)], axis=-1),
            packed)
        mask = jnp.concatenate(
            [mask, jnp.zeros(mask.shape[:-1] + (pad,), mask.dtype)], axis=-1)
    n_blocks = (s + pad) // block_size
    to_blocks = lambda x: jnp.moveaxis(
        x.reshape(x.shape[:-1] + (n_blocks, block_size)), -2, 0)
    blocks = jax.tree.map(to_blocks, packed)
    mask_b = to_blocks(mask)

    n = t_grid.shape[-1]
    zero = jnp.zeros(mask.shape[:-1] + (n,), t_grid.dtype)

    def body(carry, xs):
        el_acc, vl_acc = carry
        pk, mk = xs
        cur = _curves_from_packed(pk, t_grid, w_mat, priors, d_points)
        el_acc = el_acc + jnp.einsum("...sn,...s->...n", cur.EL, mk)
        vl_acc = vl_acc + jnp.einsum("...sn,...s->...n", cur.VL, mk)
        return (el_acc, vl_acc), None

    (el, vl), _ = jax.lax.scan(body, (zero, zero), (blocks, mask_b))
    return MomentCurves(EL=el, VL=vl)


def masked_curve_reduction(curves: MomentCurves, mask: jax.Array,
                           block_size: int = 512) -> MomentCurves:
    """Reduce already-evaluated per-slot curves ``[S, N]`` to the masked
    cluster aggregate ``[N]`` with the **exact reduction structure** of
    ``aggregate_moment_curves``: one einsum up to ``block_size`` slots, a
    left-fold of per-``block_size``-block einsums beyond.

    This exists for callers that evaluate the per-slot curves elsewhere —
    the device-sharded admission core evaluates each shard's curves locally,
    all-gathers them, and reduces here — and must still reproduce the fused
    aggregate bit-for-bit: floating-point sums are order-sensitive, so only
    the same block split and the same left-fold over blocks gives the same
    result as the unsharded path. Keep this in lockstep with
    ``aggregate_moment_curves`` (equivalence is pinned in
    ``tests/test_aggregate_fastpath.py``).
    """
    s = mask.shape[-1]
    if s <= block_size:
        return MomentCurves(
            EL=jnp.einsum("...sn,...s->...n", curves.EL, mask),
            VL=jnp.einsum("...sn,...s->...n", curves.VL, mask))

    pad = (-s) % block_size
    if pad:
        # filler slots contribute 0 * finite = 0, exactly as the fused
        # path's mask-zeroed benign filler slots do
        curves = jax.tree.map(
            lambda x: jnp.concatenate(
                [x, jnp.zeros(x.shape[:-2] + (pad, x.shape[-1]), x.dtype)],
                axis=-2),
            curves)
        mask = jnp.concatenate(
            [mask, jnp.zeros(mask.shape[:-1] + (pad,), mask.dtype)], axis=-1)
    n_blocks = (s + pad) // block_size
    n = curves.EL.shape[-1]
    to_blocks_c = lambda x: jnp.moveaxis(
        x.reshape(x.shape[:-2] + (n_blocks, block_size, n)), -3, 0)
    blocks = jax.tree.map(to_blocks_c, curves)
    mask_b = jnp.moveaxis(
        mask.reshape(mask.shape[:-1] + (n_blocks, block_size)), -2, 0)
    zero = jnp.zeros(mask.shape[:-1] + (n,), curves.EL.dtype)

    def body(carry, xs):
        el_acc, vl_acc = carry
        cur, mk = xs
        el_acc = el_acc + jnp.einsum("...sn,...s->...n", cur.EL, mk)
        vl_acc = vl_acc + jnp.einsum("...sn,...s->...n", cur.VL, mk)
        return (el_acc, vl_acc), None

    (el, vl), _ = jax.lax.scan(body, (zero, zero), (blocks, mask_b))
    return MomentCurves(EL=el, VL=vl)


def moment_curves_discrete(
    bel: GammaBelief,
    cores: jax.Array,
    n_steps: int,
    dt: float,
    priors: PopulationPriors,
    **_legacy,
) -> MomentCurves:
    """Uniform-grid curves at t = dt*(1..n_steps), per the paper's Prop. 5.

    Scale-outs are Poisson *per step* (count ~ Pois(lam mu^nu dt)); a core
    added in step i survives to step n w.p. e^(-(n-i) dt mu). All n evaluated
    simultaneously with prefix sums (O(N) total instead of the paper's O(N²)).
    """
    nu = priors.nu
    a, b = bel.mu_a[..., None], bel.mu_b[..., None]
    el, el2 = _lam_moments(bel)
    e_s1, e_s1_sq, e_ss2 = _sigma_moments(bel)
    eu, eu2 = el * e_s1, el2 * e_s1_sq

    n = n_steps
    d = jnp.arange(n, dtype=jnp.float32)       # elapsed steps n - i = 0..n-1
    s = jnp.arange(2 * n - 1, dtype=jnp.float32)
    g1 = _g(a, b, nu, d * dt)                  # [..., n]
    g2 = _g(a, b, nu, 2.0 * d * dt)
    g3 = _g(a, b, 2.0 * nu, s * dt)            # [..., 2n-1]

    cs1 = jnp.cumsum(g1, axis=-1)              # sum_{d=0}^{m} g1
    cs2 = jnp.cumsum(g2, axis=-1)
    a3 = jnp.cumsum(g3, axis=-1)
    b3 = jnp.cumsum(s * g3, axis=-1)

    nn = jnp.arange(1, n + 1, dtype=jnp.float32)
    i_nm1 = jnp.arange(0, n)                   # index n-1
    i_2nm2 = jnp.arange(0, 2 * n, 2)           # index 2n-2

    ew = jnp.take(cs1, i_nm1, axis=-1)
    eq = eu[..., None] * dt * ew
    evq = el[..., None] * dt * (
        e_s1[..., None] * jnp.take(cs1, i_nm1, axis=-1)
        + e_ss2[..., None] * jnp.take(cs2, i_nm1, axis=-1)
    )
    # E[W_n^2] = sum_{s=0}^{2n-2} min(s+1, 2n-1-s) g3(s)
    a_n = jnp.take(a3, i_nm1, axis=-1)
    b_n = jnp.take(b3, i_nm1, axis=-1)
    a_2n = jnp.take(a3, i_2nm2, axis=-1)
    b_2n = jnp.take(b3, i_2nm2, axis=-1)
    ew2 = (b_n + a_n) + ((2.0 * nn - 1.0) * (a_2n - a_n) - (b_2n - b_n))
    veq = eu2[..., None] * dt**2 * ew2 - (eu[..., None] * dt * ew) ** 2
    vq = evq + jnp.maximum(veq, 0.0)

    t = nn * dt
    c = cores[..., None].astype(jnp.float32)
    p1 = _g(a, b, 0.0, t)
    p2 = _g(a, b, 0.0, 2.0 * t)
    ebn = c * p1
    vb = c * (p1 - p2) + c**2 * jnp.maximum(p2 - p1**2, 0.0)
    em = jnp.exp(-a * jnp.log1p(priors.delta * t / b))
    vm = em * (1.0 - em)

    # Paper-exact D recursion on the uniform step grid (lag-cumsum, O(N)).
    e_mu_nu = bel.expected_mu_pow(nu)
    ed = _d_curve_uniform(bel.mu_a, bel.mu_b, eu, e_mu_nu,
                          cores.astype(jnp.float32), jnp.float32(dt), n,
                          midpoint=False)
    vd = ed * (1.0 - ed)

    er = eq + ebn
    vr = vq + vb
    edr = ed * er
    vdr = _product_var(ed, vd, er, vr)
    elc = em * edr
    vl = _product_var(em, vm, edr, vdr)
    return MomentCurves(EL=elc, VL=vl)


def moment_curves_discrete_naive(
    bel_np, cores, n_steps: int, dt: float, priors: PopulationPriors
) -> MomentCurves:
    """Direct O(N²) numpy transcription of the discrete sums — test oracle.

    ``bel_np``: GammaBelief of scalar floats; ``cores``: scalar.
    """
    from math import lgamma

    a, b = float(bel_np.mu_a), float(bel_np.mu_b)
    al, bl = float(bel_np.lam_a), float(bel_np.lam_b)
    asg, bsg = float(bel_np.sig_a), float(bel_np.sig_b)
    nu, delta = priors.nu, priors.delta

    def g(p, tau):
        return np.exp(lgamma(a + p) - lgamma(a) - p * np.log(b) - (a + p) * np.log1p(tau / b))

    el = al / bl
    el2 = al * (al + 1) / bl**2
    es = asg / bsg
    es2 = asg * (asg + 1) / bsg**2
    e_s1, e_s1_sq, e_ss2 = es + 1, es2 + 2 * es + 1, es2 + 2 * es
    eu, eu2 = el * e_s1, el2 * e_s1_sq
    e_mu_nu = g(nu, 0.0)

    n_arr = np.arange(1, n_steps + 1)
    eq = np.zeros(n_steps); vq = np.zeros(n_steps)
    ebv = np.zeros(n_steps); vb = np.zeros(n_steps)
    em = np.zeros(n_steps); ed = np.zeros(n_steps)
    for ni, n in enumerate(n_arr):
        ew = sum(g(nu, (n - i) * dt) for i in range(1, n + 1))
        eq[ni] = eu * dt * ew
        evq = el * dt * sum(
            e_s1 * g(nu, (n - i) * dt) + e_ss2 * g(nu, 2 * (n - i) * dt)
            for i in range(1, n + 1)
        )
        ew2 = sum(
            g(2 * nu, (2 * n - i - j) * dt)
            for i in range(1, n + 1) for j in range(1, n + 1)
        )
        veq = eu2 * dt**2 * ew2 - (eu * dt * ew) ** 2
        vq[ni] = evq + max(veq, 0.0)
        t = n * dt
        p1, p2 = g(0.0, t), g(0.0, 2 * t)
        ebv[ni] = cores * p1
        vb[ni] = cores * (p1 - p2) + cores**2 * max(p2 - p1**2, 0.0)
        em[ni] = np.exp(-a * np.log1p(delta * t / b))

    # D recursion, paper (16)-(17) on the uniform grid
    ed_prev = 1.0
    q_step = eu * e_mu_nu * dt
    for ni, n in enumerate(n_arr):
        p_self = g(0.0, n * dt)
        log_dead = cores * np.log1p(-min(p_self, 1 - 1e-7))
        for i in range(1, n):
            pij = g(0.0, (n - i) * dt)
            log_dead += q_step * np.log1p(-min(pij, 1 - 1e-7))
        factor = -np.expm1(log_dead)
        ed[ni] = (ed_prev if ni else 1.0) * factor
        ed_prev = ed[ni]

    vm = em * (1 - em)
    vd = ed * (1 - ed)
    er, vr = eq + ebv, vq + vb
    edr = ed * er
    vdr = vd * vr + vd * er**2 + ed**2 * vr
    elc = em * edr
    vl = vm * vdr + vm * edr**2 + em**2 * vdr
    return MomentCurves(EL=elc, VL=vl)
