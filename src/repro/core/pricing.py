"""Variance-based pricing & information elicitation (paper §7).

The payment rule q(x) = kappa1 * C^x + kappa2 * Var(x) makes labeling
deployment types a dominant strategy (Prop. 4 / Cor. 2, via the law of total
variance): a mixture of two types always has at least the mixture-weighted
variance of its components, so a user minimizes the variance charge by
splitting the mixture into labeled categories.

``mixture_moments`` implements the provider's belief over an *unlabeled*
arrival (a type mixture) and is the exact law-of-total-variance computation
the proposition rests on — reused by the Fig. 2 benchmark and tested directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .moments import MomentCurves


def payment(c0: jax.Array, var_estimate: jax.Array,
            kappa1: float = 1.0, kappa2: float = 0.01) -> jax.Array:
    """Hourly variance-based payment rule, Eq. (30)."""
    return kappa1 * c0 + kappa2 * var_estimate


def variance_estimate(curves: MomentCurves) -> jax.Array:
    """Provider's scalar Var(x) estimate for pricing: the peak of the
    posterior-predictive variance curve over the horizon."""
    return jnp.max(curves.VL, axis=-1)


def mixture_moments(weights: jax.Array, curves: MomentCurves) -> MomentCurves:
    """Moments of a mixture over K type-components (law of total variance).

    weights: [K]; curves.EL/VL: [K, ..., N]. Returns the mixture's curves:
      E = sum_k w_k E_k
      V = sum_k w_k (V_k + E_k^2) - E^2   (= E[V|type] + V[E|type])
    """
    w = weights.reshape((-1,) + (1,) * (curves.EL.ndim - 1))
    e = jnp.sum(w * curves.EL, axis=0)
    second = jnp.sum(w * (curves.VL + curves.EL**2), axis=0)
    return MomentCurves(EL=e, VL=jnp.maximum(second - e**2, 0.0))


def mixture_variance_excess(weights: jax.Array, e_components: jax.Array,
                            v_components: jax.Array) -> jax.Array:
    """Var(mixture) - sum_k w_k Var(component_k) = Var_k(E[.|k]) >= 0 —
    the quantity Prop. 4 shows is nonnegative (the user's saving from labeling).
    """
    e_mix = jnp.sum(weights * e_components, axis=0)
    return jnp.sum(weights * (e_components - e_mix) ** 2, axis=0)
