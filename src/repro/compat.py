"""Version-compatibility shims for the jax API surface this repo uses.

The codebase targets the modern ``jax.shard_map`` entry point (jax >= 0.6);
the pinned toolchain ships jax 0.4.37, where shard_map still lives in
``jax.experimental.shard_map`` and the replication-checking flag is named
``check_rep`` instead of ``check_vma``. Every shard_map call site routes
through :func:`shard_map` below so the rest of the code is written once
against the new API.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable

import jax

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # jax < 0.6: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_ACCEPTED = frozenset(
    inspect.signature(_shard_map_impl).parameters
)


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool | None = None, **kwargs: Any) -> Callable:
    """``jax.shard_map`` with the new-API signature on every supported jax.

    ``check_vma`` (new name) is translated to ``check_rep`` (old name) when
    the installed implementation predates the rename; both names disable the
    same replication/varying-mesh-axes check.
    """
    if check_vma is not None:
        if "check_vma" in _ACCEPTED:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _ACCEPTED:
            kwargs["check_rep"] = check_vma
        # else: the flag vanished entirely; the default behaviour is fine.
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


def tpu_compiler_params(**kwargs):
    """Pallas-TPU compiler params across the 0.4.x -> 0.6 rename
    (``TPUCompilerParams`` became ``CompilerParams``)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
