"""Sharded, step-atomic, async checkpointing with restore-time resharding.

Layout:  <dir>/step_<n>/
            manifest.json            # pytree structure + shapes + dtypes
            shard_<k>.npz            # flattened leaves (chunked)
         <dir>/LATEST                # atomic pointer (written last)

* **step-atomic**: shards are written to a tmp dir, the manifest last, then a
  rename + LATEST update — a crash mid-save never corrupts the previous
  checkpoint (fault-tolerance requirement).
* **async**: ``save_async`` snapshots to host memory and writes on a
  background thread so training continues (wait() to join).
* **resharding restore**: leaves are stored unsharded (gathered); ``restore``
  takes target shardings and device_puts each leaf against them, so a
  checkpoint taken on mesh (2,16,16) restores onto (16,16) or a single CPU
  device (elastic downsize path; see runtime.elastic).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MAX_SHARD_BYTES = 512 * 1024 * 1024


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous step-atomic save. Returns the checkpoint path."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    shards, cur, cur_bytes, idx = [], {}, 0, 0
    for i, arr in enumerate(host):
        cur[f"leaf_{i}"] = arr
        cur_bytes += arr.nbytes
        if cur_bytes >= _MAX_SHARD_BYTES:
            np.savez(os.path.join(tmp, f"shard_{idx}.npz"), **cur)
            shards.append(len(cur))
            cur, cur_bytes, idx = {}, 0, idx + 1
    if cur:
        np.savez(os.path.join(tmp, f"shard_{idx}.npz"), **cur)
        shards.append(len(cur))

    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(host),
        "n_shards": len(shards),
        "shapes": [list(a.shape) for a in host],
        "dtypes": [str(a.dtype) for a in host],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    return final


class AsyncCheckpointer:
    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: Any):
        self.wait()
        # snapshot to host synchronously (cheap vs device compute), write async
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]
        snapshot = jax.tree.unflatten(treedef, host)

        def _write():
            save(self.directory, step, snapshot)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    path = os.path.join(directory, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore(directory: str, step: int, target_tree: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target_tree``; device_put against
    ``shardings`` (same structure) if given — this is the resharding path."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    host = {}
    for k in range(manifest["n_shards"]):
        with np.load(os.path.join(path, f"shard_{k}.npz")) as z:
            for name in z.files:
                host[int(name.split("_")[1])] = z[name]
    leaves = [host[i] for i in range(manifest["n_leaves"])]

    t_leaves, treedef = jax.tree.flatten(target_tree)
    assert len(t_leaves) == len(leaves), (
        f"checkpoint has {len(leaves)} leaves, target {len(t_leaves)}")
    if shardings is not None:
        s_leaves = jax.tree.flatten(shardings)[0]
        leaves = [jax.device_put(a, s) for a, s in zip(leaves, s_leaves)]
    else:
        leaves = [jnp.asarray(a) for a in leaves]
    return jax.tree.unflatten(treedef, leaves)
