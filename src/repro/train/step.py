"""Distributed train step: loss + grad + AdamW under pjit/GSPMD.

The step is a single jit-compiled function whose in/out shardings pin params
and optimizer state to the 2D FSDP×TP layout (models.spec) and the batch to
the data axes. Gradient accumulation over ``microbatches`` runs as a scan so
the weight all-gathers overlap with per-microbatch compute under XLA's
latency-hiding scheduler (mesh.py documents the flags), and only one
reduce-scatter of the summed grads hits the wire per step.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..models.spec import resolve_spec
from ..optim import adamw
from ..optim.compression import (ErrorFeedback, compress_with_feedback,
                                 init_error_feedback)


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState
    ef: Optional[ErrorFeedback]  # gradient-compression error feedback


def init_train_state(model, key, *, compress: bool = False,
                     param_dtype=jnp.float32) -> TrainState:
    params = model.init(key, param_dtype)
    return TrainState(
        params=params,
        opt=adamw.init_opt_state(params),
        ef=init_error_feedback(params) if compress else None,
    )


def abstract_train_state(model, *, compress: bool = False,
                         param_dtype=jnp.float32) -> TrainState:
    params = model.abstract_params(param_dtype)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return TrainState(
        params=params,
        opt=adamw.OptState(
            m=jax.tree.map(f32, params),
            v=jax.tree.map(f32, params),
            step=jax.ShapeDtypeStruct((), jnp.int32),
        ),
        ef=ErrorFeedback(jax.tree.map(f32, params)) if compress else None,
    )


def state_shardings(model, mesh: Mesh, *, compress: bool = False):
    ps = model.param_shardings(mesh)
    return TrainState(
        params=ps,
        opt=adamw.OptState(
            m=ps, v=ps,
            step=NamedSharding(mesh, PartitionSpec()),
        ),
        ef=ErrorFeedback(ps) if compress else None,
    )


def batch_shardings(batch_specs: dict, mesh: Mesh) -> dict:
    out = {}
    for k, v in batch_specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, resolve_spec(v.shape, axes, mesh))
    return out


def make_train_step(model, opt_cfg: adamw.AdamWConfig, mesh: Optional[Mesh],
                    *, microbatches: int = 1, compress: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch, mesh)

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def slice_mb(i, x):
            mb = x.shape[0] // microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def acc_step(carry, i):
            loss_acc, grads_acc = carry
            mb = jax.tree.map(functools.partial(slice_mb, i), batch)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
            return (loss_acc + loss, grads_acc), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss_sum, grads), metrics = jax.lax.scan(
            acc_step, (jnp.zeros(()), zeros), jnp.arange(microbatches))
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, grads)
        last_metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum * inv, last_metrics, grads

    def step(state: TrainState, batch: dict):
        loss, metrics, grads = grads_of(state.params, batch)
        ef = state.ef
        compress_fn = None
        if compress and ef is not None:
            grads, ef = compress_with_feedback(grads, ef)
        new_params, new_opt, opt_metrics = adamw.apply_updates(
            opt_cfg, state.params, grads, state.opt, compress_fn)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(new_params, new_opt, ef), metrics

    if mesh is None:
        return jax.jit(step)
    ss = None  # shardings resolved by caller via lower(); keep step pure
    return step
