"""Pipeline parallelism: GPipe microbatch streaming via shard_map +
collective_permute.

For scaling beyond the (pod, data, model) production mesh — e.g. 1000+ nodes
where a layer stack no longer fits a single pod's TP domain — the layer stack
is partitioned across a `stage` mesh axis and microbatches stream through the
stages; each tick every stage applies its layer chunk and ppermutes its
activation to the next stage. Differentiable end-to-end (jax transposes
ppermute automatically), so `jax.grad` of a pipelined loss just works.

Bubble fraction = (S-1)/(M+S-1) — choose M >> S. Off by default: the
production dry-run meshes carry DP/FSDP/TP/EP; this module is the documented
and tested PP option (tests/test_pipeline.py proves forward and gradient
equivalence with the sequential stack on a multi-device mesh).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map


def make_pipeline_forward(stage_fn: Callable, n_stages: int, mesh,
                          data_axis: str | None = "data"):
    """Build a pipelined forward over a stacked-parameter layer stack.

    stage_fn(params_chunk, x) -> x : applies one stage's layer chunk
      (params_chunk: [L/S, ...] pytree slice; x: [mb, ...] activation).
    Returns pipeline(params, x_mb) where params: [L, ...] stacked pytree
    (sharded over 'stage') and x_mb: [M, mb, ...] microbatches. Output:
    [M, mb, ...] (replicated over 'stage').
    """
    s = n_stages

    def inner(params_local, x_mb):
        stage = jax.lax.axis_index("stage")
        m = x_mb.shape[0]
        ticks = m + s - 1
        perm = [(i, (i + 1) % s) for i in range(s)]

        def tick(carry, t):
            recv, outputs = carry
            xin = jnp.where(stage == 0,
                            x_mb[jnp.clip(t, 0, m - 1)], recv)
            y = stage_fn(params_local, xin)
            recv_next = jax.lax.ppermute(y, "stage", perm)
            mb_idx = t - (s - 1)
            valid = (stage == s - 1) & (mb_idx >= 0) & (mb_idx < m)
            idx = jnp.clip(mb_idx, 0, m - 1)
            cur = jax.lax.dynamic_index_in_dim(outputs, idx, 0,
                                               keepdims=False)
            upd = jnp.where(valid, y, cur)
            outputs = jax.lax.dynamic_update_index_in_dim(outputs, upd, idx, 0)
            return (recv_next, outputs), None

        outputs0 = jnp.zeros_like(x_mb)
        recv0 = jnp.zeros_like(x_mb[0])
        (_, outputs), _ = jax.lax.scan(tick, (recv0, outputs0),
                                       jnp.arange(ticks))
        # broadcast the last stage's outputs to every stage
        mask = (stage == s - 1).astype(outputs.dtype)
        return jax.lax.psum(outputs * mask, "stage")

    dspec = (data_axis,) if data_axis and data_axis in mesh.axis_names else (None,)
    x_spec = P(None, *dspec, None, None)

    def pipeline(params, x_mb):
        param_specs = jax.tree.map(lambda _: P("stage"), params)
        return shard_map(
            inner, mesh=mesh,
            in_specs=(param_specs, x_spec),
            out_specs=x_spec,
            check_vma=False,
        )(params, x_mb)

    return pipeline


def sequential_reference(stage_fn: Callable, n_stages: int, params, x_mb):
    """Ground truth: apply all stages sequentially to each microbatch."""
    def apply_all(x):
        l = jax.tree.leaves(params)[0].shape[0]
        chunk = l // n_stages
        for si in range(n_stages):
            p = jax.tree.map(lambda a: a[si * chunk:(si + 1) * chunk], params)
            x = stage_fn(p, x)
        return x
    return jax.vmap(apply_all)(x_mb)
