"""Fault tolerance & straggler mitigation for the training driver.

Production story (1000+ nodes): every step is wrapped in a watchdog; each
host heartbeats; on failure the controller restarts the job, every host
reloads the LATEST step-atomic checkpoint, and — if the machine set changed —
restores with *resharding* onto the surviving mesh (runtime.elastic). At this
container's scale the machinery is exercised through a failure-injection hook
(tests/test_fault_tolerance.py kills and resumes a real training loop).

Straggler mitigation: per-step wall times feed an EWMA; a step slower than
``straggler_factor``× the EWMA marks the host a straggler, which at fleet
scale triggers hot-spare swap-in; here it is surfaced in the metrics so the
policy layer (the paper's admission controller!) can treat the pod as
degraded capacity.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class FailureInjector:
    """Deterministic fault injection for tests: raises at the given steps."""

    def __init__(self, fail_at: tuple = ()):
        self.fail_at = set(fail_at)
        self.fired: set = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


@dataclass
class StragglerWatchdog:
    ewma_alpha: float = 0.2
    straggler_factor: float = 2.5
    warmup_steps: int = 3
    _ewma: float = 0.0
    _n: int = 0
    events: list = field(default_factory=list)

    def observe(self, step: int, step_time: float) -> bool:
        self._n += 1
        if self._n <= self.warmup_steps:
            self._ewma = step_time if self._ewma == 0.0 else (
                0.5 * self._ewma + 0.5 * step_time)
            return False
        is_straggler = step_time > self.straggler_factor * self._ewma
        if is_straggler:
            self.events.append((step, step_time, self._ewma))
        else:
            self._ewma = (1 - self.ewma_alpha) * self._ewma \
                + self.ewma_alpha * step_time
        return is_straggler


class HeartbeatMonitor:
    """Host-liveness bookkeeping (single-process stand-in for the fleet RPC)."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.last_beat = {h: time.monotonic() for h in range(n_hosts)}

    def beat(self, host: int):
        self.last_beat[host] = time.monotonic()

    def dead_hosts(self) -> list:
        now = time.monotonic()
        return [h for h, t in self.last_beat.items()
                if now - t > self.timeout_s]


def run_with_restarts(
    train_loop: Callable[[int], int],
    *,
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, Exception], None]] = None,
) -> int:
    """Drive ``train_loop(start_step) -> final_step`` with restart-on-failure.

    ``train_loop`` is expected to resume from the latest checkpoint when
    re-entered (see launch/train.py). Returns the final step reached.
    """
    start = 0
    for attempt in range(max_restarts + 1):
        try:
            return train_loop(start)
        except RuntimeError as e:
            if attempt == max_restarts:
                raise
            if on_restart is not None:
                on_restart(attempt, e)
            # train_loop re-reads LATEST itself; start value is advisory
            start = -1
    raise AssertionError("unreachable")
