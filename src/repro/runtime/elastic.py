"""Elastic scaling: reshard a training state onto a different mesh.

When a pod is lost (512 -> 256 chips) or gained, the surviving job rebuilds
its mesh, recomputes the parameter shardings for the new mesh (models.spec
resolves the same logical rules against the new axis sizes), and restores the
step-atomic checkpoint with device_put against the new shardings
(checkpoint.restore's resharding path). Nothing about the model or optimizer
needs to change because shardings are derived, not stored.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh

from ..checkpoint import checkpointer
from ..train.step import abstract_train_state, state_shardings


def reshard_restore(model, ckpt_dir: str, new_mesh: Mesh, *,
                    compress: bool = False, step: int | None = None) -> Any:
    """Load the latest (or given) step onto ``new_mesh`` with fresh shardings."""
    if step is None:
        step = checkpointer.latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint under {ckpt_dir}"
    target = abstract_train_state(model, compress=compress)
    shardings = state_shardings(model, new_mesh, compress=compress)
    return checkpointer.restore(ckpt_dir, step, target, shardings), step


def reshard_in_memory(state: Any, model, new_mesh: Mesh, *,
                      compress: bool = False) -> Any:
    """Live resharding (no disk round-trip) for planned topology changes."""
    shardings = state_shardings(model, new_mesh, compress=compress)
    return jax.tree.map(jax.device_put, state, shardings)
