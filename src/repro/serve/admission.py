"""Online admission service: the simulator's admission core, served live.

The paper's provider "has to continuously decide" admission as workloads
arrive — this module is that decision loop as a long-lived engine rather
than an offline ``lax.scan``:

  * ``OnlineAdmissionEngine`` holds one device-resident ``CoreState`` (slot
    table + beliefs + maintained aggregate moment curves) and advances it
    with individually **jitted, buffer-donating** steps built from the same
    ``sim.core.make_admission_core`` functions the simulators scan. Because
    the functions are shared — not re-implemented — feeding the engine the
    exact event/arrival sequence drawn by ``make_run`` reproduces the same
    admit/reject decisions and final metrics bit-for-bit (asserted in
    ``tests/test_online_admission.py``).
  * A **micro-batching front-end**: concurrent ``submit()`` calls enqueue
    arrival tickets (plain numpy, no device work on the caller's thread) and
    receive futures; each ``flush()`` coalesces the queue into fixed-width
    decision batches, so a burst of concurrent requests costs one device
    step per ``micro_batch`` of them instead of one aggregate recompute per
    request (the ``naive=True`` ablation path, kept for
    ``benchmarks/serve_bench.py`` to measure against).
  * **Event ingestion between steps**: ``tick()`` advances cluster dynamics
    one ``dt``-hour window — either simulated from the fitted processes
    (``tick(key)``, the benchmark/daemon regime) or applied from *observed*
    departures and scale-out requests (``tick(events=...)``, the production
    regime) — and refreshes the aggregate curves on the blocked
    ``agg_refresh_steps`` schedule, selected from the measured K-curve via
    ``tuning.pick_agg_refresh`` when a scale name is given.

Fleet configurations run the same engine with a leading ``[C]`` cluster
axis and a ``sim.routing.Router`` assigning each micro-batch lane to a
cluster before per-cluster admission, mirroring ``make_fleet_run`` exactly.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import threading
import time
import warnings
from concurrent.futures import Future
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.belief import belief_from_prior, observe_initial_size
from ..core.policies import PolicyParams
from ..core.processes import DeploymentParams, sample_params
from ..obs.counters import WindowStats, fold_window, telemetry_summary
from ..obs.export import HostHistogram, log_buckets
from ..obs.tracing import DecisionTracer, annotate
from ..sim.core import (ArrivalStream, CoreState, FleetConfig, SimConfig,
                        StepOutcome, make_admission_core, slot_mesh)
from ..sim.simulator import (_accumulate_step, _cluster_step_keys,
                             _fleet_metrics, _run_metrics, broadcast_policy)

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One admission request: the per-arrival lane of an ``ArrivalStream``.

    ``params`` are the arrival's true process parameters — used only to
    *simulate* the deployment's future dynamics (benchmarks, the daemon's
    synthetic load); a production deployment's real events arrive through
    ``tick(events=...)`` instead and ``params`` is dead weight there.
    """

    c0: float
    bel: object                    # GammaBelief scalars (provider's prior)
    bel_alt: object                # second mixture component (§7 unlabeled)
    params: object                 # DeploymentParams scalars

    @staticmethod
    def from_stream(stream: ArrivalStream, t: int, a: int) -> "Arrival":
        pick = lambda x: np.asarray(x[t, a])
        return Arrival(c0=float(pick(stream.c0)),
                       bel=jax.tree.map(pick, stream.bel),
                       bel_alt=jax.tree.map(pick, stream.bel_alt),
                       params=jax.tree.map(pick, stream.params))

    @staticmethod
    def draw(key: jax.Array, cfg: SimConfig) -> "Arrival":
        """Sample one arrival from the population priors (ad-hoc load)."""
        kp, kc = jax.random.split(key)
        params = sample_params(kp, cfg.priors, ())
        c0 = float(1 + jax.random.poisson(kc, params.sig))
        bel = observe_initial_size(belief_from_prior(cfg.priors, ()),
                                   jnp.asarray(c0))
        return Arrival(c0=c0, bel=jax.tree.map(np.asarray, bel),
                       bel_alt=jax.tree.map(np.asarray, bel),
                       params=jax.tree.map(np.asarray, params))


class ExternalEvents(NamedTuple):
    """Observed cluster events for one ``dt``-hour window (production
    ingestion path — replaces the fitted processes' simulated draw).

    All arrays are per-slot ``[S]`` (``[C, S]`` for fleets): ``core_deaths``
    cores lost per deployment, ``spont_death`` whole-deployment shutdowns,
    and the window's scale-out demand (``scaleout_cores`` cores over
    ``n_scaleouts`` requests; grants are decided against capacity in slot
    order, exactly as the simulated path does).
    """

    core_deaths: jax.Array
    spont_death: jax.Array
    scaleout_cores: jax.Array
    n_scaleouts: jax.Array


class OnlineAdmissionEngine:
    """Long-lived micro-batched admission engine over one ``AdmissionCore``.

    Protocol (one ``dt``-hour window per ``tick``, decisions in between)::

        eng = OnlineAdmissionEngine(cfg, grid, SECOND, policy)
        fut = eng.submit(Arrival.draw(key, cfg))   # any thread, any time
        eng.tick(step_key)                         # dynamics + agg refresh
        eng.flush()                                # decide pending batch
        fut.result()                               # -> bool (admitted?)
        ...
        eng.metrics()                              # RunMetrics so far

    The slot/belief/aggregate state lives on device as one ``CoreState``
    pytree and is **donated** through every jitted step, so a tick or a
    micro-batch decision never allocates a second copy of the slot table.
    ``cfg`` may be a ``SimConfig`` (single cluster) or ``FleetConfig``
    (leading ``[C]`` axis + routing). ``naive=True`` selects the ablation
    front-end: one full aggregate recompute + width-1 decision per request
    (what admission costs without the maintained incremental aggregate).

    Scaling and latency knobs:

      * ``shards=N`` shards the slot table over N devices via the
        ``sim.core.slot_mesh`` lane (single-cluster engines only): every
        jitted step runs as a ``shard_map`` with per-shard moment-curve
        evaluation and the unsharded path's exact reduction order, so the
        sharded engine's decisions and metrics are **bit-for-bit** equal to
        the unsharded engine's — one engine scales state with device count
        instead of being capped by one device's ``max_slots``.
      * ``flush_slo_ms=L`` replaces caller-driven flushing with the
        deadline scheduler (see ``start``/``_deadline_loop``): partial
        micro-batches fire when the oldest pending request approaches its
        L-millisecond decision SLO, full batches when ``micro_batch``
        requests are queued. Misses are counted in
        ``metrics_snapshot()["engine"]["deadline_misses"]``.
      * ``seed`` roots the engine's key chain: the observed-events tick
        path derives its per-window key by ``fold_in(PRNGKey(seed), tick)``
        so distinct engines/restarts draw decorrelated belief noise.

    Observability: with ``cfg.telemetry`` the ``CoreState`` carries the
    device telemetry rider through every step, and ``metrics_snapshot()``
    exports it (plus host-side decision-latency / flush-batch-size
    histograms and queue/pump gauges) without synchronizing the pump —
    that is what the daemon's ``/metrics`` endpoint serves. An attached
    ``obs.tracing.DecisionTracer`` additionally receives one structured
    record per ``submit``-path decision (single-cluster engines include
    the policy score via the traced decide path), and an attached
    ``tuning.drift.DriftDetector`` is fed the between-scrape observable
    deltas so prior drift surfaces on the same endpoint.
    """

    def __init__(self, cfg, grid, policy_kind: int, policy: PolicyParams, *,
                 router=None, micro_batch: Optional[int] = None,
                 naive: bool = False, scale: Optional[str] = None,
                 tracer: Optional[DecisionTracer] = None,
                 drift_detector=None, shards: Optional[int] = None,
                 flush_slo_ms: Optional[float] = None, seed: int = 0):
        self.fleet = isinstance(cfg, FleetConfig)
        base = cfg.base if self.fleet else cfg
        if scale is not None:
            from ..tuning import pick_agg_refresh
            base = base._replace(agg_refresh_steps=pick_agg_refresh(
                scale, fallback=base.agg_refresh_steps,
                n_steps=base.n_steps))
        self.cfg = FleetConfig(base=base, capacities=cfg.capacities) \
            if self.fleet else base
        self.base = base
        self.n_shards = int(shards or 1)
        if self.n_shards > 1 and self.fleet:
            raise ValueError(
                "shards= shards one cluster's slot table over devices; "
                "fleet engines already spread state over the cluster axis "
                "— run one sharded engine per cluster instead")
        mesh = slot_mesh(self.n_shards) if self.n_shards > 1 else None
        self.core = make_admission_core(base, grid, policy_kind, mesh=mesh)
        self.k_refresh = base.agg_refresh_steps
        if flush_slo_ms is not None and flush_slo_ms <= 0:
            raise ValueError("flush_slo_ms must be positive")
        self.flush_slo_s = (None if flush_slo_ms is None
                            else float(flush_slo_ms) / 1e3)
        self.deadline_misses = 0
        self._flush_cost_s = 0.0    # EWMA of observed flush wall time
        self._base_key = jax.random.PRNGKey(seed)
        self.naive = naive
        self.width = int(micro_batch or base.max_arrivals)
        self.n_c = self.cfg.n_clusters if self.fleet else 1
        self._caps = (jnp.asarray(self.cfg.capacities, jnp.float32)
                      if self.fleet else
                      jnp.asarray(base.capacity, jnp.float32))
        if self.fleet:
            from ..sim.routing import LeastUtilizedRouter
            self.router = LeastUtilizedRouter() if router is None else router
            policy = broadcast_policy(policy, self.n_c)
        self.policy = policy

        # -- engine state (owned by the engine thread) ----------------------
        cs = self.core.init()
        if self.fleet:
            cs = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n_c,) + x.shape), cs)
        self._cs: CoreState = cs
        self._out: Optional[StepOutcome] = None   # current window's dynamics
        self._util = None                         # decision-time utilization
        self._step_key = None                     # key of the open window
        self._acc = 0.0                           # window accept/reject
        self._rej = 0.0                           # counts ([C] for fleets)
        self._rej_all = 0.0                       # fleet: routed-nowhere
        self.ticks = 0
        self.decisions = 0
        self._util_trace: list = []
        self._fail_trace: list = []
        self._pad = self._pad_template()

        # -- micro-batch front-end ------------------------------------------
        self._pending: list = []                  # [(Arrival, Future, t_sub)]
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._pump: Optional[threading.Thread] = None
        self._stop = threading.Event()

        # -- observability --------------------------------------------------
        # one reentrant lock serializes every jit-call-and-reassign of the
        # donated CoreState against metrics_snapshot's jnp.copy — without it
        # a snapshot racing the pump could read already-donated buffers
        self._state_lock = threading.RLock()
        self.tracer = tracer
        if self.flush_slo_s is not None:
            # SLO-anchored buckets: the SLO itself is a bucket edge, so the
            # interpolated p99 certifies SLO attainment (p99 <= SLO exactly
            # when no observation crossed the SLO edge) instead of smearing
            # sub-SLO latencies into a coarse decade-wide default bucket
            slo = self.flush_slo_s
            self._hist_latency = HostHistogram(
                log_buckets(slo / 512.0, slo, 10) + (2.0 * slo, 4.0 * slo))
        else:
            self._hist_latency = HostHistogram()  # submit->decision, seconds
        self._hist_batch = HostHistogram(
            log_buckets(1.0, float(max(self.width, 2)), 8))
        self.n_flushes = 0
        self.n_refreshes = 0
        self._pump_idle_s = 0.0
        self._pump_busy_s = 0.0
        self._req_id = 0
        self._last_diag = None                    # DecisionDiag of last slice
        # live drift detection: a tuning.drift.DriftDetector fed the obs
        # deltas between metrics_snapshot scrapes (the scrape cadence IS the
        # detector's window — monitoring-driven, zero decision-path cost)
        if drift_detector is not None and not base.telemetry:
            raise ValueError("drift_detector requires cfg.telemetry=True "
                             "(the detector consumes the telemetry rider's "
                             "observable totals)")
        self.drift = drift_detector
        self._drift_prev_obs: Optional[dict] = None
        self._policy_info = {
            "kind": np.asarray(policy.kind).tolist(),
            "threshold": np.asarray(policy.threshold).tolist(),
            "rho": np.asarray(policy.rho).tolist(),
        }

        self._build_jit()

    # ------------------------------------------------------------------ jit

    def _build_jit(self):
        core, cfg, n_c, caps = self.core, self.base, self.n_c, self._caps

        if not self.fleet:
            self._j_refresh = jax.jit(core.refresh_aggregates,
                                      donate_argnums=(0,))
            self._j_tick = jax.jit(lambda k, cs: core.apply_events(k, cs),
                                   donate_argnums=(1,))
            self._j_ingest = jax.jit(self._ingest_one, donate_argnums=(1,))

            def decide(policy, cs, util, batch, valid):
                cand = core.candidates(batch)
                cs, accept = core.decide_batch(policy, cs, util, cand,
                                               batch, valid)
                # post-placement utilization, so a second flush inside the
                # same window admits against the already-placed arrivals
                util = jnp.sum(cs.slots.cores
                               * cs.slots.alive.astype(jnp.float32))
                return cs, accept, util

            self._j_decide = jax.jit(decide, donate_argnums=(1,))

            def decide_traced(policy, cs, util, batch, valid):
                cand = core.candidates(batch)
                cs, accept, diag = core.decide_batch_traced(
                    policy, cs, util, cand, batch, valid)
                util = jnp.sum(cs.slots.cores
                               * cs.slots.alive.astype(jnp.float32))
                return cs, accept, util, diag

            self._j_decide_traced = jax.jit(decide_traced, donate_argnums=(1,))

            def naive_decide(policy, cs, util, batch, valid):
                # ablation: full O(slots * grid) aggregate recompute, then a
                # width-1 decision — the cost of admission without the
                # incrementally-maintained aggregate
                cs = core.refresh_aggregates(cs)
                return decide(policy, cs, util, batch, valid)

            self._j_naive = jax.jit(naive_decide, donate_argnums=(1,))
        else:
            self._j_refresh = jax.jit(jax.vmap(core.refresh_aggregates),
                                      donate_argnums=(0,))

            def fleet_tick(key, cs):
                keys_c = _cluster_step_keys(key, n_c)
                return jax.vmap(
                    lambda cap, k, cs_c: core.apply_events(k, cs_c, cap))(
                        caps, keys_c, cs)

            self._j_tick = jax.jit(fleet_tick, donate_argnums=(1,))
            self._j_ingest = jax.jit(
                jax.vmap(self._ingest_one, in_axes=(0, 0, 0)),
                donate_argnums=(1,))

            def fleet_decide(policy, cs, util, batch, valid, route_key,
                             rej_all):
                from ..sim.routing import RouteContext

                cand = core.candidates(batch)
                assign = self.router.route(route_key, RouteContext(
                    cand=cand, c0=batch.c0, valid=valid, agg_el=cs.agg_el,
                    agg_vl=cs.agg_vl, util=util, capacities=caps,
                    policy=policy))
                assign = jnp.clip(assign, 0, n_c)   # sentinel n_c = nowhere
                mask = valid[None, :] & (
                    assign[None, :] == jnp.arange(n_c)[:, None])
                rej_all = rej_all + jnp.sum(
                    (valid & (assign == n_c)).astype(jnp.float32))
                cs, accept = jax.vmap(
                    lambda pol_c, cs_c, u_c, m_c: core.decide_batch(
                        pol_c, cs_c, u_c, cand, batch, m_c))(
                            policy, cs, util, mask)
                n_acc = jnp.sum(accept.astype(jnp.float32), axis=1)
                n_rej = jnp.sum(mask.astype(jnp.float32), axis=1) - n_acc
                util = jnp.sum(cs.slots.cores
                               * cs.slots.alive.astype(jnp.float32), axis=-1)
                return cs, accept, util, n_acc, n_rej, rej_all

            self._j_decide = jax.jit(fleet_decide, donate_argnums=(1,))

            def fleet_naive(policy, cs, util, batch, valid, route_key,
                            rej_all):
                cs = jax.vmap(core.refresh_aggregates)(cs)
                return fleet_decide(policy, cs, util, batch, valid,
                                    route_key, rej_all)

            self._j_naive = jax.jit(fleet_naive, donate_argnums=(1,))

        # no donation: the engine keeps referencing the aggregate buffers of
        # the CoreState it passes in (only the slot accumulators change)
        self._j_close = jax.jit(
            lambda cs, out, n_acc, n_rej: _accumulate_step(
                cs.slots, out, n_acc, n_rej, cfg.dt))

    def _ingest_one(self, capacity, cs: CoreState, ev: ExternalEvents):
        """Apply one cluster's observed events: the simulated
        ``_step_dynamics`` arithmetic with the random event draw replaced by
        the observation (same death clamping, greedy slot-order grants
        against capacity, and conjugate belief updates)."""
        from ..core.belief import update_on_events

        cfg, state = self.base, cs.slots
        alive_f = state.alive.astype(jnp.float32)
        deaths = jnp.minimum(ev.core_deaths.astype(jnp.float32),
                             state.cores) * alive_f
        exposure = state.cores * cfg.dt * alive_f
        cores = state.cores - deaths
        cores = jnp.where(ev.spont_death & state.alive, 0.0, cores)
        alive = state.alive & (cores > 0.0)
        departed = jnp.sum((state.alive & ~alive).astype(jnp.float32))
        alive_f = alive.astype(jnp.float32)

        req = ev.scaleout_cores.astype(jnp.float32) * alive_f
        n_req = ev.n_scaleouts.astype(jnp.float32) * alive_f
        util = jnp.sum(cores * alive_f)
        grant = (util + jnp.cumsum(req)) <= capacity
        cores = cores + jnp.where(grant, req, 0.0)
        failed = jnp.sum(jnp.where(~grant, n_req, 0.0))
        util = jnp.sum(cores * alive_f)

        bel = update_on_events(
            state.bel, core_deaths=deaths, exposure_core_hours=exposure,
            n_scaleouts=n_req, scaleout_cores=req,
            alive_hours=cfg.dt * alive_f, priors=cfg.priors)
        tel = cs.tel
        if cfg.telemetry:
            spont = jnp.sum((ev.spont_death & state.alive)
                            .astype(jnp.float32))
            tel = fold_window(tel, util, capacity, WindowStats(
                core_deaths=jnp.sum(deaths),
                exposure_core_hours=jnp.sum(exposure),
                n_scaleouts=jnp.sum(n_req),
                scaleout_cores=jnp.sum(req),
                alive_hours=cfg.dt * jnp.sum(alive_f),
                spont_deaths=spont, departed=departed))
        cs = cs._replace(slots=state._replace(alive=alive, cores=cores,
                                              bel=bel), tel=tel)
        return cs, StepOutcome(util=util, failed=failed,
                               n_requests=jnp.sum(n_req), departed=departed)

    # ------------------------------------------------------- step protocol

    def tick(self, key: Optional[jax.Array] = None,
             events: Optional[ExternalEvents] = None):
        """Advance cluster dynamics one ``dt``-hour window.

        Closes the previous decision window (folding its counters into the
        metric accumulators), refreshes the aggregate curves when the
        blocked ``agg_refresh_steps`` schedule says so, then applies this
        window's deaths / scale-out grants / belief updates — simulated from
        the fitted processes under ``key``, or observed via ``events``.
        """
        if (key is None) == (events is None):
            raise ValueError("tick() needs exactly one of key= or events=")
        with self._state_lock:
            self._close_window()
            if self.ticks % self.k_refresh == 0 and not self.naive:
                with annotate("repro.engine.refresh"):
                    self._cs = self._j_refresh(self._cs)
                self.n_refreshes += 1
            with annotate("repro.engine.tick"):
                if events is not None:
                    ev = jax.tree.map(jnp.asarray, events)
                    self._cs, self._out = self._j_ingest(self._caps,
                                                         self._cs, ev)
                    # derive from the engine's seed chain: PRNGKey(self.ticks)
                    # here would be identical across engines, fleet clusters,
                    # and restarts, perfectly correlating any downstream
                    # belief noise
                    self._step_key = jax.random.fold_in(self._base_key,
                                                        self.ticks)
                else:
                    self._cs, self._out = self._j_tick(key, self._cs)
                    self._step_key = key
            self._util = self._out.util
            self._acc = self._rej = 0.0
            self.ticks += 1

    def _close_window(self):
        with self._state_lock:
            if self._out is None:
                return
            slots, util_end = self._j_close(
                self._cs, self._out, jnp.asarray(self._acc, jnp.float32),
                jnp.asarray(self._rej, jnp.float32))
            self._cs = self._cs._replace(slots=slots)
            self._util_trace.append(util_end)
            self._fail_trace.append(self._out.failed)
            self._out = None
            # zero the folded window counters so a second close (metrics()
            # followed by tick()) cannot double-count them
            self._acc = self._rej = 0.0

    # ------------------------------------------------- micro-batch frontend

    def submit(self, arrival: Arrival) -> Future:
        """Enqueue one admission request; resolves to ``bool`` (admitted)
        at the next ``flush``. Thread-safe and device-free: callers hand
        over plain numpy scalars, the engine thread does all jax work."""
        fut: Future = Future()
        with self._lock:
            self._pending.append((arrival, fut, time.monotonic()))
            self._work.notify()
        return fut

    @property
    def n_pending(self) -> int:
        with self._lock:
            return len(self._pending)

    def flush(self) -> int:
        """Decide every pending request in fixed-width micro-batches (or one
        by one on the naive ablation path); resolves their futures. Returns
        the number of decisions made.

        The whole drain runs under ``_state_lock``: the ``_out`` check and
        the decides it gates are one critical section, so a concurrent
        ``tick()``/``metrics()`` cannot close the window mid-flight. A chunk
        that raises fails every remaining future with the exception instead
        of leaving callers blocked forever."""
        with self._state_lock:
            if self._out is None:
                raise RuntimeError("flush() before the first tick()")
            with self._lock:
                pending, self._pending = self._pending, []
            if not pending:
                return 0
            chunk = 1 if self.naive else self.width
            t0 = time.monotonic()
            done = 0
            try:
                with annotate("repro.engine.flush"):
                    for i in range(0, len(pending), chunk):
                        part = pending[i:i + chunk]
                        accept = self._decide([a for a, _, _ in part])
                        self._trace_part(part, accept)
                        for (_, fut, _), ok in zip(part, accept):
                            fut.set_result(bool(ok))
                        done = i + len(part)
            except BaseException as exc:
                for _, fut, _ in pending[done:]:
                    if not fut.done():
                        fut.set_exception(exc)
                raise
            cost = time.monotonic() - t0
            self._flush_cost_s = (cost if self._flush_cost_s == 0.0
                                  else 0.8 * self._flush_cost_s + 0.2 * cost)
            self.n_flushes += 1
        return len(pending)

    def _trace_part(self, part: list, accept: np.ndarray) -> None:
        """Record one decided micro-batch chunk: submit→decision latency
        into the host histogram, plus (when a tracer is attached) one
        structured record per decision with the policy score/threshold from
        the traced decide path. The diag arrays are materialized to numpy
        once per chunk before the record loop — indexing the device arrays
        per record would cost one device→host sync per decision."""
        t_dec = time.monotonic()
        diag = self._last_diag
        if diag is not None and self.tracer is not None:
            diag = jax.tree.map(np.asarray, diag)
        with self._state_lock:
            self._hist_batch.observe(float(len(part)))
            for j, ((_, _, t_sub), ok) in enumerate(zip(part, accept)):
                lat = t_dec - t_sub
                self._hist_latency.observe(lat)
                if self.flush_slo_s is not None and lat > self.flush_slo_s:
                    self.deadline_misses += 1
                if self.tracer is None:
                    continue
                self._req_id += 1
                rec = dict(step=self.ticks, req_id=self._req_id,
                           policy_kind=self._policy_info["kind"],
                           verdict=bool(ok), latency_s=lat,
                           batch_size=len(part))
                if diag is not None:
                    rec["score"] = diag.score[j]
                    rec["threshold"] = diag.threshold[j]
                    rec["fits"] = diag.fits[j]
                else:
                    rec["threshold"] = self._policy_info["threshold"]
                self.tracer.record(**rec)

    def decide_slice(self, stream_t: ArrivalStream,
                     valid: np.ndarray) -> np.ndarray:
        """Decide one pre-stacked width-``micro_batch`` arrival slice (the
        zero-copy path the equivalence tests and benchmarks drive; ``submit``
        + ``flush`` stack onto exactly this). Returns the ``[A]`` accept
        mask (for fleets: OR over the per-cluster ``[C, A]`` decisions)."""
        valid = jnp.asarray(valid)
        fn = self._j_naive if self.naive else self._j_decide
        with self._state_lock:
            # checked under the lock: a concurrent tick()/metrics() closing
            # the window flips _out to None mid-flight otherwise
            if self._out is None:
                raise RuntimeError("decide_slice() before the first tick()")
            self._last_diag = None
            if not self.fleet:
                if self.tracer is not None and not self.naive:
                    self._cs, accept, self._util, self._last_diag = \
                        self._j_decide_traced(self.policy, self._cs,
                                              self._util, stream_t, valid)
                else:
                    self._cs, accept, self._util = fn(
                        self.policy, self._cs, self._util, stream_t, valid)
                accept = np.asarray(accept)
                n_acc = float(np.sum(accept))
                self._acc += n_acc
                self._rej += float(np.sum(np.asarray(valid))) - n_acc
            else:
                rkey = jax.random.fold_in(self._step_key, self.n_c)
                (self._cs, accept_c, self._util, n_acc, n_rej,
                 self._rej_all) = fn(
                    self.policy, self._cs, self._util, stream_t, valid, rkey,
                    jnp.asarray(self._rej_all, jnp.float32))
                self._acc = self._acc + np.asarray(n_acc)
                self._rej = self._rej + np.asarray(n_rej)
                accept = np.asarray(jnp.any(accept_c, axis=0))
            self.decisions += int(np.sum(np.asarray(valid)))
        return accept

    def _decide(self, arrivals: list) -> np.ndarray:
        """Stack ``Arrival`` tickets into one padded fixed-width slice."""
        n = len(arrivals)
        width = 1 if self.naive else self.width
        lanes = [self._lane(a) for a in arrivals]
        lanes += [self._pad] * (width - n)
        batch = jax.tree.map(lambda *xs: np.stack(xs), *lanes)
        valid = np.arange(width) < n
        return self.decide_slice(batch, valid)[:n]

    def _lane(self, a: Arrival) -> ArrivalStream:
        return ArrivalStream(params=a.params, c0=np.float32(a.c0),
                             bel=a.bel, bel_alt=a.bel_alt,
                             n_arrivals=np.int32(1))

    def _pad_template(self) -> ArrivalStream:
        bel = jax.tree.map(np.asarray, belief_from_prior(self.base.priors, ()))
        params = DeploymentParams(lam=np.float32(0.0), mu=np.float32(1.0),
                                  sig=np.float32(0.0))
        return ArrivalStream(params=params, c0=np.float32(1.0), bel=bel,
                             bel_alt=bel, n_arrivals=np.int32(0))

    # ------------------------------------------------------------ async pump

    def start(self, interval_s: float = 0.001):
        """Run the flush loop on a background thread: concurrent submitters
        get their futures resolved as the engine coalesces the queue.

        Without ``flush_slo_ms`` this is the legacy pump (poll every
        ``interval_s``, drain whatever is queued). With ``flush_slo_ms`` set
        it is the deadline scheduler (``_deadline_loop``): fire a full
        micro-batch the moment ``width`` requests are pending, otherwise
        fire a partial batch when the oldest pending request approaches its
        latency SLO."""
        if self._pump is not None:
            raise RuntimeError("engine pump already running")
        self._stop.clear()
        target = (self._deadline_loop if self.flush_slo_s is not None
                  else lambda: self._pump_loop(interval_s))
        self._pump = threading.Thread(target=target, daemon=True)
        self._pump.start()

    def _pump_loop(self, interval_s: float):
        while not self._stop.is_set():
            t0 = time.monotonic()
            if self.n_pending:
                self.flush()
                self._pump_busy_s += time.monotonic() - t0
            else:
                self._stop.wait(interval_s)
                self._pump_idle_s += time.monotonic() - t0

    def _deadline_loop(self):
        """Latency-SLO-aware flush scheduler. Each ``submit()`` stamps its
        enqueue time; the oldest pending request's implicit deadline is
        ``t_sub + flush_slo_s``. Under load the width trigger fires full
        micro-batches (max throughput); at low rate the deadline trigger
        fires a partial batch a safety margin before the oldest request's
        deadline, where the margin is an EWMA of observed flush cost (so
        decisions land before — not at — the SLO) floored at 5% of the SLO.

        The condition's lock is released before flushing: ``flush()`` takes
        ``_state_lock`` then ``_lock``, and ``metrics_snapshot`` holds
        ``_state_lock`` while reading ``n_pending`` — flushing while holding
        ``_lock`` would invert that ordering and deadlock."""
        slo = self.flush_slo_s
        while not self._stop.is_set():
            fire = False
            with self._work:
                while not self._stop.is_set() and not fire:
                    if len(self._pending) >= self.width:
                        fire = True
                    elif self._pending:
                        margin = max(2.0 * self._flush_cost_s, 0.05 * slo)
                        due = self._pending[0][2] + slo - margin
                        wait = due - time.monotonic()
                        if wait <= 0.0:
                            fire = True
                        else:
                            self._work.wait(wait)
                    else:
                        t0 = time.monotonic()
                        self._work.wait()
                        self._pump_idle_s += time.monotonic() - t0
            if fire:
                t0 = time.monotonic()
                self.flush()
                self._pump_busy_s += time.monotonic() - t0

    def stop(self):
        if self._pump is None:
            return
        self._stop.set()
        with self._work:
            self._work.notify_all()
        self._pump.join()
        self._pump = None
        self.flush()

    # -------------------------------------------------------------- metrics

    def metrics(self):
        """Run-so-far metrics, assembled exactly as the offline drivers
        assemble theirs (same helpers, same arithmetic): ``RunMetrics`` for
        a single cluster, ``FleetMetrics`` for a fleet. After ``n_steps``
        ticks over a ``make_run`` event stream these equal the offline
        result bit-for-bit."""
        self._close_window()
        n_t = len(self._util_trace)
        horizon = (self.base.horizon_hours if n_t == self.base.n_steps
                   else max(n_t, 1) * self.base.dt)
        if n_t:
            util_trace = jnp.stack(self._util_trace)   # [T] / [T, C]
            fail_trace = jnp.stack(self._fail_trace)
        else:
            shape = (0, self.n_c) if self.fleet else (0,)
            util_trace = fail_trace = jnp.zeros(shape)
        if not self.fleet:
            return jax.tree.map(np.asarray, _run_metrics(
                self.base, self._cs.slots, util_trace, fail_trace,
                horizon_hours=horizon))
        return jax.tree.map(np.asarray, _fleet_metrics(
            self.base, self._caps, self._cs.slots, util_trace.T,
            fail_trace.T, jnp.asarray(self._rej_all, jnp.float32),
            horizon_hours=horizon))

    def metrics_snapshot(self) -> dict:
        """Non-blocking observability snapshot: engine counters, the
        decision-latency / flush-batch-size host histograms, and (with
        ``cfg.telemetry``) the device telemetry rider's summary.

        With a ``drift_detector`` attached, each scrape additionally feeds
        the detector one window of observable deltas (cumulative telemetry
        obs now minus the previous scrape — so the scrape cadence defines
        the detector window) and exports its state under ``"drift"``.

        Unlike ``metrics()`` this never closes the open window, never
        flushes, and never synchronizes with the pump: it holds the state
        lock only long enough to dispatch a ``jnp.copy`` of the telemetry
        leaves (async, cheap) and to snapshot the host histograms, then
        materializes the copy outside the lock — a Prometheus scrape cannot
        stall admission. Safe from any thread."""
        with self._state_lock:
            tel = self._cs.tel
            tel_copy = (jax.tree.map(jnp.copy, tel)
                        if tel is not None else None)
            idle, busy = self._pump_idle_s, self._pump_busy_s
            eng = {
                "n_requests": self.decisions,
                "n_flushes": self.n_flushes,
                "n_refreshes": self.n_refreshes,
                "n_ticks": self.ticks,
                "queue_depth": self.n_pending,
                "pump_idle_fraction": (idle / (idle + busy)
                                       if idle + busy > 0 else 0.0),
                "decision_latency_seconds": self._hist_latency.snapshot(),
                "flush_batch_size": self._hist_batch.snapshot(),
                "deadline_misses": self.deadline_misses,
                "flush_slo_ms": (0.0 if self.flush_slo_s is None
                                 else self.flush_slo_s * 1e3),
                "n_shards": self.n_shards,
            }
        snap = {"engine": eng}
        if tel_copy is not None:
            snap["telemetry"] = telemetry_summary(tel_copy)
            if self.drift is not None:
                from ..tuning.drift import channels_from_obs

                obs = snap["telemetry"]["obs"]
                with self._state_lock:
                    prev = self._drift_prev_obs
                    delta = (obs if prev is None else
                             {k: obs[k] - prev.get(k, 0.0) for k in obs})
                    self._drift_prev_obs = dict(obs)
                    self.drift.update(channels_from_obs(delta))
                    snap["drift"] = self.drift.snapshot()
        return snap


# ---------------------------------------------------------------------------
# Tuned operating points: committed BENCH_<scale>.json rows as the source of
# the daemon's default thresholds (same artifact-reader pattern as
# tuning.kcurve — no simulation, no benchmarks import, just the repo root).
# ---------------------------------------------------------------------------

OPERATING_ROW_PREFIX = "serve"

_OP_RE = re.compile(r"theta=(?P<th>[-\d.e+]+) capacity=(?P<cap>[-\d.e+]+)"
                    r" tau=(?P<tau>[-\d.e+]+)")


def operating_row_name(scale_name: str, kind_name: str) -> str:
    return f"{OPERATING_ROW_PREFIX}/{scale_name}/operating_point/{kind_name}"


def format_operating_derived(theta: float, capacity: float,
                             tau: float) -> str:
    return f"theta={theta:.6g} capacity={capacity:.6g} tau={tau:.3g}"


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """A tuned (theta, capacity, tau) admission operating point recorded in
    a BENCH artifact. ``theta`` is the threshold (zeroth/first, in cores —
    rescaled linearly when serving a different capacity) or rho (second,
    scale-free)."""

    kind_name: str
    theta: float
    capacity: float
    tau: float

    def theta_for(self, capacity: float) -> float:
        if self.kind_name == "second":
            return self.theta
        return self.theta * (capacity / self.capacity)


def load_operating_point(kind_name: str, scale_name: str = "quick",
                         bench_path: Optional[str] = None
                         ) -> Optional[OperatingPoint]:
    """Read the tuned operating point for a policy kind from the committed
    ``BENCH_<scale>.json`` (or ``bench_path`` / ``$REPRO_BENCH_JSON``).
    Returns ``None`` when no row exists — callers fall back to their
    hand-picked constants (and should warn)."""
    path = bench_path or os.environ.get("REPRO_BENCH_JSON") or os.path.join(
        _REPO_ROOT, f"BENCH_{scale_name}.json")
    try:
        with open(path, encoding="utf-8") as f:
            rows = json.load(f).get("rows", [])
    except (OSError, ValueError):
        return None
    name = operating_row_name(scale_name, kind_name)
    for row in rows:
        if row.get("name") != name:
            continue
        m = _OP_RE.match(row.get("derived", ""))
        if m:
            return OperatingPoint(kind_name=kind_name, theta=float(m["th"]),
                                  capacity=float(m["cap"]),
                                  tau=float(m["tau"]))
    return None


def default_policy_param(kind_name: str, capacity: float,
                         scale_name: str = "quick",
                         bench_path: Optional[str] = None) -> float:
    """The daemon's default threshold/rho: the tuned operating point from
    the committed BENCH artifact, rescaled to ``capacity``; the legacy
    hand-picked constants (0.15 / 0.7 * capacity) only as a warned
    fallback."""
    op = load_operating_point(kind_name, scale_name, bench_path)
    if op is not None:
        return op.theta_for(capacity)
    warnings.warn(
        f"no tuned operating point for policy {kind_name!r} at scale "
        f"{scale_name!r} (run benchmarks.serve_bench to record one); "
        "falling back to hand-picked constants", stacklevel=2)
    return 0.15 if kind_name == "second" else 0.7 * capacity
