"""Online serving layer: the continuous-batching LM engine (``engine``) and
the live micro-batched admission service built on the shared admission core
(``admission``)."""
from .admission import (Arrival, ExternalEvents, OnlineAdmissionEngine,
                        OperatingPoint, default_policy_param,
                        format_operating_derived, load_operating_point,
                        operating_row_name)
from .engine import Request, ServeEngine
from ..obs.export import MetricsServer, snapshot_to_prometheus

__all__ = [
    "Arrival", "ExternalEvents", "OnlineAdmissionEngine", "OperatingPoint",
    "default_policy_param", "format_operating_derived",
    "load_operating_point", "operating_row_name", "Request", "ServeEngine",
    "MetricsServer", "snapshot_to_prometheus",
]
