"""Batched serving engine: continuous-batching decode loop over a KV cache.

Requests enter a waiting queue; each engine step either (a) prefills a
waiting request into a free cache slot or (b) decodes one token for every
active slot. Slots whose sequence emits EOS (or hits max_new_tokens) free
their cache row. This is the vLLM-style loop reduced to its essentials, and
is the workload the paper's admission controller gates in
examples/admission_serving.py (an engine = a deployment whose "cores" are
cache slots that scale out with load).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, max_batch: int = 8,
                 max_seq: int = 512, eos_id: int = 1, mesh=None,
                 prefill_mode: str = "fused"):
        if prefill_mode not in ("fused", "loop"):
            raise ValueError(f"prefill_mode must be fused|loop: {prefill_mode}")
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.mesh = mesh
        self.prefill_mode = prefill_mode
        self.cache = model.init_cache(max_batch, max_seq, dtype=jnp.float32)
        self.active: list[Optional[Request]] = [None] * max_batch
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        self.tokens = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, mesh))

        def _prefill_scan(p, toks, cache, prompt, slot):
            def body(c, tok):
                _, c = model.decode_step(p, toks.at[slot].set(tok), c, mesh)
                return c, None
            return jax.lax.scan(body, cache, prompt)[0]

        # one dispatch per prompt instead of one per token; retraced per
        # distinct prompt length (scan lengths are static)
        self._prefill = jax.jit(_prefill_scan)

    # -- queue management -----------------------------------------------------

    def submit(self, req: Request):
        self.waiting.append(req)

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.active)

    def _admit_one(self) -> bool:
        """Prefill one waiting request into a free slot (single-slot prefill:
        decode its prompt token by token into the shared cache row)."""
        if not self.waiting:
            return False
        try:
            slot = self.active.index(None)
        except ValueError:
            return False
        req = self.waiting.pop(0)
        # teacher-force the prompt through decode steps for this slot only
        # (other slots re-decode their current token, exactly as in the
        # token-by-token loop, so both modes advance the cache identically)
        if len(req.prompt) > 1:
            if self.prefill_mode == "fused":
                self.cache = self._prefill(
                    self.params, jnp.asarray(self.tokens), self.cache,
                    jnp.asarray(req.prompt[:-1]), jnp.int32(slot))
            else:
                for tok in req.prompt[:-1]:
                    step_tokens = self.tokens.copy()
                    step_tokens[slot] = tok
                    _, self.cache = self._decode(
                        self.params, jnp.asarray(step_tokens), self.cache)
        self.tokens[slot] = int(req.prompt[-1])
        self.active[slot] = req
        return True

    def step(self) -> int:
        """One engine step; returns number of tokens emitted."""
        self._admit_one()
        if self.n_active == 0:
            return 0
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.tokens), self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        emitted = 0
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self.tokens[slot] = tok
            emitted += 1
            if tok == self.eos_id or len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.active[slot] = None
                self.finished.append(req)
        return emitted

    def run_until_drained(self, max_steps: int = 10_000) -> list:
        """Step until queues empty; returns the requests completed during
        this call (in completion order)."""
        n0 = len(self.finished)
        for _ in range(max_steps):
            if not self.waiting and self.n_active == 0:
                break
            self.step()
        return self.finished[n0:]
