"""Batched serving engine: continuous-batching decode loop over a KV cache.

Requests enter a waiting queue; each engine step either (a) prefills a
waiting request into a free cache slot or (b) decodes one token for every
active slot. Slots whose sequence emits EOS (or hits max_new_tokens) free
their cache row. This is the vLLM-style loop reduced to its essentials, and
is the workload the paper's admission controller gates in
examples/admission_serving.py (an engine = a deployment whose "cores" are
cache slots that scale out with load).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 32
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, max_batch: int = 8,
                 max_seq: int = 512, eos_id: int = 1, mesh=None):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.mesh = mesh
        self.cache = model.init_cache(max_batch, max_seq, dtype=jnp.float32)
        self.active: list[Optional[Request]] = [None] * max_batch
        self.waiting: list[Request] = []
        self.tokens = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, mesh))

    # -- queue management -----------------------------------------------------

    def submit(self, req: Request):
        self.waiting.append(req)

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.active)

    def _admit_one(self) -> bool:
        """Prefill one waiting request into a free slot (single-slot prefill:
        decode its prompt token by token into the shared cache row)."""
        if not self.waiting:
            return False
        try:
            slot = self.active.index(None)
        except ValueError:
            return False
        req = self.waiting.pop(0)
        # teacher-force the prompt through decode steps for this slot only
        for tok in req.prompt[:-1]:
            step_tokens = self.tokens.copy()
            step_tokens[slot] = tok
            logits, self.cache = self._decode(
                self.params, jnp.asarray(step_tokens), self.cache)
        self.tokens[slot] = int(req.prompt[-1])
        self.active[slot] = req
        return True

    def step(self) -> int:
        """One engine step; returns number of tokens emitted."""
        self._admit_one()
        if self.n_active == 0:
            return 0
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.tokens), self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        emitted = 0
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.out_tokens.append(tok)
            self.tokens[slot] = tok
            emitted += 1
            if tok == self.eos_id or len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.active[slot] = None
        return emitted

    def run_until_drained(self, max_steps: int = 10_000) -> list:
        done = []
        for _ in range(max_steps):
            if not self.waiting and self.n_active == 0:
                break
            self.step()
        return done
