"""Per-scenario threshold re-tuning over the trace scenario registry.

The quick scenario sweep (PR 2) replays *stationary-tuned* policies under
non-stationary traces on purpose — that measures robustness. The ROADMAP's
open item is the other half: re-tune each policy **against the scenario's own
arrivals** at the same SLA target, so the robustness gap (stationary-tuned
vs re-tuned utilization at matched SLA) is measured rather than implied.

``replay_stream_batch`` synthesizes a per-run trace ensemble for a scenario
and stacks the replay streams; ``calibrate_scenario`` evaluates the
stationary parameter and runs a full ``tuning.calibrate`` on those exact
streams — same keys, same arrivals, only the parameter differs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from ..sim.simulator import ArrivalStream, SimConfig
from ..traces import synthesize_scenario, trace_to_stream
from .calibrate import CalibrationResult, calibrate, eval_theta_grid, sla_ci


def replay_stream_batch(trace_key, run_key, scenario: str, spec, cfg: SimConfig,
                        n_runs: int):
    """One scenario -> a stacked [R] replay-stream batch plus [R] run keys.

    Each run gets its own synthesized trace (an iid draw of the scenario's
    arrival process) so the batch estimates the scenario's population, not a
    single trace. Run keys come from a distinct root: within-run randomness
    (deaths, scale-out timing) must not correlate with the replayed arrivals.
    Returns ``(streams, run_keys, n_dropped)`` — dropped counts arrivals lost
    to the per-step ``cfg.max_arrivals`` cap, summed over the batch.
    """
    t_keys = jax.random.split(trace_key, n_runs)
    run_keys = jax.random.split(run_key, n_runs)
    streams, dropped = [], 0
    for tk in t_keys:
        s, n_drop = trace_to_stream(synthesize_scenario(tk, scenario, spec),
                                    cfg)
        streams.append(s)
        dropped += int(n_drop)
    stacked = jax.tree.map(lambda *xs: np.stack(xs), *streams)
    return stacked, run_keys, dropped


@dataclasses.dataclass(frozen=True)
class ScenarioCalibration:
    """Stationary-tuned vs re-tuned operating points on identical arrivals."""

    scenario: str
    kind: int
    stationary_theta: float
    stationary_util: float
    stationary_sla: float     # aggregate failure rate at the stationary theta
    retuned: CalibrationResult

    @property
    def util_gap(self) -> float:
        """Re-tuned minus stationary utilization: what re-tuning buys (or,
        when the stationary theta was SLA-violating under this scenario,
        what honoring the SLA costs)."""
        return self.retuned.utilization - self.stationary_util


def calibrate_scenario(
    run_fn,
    kind: int,
    scenario: str,
    streams: ArrivalStream,
    run_keys,
    *,
    capacity: float,
    tau: float,
    stationary_theta: float,
    n_grid: int = 8,
    max_stages: int = 2,
    marginal: bool = False,
    devices=None,
) -> ScenarioCalibration:
    """Measure the robustness gap for one (scenario, policy kind) pair.

    Evaluates the stationary-tuned ``stationary_theta`` and a full SLA
    re-calibration on the **same** stacked replay streams and run keys, so
    the two operating points differ only in the parameter. ``run_fn`` must
    be built for the replay config the streams were made with.
    """
    m = eval_theta_grid(run_fn, kind, [stationary_theta], run_keys,
                        capacity=capacity, marginal=marginal, streams=streams,
                        devices=devices)
    fails = np.asarray(m.failed_requests)[0]
    reqs = np.asarray(m.total_requests)[0]
    stat_sla, _, _ = sla_ci(fails, reqs)
    stat_util = float(np.mean(np.asarray(m.utilization)[0]))

    retuned = calibrate(run_fn, kind, run_keys, capacity=capacity, tau=tau,
                        n_grid=n_grid, max_stages=max_stages,
                        marginal=marginal, streams=streams, devices=devices)
    return ScenarioCalibration(
        scenario=scenario, kind=kind,
        stationary_theta=float(stationary_theta),
        stationary_util=stat_util, stationary_sla=float(stat_sla),
        retuned=retuned,
    )
