"""Calibration subsystem: batched SLA tuning, per-scenario re-tuning, and
the ``agg_refresh`` K-curve (paper §5.2, as first-class testable code).

  * ``calibrate``  — whole-theta-grid SLA-constrained search in one
    device-sharded batched pass, CI-aware stage stopping, every policy kind
    in ``core.policies``; the serial ``core.policies.tune_threshold``
    bisection stays as the reference oracle the tests compare against.
  * ``scenarios``  — re-tune a policy against a trace scenario's own replay
    streams and report stationary-tuned vs re-tuned operating points at
    matched SLA (the robustness gap, measured).
  * ``kcurve``     — utilization and SLA-slack vs ``agg_refresh_steps``,
    recorded into BENCH artifacts; ``pick_agg_refresh`` selects the
    per-scale refresh interval from the measured curve instead of by hand.
  * ``drift``      — drift-aware streaming recalibration: censoring-robust
    drift channels over the windowed sufficient statistics, a Monte-Carlo-
    calibrated two-sided CUSUM detector (offline over replay windows and
    live via the engine's telemetry), warm-started re-tuning around the
    incumbent, and the never/triggered/oracle regret protocol.
"""
from .calibrate import (SPACE_LINEAR, SPACE_LOG10, CalibrationResult,
                        ProbeStage, calibrate, eval_theta_grid, from_param,
                        sla_ci, theta_space, to_param)
from .scenarios import (ScenarioCalibration, calibrate_scenario,
                        replay_stream_batch)
from .kcurve import (DEFAULT_UTIL_TOL, KPoint, format_kcurve_derived,
                     kcurve_divisors, kcurve_row_name, load_kcurve,
                     parse_kcurve_rows, pick_agg_refresh, pick_from_curve,
                     sweep_kcurve)
from .drift import (DRIFT_CHANNELS, DriftArm, DriftDetector, DriftNull,
                    DriftProtocolResult, DriftReport, DriftUpdate,
                    calibrate_drift_detector, channels_from_obs,
                    channels_from_stats, detect_drift, retune_warm,
                    run_drift_protocol, warm_theta_bounds,
                    window_channel_values)

__all__ = [
    "SPACE_LINEAR", "SPACE_LOG10", "CalibrationResult", "ProbeStage",
    "calibrate", "eval_theta_grid", "from_param", "sla_ci", "theta_space",
    "to_param",
    "ScenarioCalibration", "calibrate_scenario", "replay_stream_batch",
    "DEFAULT_UTIL_TOL", "KPoint", "format_kcurve_derived", "kcurve_divisors",
    "kcurve_row_name", "load_kcurve", "parse_kcurve_rows", "pick_agg_refresh",
    "pick_from_curve", "sweep_kcurve",
    "DRIFT_CHANNELS", "DriftArm", "DriftDetector", "DriftNull",
    "DriftProtocolResult", "DriftReport", "DriftUpdate",
    "calibrate_drift_detector", "channels_from_obs", "channels_from_stats",
    "detect_drift", "retune_warm", "run_drift_protocol", "warm_theta_bounds",
    "window_channel_values",
]
