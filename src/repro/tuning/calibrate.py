"""Batched SLA-constrained policy calibration (paper §5.2, as a subsystem).

The paper tunes every admission policy's free parameter by binary search
subject to the SLA and re-tunes whenever the environment changes. The serial
reference (``core.policies.tune_threshold``) pays one full simulation batch
per probe; here the whole candidate grid is evaluated in **one** pass:

  * the theta grid [T] and the run-key batch [R] are flattened into a single
    [T*R] batch of (key, theta[, stream]) triples and pushed through the same
    device-sharded vmap machinery as ``sim.run_keyed_batch`` (policy
    parameters are traced, so one compile serves every candidate);
  * run keys are **shared across thetas** (common random numbers), so the
    empirical SLA curve is monotone-by-construction up to trajectory
    divergence and candidate grids are comparable point by point;
  * selection is by **value**, not grid position: the largest feasible theta
    wins, so the result is invariant to grid permutation and to how the
    batch was sharded across devices (property-tested);
  * refinement stages tighten the grid around the winner only while the SLA
    estimate's confidence interval still straddles the target — once the
    measured failure rate separates from tau, more grid resolution is
    statistical noise (CI-aware stopping).

Replay calibration: pass ``streams`` (a stacked [R] ``ArrivalStream`` batch,
one per run key) and every theta is evaluated against those exact arrivals —
this is what per-scenario re-tuning (``tuning.scenarios``) builds on.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.policies import SECOND, make_policy
from ..sim.simulator import (ArrivalStream, _pad_batch,
                             shard_batch_over_devices)

#: search-space coordinates per policy kind: SECOND tunes the Cantelli rho on
#: a log10 grid (the feasible range spans ~4 decades); the threshold kinds
#: tune cores linearly as fractions of capacity.
SPACE_LINEAR, SPACE_LOG10 = "linear", "log10"


def theta_space(kind: int, capacity: float,
                lo: Optional[float] = None,
                hi: Optional[float] = None) -> tuple[float, float, str]:
    """Default (lo, hi, space) search bounds for a policy kind.

    Bounds are expressed in *search* coordinates: raw cores for the
    threshold policies, log10(rho) for the second-moment policy. Explicit
    ``lo``/``hi`` override the defaults (still in search coordinates).
    """
    if kind == SECOND:
        return (np.log10(2e-4) if lo is None else lo,
                np.log10(0.9) if hi is None else hi, SPACE_LOG10)
    from ..core.policies import ZEROTH

    return (0.2 * capacity if lo is None else lo,
            (1.0 if kind == ZEROTH else 1.05) * capacity if hi is None else hi,
            SPACE_LINEAR)


def to_param(x, space: str):
    """Search coordinate -> policy parameter."""
    return 10.0 ** x if space == SPACE_LOG10 else x


def from_param(p, space: str):
    """Policy parameter -> search coordinate."""
    return np.log10(p) if space == SPACE_LOG10 else p


def sla_ci(fails: np.ndarray, reqs: np.ndarray,
           z: float = 1.96) -> tuple[float, float, float]:
    """Cluster-robust normal CI for the aggregate SLA failure rate.

    Failures are concentrated in tail runs, so a per-request binomial CI
    would be wildly anti-conservative; treat each *run* as the sampling unit
    (ratio estimator over run totals, variance from run-level residuals).
    Returns ``(rate, lo, hi)``; a batch with zero observed failures has a
    degenerate [0, 0] interval — separated below any positive target.
    """
    f = np.asarray(fails, dtype=np.float64)
    r = np.asarray(reqs, dtype=np.float64)
    n = len(f)
    tot_r = max(r.sum(), 1.0)
    rate = f.sum() / tot_r
    if n < 2:
        return float(rate), float(rate), float(rate)
    resid = f - rate * r
    var = np.sum(resid**2) * n / (n - 1)
    se = np.sqrt(var) / tot_r
    return float(rate), float(max(rate - z * se, 0.0)), float(rate + z * se)


@dataclasses.dataclass(frozen=True)
class ProbeStage:
    """One evaluated candidate grid: thetas (parameter space) with the
    aggregate failure rate and per-run utilizations measured at each."""

    thetas: np.ndarray      # [T] parameter-space candidates
    agg_fail: np.ndarray    # [T] aggregate failure rate over the run batch
    util: np.ndarray        # [T, R] per-run utilizations


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """Output of ``calibrate``: the tuned parameter plus the evidence."""

    kind: int
    theta: float            # tuned parameter (largest SLA-feasible candidate)
    feasible: bool          # did any candidate meet the SLA?
    tau: float              # the SLA target calibrated against
    sla_fail: float         # measured aggregate failure rate at theta
    sla_lo: float           # cluster-robust CI on sla_fail
    sla_hi: float
    separated: bool         # CI no longer straddles tau (stopping condition)
    utilization: float      # mean utilization at theta
    util_runs: np.ndarray   # [R] per-run utilizations at theta (for BCa CIs)
    grid_step: float        # final-stage grid spacing, search coordinates
    space: str              # SPACE_LINEAR | SPACE_LOG10
    stages: tuple           # tuple[ProbeStage] — every grid evaluated
    n_sims: int             # total full simulations spent


# calibrate builds one flat batched evaluator per (run_fn, kind, ...); cache
# the jitted/sharded wrappers so repeated calibrations (scenario sweeps, the
# K-curve) re-trace neither the vmap nor the shard_map. Mirrors
# simulator._SHARDED_RUN_CACHE.
_EVAL_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_EVAL_CACHE_MAX = 16


def _theta_batch_fn(run_fn, kind: int, capacity: float, marginal: bool,
                    has_streams: bool, devices, policy_fn=None):
    """Flat [T*R] (key, theta[, stream]) evaluator, device-sharded on a
    multi-device host (ragged flat batches are padded by the caller).

    ``policy_fn(theta) -> PolicyParams`` overrides the default scalar
    ``make_policy`` construction — the fleet path passes a
    ``core.policies.fleet_policy`` closure so every candidate theta becomes
    a cluster-axis-broadcast policy inside the same flattened pass. Reuse
    one function object across calls to keep the compiled-wrapper cache hot.
    """
    cache_key = (run_fn, kind, float(capacity), marginal, policy_fn,
                 has_streams, devices)
    fn = _EVAL_CACHE.get(cache_key)
    if fn is not None:
        _EVAL_CACHE.move_to_end(cache_key)
        return fn

    if policy_fn is None:
        policy_fn = lambda theta: make_policy(
            kind, threshold=theta, rho=theta, capacity=capacity,
            marginal=marginal)

    if has_streams:
        def one(key, theta, stream):
            return run_fn(key, policy_fn(theta), stream)

        batched = jax.vmap(one, in_axes=(0, 0, 0))
        n_batch = 3
    else:
        def one(key, theta):
            return run_fn(key, policy_fn(theta))

        batched = jax.vmap(one, in_axes=(0, 0))
        n_batch = 2

    if len(devices) > 1:
        fn = shard_batch_over_devices(batched, devices, "cal",
                                      n_batch_args=n_batch)
    else:
        fn = jax.jit(batched)
    _EVAL_CACHE[cache_key] = fn
    while len(_EVAL_CACHE) > _EVAL_CACHE_MAX:
        _EVAL_CACHE.popitem(last=False)
    return fn


def eval_theta_grid(run_fn, kind: int, thetas, keys, *, capacity: float,
                    marginal: bool = False,
                    streams: Optional[ArrivalStream] = None,
                    devices=None, policy_fn=None):
    """Evaluate a whole [T] parameter grid over a shared [R] key batch in one
    device-sharded pass; returns ``RunMetrics`` with leading shape [T, R].

    Keys (and replay streams, when given) are shared across thetas — common
    random numbers — so grid points differ only through the policy.
    ``policy_fn(theta)`` overrides how a candidate becomes a ``PolicyParams``
    (see ``_theta_batch_fn``); with a fleet ``run_fn`` the returned pytree is
    ``FleetMetrics`` — its fleet-level fields reshape the same way.

    A flat [T*R] batch that does not divide the device count is padded to
    the next multiple (repeating its last triple) and sliced afterwards —
    same treatment as ``run_keyed_batch``, no silent single-device fallback.
    """
    thetas = jnp.asarray(thetas, jnp.float32)
    keys = jnp.asarray(keys)
    t_n, r_n = thetas.shape[0], keys.shape[0]
    n_flat = t_n * r_n
    devices = tuple(jax.devices() if devices is None else devices)

    thetas_flat = jnp.repeat(thetas, r_n)
    keys_flat = jnp.tile(keys, (t_n, 1))
    args = (keys_flat, thetas_flat)
    if streams is not None:
        tile = lambda x: jnp.tile(x, (t_n,) + (1,) * (x.ndim - 1))
        args = args + (jax.tree.map(tile, streams),)
    pad = (-n_flat) % len(devices) if len(devices) > 1 else 0
    args = _pad_batch(args, len(args), pad)
    fn = _theta_batch_fn(run_fn, kind, capacity, marginal, streams is not None,
                         devices, policy_fn=policy_fn)
    metrics = fn(*args)
    if pad:
        metrics = jax.tree.map(lambda x: x[:n_flat], metrics)
    return jax.tree.map(lambda x: x.reshape((t_n, r_n) + x.shape[1:]), metrics)


def calibrate(
    run_fn,
    kind: int,
    keys,
    *,
    capacity: float,
    tau: float,
    lo: Optional[float] = None,
    hi: Optional[float] = None,
    n_grid: int = 8,
    thetas: Optional[Sequence[float]] = None,
    max_stages: int = 3,
    marginal: bool = False,
    streams: Optional[ArrivalStream] = None,
    devices=None,
    z: float = 1.96,
    policy_fn=None,
) -> CalibrationResult:
    """SLA-constrained calibration of one policy's free parameter.

    Evaluates candidate grids of ``n_grid`` thetas (each grid in a single
    batched, device-sharded pass over the shared ``keys``), picks the largest
    candidate whose aggregate failure rate meets ``tau``, and tightens the
    grid around the winner for up to ``max_stages`` stages — stopping early
    once the winner's SLA confidence interval separates from ``tau``
    (see ``sla_ci``; further grid resolution below the estimator's noise
    floor is meaningless).

    ``thetas`` (parameter space) overrides the generated grid and implies a
    single stage — the oracle/property tests use this for determinism.
    ``streams`` calibrates against a fixed stacked [R] replay-stream batch
    instead of prior-sampled arrivals (per-scenario re-tuning).
    ``policy_fn(theta)`` overrides candidate-policy construction — pass a
    ``core.policies.fleet_policy`` closure (and the fleet's *total* capacity
    as ``capacity``, so the search bounds scale correctly) to tune
    heterogeneous per-cluster thresholds of a ``make_fleet_run`` simulator
    in the same flattened device-sharded pass.

    The result is invariant to permutation of the candidate grid and to the
    device sharding of the flat batch: selection is by candidate *value* and
    every candidate sees the identical key batch.
    """
    keys = jnp.asarray(keys)
    x_lo, x_hi, space = theta_space(kind, capacity, lo, hi)
    x0_lo, x0_hi = x_lo, x_hi
    explicit = thetas is not None
    if explicit:
        max_stages = 1

    stages = []
    n_sims = 0
    best = None
    for _stage in range(max_stages):
        if explicit:
            theta_vec = np.asarray(thetas, dtype=np.float64)
            xs = from_param(theta_vec, space)
        else:
            xs = np.linspace(x_lo, x_hi, n_grid)
            theta_vec = np.asarray([to_param(x, space) for x in xs])
        m = eval_theta_grid(run_fn, kind, theta_vec, keys, capacity=capacity,
                            marginal=marginal, streams=streams,
                            devices=devices, policy_fn=policy_fn)
        fails = np.asarray(m.failed_requests)   # [T, R]
        reqs = np.asarray(m.total_requests)
        utils = np.asarray(m.utilization)
        n_sims += fails.size
        agg_fail = fails.sum(1) / np.maximum(reqs.sum(1), 1.0)
        stages.append(ProbeStage(thetas=theta_vec, agg_fail=agg_fail,
                                 util=utils))

        feasible = agg_fail <= tau
        if feasible.any():
            # by value, not index: permutation/sharding invariance
            idx = int(np.argmax(np.where(feasible, theta_vec, -np.inf)))
            any_feasible = True
        else:
            idx = int(np.argmin(theta_vec))
            any_feasible = False
        rate, ci_lo, ci_hi = sla_ci(fails[idx], reqs[idx], z=z)
        span = ((np.max(xs) - np.min(xs)) / max(len(xs) - 1, 1)
                if len(xs) > 1 else 0.0)
        best = {
            "theta": float(theta_vec[idx]), "feasible": any_feasible,
            "sla_fail": rate, "sla_lo": ci_lo, "sla_hi": ci_hi,
            "util_runs": utils[idx], "grid_step": float(span),
        }
        separated = not (ci_lo <= tau <= ci_hi)
        if separated or span == 0.0:
            break
        # tighten around the winner (search coordinates), clipped to the
        # original bounds so refinement never escapes the search space
        x_star = from_param(best["theta"], space)
        x_lo = max(x_star - span, x0_lo)
        x_hi = min(x_star + span, x0_hi)

    return CalibrationResult(
        kind=kind, theta=best["theta"], feasible=best["feasible"], tau=tau,
        sla_fail=best["sla_fail"], sla_lo=best["sla_lo"],
        sla_hi=best["sla_hi"], separated=separated,
        utilization=float(np.mean(best["util_runs"])),
        util_runs=np.asarray(best["util_runs"]),
        grid_step=best["grid_step"], space=space, stages=tuple(stages),
        n_sims=n_sims,
    )
