"""The ``agg_refresh_steps`` K-curve: measure it, record it, select from it.

The simulator's scan is blocked by ``agg_refresh_steps`` (= K): the
cluster-wide aggregate moment curves are fully recomputed once per block and
maintained incrementally in between. Staleness cuts both ways — missed
deaths are conservative, missed scale-out growth is optimistic — and the
residual bias is absorbed by threshold tuning *at the same K*. So the honest
way to pick K is a measured curve: sweep K at the fixed stationary-tuned
theta **and** with the theta re-tuned per K, record utilization and
SLA-slack (tau minus the measured failure rate) against K, and pick the
largest K that keeps the re-tuned operating point SLA-feasible without
giving up utilization.

``benchmarks/tuning_bench.py`` runs the sweep and records one row per K into
``BENCH_<scale>.json``; ``pick_agg_refresh`` reads the recorded curve back
(committed artifact — no simulation at import time) and is what
``benchmarks/common.sim_config`` consumes instead of the previously
hand-picked 4/8/12 per preset.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Optional, Sequence

import jax
import numpy as np

from ..sim.simulator import SimConfig, make_run
from .calibrate import calibrate, eval_theta_grid, sla_ci

_REPO_ROOT = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))

#: a K-point this close to the best re-tuned utilization counts as "free"
DEFAULT_UTIL_TOL = 0.01


def kcurve_divisors(n_steps: int, k_max: int = 16) -> list[int]:
    """Candidate refresh intervals: divisors of ``n_steps`` up to ``k_max``
    (the scan requires K | n_steps; see ``SimConfig`` validation)."""
    return [k for k in range(1, k_max + 1) if n_steps % k == 0]


@dataclasses.dataclass(frozen=True)
class KPoint:
    """One measured K: operating points at fixed and re-tuned thetas."""

    k: int
    theta_fixed: float
    util_fixed: float
    slack_fixed: float        # tau - sla_fail at the fixed theta
    theta_retuned: float
    util_retuned: float
    slack_retuned: float
    retuned_feasible: bool


def sweep_kcurve(
    cfg: SimConfig,
    grid,
    kind: int,
    keys,
    *,
    tau: float,
    ks: Optional[Sequence[int]] = None,
    theta_fixed: Optional[float] = None,
    n_grid: int = 6,
    max_stages: int = 2,
    devices=None,
) -> list[KPoint]:
    """Measure the K-curve for one policy kind.

    ``theta_fixed`` defaults to a calibration at the smallest K in ``ks``
    (the least-stale reference); each K then gets (a) that fixed theta
    evaluated as-is — the bias you eat by *not* re-tuning after changing K —
    and (b) a full re-calibration at that K, which is the operating point a
    deployment would actually run. All evaluations share ``keys`` (common
    random numbers), so the curve is smooth in K up to trajectory divergence.
    """
    ks = kcurve_divisors(cfg.n_steps) if ks is None else sorted(ks)
    if not ks:
        raise ValueError(f"no candidate K divides n_steps={cfg.n_steps}")
    ref_cfg = cfg._replace(agg_refresh_steps=ks[0])
    ref_run = make_run(ref_cfg, grid, kind)
    ref = None
    if theta_fixed is None:
        ref = calibrate(ref_run, kind, keys, capacity=cfg.capacity, tau=tau,
                        n_grid=n_grid, max_stages=max_stages, devices=devices)
        theta_fixed = ref.theta

    points = []
    for k in ks:
        run_fn = (ref_run if k == ks[0]
                  else make_run(cfg._replace(agg_refresh_steps=k), grid, kind))
        m = eval_theta_grid(run_fn, kind, [theta_fixed], keys,
                            capacity=cfg.capacity, devices=devices)
        sla_f, _, _ = sla_ci(np.asarray(m.failed_requests)[0],
                             np.asarray(m.total_requests)[0])
        util_f = float(np.mean(np.asarray(m.utilization)[0]))
        if k == ks[0] and ref is not None:
            res = ref  # the reference calibration IS this K's re-tune
        else:
            res = calibrate(run_fn, kind, keys, capacity=cfg.capacity,
                            tau=tau, n_grid=n_grid, max_stages=max_stages,
                            devices=devices)
        points.append(KPoint(
            k=int(k), theta_fixed=float(theta_fixed), util_fixed=util_f,
            slack_fixed=float(tau - sla_f), theta_retuned=res.theta,
            util_retuned=res.utilization,
            slack_retuned=float(tau - res.sla_fail),
            retuned_feasible=res.feasible,
        ))
    return points


def pick_from_curve(points: Sequence[KPoint],
                    util_tol: float = DEFAULT_UTIL_TOL) -> int:
    """Select K from a measured curve: among K whose *re-tuned* operating
    point is SLA-feasible (slack >= 0) and within ``util_tol`` of the best
    re-tuned utilization, take the largest (refresh cost falls ~linearly in
    K). Falls back to the smallest measured K when nothing is feasible."""
    if not points:
        raise ValueError("empty K-curve")
    ok = [p for p in points if p.retuned_feasible and p.slack_retuned >= 0.0]
    if not ok:
        return min(points, key=lambda p: p.k).k
    best_util = max(p.util_retuned for p in ok)
    free = [p for p in ok if p.util_retuned >= best_util - util_tol]
    return max(free, key=lambda p: p.k).k


# ---------------------------------------------------------------------------
# BENCH_<scale>.json (de)serialization — the bench rows are the persistence
# format, so the writer (benchmarks/tuning_bench.py) and the reader
# (pick_agg_refresh via load_kcurve) share these two functions.
# ---------------------------------------------------------------------------

KCURVE_ROW_PREFIX = "tuning/kcurve"

_DERIVED_RE = re.compile(
    r"util_fixed=(?P<uf>[-\d.e+]+) slack_fixed=(?P<sf>[-\d.e+]+)"
    r" util_retuned=(?P<ur>[-\d.e+]+) slack_retuned=(?P<sr>[-\d.e+]+)"
    r" theta_fixed=(?P<tf>[-\d.e+]+) theta_retuned=(?P<tr>[-\d.e+]+)"
    r" feasible=(?P<fe>[01])")


def kcurve_row_name(scale_name: str, k: int) -> str:
    return f"{KCURVE_ROW_PREFIX}/{scale_name}/K={k}"


def format_kcurve_derived(p: KPoint) -> str:
    return (f"util_fixed={p.util_fixed:.4f} slack_fixed={p.slack_fixed:.3e}"
            f" util_retuned={p.util_retuned:.4f}"
            f" slack_retuned={p.slack_retuned:.3e}"
            f" theta_fixed={p.theta_fixed:.6g}"
            f" theta_retuned={p.theta_retuned:.6g}"
            f" feasible={int(p.retuned_feasible)}")


def parse_kcurve_rows(rows, scale_name: str) -> list[KPoint]:
    """Recover KPoints from BENCH rows (``{"name": ..., "derived": ...}``)."""
    prefix = f"{KCURVE_ROW_PREFIX}/{scale_name}/K="
    points = []
    for row in rows:
        name = row.get("name", "")
        if not name.startswith(prefix):
            continue
        m = _DERIVED_RE.match(row.get("derived", ""))
        if not m:
            continue
        points.append(KPoint(
            k=int(name[len(prefix):]),
            theta_fixed=float(m["tf"]), util_fixed=float(m["uf"]),
            slack_fixed=float(m["sf"]), theta_retuned=float(m["tr"]),
            util_retuned=float(m["ur"]), slack_retuned=float(m["sr"]),
            retuned_feasible=m["fe"] == "1",
        ))
    return sorted(points, key=lambda p: p.k)


_BENCH_CACHE: dict = {}


def _read_bench_rows(path: str):
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return None
    cached = _BENCH_CACHE.get(path)
    if cached is not None and cached[0] == mtime:
        return cached[1]
    try:
        with open(path, encoding="utf-8") as f:
            rows = json.load(f).get("rows", [])
    except (OSError, ValueError):
        return None
    _BENCH_CACHE[path] = (mtime, rows)
    return rows


def load_kcurve(scale_name: str,
                bench_path: Optional[str] = None) -> list[KPoint]:
    """The recorded K-curve for a scale, from committed BENCH artifacts.

    Looks in ``bench_path`` when given (or ``$REPRO_BENCH_JSON``), otherwise
    ``BENCH_<scale>.json`` at the repo root — row names carry the scale
    (``tuning/kcurve/<scale>/K=...``), so only rows measured at this scale
    ever parse. Returns ``[]`` when no curve has been recorded yet."""
    candidates = ([bench_path] if bench_path else
                  ([os.environ["REPRO_BENCH_JSON"]]
                   if os.environ.get("REPRO_BENCH_JSON") else
                   [os.path.join(_REPO_ROOT, f"BENCH_{scale_name}.json")]))
    for path in candidates:
        rows = _read_bench_rows(path)
        if rows is None:
            continue
        points = parse_kcurve_rows(rows, scale_name)
        if points:
            return points
    return []


def pick_agg_refresh(scale_name: str, *, fallback: int = 1,
                     n_steps: Optional[int] = None,
                     bench_path: Optional[str] = None,
                     util_tol: float = DEFAULT_UTIL_TOL) -> int:
    """Per-scale refresh interval from the measured K-curve.

    Returns ``pick_from_curve`` over the recorded curve for ``scale_name``;
    ``fallback`` (the preset's hand-picked value) when none is recorded. When
    ``n_steps`` is given the choice must divide it (config overrides can
    change the horizon after the curve was measured) — infeasible choices
    fall back likewise."""
    points = load_kcurve(scale_name, bench_path)
    if n_steps is not None:
        points = [p for p in points if n_steps % p.k == 0]
    if not points:
        return fallback
    k = pick_from_curve(points, util_tol)
    if n_steps is not None and n_steps % k != 0:
        return fallback
    return k
