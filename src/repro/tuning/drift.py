"""Drift-aware streaming recalibration: detect prior drift, re-tune warm.

The paper tunes each admission policy once against stationary Table-1 priors
and "re-tunes whenever the environment changes" — but its own motivating
multi-month traces drift, and the scenario sweep showed stationary-tuned
operating points violating the SLA badly under non-stationary arrivals.
This module closes that loop in three pieces:

  * **Channels** — per-window drift statistics derived from the same
    sufficient statistics both fitting paths accumulate: offline from
    ``traces.fit.window_stats`` (``FitStats.drift_channels()``), live from
    the telemetry rider's observable totals
    (``obs.counters.telemetry_summary()["obs"]`` deltas, the very sums
    ``core.belief.pseudo_counts_from_observables`` consumes). The offline
    channels are *unweighted means of per-deployment unbiased estimates*
    (mean deaths/core-hours, mean scale-outs/alive-hour, mean size-minus-1):
    pooled ratio rates are tilted by horizon censoring — deployments
    arriving late are observed briefly, which re-weights the heavy-tailed mu
    population toward fast-dying deployments and fakes a drift signal near
    the end of every trace — while the per-deployment estimates are
    conditionally unbiased under any censoring, so their window means are
    flat on a stationary trace.
  * **Detector** — ``DriftDetector``, a two-sided CUSUM over standardized
    channel deviations (Gaussian increments with slack ``k``; GLR-style in
    that the decision statistic is the max over channels and directions).
    The null (per-channel mean/std and the firing threshold) is **calibrated
    by Monte Carlo** on stationary replays of the same trace spec and window
    layout (``calibrate_drift_detector``): the threshold is the empirical
    (1 - alpha) quantile of the stationary max-statistic, so the false-alarm
    rate is <= alpha by construction and any residual window-layout effects
    are absorbed into the null.
  * **Re-tuning** — on trigger, ``retune_warm`` runs the device-sharded
    ``tuning.calibrate`` pass on a *warm-started* grid: search bounds
    shrunk around the incumbent theta (``warm_theta_bounds``), so the
    re-tune costs a fraction of the cold calibration — escalating to the
    cold bounds when the warm window holds no feasible theta (a drift too
    large for the warm assumption). ``run_drift_protocol``
    measures what that buys: regret (utilization at matched SLA, infeasible
    operating points credited zero) of *never* re-tuning and of
    *detector-triggered* warm re-tuning against an *oracle* that re-tunes
    cold at the drift onset — the triggered arm pays for its detection
    delay with the incumbent's (usually zero-credit) utilization.

Everything here is a cold path: numpy on host, simulations through the same
``make_run``/``calibrate`` machinery the rest of the tuning subsystem uses.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import numpy as np

from ..obs.log import get_logger
from ..traces import (DRIFT_MU_SCALE, DRIFT_RAMP_FRACS, DRIFT_STEP_FRAC,
                      WorkloadTrace, drifted_priors, synthesize_scenario,
                      window_stats)
from .calibrate import (CalibrationResult, calibrate, eval_theta_grid,
                        from_param, sla_ci, theta_space)

log = get_logger(__name__)

#: detector channels, in the order reports list them
DRIFT_CHANNELS = ("mu", "scaleout", "size")

#: drift onset (hours) of the shipped drifting scenarios, per horizon
_SCENARIO_ONSET = {
    "drift_step": lambda h: DRIFT_STEP_FRAC * h,
    "drift_ramp": lambda h: DRIFT_RAMP_FRACS[0] * h,
}


# ---------------------------------------------------------------------------
# Channels
# ---------------------------------------------------------------------------

def channels_from_stats(stats) -> dict:
    """Offline channel values of one ``traces.fit.FitStats`` window (the
    censoring-robust per-deployment means; see module docstring)."""
    return stats.drift_channels()


def channels_from_obs(obs: dict) -> dict:
    """Live channel values from one window's telemetry observable *deltas*
    (``telemetry_summary()["obs"]`` now minus previous scrape). Telemetry
    windows slice time, not arrivals, so the plain ratio rates are already
    censoring-free here: deaths per core-hour of exposure, scale-outs per
    alive-hour, and mean granted scale-out size. Channels with no exposure
    in the window are NaN and skipped by the detector."""
    deaths = float(obs.get("core_deaths", 0.0))
    exposure = float(obs.get("exposure_core_hours", 0.0))
    n_so = float(obs.get("n_scaleouts", 0.0))
    alive = float(obs.get("alive_hours", 0.0))
    so_cores = float(obs.get("scaleout_cores", 0.0))
    return {
        "mu": deaths / exposure if exposure > 0 else float("nan"),
        "scaleout": n_so / alive if alive > 0 else float("nan"),
        "size": (so_cores - n_so) / n_so if n_so > 0 else float("nan"),
    }


def window_channel_values(trace: WorkloadTrace,
                          window_hours: float) -> list[dict]:
    """Split a trace into consecutive arrival windows of ``window_hours``
    and return each window's channel values (offline replay feed)."""
    horizon = float(np.asarray(trace.horizon_hours))
    n_w = max(int(math.ceil(horizon / window_hours - 1e-9)), 1)
    return [channels_from_stats(
        window_stats(trace, i * window_hours, (i + 1) * window_hours))
        for i in range(n_w)]


# ---------------------------------------------------------------------------
# Detector
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DriftNull:
    """Calibrated null model of one detector deployment: per-channel mean
    and standard deviation of the window channel values on *stationary*
    replays, plus the Monte-Carlo firing threshold at ``alpha``."""

    mean: dict
    std: dict
    threshold: float
    alpha: float
    slack: float          # CUSUM drift allowance, in null-std units
    n_reps: int           # stationary replays behind the calibration
    n_windows: int        # windows per replay the threshold was set over


@dataclasses.dataclass(frozen=True)
class DriftUpdate:
    """One detector step: the decision statistic after this window."""

    window: int                    # 0-based index of the window just seen
    stat: float                    # max over channels/directions
    fired: bool                    # latched: has the detector ever fired?
    fired_window: Optional[int]    # first window at which it fired
    channel_stats: dict            # per-channel max(up, down) CUSUM values


class DriftDetector:
    """Two-sided CUSUM drift detector over standardized channel values.

    Per channel c and window value x: z = (x - mean_c) / std_c, then

        up_c   <- max(0, up_c   + z - k)      (channel rose)
        down_c <- max(0, down_c - z - k)      (channel fell)

    with slack ``k = null.slack``. The decision statistic is the max over
    channels and directions; the detector fires (and latches) when it
    exceeds ``null.threshold``. NaN channel values (quiet windows) skip
    that channel's update — the CUSUM holds its value.
    """

    def __init__(self, null: DriftNull):
        self.null = null
        self.reset()

    def reset(self) -> None:
        self._up = {c: 0.0 for c in self.null.mean}
        self._down = {c: 0.0 for c in self.null.mean}
        self.n_windows = 0
        self.fired = False
        self.fired_window: Optional[int] = None

    @property
    def stat(self) -> float:
        vals = [max(self._up[c], self._down[c]) for c in self._up]
        return max(vals) if vals else 0.0

    def update(self, values: dict) -> DriftUpdate:
        """Feed one window's channel values; returns the updated decision."""
        k = self.null.slack
        for c in self._up:
            x = values.get(c, float("nan"))
            sd = self.null.std.get(c, 0.0)
            if not np.isfinite(x) or not sd > 0:
                continue
            z = (x - self.null.mean[c]) / sd
            self._up[c] = max(0.0, self._up[c] + z - k)
            self._down[c] = max(0.0, self._down[c] - z - k)
        window = self.n_windows
        self.n_windows += 1
        if not self.fired and self.stat > self.null.threshold:
            self.fired = True
            self.fired_window = window
            log.info("drift detector fired at window %d (stat %.2f > %.2f)",
                     window, self.stat, self.null.threshold)
        return DriftUpdate(
            window=window, stat=self.stat, fired=self.fired,
            fired_window=self.fired_window,
            channel_stats={c: max(self._up[c], self._down[c])
                           for c in self._up})

    def snapshot(self) -> dict:
        """Flat metrics-endpoint view of the detector state."""
        return {
            "stat": self.stat,
            "threshold": self.null.threshold,
            "fired": int(self.fired),
            "fired_window": (-1 if self.fired_window is None
                             else self.fired_window),
            "n_windows": self.n_windows,
            "channel_stats": {c: max(self._up[c], self._down[c])
                              for c in self._up},
        }


def calibrate_drift_detector(key: jax.Array, spec, *, window_hours: float,
                             n_reps: int = 12, alpha: float = 0.1,
                             slack: float = 0.5,
                             scenario: str = "baseline") -> DriftNull:
    """Monte-Carlo null calibration on stationary replays.

    Synthesizes ``n_reps`` stationary traces of ``spec``, windows each with
    the *same* layout the detector will run with, pools the per-window
    channel values into the null mean/std, and sets the firing threshold to
    the empirical (1 - alpha) quantile (``method="higher"``, conservative)
    of the per-replay *max* CUSUM statistic — so a fresh stationary replay
    fires with probability <= alpha, whatever window-layout or residual
    censoring effects the spec carries.
    """
    keys = jax.random.split(key, n_reps)
    reps = [window_channel_values(synthesize_scenario(k, scenario, spec),
                                  window_hours) for k in keys]
    mean, std = {}, {}
    for c in DRIFT_CHANNELS:
        xs = np.asarray([v[c] for rep in reps for v in rep], np.float64)
        xs = xs[np.isfinite(xs)]
        mean[c] = float(xs.mean()) if xs.size else 0.0
        std[c] = float(max(xs.std(ddof=1), 1e-9)) if xs.size > 1 else 0.0

    probe = DriftNull(mean=mean, std=std, threshold=float("inf"),
                      alpha=alpha, slack=slack, n_reps=n_reps,
                      n_windows=len(reps[0]) if reps else 0)
    maxes = []
    for rep in reps:
        det = DriftDetector(probe)
        maxes.append(max(det.update(v).stat for v in rep))
    threshold = float(np.quantile(np.asarray(maxes), 1.0 - alpha,
                                  method="higher"))
    log.debug("drift null: threshold=%.3f (alpha=%.2g over %d reps x %d "
              "windows)", threshold, alpha, n_reps, probe.n_windows)
    return dataclasses.replace(probe, threshold=threshold)


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """One offline detector pass over a trace's replay windows."""

    fired: bool
    fired_window: Optional[int]
    n_windows: int
    window_hours: float
    stats: np.ndarray              # [W] decision-statistic trajectory


def detect_drift(trace: WorkloadTrace, null: DriftNull, *,
                 window_hours: float) -> DriftReport:
    """Run a freshly-reset detector over a trace's arrival windows."""
    det = DriftDetector(null)
    updates = [det.update(v)
               for v in window_channel_values(trace, window_hours)]
    return DriftReport(
        fired=det.fired, fired_window=det.fired_window,
        n_windows=len(updates), window_hours=float(window_hours),
        stats=np.asarray([u.stat for u in updates]))


# ---------------------------------------------------------------------------
# Warm re-tuning
# ---------------------------------------------------------------------------

def warm_theta_bounds(kind: int, theta0: float, capacity: float, *,
                      frac: float = 0.25) -> tuple[float, float]:
    """Search bounds (in search coordinates) for a warm re-tune: a window of
    ``frac`` of the cold search span on each side of the incumbent,
    clipped to the cold bounds."""
    x_lo, x_hi, space = theta_space(kind, capacity)
    x0 = float(from_param(theta0, space))
    half = frac * (x_hi - x_lo)
    return max(x0 - half, x_lo), min(x0 + half, x_hi)


def retune_warm(run_fn, kind: int, keys, *, capacity: float, tau: float,
                theta0: float, frac: float = 0.25, n_grid: int = 5,
                max_stages: int = 2, escalate: bool = True,
                escalate_grid: Optional[int] = None,
                devices=None) -> CalibrationResult:
    """Incremental re-calibration around the incumbent ``theta0``: the same
    device-sharded ``tuning.calibrate`` pass on the shrunk
    ``warm_theta_bounds`` window — a fraction of the cold grid's
    simulations, because the incumbent is assumed near-feasible.

    When the drift has moved the feasible set beyond the warm window (every
    warm candidate violates the SLA), ``escalate=True`` (the default)
    re-runs on the full cold bounds rather than returning an infeasible
    operating point — the re-tune then costs cold price (both passes'
    simulations are accounted), but a large drift degrades to the cold
    calibration instead of to *no* feasible theta. ``escalate_grid`` sets
    the escalation pass's grid density (default: ``n_grid``) so a caller
    comparing against its own cold calibration can make the escalated pass
    literally that calibration."""
    lo, hi = warm_theta_bounds(kind, theta0, capacity, frac=frac)
    res = calibrate(run_fn, kind, keys, capacity=capacity, tau=tau,
                    lo=lo, hi=hi, n_grid=n_grid, max_stages=max_stages,
                    devices=devices)
    if escalate and not res.feasible:
        log.info("warm re-tune window [%.3g, %.3g] infeasible at tau=%g; "
                 "escalating to cold bounds", lo, hi, tau)
        cold = calibrate(run_fn, kind, keys, capacity=capacity, tau=tau,
                         n_grid=escalate_grid or n_grid,
                         max_stages=max_stages, devices=devices)
        res = dataclasses.replace(cold, n_sims=cold.n_sims + res.n_sims)
    return res


# ---------------------------------------------------------------------------
# The regret protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DriftArm:
    """One re-tuning strategy evaluated on the post-drift regime."""

    name: str
    theta: float
    feasible: bool         # SLA met at theta on the post-drift runs
    sla_fail: float
    util_raw: float        # mean utilization, ignoring SLA credit
    util: float            # credited: 0 when infeasible, delay-weighted
    regret: float          # oracle credited util minus this arm's
    n_sims: int


@dataclasses.dataclass(frozen=True)
class DriftProtocolResult:
    """Everything ``run_drift_protocol`` measured."""

    kind: int
    scenario: str
    theta0: float                  # stationary-calibrated incumbent
    base: CalibrationResult        # the stationary calibration
    null: DriftNull
    report: DriftReport            # detector pass over the drifting trace
    onset_window: int              # window the drift starts in
    delay_windows: int             # fired_window - onset_window (>= 0)
    delay_frac: float              # post-onset time spent undetected
    oracle: DriftArm
    triggered: DriftArm
    never: DriftArm
    oracle_ci: tuple               # normal CI on oracle credited util
    within_ci: bool                # triggered arm's post-re-tune credited
                                   # util >= oracle CI lower edge (the delay
                                   # cost is regret's job, not this flag's)
    n_sims: int


def _credit(util: float, feasible: bool) -> float:
    """Utilization credit at matched SLA: infeasible operating points earn
    nothing (the provider pays the violation, not the utilization)."""
    return util if feasible else 0.0


def run_drift_protocol(key: jax.Array, *, kind: int, cfg, grid, spec,
                       tau: float, window_hours: float,
                       scenario: str = "drift_step",
                       mu_scale: float = DRIFT_MU_SCALE,
                       n_runs: int = 6, n_grid: int = 6,
                       warm_frac: float = 0.25, warm_grid: int = 5,
                       alpha: float = 0.1, n_null_reps: int = 10,
                       devices=None) -> DriftProtocolResult:
    """Measure the regret of drift-triggered warm re-tuning.

    Piecewise-stationary protocol:

      1. Calibrate the incumbent ``theta0`` on the stationary priors
         (``cfg.priors``) — the operating point a provider would run.
      2. Calibrate the detector null on stationary replays of ``spec`` and
         run the detector over one drifting-scenario trace; the detection
         delay (windows past the drift onset) is what the triggered arm
         pays for.
      3. Evaluate three arms on the *post-drift* regime
         (``drifted_priors(cfg.priors, mu_scale)``, fresh run keys, common
         random numbers across arms): **never** keeps theta0; **oracle**
         re-tunes cold at the onset with zero delay; **triggered** re-tunes
         on the shrunk warm grid and is credited the incumbent's
         utilization for the detection-delay fraction of the post-onset
         horizon.

    Regret is against the oracle's credited utilization (infeasible => 0
    credit). The shipped drift direction — mu down, lifetimes up — is the
    dangerous one: load grows, the stationary theta slides past the SLA,
    and never-re-tuning forfeits its credit entirely.
    """
    from ..sim.simulator import make_run

    k0, k_null, k_trace, k_b = jax.random.split(key, 4)
    run_fn = make_run(cfg, grid, kind)
    keys0 = jax.random.split(k0, n_runs)
    base = calibrate(run_fn, kind, keys0, capacity=cfg.capacity, tau=tau,
                     n_grid=n_grid, max_stages=2, devices=devices)
    theta0 = base.theta

    null = calibrate_drift_detector(k_null, spec, window_hours=window_hours,
                                    n_reps=n_null_reps, alpha=alpha)
    trace = synthesize_scenario(k_trace, scenario, spec)
    report = detect_drift(trace, null, window_hours=window_hours)

    horizon = float(spec.horizon_hours)
    onset_h = _SCENARIO_ONSET.get(scenario, lambda h: 0.0)(horizon)
    onset_window = int(onset_h / window_hours)
    if report.fired:
        # detection closes at the end of the fired window
        delay_windows = max(report.fired_window + 1 - onset_window, 0)
    else:
        delay_windows = report.n_windows - onset_window
    post_onset_h = max(horizon - onset_h, window_hours)
    delay_frac = min(max(delay_windows * window_hours / post_onset_h, 0.0),
                     1.0)

    # -- post-drift regime: three arms on common random numbers -------------
    cfg2 = cfg._replace(priors=drifted_priors(cfg.priors, mu_scale))
    run_fn2 = make_run(cfg2, grid, kind)
    keys_b = jax.random.split(k_b, n_runs)

    m = eval_theta_grid(run_fn2, kind, [theta0], keys_b,
                        capacity=cfg2.capacity, devices=devices)
    fails = np.asarray(m.failed_requests)[0]
    reqs = np.asarray(m.total_requests)[0]
    sla_never, _, _ = sla_ci(fails, reqs)
    util_never_raw = float(np.mean(np.asarray(m.utilization)[0]))
    feas_never = sla_never <= tau
    cred_never = _credit(util_never_raw, feas_never)

    oracle_cal = calibrate(run_fn2, kind, keys_b, capacity=cfg2.capacity,
                           tau=tau, n_grid=n_grid, max_stages=2,
                           devices=devices)
    cred_oracle = _credit(oracle_cal.utilization, oracle_cal.feasible)

    warm = retune_warm(run_fn2, kind, keys_b, capacity=cfg2.capacity,
                       tau=tau, theta0=theta0, frac=warm_frac,
                       n_grid=warm_grid, max_stages=2, escalate_grid=n_grid,
                       devices=devices)
    cred_warm = _credit(warm.utilization, warm.feasible)
    # the triggered arm runs the incumbent until detection, then the warm
    # re-tune — credited pro rata over the post-onset horizon
    util_triggered = (1.0 - delay_frac) * cred_warm + delay_frac * cred_never

    oracle = DriftArm(name="oracle", theta=oracle_cal.theta,
                      feasible=oracle_cal.feasible,
                      sla_fail=oracle_cal.sla_fail,
                      util_raw=oracle_cal.utilization, util=cred_oracle,
                      regret=0.0, n_sims=oracle_cal.n_sims)
    never = DriftArm(name="never", theta=float(theta0), feasible=feas_never,
                     sla_fail=float(sla_never), util_raw=util_never_raw,
                     util=cred_never, regret=cred_oracle - cred_never,
                     n_sims=n_runs)
    triggered = DriftArm(name="triggered", theta=warm.theta,
                         feasible=warm.feasible, sla_fail=warm.sla_fail,
                         util_raw=warm.utilization, util=util_triggered,
                         regret=cred_oracle - util_triggered,
                         n_sims=warm.n_sims)

    ur = np.asarray(oracle_cal.util_runs, np.float64)
    se = float(ur.std(ddof=1) / np.sqrt(len(ur))) if len(ur) > 1 else 0.0
    ci = (cred_oracle - 1.96 * se, cred_oracle + 1.96 * se)
    # the CI claim is about the *recovered operating point*: matching the
    # zero-delay oracle's total credit is structurally impossible whenever
    # the incumbent earns nothing during the detection delay, so the delay
    # cost lives in ``regret`` and ``within_ci`` asks whether the warm
    # re-tune's steady-state utilization is indistinguishable from the
    # oracle's
    within = cred_warm >= ci[0]

    n_sims = base.n_sims + oracle_cal.n_sims + warm.n_sims + n_runs
    log.info("drift protocol [%s kind=%d]: delay=%d windows, regret "
             "never=%.4f triggered=%.4f (oracle util %.4f)", scenario, kind,
             delay_windows, never.regret, triggered.regret, cred_oracle)
    return DriftProtocolResult(
        kind=kind, scenario=scenario, theta0=float(theta0), base=base,
        null=null, report=report, onset_window=onset_window,
        delay_windows=int(delay_windows), delay_frac=float(delay_frac),
        oracle=oracle, triggered=triggered, never=never, oracle_ci=ci,
        within_ci=bool(within), n_sims=int(n_sims))
