"""Unified decoder-only LM covering the dense / moe / hybrid / ssm / vlm
families. One scanned block body per architecture (homogeneous stacks scan for
O(1)-in-depth HLO and compile time; heterogeneous stacks — xLSTM — unroll).

Block structure by family:
  dense/vlm : x += attn(ln1 x);             x += mlp(ln2 x)
  moe       : x += attn(ln1 x);             x += moe(ln2 x)
  hybrid    : x += attn(ln1 x) + ssm(ln1 x) x += mlp(ln2 x)   (hymba parallel)
  ssm       : x += mlstm(ln1 x) | slstm(ln1 x)                (no FFN, d_ff=0)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import moe as moe_lib
from . import ssm as ssm_lib
from . import xlstm as xlstm_lib
from .layers import (AttnConfig, KVCache, attention, attention_decode,
                     attention_params, init_kv_cache, mlp, mlp_params,
                     rmsnorm, rmsnorm_params)
from .spec import (P, abstract_params, count_params, init_params,
                   logical_constraint, param_shardings, param_specs)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    window: int = 0             # sliding-window attention (hybrid)
    gated_mlp: bool = True
    n_experts: int = 0
    moe_top_k: int = 0
    moe_aux_coef: float = 0.01
    moe_capacity_factor: float = 1.25
    ssm_state: int = 0
    enc_layers: int = 0         # audio (whisper) encoder depth
    enc_seq: int = 1500         # audio frames after the (stubbed) frontend
    scan_layers: bool = True
    remat: bool = True
    attn_chunk: int = 0         # chunked flash-style attention block (0 = off)
    moe_local_dispatch: bool = False  # shard_map'd EP dispatch (§Perf)
    dtype: Any = jnp.bfloat16   # activation/compute dtype
    use_flash_kernel: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can serve arbitrarily long contexts with O(1)/O(window) state."""
        return self.family in ("hybrid", "ssm")

    def attn_config(self, causal=True) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.resolved_head_dim,
            qk_norm=self.qk_norm, causal=causal, window=self.window,
            rope_theta=self.rope_theta, chunk=self.attn_chunk,
        )

    def ssm_config(self) -> ssm_lib.SSMConfig:
        return ssm_lib.SSMConfig(
            d_model=self.d_model, d_inner=self.d_model,
            n_heads=self.n_heads, state=self.ssm_state,
        )

    def xlstm_config(self) -> xlstm_lib.XLSTMConfig:
        return xlstm_lib.XLSTMConfig(d_model=self.d_model,
                                     n_heads=self.n_heads)

    def moe_config(self) -> moe_lib.MoEConfig:
        return moe_lib.MoEConfig(
            d_model=self.d_model, d_ff=self.d_ff, n_experts=self.n_experts,
            top_k=self.moe_top_k, capacity_factor=self.moe_capacity_factor,
        )


def _stack_descriptors(tree: Any, n: int) -> Any:
    """Prepend a scanned 'layers' axis to every descriptor."""
    return jax.tree.map(
        lambda p: P((n, *p.shape), ("layers", *p.axes), p.init, p.scale),
        tree, is_leaf=lambda x: isinstance(x, P),
    )


class DecoderLM:
    """Functional decoder LM; all methods are pure and jit-compatible."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        if cfg.family == "ssm":
            self.layer_types = tuple(
                "mlstm" if i % 2 == 0 else "slstm" for i in range(cfg.n_layers)
            )
        elif cfg.family == "hybrid":
            self.layer_types = ("hybrid",) * cfg.n_layers
        else:
            self.layer_types = ("attn",) * cfg.n_layers
        self.homogeneous = len(set(self.layer_types)) == 1 and cfg.scan_layers

    # -- parameters ---------------------------------------------------------

    def _block_descriptors(self, ltype: str) -> dict:
        cfg = self.cfg
        d: dict = {"ln1": rmsnorm_params(cfg.d_model)}
        if ltype in ("attn", "hybrid"):
            d["attn"] = attention_params(cfg.attn_config())
        if ltype == "hybrid":
            d["ssm"] = ssm_lib.ssm_params(cfg.ssm_config())
        if ltype == "mlstm":
            d["mlstm"] = xlstm_lib.mlstm_params(cfg.xlstm_config())
        if ltype == "slstm":
            d["slstm"] = xlstm_lib.slstm_params(cfg.xlstm_config())
        if cfg.d_ff > 0:
            d["ln2"] = rmsnorm_params(cfg.d_model)
            if cfg.family == "moe":
                d["ffn"] = moe_lib.moe_params(cfg.moe_config())
            else:
                d["ffn"] = mlp_params(cfg.d_model, cfg.d_ff, cfg.gated_mlp)
        return d

    def param_descriptors(self) -> dict:
        cfg = self.cfg
        tree: dict = {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=1.0),
            "final_norm": rmsnorm_params(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            tree["lm_head"] = P((cfg.d_model, cfg.vocab), ("embed", "vocab"))
        if self.homogeneous:
            tree["layers"] = _stack_descriptors(
                self._block_descriptors(self.layer_types[0]), cfg.n_layers
            )
        else:
            tree["layers"] = [
                self._block_descriptors(t) for t in self.layer_types
            ]
        return tree

    def init(self, key: jax.Array, dtype=jnp.float32):
        return init_params(key, self.param_descriptors(), dtype)

    def abstract_params(self, dtype=jnp.float32):
        return abstract_params(self.param_descriptors(), dtype)

    def param_specs(self, mesh):
        return param_specs(self.param_descriptors(), mesh)

    def param_shardings(self, mesh, drop_axes: tuple = ()):
        return param_shardings(self.param_descriptors(), mesh, drop_axes)

    def n_params(self) -> int:
        return count_params(self.param_descriptors())

    def n_active_params(self) -> int:
        """Active params per token (MoE discounts inactive experts)."""
        total = self.n_params()
        cfg = self.cfg
        if cfg.family != "moe":
            return total
        per_expert = 3 * cfg.d_model * cfg.d_ff
        inactive = cfg.n_layers * (cfg.n_experts - cfg.moe_top_k) * per_expert
        return total - inactive

    # -- forward ------------------------------------------------------------

    def _block_apply(self, ltype: str, p: dict, x: jax.Array, mesh):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        h = rmsnorm(p["ln1"], x)
        if ltype == "attn":
            mix = attention(p["attn"], cfg.attn_config(), h,
                            use_kernel=cfg.use_flash_kernel)
        elif ltype == "hybrid":
            mix = attention(p["attn"], cfg.attn_config(), h,
                            use_kernel=cfg.use_flash_kernel)
            mix = mix + ssm_lib.ssm(p["ssm"], cfg.ssm_config(), h)
        elif ltype == "mlstm":
            mix = xlstm_lib.mlstm(p["mlstm"], cfg.xlstm_config(), h)
        else:  # slstm
            mix, _ = xlstm_lib.slstm(p["slstm"], cfg.xlstm_config(), h)
        x = x + mix
        x = logical_constraint(x, ("batch", "seq", None), mesh)
        if cfg.d_ff > 0:
            h2 = rmsnorm(p["ln2"], x)
            if cfg.family == "moe":
                out = self._moe(p["ffn"], h2, mesh)
                x = x + out.y
                aux = out.aux_loss
            else:
                x = x + mlp(p["ffn"], h2)
            x = logical_constraint(x, ("batch", "seq", None), mesh)
        return x, aux

    def _moe(self, p, h, mesh):
        cfg = self.cfg
        if cfg.moe_local_dispatch and mesh is not None:
            return moe_lib.moe_local(p, cfg.moe_config(), h, mesh)
        return moe_lib.moe(p, cfg.moe_config(), h)

    def _backbone(self, params, x: jax.Array, mesh) -> tuple:
        """Token embeddings -> final norm. Returns (hidden, total aux loss)."""
        cfg = self.cfg
        if self.homogeneous:
            body = functools.partial(self._block_apply, self.layer_types[0],
                                     mesh=mesh)
            fn = (lambda carry, p: body(p, carry))
            if cfg.remat:
                fn = jax.checkpoint(
                    fn, policy=jax.checkpoint_policies.nothing_saveable
                )
            x, auxs = jax.lax.scan(fn, x, params["layers"])
            aux = jnp.sum(auxs)
        else:
            aux = jnp.zeros((), jnp.float32)
            for ltype, p in zip(self.layer_types, params["layers"]):
                blk = functools.partial(self._block_apply, ltype, mesh=mesh)
                if cfg.remat:
                    blk = jax.checkpoint(
                        blk, policy=jax.checkpoint_policies.nothing_saveable)
                x, a = blk(p, x)
                aux = aux + a
        return rmsnorm(params["final_norm"], x), aux

    def _logits(self, params, hidden: jax.Array, mesh) -> jax.Array:
        cfg = self.cfg
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = jnp.einsum("bsd,dv->bsv", hidden, head.astype(hidden.dtype))
        return logical_constraint(logits, ("batch", "seq", "vocab"), mesh)

    def forward(self, params, tokens: jax.Array, mesh=None) -> jax.Array:
        cfg = self.cfg
        x = params["embed"].astype(cfg.dtype)[tokens]
        x = logical_constraint(x, ("batch", "seq", None), mesh)
        hidden, _ = self._backbone(params, x, mesh)
        return self._logits(params, hidden, mesh)

    def loss(self, params, batch: dict, mesh=None) -> tuple:
        """Next-token cross entropy (+ MoE aux). batch: tokens/labels [B,S]."""
        cfg = self.cfg
        x = params["embed"].astype(cfg.dtype)[batch["tokens"]]
        x = logical_constraint(x, ("batch", "seq", None), mesh)
        hidden, aux = self._backbone(params, x, mesh)
        logits = self._logits(params, hidden, mesh).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
        loss = jnp.mean(nll)
        if cfg.family == "moe":
            loss = loss + cfg.moe_aux_coef * aux / cfg.n_layers
        return loss, {"nll": jnp.mean(nll), "aux": aux}

    # -- serving ------------------------------------------------------------

    def _cache_len(self, max_seq: int) -> int:
        return min(self.cfg.window, max_seq) if self.cfg.window else max_seq

    def _layer_cache(self, ltype: str, batch: int, max_seq: int, dtype):
        cfg = self.cfg
        if ltype == "attn":
            return init_kv_cache(batch, self._cache_len(max_seq),
                                 cfg.attn_config(), dtype)
        if ltype == "hybrid":
            return {
                "attn": init_kv_cache(batch, self._cache_len(max_seq),
                                      cfg.attn_config(), dtype),
                "ssm": ssm_lib.init_ssm_cache(batch, cfg.ssm_config(), dtype),
            }
        if ltype == "mlstm":
            return xlstm_lib.init_mlstm_cache(batch, cfg.xlstm_config())
        return xlstm_lib.init_slstm_state(batch, cfg.xlstm_config())

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        if self.homogeneous:
            one = self._layer_cache(self.layer_types[0], batch, max_seq, dtype)
            return jax.tree.map(
                lambda c: jnp.broadcast_to(c, (self.cfg.n_layers, *c.shape)),
                one,
            )
        return [self._layer_cache(t, batch, max_seq, dtype)
                for t in self.layer_types]

    def _layer_cache_axes(self, ltype: str):
        kv_axes = KVCache(k=("batch", "kv_seq", "kv_heads", None),
                          v=("batch", "kv_seq", "kv_heads", None), length=())
        ssm_axes = ssm_lib.SSMCache(h=("batch", "heads", None, None),
                                    conv=("batch", None, "ssm_inner"))
        if ltype == "attn":
            return kv_axes
        if ltype == "hybrid":
            return {"attn": kv_axes, "ssm": ssm_axes}
        if ltype == "mlstm":
            return xlstm_lib.MLSTMCache(h=("batch", "heads", None, None))
        return xlstm_lib.SLSTMState(*((("batch", "heads", None),) * 4))

    def cache_axes(self):
        if self.homogeneous:
            one = self._layer_cache_axes(self.layer_types[0])
            from .spec import _is_axes_leaf
            return jax.tree.map(lambda a: ("layers", *a), one,
                                is_leaf=_is_axes_leaf)
        return [self._layer_cache_axes(t) for t in self.layer_types]

    def cache_shardings(self, mesh, batch: int, max_seq: int,
                        dtype=jnp.bfloat16):
        from .spec import shardings_for_tree
        shapes = jax.eval_shape(
            functools.partial(self.init_cache, batch, max_seq, dtype))
        return shardings_for_tree(shapes, self.cache_axes(), mesh)

    def _block_decode(self, ltype: str, p: dict, x: jax.Array, cache, mesh):
        cfg = self.cfg
        h = rmsnorm(p["ln1"], x)
        if ltype == "attn":
            mix, new_cache = attention_decode(p["attn"], cfg.attn_config(), h,
                                              cache, mesh=mesh)
        elif ltype == "hybrid":
            mix_a, kv = attention_decode(p["attn"], cfg.attn_config(), h,
                                         cache["attn"], mesh=mesh)
            mix_s, sc = ssm_lib.ssm_decode(p["ssm"], cfg.ssm_config(), h,
                                           cache["ssm"])
            mix, new_cache = mix_a + mix_s, {"attn": kv, "ssm": sc}
        elif ltype == "mlstm":
            mix, new_cache = xlstm_lib.mlstm_decode(p["mlstm"],
                                                    cfg.xlstm_config(), h,
                                                    cache)
        else:
            wx = jnp.einsum("bsd,de->bse", h,
                            p["slstm"]["w_gates"].astype(h.dtype))
            st = xlstm_lib._slstm_cell(p["slstm"], cfg.xlstm_config(), cache,
                                       wx[:, 0])
            hs = rmsnorm(p["slstm"]["head_norm"], st.h[:, None])
            b = x.shape[0]
            hs = hs.reshape(b, 1, cfg.d_model).astype(x.dtype)
            mix = jnp.einsum("bse,ed->bsd", hs,
                             p["slstm"]["w_out"].astype(x.dtype))
            new_cache = st
        x = x + mix
        if cfg.d_ff > 0:
            h2 = rmsnorm(p["ln2"], x)
            if cfg.family == "moe":
                x = x + self._moe(p["ffn"], h2, mesh).y
            else:
                x = x + mlp(p["ffn"], h2)
        return x, new_cache

    def decode_step(self, params, tokens: jax.Array, cache, mesh=None):
        """tokens: [B] -> (logits [B, V], new cache). One decode position."""
        cfg = self.cfg
        x = params["embed"].astype(cfg.dtype)[tokens][:, None]  # [B,1,D]
        if self.homogeneous:
            def fn(carry, xs):
                p, c = xs
                y, nc = self._block_decode(self.layer_types[0], p, carry, c,
                                           mesh)
                return y, nc
            x, new_cache = jax.lax.scan(fn, x, (params["layers"], cache))
        else:
            new_cache = []
            for ltype, p, c in zip(self.layer_types, params["layers"], cache):
                x, nc = self._block_decode(ltype, p, x, c, mesh)
                new_cache.append(nc)
        hidden = rmsnorm(params["final_norm"], x)
        logits = self._logits(params, hidden, mesh)[:, 0]
        return logits.astype(jnp.float32), new_cache

    def prefill(self, params, tokens: jax.Array, mesh=None):
        """Run the full prompt, build decode caches, return last logits.

        Implemented as forward + cache construction per layer. Attention
        caches keep the last ``window`` (or all) positions; SSM/xLSTM caches
        are the final recurrent states.
        """
        cfg = self.cfg
        b, s = tokens.shape
        x = params["embed"].astype(cfg.dtype)[tokens]
        x = logical_constraint(x, ("batch", "seq", None), mesh)

        def prefill_block(ltype, p, x):
            h = rmsnorm(p["ln1"], x)
            cache = None
            if ltype in ("attn", "hybrid"):
                from .layers import _qkv
                acfg = cfg.attn_config()
                q, k, v = _qkv(p["attn"], acfg, h, jnp.arange(s))
                from .layers import _sdpa, _sdpa_chunked
                mix = (_sdpa_chunked(q, k, v, acfg) if acfg.chunk > 0
                       else _sdpa(q, k, v, acfg))
                mix = jnp.einsum("bshk,hkd->bsd", mix,
                                 p["attn"]["wo"].astype(x.dtype))
                cl = self._cache_len(s)
                # rolling-buffer alignment: slot = pos % cl
                last = jnp.arange(s - cl, s)
                slots = last % cl
                kc = jnp.zeros((b, cl, *k.shape[2:]), jnp.bfloat16
                               ).at[:, slots].set(k[:, last].astype(jnp.bfloat16))
                vc = jnp.zeros((b, cl, *v.shape[2:]), jnp.bfloat16
                               ).at[:, slots].set(v[:, last].astype(jnp.bfloat16))
                cache = KVCache(k=kc, v=vc, length=jnp.asarray(s, jnp.int32))
                if ltype == "hybrid":
                    scfg = cfg.ssm_config()
                    xi = jnp.einsum("bsd,de->bse", h,
                                    p["ssm"]["w_in"].astype(h.dtype))
                    xin, z = jnp.split(xi, 2, axis=-1)
                    xc, conv_carry = ssm_lib._causal_conv(
                        xin, p["ssm"]["conv"].astype(h.dtype))
                    a, dt, bm, cm = ssm_lib._gates(p["ssm"], scfg, h)
                    vals = xc.reshape(b, s, scfg.n_heads, scfg.head_dim)
                    y, h_fin = ssm_lib.ssd_scan(a, dt, bm, cm, vals, scfg.chunk)
                    y = y + p["ssm"]["d_skip"].astype(h.dtype)[None, None, :, None] * vals
                    y = y.reshape(b, s, scfg.d_inner) * jax.nn.silu(z)
                    mix = mix + jnp.einsum("bse,ed->bsd", y,
                                           p["ssm"]["w_out"].astype(h.dtype))
                    cache = {
                        "attn": cache,
                        "ssm": ssm_lib.SSMCache(
                            h=h_fin, conv=xin[:, -(scfg.conv_kernel - 1):]
                            .astype(jnp.bfloat16)),
                    }
            elif ltype == "mlstm":
                xcfg = cfg.xlstm_config()
                q, k, v, ig, fg = xlstm_lib._mlstm_gates(p["mlstm"], xcfg, h)
                ones = jnp.ones((*v.shape[:-1], 1), v.dtype)
                y_ext, h_fin = ssm_lib.ssd_scan(
                    fg, ig, k, q, jnp.concatenate([v, ones], -1), xcfg.chunk)
                z = jnp.einsum("bsd,de->bse", h,
                               p["mlstm"]["w_z"].astype(h.dtype))
                mix = xlstm_lib._mlstm_norm_out(p["mlstm"], xcfg, y_ext, z,
                                                x.dtype)
                cache = xlstm_lib.MLSTMCache(h=h_fin)
            else:  # slstm
                mix, cache = xlstm_lib.slstm(p["slstm"], cfg.xlstm_config(), h)
            x = x + mix
            if cfg.d_ff > 0:
                h2 = rmsnorm(p["ln2"], x)
                if cfg.family == "moe":
                    x = x + self._moe(p["ffn"], h2, mesh).y
                else:
                    x = x + mlp(p["ffn"], h2)
            return x, cache

        if self.homogeneous:
            def fn(carry, p):
                return prefill_block(self.layer_types[0], p, carry)
            x, caches = jax.lax.scan(fn, x, params["layers"])
        else:
            caches = []
            for ltype, p in zip(self.layer_types, params["layers"]):
                x, c = prefill_block(ltype, p, x)
                caches.append(c)
        hidden = rmsnorm(params["final_norm"], x[:, -1:])
        logits = self._logits(params, hidden, mesh)[:, 0]
        return logits.astype(jnp.float32), caches
