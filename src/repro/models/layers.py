"""Shared transformer layers: norms, RoPE, GQA attention, MLP.

Pure functions over parameter dicts built from spec.P descriptors. All
attention paths support GQA (n_kv_heads <= n_heads), optional qk-norm
(qwen3/chameleon), optional sliding windows (hymba), causal or bidirectional
masks, and a KV-cache decode mode. The prefill attention dispatches to the
Pallas flash kernel when enabled (kernels.flash_attention), otherwise to the
pure-jnp reference path (identical math; the kernel is validated against it).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .spec import P

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_params(d: int) -> dict:
    return {"scale": P((d,), ("embed",), init="ones")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embedding. x: [B, S, H, Dh]; positions: [B, S] or [S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B?, S, half]
    if angles.ndim == 2:
        angles = angles[None]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    causal: bool = True
    window: int = 0          # 0 = full attention; >0 = sliding window
    rope_theta: float = 1e4
    use_rope: bool = True
    chunk: int = 0           # >0: chunked (flash-style) attention, O(S*chunk)
                             # logits memory instead of O(S^2)


def attention_params(cfg: AttnConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": P((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": P((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"scale": P((dh,), (None,), init="ones")}
        p["k_norm"] = {"scale": P((dh,), (None,), init="ones")}
    return p


def _qkv(params, cfg: AttnConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, cfg: AttnConfig, q_offset: int | jax.Array = 0):
    """Reference scaled-dot-product attention with GQA + masks.

    q: [B, Sq, H, Dh]; k/v: [B, Sk, KVH, Dh]. q_offset: absolute position of
    q[0] (for decode/cache). Returns [B, Sq, H, Dh]. f32 accumulation.
    """
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    groups = h // kvh
    qg = q.reshape(b, sq, kvh, groups, dh)
    # native-dtype dots with f32 accumulation: avoids materializing f32
    # copies of K/V (2-3x HBM traffic on the decode path — §Perf iter 5)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(dh).astype(jnp.float32)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if cfg.causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if cfg.window > 0:
        mask &= kpos[None, :] > qpos[:, None] - cfg.window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def _sdpa_chunked(q, k, v, cfg: AttnConfig):
    """Flash-style attention in pure XLA: scan over query blocks, full K per
    block, masked softmax in f32. Peak logits memory O(chunk * Sk) instead of
    O(Sq * Sk) — the memory-roofline fix for 32k prefill (§Perf). The Pallas
    kernel is the TPU-native equivalent; this path compiles everywhere and is
    what the dry-run lowers."""
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    c = min(cfg.chunk, sq)
    if sq % c != 0:
        return _sdpa(q, k, v, cfg)
    nq = sq // c
    groups = h // kvh
    qb = q.reshape(b, nq, c, h, dh).swapaxes(0, 1)  # [nq, B, c, H, Dh]
    kpos = jnp.arange(sk)

    def block(_, xs):
        qi, qblk = xs
        qg = qblk.reshape(b, c, kvh, groups, dh)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                            preferred_element_type=jnp.float32) / jnp.sqrt(dh)
        qpos = qi * c + jnp.arange(c)
        mask = jnp.ones((c, sk), bool)
        if cfg.causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if cfg.window > 0:
            mask &= kpos[None, :] > qpos[:, None] - cfg.window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return None, out.reshape(b, c, h, dh).astype(q.dtype)

    _, blocks = jax.lax.scan(block, None, (jnp.arange(nq), qb))
    return blocks.swapaxes(0, 1).reshape(b, sq, h, dh)


def attention(params, cfg: AttnConfig, x, positions=None, *,
              kv: Optional[tuple] = None, use_kernel: bool = False):
    """Full-sequence attention (train/prefill). x: [B, S, D].

    kv: optional external (k, v) for cross-attention (whisper decoder).
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _qkv(params, cfg, x, positions)
    if kv is not None:
        k, v = kv
    if use_kernel and kv is None:
        from ..kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=cfg.causal,
                                     window=cfg.window)
    elif cfg.chunk > 0:
        out = _sdpa_chunked(q, k, v, cfg)
    else:
        out = _sdpa(q, k, v, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


class KVCache(NamedTuple):
    k: jax.Array   # [B, S_max, KVH, Dh]
    v: jax.Array
    length: jax.Array  # scalar int32 — tokens currently cached


def init_kv_cache(batch: int, max_seq: int, cfg: AttnConfig,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((), jnp.int32))


def _cache_update(cache_arr, new, slot, mesh):
    """Write one token's K/V at a dynamic slot.

    With the cache sequence dim sharded over `model`, a plain
    dynamic_update_slice makes GSPMD rewrite the op as full-cache f32 selects
    plus an all-gather (~10x the physical decode traffic — §Perf iter 6).
    shard_map makes the write local to the owning rank: O(one token) traffic.
    """
    slot = jnp.asarray(slot, jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    if (mesh is None or "model" not in mesh.axis_names
            or dict(zip(mesh.axis_names,
                        mesh.devices.shape)).get("model", 1) <= 1
            or cache_arr.shape[1] % mesh.shape["model"] != 0):
        return jax.lax.dynamic_update_slice(
            cache_arr, new.astype(cache_arr.dtype), (zero, slot, zero, zero))

    from jax.sharding import PartitionSpec
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if len(dp) != 1 else dp[0]

    def inner(c, n, s):
        s_loc = c.shape[1]
        rank = jax.lax.axis_index("model").astype(jnp.int32)
        ls = s - rank * s_loc
        inb = (ls >= 0) & (ls < s_loc)
        ls_c = jnp.clip(ls, 0, s_loc - 1)
        z = jnp.zeros((), jnp.int32)
        old = jax.lax.dynamic_slice(
            c, (z, ls_c, z, z), (c.shape[0], 1, c.shape[2], c.shape[3]))
        upd = jnp.where(inb, n.astype(c.dtype), old)
        return jax.lax.dynamic_update_slice(c, upd, (z, ls_c, z, z))

    from ..compat import shard_map

    return shard_map(
        inner, mesh=mesh,
        in_specs=(PartitionSpec(dp_spec, "model", None, None),
                  PartitionSpec(dp_spec, None, None, None),
                  PartitionSpec()),
        out_specs=PartitionSpec(dp_spec, "model", None, None),
        check_vma=False,
    )(cache_arr, new, slot)


def attention_decode(params, cfg: AttnConfig, x, cache: KVCache, *,
                     use_kernel: bool = False, mesh=None):
    """Single-token decode. x: [B, 1, D]; returns (out [B,1,D], new cache).

    With a sliding window the cache is a rolling buffer of size window.
    """
    b = x.shape[0]
    pos = cache.length
    q, k_new, v_new = _qkv(params, cfg, x, jnp.full((b, 1), pos))
    size = cache.k.shape[1]
    slot = jnp.where(cfg.window > 0, pos % size, pos)
    k = _cache_update(cache.k, k_new, slot, mesh)
    v = _cache_update(cache.v, v_new, slot, mesh)
    kvh, dh = cfg.n_kv_heads, cfg.head_dim
    groups = cfg.n_heads // kvh
    qg = q.reshape(b, kvh, groups, dh)
    if use_kernel:
        from ..kernels.decode_gqa import ops as dg_ops
        valid_len = jnp.minimum(pos + 1, size)
        out = dg_ops.decode_gqa(q[:, 0], k, v, valid_len)
    else:
        logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                            preferred_element_type=jnp.float32) / jnp.sqrt(dh)
        kpos = jnp.arange(size)
        valid = kpos <= pos if cfg.window == 0 else (
            (kpos <= pos) | (pos >= size)
        )
        logits = jnp.where(valid[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        out = out.reshape(b, cfg.n_heads, dh)
    out = out.reshape(b, 1, cfg.n_heads, dh).astype(x.dtype)
    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return proj, KVCache(k=k, v=v, length=pos + 1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_params(d: int, f: int, gated: bool = True) -> dict:
    p = {
        "w_in": P((d, f), ("embed", "mlp")),
        "w_out": P((f, d), ("mlp", "embed")),
    }
    if gated:
        p["w_gate"] = P((d, f), ("embed", "mlp"))
    return p


def mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(x.dtype))
    if "w_gate" in params:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(x.dtype))
