"""Model substrate: the 10 assigned architectures behind one registry."""
from .lm import DecoderLM, ModelConfig
from .encdec import EncDecLM
from .registry import (ARCH_NAMES, build_model, get_config, input_specs,
                       reduced_config)

__all__ = ["DecoderLM", "EncDecLM", "ModelConfig", "ARCH_NAMES",
           "build_model", "get_config", "input_specs", "reduced_config"]
