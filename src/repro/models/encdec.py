"""Encoder-decoder backbone (whisper-small). The audio conv frontend is a
STUB per the assignment brief: input_specs() provides precomputed frame
embeddings [B, enc_seq, d_model] (what whisper's two conv layers would emit).

Simplifications vs arXiv:2212.04356, documented in DESIGN.md: RMSNorm instead
of LayerNorm+bias, sinusoidal positions on both sides (whisper-small's learned
decoder positions cap at 448 tokens; the assigned decode_32k shape requires
arbitrary positions), non-gated GELU MLP (faithful).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import (AttnConfig, KVCache, attention, attention_decode,
                     attention_params, init_kv_cache, mlp, mlp_params,
                     rmsnorm, rmsnorm_params, _qkv)
from .spec import (P, abstract_params, count_params, init_params,
                   logical_constraint, param_shardings, param_specs)


def sinusoidal(positions: jax.Array, d: int, dtype) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half) / half * jnp.log(10_000.0))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


class EncDecLM:
    """Whisper-style enc-dec; mirrors DecoderLM's public API."""

    def __init__(self, cfg):
        self.cfg = cfg  # ModelConfig with enc_layers/enc_seq set

    def _attn_cfg(self, causal: bool) -> AttnConfig:
        c = self.cfg.attn_config(causal=causal)
        return c._replace(use_rope=False)  # absolute sinusoidal positions

    def _enc_block_desc(self) -> dict:
        cfg = self.cfg
        return {
            "ln1": rmsnorm_params(cfg.d_model),
            "attn": attention_params(self._attn_cfg(False)),
            "ln2": rmsnorm_params(cfg.d_model),
            "ffn": mlp_params(cfg.d_model, cfg.d_ff, gated=False),
        }

    def _dec_block_desc(self) -> dict:
        cfg = self.cfg
        return {
            "ln1": rmsnorm_params(cfg.d_model),
            "self_attn": attention_params(self._attn_cfg(True)),
            "ln_x": rmsnorm_params(cfg.d_model),
            "cross_attn": attention_params(self._attn_cfg(False)),
            "ln2": rmsnorm_params(cfg.d_model),
            "ffn": mlp_params(cfg.d_model, cfg.d_ff, gated=False),
        }

    def param_descriptors(self) -> dict:
        cfg = self.cfg
        return {
            "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed")),
            "enc_norm": rmsnorm_params(cfg.d_model),
            "final_norm": rmsnorm_params(cfg.d_model),
            "lm_head": P((cfg.d_model, cfg.vocab), ("embed", "vocab")),
            "encoder": [self._enc_block_desc() for _ in range(cfg.enc_layers)],
            "decoder": [self._dec_block_desc() for _ in range(cfg.n_layers)],
        }

    def init(self, key, dtype=jnp.float32):
        return init_params(key, self.param_descriptors(), dtype)

    def abstract_params(self, dtype=jnp.float32):
        return abstract_params(self.param_descriptors(), dtype)

    def param_specs(self, mesh):
        return param_specs(self.param_descriptors(), mesh)

    def param_shardings(self, mesh, drop_axes: tuple = ()):
        return param_shardings(self.param_descriptors(), mesh, drop_axes)

    def n_params(self) -> int:
        return count_params(self.param_descriptors())

    n_active_params = n_params

    # -- encoder -------------------------------------------------------------

    def encode(self, params, frames: jax.Array, mesh=None) -> jax.Array:
        """frames: [B, S_enc, D] (stub frontend output) -> [B, S_enc, D]."""
        cfg = self.cfg
        x = frames.astype(cfg.dtype)
        x = x + sinusoidal(jnp.arange(x.shape[1]), cfg.d_model, cfg.dtype)
        x = logical_constraint(x, ("batch", "seq", None), mesh)
        for p in params["encoder"]:
            x = x + attention(p["attn"], self._attn_cfg(False),
                              rmsnorm(p["ln1"], x))
            x = x + mlp(p["ffn"], rmsnorm(p["ln2"], x))
        return rmsnorm(params["enc_norm"], x)

    # -- decoder -------------------------------------------------------------

    def _dec_block(self, p, x, enc_out, positions):
        x = x + attention(p["self_attn"], self._attn_cfg(True),
                          rmsnorm(p["ln1"], x), positions)
        h = rmsnorm(p["ln_x"], x)
        _, ek, ev = _qkv(p["cross_attn"], self._attn_cfg(False), enc_out,
                         jnp.arange(enc_out.shape[1]))
        x = x + attention(p["cross_attn"], self._attn_cfg(False), h,
                          positions, kv=(ek, ev))
        x = x + mlp(p["ffn"], rmsnorm(p["ln2"], x))
        return x

    def forward(self, params, batch_or_tokens, mesh=None, frames=None):
        if isinstance(batch_or_tokens, dict):
            tokens = batch_or_tokens["tokens"]
            frames = batch_or_tokens["frames"]
        else:
            tokens = batch_or_tokens
        cfg = self.cfg
        enc_out = self.encode(params, frames, mesh)
        x = params["embed"].astype(cfg.dtype)[tokens]
        x = x + sinusoidal(jnp.arange(x.shape[1]), cfg.d_model, cfg.dtype)
        x = logical_constraint(x, ("batch", "seq", None), mesh)
        pos = jnp.arange(x.shape[1])
        for p in params["decoder"]:
            x = self._dec_block(p, x, enc_out, pos)
        hidden = rmsnorm(params["final_norm"], x)
        logits = jnp.einsum("bsd,dv->bsv", hidden,
                            params["lm_head"].astype(hidden.dtype))
        return logical_constraint(logits, ("batch", "seq", "vocab"), mesh)

    def loss(self, params, batch: dict, mesh=None):
        logits = self.forward(params, batch, mesh).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
        return jnp.mean(nll), {"nll": jnp.mean(nll),
                               "aux": jnp.zeros((), jnp.float32)}

    # -- serving --------------------------------------------------------------

    class Cache(NamedTuple):
        self_kv: list          # per decoder layer KVCache
        cross_kv: list         # per decoder layer (k, v) of encoder output
        length: jax.Array

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        self_kv = [init_kv_cache(batch, max_seq, self._attn_cfg(True), dtype)
                   for _ in range(cfg.n_layers)]
        kvh, dh = cfg.n_kv_heads, cfg.resolved_head_dim
        cross = [(jnp.zeros((batch, cfg.enc_seq, kvh, dh), dtype),) * 2
                 for _ in range(cfg.n_layers)]
        return EncDecLM.Cache(self_kv=self_kv, cross_kv=cross,
                              length=jnp.zeros((), jnp.int32))

    def cache_axes(self):
        cfg = self.cfg
        kv_axes = KVCache(k=("batch", "kv_seq", "kv_heads", None),
                          v=("batch", "kv_seq", "kv_heads", None), length=())
        cross = (("batch", None, "kv_heads", None),) * 2
        return EncDecLM.Cache(
            self_kv=[kv_axes] * cfg.n_layers,
            cross_kv=[cross] * cfg.n_layers,
            length=(),
        )

    def cache_shardings(self, mesh, batch: int, max_seq: int,
                        dtype=jnp.bfloat16):
        import functools
        from .spec import shardings_for_tree
        shapes = jax.eval_shape(
            functools.partial(self.init_cache, batch, max_seq, dtype))
        return shardings_for_tree(shapes, self.cache_axes(), mesh)

    def prefill(self, params, tokens, mesh=None, frames=None):
        """Encode + consume the prompt; returns (last logits, cache)."""
        cfg = self.cfg
        b, s = tokens.shape
        enc_out = self.encode(params, frames, mesh)
        x = params["embed"].astype(cfg.dtype)[tokens]
        x = x + sinusoidal(jnp.arange(s), cfg.d_model, cfg.dtype)
        pos = jnp.arange(s)
        self_kv, cross_kv = [], []
        for p in params["decoder"]:
            h = rmsnorm(p["ln1"], x)
            acfg = self._attn_cfg(True)
            q, k, v = _qkv(p["self_attn"], acfg, h, pos)
            from .layers import _sdpa
            mix = _sdpa(q, k, v, acfg)
            x = x + jnp.einsum("bshk,hkd->bsd", mix,
                               p["self_attn"]["wo"].astype(x.dtype))
            self_kv.append(KVCache(k=k.astype(jnp.bfloat16),
                                   v=v.astype(jnp.bfloat16),
                                   length=jnp.asarray(s, jnp.int32)))
            hx = rmsnorm(p["ln_x"], x)
            _, ek, ev = _qkv(p["cross_attn"], self._attn_cfg(False), enc_out,
                             jnp.arange(enc_out.shape[1]))
            x = x + attention(p["cross_attn"], self._attn_cfg(False), hx, pos,
                              kv=(ek, ev))
            cross_kv.append((ek.astype(jnp.bfloat16), ev.astype(jnp.bfloat16)))
            x = x + mlp(p["ffn"], rmsnorm(p["ln2"], x))
        hidden = rmsnorm(params["final_norm"], x[:, -1:])
        logits = jnp.einsum("bsd,dv->bsv", hidden,
                            params["lm_head"].astype(hidden.dtype))[:, 0]
        # pad self-kv to allow further decoding is left to the caller's max_seq
        return logits.astype(jnp.float32), EncDecLM.Cache(
            self_kv=self_kv, cross_kv=cross_kv,
            length=jnp.asarray(s, jnp.int32))

    def decode_step(self, params, tokens, cache, mesh=None):
        cfg = self.cfg
        x = params["embed"].astype(cfg.dtype)[tokens][:, None]
        x = x + sinusoidal(cache.length[None], cfg.d_model, cfg.dtype)[None]
        new_self = []
        for p, kv, (ek, ev) in zip(params["decoder"], cache.self_kv,
                                   cache.cross_kv):
            h = rmsnorm(p["ln1"], x)
            kvc = kv._replace(length=cache.length)
            mix, nkv = attention_decode(p["self_attn"], self._attn_cfg(True),
                                        h, kvc, mesh=mesh)
            x = x + mix
            new_self.append(nkv)
            hx = rmsnorm(p["ln_x"], x)
            pos = cache.length[None, None]
            x = x + attention(p["cross_attn"], self._attn_cfg(False), hx,
                              jnp.broadcast_to(pos, (x.shape[0], 1)),
                              kv=(ek.astype(x.dtype), ev.astype(x.dtype)))
            x = x + mlp(p["ffn"], rmsnorm(p["ln2"], x))
        hidden = rmsnorm(params["final_norm"], x)
        logits = jnp.einsum("bsd,dv->bsv", hidden,
                            params["lm_head"].astype(hidden.dtype))[:, 0]
        return logits.astype(jnp.float32), EncDecLM.Cache(
            self_kv=new_self, cross_kv=cache.cross_kv,
            length=cache.length + 1)
