"""Mixture-of-Experts layer with sort-based token dispatch (expert parallel).

Top-k routing -> flatten (token, expert) assignments -> argsort by expert ->
capacity-bounded scatter into an [E, C, D] buffer -> batched per-expert
matmuls -> weighted scatter-add back to tokens. The [E, ...] dims carry the
"experts" logical axis, so experts shard over the `model` mesh axis (EP) and
GSPMD inserts the all-to-all at the token->expert boundary.

FLOP cost is top_k/E of the dense-all-experts equivalent (vs the E/top_k
overhead of naive one-hot dispatch), which is what makes the moonshot config
(64 experts, top-6) roofline-viable.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .spec import P


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int           # per-expert hidden size
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


def moe_params(cfg: MoEConfig) -> dict:
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": P((d, e), ("embed", "experts"), scale=0.1),
        "w_gate": P((e, d, f), ("experts", "embed", "mlp")),
        "w_in": P((e, d, f), ("experts", "embed", "mlp")),
        "w_out": P((e, f, d), ("experts", "mlp", "embed")),
    }


class MoEOut(NamedTuple):
    y: jax.Array
    aux_loss: jax.Array    # load-balance loss (Switch-style)
    dropped_frac: jax.Array


def moe(params: dict, cfg: MoEConfig, x: jax.Array) -> MoEOut:
    """x: [B, S, D] -> MoEOut with y: [B, S, D]."""
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, k)               # [T, k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # load-balance aux loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                              # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=1),
        axis=0,
    ) / k
    aux = e * jnp.sum(me * ce)

    flat_expert = expert_idx.reshape(-1)                      # [T*k]
    flat_token = jnp.repeat(jnp.arange(t), k)
    flat_gate = gate.reshape(-1)

    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    counts = jnp.bincount(flat_expert, length=e)
    starts = jnp.cumsum(counts) - counts                      # [E]
    pos = jnp.arange(t * k) - starts[sorted_expert]

    cap = max(1, int(round(t * k / e * cfg.capacity_factor)))
    keep = pos < cap
    buf_idx = jnp.where(keep, sorted_expert * cap + pos, e * cap)  # drop slot

    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[buf_idx].set(xf[sorted_token])
    buf = buf[:-1].reshape(e, cap, d)

    gt = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_in"].astype(x.dtype))
    h = jax.nn.silu(gt) * up
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(x.dtype))

    yf = y_e.reshape(e * cap, d)
    contrib = jnp.where(keep[:, None],
                        yf[jnp.minimum(buf_idx, e * cap - 1)]
                        * sorted_gate[:, None].astype(x.dtype),
                        0.0)
    y = jnp.zeros((t, d), x.dtype).at[sorted_token].add(contrib)
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (t * k)
    return MoEOut(y=y.reshape(b, s, d), aux_loss=aux, dropped_frac=dropped)


# ---------------------------------------------------------------------------
# Local-dispatch expert parallelism (§Perf optimization, beyond-paper).
#
# The global-argsort dispatch above lets GSPMD implement token gathers across
# the *data* axis as full-activation all-gathers (~hundreds of GiB/layer for
# dbrx train — see EXPERIMENTS.md §Perf). Local dispatch shard_maps the layer:
# activations stay sharded over the data axes and replicated over `model`;
# each model rank routes its (local) tokens to the experts it owns, computes,
# and a single activation-sized psum over `model` combines the top-k expert
# contributions. Per-layer wire drops from O(T·D·gathers) on the data axis to
# one [T_local, D] all-reduce on the model axis.
# ---------------------------------------------------------------------------

def moe_local(params: dict, cfg: MoEConfig, x: jax.Array, mesh) -> MoEOut:
    """shard_map'd MoE. Falls back to global dispatch when the mesh has no
    usable `model` axis or experts don't divide across it."""
    from jax.sharding import PartitionSpec
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if n_model <= 1 or cfg.n_experts % n_model != 0:
        return moe(params, cfg, x)
    e_loc = cfg.n_experts // n_model
    k = cfg.top_k
    e = cfg.n_experts
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if len(dp) != 1 else dp[0]

    def inner(router, wg, wi, wo, xl):
        b, s, d = xl.shape
        t = b * s
        xf = xl.reshape(t, d)
        logits = jnp.einsum("td,de->te", xf, router.astype(xl.dtype))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        gate, eidx = jax.lax.top_k(probs, k)
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(eidx, e, dtype=jnp.float32),
                              axis=1), axis=0) / k
        aux = e * jnp.sum(me * ce)

        mi = jax.lax.axis_index("model")
        lo = mi * e_loc
        flat_e = eidx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(t), k)
        flat_g = gate.reshape(-1)
        is_local = (flat_e >= lo) & (flat_e < lo + e_loc)
        le = jnp.where(is_local, flat_e - lo, e_loc)  # e_loc = drop bucket
        order = jnp.argsort(le)
        se, st_, sg = le[order], flat_t[order], flat_g[order]
        counts = jnp.bincount(se, length=e_loc + 1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(t * k) - starts[se]
        cap = max(1, int(round(t * k / e * cfg.capacity_factor)))
        keep = (pos < cap) & (se < e_loc)
        buf_idx = jnp.where(keep, se * cap + pos, e_loc * cap)
        buf = jnp.zeros((e_loc * cap + 1, d), xl.dtype
                        ).at[buf_idx].set(xf[st_])
        buf = buf[:-1].reshape(e_loc, cap, d)
        gt = jnp.einsum("ecd,edf->ecf", buf, wg.astype(xl.dtype))
        up = jnp.einsum("ecd,edf->ecf", buf, wi.astype(xl.dtype))
        y_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gt) * up,
                         wo.astype(xl.dtype))
        yf = y_e.reshape(e_loc * cap, d)
        contrib = jnp.where(keep[:, None],
                            yf[jnp.minimum(buf_idx, e_loc * cap - 1)]
                            * sg[:, None].astype(xl.dtype), 0.0)
        y = jnp.zeros((t, d), xl.dtype).at[st_].add(contrib)
        y = jax.lax.psum(y, "model")
        dropped = jax.lax.psum(
            jnp.sum((~keep & is_local[order]).astype(jnp.float32)), "model"
        ) / (t * k)
        return y.reshape(b, s, d), aux, dropped

    from ..compat import shard_map

    y, aux, dropped = shard_map(
        inner, mesh=mesh,
        in_specs=(PartitionSpec(), PartitionSpec("model", None, None),
                  PartitionSpec("model", None, None),
                  PartitionSpec("model", None, None),
                  PartitionSpec(dp_spec, None, None)),
        out_specs=(PartitionSpec(dp_spec, None, None), PartitionSpec(),
                   PartitionSpec()),
        check_vma=False,
    )(params["router"], params["w_gate"], params["w_in"], params["w_out"], x)
    return MoEOut(y=y, aux_loss=aux, dropped_frac=dropped)
