"""Architecture registry: --arch <id> -> model instance + input specs."""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from .encdec import EncDecLM
from .lm import DecoderLM, ModelConfig

_ARCH_MODULES = {
    "hymba-1.5b": "hymba_1_5b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "dbrx-132b": "dbrx_132b",
    "granite-20b": "granite_20b",
    "llama3.2-1b": "llama3_2_1b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-14b": "qwen3_14b",
    "xlstm-125m": "xlstm_125m",
    "chameleon-34b": "chameleon_34b",
    "whisper-small": "whisper_small",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def build_model(cfg_or_name):
    cfg = get_config(cfg_or_name) if isinstance(cfg_or_name, str) else cfg_or_name
    if cfg.family == "audio":
        return EncDecLM(cfg)
    return DecoderLM(cfg)


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    import dataclasses

    heads = min(cfg.n_heads, 4)
    kv = max(1, min(cfg.n_kv_heads, heads))
    heads = (heads // kv) * kv
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=256,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        moe_capacity_factor=4.0,  # dropless at smoke-test scale
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        window=min(cfg.window, 16) if cfg.window else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_seq=24 if cfg.enc_layers else 1500,
        dtype=jnp.float32,
    )


def input_specs(cfg: ModelConfig, shape_cfg) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a (arch, shape)
    cell — weak-type-correct, shardable, no device allocation."""
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    tok = lambda shape: jax.ShapeDtypeStruct(shape, jnp.int32)
    if shape_cfg.kind == "train":
        specs = {"tokens": tok((b, s)), "labels": tok((b, s))}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if shape_cfg.kind == "prefill":
        specs = {"tokens": tok((b, s))}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token against a cache of seq_len
    return {"tokens": tok((b,))}
