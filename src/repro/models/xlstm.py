"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) + sLSTM (scalar memory,
recurrent). Simplifications vs arXiv:2405.04517 (documented in DESIGN.md):

  * mLSTM is expressed as gated linear attention and reuses the SSD chunk
    machinery from models.ssm (state = k⊗v matrix per head + normalizer
    column). Input gate uses softplus intensity instead of the stabilized
    exponential gate — same qualitative dynamics, numerically tame.
  * sLSTM keeps the stabilized exponential gating (m_t running max trick) and
    the per-head recurrent R matrices; it scans over time (inherently
    sequential, as the paper notes).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .spec import P
from .ssm import ssd_scan


class XLSTMConfig(NamedTuple):
    d_model: int
    n_heads: int
    chunk: int = 128

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_params(cfg: XLSTMConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    return {
        "w_q": P((d, d), ("embed", "heads")),
        "w_k": P((d, d), ("embed", "heads")),
        "w_v": P((d, d), ("embed", "heads")),
        "w_if": P((d, 2 * h), ("embed", "heads"), scale=0.1),
        "if_bias": P((2 * h,), ("heads",), init="zeros"),
        "w_z": P((d, d), ("embed", "heads")),
        "head_norm": {"scale": P((cfg.head_dim,), (None,), init="ones")},
        "w_out": P((d, d), ("heads", "embed")),
    }


def _mlstm_gates(params, cfg: XLSTMConfig, x):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["w_q"].astype(x.dtype)).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", x, params["w_k"].astype(x.dtype)).reshape(b, s, h, dh)
    v = jnp.einsum("bsd,de->bse", x, params["w_v"].astype(x.dtype)).reshape(b, s, h, dh)
    k = k / jnp.sqrt(jnp.asarray(dh, x.dtype))
    gif = jnp.einsum("bsd,de->bse", x, params["w_if"].astype(x.dtype)) + params[
        "if_bias"
    ].astype(x.dtype)
    i_pre, f_pre = jnp.split(gif.reshape(b, s, 2, h), 2, axis=2)
    i_gate = jax.nn.softplus(i_pre[:, :, 0])            # [B,S,H] >= 0
    f_gate = jax.nn.sigmoid(f_pre[:, :, 0].astype(jnp.float32))  # decay in (0,1)
    return q, k, v, i_gate, f_gate


def _mlstm_norm_out(params, cfg, y_ext, z, x_dtype):
    """Split (values, normalizer), normalize, head-norm, gate, project."""
    y, norm = y_ext[..., :-1], y_ext[..., -1:]
    y = y / jnp.maximum(jnp.abs(norm), 1.0)
    from .layers import rmsnorm
    y = rmsnorm(params["head_norm"], y)
    b, s = y.shape[:2]
    y = y.reshape(b, s, cfg.d_model).astype(x_dtype) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x_dtype))


def mlstm(params: dict, cfg: XLSTMConfig, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    q, k, v, i_gate, f_gate = _mlstm_gates(params, cfg, x)
    ones = jnp.ones((*v.shape[:-1], 1), v.dtype)
    v_ext = jnp.concatenate([v, ones], axis=-1)          # normalizer column
    y_ext, _ = ssd_scan(f_gate, i_gate, k, q, v_ext, cfg.chunk)
    z = jnp.einsum("bsd,de->bse", x, params["w_z"].astype(x.dtype))
    return _mlstm_norm_out(params, cfg, y_ext, z, x.dtype)


class MLSTMCache(NamedTuple):
    h: jax.Array   # [B, H, Dh, Dh+1] f32 (matrix memory + normalizer)


def init_mlstm_cache(batch: int, cfg: XLSTMConfig) -> MLSTMCache:
    return MLSTMCache(
        h=jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim + 1),
                    jnp.float32)
    )


def mlstm_decode(params: dict, cfg: XLSTMConfig, x: jax.Array,
                 cache: MLSTMCache):
    b = x.shape[0]
    q, k, v, i_gate, f_gate = _mlstm_gates(params, cfg, x)
    ones = jnp.ones((*v.shape[:-1], 1), v.dtype)
    v_ext = jnp.concatenate([v, ones], axis=-1)
    u = i_gate[:, 0, :, None, None].astype(jnp.float32) * (
        k[:, 0].astype(jnp.float32)[..., None]
        * v_ext[:, 0].astype(jnp.float32)[:, :, None, :]
    )
    h_new = f_gate[:, 0, :, None, None] * cache.h + u
    y_ext = jnp.einsum("bhn,bhnd->bhd", q[:, 0].astype(jnp.float32), h_new)
    z = jnp.einsum("bsd,de->bse", x, params["w_z"].astype(x.dtype))
    out = _mlstm_norm_out(params, cfg, y_ext[:, None], z, x.dtype)
    return out, MLSTMCache(h=h_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_params(cfg: XLSTMConfig) -> dict:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "w_gates": P((d, 4 * d), ("embed", "heads")),        # z, i, f, o
        "r_gates": P((h, dh, 4 * dh), ("heads", None, None), scale=0.5),
        "b_gates": P((4 * d,), ("heads",), init="zeros"),
        "head_norm": {"scale": P((dh,), (None,), init="ones")},
        "w_out": P((d, d), ("heads", "embed")),
    }


class SLSTMState(NamedTuple):
    c: jax.Array   # [B, H, Dh]
    n: jax.Array
    h: jax.Array
    m: jax.Array   # stabilizer (running max of log gates)


def init_slstm_state(batch: int, cfg: XLSTMConfig) -> SLSTMState:
    z = jnp.zeros((batch, cfg.n_heads, cfg.head_dim), jnp.float32)
    return SLSTMState(c=z, n=z + 1e-6, h=z, m=z - 10.0)


def _slstm_cell(params, cfg: XLSTMConfig, state: SLSTMState, wx_t):
    """One timestep. wx_t: [B, 4*D] precomputed input projection."""
    b = wx_t.shape[0]
    h_, dh = cfg.n_heads, cfg.head_dim
    rec = jnp.einsum("bhd,hde->bhe", state.h.astype(wx_t.dtype),
                     params["r_gates"].astype(wx_t.dtype))   # [B,H,4*Dh]
    gates = wx_t.reshape(b, h_, 4 * dh) + rec + params["b_gates"].astype(
        wx_t.dtype
    ).reshape(h_, 4 * dh)
    z_pre, i_pre, f_pre, o_pre = jnp.split(gates.astype(jnp.float32), 4, -1)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    lf = -jax.nn.softplus(-f_pre)     # log sigmoid(f_pre)
    li = i_pre
    m_new = jnp.maximum(lf + state.m, li)
    i_g = jnp.exp(li - m_new)
    f_g = jnp.exp(lf + state.m - m_new)
    c_new = f_g * state.c + i_g * z
    n_new = f_g * state.n + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return SLSTMState(c=c_new, n=n_new, h=h_new, m=m_new)


def slstm(params: dict, cfg: XLSTMConfig, x: jax.Array,
          state: SLSTMState | None = None):
    """Full-sequence sLSTM. x: [B,S,D] -> ([B,S,D], final state)."""
    b, s, d = x.shape
    wx = jnp.einsum("bsd,de->bse", x, params["w_gates"].astype(x.dtype))
    if state is None:
        state = init_slstm_state(b, cfg)

    def step(st, wx_t):
        st = _slstm_cell(params, cfg, st, wx_t)
        return st, st.h

    state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1)  # [B,S,H,Dh]
    from .layers import rmsnorm
    hs = rmsnorm(params["head_norm"], hs).reshape(b, s, d).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", hs, params["w_out"].astype(x.dtype)), state
