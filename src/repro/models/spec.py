"""Parameter descriptors with logical sharding axes (MaxText-style rules).

Models declare parameters as ``P(shape, logical_axes)`` descriptors in a
nested dict. ``init_params`` materializes them; ``param_specs`` resolves each
logical axis to mesh axes via LOGICAL_RULES with a divisibility fallback
(a dim that does not divide evenly over its mesh axes is left unsharded, so
e.g. GQA kv_heads=1 or vocab=32001 simply replicate instead of erroring).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec


class P(NamedTuple):
    """Declarative parameter: shape + logical axis names + initializer."""

    shape: tuple
    axes: tuple          # logical axis name per dim (None -> replicated)
    init: str = "normal"  # normal | zeros | ones | scaled (fan-in)
    scale: float = 1.0


#: logical axis -> tuple of mesh axis names (missing mesh axes are skipped)
LOGICAL_RULES: dict[str, tuple] = {
    "vocab": ("model",),
    "embed": ("pod", "data"),      # FSDP / ZeRO-3 weight sharding
    "heads": ("model",),           # tensor parallel attention
    "kv_heads": ("model",),
    "mlp": ("model",),             # tensor parallel feed-forward
    "experts": ("model",),         # expert parallel MoE
    "ssm_inner": ("model",),
    "batch": ("pod", "data"),      # data parallel
    "kv_seq": ("model",),          # sequence-sharded KV cache (flash-decode)
    "seq": (),
    "head_dim": (),
    "state": (),
    "layers": (),
    "conv": (),
}


def mesh_axis_size(mesh: Mesh, names: tuple) -> int:
    return math.prod(mesh.shape[n] for n in names if n in mesh.axis_names)


def resolve_spec(shape: tuple, axes: tuple, mesh: Mesh,
                 drop_axes: tuple = ()) -> PartitionSpec:
    """Logical axes -> PartitionSpec honoring divisibility and single-use.

    ``drop_axes``: logical names to leave unsharded — e.g. serving paths drop
    'embed' (the FSDP dim) so weights replicate over the data axes instead of
    being re-gathered every decode step."""
    used: set = set()
    out = []
    for dim, ax in zip(shape, axes):
        entry = None
        if ax is not None and ax not in drop_axes:
            mesh_axes = tuple(
                m for m in LOGICAL_RULES.get(ax, ())
                if m in mesh.axis_names and m not in used
            )
            if mesh_axes and dim % mesh_axis_size(mesh, mesh_axes) == 0:
                entry = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
                used.update(mesh_axes)
        out.append(entry)
    while out and out[-1] is None:  # trailing Nones are implicit
        out.pop()
    return PartitionSpec(*out)


def is_descriptor(x: Any) -> bool:
    return isinstance(x, P)


def init_params(key: jax.Array, tree: Any, dtype=jnp.float32) -> Any:
    """Materialize a descriptor tree into arrays (fan-in scaled normals)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_descriptor)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, p in zip(keys, leaves):
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dtype))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dtype))
        else:
            fan_in = p.shape[0] if len(p.shape) == 1 else math.prod(p.shape[:-1])
            std = p.scale / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, p.shape) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(tree: Any, dtype=jnp.float32) -> Any:
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), tree,
        is_leaf=is_descriptor,
    )


def param_specs(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda p: resolve_spec(p.shape, p.axes, mesh), tree,
        is_leaf=is_descriptor,
    )


def param_shardings(tree: Any, mesh: Mesh, drop_axes: tuple = ()) -> Any:
    return jax.tree.map(
        lambda p: NamedSharding(mesh, resolve_spec(p.shape, p.axes, mesh,
                                                   drop_axes)),
        tree, is_leaf=is_descriptor,
    )


def logical_constraint(x: jax.Array, axes: tuple, mesh: Mesh | None) -> jax.Array:
    """Activation sharding constraint by logical axes (no-op without a mesh)."""
    if mesh is None or not mesh.axis_names or math.prod(mesh.devices.shape) == 1:
        return x
    spec = resolve_spec(x.shape, axes, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def count_params(tree: Any) -> int:
    """Total parameter count of a descriptor tree (no materialization)."""
    leaves = jax.tree.leaves(tree, is_leaf=is_descriptor)
    return sum(math.prod(p.shape) for p in leaves)


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x
    )


def shardings_for_tree(shapes: Any, axes: Any, mesh: Mesh) -> Any:
    """NamedShardings for an arbitrary pytree of ShapeDtypeStructs given a
    structurally-matching tree whose leaves are logical-axes tuples."""
    s_leaves, treedef = jax.tree.flatten(shapes)
    a_leaves = jax.tree.flatten(axes, is_leaf=_is_axes_leaf)[0]
    assert len(s_leaves) == len(a_leaves), "axes tree mismatch"
    out = [
        NamedSharding(mesh, resolve_spec(s.shape, a, mesh))
        for s, a in zip(s_leaves, a_leaves)
    ]
    return jax.tree.unflatten(treedef, out)
