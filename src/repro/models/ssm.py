"""Selective state-space (Mamba-2/SSD-style) layer — chunked parallel scan.

The recurrence per head (state matrix h: [N, Dh]):

    h_t = a_t * h_{t-1} + dt_t * (B_t ⊗ x_t)        a_t = exp(-softplus(A) dt_t)
    y_t = C_t · h_t + D * x_t

is evaluated in chunks of length Q ("SSD" decomposition): within a chunk a
masked decay matrix turns the scan into two small matmuls (linear-attention
form); across chunks a lax.scan carries the [B, H, N, Dh] state. This is the
TPU-native adaptation of Mamba's CUDA selective-scan: MXU-friendly chunk
matmuls instead of a warp-level sequential scan (DESIGN.md "hardware
adaptation"). Decode is the O(1) recurrence step.

Used by hymba's parallel attention+SSM heads and reused (as chunked gated
linear attention) by the xLSTM mLSTM block.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .spec import P


class SSMConfig(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    state: int          # N
    conv_kernel: int = 4
    chunk: int = 128

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


def ssm_params(cfg: SSMConfig) -> dict:
    d, i, h, n = cfg.d_model, cfg.d_inner, cfg.n_heads, cfg.state
    return {
        "w_in": P((d, 2 * i), ("embed", "ssm_inner")),       # x and gate z
        "conv": P((cfg.conv_kernel, i), ("conv", "ssm_inner"), scale=0.5),
        "w_dt": P((d, h), ("embed", "heads"), scale=0.1),
        "dt_bias": P((h,), ("heads",), init="zeros"),
        "w_bc": P((d, 2 * h * n), ("embed", "heads"), scale=0.5),
        "a_log": P((h,), ("heads",), init="zeros"),
        "d_skip": P((h,), ("heads",), init="ones"),
        "w_out": P((i, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, carry: jax.Array | None = None):
    """Depthwise causal conv along seq. x: [B,S,I], w: [K,I].

    carry: [B, K-1, I] previous inputs for decode; returns (y, new_carry).
    """
    k = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k)
    )
    new_carry = xp[:, -(k - 1):] if k > 1 else carry
    return jax.nn.silu(y), new_carry


def _gates(params, cfg: SSMConfig, xr: jax.Array):
    """Common projections. xr: [B,S,D] -> (a, dt, B, C) with
    a,dt: [B,S,H]; B,C: [B,S,H,N]."""
    b, s, _ = xr.shape
    h, n = cfg.n_heads, cfg.state
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", xr, params["w_dt"].astype(xr.dtype))
        + params["dt_bias"].astype(xr.dtype)
    )
    bc = jnp.einsum("bsd,de->bse", xr, params["w_bc"].astype(xr.dtype))
    bmat, cmat = jnp.split(bc.reshape(b, s, h, 2 * n), 2, axis=-1)
    a = jnp.exp(-jax.nn.softplus(params["a_log"].astype(jnp.float32)) * dt.astype(jnp.float32))
    return a, dt, bmat, cmat


def ssd_scan(a, dt, bmat, cmat, values, chunk: int, h0=None):
    """Chunked linear recurrence.

    a, dt: [B,S,H]; bmat/cmat: [B,S,H,N]; values: [B,S,H,Dh].
    Returns (y: [B,S,H,Dh], h_final: [B,H,N,Dh]). f32 state.
    """
    b, s, h, n = bmat.shape
    dh = values.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"
    nc = s // q

    def resh(x):
        return x.reshape(b, nc, q, *x.shape[2:]).swapaxes(0, 1)

    a_c, dt_c, b_c, c_c, v_c = map(resh, (a, dt, bmat, cmat, values))
    if h0 is None:
        h0 = jnp.zeros((b, h, n, dh), jnp.float32)

    mask = jnp.tril(jnp.ones((q, q), bool))

    def chunk_step(hc, xs):
        ac, dtc, bb, cc, vv = xs          # [B,Q,H,...]
        la = jnp.log(jnp.maximum(ac.astype(jnp.float32), 1e-37))
        cum = jnp.cumsum(la, axis=1)      # [B,Q,H] inclusive
        # intra-chunk: G[i,j] = (C_i . B_j) exp(cum_i - cum_j) (j <= i)
        decay = jnp.exp(cum[:, :, None] - cum[:, None, :, :])  # [B,Q,Q,H]
        decay = jnp.where(mask[None, :, :, None], decay, 0.0)
        g = jnp.einsum("bihn,bjhn->bijh", cc.astype(jnp.float32),
                       bb.astype(jnp.float32)) * decay
        g = g * dtc.astype(jnp.float32)[:, None]
        y_intra = jnp.einsum("bijh,bjhd->bihd", g, vv.astype(jnp.float32))
        # inter-chunk: C_i . (exp(cum_i) h_start)
        y_inter = jnp.einsum(
            "bihn,bhnd->bihd", cc.astype(jnp.float32) * jnp.exp(cum)[..., None], hc
        )
        # state update
        w = jnp.exp(cum[:, -1:, :] - cum) * dtc.astype(jnp.float32)  # [B,Q,H]
        h_new = (
            jnp.exp(cum[:, -1])[:, :, None, None] * hc
            + jnp.einsum("bqh,bqhn,bqhd->bhnd", w, bb.astype(jnp.float32),
                         vv.astype(jnp.float32))
        )
        return h_new, (y_intra + y_inter).astype(values.dtype)

    h_final, y = jax.lax.scan(chunk_step, h0, (a_c, dt_c, b_c, c_c, v_c))
    y = y.swapaxes(0, 1).reshape(b, s, h, dh)
    return y, h_final


class SSMCache(NamedTuple):
    h: jax.Array         # [B, H, N, Dh] f32
    conv: jax.Array      # [B, K-1, I]


def init_ssm_cache(batch: int, cfg: SSMConfig, dtype=jnp.bfloat16) -> SSMCache:
    return SSMCache(
        h=jnp.zeros((batch, cfg.n_heads, cfg.state, cfg.head_dim), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner), dtype),
    )


def ssm(params: dict, cfg: SSMConfig, x: jax.Array) -> jax.Array:
    """Full-sequence forward. x: [B,S,D] -> [B,S,D]."""
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    xi = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(x.dtype))
    xin, z = jnp.split(xi, 2, axis=-1)
    xc, _ = _causal_conv(xin, params["conv"].astype(x.dtype))
    a, dt, bmat, cmat = _gates(params, cfg, x)
    vals = xc.reshape(b, s, h, dh)
    y, _ = ssd_scan(a, dt, bmat, cmat, vals, cfg.chunk)
    y = y + params["d_skip"].astype(x.dtype)[None, None, :, None] * vals
    y = y.reshape(b, s, cfg.d_inner) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))


def ssm_decode(params: dict, cfg: SSMConfig, x: jax.Array, cache: SSMCache):
    """Single-token decode. x: [B,1,D] -> ([B,1,D], new cache)."""
    b = x.shape[0]
    h, dh, n = cfg.n_heads, cfg.head_dim, cfg.state
    xi = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(x.dtype))
    xin, z = jnp.split(xi, 2, axis=-1)
    xc, conv_new = _causal_conv(xin, params["conv"].astype(x.dtype), cache.conv)
    a, dt, bmat, cmat = _gates(params, cfg, x)
    v = xc.reshape(b, 1, h, dh)[:, 0].astype(jnp.float32)          # [B,H,Dh]
    a0 = a[:, 0]                                                    # [B,H]
    u = dt[:, 0].astype(jnp.float32)[..., None, None] * (
        bmat[:, 0].astype(jnp.float32)[..., None] * v[:, :, None, :]
    )                                                               # [B,H,N,Dh]
    h_new = a0[..., None, None] * cache.h + u
    y = jnp.einsum("bhn,bhnd->bhd", cmat[:, 0].astype(jnp.float32), h_new)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * v
    y = y.reshape(b, 1, cfg.d_inner).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    return out, SSMCache(h=h_new, conv=conv_new)
