"""Sharding-aware synthetic token pipeline with background prefetch.

Produces the training batches the assigned shapes need (tokens/labels, plus
stub frame embeddings for the audio arch) as host numpy, double-buffered on a
background thread, and placed with jax.device_put against the batch sharding
so each host only materializes its addressable shard (the standard multi-host
input path; on 1 CPU device it degenerates gracefully).

A real deployment would swap `_synth_document` for a tokenized corpus reader;
everything else (sharding placement, prefetch, determinism-by-step) stays.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


class PipelineConfig:
    def __init__(self, *, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, frames_dim: int = 0, enc_seq: int = 0,
                 prefetch: int = 2):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.frames_dim = frames_dim
        self.enc_seq = enc_seq
        self.prefetch = prefetch


def _synth_document(rng: np.random.Generator, vocab: int, seq: int) -> np.ndarray:
    """Markovian synthetic tokens (learnable structure, not uniform noise):
    token_{t+1} = (a * token_t + noise) mod vocab with regime switches."""
    a = int(rng.integers(3, 17))
    x = np.empty(seq + 1, np.int64)
    x[0] = rng.integers(vocab)
    noise = rng.integers(0, 7, size=seq)
    for t in range(seq):
        x[t + 1] = (a * x[t] + noise[t]) % vocab
    return x


def make_batch(cfg: PipelineConfig, step: int) -> dict:
    """Deterministic batch for a global step (restart-safe: data position is
    a pure function of step, so checkpoint restore replays exactly)."""
    rng = np.random.default_rng((cfg.seed, step))
    toks = np.stack([
        _synth_document(rng, cfg.vocab, cfg.seq_len)
        for _ in range(cfg.global_batch)
    ])
    batch = {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }
    if cfg.frames_dim:
        batch["frames"] = rng.standard_normal(
            (cfg.global_batch, cfg.enc_seq, cfg.frames_dim), np.float32)
    return batch


class Prefetcher:
    """Background-thread double buffering + device placement."""

    def __init__(self, cfg: PipelineConfig, shardings: Optional[dict] = None,
                 start_step: int = 0):
        self.cfg = cfg
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            batch = make_batch(self.cfg, self._step)
            self._step += 1
            if self.shardings is not None:
                batch = {
                    k: jax.device_put(v, self.shardings.get(k))
                    for k, v in batch.items()
                }
            self._q.put(batch)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
