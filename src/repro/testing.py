"""Property-test support: ``hypothesis`` when installed, deterministic
fallback otherwise.

The test suite's property tests want hypothesis's shrinking and example
database, but the pinned offline environment cannot install it. Importing
``given`` / ``settings`` / ``strategies`` from this module uses the real
library when available (it stays a ``dev`` extra in pyproject.toml) and
otherwise degrades to a small deterministic sampler implementing exactly the
subset the suite uses:

  * ``strategies.integers(min_value, max_value)``
  * ``strategies.floats(min_value, max_value)``
  * ``@given(**kwargs_of_strategies)``
  * ``@settings(max_examples=..., deadline=...)`` (deadline is ignored)

The fallback seeds a PRNG from the test function's qualified name, so runs
are reproducible, and always includes the all-min / all-max corner examples
before random interior samples.
"""
from __future__ import annotations

import functools
import inspect
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as _np

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """A bounded scalar sampler with explicit corner examples."""

        def __init__(self, corners, sample):
            self.corners = corners      # tried first, in order
            self.sample = sample        # sample(rng) -> random interior value

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                corners=[int(min_value), int(max_value)],
                sample=lambda rng: int(
                    rng.integers(min_value, int(max_value) + 1)),
            )

        @staticmethod
        def floats(min_value, max_value, **_kw):
            lo, hi = float(min_value), float(max_value)
            return _Strategy(
                corners=[lo, hi],
                sample=lambda rng: float(rng.uniform(lo, hi)),
            )

    strategies = _Strategies()

    def settings(max_examples=None, deadline=None, **_ignored):
        """Record ``max_examples`` on the decorated test (deadline ignored)."""

        def deco(fn):
            if max_examples is not None:
                fn._pt_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        """Run the test body over deterministic samples of each strategy."""

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_pt_max_examples",
                            getattr(fn, "_pt_max_examples",
                                    _DEFAULT_MAX_EXAMPLES))
                rng = _np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                names = sorted(strats)
                n_corners = max(len(strats[k].corners) for k in names)
                for i in range(max(1, n)):
                    if i < n_corners:
                        drawn = {
                            k: strats[k].corners[min(
                                i, len(strats[k].corners) - 1)]
                            for k in names
                        }
                    else:
                        drawn = {k: strats[k].sample(rng) for k in names}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:  # noqa: BLE001 - re-raise w/ repro
                        raise AssertionError(
                            f"property test failed on example {drawn!r} "
                            f"(deterministic fallback, example {i + 1}/{n})"
                        ) from e

            # pytest collects the *wrapper*: hide the strategy-supplied
            # parameters so they are not mistaken for fixtures.
            sig = inspect.signature(fn)
            kept = [p for name, p in sig.parameters.items()
                    if name not in strats]
            wrapper.__signature__ = sig.replace(parameters=kept)
            del wrapper.__wrapped__
            return wrapper

        return deco
