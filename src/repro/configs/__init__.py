"""Per-architecture configs (one module per assigned arch) + input shapes."""
from .shapes import SHAPES, ShapeConfig, applicable

__all__ = ["SHAPES", "ShapeConfig", "applicable"]
