"""whisper-small [audio] enc-dec (arXiv:2212.04356).

12L encoder + 12L decoder, d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
The conv audio frontend is a STUB: input_specs() provides precomputed frame
embeddings [B, 1500, 768]."""
from repro.models.lm import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865,
    enc_layers=12, enc_seq=1500, gated_mlp=False, scan_layers=False,
)
