"""xlstm-125m [ssm] (arXiv:2405.04517).

12L d_model=768 4H d_ff=0 vocab=50304 — alternating mLSTM/sLSTM blocks
(blocks carry their own projections; no separate FFN). Unrolled layers
(heterogeneous stack). Runs long_500k (O(1) recurrent state)."""
from repro.models.lm import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304, scan_layers=False,
)
