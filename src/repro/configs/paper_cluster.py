"""The paper's own simulation setting (§5.2) as canonical presets.

``PAPER_FULL`` is the exact published configuration (c=20,000, 1 arrival/h,
3-year horizon, SLA 0.01%); it needs cluster hours. ``PAPER_CPU`` is the
calibrated down-scale used by the default benchmarks (see
benchmarks/common.SCALES and the scale-validity discussion in EXPERIMENTS.md
§Paper). Both use the fitted Azure priors of Table 1.
"""
from repro.core.processes import AZURE_PRIORS
from repro.sim.simulator import SimConfig
from repro.traces.synth import TraceSpec

#: paper §5.2, verbatim scale
PAPER_FULL = SimConfig(
    capacity=20_000.0,
    arrival_rate=1.0,
    horizon_hours=3 * 365 * 24.0,
    dt=6.0,
    max_slots=8192,
    max_arrivals=8,
    priors=AZURE_PRIORS,
)

#: CPU-runnable scale preserving the paper's regime (cluster >> deployment)
PAPER_CPU = SimConfig(
    capacity=2_500.0,
    arrival_rate=0.125,
    horizon_hours=1.25 * 365 * 24.0,
    dt=12.0,
    max_slots=768,
    max_arrivals=5,
    priors=AZURE_PRIORS,
)

#: synthetic-trace counterparts of the presets (repro.traces): capacity is
#: sized ~2x the expected arrival count so bursty scenarios (flash crowds)
#: never clip against the columnar buffer.
TRACE_FULL = TraceSpec(
    horizon_hours=PAPER_FULL.horizon_hours,
    arrival_rate=PAPER_FULL.arrival_rate,
    max_deployments=65_536,
    max_events=32,
    priors=AZURE_PRIORS,
)

TRACE_CPU = TraceSpec(
    horizon_hours=PAPER_CPU.horizon_hours,
    arrival_rate=PAPER_CPU.arrival_rate,
    max_deployments=4_096,
    max_events=16,
    priors=AZURE_PRIORS,
)

#: paper §5.2 tuned thresholds at full scale (Table 2) — reference points
PAPER_TABLE2 = {
    "zeroth_threshold": 8_864.0,
    "first_threshold": 14_223.0,
    "second_rho": 0.112,
    "utilization": {"zeroth": 0.5045, "first": 0.6619, "second": 0.6732},
    "sla": 1e-4,
}
