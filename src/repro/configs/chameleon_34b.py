"""chameleon-34b [vlm] early-fusion (arXiv:2405.09818).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. The VQ image
tokenizer is a STUB: input_specs() provides fused text+image token ids over
the shared 65536 vocab; the backbone is a dense decoder with qk-norm
(chameleon's training stabilizer)."""
from repro.models.lm import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab=65536, qk_norm=True,
)
