"""hymba-1.5b [hybrid]: parallel attention+Mamba heads (arXiv:2411.13676).

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16. Sliding
window (2048) on the attention branch; the SSM branch carries global context,
making the arch sub-quadratic (runs long_500k).
"""
from repro.models.lm import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001, ssm_state=16,
    window=2048, rope_theta=10_000.0,
)
