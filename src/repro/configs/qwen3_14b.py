"""qwen3-14b [dense] (hf:Qwen/Qwen3 family).

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, qk-norm,
head_dim=128, rope theta 1M."""
from repro.models.lm import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=17408, vocab=151936,
    qk_norm=True, head_dim=128, rope_theta=1_000_000.0,
)
