"""Assigned input shapes and (arch × shape) applicability rules.

LM transformer shapes are seq_len × global_batch. ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), NOT
``train_step``. ``long_500k`` requires sub-quadratic context state and runs
only for the hybrid/ssm archs (skips recorded in DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable(arch_cfg, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason). long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and arch_cfg.family not in ("hybrid", "ssm"):
        return False, "pure full-attention arch: 500k dense decode skipped"
    return True, ""
