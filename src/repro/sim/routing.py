"""Fleet routing: assign each arriving deployment to a cluster (paper §2).

The paper frames the provider's problem as dispatch-then-admit: a workload
first goes to one of many clusters, and that cluster's admission policy then
accepts or rejects it. ``make_fleet_run`` calls a ``Router`` once per step,
*before* ``core.policies.admit_sequential`` runs inside the target cluster —
so a router chooses where an arrival is considered, and the per-cluster
policy still has the final word.

A router maps the step's ``[A]`` pre-drawn arrivals to cluster indices in
``[0, C)`` — or to the sentinel ``C`` ("no cluster would take it"), which
the fleet simulator counts as **rejected-by-all** without entering any
cluster's admission scan. Routers see the ``RouteContext``: the candidates'
moment curves, each cluster's maintained aggregate curves and instantaneous
utilization, the per-cluster capacities, and the (cluster-axis-broadcast)
fleet policy. All routers are traceable (they run inside the jitted scan).

Shipped routers:

  * ``RandomRouter``          — uniform over clusters (the null baseline).
  * ``LeastUtilizedRouter``   — lowest utilization *fraction*, folding each
    routed arrival's request into the running utilization so a burst within
    one step spreads instead of dogpiling (a small lax.scan over arrivals).
  * ``PowerOfTwoRouter``      — classic power-of-two-choices, scored on the
    per-cluster aggregate moment curves (predicted peak load fraction
    ``max_n agg_EL / capacity``); falls back to instantaneous utilization
    when the policy kind carries no curves (zeroth).
  * ``ThresholdCascadeRouter``— mirrors the paper's per-cluster policy: try
    clusters in index order and take the first whose admission condition
    (``core.policies.decide`` on the current aggregates) would accept;
    arrivals no cluster would accept get the rejected-by-all sentinel.
    Stateless within a step on purpose: the authoritative sequential
    accounting still happens in the target cluster's ``admit_sequential``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.moments import MomentCurves
from ..core.policies import PolicyParams, decide


class RouteContext(NamedTuple):
    """Everything a router may consult for one step's assignment."""

    cand: MomentCurves       # [A, N] candidate moment curves
    c0: jax.Array            # [A] requested initial cores
    valid: jax.Array         # [A] bool: slot actually carries an arrival
    agg_el: jax.Array        # [C, N] per-cluster maintained aggregate E[L]
    agg_vl: jax.Array        # [C, N] per-cluster maintained aggregate V[L]
    util: jax.Array          # [C] instantaneous active cores per cluster
    capacities: jax.Array    # [C] per-cluster core capacities
    policy: PolicyParams     # cluster-axis-broadcast fleet policy ([C] fields)

    @property
    def n_clusters(self) -> int:
        return self.capacities.shape[0]


class Router:
    """Pluggable arrival→cluster assignment. Subclasses implement ``route``.

    ``route`` must be traceable and return an ``[A]`` int32 vector of
    cluster indices in ``[0, C]`` — the value ``C`` is the rejected-by-all
    sentinel. Entries for invalid arrival slots are ignored.
    """

    name: str = "?"

    def route(self, key: jax.Array, ctx: RouteContext) -> jax.Array:
        raise NotImplementedError


class RandomRouter(Router):
    """Uniform random assignment — the null baseline every other router must
    beat at matched fleet SLA."""

    name = "random"

    def route(self, key: jax.Array, ctx: RouteContext) -> jax.Array:
        return jax.random.randint(key, ctx.c0.shape, 0, ctx.n_clusters,
                                  dtype=jnp.int32)


class LeastUtilizedRouter(Router):
    """Send each arrival to the cluster with the lowest utilization fraction.

    Arrivals within one step are assigned sequentially, folding each routed
    request's ``c0`` into the running utilization, so a same-step burst
    spreads across clusters instead of all chasing the same pre-step argmin.
    """

    name = "least_utilized"

    def route(self, key: jax.Array, ctx: RouteContext) -> jax.Array:
        idx = jnp.arange(ctx.n_clusters)

        def pick(u, x):
            c0, ok = x
            c = jnp.argmin(u / ctx.capacities).astype(jnp.int32)
            u = u + jnp.where((idx == c) & ok, c0, 0.0)
            return u, c

        _, assign = jax.lax.scan(pick, ctx.util, (ctx.c0, ctx.valid))
        return assign


class PowerOfTwoRouter(Router):
    """Power-of-two-choices over the per-cluster aggregate moment curves.

    Each arrival samples two *distinct* clusters (the second choice is
    uniform over the rest, the classic without-replacement scheme — with
    replacement, 1/C of arrivals would degenerate to pure random routing)
    and takes the one whose predicted peak load fraction —
    ``max_n agg_EL[c, n] / capacity_c``, the same aggregate the admission
    policies consume — is lower. With a zeroth-moment policy the maintained
    curves are identically zero, so the score falls back to the
    instantaneous utilization fraction (making the router the classic
    load-based po2 there).
    """

    name = "power_of_two"

    def route(self, key: jax.Array, ctx: RouteContext) -> jax.Array:
        n_c = ctx.n_clusters
        ka, kb = jax.random.split(key)
        a = jax.random.randint(ka, ctx.c0.shape, 0, n_c, dtype=jnp.int32)
        off = jax.random.randint(kb, ctx.c0.shape, 0, max(n_c - 1, 1),
                                 dtype=jnp.int32)
        b = (a + 1 + off) % n_c
        curve_score = jnp.max(ctx.agg_el, axis=1) / ctx.capacities
        util_score = ctx.util / ctx.capacities
        score = jnp.where(jnp.max(ctx.agg_el) > 0.0, curve_score, util_score)
        return jnp.where(score[a] <= score[b], a, b)


class ThresholdCascadeRouter(Router):
    """First cluster (in index order) whose admission policy would accept.

    Evaluates ``core.policies.decide`` for every (cluster, arrival) pair on
    the clusters' current maintained aggregates; an arrival is routed to the
    lowest-index accepting cluster, and to the rejected-by-all sentinel
    ``C`` when no cluster's condition holds. This mirrors the paper's
    per-cluster policy applied fleet-wide: the dispatch layer never admits
    anything the cluster policy wouldn't. Within-step interactions (an
    earlier arrival filling the cluster) are resolved by the target
    cluster's own ``admit_sequential``, which remains authoritative.
    """

    name = "cascade"

    def route(self, key: jax.Array, ctx: RouteContext) -> jax.Array:
        would_accept = jax.vmap(                 # over clusters ->
            lambda pol_c, el, vl, u: jax.vmap(   # over arrivals
                lambda ce, cv, c0: decide(pol_c, el, vl, u,
                                          MomentCurves(ce, cv), c0))(
                ctx.cand.EL, ctx.cand.VL, ctx.c0))(
            ctx.policy, ctx.agg_el, ctx.agg_vl, ctx.util)        # [C, A]
        first = jnp.argmax(would_accept, axis=0).astype(jnp.int32)
        return jnp.where(jnp.any(would_accept, axis=0), first,
                         jnp.int32(ctx.n_clusters))


#: name -> zero-arg factory, for benchmarks and CLI surfaces
ROUTERS = {
    r.name: r for r in (RandomRouter, LeastUtilizedRouter, PowerOfTwoRouter,
                        ThresholdCascadeRouter)
}
