"""Fleet routing: assign each arriving deployment to a cluster (paper §2).

The paper frames the provider's problem as dispatch-then-admit: a workload
first goes to one of many clusters, and that cluster's admission policy then
accepts or rejects it. ``make_fleet_run`` calls a ``Router`` once per step,
*before* ``core.policies.admit_sequential`` runs inside the target cluster —
so a router chooses where an arrival is considered, and the per-cluster
policy still has the final word.

A router maps the step's ``[A]`` pre-drawn arrivals to cluster indices in
``[0, C)`` — or to the sentinel ``C`` ("no cluster would take it"), which
the fleet simulator counts as **rejected-by-all** without entering any
cluster's admission scan. Routers see the ``RouteContext``: the candidates'
moment curves, each cluster's maintained aggregate curves and instantaneous
utilization, the per-cluster capacities, and the (cluster-axis-broadcast)
fleet policy. All routers are traceable (they run inside the jitted scan).

Shipped routers:

  * ``RandomRouter``          — uniform over clusters (the null baseline).
  * ``LeastUtilizedRouter``   — lowest utilization *fraction*, folding each
    routed arrival's request into the running utilization so a burst within
    one step spreads instead of dogpiling (a small lax.scan over arrivals).
  * ``PowerOfTwoRouter``      — classic power-of-two-choices, scored on the
    per-cluster aggregate moment curves (predicted peak load fraction
    ``max_n agg_EL / capacity``); falls back to instantaneous utilization
    when the policy kind carries no curves (zeroth).
  * ``ThresholdCascadeRouter``— mirrors the paper's per-cluster policy: try
    clusters in index order and take the first whose admission condition
    (``core.policies.decide`` on the running aggregates) would accept;
    arrivals no cluster would accept get the rejected-by-all sentinel.
    Routed candidates are folded into the chosen cluster's running
    aggregates (the same fold ``admit_sequential`` applies), so routing
    and the target cluster's admission agree arrival for arrival.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.moments import MomentCurves
from ..core.policies import PolicyParams, decide


class RouteContext(NamedTuple):
    """Everything a router may consult for one step's assignment."""

    cand: MomentCurves       # [A, N] candidate moment curves
    c0: jax.Array            # [A] requested initial cores
    valid: jax.Array         # [A] bool: slot actually carries an arrival
    agg_el: jax.Array        # [C, N] per-cluster maintained aggregate E[L]
    agg_vl: jax.Array        # [C, N] per-cluster maintained aggregate V[L]
    util: jax.Array          # [C] instantaneous active cores per cluster
    capacities: jax.Array    # [C] per-cluster core capacities
    policy: PolicyParams     # cluster-axis-broadcast fleet policy ([C] fields)

    @property
    def n_clusters(self) -> int:
        return self.capacities.shape[0]


class Router:
    """Pluggable arrival→cluster assignment. Subclasses implement ``route``.

    ``route`` must be traceable and return an ``[A]`` int32 vector of
    cluster indices in ``[0, C]`` — the value ``C`` is the rejected-by-all
    sentinel. Entries for invalid arrival slots are ignored.
    """

    name: str = "?"

    def route(self, key: jax.Array, ctx: RouteContext) -> jax.Array:
        raise NotImplementedError


class RandomRouter(Router):
    """Uniform random assignment — the null baseline every other router must
    beat at matched fleet SLA."""

    name = "random"

    def route(self, key: jax.Array, ctx: RouteContext) -> jax.Array:
        return jax.random.randint(key, ctx.c0.shape, 0, ctx.n_clusters,
                                  dtype=jnp.int32)


class LeastUtilizedRouter(Router):
    """Send each arrival to the cluster with the lowest utilization fraction.

    Arrivals within one step are assigned sequentially, folding each routed
    request's ``c0`` into the running utilization, so a same-step burst
    spreads across clusters instead of all chasing the same pre-step argmin.
    """

    name = "least_utilized"

    def route(self, key: jax.Array, ctx: RouteContext) -> jax.Array:
        idx = jnp.arange(ctx.n_clusters)

        def pick(u, x):
            c0, ok = x
            c = jnp.argmin(u / ctx.capacities).astype(jnp.int32)
            u = u + jnp.where((idx == c) & ok, c0, 0.0)
            return u, c

        _, assign = jax.lax.scan(pick, ctx.util, (ctx.c0, ctx.valid))
        return assign


class PowerOfTwoRouter(Router):
    """Power-of-two-choices over the per-cluster aggregate moment curves.

    Each arrival samples two *distinct* clusters (the second choice is
    uniform over the rest, the classic without-replacement scheme — with
    replacement, 1/C of arrivals would degenerate to pure random routing)
    and takes the one whose predicted peak load fraction —
    ``max_n agg_EL[c, n] / capacity_c``, the same aggregate the admission
    policies consume — is lower. With a zeroth-moment policy the maintained
    curves are identically zero, so the score falls back to the
    instantaneous utilization fraction (making the router the classic
    load-based po2 there).
    """

    name = "power_of_two"

    def route(self, key: jax.Array, ctx: RouteContext) -> jax.Array:
        n_c = ctx.n_clusters
        ka, kb = jax.random.split(key)
        a = jax.random.randint(ka, ctx.c0.shape, 0, n_c, dtype=jnp.int32)
        off = jax.random.randint(kb, ctx.c0.shape, 0, max(n_c - 1, 1),
                                 dtype=jnp.int32)
        b = (a + 1 + off) % n_c
        curve_score = jnp.max(ctx.agg_el, axis=1) / ctx.capacities
        util_score = ctx.util / ctx.capacities
        score = jnp.where(jnp.max(ctx.agg_el) > 0.0, curve_score, util_score)
        return jnp.where(score[a] <= score[b], a, b)


class ThresholdCascadeRouter(Router):
    """First cluster (in index order) whose admission policy would accept,
    with routed candidates folded into the running per-cluster aggregates.

    Arrivals are considered sequentially within the step; an arrival is
    routed to the lowest-index cluster whose ``core.policies.decide``
    accepts it on that cluster's *running* (agg_EL, agg_VL, util) state,
    and its moment curves and request are folded into the chosen cluster
    before the next arrival is scored — the exact fold
    ``admit_sequential`` applies inside the target cluster. By induction,
    every cascade-routed arrival is then accepted by its target cluster's
    sequential admission (same ``decide``, same running state), so routing
    and admission agree arrival for arrival; the earlier stateless variant
    could route two same-step arrivals into a cluster with room for one.
    Arrivals no cluster accepts get the rejected-by-all sentinel ``C``.
    The target cluster's ``admit_sequential`` remains authoritative — the
    fold here is a per-step shadow of it, never written back.
    """

    name = "cascade"

    def route(self, key: jax.Array, ctx: RouteContext) -> jax.Array:
        n_c = ctx.n_clusters
        idx = jnp.arange(n_c)

        def pick(carry, x):
            el, vl, u = carry                  # [C, N], [C, N], [C]
            ce, cv, c0, ok = x                 # [N], [N], scalar, bool
            acc = jax.vmap(                    # over clusters
                lambda pol_c, el_c, vl_c, u_c: decide(
                    pol_c, el_c, vl_c, u_c, MomentCurves(ce, cv), c0))(
                ctx.policy, el, vl, u)         # [C]
            routed = jnp.any(acc) & ok
            c = jnp.argmax(acc).astype(jnp.int32)
            sel = (idx == c) & routed
            el = el + jnp.where(sel[:, None], ce[None, :], 0.0)
            vl = vl + jnp.where(sel[:, None], cv[None, :], 0.0)
            u = u + jnp.where(sel, c0, 0.0)
            return (el, vl, u), jnp.where(routed, c, jnp.int32(n_c))

        _, assign = jax.lax.scan(
            pick, (ctx.agg_el, ctx.agg_vl, ctx.util),
            (ctx.cand.EL, ctx.cand.VL, ctx.c0, ctx.valid))
        return assign


#: name -> zero-arg factory, for benchmarks and CLI surfaces
ROUTERS = {
    r.name: r for r in (RandomRouter, LeastUtilizedRouter, PowerOfTwoRouter,
                        ThresholdCascadeRouter)
}
