"""Run metrics: utilization aggregation, SLA accounting, BCa bootstrap CIs.

The paper reports 95% bias-corrected and accelerated (BCa) bootstrap
confidence intervals (Efron 1987) because importance sampling biases naive
standard errors. ``bca_ci`` implements BCa for (optionally weighted) run-level
statistics.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import numpy as np
from scipy.special import ndtr, ndtri


class CI(NamedTuple):
    estimate: float
    lo: float
    hi: float

    def __str__(self) -> str:  # pragma: no cover - display helper
        return f"{self.estimate:.4f} ({self.lo:.4f}, {self.hi:.4f})"


def weighted_mean(values: np.ndarray, weights: Optional[np.ndarray] = None) -> float:
    values = np.asarray(values, dtype=np.float64)
    if weights is None:
        return float(values.mean())
    w = np.asarray(weights, dtype=np.float64)
    return float(np.sum(w * values) / np.sum(w))


def bca_ci(
    values: np.ndarray,
    weights: Optional[np.ndarray] = None,
    stat: Callable[[np.ndarray, Optional[np.ndarray]], float] = weighted_mean,
    n_resamples: int = 10_000,
    alpha: float = 0.05,
    seed: int = 0,
) -> CI:
    """BCa bootstrap CI of ``stat`` over run-level ``values`` (Efron 1987).

    Importance-sampling ``weights`` ride along with their runs during
    resampling (resample runs uniformly, recompute the weighted statistic),
    which is the standard weighted-bootstrap treatment.

    The ``n_resamples`` bootstrap loop is vectorized for the default
    ``weighted_mean`` statistic — one ``[n_resamples, n]`` gather and a
    row reduction instead of ``n_resamples`` python calls (same resample
    index matrix, same float64 row arithmetic, so the returned CI is
    identical to the loop's at a fixed seed — pinned by
    ``tests/test_telemetry.py``). A custom ``stat`` keeps the general
    one-call-per-resample path.
    """
    values = np.asarray(values, dtype=np.float64)
    n = len(values)
    rng = np.random.default_rng(seed)
    theta_hat = stat(values, weights)

    idx = rng.integers(0, n, size=(n_resamples, n))
    if stat is weighted_mean:
        if weights is None:
            boot = values[idx].mean(axis=1)
        else:
            w = np.asarray(weights, dtype=np.float64)[idx]
            boot = np.sum(w * values[idx], axis=1) / np.sum(w, axis=1)
    else:
        boot = np.empty(n_resamples)
        for i in range(n_resamples):
            sel = idx[i]
            boot[i] = stat(values[sel],
                           None if weights is None else weights[sel])

    # bias correction
    prop = np.mean(boot < theta_hat)
    prop = min(max(prop, 1.0 / n_resamples), 1.0 - 1.0 / n_resamples)
    z0 = ndtri(prop)

    # acceleration via jackknife
    jack = np.empty(n)
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        mask[i] = False
        jack[i] = stat(values[mask], None if weights is None else weights[mask])
        mask[i] = True
    jm = jack.mean()
    num = np.sum((jm - jack) ** 3)
    den = 6.0 * np.sum((jm - jack) ** 2) ** 1.5
    a = num / den if den > 0 else 0.0

    z_lo, z_hi = ndtri(alpha / 2.0), ndtri(1.0 - alpha / 2.0)
    p_lo = ndtr(z0 + (z0 + z_lo) / (1.0 - a * (z0 + z_lo)))
    p_hi = ndtr(z0 + (z0 + z_hi) / (1.0 - a * (z0 + z_hi)))
    lo, hi = np.quantile(boot, [p_lo, p_hi])
    return CI(estimate=float(theta_hat), lo=float(lo), hi=float(hi))


def fleet_utilization(util_clusters: np.ndarray,
                      capacities: np.ndarray) -> np.ndarray:
    """Fleet utilization from per-cluster utilizations: the capacity-weighted
    mean over the trailing cluster axis (equals total core-hours over total
    capacity-hours, which is what ``FleetMetrics.utilization`` reports)."""
    u = np.asarray(util_clusters, dtype=np.float64)
    c = np.asarray(capacities, dtype=np.float64)
    return np.sum(u * c, axis=-1) / np.sum(c)


def fleet_sla_failure_rate(failed_clusters: np.ndarray,
                           requests_clusters: np.ndarray,
                           weights: Optional[np.ndarray] = None) -> float:
    """Aggregate fleet SLA failure rate from per-cluster run totals.

    ``failed_clusters``/``requests_clusters`` carry a trailing cluster axis
    (leading axes are runs); counts are summed over clusters first — the
    fleet SLA is one constraint over the whole fleet's requests, not a mean
    of per-cluster rates — then aggregated over runs exactly like
    ``sla_failure_rate`` (optionally importance-weighted).
    """
    f = np.asarray(failed_clusters, dtype=np.float64).sum(axis=-1)
    r = np.asarray(requests_clusters, dtype=np.float64).sum(axis=-1)
    return sla_failure_rate(f, r, weights=weights)


def sla_failure_rate(total_failed: np.ndarray, total_requests: np.ndarray,
                     weights: Optional[np.ndarray] = None) -> float:
    """Aggregate SLA failure fraction over runs (failures are concentrated in
    tail runs, so aggregate counts — not per-run rates — are averaged, as in
    the paper's 'satisfied on average' check)."""
    f = np.asarray(total_failed, dtype=np.float64)
    r = np.asarray(total_requests, dtype=np.float64)
    if weights is None:
        weights = np.ones_like(f)
    w = np.asarray(weights, dtype=np.float64)
    return float(np.sum(w * f) / max(np.sum(w * r), 1.0))
