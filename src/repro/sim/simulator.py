"""Monte-Carlo cluster simulator (paper §5): lax.scan over time, vmap over runs.

Deployments live in a fixed slot array (jit/vmap-friendly replacement for the
paper's dynamic deployment lists — see DESIGN.md "hardware adaptation"). Each
step of length ``dt`` hours:

  1. core deaths (exact binomial thinning) + spontaneous shutdown (M process)
  2. scale-out requests; granted greedily in slot order while the cluster has
     capacity, otherwise logged as SLA failures (entire request fails)
  3. belief updates from the observed events (conjugate, core.belief)
  4. arrivals (Poisson, capped at ``max_arrivals`` per step) admitted by the
     policy via core.policies.admit_sequential, then placed into free slots

Steps 1–3 are the admission core's ``apply_events``, step 4 its
``decide_batch`` — the step machinery itself lives in ``sim.core`` as pure
functions over one ``CoreState`` pytree (slot table + beliefs + maintained
aggregate curves), shared bit-for-bit with the online serving engine
(``serve.admission``). ``make_run``/``make_fleet_run`` below are thin
``lax.scan`` drivers over that core plus the run-level metric accounting.

Arrival parameters are **pre-drawn outside the scan** so importance sampling
(App. D) can bucket a run by its badness measure before paying for the full
simulation, and so labeled/unlabeled (§7) and pseudo-observation (§6) priors
can be prepared per arrival. The pre-drawn ``ArrivalStream`` is produced by a
pluggable ``ArrivalSource``: ``PriorArrivalSource`` samples the population
priors (the paper's setting), ``traces.replay.TraceArrivalSource`` replays a
recorded ``WorkloadTrace`` — the scan body never knows the difference.

The scan is **blocked by ``agg_refresh_steps``**: cluster-wide aggregate
moment curves (the only thing the admission policies consume) are fully
recomputed once per block — through a fused masked reduction, the per-slot
reference, or the Pallas aggregate kernel (``agg_backend``) — and maintained
incrementally inside the block by folding placed candidates' curves into
the running sums. Per-decision cost is therefore O(grid), independent of the
slot-array size, which is what makes the paper-scale preset feasible on CPU.

**Fleet mode** (paper §2's provider view: dispatch *then* admit): the same
step machinery runs with a leading cluster axis. ``make_fleet_run`` simulates
``FleetConfig.n_clusters`` heterogeneous clusters in one scan — ``CoreState``
and the per-cluster ``RunMetrics`` all carry a leading ``[C]`` axis (the core
functions are vmapped inside the scan body; ``capacity`` becomes the
per-cluster array), and the blocked ``agg_refresh_steps`` refresh runs per
cluster. A pluggable ``sim.routing.Router`` maps each fleet-wide arrival to
a target cluster *before* ``admit_sequential`` runs there (arrivals no
cluster would take are counted as rejected-by-all). A one-cluster fleet
reproduces the single-cluster simulator key-for-key: cluster 0 keeps the
undiverted per-step key chain and the core functions are exactly the
single-cluster code path.
"""
from __future__ import annotations

import collections
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.policies import PolicyParams
# Static configuration, arrival streams, and the admission-core layer all
# live in sim.core; everything historically importable from this module is
# re-exported here (and from sim/__init__) unchanged.
from .core import (AGG_FUSED, AGG_KERNEL, AGG_REFERENCE, GLOBAL, MIX_LABELED,
                   MIX_UNLABELED, PSEUDO, AdmissionCore, ArrivalSource,
                   ArrivalStream, CoreState, FleetConfig, PriorArrivalSource,
                   SimConfig, SimState, StepOutcome, _init_state,
                   _place_arrivals, _step_dynamics, _validate_config,
                   _validate_fleet_config, draw_arrival_stream,
                   make_admission_core, make_config, make_fleet_config,
                   stream_config)

__all__ = [  # noqa: F822 — re-exports keep the historical import surface
    "AGG_FUSED", "AGG_KERNEL", "AGG_REFERENCE", "GLOBAL", "MIX_LABELED",
    "MIX_UNLABELED", "PSEUDO", "AdmissionCore", "ArrivalSource",
    "ArrivalStream", "CoreState", "FleetConfig", "FleetMetrics",
    "PriorArrivalSource", "RunMetrics", "SimConfig", "SimState",
    "StepOutcome", "broadcast_policy", "draw_arrival_stream",
    "make_admission_core", "make_config", "make_fleet_config",
    "make_fleet_run", "make_run", "run_batch", "run_keyed_batch",
    "shard_batch_over_devices", "stream_config",
]


class RunMetrics(NamedTuple):
    utilization: jax.Array        # time-average active cores / capacity
    failure_rate: jax.Array       # failed scale-out requests / total requests
    total_requests: jax.Array
    failed_requests: jax.Array
    arrivals_accepted: jax.Array
    arrivals_rejected: jax.Array
    slot_overflow: jax.Array      # arrivals lost to slot-array exhaustion
    n_departed: jax.Array         # deployments that died (spontaneous or
                                  # core exhaustion) over the whole run
    alive_end: jax.Array          # deployments still alive at the horizon
    util_trace: jax.Array         # [T] active cores after each step
    fail_trace: jax.Array         # [T] failed requests per step


class FleetMetrics(NamedTuple):
    """Fleet-level reductions plus the per-cluster ``RunMetrics``.

    The scalar fields mirror ``RunMetrics`` reduced over the cluster axis
    (capacity-weighted utilization; summed counts) so fleet runs drop into
    any consumer of run-level metrics — ``estimate_from_plan``, the SLA
    aggregation in ``sim.metrics`` — unchanged. ``per_cluster`` carries the
    full ``[C]``-leading per-cluster metrics (``util_trace`` is ``[C, T]``).
    """

    utilization: jax.Array        # total core-hours / (horizon * total capacity)
    failure_rate: jax.Array       # summed failures / summed requests
    total_requests: jax.Array
    failed_requests: jax.Array
    arrivals_accepted: jax.Array
    arrivals_rejected: jax.Array  # per-cluster rejections + rejected_by_all
    rejected_by_all: jax.Array    # arrivals the router could place nowhere
                                  # (threshold-cascade sentinel; 0 for
                                  # single-target routers)
    slot_overflow: jax.Array
    util_trace: jax.Array         # [T] fleet active cores after each step
    fail_trace: jax.Array         # [T] fleet failed requests per step
    per_cluster: RunMetrics       # leading [C] axis on every field


def _run_metrics(cfg: SimConfig, slots: SimState, util_trace, fail_trace,
                 capacity=None, horizon_hours=None) -> RunMetrics:
    """Assemble ``RunMetrics`` from final slot-table accumulators. Shared by
    the offline scan driver and the online engine, so "final metrics" means
    the same arithmetic in both regimes."""
    cap = cfg.capacity if capacity is None else capacity
    horizon = cfg.horizon_hours if horizon_hours is None else horizon_hours
    return RunMetrics(
        utilization=slots.core_hours / (horizon * cap),
        failure_rate=slots.fail_requests
        / jnp.maximum(slots.total_requests, 1.0),
        total_requests=slots.total_requests,
        failed_requests=slots.fail_requests,
        arrivals_accepted=slots.arr_accepted,
        arrivals_rejected=slots.arr_rejected,
        slot_overflow=slots.slot_overflow,
        n_departed=slots.n_departed,
        alive_end=jnp.sum(slots.alive.astype(jnp.float32), axis=-1),
        util_trace=util_trace,
        fail_trace=fail_trace,
    )


def _fleet_metrics(cfg: SimConfig, caps, state: SimState, util_trace,
                   fail_trace, rej_all, horizon_hours=None) -> FleetMetrics:
    """Assemble ``FleetMetrics`` from per-cluster slot-table accumulators
    (leading ``[C]`` axis; ``util_trace``/``fail_trace`` are ``[C, T]``).
    Shared by the offline fleet scan driver and the online engine."""
    horizon = cfg.horizon_hours if horizon_hours is None else horizon_hours
    per_cluster = _run_metrics(cfg, state, util_trace, fail_trace,
                               capacity=caps, horizon_hours=horizon)
    tot_req = jnp.sum(state.total_requests)
    tot_fail = jnp.sum(state.fail_requests)
    return FleetMetrics(
        utilization=jnp.sum(state.core_hours) / (horizon * jnp.sum(caps)),
        failure_rate=tot_fail / jnp.maximum(tot_req, 1.0),
        total_requests=tot_req,
        failed_requests=tot_fail,
        arrivals_accepted=jnp.sum(state.arr_accepted),
        arrivals_rejected=jnp.sum(state.arr_rejected) + rej_all,
        rejected_by_all=rej_all,
        slot_overflow=jnp.sum(state.slot_overflow),
        util_trace=jnp.sum(util_trace, axis=0),
        fail_trace=jnp.sum(fail_trace, axis=0),
        per_cluster=per_cluster,
    )


def _accumulate_step(slots: SimState, out: StepOutcome, n_acc, n_rej,
                     dt: float):
    """Fold one step's outcome into the slot-table metric accumulators;
    returns (slots, util_end). Identical arithmetic for the offline scan and
    the online engine's end-of-step bookkeeping."""
    util_end = jnp.sum(slots.cores * slots.alive.astype(jnp.float32), axis=-1)
    slots = slots._replace(
        core_hours=slots.core_hours + util_end * dt,
        fail_requests=slots.fail_requests + out.failed,
        total_requests=slots.total_requests + out.n_requests,
        arr_accepted=slots.arr_accepted + n_acc,
        arr_rejected=slots.arr_rejected + n_rej,
        n_departed=slots.n_departed + out.departed,
    )
    return slots, util_end


def make_run(cfg: SimConfig, horizon_grid: jax.Array, policy_kind: int,
             arrival_source: ArrivalSource | None = None,
             record_decisions: bool = False):
    """Build the jitted simulator for a fixed policy *kind* (threshold/rho stay
    traced so tuning does not re-jit). Returns run(key, policy) -> RunMetrics.

    ``arrival_source`` selects where arrivals come from (default: sample the
    population priors); an explicit ``stream`` argument to run() still takes
    precedence over the source. With ``record_decisions=True`` the run
    returns ``(RunMetrics, accept [T, A])`` — the per-step admit/reject
    decisions, which is what the online/offline equivalence tests compare.
    With ``cfg.telemetry`` the final ``obs.counters.TelemetryState`` rider is
    appended as one more return element (``(metrics, tel)``, or
    ``(metrics, accept, tel)`` when also recording decisions); decisions and
    metrics are bit-identical with the rider on or off.

    The scan is blocked by ``cfg.agg_refresh_steps`` (= K): the cluster-wide
    aggregate moment curves are fully recomputed from the slot array once per
    block (via ``cfg.agg_backend``), and inside a block the aggregate is
    maintained *incrementally* — each *placed* candidate's curves are folded
    into the running sums, so the per-decision cost is O(grid), independent
    of occupancy. Between refreshes the aggregate is stale by at most K
    steps of within-block dynamics: deaths shrink the true load (stale
    aggregate over-estimates, conservative), while scale-out grants and
    belief updates grow it (stale aggregate under-estimates, optimistic) —
    so K must stay small relative to the scale-out dynamics, and any
    residual bias is absorbed by the SLA-constrained threshold tuning, which
    calibrates against the same simulator at the same K. K = 1 recomputes
    every step (the refresh then lags the seed's in-step recompute by
    exactly the current step's death/belief update).
    """
    core = make_admission_core(cfg, horizon_grid, policy_kind)
    source = PriorArrivalSource() if arrival_source is None else arrival_source
    k_refresh = cfg.agg_refresh_steps
    n_outer = cfg.n_steps // k_refresh

    def step(policy: PolicyParams, cs: CoreState, xs):
        key, stream_t = xs
        cs, out = core.apply_events(key, cs)

        # 4. arrivals, admitted against the maintained aggregate -------------
        valid = jnp.arange(cfg.max_arrivals) < stream_t.n_arrivals
        cand = core.candidates(stream_t)
        cs, accept = core.decide_batch(policy, cs, out.util, cand, stream_t,
                                       valid)

        n_acc = jnp.sum(accept.astype(jnp.float32))
        n_rej = jnp.sum(valid.astype(jnp.float32)) - n_acc
        slots, util_end = _accumulate_step(cs.slots, out, n_acc, n_rej, cfg.dt)
        traces = (util_end, out.failed, accept) if record_decisions \
            else (util_end, out.failed)
        return cs._replace(slots=slots), traces

    def outer_block(policy: PolicyParams, cs: CoreState, xs_block):
        # full refresh of the aggregate from the slot array, once per block
        cs = core.refresh_aggregates(cs)
        return jax.lax.scan(functools.partial(step, policy), cs, xs_block)

    @functools.partial(jax.jit, static_argnames=())
    def run(key: jax.Array, policy: PolicyParams,
            stream: Optional[ArrivalStream] = None):
        k_stream, k_scan = jax.random.split(key)
        if stream is None:
            stream = source.stream(k_stream, cfg)
        keys = jax.random.split(k_scan, cfg.n_steps)
        cs0 = core.init()
        block = lambda x: x.reshape((n_outer, k_refresh) + x.shape[1:])
        xs = jax.tree.map(block, (keys, stream))
        cs, traces = jax.lax.scan(
            functools.partial(outer_block, policy), cs0, xs
        )
        util_trace, fail_trace = traces[0], traces[1]
        metrics = _run_metrics(cfg, cs.slots,
                               util_trace.reshape(cfg.n_steps),
                               fail_trace.reshape(cfg.n_steps))
        out = (metrics,)
        if record_decisions:
            out += (traces[2].reshape(cfg.n_steps, cfg.max_arrivals),)
        if cfg.telemetry:
            out += (cs.tel,)
        return out if len(out) > 1 else metrics

    return run


# ---------------------------------------------------------------------------
# Fleet mode: a leading cluster axis over the same step machinery.
# ---------------------------------------------------------------------------


def _cluster_step_keys(key: jax.Array, n_clusters: int) -> jax.Array:
    """[C] per-cluster event keys for one step.

    Cluster 0 keeps the undiverted per-step key, so a one-cluster fleet
    reproduces ``make_run``'s event randomness key-for-key; clusters 1..C-1
    fold their index in (independent chains, no cross-cluster correlation).
    """
    if n_clusters == 1:
        return key[None]
    return jnp.stack([key] + [jax.random.fold_in(key, c)
                              for c in range(1, n_clusters)])


def _check_fleet_policy_capacity(policy: PolicyParams, fcfg: FleetConfig):
    """Fail fast on a mis-specified fleet policy: each cluster's ``decide``
    admits against ``policy.capacity``, so a scalar fleet-*total* capacity
    tiled to every cluster would let each cluster believe it owns the whole
    fleet's budget — calibration would then return plausible-looking but
    wildly over-optimistic thetas with no error. Skipped when the capacity
    leaf is traced (the values are checked at the first concrete call)."""
    cap = getattr(policy, "capacity", None)
    if cap is None or isinstance(cap, jax.core.Tracer):
        return
    cap = np.asarray(cap)
    target = np.asarray(fcfg.capacities, dtype=np.float64)
    ok = (cap.ndim == 0 or cap.shape == target.shape) and np.allclose(
        np.asarray(cap, np.float64), target, rtol=1e-5)
    if not ok:
        raise ValueError(
            f"policy capacity {cap} does not match FleetConfig.capacities "
            f"{fcfg.capacities}: each cluster admits against its OWN "
            "capacity. Build fleet policies with core.policies.fleet_policy"
            "(kind, capacities=fleet_cfg.capacities, ...); when tuning, pass "
            "such a closure as calibrate(..., policy_fn=...).")


def broadcast_policy(policy: PolicyParams, n_clusters: int) -> PolicyParams:
    """Give every PolicyParams field a leading ``[C]`` cluster axis.

    Scalar fields are tiled; fields already carrying the cluster axis (from
    ``core.policies.fleet_policy``) pass through unchanged. Anything else is
    a shape error — per-cluster parameters must be built deliberately.
    """

    def bc(x):
        x = jnp.asarray(x)
        if x.ndim == 0:
            return jnp.broadcast_to(x, (n_clusters,))
        if x.shape[0] == n_clusters and x.ndim == 1:
            return x
        raise ValueError(
            f"policy field has shape {x.shape}; expected a scalar or a "
            f"[{n_clusters}]-vector (one entry per cluster)")

    return jax.tree.map(bc, policy)


def make_fleet_run(fcfg: FleetConfig, horizon_grid: jax.Array,
                   policy_kind: int, router=None,
                   arrival_source: ArrivalSource | None = None,
                   record_decisions: bool = False):
    """Build the jitted fleet simulator: route, then admit per cluster.

    Returns ``run(key, policy, stream=None) -> FleetMetrics``. ``policy``
    is normally a ``core.policies.fleet_policy`` (``[C]`` fields, per-cluster
    capacities and thresholds); a plain scalar ``PolicyParams`` is tiled to
    every cluster via ``broadcast_policy``, which is only meaningful for a
    homogeneous fleet — ``run`` fails fast when the policy's capacity does
    not match ``FleetConfig.capacities`` per cluster (a tiled fleet-total
    would let every cluster admit against the whole fleet's budget). With
    ``record_decisions=True`` the run returns ``(FleetMetrics,
    accept [T, C, A], assign [T, A])``. With ``fcfg.base.telemetry`` the
    final per-cluster ``TelemetryState`` rider (every leaf ``[C]``-leading;
    ``n_routed`` across clusters is the routing count vector) is appended as
    one more return element.

    Each step: per-cluster dynamics (the core's ``apply_events`` against the
    cluster's own capacity, vmapped over the cluster axis with independent
    key chains), one shared candidate-curve evaluation for the step's
    fleet-wide arrivals, the ``router``'s cluster assignment from the
    per-cluster maintained aggregates, then the core's per-cluster
    ``decide_batch`` (sequential admission + slot placement + incremental
    aggregate fold) on each cluster's assigned arrivals. The blocked
    ``agg_refresh_steps`` refresh recomputes every cluster's aggregate from
    its own slot array once per block. Arrivals the router maps to the
    sentinel ``C`` (the threshold cascade's "no cluster would take it") are
    counted as ``rejected_by_all`` and enter no cluster's admission scan.
    """
    from .routing import LeastUtilizedRouter

    _validate_fleet_config(fcfg)
    cfg = fcfg.base
    core = make_admission_core(cfg, horizon_grid, policy_kind)
    n_c = fcfg.n_clusters
    caps = jnp.asarray(fcfg.capacities, jnp.float32)
    router = LeastUtilizedRouter() if router is None else router
    source = PriorArrivalSource() if arrival_source is None else arrival_source
    k_refresh = cfg.agg_refresh_steps
    n_outer = cfg.n_steps // k_refresh

    def fleet_step(policy: PolicyParams, carry, xs):
        cs, rej_all = carry                          # cs leaves: [C, ...]
        key, stream_t = xs
        keys_c = _cluster_step_keys(key, n_c)
        cs, out = jax.vmap(
            lambda cap, k, cs_c: core.apply_events(k, cs_c, cap))(
                caps, keys_c, cs)

        valid = jnp.arange(cfg.max_arrivals) < stream_t.n_arrivals
        cand = core.candidates(stream_t)

        from .routing import RouteContext

        assign = router.route(
            jax.random.fold_in(key, n_c),
            RouteContext(cand=cand, c0=stream_t.c0, valid=valid,
                         agg_el=cs.agg_el, agg_vl=cs.agg_vl, util=out.util,
                         capacities=caps, policy=policy))
        assign = jnp.clip(assign, 0, n_c)           # sentinel n_c = nowhere
        cluster_mask = valid[None, :] & (
            assign[None, :] == jnp.arange(n_c)[:, None])   # [C, A]
        rej_all = rej_all + jnp.sum(
            (valid & (assign == n_c)).astype(jnp.float32))

        cs, accept = jax.vmap(
            lambda pol_c, cs_c, u_c, valid_c: core.decide_batch(
                pol_c, cs_c, u_c, cand, stream_t, valid_c))(
                    policy, cs, out.util, cluster_mask)

        n_acc = jnp.sum(accept.astype(jnp.float32), axis=1)          # [C]
        n_rej = jnp.sum(cluster_mask.astype(jnp.float32), axis=1) - n_acc
        slots, util_end = _accumulate_step(cs.slots, out, n_acc, n_rej, cfg.dt)
        traces = (util_end, out.failed, accept, assign) if record_decisions \
            else (util_end, out.failed)
        return (cs._replace(slots=slots), rej_all), traces

    def outer_block(policy: PolicyParams, carry, xs_block):
        cs, rej_all = carry
        # full per-cluster refresh of the aggregates, once per block
        cs = jax.vmap(core.refresh_aggregates)(cs)
        return jax.lax.scan(functools.partial(fleet_step, policy),
                            (cs, rej_all), xs_block)

    @functools.partial(jax.jit, static_argnames=())
    def _sim_run(key: jax.Array, policy: PolicyParams,
                 stream: Optional[ArrivalStream] = None):
        policy = broadcast_policy(policy, n_c)
        k_stream, k_scan = jax.random.split(key)
        if stream is None:
            stream = source.stream(k_stream, cfg)
        keys = jax.random.split(k_scan, cfg.n_steps)
        cs0 = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_c,) + x.shape), core.init())
        block = lambda x: x.reshape((n_outer, k_refresh) + x.shape[1:])
        xs = jax.tree.map(block, (keys, stream))
        (cs, rej_all), traces = jax.lax.scan(
            functools.partial(outer_block, policy),
            (cs0, jnp.zeros(())), xs
        )
        util_trace = traces[0].reshape(cfg.n_steps, n_c).T      # [C, T]
        fail_trace = traces[1].reshape(cfg.n_steps, n_c).T
        metrics = _fleet_metrics(cfg, caps, cs.slots, util_trace, fail_trace,
                                 rej_all)
        out = (metrics,)
        if record_decisions:
            out += (traces[2].reshape(cfg.n_steps, n_c, cfg.max_arrivals),
                    traces[3].reshape(cfg.n_steps, cfg.max_arrivals))
        if cfg.telemetry:
            out += (cs.tel,)
        return out if len(out) > 1 else metrics

    def run(key: jax.Array, policy: PolicyParams,
            stream: Optional[ArrivalStream] = None):
        _check_fleet_policy_capacity(policy, fcfg)
        return _sim_run(key, policy, stream)

    return run


def shard_batch_over_devices(batched, devices, axis: str,
                             n_replicated_args: int = 0,
                             n_batch_args: int = 1):
    """jit(shard_map(batched)) over a 1-d device mesh named ``axis``.

    ``batched`` maps ``n_batch_args`` leading-axis batches (plus
    ``n_replicated_args`` trailing broadcast arguments) to a pytree with the
    same leading axis; the batches are split across devices, replicated args
    go everywhere. The batch size must divide the device count — callers
    with ragged batches pad first (see ``run_keyed_batch``). Shared by
    ``run_batch`` (one batch arg: keys), the trace-ensemble path (two: keys
    + a stream batch), and the importance-sampling probe loop.
    """
    from jax.sharding import Mesh, PartitionSpec as P

    from ..compat import shard_map

    mesh = Mesh(np.asarray(devices), (axis,))
    in_specs = (P(axis),) * n_batch_args + (P(),) * n_replicated_args
    return jax.jit(shard_map(batched, mesh=mesh, in_specs=in_specs,
                             out_specs=P(axis), check_vma=False))


# bounded LRU: a weak-keyed cache cannot work here (the cached shard_map
# wrapper closes over run_fn, so the value would pin its own key), and jax's
# jit cache pins run_fn process-wide anyway — so just cap how many compiled
# sharded wrappers we keep across a sweep
_SHARDED_RUN_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_SHARDED_RUN_CACHE_MAX = 8


def _pad_batch(args, n_batch: int, pad: int):
    """Pad the leading axis of the first ``n_batch`` args by repeating their
    last row ``pad`` times (trailing args are replicated, never padded)."""
    if pad == 0:
        return args
    pad_fn = lambda x: jnp.concatenate(
        [x, jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])], axis=0)
    return tuple(jax.tree.map(pad_fn, a) for a in args[:n_batch]) \
        + args[n_batch:]


def run_keyed_batch(run_fn, keys: jax.Array, policy: PolicyParams,
                    *, streams: Optional[ArrivalStream] = None,
                    devices=None) -> RunMetrics:
    """Simulate an explicit ``[R, ...]`` batch of PRNG keys: vmap over runs,
    shard_map over devices.

    With more than one local device the key batch is sharded over a 1-d mesh
    and each device vmaps its shard (pure data parallelism — runs never
    communicate). A batch that does not divide the device count is **padded**
    to the next multiple by repeating its last run (streams ride along), and
    the padded lanes are sliced off before returning — so they never reach a
    caller's metric reductions. Single-device falls back to a plain vmap.
    The compiled sharded wrapper is cached per (run_fn, devices) — the policy
    is a traced argument — so repeated calls do not re-trace.

    Taking keys (not a count) is what lets the importance-sampling estimator
    route its pre-selected ``ImportancePlan.keys`` through the same sharded
    path as ordinary batches (see ``importance.simulate_plan``).

    ``streams`` (optional) is a leading-axis ``[R, ...]`` batch of pre-built
    ``ArrivalStream``\\ s, one per run, sharded alongside the keys — the
    trace-ensemble importance path uses this to pair each selected replay
    stream with its run key (see ``importance.simulate_trace_plan``).
    """
    keys = jnp.asarray(keys)
    n_runs = keys.shape[0]
    devices = tuple(jax.devices() if devices is None else devices)
    n_dev = len(devices)
    if streams is None:
        batched = jax.vmap(run_fn, in_axes=(0, None))
        args = (keys, policy)
        n_batch = 1
    else:
        batched = jax.vmap(lambda k, s, p: run_fn(k, p, s),
                           in_axes=(0, 0, None))
        args = (keys, streams, policy)
        n_batch = 2
    if n_dev <= 1:
        return batched(*args)

    pad = (-n_runs) % n_dev
    args = _pad_batch(args, n_batch, pad)
    cache_key = (run_fn, devices, n_batch)
    sharded = _SHARDED_RUN_CACHE.get(cache_key)
    if sharded is None:
        sharded = shard_batch_over_devices(batched, devices, "runs",
                                           n_replicated_args=1,
                                           n_batch_args=n_batch)
        _SHARDED_RUN_CACHE[cache_key] = sharded
        while len(_SHARDED_RUN_CACHE) > _SHARDED_RUN_CACHE_MAX:
            _SHARDED_RUN_CACHE.popitem(last=False)
    else:
        _SHARDED_RUN_CACHE.move_to_end(cache_key)
    metrics = sharded(*args)
    if pad:
        metrics = jax.tree.map(lambda x: x[:n_runs], metrics)
    return metrics


def run_batch(run_fn, key: jax.Array, policy: PolicyParams, n_runs: int,
              *, devices=None) -> RunMetrics:
    """A batch of ``n_runs`` independent runs split from one key; see
    ``run_keyed_batch`` for the sharding behavior."""
    return run_keyed_batch(run_fn, jax.random.split(key, n_runs), policy,
                           devices=devices)
