"""Monte-Carlo cluster simulator (paper §5): lax.scan over time, vmap over runs.

Deployments live in a fixed slot array (jit/vmap-friendly replacement for the
paper's dynamic deployment lists — see DESIGN.md "hardware adaptation"). Each
step of length ``dt`` hours:

  1. core deaths (exact binomial thinning) + spontaneous shutdown (M process)
  2. scale-out requests; granted greedily in slot order while the cluster has
     capacity, otherwise logged as SLA failures (entire request fails)
  3. belief updates from the observed events (conjugate, core.belief)
  4. arrivals (Poisson, capped at ``max_arrivals`` per step) admitted by the
     policy via core.policies.admit_sequential, then placed into free slots

Arrival parameters are **pre-drawn outside the scan** so importance sampling
(App. D) can bucket a run by its badness measure before paying for the full
simulation, and so labeled/unlabeled (§7) and pseudo-observation (§6) priors
can be prepared per arrival. The pre-drawn ``ArrivalStream`` is produced by a
pluggable ``ArrivalSource``: ``PriorArrivalSource`` samples the population
priors (the paper's setting), ``traces.replay.TraceArrivalSource`` replays a
recorded ``WorkloadTrace`` — the scan body never knows the difference.

The scan is **blocked by ``agg_refresh_steps``**: cluster-wide aggregate
moment curves (the only thing the admission policies consume) are fully
recomputed once per block — through a fused masked reduction, the per-slot
reference, or the Pallas aggregate kernel (``agg_backend``) — and maintained
incrementally inside the block by folding placed candidates' curves into
the running sums. Per-decision cost is therefore O(grid), independent of the
slot-array size, which is what makes the paper-scale preset feasible on CPU.
"""
from __future__ import annotations

import collections
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.belief import (GammaBelief, apply_pseudo_observations,
                           belief_from_prior, observe_initial_size,
                           update_on_events)
from ..core.moments import (MomentCurves, aggregate_moment_curves,
                            moment_curves, moment_curves_fused)
from ..core.policies import ZEROTH, PolicyParams, admit_sequential
from ..core.pricing import mixture_moments
from ..core.processes import (DeploymentParams, PopulationPriors,
                              sample_params, sample_pseudo_observations,
                              sample_step_events)

GLOBAL, PSEUDO, MIX_LABELED, MIX_UNLABELED = "global", "pseudo", "labeled", "unlabeled"
AGG_FUSED, AGG_REFERENCE, AGG_KERNEL = "fused", "reference", "kernel"


class SimConfig(NamedTuple):
    """Static simulation configuration (python values; changing any re-jits)."""

    capacity: float = 2_000.0
    arrival_rate: float = 0.1        # deployments/hour (paper: 1.0 at c=20,000)
    horizon_hours: float = 365 * 24.0
    dt: float = 6.0                  # hours per step
    max_slots: int = 1024
    max_arrivals: int = 4            # cap per step (Poisson tail clipped)
    prior_mode: str = GLOBAL         # GLOBAL | PSEUDO | MIX_LABELED | MIX_UNLABELED
    n_pseudo_obs: int = 0            # paper §6: 0/1/5/50
    d_points: int = 24               # D-term checkpoint count
    use_kernel: bool = False         # Pallas moment_curves kernel (TPU path;
                                     # interpret-mode on CPU, so off by default)
    agg_backend: str = AGG_FUSED     # AGG_FUSED | AGG_REFERENCE | AGG_KERNEL:
                                     # how the cluster-wide aggregate curves
                                     # are computed each step (see make_run)
    agg_refresh_steps: int = 1       # full aggregate recompute every K steps;
                                     # between refreshes admitted candidates'
                                     # curves are folded in incrementally
                                     # (K=1: recompute every step)
    priors: PopulationPriors = None  # population priors; prefer make_config,
                                     # which defaults these to AZURE_PRIORS

    @property
    def n_steps(self) -> int:
        return int(round(self.horizon_hours / self.dt))


def make_config(**overrides) -> SimConfig:
    """Documented SimConfig constructor: ``priors`` defaults to the fitted
    Azure priors instead of ``None`` and every field is validated eagerly, so
    a bad config fails here rather than deep inside ``belief_from_prior``."""
    if overrides.get("priors") is None:
        from ..core import AZURE_PRIORS

        overrides["priors"] = AZURE_PRIORS
    return _validate_config(SimConfig(**overrides))


def _validate_config(cfg: SimConfig) -> SimConfig:
    if cfg.priors is None:
        raise ValueError(
            "SimConfig.priors is None. Construct configs via "
            "repro.sim.make_config(...) (defaults to AZURE_PRIORS) or pass "
            "priors=<PopulationPriors> explicitly."
        )
    if cfg.prior_mode not in (GLOBAL, PSEUDO, MIX_LABELED, MIX_UNLABELED):
        raise ValueError(f"unknown prior_mode {cfg.prior_mode!r}")
    if cfg.agg_backend not in (AGG_FUSED, AGG_REFERENCE, AGG_KERNEL):
        raise ValueError(f"unknown agg_backend {cfg.agg_backend!r}")
    if cfg.n_steps <= 0 or cfg.max_slots <= 0 or cfg.max_arrivals <= 0:
        raise ValueError(
            f"degenerate SimConfig: n_steps={cfg.n_steps} "
            f"max_slots={cfg.max_slots} max_arrivals={cfg.max_arrivals}"
        )
    if cfg.agg_refresh_steps < 1 or cfg.n_steps % cfg.agg_refresh_steps:
        raise ValueError(
            f"agg_refresh_steps={cfg.agg_refresh_steps} must be >= 1 and "
            f"divide n_steps={cfg.n_steps}"
        )
    return cfg


class ArrivalStream(NamedTuple):
    """Pre-drawn per-(step, arrival-slot) quantities. Leading dims [T, A]."""

    params: DeploymentParams         # true parameters of the arriving deployment
    c0: jax.Array                    # initial request size
    bel: GammaBelief                 # provider's prior belief for the arrival
    bel_alt: GammaBelief             # second mixture component (unlabeled mode)
    n_arrivals: jax.Array            # [T] arrivals per step (already capped)


class ArrivalSource:
    """Pluggable producer of the pre-drawn ``ArrivalStream``.

    ``make_run`` consumes arrivals exclusively through this interface: the
    scan body, policies, and importance sampling only ever see the stream,
    so any source that returns correctly-shaped ``[n_steps, max_arrivals]``
    fields plugs in without touching the simulator. Two backends ship:
    ``PriorArrivalSource`` (sample the population priors — the seed
    behavior) and ``traces.replay.TraceArrivalSource`` (replay a recorded
    ``WorkloadTrace``). ``stream`` is called inside the jitted run, so it
    must be traceable; closed-over trace arrays become constants.
    """

    def stream(self, key: jax.Array, cfg: SimConfig) -> "ArrivalStream":
        raise NotImplementedError


class PriorArrivalSource(ArrivalSource):
    """Draw every arrival from the population priors (paper §5 default)."""

    def stream(self, key: jax.Array, cfg: SimConfig) -> "ArrivalStream":
        return draw_arrival_stream(key, cfg)


class RunMetrics(NamedTuple):
    utilization: jax.Array        # time-average active cores / capacity
    failure_rate: jax.Array       # failed scale-out requests / total requests
    total_requests: jax.Array
    failed_requests: jax.Array
    arrivals_accepted: jax.Array
    arrivals_rejected: jax.Array
    slot_overflow: jax.Array      # arrivals lost to slot-array exhaustion
    util_trace: jax.Array         # [T] active cores after each step
    fail_trace: jax.Array         # [T] failed requests per step


class SimState(NamedTuple):
    alive: jax.Array              # [S] bool
    cores: jax.Array              # [S] float32
    params: DeploymentParams      # [S]
    bel: GammaBelief              # [S]
    core_hours: jax.Array
    fail_requests: jax.Array
    total_requests: jax.Array
    arr_accepted: jax.Array
    arr_rejected: jax.Array
    slot_overflow: jax.Array


def draw_arrival_stream(key: jax.Array, cfg: SimConfig) -> ArrivalStream:
    """Pre-draw every arrival's true params, request size and prior belief."""
    t_steps, a_max = cfg.n_steps, cfg.max_arrivals
    shape = (t_steps, a_max)
    kn, kp, kc, ko, kq, kb = jax.random.split(key, 6)
    n_arr = jnp.minimum(
        jax.random.poisson(kn, cfg.arrival_rate * cfg.dt, (t_steps,)), a_max
    )
    params = sample_params(kp, cfg.priors, shape)
    c0 = (1 + jax.random.poisson(kc, params.sig)).astype(jnp.float32)

    prior = belief_from_prior(cfg.priors, shape)
    if cfg.prior_mode == GLOBAL:
        bel = prior
        bel_alt = bel
    elif cfg.prior_mode == PSEUDO:
        obs = sample_pseudo_observations(ko, params, cfg.priors, cfg.n_pseudo_obs)
        bel = apply_pseudo_observations(prior, obs, cfg.priors)
        bel_alt = bel
    else:
        # §7: the user has two types; the submitted deployment is the drawn
        # ``params``; the alternative type is an independent draw. The provider
        # holds n_pseudo_obs observations of each type.
        alt = sample_params(kq, cfg.priors, shape)
        k1, k2 = jax.random.split(kb)
        obs = sample_pseudo_observations(k1, params, cfg.priors, cfg.n_pseudo_obs)
        obs_alt = sample_pseudo_observations(k2, alt, cfg.priors, cfg.n_pseudo_obs)
        bel = apply_pseudo_observations(prior, obs, cfg.priors)
        bel_alt = apply_pseudo_observations(prior, obs_alt, cfg.priors)
    bel = observe_initial_size(bel, c0)
    return ArrivalStream(params=params, c0=c0, bel=bel, bel_alt=bel_alt,
                         n_arrivals=n_arr)


def _init_state(cfg: SimConfig) -> SimState:
    s = cfg.max_slots
    zero_params = DeploymentParams(
        lam=jnp.zeros(s), mu=jnp.full((s,), 1.0), sig=jnp.zeros(s)
    )
    return SimState(
        alive=jnp.zeros(s, bool),
        cores=jnp.zeros(s, jnp.float32),
        params=zero_params,
        bel=belief_from_prior(cfg.priors, (s,)),
        core_hours=jnp.zeros(()),
        fail_requests=jnp.zeros(()),
        total_requests=jnp.zeros(()),
        arr_accepted=jnp.zeros(()),
        arr_rejected=jnp.zeros(()),
        slot_overflow=jnp.zeros(()),
    )


def _place_arrivals(state: SimState, accept, stream_t: ArrivalStream, cfg: SimConfig):
    """Place accepted arrivals into free slots, one vectorized pass.

    The i-th accepted arrival goes to the i-th free slot (in slot order) —
    identical semantics to the previous sequential argmin unroll, but a single
    [A, S] rank-match instead of A passes over the slot array. Accepted
    arrivals beyond the number of free slots are counted as slot overflow.

    Returns (state, placed_arrival [A]) — the mask of accepted arrivals that
    actually landed in a slot, so the caller folds only *real* deployments
    into the maintained aggregate (overflowed arrivals must not haunt it).
    """
    alive = state.alive
    free = ~alive
    rank = jnp.cumsum(free.astype(jnp.int32))          # free-slot rank, 1-based
    acc = accept.astype(jnp.int32)
    ordinal = jnp.cumsum(acc) * acc                    # i-th accepted, 1-based
    n_free = rank[-1]
    placed_arrival = accept & (ordinal <= n_free)      # [A]
    overflow = state.slot_overflow + jnp.sum(
        jnp.where(accept & ~placed_arrival, 1.0, 0.0))

    hit = free[None, :] & (rank[None, :] == ordinal[:, None]) & accept[:, None]
    placed = jnp.any(hit, axis=0)                      # [S]

    def merge(old, new_a):
        upd = hit.astype(old.dtype).T @ new_a
        return jnp.where(placed, upd, old)

    cores = merge(state.cores, stream_t.c0)
    params = jax.tree.map(lambda o, n: merge(o, n), state.params,
                          stream_t.params)
    bel = jax.tree.map(lambda o, n: merge(o, n), state.bel, stream_t.bel)
    state = state._replace(alive=alive | placed, cores=cores, params=params,
                           bel=bel, slot_overflow=overflow)
    return state, placed_arrival


def _make_aggregate_fn(cfg: SimConfig, grid: jax.Array):
    """Cluster-wide sum-over-alive-slots curve evaluator, by backend.

    AGG_REFERENCE is the seed per-slot path (materialize [S, N], mask, sum) —
    kept as the oracle the fast paths are equivalence-tested against.
    AGG_FUSED reduces block-by-block without the [S, N] intermediate;
    AGG_KERNEL is the Pallas aggregated-output kernel (interpret-mode on CPU).
    """
    if cfg.agg_backend == AGG_REFERENCE:

        def aggregate(bel, cores, alive):
            curves = moment_curves(bel, cores, grid, cfg.priors,
                                   d_points=cfg.d_points)
            alive_f = alive.astype(jnp.float32)
            return (jnp.sum(curves.EL * alive_f[:, None], axis=0),
                    jnp.sum(curves.VL * alive_f[:, None], axis=0))
    elif cfg.agg_backend == AGG_KERNEL:
        from ..kernels.moment_curves.ops import aggregate_moment_curves_kernel

        def aggregate(bel, cores, alive):
            out = aggregate_moment_curves_kernel(
                bel, cores, alive, grid, cfg.priors, d_points=cfg.d_points)
            return out.EL, out.VL
    else:

        def aggregate(bel, cores, alive):
            out = aggregate_moment_curves(bel, cores, alive, grid, cfg.priors,
                                          d_points=cfg.d_points)
            return out.EL, out.VL

    return aggregate


def make_run(cfg: SimConfig, horizon_grid: jax.Array, policy_kind: int,
             arrival_source: ArrivalSource | None = None):
    """Build the jitted simulator for a fixed policy *kind* (threshold/rho stay
    traced so tuning does not re-jit). Returns run(key, policy) -> RunMetrics.

    ``arrival_source`` selects where arrivals come from (default: sample the
    population priors); an explicit ``stream`` argument to run() still takes
    precedence over the source.

    The scan is blocked by ``cfg.agg_refresh_steps`` (= K): the cluster-wide
    aggregate moment curves are fully recomputed from the slot array once per
    block (via ``cfg.agg_backend``), and inside a block the aggregate is
    maintained *incrementally* — each *placed* candidate's curves are folded
    into the running sums, so the per-decision cost is O(grid), independent
    of occupancy. Between refreshes the aggregate is stale by at most K
    steps of within-block dynamics: deaths shrink the true load (stale
    aggregate over-estimates, conservative), while scale-out grants and
    belief updates grow it (stale aggregate under-estimates, optimistic) —
    so K must stay small relative to the scale-out dynamics, and any
    residual bias is absorbed by the SLA-constrained threshold tuning, which
    calibrates against the same simulator at the same K. K = 1 recomputes
    every step (the refresh then lags the seed's in-step recompute by
    exactly the current step's death/belief update).
    """
    _validate_config(cfg)
    source = PriorArrivalSource() if arrival_source is None else arrival_source
    needs_moments = policy_kind != ZEROTH
    grid = horizon_grid
    n_grid = grid.shape[0] if needs_moments else 1
    k_refresh = cfg.agg_refresh_steps
    n_outer = cfg.n_steps // k_refresh
    if cfg.use_kernel:
        from ..kernels.moment_curves.ops import moment_curves_kernel

        def curves_fn(bel, cores, grid_, priors, d_points):
            flat_bel = jax.tree.map(lambda a: a.reshape(-1), bel)
            out = moment_curves_kernel(flat_bel, cores.reshape(-1), grid_,
                                       priors, d_points=d_points)
            shape = cores.shape + (grid_.shape[0],)
            return MomentCurves(out.EL.reshape(shape), out.VL.reshape(shape))
    else:
        curves_fn = moment_curves_fused
    aggregate_fn = _make_aggregate_fn(cfg, grid)

    def step(policy: PolicyParams, carry, xs):
        state, agg_el, agg_vl = carry
        key, stream_t = xs
        k_ev = key
        alive_f = state.alive.astype(jnp.float32)

        # 1. deaths ---------------------------------------------------------
        ev = sample_step_events(k_ev, state.params, state.cores, cfg.priors,
                                cfg.dt, alive=state.alive)
        deaths = jnp.minimum(ev.core_deaths.astype(jnp.float32), state.cores) * alive_f
        exposure = state.cores * cfg.dt * alive_f
        cores = state.cores - deaths
        cores = jnp.where(ev.spont_death & state.alive, 0.0, cores)
        alive = state.alive & (cores > 0.0)
        alive_f = alive.astype(jnp.float32)

        # 2. scale-outs (only deployments still alive request) ---------------
        req = ev.scaleout_cores.astype(jnp.float32) * alive_f
        n_req = ev.n_scaleouts.astype(jnp.float32) * alive_f
        util = jnp.sum(cores * alive_f)
        grant = (util + jnp.cumsum(req)) <= cfg.capacity
        cores = cores + jnp.where(grant, req, 0.0)
        failed = jnp.sum(jnp.where(~grant, n_req, 0.0))
        util = jnp.sum(cores * alive_f)

        # 3. belief updates (requests are observed whether or not granted) ---
        bel = update_on_events(
            state.bel,
            core_deaths=deaths,
            exposure_core_hours=exposure,
            n_scaleouts=n_req,
            scaleout_cores=req,
            alive_hours=cfg.dt * alive_f,
            priors=cfg.priors,
        )

        # 4. arrivals, admitted against the maintained aggregate -------------
        valid = jnp.arange(cfg.max_arrivals) < stream_t.n_arrivals
        if needs_moments:
            cand = curves_fn(stream_t.bel, stream_t.c0, grid, cfg.priors,
                             d_points=cfg.d_points)
            if cfg.prior_mode == MIX_UNLABELED:
                cand_alt = curves_fn(stream_t.bel_alt, stream_t.c0, grid,
                                     cfg.priors, d_points=cfg.d_points)
                stacked = MomentCurves(
                    EL=jnp.stack([cand.EL, cand_alt.EL]),
                    VL=jnp.stack([cand.VL, cand_alt.VL]),
                )
                cand = mixture_moments(jnp.asarray([0.5, 0.5]), stacked)
        else:
            cand = MomentCurves(EL=jnp.zeros((cfg.max_arrivals, n_grid)),
                                VL=jnp.zeros((cfg.max_arrivals, n_grid)))

        res = admit_sequential(policy, agg_el, agg_vl, util, cand,
                               stream_t.c0, valid)
        state = state._replace(alive=alive, cores=cores, bel=bel)
        state, placed_arrival = _place_arrivals(state, res.accept, stream_t, cfg)
        # fold only arrivals that actually landed in a slot into the carried
        # aggregate — accepted-but-overflowed ones never became deployments
        # (the seed's per-step recompute likewise only ever saw placed slots)
        placed_f = placed_arrival.astype(jnp.float32)
        agg_el = agg_el + jnp.einsum("an,a->n", cand.EL, placed_f)
        agg_vl = agg_vl + jnp.einsum("an,a->n", cand.VL, placed_f)

        n_acc = jnp.sum(res.accept.astype(jnp.float32))
        n_rej = jnp.sum(valid.astype(jnp.float32)) - n_acc
        util_end = jnp.sum(state.cores * state.alive.astype(jnp.float32))
        state = state._replace(
            core_hours=state.core_hours + util_end * cfg.dt,
            fail_requests=state.fail_requests + failed,
            total_requests=state.total_requests + jnp.sum(n_req),
            arr_accepted=state.arr_accepted + n_acc,
            arr_rejected=state.arr_rejected + n_rej,
        )
        return (state, agg_el, agg_vl), (util_end, failed)

    def outer_block(policy: PolicyParams, state: SimState, xs_block):
        # full refresh of the aggregate from the slot array, once per block
        if needs_moments:
            agg_el, agg_vl = aggregate_fn(state.bel, state.cores, state.alive)
        else:
            agg_el = jnp.zeros((n_grid,))
            agg_vl = jnp.zeros((n_grid,))
        (state, _, _), traces = jax.lax.scan(
            functools.partial(step, policy), (state, agg_el, agg_vl), xs_block
        )
        return state, traces

    @functools.partial(jax.jit, static_argnames=())
    def run(key: jax.Array, policy: PolicyParams,
            stream: Optional[ArrivalStream] = None) -> RunMetrics:
        k_stream, k_scan = jax.random.split(key)
        if stream is None:
            stream = source.stream(k_stream, cfg)
        keys = jax.random.split(k_scan, cfg.n_steps)
        state0 = _init_state(cfg)
        block = lambda x: x.reshape((n_outer, k_refresh) + x.shape[1:])
        xs = jax.tree.map(block, (keys, stream))
        state, (util_trace, fail_trace) = jax.lax.scan(
            functools.partial(outer_block, policy), state0, xs
        )
        return RunMetrics(
            utilization=state.core_hours / (cfg.horizon_hours * cfg.capacity),
            failure_rate=state.fail_requests / jnp.maximum(state.total_requests, 1.0),
            total_requests=state.total_requests,
            failed_requests=state.fail_requests,
            arrivals_accepted=state.arr_accepted,
            arrivals_rejected=state.arr_rejected,
            slot_overflow=state.slot_overflow,
            util_trace=util_trace.reshape(cfg.n_steps),
            fail_trace=fail_trace.reshape(cfg.n_steps),
        )

    return run


def shard_batch_over_devices(batched, devices, axis: str,
                             n_replicated_args: int = 0,
                             n_batch_args: int = 1):
    """jit(shard_map(batched)) over a 1-d device mesh named ``axis``.

    ``batched`` maps ``n_batch_args`` leading-axis batches (plus
    ``n_replicated_args`` trailing broadcast arguments) to a pytree with the
    same leading axis; the batches are split across devices, replicated args
    go everywhere. Shared by ``run_batch`` (one batch arg: keys), the
    trace-ensemble path (two: keys + a stream batch), and the
    importance-sampling probe loop.
    """
    from jax.sharding import Mesh, PartitionSpec as P

    from ..compat import shard_map

    mesh = Mesh(np.asarray(devices), (axis,))
    in_specs = (P(axis),) * n_batch_args + (P(),) * n_replicated_args
    return jax.jit(shard_map(batched, mesh=mesh, in_specs=in_specs,
                             out_specs=P(axis), check_vma=False))


# bounded LRU: a weak-keyed cache cannot work here (the cached shard_map
# wrapper closes over run_fn, so the value would pin its own key), and jax's
# jit cache pins run_fn process-wide anyway — so just cap how many compiled
# sharded wrappers we keep across a sweep
_SHARDED_RUN_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_SHARDED_RUN_CACHE_MAX = 8


def run_keyed_batch(run_fn, keys: jax.Array, policy: PolicyParams,
                    *, streams: Optional[ArrivalStream] = None,
                    devices=None) -> RunMetrics:
    """Simulate an explicit ``[R, ...]`` batch of PRNG keys: vmap over runs,
    shard_map over devices.

    With more than one local device and the batch divisible by the device
    count, the key batch is sharded over a 1-d mesh and each device vmaps its
    shard (pure data parallelism — runs never communicate). Falls back to a
    plain vmap on a single device or when the batch does not divide evenly.
    The compiled sharded wrapper is cached per (run_fn, devices) — the policy
    is a traced argument — so repeated calls do not re-trace.

    Taking keys (not a count) is what lets the importance-sampling estimator
    route its pre-selected ``ImportancePlan.keys`` through the same sharded
    path as ordinary batches (see ``importance.simulate_plan``).

    ``streams`` (optional) is a leading-axis ``[R, ...]`` batch of pre-built
    ``ArrivalStream``\\ s, one per run, sharded alongside the keys — the
    trace-ensemble importance path uses this to pair each selected replay
    stream with its run key (see ``importance.simulate_trace_plan``).
    """
    keys = jnp.asarray(keys)
    n_runs = keys.shape[0]
    devices = tuple(jax.devices() if devices is None else devices)
    n_dev = len(devices)
    if streams is None:
        batched = jax.vmap(run_fn, in_axes=(0, None))
        args = (keys, policy)
        n_batch = 1
    else:
        batched = jax.vmap(lambda k, s, p: run_fn(k, p, s),
                           in_axes=(0, 0, None))
        args = (keys, streams, policy)
        n_batch = 2
    if n_dev <= 1 or n_runs % n_dev != 0:
        return batched(*args)

    cache_key = (run_fn, devices, n_batch)
    sharded = _SHARDED_RUN_CACHE.get(cache_key)
    if sharded is None:
        sharded = shard_batch_over_devices(batched, devices, "runs",
                                           n_replicated_args=1,
                                           n_batch_args=n_batch)
        _SHARDED_RUN_CACHE[cache_key] = sharded
        while len(_SHARDED_RUN_CACHE) > _SHARDED_RUN_CACHE_MAX:
            _SHARDED_RUN_CACHE.popitem(last=False)
    else:
        _SHARDED_RUN_CACHE.move_to_end(cache_key)
    return sharded(*args)


def run_batch(run_fn, key: jax.Array, policy: PolicyParams, n_runs: int,
              *, devices=None) -> RunMetrics:
    """A batch of ``n_runs`` independent runs split from one key; see
    ``run_keyed_batch`` for the sharding behavior."""
    return run_keyed_batch(run_fn, jax.random.split(key, n_runs), policy,
                           devices=devices)
