"""Monte-Carlo cluster simulator (paper §5): lax.scan over time, vmap over runs.

Deployments live in a fixed slot array (jit/vmap-friendly replacement for the
paper's dynamic deployment lists — see DESIGN.md "hardware adaptation"). Each
step of length ``dt`` hours:

  1. core deaths (exact binomial thinning) + spontaneous shutdown (M process)
  2. scale-out requests; granted greedily in slot order while the cluster has
     capacity, otherwise logged as SLA failures (entire request fails)
  3. belief updates from the observed events (conjugate, core.belief)
  4. arrivals (Poisson, capped at ``max_arrivals`` per step) admitted by the
     policy via core.policies.admit_sequential, then placed into free slots

Arrival parameters are **pre-drawn outside the scan** so importance sampling
(App. D) can bucket a run by its badness measure before paying for the full
simulation, and so labeled/unlabeled (§7) and pseudo-observation (§6) priors
can be prepared per arrival. The pre-drawn ``ArrivalStream`` is produced by a
pluggable ``ArrivalSource``: ``PriorArrivalSource`` samples the population
priors (the paper's setting), ``traces.replay.TraceArrivalSource`` replays a
recorded ``WorkloadTrace`` — the scan body never knows the difference.

The scan is **blocked by ``agg_refresh_steps``**: cluster-wide aggregate
moment curves (the only thing the admission policies consume) are fully
recomputed once per block — through a fused masked reduction, the per-slot
reference, or the Pallas aggregate kernel (``agg_backend``) — and maintained
incrementally inside the block by folding placed candidates' curves into
the running sums. Per-decision cost is therefore O(grid), independent of the
slot-array size, which is what makes the paper-scale preset feasible on CPU.

**Fleet mode** (paper §2's provider view: dispatch *then* admit): the same
step machinery runs with a leading cluster axis. ``make_fleet_run`` simulates
``FleetConfig.n_clusters`` heterogeneous clusters in one scan — ``SimState``,
the maintained aggregate curves, and the per-cluster ``RunMetrics`` all carry
a leading ``[C]`` axis (vmap inside the scan body; ``capacity`` becomes the
per-cluster array), and the blocked ``agg_refresh_steps`` refresh runs per
cluster. A pluggable ``sim.routing.Router`` maps each fleet-wide arrival to
a target cluster *before* ``admit_sequential`` runs there (arrivals no
cluster would take are counted as rejected-by-all). A one-cluster fleet
reproduces the single-cluster simulator key-for-key: cluster 0 keeps the
undiverted per-step key chain and the per-cluster step helpers are exactly
the single-cluster code path.
"""
from __future__ import annotations

import collections
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.belief import (GammaBelief, apply_pseudo_observations,
                           belief_from_prior, observe_initial_size,
                           update_on_events)
from ..core.moments import (MomentCurves, aggregate_moment_curves,
                            moment_curves, moment_curves_fused)
from ..core.policies import ZEROTH, PolicyParams, admit_sequential
from ..core.pricing import mixture_moments
from ..core.processes import (DeploymentParams, PopulationPriors,
                              sample_params, sample_pseudo_observations,
                              sample_step_events)

GLOBAL, PSEUDO, MIX_LABELED, MIX_UNLABELED = "global", "pseudo", "labeled", "unlabeled"
AGG_FUSED, AGG_REFERENCE, AGG_KERNEL = "fused", "reference", "kernel"


class SimConfig(NamedTuple):
    """Static simulation configuration (python values; changing any re-jits)."""

    capacity: float = 2_000.0
    arrival_rate: float = 0.1        # deployments/hour (paper: 1.0 at c=20,000)
    horizon_hours: float = 365 * 24.0
    dt: float = 6.0                  # hours per step
    max_slots: int = 1024
    max_arrivals: int = 4            # cap per step (Poisson tail clipped)
    prior_mode: str = GLOBAL         # GLOBAL | PSEUDO | MIX_LABELED | MIX_UNLABELED
    n_pseudo_obs: int = 0            # paper §6: 0/1/5/50
    d_points: int = 24               # D-term checkpoint count
    use_kernel: bool = False         # Pallas moment_curves kernel (TPU path;
                                     # interpret-mode on CPU, so off by default)
    agg_backend: str = AGG_FUSED     # AGG_FUSED | AGG_REFERENCE | AGG_KERNEL:
                                     # how the cluster-wide aggregate curves
                                     # are computed each step (see make_run)
    agg_refresh_steps: int = 1       # full aggregate recompute every K steps;
                                     # between refreshes admitted candidates'
                                     # curves are folded in incrementally
                                     # (K=1: recompute every step)
    priors: PopulationPriors = None  # population priors; prefer make_config,
                                     # which defaults these to AZURE_PRIORS

    @property
    def n_steps(self) -> int:
        return int(round(self.horizon_hours / self.dt))


def make_config(**overrides) -> SimConfig:
    """Documented SimConfig constructor: ``priors`` defaults to the fitted
    Azure priors instead of ``None`` and every field is validated eagerly, so
    a bad config fails here rather than deep inside ``belief_from_prior``."""
    if overrides.get("priors") is None:
        from ..core import AZURE_PRIORS

        overrides["priors"] = AZURE_PRIORS
    return _validate_config(SimConfig(**overrides))


def _validate_config(cfg: SimConfig) -> SimConfig:
    if cfg.priors is None:
        raise ValueError(
            "SimConfig.priors is None. Construct configs via "
            "repro.sim.make_config(...) (defaults to AZURE_PRIORS) or pass "
            "priors=<PopulationPriors> explicitly."
        )
    if cfg.prior_mode not in (GLOBAL, PSEUDO, MIX_LABELED, MIX_UNLABELED):
        raise ValueError(f"unknown prior_mode {cfg.prior_mode!r}")
    if cfg.agg_backend not in (AGG_FUSED, AGG_REFERENCE, AGG_KERNEL):
        raise ValueError(f"unknown agg_backend {cfg.agg_backend!r}")
    if cfg.n_pseudo_obs < 0:
        raise ValueError(f"n_pseudo_obs={cfg.n_pseudo_obs} must be >= 0")
    if cfg.prior_mode != GLOBAL and cfg.n_pseudo_obs == 0:
        raise ValueError(
            f"prior_mode={cfg.prior_mode!r} with n_pseudo_obs=0 silently "
            "degenerates to GLOBAL (zero pseudo observations leave every "
            "belief — including the §7 mixture components — at the "
            "population prior): use prior_mode=GLOBAL, or set "
            "n_pseudo_obs >= 1"
        )
    if cfg.n_steps <= 0 or cfg.max_slots <= 0 or cfg.max_arrivals <= 0:
        raise ValueError(
            f"degenerate SimConfig: n_steps={cfg.n_steps} "
            f"max_slots={cfg.max_slots} max_arrivals={cfg.max_arrivals}"
        )
    if cfg.agg_refresh_steps < 1 or cfg.n_steps % cfg.agg_refresh_steps:
        raise ValueError(
            f"agg_refresh_steps={cfg.agg_refresh_steps} must be >= 1 and "
            f"divide n_steps={cfg.n_steps}"
        )
    return cfg


class FleetConfig(NamedTuple):
    """Static fleet configuration: a per-cluster ``SimConfig`` template plus
    the per-cluster capacities.

    ``base`` describes each cluster's slot array, step size, information
    model, and aggregate-refresh blocking — *and* the fleet-wide arrival
    process (``arrival_rate``/``max_arrivals`` are the whole fleet's: one
    stream is drawn and routed, not one per cluster). ``base.capacity``
    conventionally holds the fleet total (``make_fleet_config`` sets it);
    the authoritative per-cluster capacities are ``capacities``.
    """

    base: SimConfig
    capacities: tuple                # per-cluster core capacities (static)

    @property
    def n_clusters(self) -> int:
        return len(self.capacities)

    @property
    def total_capacity(self) -> float:
        return float(sum(self.capacities))


def make_fleet_config(capacities, **base_overrides) -> FleetConfig:
    """Documented FleetConfig constructor: ``base_overrides`` build the
    per-cluster template through ``make_config`` (so priors default to
    AZURE_PRIORS and every field is validated); ``base.capacity`` defaults
    to the fleet total."""
    caps = tuple(float(c) for c in capacities)
    base_overrides.setdefault("capacity", sum(caps))
    return _validate_fleet_config(
        FleetConfig(base=make_config(**base_overrides), capacities=caps))


def _validate_fleet_config(fcfg: FleetConfig) -> FleetConfig:
    if not fcfg.capacities:
        raise ValueError("FleetConfig.capacities is empty")
    if any(not np.isfinite(c) or c <= 0.0 for c in fcfg.capacities):
        raise ValueError(
            f"FleetConfig.capacities must be positive, got {fcfg.capacities}")
    _validate_config(fcfg.base)
    return fcfg


def stream_config(cfg) -> SimConfig:
    """The ``SimConfig`` governing arrival-stream layout and priors.

    Identity for a plain ``SimConfig``; for a ``FleetConfig`` it is the base
    template with the fleet-total capacity — fleet arrivals are drawn (or
    replayed) fleet-wide and only routed to clusters at simulation time, so
    everything stream-shaped (``draw_arrival_stream``, trace replay, badness
    measures) works on this reduced config.
    """
    if isinstance(cfg, FleetConfig):
        return cfg.base._replace(capacity=cfg.total_capacity)
    return cfg


class ArrivalStream(NamedTuple):
    """Pre-drawn per-(step, arrival-slot) quantities. Leading dims [T, A]."""

    params: DeploymentParams         # true parameters of the arriving deployment
    c0: jax.Array                    # initial request size
    bel: GammaBelief                 # provider's prior belief for the arrival
    bel_alt: GammaBelief             # second mixture component (unlabeled mode)
    n_arrivals: jax.Array            # [T] arrivals per step (already capped)


class ArrivalSource:
    """Pluggable producer of the pre-drawn ``ArrivalStream``.

    ``make_run`` consumes arrivals exclusively through this interface: the
    scan body, policies, and importance sampling only ever see the stream,
    so any source that returns correctly-shaped ``[n_steps, max_arrivals]``
    fields plugs in without touching the simulator. Two backends ship:
    ``PriorArrivalSource`` (sample the population priors — the seed
    behavior) and ``traces.replay.TraceArrivalSource`` (replay a recorded
    ``WorkloadTrace``). ``stream`` is called inside the jitted run, so it
    must be traceable; closed-over trace arrays become constants.
    """

    def stream(self, key: jax.Array, cfg: SimConfig) -> "ArrivalStream":
        raise NotImplementedError


class PriorArrivalSource(ArrivalSource):
    """Draw every arrival from the population priors (paper §5 default)."""

    def stream(self, key: jax.Array, cfg: SimConfig) -> "ArrivalStream":
        return draw_arrival_stream(key, cfg)


class RunMetrics(NamedTuple):
    utilization: jax.Array        # time-average active cores / capacity
    failure_rate: jax.Array       # failed scale-out requests / total requests
    total_requests: jax.Array
    failed_requests: jax.Array
    arrivals_accepted: jax.Array
    arrivals_rejected: jax.Array
    slot_overflow: jax.Array      # arrivals lost to slot-array exhaustion
    n_departed: jax.Array         # deployments that died (spontaneous or
                                  # core exhaustion) over the whole run
    alive_end: jax.Array          # deployments still alive at the horizon
    util_trace: jax.Array         # [T] active cores after each step
    fail_trace: jax.Array         # [T] failed requests per step


class FleetMetrics(NamedTuple):
    """Fleet-level reductions plus the per-cluster ``RunMetrics``.

    The scalar fields mirror ``RunMetrics`` reduced over the cluster axis
    (capacity-weighted utilization; summed counts) so fleet runs drop into
    any consumer of run-level metrics — ``estimate_from_plan``, the SLA
    aggregation in ``sim.metrics`` — unchanged. ``per_cluster`` carries the
    full ``[C]``-leading per-cluster metrics (``util_trace`` is ``[C, T]``).
    """

    utilization: jax.Array        # total core-hours / (horizon * total capacity)
    failure_rate: jax.Array       # summed failures / summed requests
    total_requests: jax.Array
    failed_requests: jax.Array
    arrivals_accepted: jax.Array
    arrivals_rejected: jax.Array  # per-cluster rejections + rejected_by_all
    rejected_by_all: jax.Array    # arrivals the router could place nowhere
                                  # (threshold-cascade sentinel; 0 for
                                  # single-target routers)
    slot_overflow: jax.Array
    util_trace: jax.Array         # [T] fleet active cores after each step
    fail_trace: jax.Array         # [T] fleet failed requests per step
    per_cluster: RunMetrics       # leading [C] axis on every field


class SimState(NamedTuple):
    alive: jax.Array              # [S] bool
    cores: jax.Array              # [S] float32
    params: DeploymentParams      # [S]
    bel: GammaBelief              # [S]
    core_hours: jax.Array
    fail_requests: jax.Array
    total_requests: jax.Array
    arr_accepted: jax.Array
    arr_rejected: jax.Array
    slot_overflow: jax.Array
    n_departed: jax.Array


def draw_arrival_stream(key: jax.Array, cfg: SimConfig) -> ArrivalStream:
    """Pre-draw every arrival's true params, request size and prior belief."""
    cfg = stream_config(cfg)
    t_steps, a_max = cfg.n_steps, cfg.max_arrivals
    shape = (t_steps, a_max)
    kn, kp, kc, ko, kq, kb = jax.random.split(key, 6)
    n_arr = jnp.minimum(
        jax.random.poisson(kn, cfg.arrival_rate * cfg.dt, (t_steps,)), a_max
    )
    params = sample_params(kp, cfg.priors, shape)
    c0 = (1 + jax.random.poisson(kc, params.sig)).astype(jnp.float32)

    prior = belief_from_prior(cfg.priors, shape)
    if cfg.prior_mode == GLOBAL:
        bel = prior
        bel_alt = bel
    elif cfg.prior_mode == PSEUDO:
        obs = sample_pseudo_observations(ko, params, cfg.priors, cfg.n_pseudo_obs)
        bel = apply_pseudo_observations(prior, obs, cfg.priors)
        bel_alt = bel
    else:
        # §7: the user has two types; the submitted deployment is the drawn
        # ``params``; the alternative type is an independent draw. The provider
        # holds n_pseudo_obs observations of each type.
        alt = sample_params(kq, cfg.priors, shape)
        k1, k2 = jax.random.split(kb)
        obs = sample_pseudo_observations(k1, params, cfg.priors, cfg.n_pseudo_obs)
        obs_alt = sample_pseudo_observations(k2, alt, cfg.priors, cfg.n_pseudo_obs)
        bel = apply_pseudo_observations(prior, obs, cfg.priors)
        bel_alt = apply_pseudo_observations(prior, obs_alt, cfg.priors)
    bel = observe_initial_size(bel, c0)
    return ArrivalStream(params=params, c0=c0, bel=bel, bel_alt=bel_alt,
                         n_arrivals=n_arr)


def _init_state(cfg: SimConfig) -> SimState:
    s = cfg.max_slots
    zero_params = DeploymentParams(
        lam=jnp.zeros(s), mu=jnp.full((s,), 1.0), sig=jnp.zeros(s)
    )
    return SimState(
        alive=jnp.zeros(s, bool),
        cores=jnp.zeros(s, jnp.float32),
        params=zero_params,
        bel=belief_from_prior(cfg.priors, (s,)),
        core_hours=jnp.zeros(()),
        fail_requests=jnp.zeros(()),
        total_requests=jnp.zeros(()),
        arr_accepted=jnp.zeros(()),
        arr_rejected=jnp.zeros(()),
        slot_overflow=jnp.zeros(()),
        n_departed=jnp.zeros(()),
    )


def _place_arrivals(state: SimState, accept, stream_t: ArrivalStream, cfg: SimConfig):
    """Place accepted arrivals into free slots, one vectorized pass.

    The i-th accepted arrival goes to the i-th free slot (in slot order) —
    identical semantics to the previous sequential argmin unroll, but a single
    [A, S] rank-match instead of A passes over the slot array. Accepted
    arrivals beyond the number of free slots are counted as slot overflow.

    Returns (state, placed_arrival [A]) — the mask of accepted arrivals that
    actually landed in a slot, so the caller folds only *real* deployments
    into the maintained aggregate (overflowed arrivals must not haunt it).
    """
    alive = state.alive
    free = ~alive
    rank = jnp.cumsum(free.astype(jnp.int32))          # free-slot rank, 1-based
    acc = accept.astype(jnp.int32)
    ordinal = jnp.cumsum(acc) * acc                    # i-th accepted, 1-based
    n_free = rank[-1]
    placed_arrival = accept & (ordinal <= n_free)      # [A]
    overflow = state.slot_overflow + jnp.sum(
        jnp.where(accept & ~placed_arrival, 1.0, 0.0))

    hit = free[None, :] & (rank[None, :] == ordinal[:, None]) & accept[:, None]
    placed = jnp.any(hit, axis=0)                      # [S]

    def merge(old, new_a):
        upd = hit.astype(old.dtype).T @ new_a
        return jnp.where(placed, upd, old)

    cores = merge(state.cores, stream_t.c0)
    params = jax.tree.map(lambda o, n: merge(o, n), state.params,
                          stream_t.params)
    bel = jax.tree.map(lambda o, n: merge(o, n), state.bel, stream_t.bel)
    state = state._replace(alive=alive | placed, cores=cores, params=params,
                           bel=bel, slot_overflow=overflow)
    return state, placed_arrival


def _make_aggregate_fn(cfg: SimConfig, grid: jax.Array):
    """Cluster-wide sum-over-alive-slots curve evaluator, by backend.

    AGG_REFERENCE is the seed per-slot path (materialize [S, N], mask, sum) —
    kept as the oracle the fast paths are equivalence-tested against.
    AGG_FUSED reduces block-by-block without the [S, N] intermediate;
    AGG_KERNEL is the Pallas aggregated-output kernel (interpret-mode on CPU).
    """
    if cfg.agg_backend == AGG_REFERENCE:

        def aggregate(bel, cores, alive):
            curves = moment_curves(bel, cores, grid, cfg.priors,
                                   d_points=cfg.d_points)
            alive_f = alive.astype(jnp.float32)
            return (jnp.sum(curves.EL * alive_f[:, None], axis=0),
                    jnp.sum(curves.VL * alive_f[:, None], axis=0))
    elif cfg.agg_backend == AGG_KERNEL:
        from ..kernels.moment_curves.ops import aggregate_moment_curves_kernel

        def aggregate(bel, cores, alive):
            out = aggregate_moment_curves_kernel(
                bel, cores, alive, grid, cfg.priors, d_points=cfg.d_points)
            return out.EL, out.VL
    else:

        def aggregate(bel, cores, alive):
            out = aggregate_moment_curves(bel, cores, alive, grid, cfg.priors,
                                          d_points=cfg.d_points)
            return out.EL, out.VL

    return aggregate


def _make_curves_fn(cfg: SimConfig):
    """Per-candidate moment-curve evaluator (fused jnp or Pallas kernel)."""
    if cfg.use_kernel:
        from ..kernels.moment_curves.ops import moment_curves_kernel

        def curves_fn(bel, cores, grid_, priors, d_points):
            flat_bel = jax.tree.map(lambda a: a.reshape(-1), bel)
            out = moment_curves_kernel(flat_bel, cores.reshape(-1), grid_,
                                       priors, d_points=d_points)
            shape = cores.shape + (grid_.shape[0],)
            return MomentCurves(out.EL.reshape(shape), out.VL.reshape(shape))

        return curves_fn
    return moment_curves_fused


def _make_candidates_fn(cfg: SimConfig, grid: jax.Array, needs_moments: bool,
                        n_grid: int, curves_fn):
    """[A, N] candidate curves for one step's pre-drawn arrivals (mixture
    moments in the §7 unlabeled mode; zeros when the policy ignores them)."""

    def candidates(stream_t: ArrivalStream) -> MomentCurves:
        if not needs_moments:
            return MomentCurves(EL=jnp.zeros((cfg.max_arrivals, n_grid)),
                                VL=jnp.zeros((cfg.max_arrivals, n_grid)))
        cand = curves_fn(stream_t.bel, stream_t.c0, grid, cfg.priors,
                         d_points=cfg.d_points)
        if cfg.prior_mode == MIX_UNLABELED:
            cand_alt = curves_fn(stream_t.bel_alt, stream_t.c0, grid,
                                 cfg.priors, d_points=cfg.d_points)
            stacked = MomentCurves(
                EL=jnp.stack([cand.EL, cand_alt.EL]),
                VL=jnp.stack([cand.VL, cand_alt.VL]),
            )
            cand = mixture_moments(jnp.asarray([0.5, 0.5]), stacked)
        return cand

    return candidates


def _step_dynamics(cfg: SimConfig, capacity, key, state: SimState):
    """Steps 1–3 of one ``dt``-hour step for ONE cluster: deaths, scale-out
    grants against ``capacity`` (a traced value — the fleet passes each
    cluster's own), and conjugate belief updates.

    Returns ``(state, util, failed, n_req_total, departed)`` with the slot
    arrays updated and the metric counters untouched (the caller accumulates
    them after admission).
    """
    alive_f = state.alive.astype(jnp.float32)

    # 1. deaths ---------------------------------------------------------
    ev = sample_step_events(key, state.params, state.cores, cfg.priors,
                            cfg.dt, alive=state.alive)
    deaths = jnp.minimum(ev.core_deaths.astype(jnp.float32), state.cores) * alive_f
    exposure = state.cores * cfg.dt * alive_f
    cores = state.cores - deaths
    cores = jnp.where(ev.spont_death & state.alive, 0.0, cores)
    alive = state.alive & (cores > 0.0)
    departed = jnp.sum((state.alive & ~alive).astype(jnp.float32))
    alive_f = alive.astype(jnp.float32)

    # 2. scale-outs (only deployments still alive request) ---------------
    req = ev.scaleout_cores.astype(jnp.float32) * alive_f
    n_req = ev.n_scaleouts.astype(jnp.float32) * alive_f
    util = jnp.sum(cores * alive_f)
    grant = (util + jnp.cumsum(req)) <= capacity
    cores = cores + jnp.where(grant, req, 0.0)
    failed = jnp.sum(jnp.where(~grant, n_req, 0.0))
    util = jnp.sum(cores * alive_f)

    # 3. belief updates (requests are observed whether or not granted) ---
    bel = update_on_events(
        state.bel,
        core_deaths=deaths,
        exposure_core_hours=exposure,
        n_scaleouts=n_req,
        scaleout_cores=req,
        alive_hours=cfg.dt * alive_f,
        priors=cfg.priors,
    )
    state = state._replace(alive=alive, cores=cores, bel=bel)
    return state, util, failed, jnp.sum(n_req), departed


def _admit_place_fold(cfg: SimConfig, policy: PolicyParams, state: SimState,
                      agg_el, agg_vl, util, cand: MomentCurves,
                      stream_t: ArrivalStream, valid):
    """Step 4 for ONE cluster: sequential admission of the (cluster-masked)
    candidates against the maintained aggregate, slot placement, and the
    incremental aggregate fold of *placed* arrivals.

    Folds only arrivals that actually landed in a slot into the carried
    aggregate — accepted-but-overflowed ones never became deployments (the
    seed's per-step recompute likewise only ever saw placed slots).
    """
    res = admit_sequential(policy, agg_el, agg_vl, util, cand,
                           stream_t.c0, valid)
    state, placed_arrival = _place_arrivals(state, res.accept, stream_t, cfg)
    placed_f = placed_arrival.astype(jnp.float32)
    agg_el = agg_el + jnp.einsum("an,a->n", cand.EL, placed_f)
    agg_vl = agg_vl + jnp.einsum("an,a->n", cand.VL, placed_f)
    return state, agg_el, agg_vl, res.accept


def make_run(cfg: SimConfig, horizon_grid: jax.Array, policy_kind: int,
             arrival_source: ArrivalSource | None = None):
    """Build the jitted simulator for a fixed policy *kind* (threshold/rho stay
    traced so tuning does not re-jit). Returns run(key, policy) -> RunMetrics.

    ``arrival_source`` selects where arrivals come from (default: sample the
    population priors); an explicit ``stream`` argument to run() still takes
    precedence over the source.

    The scan is blocked by ``cfg.agg_refresh_steps`` (= K): the cluster-wide
    aggregate moment curves are fully recomputed from the slot array once per
    block (via ``cfg.agg_backend``), and inside a block the aggregate is
    maintained *incrementally* — each *placed* candidate's curves are folded
    into the running sums, so the per-decision cost is O(grid), independent
    of occupancy. Between refreshes the aggregate is stale by at most K
    steps of within-block dynamics: deaths shrink the true load (stale
    aggregate over-estimates, conservative), while scale-out grants and
    belief updates grow it (stale aggregate under-estimates, optimistic) —
    so K must stay small relative to the scale-out dynamics, and any
    residual bias is absorbed by the SLA-constrained threshold tuning, which
    calibrates against the same simulator at the same K. K = 1 recomputes
    every step (the refresh then lags the seed's in-step recompute by
    exactly the current step's death/belief update).
    """
    _validate_config(cfg)
    source = PriorArrivalSource() if arrival_source is None else arrival_source
    needs_moments = policy_kind != ZEROTH
    grid = horizon_grid
    n_grid = grid.shape[0] if needs_moments else 1
    k_refresh = cfg.agg_refresh_steps
    n_outer = cfg.n_steps // k_refresh
    curves_fn = _make_curves_fn(cfg)
    aggregate_fn = _make_aggregate_fn(cfg, grid)
    candidates_fn = _make_candidates_fn(cfg, grid, needs_moments, n_grid,
                                        curves_fn)

    def step(policy: PolicyParams, carry, xs):
        state, agg_el, agg_vl = carry
        key, stream_t = xs
        state, util, failed, n_req_total, departed = _step_dynamics(
            cfg, cfg.capacity, key, state)

        # 4. arrivals, admitted against the maintained aggregate -------------
        valid = jnp.arange(cfg.max_arrivals) < stream_t.n_arrivals
        cand = candidates_fn(stream_t)
        state, agg_el, agg_vl, accept = _admit_place_fold(
            cfg, policy, state, agg_el, agg_vl, util, cand, stream_t, valid)

        n_acc = jnp.sum(accept.astype(jnp.float32))
        n_rej = jnp.sum(valid.astype(jnp.float32)) - n_acc
        util_end = jnp.sum(state.cores * state.alive.astype(jnp.float32))
        state = state._replace(
            core_hours=state.core_hours + util_end * cfg.dt,
            fail_requests=state.fail_requests + failed,
            total_requests=state.total_requests + n_req_total,
            arr_accepted=state.arr_accepted + n_acc,
            arr_rejected=state.arr_rejected + n_rej,
            n_departed=state.n_departed + departed,
        )
        return (state, agg_el, agg_vl), (util_end, failed)

    def outer_block(policy: PolicyParams, state: SimState, xs_block):
        # full refresh of the aggregate from the slot array, once per block
        if needs_moments:
            agg_el, agg_vl = aggregate_fn(state.bel, state.cores, state.alive)
        else:
            agg_el = jnp.zeros((n_grid,))
            agg_vl = jnp.zeros((n_grid,))
        (state, _, _), traces = jax.lax.scan(
            functools.partial(step, policy), (state, agg_el, agg_vl), xs_block
        )
        return state, traces

    @functools.partial(jax.jit, static_argnames=())
    def run(key: jax.Array, policy: PolicyParams,
            stream: Optional[ArrivalStream] = None) -> RunMetrics:
        k_stream, k_scan = jax.random.split(key)
        if stream is None:
            stream = source.stream(k_stream, cfg)
        keys = jax.random.split(k_scan, cfg.n_steps)
        state0 = _init_state(cfg)
        block = lambda x: x.reshape((n_outer, k_refresh) + x.shape[1:])
        xs = jax.tree.map(block, (keys, stream))
        state, (util_trace, fail_trace) = jax.lax.scan(
            functools.partial(outer_block, policy), state0, xs
        )
        return RunMetrics(
            utilization=state.core_hours / (cfg.horizon_hours * cfg.capacity),
            failure_rate=state.fail_requests / jnp.maximum(state.total_requests, 1.0),
            total_requests=state.total_requests,
            failed_requests=state.fail_requests,
            arrivals_accepted=state.arr_accepted,
            arrivals_rejected=state.arr_rejected,
            slot_overflow=state.slot_overflow,
            n_departed=state.n_departed,
            alive_end=jnp.sum(state.alive.astype(jnp.float32)),
            util_trace=util_trace.reshape(cfg.n_steps),
            fail_trace=fail_trace.reshape(cfg.n_steps),
        )

    return run


# ---------------------------------------------------------------------------
# Fleet mode: a leading cluster axis over the same step machinery.
# ---------------------------------------------------------------------------


def _cluster_step_keys(key: jax.Array, n_clusters: int) -> jax.Array:
    """[C] per-cluster event keys for one step.

    Cluster 0 keeps the undiverted per-step key, so a one-cluster fleet
    reproduces ``make_run``'s event randomness key-for-key; clusters 1..C-1
    fold their index in (independent chains, no cross-cluster correlation).
    """
    if n_clusters == 1:
        return key[None]
    return jnp.stack([key] + [jax.random.fold_in(key, c)
                              for c in range(1, n_clusters)])


def _check_fleet_policy_capacity(policy: PolicyParams, fcfg: FleetConfig):
    """Fail fast on a mis-specified fleet policy: each cluster's ``decide``
    admits against ``policy.capacity``, so a scalar fleet-*total* capacity
    tiled to every cluster would let each cluster believe it owns the whole
    fleet's budget — calibration would then return plausible-looking but
    wildly over-optimistic thetas with no error. Skipped when the capacity
    leaf is traced (the values are checked at the first concrete call)."""
    cap = getattr(policy, "capacity", None)
    if cap is None or isinstance(cap, jax.core.Tracer):
        return
    cap = np.asarray(cap)
    target = np.asarray(fcfg.capacities, dtype=np.float64)
    ok = (cap.ndim == 0 or cap.shape == target.shape) and np.allclose(
        np.asarray(cap, np.float64), target, rtol=1e-5)
    if not ok:
        raise ValueError(
            f"policy capacity {cap} does not match FleetConfig.capacities "
            f"{fcfg.capacities}: each cluster admits against its OWN "
            "capacity. Build fleet policies with core.policies.fleet_policy"
            "(kind, capacities=fleet_cfg.capacities, ...); when tuning, pass "
            "such a closure as calibrate(..., policy_fn=...).")


def broadcast_policy(policy: PolicyParams, n_clusters: int) -> PolicyParams:
    """Give every PolicyParams field a leading ``[C]`` cluster axis.

    Scalar fields are tiled; fields already carrying the cluster axis (from
    ``core.policies.fleet_policy``) pass through unchanged. Anything else is
    a shape error — per-cluster parameters must be built deliberately.
    """

    def bc(x):
        x = jnp.asarray(x)
        if x.ndim == 0:
            return jnp.broadcast_to(x, (n_clusters,))
        if x.shape[0] == n_clusters and x.ndim == 1:
            return x
        raise ValueError(
            f"policy field has shape {x.shape}; expected a scalar or a "
            f"[{n_clusters}]-vector (one entry per cluster)")

    return jax.tree.map(bc, policy)


def make_fleet_run(fcfg: FleetConfig, horizon_grid: jax.Array,
                   policy_kind: int, router=None,
                   arrival_source: ArrivalSource | None = None):
    """Build the jitted fleet simulator: route, then admit per cluster.

    Returns ``run(key, policy, stream=None) -> FleetMetrics``. ``policy``
    is normally a ``core.policies.fleet_policy`` (``[C]`` fields, per-cluster
    capacities and thresholds); a plain scalar ``PolicyParams`` is tiled to
    every cluster via ``broadcast_policy``, which is only meaningful for a
    homogeneous fleet — ``run`` fails fast when the policy's capacity does
    not match ``FleetConfig.capacities`` per cluster (a tiled fleet-total
    would let every cluster admit against the whole fleet's budget).

    Each step: per-cluster dynamics (deaths / scale-out grants against the
    cluster's own capacity / belief updates, vmapped over the cluster axis
    with independent key chains), one shared candidate-curve evaluation for
    the step's fleet-wide arrivals, the ``router``'s cluster assignment from
    the per-cluster maintained aggregates, then per-cluster
    ``admit_sequential`` + slot placement + incremental aggregate fold on
    each cluster's assigned arrivals. The blocked ``agg_refresh_steps``
    refresh recomputes every cluster's aggregate from its own slot array
    once per block. Arrivals the router maps to the sentinel ``C`` (the
    threshold cascade's "no cluster would take it") are counted as
    ``rejected_by_all`` and enter no cluster's admission scan.
    """
    from .routing import LeastUtilizedRouter

    _validate_fleet_config(fcfg)
    cfg = fcfg.base
    n_c = fcfg.n_clusters
    caps = jnp.asarray(fcfg.capacities, jnp.float32)
    router = LeastUtilizedRouter() if router is None else router
    source = PriorArrivalSource() if arrival_source is None else arrival_source
    needs_moments = policy_kind != ZEROTH
    grid = horizon_grid
    n_grid = grid.shape[0] if needs_moments else 1
    k_refresh = cfg.agg_refresh_steps
    n_outer = cfg.n_steps // k_refresh
    curves_fn = _make_curves_fn(cfg)
    aggregate_fn = _make_aggregate_fn(cfg, grid)
    candidates_fn = _make_candidates_fn(cfg, grid, needs_moments, n_grid,
                                        curves_fn)

    def fleet_step(policy: PolicyParams, carry, xs):
        state, agg_el, agg_vl, rej_all = carry      # [C, ...] everywhere
        key, stream_t = xs
        keys_c = _cluster_step_keys(key, n_c)
        state, util, failed, n_req_total, departed = jax.vmap(
            lambda cap, k, st: _step_dynamics(cfg, cap, k, st))(
                caps, keys_c, state)

        valid = jnp.arange(cfg.max_arrivals) < stream_t.n_arrivals
        cand = candidates_fn(stream_t)

        from .routing import RouteContext

        assign = router.route(
            jax.random.fold_in(key, n_c),
            RouteContext(cand=cand, c0=stream_t.c0, valid=valid,
                         agg_el=agg_el, agg_vl=agg_vl, util=util,
                         capacities=caps, policy=policy))
        assign = jnp.clip(assign, 0, n_c)           # sentinel n_c = nowhere
        cluster_mask = valid[None, :] & (
            assign[None, :] == jnp.arange(n_c)[:, None])   # [C, A]
        rej_all = rej_all + jnp.sum(
            (valid & (assign == n_c)).astype(jnp.float32))

        state, agg_el, agg_vl, accept = jax.vmap(
            lambda pol_c, st_c, el_c, vl_c, u_c, valid_c: _admit_place_fold(
                cfg, pol_c, st_c, el_c, vl_c, u_c, cand, stream_t, valid_c))(
                    policy, state, agg_el, agg_vl, util, cluster_mask)

        n_acc = jnp.sum(accept.astype(jnp.float32), axis=1)          # [C]
        n_rej = jnp.sum(cluster_mask.astype(jnp.float32), axis=1) - n_acc
        util_end = jnp.sum(
            state.cores * state.alive.astype(jnp.float32), axis=1)   # [C]
        state = state._replace(
            core_hours=state.core_hours + util_end * cfg.dt,
            fail_requests=state.fail_requests + failed,
            total_requests=state.total_requests + n_req_total,
            arr_accepted=state.arr_accepted + n_acc,
            arr_rejected=state.arr_rejected + n_rej,
            n_departed=state.n_departed + departed,
        )
        return (state, agg_el, agg_vl, rej_all), (util_end, failed)

    def outer_block(policy: PolicyParams, carry, xs_block):
        state, rej_all = carry
        # full per-cluster refresh of the aggregates, once per block
        if needs_moments:
            agg_el, agg_vl = jax.vmap(aggregate_fn)(state.bel, state.cores,
                                                    state.alive)
        else:
            agg_el = jnp.zeros((n_c, n_grid))
            agg_vl = jnp.zeros((n_c, n_grid))
        (state, _, _, rej_all), traces = jax.lax.scan(
            functools.partial(fleet_step, policy),
            (state, agg_el, agg_vl, rej_all), xs_block
        )
        return (state, rej_all), traces

    @functools.partial(jax.jit, static_argnames=())
    def _sim_run(key: jax.Array, policy: PolicyParams,
                 stream: Optional[ArrivalStream] = None) -> FleetMetrics:
        policy = broadcast_policy(policy, n_c)
        k_stream, k_scan = jax.random.split(key)
        if stream is None:
            stream = source.stream(k_stream, cfg)
        keys = jax.random.split(k_scan, cfg.n_steps)
        state0 = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_c,) + x.shape), _init_state(cfg))
        block = lambda x: x.reshape((n_outer, k_refresh) + x.shape[1:])
        xs = jax.tree.map(block, (keys, stream))
        (state, rej_all), (util_trace, fail_trace) = jax.lax.scan(
            functools.partial(outer_block, policy),
            (state0, jnp.zeros(())), xs
        )
        util_trace = util_trace.reshape(cfg.n_steps, n_c).T      # [C, T]
        fail_trace = fail_trace.reshape(cfg.n_steps, n_c).T
        per_cluster = RunMetrics(
            utilization=state.core_hours / (cfg.horizon_hours * caps),
            failure_rate=state.fail_requests
            / jnp.maximum(state.total_requests, 1.0),
            total_requests=state.total_requests,
            failed_requests=state.fail_requests,
            arrivals_accepted=state.arr_accepted,
            arrivals_rejected=state.arr_rejected,
            slot_overflow=state.slot_overflow,
            n_departed=state.n_departed,
            alive_end=jnp.sum(state.alive.astype(jnp.float32), axis=1),
            util_trace=util_trace,
            fail_trace=fail_trace,
        )
        tot_req = jnp.sum(state.total_requests)
        tot_fail = jnp.sum(state.fail_requests)
        return FleetMetrics(
            utilization=jnp.sum(state.core_hours)
            / (cfg.horizon_hours * jnp.sum(caps)),
            failure_rate=tot_fail / jnp.maximum(tot_req, 1.0),
            total_requests=tot_req,
            failed_requests=tot_fail,
            arrivals_accepted=jnp.sum(state.arr_accepted),
            arrivals_rejected=jnp.sum(state.arr_rejected) + rej_all,
            rejected_by_all=rej_all,
            slot_overflow=jnp.sum(state.slot_overflow),
            util_trace=jnp.sum(util_trace, axis=0),
            fail_trace=jnp.sum(fail_trace, axis=0),
            per_cluster=per_cluster,
        )

    def run(key: jax.Array, policy: PolicyParams,
            stream: Optional[ArrivalStream] = None) -> FleetMetrics:
        _check_fleet_policy_capacity(policy, fcfg)
        return _sim_run(key, policy, stream)

    return run


def shard_batch_over_devices(batched, devices, axis: str,
                             n_replicated_args: int = 0,
                             n_batch_args: int = 1):
    """jit(shard_map(batched)) over a 1-d device mesh named ``axis``.

    ``batched`` maps ``n_batch_args`` leading-axis batches (plus
    ``n_replicated_args`` trailing broadcast arguments) to a pytree with the
    same leading axis; the batches are split across devices, replicated args
    go everywhere. The batch size must divide the device count — callers
    with ragged batches pad first (see ``run_keyed_batch``). Shared by
    ``run_batch`` (one batch arg: keys), the trace-ensemble path (two: keys
    + a stream batch), and the importance-sampling probe loop.
    """
    from jax.sharding import Mesh, PartitionSpec as P

    from ..compat import shard_map

    mesh = Mesh(np.asarray(devices), (axis,))
    in_specs = (P(axis),) * n_batch_args + (P(),) * n_replicated_args
    return jax.jit(shard_map(batched, mesh=mesh, in_specs=in_specs,
                             out_specs=P(axis), check_vma=False))


# bounded LRU: a weak-keyed cache cannot work here (the cached shard_map
# wrapper closes over run_fn, so the value would pin its own key), and jax's
# jit cache pins run_fn process-wide anyway — so just cap how many compiled
# sharded wrappers we keep across a sweep
_SHARDED_RUN_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_SHARDED_RUN_CACHE_MAX = 8


def _pad_batch(args, n_batch: int, pad: int):
    """Pad the leading axis of the first ``n_batch`` args by repeating their
    last row ``pad`` times (trailing args are replicated, never padded)."""
    if pad == 0:
        return args
    pad_fn = lambda x: jnp.concatenate(
        [x, jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])], axis=0)
    return tuple(jax.tree.map(pad_fn, a) for a in args[:n_batch]) \
        + args[n_batch:]


def run_keyed_batch(run_fn, keys: jax.Array, policy: PolicyParams,
                    *, streams: Optional[ArrivalStream] = None,
                    devices=None) -> RunMetrics:
    """Simulate an explicit ``[R, ...]`` batch of PRNG keys: vmap over runs,
    shard_map over devices.

    With more than one local device the key batch is sharded over a 1-d mesh
    and each device vmaps its shard (pure data parallelism — runs never
    communicate). A batch that does not divide the device count is **padded**
    to the next multiple by repeating its last run (streams ride along), and
    the padded lanes are sliced off before returning — so they never reach a
    caller's metric reductions. Single-device falls back to a plain vmap.
    The compiled sharded wrapper is cached per (run_fn, devices) — the policy
    is a traced argument — so repeated calls do not re-trace.

    Taking keys (not a count) is what lets the importance-sampling estimator
    route its pre-selected ``ImportancePlan.keys`` through the same sharded
    path as ordinary batches (see ``importance.simulate_plan``).

    ``streams`` (optional) is a leading-axis ``[R, ...]`` batch of pre-built
    ``ArrivalStream``\\ s, one per run, sharded alongside the keys — the
    trace-ensemble importance path uses this to pair each selected replay
    stream with its run key (see ``importance.simulate_trace_plan``).
    """
    keys = jnp.asarray(keys)
    n_runs = keys.shape[0]
    devices = tuple(jax.devices() if devices is None else devices)
    n_dev = len(devices)
    if streams is None:
        batched = jax.vmap(run_fn, in_axes=(0, None))
        args = (keys, policy)
        n_batch = 1
    else:
        batched = jax.vmap(lambda k, s, p: run_fn(k, p, s),
                           in_axes=(0, 0, None))
        args = (keys, streams, policy)
        n_batch = 2
    if n_dev <= 1:
        return batched(*args)

    pad = (-n_runs) % n_dev
    args = _pad_batch(args, n_batch, pad)
    cache_key = (run_fn, devices, n_batch)
    sharded = _SHARDED_RUN_CACHE.get(cache_key)
    if sharded is None:
        sharded = shard_batch_over_devices(batched, devices, "runs",
                                           n_replicated_args=1,
                                           n_batch_args=n_batch)
        _SHARDED_RUN_CACHE[cache_key] = sharded
        while len(_SHARDED_RUN_CACHE) > _SHARDED_RUN_CACHE_MAX:
            _SHARDED_RUN_CACHE.popitem(last=False)
    else:
        _SHARDED_RUN_CACHE.move_to_end(cache_key)
    metrics = sharded(*args)
    if pad:
        metrics = jax.tree.map(lambda x: x[:n_runs], metrics)
    return metrics


def run_batch(run_fn, key: jax.Array, policy: PolicyParams, n_runs: int,
              *, devices=None) -> RunMetrics:
    """A batch of ``n_runs`` independent runs split from one key; see
    ``run_keyed_batch`` for the sharding behavior."""
    return run_keyed_batch(run_fn, jax.random.split(key, n_runs), policy,
                           devices=devices)
