"""Monte-Carlo cluster simulator (paper §5): lax.scan over time, vmap over runs.

Deployments live in a fixed slot array (jit/vmap-friendly replacement for the
paper's dynamic deployment lists — see DESIGN.md "hardware adaptation"). Each
step of length ``dt`` hours:

  1. core deaths (exact binomial thinning) + spontaneous shutdown (M process)
  2. scale-out requests; granted greedily in slot order while the cluster has
     capacity, otherwise logged as SLA failures (entire request fails)
  3. belief updates from the observed events (conjugate, core.belief)
  4. arrivals (Poisson, capped at ``max_arrivals`` per step) admitted by the
     policy via core.policies.admit_sequential, then placed into free slots

Arrival parameters are **pre-drawn outside the scan** so importance sampling
(App. D) can bucket a run by its badness measure before paying for the full
simulation, and so labeled/unlabeled (§7) and pseudo-observation (§6) priors
can be prepared per arrival.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core.belief import (GammaBelief, apply_pseudo_observations,
                           belief_from_prior, observe_initial_size,
                           update_on_events)
from ..core.moments import MomentCurves, moment_curves
from ..core.policies import ZEROTH, PolicyParams, admit_sequential
from ..core.pricing import mixture_moments
from ..core.processes import (DeploymentParams, PopulationPriors,
                              sample_params, sample_pseudo_observations,
                              sample_step_events)

GLOBAL, PSEUDO, MIX_LABELED, MIX_UNLABELED = "global", "pseudo", "labeled", "unlabeled"


class SimConfig(NamedTuple):
    """Static simulation configuration (python values; changing any re-jits)."""

    capacity: float = 2_000.0
    arrival_rate: float = 0.1        # deployments/hour (paper: 1.0 at c=20,000)
    horizon_hours: float = 365 * 24.0
    dt: float = 6.0                  # hours per step
    max_slots: int = 1024
    max_arrivals: int = 4            # cap per step (Poisson tail clipped)
    prior_mode: str = GLOBAL         # GLOBAL | PSEUDO | MIX_LABELED | MIX_UNLABELED
    n_pseudo_obs: int = 0            # paper §6: 0/1/5/50
    d_points: int = 24               # D-term checkpoint count
    use_kernel: bool = False         # Pallas moment_curves kernel (TPU path;
                                     # interpret-mode on CPU, so off by default)
    priors: PopulationPriors = None  # set via make_config

    @property
    def n_steps(self) -> int:
        return int(round(self.horizon_hours / self.dt))


class ArrivalStream(NamedTuple):
    """Pre-drawn per-(step, arrival-slot) quantities. Leading dims [T, A]."""

    params: DeploymentParams         # true parameters of the arriving deployment
    c0: jax.Array                    # initial request size
    bel: GammaBelief                 # provider's prior belief for the arrival
    bel_alt: GammaBelief             # second mixture component (unlabeled mode)
    n_arrivals: jax.Array            # [T] arrivals per step (already capped)


class RunMetrics(NamedTuple):
    utilization: jax.Array        # time-average active cores / capacity
    failure_rate: jax.Array       # failed scale-out requests / total requests
    total_requests: jax.Array
    failed_requests: jax.Array
    arrivals_accepted: jax.Array
    arrivals_rejected: jax.Array
    slot_overflow: jax.Array      # arrivals lost to slot-array exhaustion
    util_trace: jax.Array         # [T] active cores after each step
    fail_trace: jax.Array         # [T] failed requests per step


class SimState(NamedTuple):
    alive: jax.Array              # [S] bool
    cores: jax.Array              # [S] float32
    params: DeploymentParams      # [S]
    bel: GammaBelief              # [S]
    core_hours: jax.Array
    fail_requests: jax.Array
    total_requests: jax.Array
    arr_accepted: jax.Array
    arr_rejected: jax.Array
    slot_overflow: jax.Array


def draw_arrival_stream(key: jax.Array, cfg: SimConfig) -> ArrivalStream:
    """Pre-draw every arrival's true params, request size and prior belief."""
    t_steps, a_max = cfg.n_steps, cfg.max_arrivals
    shape = (t_steps, a_max)
    kn, kp, kc, ko, kq, kb = jax.random.split(key, 6)
    n_arr = jnp.minimum(
        jax.random.poisson(kn, cfg.arrival_rate * cfg.dt, (t_steps,)), a_max
    )
    params = sample_params(kp, cfg.priors, shape)
    c0 = (1 + jax.random.poisson(kc, params.sig)).astype(jnp.float32)

    prior = belief_from_prior(cfg.priors, shape)
    if cfg.prior_mode == GLOBAL:
        bel = prior
        bel_alt = bel
    elif cfg.prior_mode == PSEUDO:
        obs = sample_pseudo_observations(ko, params, cfg.priors, cfg.n_pseudo_obs)
        bel = apply_pseudo_observations(prior, obs, cfg.priors)
        bel_alt = bel
    else:
        # §7: the user has two types; the submitted deployment is the drawn
        # ``params``; the alternative type is an independent draw. The provider
        # holds n_pseudo_obs observations of each type.
        alt = sample_params(kq, cfg.priors, shape)
        k1, k2 = jax.random.split(kb)
        obs = sample_pseudo_observations(k1, params, cfg.priors, cfg.n_pseudo_obs)
        obs_alt = sample_pseudo_observations(k2, alt, cfg.priors, cfg.n_pseudo_obs)
        bel = apply_pseudo_observations(prior, obs, cfg.priors)
        bel_alt = apply_pseudo_observations(prior, obs_alt, cfg.priors)
    bel = observe_initial_size(bel, c0)
    return ArrivalStream(params=params, c0=c0, bel=bel, bel_alt=bel_alt,
                         n_arrivals=n_arr)


def _init_state(cfg: SimConfig) -> SimState:
    s = cfg.max_slots
    zero_params = DeploymentParams(
        lam=jnp.zeros(s), mu=jnp.full((s,), 1.0), sig=jnp.zeros(s)
    )
    return SimState(
        alive=jnp.zeros(s, bool),
        cores=jnp.zeros(s, jnp.float32),
        params=zero_params,
        bel=belief_from_prior(cfg.priors, (s,)),
        core_hours=jnp.zeros(()),
        fail_requests=jnp.zeros(()),
        total_requests=jnp.zeros(()),
        arr_accepted=jnp.zeros(()),
        arr_rejected=jnp.zeros(()),
        slot_overflow=jnp.zeros(()),
    )


def _place_arrivals(state: SimState, accept, stream_t: ArrivalStream, cfg: SimConfig):
    """Place accepted arrivals into free slots (static unroll over A<=cap)."""
    alive, cores = state.alive, state.cores
    params, bel = state.params, state.bel
    overflow = state.slot_overflow
    for a in range(cfg.max_arrivals):
        free = jnp.argmin(alive)  # first False (0 if none free -> check)
        can = accept[a] & ~alive[free]
        overflow = overflow + jnp.where(accept[a] & alive[free], 1.0, 0.0)
        onehot = (jnp.arange(cfg.max_slots) == free) & can
        alive = alive | onehot
        cores = jnp.where(onehot, stream_t.c0[a], cores)
        params = jax.tree.map(
            lambda s_, n: jnp.where(onehot, n[a], s_), params, stream_t.params
        )
        bel = jax.tree.map(
            lambda s_, n: jnp.where(onehot, n[a], s_), bel, stream_t.bel
        )
    return state._replace(alive=alive, cores=cores, params=params, bel=bel,
                          slot_overflow=overflow)


def make_run(cfg: SimConfig, horizon_grid: jax.Array, policy_kind: int):
    """Build the jitted simulator for a fixed policy *kind* (threshold/rho stay
    traced so tuning does not re-jit). Returns run(key, policy) -> RunMetrics."""
    needs_moments = policy_kind != ZEROTH
    grid = horizon_grid
    n_grid = grid.shape[0] if needs_moments else 1
    if cfg.use_kernel:
        from ..kernels.moment_curves.ops import moment_curves_kernel

        def curves_fn(bel, cores, grid_, priors, d_points):
            flat_bel = jax.tree.map(lambda a: a.reshape(-1), bel)
            out = moment_curves_kernel(flat_bel, cores.reshape(-1), grid_,
                                       priors, d_points=d_points)
            shape = cores.shape + (grid_.shape[0],)
            return MomentCurves(out.EL.reshape(shape), out.VL.reshape(shape))
    else:
        curves_fn = moment_curves

    def step(policy: PolicyParams, state: SimState, xs):
        key, stream_t = xs
        k_ev = key
        alive_f = state.alive.astype(jnp.float32)

        # 1. deaths ---------------------------------------------------------
        ev = sample_step_events(k_ev, state.params, state.cores, cfg.priors, cfg.dt)
        deaths = jnp.minimum(ev.core_deaths.astype(jnp.float32), state.cores) * alive_f
        exposure = state.cores * cfg.dt * alive_f
        cores = state.cores - deaths
        cores = jnp.where(ev.spont_death & state.alive, 0.0, cores)
        alive = state.alive & (cores > 0.0)
        alive_f = alive.astype(jnp.float32)

        # 2. scale-outs (only deployments still alive request) ---------------
        req = ev.scaleout_cores.astype(jnp.float32) * alive_f
        n_req = ev.n_scaleouts.astype(jnp.float32) * alive_f
        util = jnp.sum(cores * alive_f)
        grant = (util + jnp.cumsum(req)) <= cfg.capacity
        cores = cores + jnp.where(grant, req, 0.0)
        failed = jnp.sum(jnp.where(~grant, n_req, 0.0))
        util = jnp.sum(cores * alive_f)

        # 3. belief updates (requests are observed whether or not granted) ---
        bel = update_on_events(
            state.bel,
            core_deaths=deaths,
            exposure_core_hours=exposure,
            n_scaleouts=n_req,
            scaleout_cores=req,
            alive_hours=cfg.dt * alive_f,
            priors=cfg.priors,
        )

        # 4. arrivals ---------------------------------------------------------
        valid = jnp.arange(cfg.max_arrivals) < stream_t.n_arrivals
        if needs_moments:
            slot_curves = curves_fn(bel, cores, grid, cfg.priors,
                                    d_points=cfg.d_points)
            agg_el = jnp.sum(slot_curves.EL * alive_f[:, None], axis=0)
            agg_vl = jnp.sum(slot_curves.VL * alive_f[:, None], axis=0)
            cand = curves_fn(stream_t.bel, stream_t.c0, grid, cfg.priors,
                             d_points=cfg.d_points)
            if cfg.prior_mode == MIX_UNLABELED:
                cand_alt = curves_fn(stream_t.bel_alt, stream_t.c0, grid,
                                     cfg.priors, d_points=cfg.d_points)
                stacked = MomentCurves(
                    EL=jnp.stack([cand.EL, cand_alt.EL]),
                    VL=jnp.stack([cand.VL, cand_alt.VL]),
                )
                cand = mixture_moments(jnp.asarray([0.5, 0.5]), stacked)
        else:
            agg_el = jnp.zeros((n_grid,))
            agg_vl = jnp.zeros((n_grid,))
            cand = MomentCurves(EL=jnp.zeros((cfg.max_arrivals, n_grid)),
                                VL=jnp.zeros((cfg.max_arrivals, n_grid)))

        res = admit_sequential(policy, agg_el, agg_vl, util, cand,
                               stream_t.c0, valid)
        state = state._replace(alive=alive, cores=cores, bel=bel)
        state = _place_arrivals(state, res.accept, stream_t, cfg)

        n_acc = jnp.sum(res.accept.astype(jnp.float32))
        n_rej = jnp.sum(valid.astype(jnp.float32)) - n_acc
        util_end = jnp.sum(state.cores * state.alive.astype(jnp.float32))
        state = state._replace(
            core_hours=state.core_hours + util_end * cfg.dt,
            fail_requests=state.fail_requests + failed,
            total_requests=state.total_requests + jnp.sum(n_req),
            arr_accepted=state.arr_accepted + n_acc,
            arr_rejected=state.arr_rejected + n_rej,
        )
        return state, (util_end, failed)

    @functools.partial(jax.jit, static_argnames=())
    def run(key: jax.Array, policy: PolicyParams,
            stream: Optional[ArrivalStream] = None) -> RunMetrics:
        k_stream, k_scan = jax.random.split(key)
        if stream is None:
            stream = draw_arrival_stream(k_stream, cfg)
        keys = jax.random.split(k_scan, cfg.n_steps)
        state0 = _init_state(cfg)
        state, (util_trace, fail_trace) = jax.lax.scan(
            functools.partial(step, policy), state0, (keys, stream)
        )
        return RunMetrics(
            utilization=state.core_hours / (cfg.horizon_hours * cfg.capacity),
            failure_rate=state.fail_requests / jnp.maximum(state.total_requests, 1.0),
            total_requests=state.total_requests,
            failed_requests=state.fail_requests,
            arrivals_accepted=state.arr_accepted,
            arrivals_rejected=state.arr_rejected,
            slot_overflow=state.slot_overflow,
            util_trace=util_trace,
            fail_trace=fail_trace,
        )

    return run


def run_batch(run_fn, key: jax.Array, policy: PolicyParams, n_runs: int) -> RunMetrics:
    """vmap a batch of independent runs."""
    keys = jax.random.split(key, n_runs)
    return jax.vmap(lambda k: run_fn(k, policy))(keys)
