"""Monte-Carlo cluster simulation substrate (paper §5 evaluation machinery).

Two simulator entry points share one step machinery: ``make_run`` (a single
cluster — the paper's §5 setting) and ``make_fleet_run`` (a fleet of
heterogeneous clusters with a routing layer ahead of per-cluster admission —
the paper's §2 provider view). Routers live in ``sim.routing``.
"""
from .simulator import (AGG_FUSED, AGG_KERNEL, AGG_REFERENCE, GLOBAL, PSEUDO,
                        MIX_LABELED, MIX_UNLABELED, AdmissionCore,
                        ArrivalSource, ArrivalStream, CoreState, FleetConfig,
                        FleetMetrics, PriorArrivalSource, RunMetrics,
                        SimConfig, SimState, StepOutcome, broadcast_policy,
                        draw_arrival_stream, make_admission_core, make_config,
                        make_fleet_config, make_fleet_run, make_run,
                        run_batch, run_keyed_batch, stream_config)
from .core import slot_mesh
from .routing import (ROUTERS, LeastUtilizedRouter, PowerOfTwoRouter,
                      RandomRouter, RouteContext, Router,
                      ThresholdCascadeRouter)
from .metrics import (CI, bca_ci, fleet_sla_failure_rate, fleet_utilization,
                      sla_failure_rate, weighted_mean)
from .importance import (ImportancePlan, TraceEnsemblePlan, badness_measure,
                         estimate_from_plan, make_importance_plan,
                         make_trace_ensemble_plan, rejection_q, simulate_plan,
                         simulate_trace_plan, stream_badness)
from ..obs.counters import TelemetryState, telemetry_summary

__all__ = [
    "AGG_FUSED", "AGG_KERNEL", "AGG_REFERENCE", "GLOBAL", "PSEUDO",
    "MIX_LABELED", "MIX_UNLABELED", "AdmissionCore", "ArrivalSource",
    "ArrivalStream", "CoreState", "FleetConfig", "FleetMetrics",
    "PriorArrivalSource", "RunMetrics", "SimConfig", "SimState",
    "StepOutcome", "broadcast_policy", "draw_arrival_stream",
    "make_admission_core", "make_config", "make_fleet_config",
    "make_fleet_run", "make_run",
    "run_batch", "run_keyed_batch", "slot_mesh", "stream_config",
    "ROUTERS", "LeastUtilizedRouter", "PowerOfTwoRouter", "RandomRouter",
    "RouteContext", "Router", "ThresholdCascadeRouter",
    "CI", "bca_ci", "fleet_sla_failure_rate", "fleet_utilization",
    "sla_failure_rate", "weighted_mean", "ImportancePlan",
    "TraceEnsemblePlan", "badness_measure", "estimate_from_plan",
    "make_importance_plan", "make_trace_ensemble_plan", "rejection_q",
    "simulate_plan", "simulate_trace_plan", "stream_badness",
    "TelemetryState", "telemetry_summary",
]
