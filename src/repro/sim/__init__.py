"""Monte-Carlo cluster simulation substrate (paper §5 evaluation machinery)."""
from .simulator import (AGG_FUSED, AGG_KERNEL, AGG_REFERENCE, GLOBAL, PSEUDO,
                        MIX_LABELED, MIX_UNLABELED, ArrivalSource,
                        ArrivalStream, PriorArrivalSource, RunMetrics,
                        SimConfig, draw_arrival_stream, make_config, make_run,
                        run_batch, run_keyed_batch)
from .metrics import CI, bca_ci, sla_failure_rate, weighted_mean
from .importance import (ImportancePlan, TraceEnsemblePlan, badness_measure,
                         estimate_from_plan, make_importance_plan,
                         make_trace_ensemble_plan, rejection_q, simulate_plan,
                         simulate_trace_plan, stream_badness)

__all__ = [
    "AGG_FUSED", "AGG_KERNEL", "AGG_REFERENCE", "GLOBAL", "PSEUDO",
    "MIX_LABELED", "MIX_UNLABELED", "ArrivalSource", "ArrivalStream",
    "PriorArrivalSource", "RunMetrics",
    "SimConfig", "draw_arrival_stream", "make_config", "make_run",
    "run_batch", "run_keyed_batch",
    "CI", "bca_ci", "sla_failure_rate", "weighted_mean", "ImportancePlan",
    "TraceEnsemblePlan", "badness_measure", "estimate_from_plan",
    "make_importance_plan", "make_trace_ensemble_plan", "rejection_q",
    "simulate_plan", "simulate_trace_plan", "stream_badness",
]
