"""The admission core: one reusable state + pure-function layer shared by the
offline simulators and the online serving engine.

The paper's provider "has to continuously decide" admission as workloads
arrive — the same decision machinery must therefore run both *offline*
(Monte-Carlo ``lax.scan`` over a pre-drawn horizon, ``sim.simulator``) and
*online* (a long-lived engine answering micro-batched admission requests,
``serve.admission``). This module is that shared layer:

  * ``CoreState`` — the complete admission state as one pytree: the slot
    table with per-deployment conjugate beliefs (``SimState``) plus the
    incrementally-maintained cluster-wide aggregate moment curves.
  * ``make_admission_core(cfg, grid, policy_kind)`` — closes over the static
    configuration and returns an ``AdmissionCore`` bundle of **pure**
    functions over ``CoreState``:

      - ``init()``                      fresh empty state
      - ``refresh_aggregates(cs)``      full aggregate recompute from slots
      - ``apply_events(key, cs)``       one ``dt``-hour step of deaths /
                                        scale-out grants / belief updates
      - ``candidates(stream_t)``        [A, N] candidate moment curves
      - ``decide_batch(policy, cs, …)`` sequential admission + slot
                                        placement + incremental fold

``sim.simulator.make_run`` / ``make_fleet_run`` are thin scan drivers over
these functions (the fleet vmaps them over a leading cluster axis), and the
online engine calls the same functions one step at a time — which is what
makes online/offline equivalence testable bit-for-bit rather than merely
plausible. Static configuration (``SimConfig``/``FleetConfig``), the
pre-drawn ``ArrivalStream`` and its pluggable ``ArrivalSource`` live here
too so both layers share one vocabulary.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.belief import (GammaBelief, apply_pseudo_observations,
                           belief_from_prior, observe_initial_size,
                           update_on_events)
from ..core.moments import (MomentCurves, aggregate_moment_curves,
                            masked_curve_reduction, moment_curves,
                            moment_curves_fused)
from ..core.policies import (ZEROTH, PolicyParams, admit_sequential,
                             admit_sequential_verbose)
from ..core.pricing import mixture_moments
from ..obs.counters import (TelemetryState, WindowStats, fold_decisions,
                            fold_window, init_telemetry, mark_refresh)
from ..core.processes import (DeploymentParams, PopulationPriors,
                              sample_params, sample_pseudo_observations,
                              sample_step_events)

GLOBAL, PSEUDO, MIX_LABELED, MIX_UNLABELED = "global", "pseudo", "labeled", "unlabeled"
AGG_FUSED, AGG_REFERENCE, AGG_KERNEL = "fused", "reference", "kernel"


class SimConfig(NamedTuple):
    """Static simulation configuration (python values; changing any re-jits)."""

    capacity: float = 2_000.0
    arrival_rate: float = 0.1        # deployments/hour (paper: 1.0 at c=20,000)
    horizon_hours: float = 365 * 24.0
    dt: float = 6.0                  # hours per step
    max_slots: int = 1024
    max_arrivals: int = 4            # cap per step (Poisson tail clipped)
    prior_mode: str = GLOBAL         # GLOBAL | PSEUDO | MIX_LABELED | MIX_UNLABELED
    n_pseudo_obs: int = 0            # paper §6: 0/1/5/50
    d_points: int = 24               # D-term checkpoint count
    use_kernel: bool = False         # Pallas moment_curves kernel (TPU path;
                                     # interpret-mode on CPU, so off by default)
    agg_backend: str = AGG_FUSED     # AGG_FUSED | AGG_REFERENCE | AGG_KERNEL:
                                     # how the cluster-wide aggregate curves
                                     # are computed each step (see make_run)
    agg_refresh_steps: int = 1       # full aggregate recompute every K steps;
                                     # between refreshes admitted candidates'
                                     # curves are folded in incrementally
                                     # (K=1: recompute every step)
    priors: PopulationPriors = None  # population priors; prefer make_config,
                                     # which defaults these to AZURE_PRIORS
    telemetry: bool = False          # carry the obs.counters.TelemetryState
                                     # rider through every step: decision
                                     # reason counters, occupancy/headroom/
                                     # staleness histograms, observables
                                     # sufficient statistics. False (the
                                     # default) compiles the rider out
                                     # entirely — decisions and metrics are
                                     # bit-identical either way

    @property
    def n_steps(self) -> int:
        return int(round(self.horizon_hours / self.dt))


def make_config(**overrides) -> SimConfig:
    """Documented SimConfig constructor: ``priors`` defaults to the fitted
    Azure priors instead of ``None`` and every field is validated eagerly, so
    a bad config fails here rather than deep inside ``belief_from_prior``."""
    if overrides.get("priors") is None:
        from ..core import AZURE_PRIORS

        overrides["priors"] = AZURE_PRIORS
    return _validate_config(SimConfig(**overrides))


def _validate_config(cfg: SimConfig) -> SimConfig:
    if cfg.priors is None:
        raise ValueError(
            "SimConfig.priors is None. Construct configs via "
            "repro.sim.make_config(...) (defaults to AZURE_PRIORS) or pass "
            "priors=<PopulationPriors> explicitly."
        )
    if cfg.prior_mode not in (GLOBAL, PSEUDO, MIX_LABELED, MIX_UNLABELED):
        raise ValueError(f"unknown prior_mode {cfg.prior_mode!r}")
    if cfg.agg_backend not in (AGG_FUSED, AGG_REFERENCE, AGG_KERNEL):
        raise ValueError(f"unknown agg_backend {cfg.agg_backend!r}")
    if cfg.n_pseudo_obs < 0:
        raise ValueError(f"n_pseudo_obs={cfg.n_pseudo_obs} must be >= 0")
    if cfg.prior_mode != GLOBAL and cfg.n_pseudo_obs == 0:
        raise ValueError(
            f"prior_mode={cfg.prior_mode!r} with n_pseudo_obs=0 silently "
            "degenerates to GLOBAL (zero pseudo observations leave every "
            "belief — including the §7 mixture components — at the "
            "population prior): use prior_mode=GLOBAL, or set "
            "n_pseudo_obs >= 1"
        )
    if cfg.n_steps <= 0 or cfg.max_slots <= 0 or cfg.max_arrivals <= 0:
        raise ValueError(
            f"degenerate SimConfig: n_steps={cfg.n_steps} "
            f"max_slots={cfg.max_slots} max_arrivals={cfg.max_arrivals}"
        )
    if cfg.agg_refresh_steps < 1 or cfg.n_steps % cfg.agg_refresh_steps:
        raise ValueError(
            f"agg_refresh_steps={cfg.agg_refresh_steps} must be >= 1 and "
            f"divide n_steps={cfg.n_steps}"
        )
    return cfg


class FleetConfig(NamedTuple):
    """Static fleet configuration: a per-cluster ``SimConfig`` template plus
    the per-cluster capacities.

    ``base`` describes each cluster's slot array, step size, information
    model, and aggregate-refresh blocking — *and* the fleet-wide arrival
    process (``arrival_rate``/``max_arrivals`` are the whole fleet's: one
    stream is drawn and routed, not one per cluster). ``base.capacity``
    conventionally holds the fleet total (``make_fleet_config`` sets it);
    the authoritative per-cluster capacities are ``capacities``.
    """

    base: SimConfig
    capacities: tuple                # per-cluster core capacities (static)

    @property
    def n_clusters(self) -> int:
        return len(self.capacities)

    @property
    def total_capacity(self) -> float:
        return float(sum(self.capacities))


def make_fleet_config(capacities, **base_overrides) -> FleetConfig:
    """Documented FleetConfig constructor: ``base_overrides`` build the
    per-cluster template through ``make_config`` (so priors default to
    AZURE_PRIORS and every field is validated); ``base.capacity`` defaults
    to the fleet total."""
    caps = tuple(float(c) for c in capacities)
    base_overrides.setdefault("capacity", sum(caps))
    return _validate_fleet_config(
        FleetConfig(base=make_config(**base_overrides), capacities=caps))


def _validate_fleet_config(fcfg: FleetConfig) -> FleetConfig:
    if not fcfg.capacities:
        raise ValueError("FleetConfig.capacities is empty")
    if any(not np.isfinite(c) or c <= 0.0 for c in fcfg.capacities):
        raise ValueError(
            f"FleetConfig.capacities must be positive, got {fcfg.capacities}")
    _validate_config(fcfg.base)
    return fcfg


def stream_config(cfg) -> SimConfig:
    """The ``SimConfig`` governing arrival-stream layout and priors.

    Identity for a plain ``SimConfig``; for a ``FleetConfig`` it is the base
    template with the fleet-total capacity — fleet arrivals are drawn (or
    replayed) fleet-wide and only routed to clusters at simulation time, so
    everything stream-shaped (``draw_arrival_stream``, trace replay, badness
    measures) works on this reduced config.
    """
    if isinstance(cfg, FleetConfig):
        return cfg.base._replace(capacity=cfg.total_capacity)
    return cfg


class ArrivalStream(NamedTuple):
    """Pre-drawn per-(step, arrival-slot) quantities. Leading dims [T, A]."""

    params: DeploymentParams         # true parameters of the arriving deployment
    c0: jax.Array                    # initial request size
    bel: GammaBelief                 # provider's prior belief for the arrival
    bel_alt: GammaBelief             # second mixture component (unlabeled mode)
    n_arrivals: jax.Array            # [T] arrivals per step (already capped)


class ArrivalSource:
    """Pluggable producer of the pre-drawn ``ArrivalStream``.

    ``make_run`` consumes arrivals exclusively through this interface: the
    scan body, policies, and importance sampling only ever see the stream,
    so any source that returns correctly-shaped ``[n_steps, max_arrivals]``
    fields plugs in without touching the simulator. Two backends ship:
    ``PriorArrivalSource`` (sample the population priors — the seed
    behavior) and ``traces.replay.TraceArrivalSource`` (replay a recorded
    ``WorkloadTrace``). ``stream`` is called inside the jitted run, so it
    must be traceable; closed-over trace arrays become constants.
    """

    def stream(self, key: jax.Array, cfg: SimConfig) -> "ArrivalStream":
        raise NotImplementedError


class PriorArrivalSource(ArrivalSource):
    """Draw every arrival from the population priors (paper §5 default)."""

    def stream(self, key: jax.Array, cfg: SimConfig) -> "ArrivalStream":
        return draw_arrival_stream(key, cfg)


def draw_arrival_stream(key: jax.Array, cfg: SimConfig) -> ArrivalStream:
    """Pre-draw every arrival's true params, request size and prior belief."""
    cfg = stream_config(cfg)
    t_steps, a_max = cfg.n_steps, cfg.max_arrivals
    shape = (t_steps, a_max)
    kn, kp, kc, ko, kq, kb = jax.random.split(key, 6)
    n_arr = jnp.minimum(
        jax.random.poisson(kn, cfg.arrival_rate * cfg.dt, (t_steps,)), a_max
    )
    params = sample_params(kp, cfg.priors, shape)
    c0 = (1 + jax.random.poisson(kc, params.sig)).astype(jnp.float32)

    prior = belief_from_prior(cfg.priors, shape)
    if cfg.prior_mode == GLOBAL:
        bel = prior
        bel_alt = bel
    elif cfg.prior_mode == PSEUDO:
        obs = sample_pseudo_observations(ko, params, cfg.priors, cfg.n_pseudo_obs)
        bel = apply_pseudo_observations(prior, obs, cfg.priors)
        bel_alt = bel
    else:
        # §7: the user has two types; the submitted deployment is the drawn
        # ``params``; the alternative type is an independent draw. The provider
        # holds n_pseudo_obs observations of each type.
        alt = sample_params(kq, cfg.priors, shape)
        k1, k2 = jax.random.split(kb)
        obs = sample_pseudo_observations(k1, params, cfg.priors, cfg.n_pseudo_obs)
        obs_alt = sample_pseudo_observations(k2, alt, cfg.priors, cfg.n_pseudo_obs)
        bel = apply_pseudo_observations(prior, obs, cfg.priors)
        bel_alt = apply_pseudo_observations(prior, obs_alt, cfg.priors)
    bel = observe_initial_size(bel, c0)
    return ArrivalStream(params=params, c0=c0, bel=bel, bel_alt=bel_alt,
                         n_arrivals=n_arr)


class SimState(NamedTuple):
    """Slot table (fixed-capacity deployment array + conjugate beliefs) plus
    the run-level metric accumulators."""

    alive: jax.Array              # [S] bool
    cores: jax.Array              # [S] float32
    params: DeploymentParams      # [S]
    bel: GammaBelief              # [S]
    core_hours: jax.Array
    fail_requests: jax.Array
    total_requests: jax.Array
    arr_accepted: jax.Array
    arr_rejected: jax.Array
    slot_overflow: jax.Array
    n_departed: jax.Array


class CoreState(NamedTuple):
    """The complete admission state: slot table + beliefs (``slots``) and the
    incrementally-maintained cluster-wide aggregate moment curves. One
    pytree, so a long-lived engine can keep it device-resident and donate it
    through every jitted step (the fleet gives every leaf a leading ``[C]``
    cluster axis).

    ``tel`` is the optional telemetry rider (``obs.counters.TelemetryState``):
    ``None`` — an empty pytree node, adding no buffers to the compiled
    programs — unless ``SimConfig(telemetry=True)``."""

    slots: SimState
    agg_el: jax.Array             # [N] aggregate E[L_n] over admitted slots
    agg_vl: jax.Array             # [N] aggregate V[L_n]
    tel: Optional["TelemetryState"] = None


class StepOutcome(NamedTuple):
    """Per-step dynamics summary from ``apply_events`` (metric inputs)."""

    util: jax.Array               # active cores after deaths + grants
    failed: jax.Array             # scale-out requests that did not fit
    n_requests: jax.Array         # total scale-out requests this step
    departed: jax.Array           # deployments that died this step


def _init_state(cfg: SimConfig) -> SimState:
    s = cfg.max_slots
    # explicit dtype => strong-typed f32: the online engine re-feeds this
    # state through jit, and a weak-typed leaf would flip to strong on the
    # first slot placement and force a full recompile of every step fn
    zero_params = DeploymentParams(
        lam=jnp.zeros(s), mu=jnp.full((s,), 1.0, jnp.float32),
        sig=jnp.zeros(s)
    )
    return SimState(
        alive=jnp.zeros(s, bool),
        cores=jnp.zeros(s, jnp.float32),
        params=zero_params,
        bel=belief_from_prior(cfg.priors, (s,)),
        core_hours=jnp.zeros(()),
        fail_requests=jnp.zeros(()),
        total_requests=jnp.zeros(()),
        arr_accepted=jnp.zeros(()),
        arr_rejected=jnp.zeros(()),
        slot_overflow=jnp.zeros(()),
        n_departed=jnp.zeros(()),
    )


def _place_arrivals(state: SimState, accept, stream_t: ArrivalStream, cfg: SimConfig):
    """Place accepted arrivals into free slots, one vectorized pass.

    The i-th accepted arrival goes to the i-th free slot (in slot order) —
    identical semantics to the previous sequential argmin unroll, but a single
    [A, S] rank-match instead of A passes over the slot array. Accepted
    arrivals beyond the number of free slots are counted as slot overflow.

    Returns (state, placed_arrival [A]) — the mask of accepted arrivals that
    actually landed in a slot, so the caller folds only *real* deployments
    into the maintained aggregate (overflowed arrivals must not haunt it).
    """
    alive = state.alive
    free = ~alive
    rank = jnp.cumsum(free.astype(jnp.int32))          # free-slot rank, 1-based
    acc = accept.astype(jnp.int32)
    ordinal = jnp.cumsum(acc) * acc                    # i-th accepted, 1-based
    n_free = rank[-1]
    placed_arrival = accept & (ordinal <= n_free)      # [A]
    overflow = state.slot_overflow + jnp.sum(
        jnp.where(accept & ~placed_arrival, 1.0, 0.0))

    hit = free[None, :] & (rank[None, :] == ordinal[:, None]) & accept[:, None]
    placed = jnp.any(hit, axis=0)                      # [S]

    def merge(old, new_a):
        upd = hit.astype(old.dtype).T @ new_a
        return jnp.where(placed, upd, old)

    cores = merge(state.cores, stream_t.c0)
    params = jax.tree.map(lambda o, n: merge(o, n), state.params,
                          stream_t.params)
    bel = jax.tree.map(lambda o, n: merge(o, n), state.bel, stream_t.bel)
    state = state._replace(alive=alive | placed, cores=cores, params=params,
                           bel=bel, slot_overflow=overflow)
    return state, placed_arrival


def _make_aggregate_fn(cfg: SimConfig, grid: jax.Array):
    """Cluster-wide sum-over-alive-slots curve evaluator, by backend.

    AGG_REFERENCE is the seed per-slot path (materialize [S, N], mask, sum) —
    kept as the oracle the fast paths are equivalence-tested against.
    AGG_FUSED reduces block-by-block without the [S, N] intermediate;
    AGG_KERNEL is the Pallas aggregated-output kernel (interpret-mode on CPU).
    """
    if cfg.agg_backend == AGG_REFERENCE:

        def aggregate(bel, cores, alive):
            curves = moment_curves(bel, cores, grid, cfg.priors,
                                   d_points=cfg.d_points)
            alive_f = alive.astype(jnp.float32)
            return (jnp.sum(curves.EL * alive_f[:, None], axis=0),
                    jnp.sum(curves.VL * alive_f[:, None], axis=0))
    elif cfg.agg_backend == AGG_KERNEL:
        from ..kernels.moment_curves.ops import aggregate_moment_curves_kernel

        def aggregate(bel, cores, alive):
            out = aggregate_moment_curves_kernel(
                bel, cores, alive, grid, cfg.priors, d_points=cfg.d_points)
            return out.EL, out.VL
    else:

        def aggregate(bel, cores, alive):
            out = aggregate_moment_curves(bel, cores, alive, grid, cfg.priors,
                                          d_points=cfg.d_points)
            return out.EL, out.VL

    return aggregate


def _make_curves_fn(cfg: SimConfig):
    """Per-candidate moment-curve evaluator (fused jnp or Pallas kernel)."""
    if cfg.use_kernel:
        from ..kernels.moment_curves.ops import moment_curves_kernel

        def curves_fn(bel, cores, grid_, priors, d_points):
            flat_bel = jax.tree.map(lambda a: a.reshape(-1), bel)
            out = moment_curves_kernel(flat_bel, cores.reshape(-1), grid_,
                                       priors, d_points=d_points)
            shape = cores.shape + (grid_.shape[0],)
            return MomentCurves(out.EL.reshape(shape), out.VL.reshape(shape))

        return curves_fn
    return moment_curves_fused


def _make_candidates_fn(cfg: SimConfig, grid: jax.Array, needs_moments: bool,
                        n_grid: int, curves_fn):
    """[A, N] candidate curves for one step's pre-drawn arrivals (mixture
    moments in the §7 unlabeled mode; zeros when the policy ignores them)."""

    def candidates(stream_t: ArrivalStream) -> MomentCurves:
        if not needs_moments:
            return MomentCurves(EL=jnp.zeros((stream_t.c0.shape[0], n_grid)),
                                VL=jnp.zeros((stream_t.c0.shape[0], n_grid)))
        cand = curves_fn(stream_t.bel, stream_t.c0, grid, cfg.priors,
                         d_points=cfg.d_points)
        if cfg.prior_mode == MIX_UNLABELED:
            cand_alt = curves_fn(stream_t.bel_alt, stream_t.c0, grid,
                                 cfg.priors, d_points=cfg.d_points)
            stacked = MomentCurves(
                EL=jnp.stack([cand.EL, cand_alt.EL]),
                VL=jnp.stack([cand.VL, cand_alt.VL]),
            )
            cand = mixture_moments(jnp.asarray([0.5, 0.5]), stacked)
        return cand

    return candidates


def _step_dynamics(cfg: SimConfig, capacity, key, state: SimState,
                   with_stats: bool = False):
    """Steps 1–3 of one ``dt``-hour step for ONE cluster: deaths, scale-out
    grants against ``capacity`` (a traced value — the fleet passes each
    cluster's own), and conjugate belief updates.

    Returns ``(state, util, failed, n_req_total, departed, stats)`` with the
    slot arrays updated and the metric counters untouched (the caller
    accumulates them after admission). ``stats`` is the window's observable
    sufficient statistics (``WindowStats``) when ``with_stats`` — the
    telemetry rider's drift-detector stream — else ``None``.
    """
    alive_f = state.alive.astype(jnp.float32)

    # 1. deaths ---------------------------------------------------------
    ev = sample_step_events(key, state.params, state.cores, cfg.priors,
                            cfg.dt, alive=state.alive)
    deaths = jnp.minimum(ev.core_deaths.astype(jnp.float32), state.cores) * alive_f
    exposure = state.cores * cfg.dt * alive_f
    cores = state.cores - deaths
    cores = jnp.where(ev.spont_death & state.alive, 0.0, cores)
    alive = state.alive & (cores > 0.0)
    departed = jnp.sum((state.alive & ~alive).astype(jnp.float32))
    spont = jnp.sum((ev.spont_death & state.alive).astype(jnp.float32))
    alive_f = alive.astype(jnp.float32)

    # 2. scale-outs (only deployments still alive request) ---------------
    req = ev.scaleout_cores.astype(jnp.float32) * alive_f
    n_req = ev.n_scaleouts.astype(jnp.float32) * alive_f
    util = jnp.sum(cores * alive_f)
    grant = (util + jnp.cumsum(req)) <= capacity
    cores = cores + jnp.where(grant, req, 0.0)
    failed = jnp.sum(jnp.where(~grant, n_req, 0.0))
    util = jnp.sum(cores * alive_f)

    # 3. belief updates (requests are observed whether or not granted) ---
    bel = update_on_events(
        state.bel,
        core_deaths=deaths,
        exposure_core_hours=exposure,
        n_scaleouts=n_req,
        scaleout_cores=req,
        alive_hours=cfg.dt * alive_f,
        priors=cfg.priors,
    )
    state = state._replace(alive=alive, cores=cores, bel=bel)
    stats = None
    if with_stats:
        stats = WindowStats(
            core_deaths=jnp.sum(deaths),
            exposure_core_hours=jnp.sum(exposure),
            n_scaleouts=jnp.sum(n_req),
            scaleout_cores=jnp.sum(req),
            alive_hours=cfg.dt * jnp.sum(alive_f),
            spont_deaths=spont,
            departed=departed,
        )
    return state, util, failed, jnp.sum(n_req), departed, stats


def slot_mesh(n_shards: int, devices=None):
    """A 1-d device mesh named ``"slots"`` over the first ``n_shards``
    devices — the mesh ``make_admission_core(..., mesh=...)`` shards the
    slot axis of ``CoreState`` over. Raises with guidance when the process
    has too few devices (CPU runs get more via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    from jax.sharding import Mesh

    devices = list(jax.devices()) if devices is None else list(devices)
    if n_shards < 1:
        raise ValueError(f"n_shards={n_shards} must be >= 1")
    if n_shards > len(devices):
        raise ValueError(
            f"n_shards={n_shards} exceeds the {len(devices)} visible "
            "device(s); on CPU, export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_shards}")
    return Mesh(np.asarray(devices[:n_shards]), ("slots",))


class AdmissionCore(NamedTuple):
    """Bundle of pure functions over ``CoreState`` for one static
    configuration (see module docstring). Built by ``make_admission_core``;
    every field closing over ``cfg``/``grid``/``policy_kind`` so callers jit,
    vmap, or scan them freely."""

    cfg: SimConfig
    grid: jax.Array
    policy_kind: int
    needs_moments: bool
    n_grid: int
    init: Callable[[], CoreState]
    refresh_aggregates: Callable[[CoreState], CoreState]
    apply_events: Callable[..., tuple]
    candidates: Callable[[ArrivalStream], MomentCurves]
    decide_batch: Callable[..., tuple]
    decide_batch_traced: Callable[..., tuple]


def make_admission_core(cfg: SimConfig, grid: jax.Array,
                        policy_kind: int, *, mesh=None) -> AdmissionCore:
    """Build the pure admission-core function bundle for one configuration.

    All five functions are pure pytree -> pytree maps (no python state), so
    the offline drivers scan them, the fleet vmaps them over the cluster
    axis, and the online engine jits them individually with donated
    ``CoreState`` buffers — one implementation, three execution regimes.

    ``mesh`` (optional, a 1-d ``jax.sharding.Mesh`` — see ``slot_mesh``)
    selects the **device-sharded lane**: ``CoreState``'s slot axis is
    partitioned over the mesh so one engine's state scales with device
    count, and ``refresh_aggregates`` evaluates each shard's per-slot moment
    curves locally before reducing them in the unsharded path's exact block
    order — decisions and metrics stay bit-for-bit identical to the
    single-device core (see ``_shard_over_slots``). ``mesh=None`` (the
    default) is exactly the historical single-device core.
    """
    _validate_config(cfg)
    needs_moments = policy_kind != ZEROTH
    n_grid = grid.shape[0] if needs_moments else 1
    curves_fn = _make_curves_fn(cfg)
    aggregate_fn = _make_aggregate_fn(cfg, grid)
    candidates_fn = _make_candidates_fn(cfg, grid, needs_moments, n_grid,
                                        curves_fn)

    def init() -> CoreState:
        return CoreState(slots=_init_state(cfg),
                         agg_el=jnp.zeros((n_grid,)),
                         agg_vl=jnp.zeros((n_grid,)),
                         tel=init_telemetry() if cfg.telemetry else None)

    def refresh_aggregates(cs: CoreState) -> CoreState:
        """Full aggregate recompute from the slot table (block boundary).
        Zeroth-moment policies never read the curves, so their refresh
        keeps the zero placeholder instead of paying for the reduction.
        With telemetry the rider's staleness clock returns to zero."""
        tel = mark_refresh(cs.tel) if cfg.telemetry else cs.tel
        if not needs_moments:
            return cs._replace(agg_el=jnp.zeros((n_grid,)),
                               agg_vl=jnp.zeros((n_grid,)), tel=tel)
        agg_el, agg_vl = aggregate_fn(cs.slots.bel, cs.slots.cores,
                                      cs.slots.alive)
        return cs._replace(agg_el=agg_el, agg_vl=agg_vl, tel=tel)

    def apply_events(key: jax.Array, cs: CoreState, capacity=None):
        """One ``dt``-hour step of cluster dynamics: deaths, scale-out
        grants against ``capacity`` (defaults to the config's own; the
        fleet passes each cluster's), and conjugate belief updates. The
        maintained aggregate is NOT touched — within-block staleness is the
        ``agg_refresh_steps`` contract. With telemetry the rider folds the
        window's occupancy and observable sufficient statistics."""
        cap = cfg.capacity if capacity is None else capacity
        slots, util, failed, n_req, departed, stats = _step_dynamics(
            cfg, cap, key, cs.slots, with_stats=cfg.telemetry)
        tel = cs.tel
        if cfg.telemetry:
            tel = fold_window(tel, util, cap, stats)
        return cs._replace(slots=slots, tel=tel), StepOutcome(
            util=util, failed=failed, n_requests=n_req, departed=departed)

    def _decide_core(policy: PolicyParams, cs: CoreState, util,
                     cand: MomentCurves, stream_t: ArrivalStream, valid,
                     verbose: bool):
        if verbose or cfg.telemetry:
            res, diag = admit_sequential_verbose(
                policy, cs.agg_el, cs.agg_vl, util, cand, stream_t.c0, valid)
        else:
            res = admit_sequential(policy, cs.agg_el, cs.agg_vl, util, cand,
                                   stream_t.c0, valid)
            diag = None
        slots, placed_arrival = _place_arrivals(cs.slots, res.accept,
                                                stream_t, cfg)
        placed_f = placed_arrival.astype(jnp.float32)
        agg_el = cs.agg_el + jnp.einsum("an,a->n", cand.EL, placed_f)
        agg_vl = cs.agg_vl + jnp.einsum("an,a->n", cand.VL, placed_f)
        tel = cs.tel
        if cfg.telemetry:
            tel = fold_decisions(tel, res.accept, valid, diag.fits,
                                 placed_arrival, stream_t.c0)
        return CoreState(slots=slots, agg_el=agg_el, agg_vl=agg_vl,
                         tel=tel), res.accept, diag

    def decide_batch(policy: PolicyParams, cs: CoreState, util,
                     cand: MomentCurves, stream_t: ArrivalStream, valid):
        """Greedy first-come-first-served admission of a candidate batch
        against the maintained aggregate (sequential, paper Assumption 3),
        slot placement, and the incremental aggregate fold of *placed*
        arrivals — accepted-but-overflowed ones never became deployments,
        so they must not haunt the carried aggregate. Returns
        (cs, accept [A]). With telemetry the rider folds the batch's reason
        counters and the admitted-arrival stream moments."""
        cs, accept, _ = _decide_core(policy, cs, util, cand, stream_t, valid,
                                     verbose=False)
        return cs, accept

    def decide_batch_traced(policy: PolicyParams, cs: CoreState, util,
                            cand: MomentCurves, stream_t: ArrivalStream,
                            valid):
        """``decide_batch`` + the per-candidate ``DecisionDiag`` (``[A]``:
        fit flag, policy score, bound) for decision tracing. Returns
        (cs, accept, diag); decisions identical to ``decide_batch``."""
        return _decide_core(policy, cs, util, cand, stream_t, valid,
                            verbose=True)

    core = AdmissionCore(cfg=cfg, grid=grid, policy_kind=policy_kind,
                         needs_moments=needs_moments, n_grid=n_grid,
                         init=init, refresh_aggregates=refresh_aggregates,
                         apply_events=apply_events, candidates=candidates_fn,
                         decide_batch=decide_batch,
                         decide_batch_traced=decide_batch_traced)
    if mesh is None:
        return core
    return _shard_over_slots(core, mesh)


def _shard_over_slots(core: AdmissionCore, mesh) -> AdmissionCore:
    """Wrap an ``AdmissionCore`` so ``CoreState``'s slot axis is sharded
    over ``mesh`` (one named axis), keeping decisions and metrics
    **bit-for-bit identical** to the unsharded core.

    What is sharded vs replicated, and why equality holds exactly:

      * The slot table and per-deployment beliefs (every ``[S]`` leaf of
        ``SimState``) live partitioned, ``S / n_shards`` slots per device —
        the state whose size the ROADMAP wants to scale with device count.
      * ``refresh_aggregates`` — the engine's dominant O(S·N) cost —
        evaluates each shard's per-slot moment curves locally, all-gathers
        the (elementwise, hence bitwise-identical) ``[S, N]`` curve values,
        and reduces them via ``masked_curve_reduction``, which replays the
        unsharded fused path's exact einsum/block-fold order. A per-shard
        partial-sum + tree-reduce would NOT be bitwise equal (float sums
        are order-sensitive); gathering the curves and reducing in the
        canonical order is what buys exact equality.
      * Per-step dynamics and admission (O(S) / O(A·N) — cheap next to the
        refresh) run replicated on the gathered slot table and re-slice the
        updated ``[S]`` leaves back to the local shard: every device runs
        the same ops on the same data (including the step's random event
        draws from the replicated key, which keeps global-shape threefry
        semantics), so the replicated outputs are identical by
        construction. ``check_vma`` stays off accordingly.
      * Scalar accumulators, aggregate curves, the telemetry rider, policy
        parameters and arrival batches are replicated (``P()``).

    Donation still works: the engine's ``jit(..., donate_argnums=...)``
    wraps these shard_mapped functions and the sharded-in/sharded-out
    specs let XLA reuse the slot-table buffers in place.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..compat import shard_map

    cfg, grid = core.cfg, core.grid
    if len(mesh.axis_names) != 1:
        raise ValueError(f"mesh must have exactly one axis, got "
                         f"{mesh.axis_names}")
    ax = mesh.axis_names[0]
    n_shards = int(mesh.devices.size)
    if cfg.max_slots % n_shards:
        raise ValueError(
            f"max_slots={cfg.max_slots} must be divisible by the "
            f"{n_shards}-device mesh")
    if cfg.agg_backend != AGG_FUSED:
        raise ValueError(
            f"sharded admission core requires agg_backend={AGG_FUSED!r} "
            f"(got {cfg.agg_backend!r}): the sharded refresh mirrors the "
            "fused block reduction bit-for-bit")
    s_local = cfg.max_slots // n_shards

    rep = lambda tree: jax.tree.map(lambda _: P(), tree)
    cs_t = jax.eval_shape(core.init)
    cs_specs = CoreState(
        slots=cs_t.slots._replace(
            alive=P(ax), cores=P(ax),
            params=jax.tree.map(lambda _: P(ax), cs_t.slots.params),
            bel=jax.tree.map(lambda _: P(ax), cs_t.slots.bel),
            core_hours=P(), fail_requests=P(), total_requests=P(),
            arr_accepted=P(), arr_rejected=P(), slot_overflow=P(),
            n_departed=P()),
        agg_el=P(), agg_vl=P(),
        tel=rep(cs_t.tel) if cs_t.tel is not None else None)

    gather = lambda x: jax.lax.all_gather(x, ax, axis=0, tiled=True)

    def gather_slots(slots: SimState) -> SimState:
        return jax.tree.map(lambda x: gather(x) if x.ndim else x, slots)

    def slice_slots(slots: SimState) -> SimState:
        i = jax.lax.axis_index(ax)
        loc = lambda x: jax.lax.dynamic_slice_in_dim(x, i * s_local,
                                                     s_local, axis=0)
        return jax.tree.map(lambda x: loc(x) if x.ndim else x, slots)

    def sharded_init() -> CoreState:
        cs = core.init()
        shardings = jax.tree.map(lambda spec: NamedSharding(mesh, spec),
                                 cs_specs,
                                 is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(cs, shardings)

    def _local_refresh(cs: CoreState) -> CoreState:
        tel = mark_refresh(cs.tel) if cfg.telemetry else cs.tel
        if not core.needs_moments:
            return cs._replace(agg_el=jnp.zeros((core.n_grid,)),
                               agg_vl=jnp.zeros((core.n_grid,)), tel=tel)
        # the O(S*N) per-slot curve math runs on the local shard only; the
        # gathered curves are then reduced in the canonical block order
        cur = moment_curves_fused(cs.slots.bel, cs.slots.cores, grid,
                                  cfg.priors, d_points=cfg.d_points)
        mask = cs.slots.alive.astype(grid.dtype)
        agg = masked_curve_reduction(jax.tree.map(gather, cur), gather(mask))
        return cs._replace(agg_el=agg.EL, agg_vl=agg.VL, tel=tel)

    sm_refresh = shard_map(_local_refresh, mesh=mesh, in_specs=(cs_specs,),
                           out_specs=cs_specs, check_vma=False)

    def _local_apply(key, cs: CoreState, capacity):
        full, out = core.apply_events(
            key, cs._replace(slots=gather_slots(cs.slots)), capacity)
        return full._replace(slots=slice_slots(full.slots)), out

    sm_apply = shard_map(
        _local_apply, mesh=mesh, in_specs=(P(), cs_specs, P()),
        out_specs=(cs_specs, P()), check_vma=False)

    def sharded_apply(key, cs: CoreState, capacity=None):
        cap = jnp.asarray(cfg.capacity if capacity is None else capacity,
                          jnp.float32)
        return sm_apply(key, cs, cap)

    def _local_decide(policy, cs, util, cand, stream_t, valid):
        full, accept = core.decide_batch(
            policy, cs._replace(slots=gather_slots(cs.slots)), util, cand,
            stream_t, valid)
        return full._replace(slots=slice_slots(full.slots)), accept

    sm_decide = shard_map(
        _local_decide, mesh=mesh,
        in_specs=(P(), cs_specs, P(), P(), P(), P()),
        out_specs=(cs_specs, P()), check_vma=False)

    def _local_decide_traced(policy, cs, util, cand, stream_t, valid):
        full, accept, diag = core.decide_batch_traced(
            policy, cs._replace(slots=gather_slots(cs.slots)), util, cand,
            stream_t, valid)
        return full._replace(slots=slice_slots(full.slots)), accept, diag

    sm_decide_traced = shard_map(
        _local_decide_traced, mesh=mesh,
        in_specs=(P(), cs_specs, P(), P(), P(), P()),
        out_specs=(cs_specs, P(), P()), check_vma=False)

    return core._replace(init=sharded_init, refresh_aggregates=sm_refresh,
                         apply_events=sharded_apply, decide_batch=sm_decide,
                         decide_batch_traced=sm_decide_traced)
