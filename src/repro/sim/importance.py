"""Importance sampling for tail (SLA) estimation — paper Appendix D.

SLA failures are concentrated in a small fraction of "bad" runs (runs whose
early arrivals include too many large, long-lived deployments). Appendix D
defines a cheap *badness measure* BM(r) computed from the pre-drawn arrival
stream alone (Def. 5), buckets runs by BM, and oversamples bad buckets.

We implement:
  * ``badness_measure`` — Def. 5: per-deployment 99% Cantelli upper bound
    i^x = E[L] + sqrt(0.99/0.01 * V[L]) from *point-mass* beliefs at the true
    parameters (the simplified sim "knows each deployment's exact type"),
    a monthly arrival/death schedule, greedy admission below 1.1*capacity,
    and BM = max over months of the admitted i^x mass.
  * ``rejection_q`` — the importance distribution q(I_i) of the paper's
    bucket-rejection scheme (Prop. 6), kept for fidelity and unit-tested for
    normalization.
  * ``make_importance_plan`` — the estimator we actually run: stratified
    allocation over the same buckets (probe many cheap BM values, estimate
    p(I_i), then fill per-bucket quotas and weight runs by p_i/n_i). This is
    the textbook-equivalent of the paper's rejection scheme in expectation
    and is deterministic in the number of expensive simulations.
  * ``simulate_plan`` / ``estimate_from_plan`` — run every selected key
    through the device-sharded ``run_keyed_batch`` (no serial per-run loop
    in callers) and combine the metrics with the stratified weights.
  * ``stream_badness`` / ``make_trace_ensemble_plan`` /
    ``simulate_trace_plan`` — the trace-replay analogue: replay is
    arrival-deterministic per trace, so the BM bucketing moves from run
    keys to *traces*; an ensemble of replay streams is probed in one
    vmapped pass, bad traces are oversampled, and (trace, run-key) pairs
    route through the same sharded batch (keys + streams sharded together).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.belief import GammaBelief
from ..core.moments import moment_curves_fused
from ..obs.log import get_logger
from .metrics import sla_failure_rate, weighted_mean
from .simulator import (ArrivalSource, ArrivalStream, RunMetrics, SimConfig,
                        draw_arrival_stream, run_keyed_batch,
                        shard_batch_over_devices, stream_config)

log = get_logger(__name__)

HOURS_PER_MONTH = 730.0


def _point_mass(params, k=1e6) -> GammaBelief:
    return GammaBelief(
        mu_a=params.mu * k, mu_b=jnp.full_like(params.mu, k),
        lam_a=params.lam * k, lam_b=jnp.full_like(params.lam, k),
        sig_a=params.sig * k, sig_b=jnp.full_like(params.sig, k),
    )


def badness_measure(key: jax.Array, cfg: SimConfig, grid: jax.Array,
                    source: Optional[ArrivalSource] = None) -> jax.Array:
    """BM(r) for the run whose arrival stream is drawn from ``key`` (Def. 5).

    Splits ``key`` exactly like ``simulator.make_run``'s run() so the BM
    describes the same arrival stream the expensive simulation will see.
    ``source`` selects the arrival backend (default: prior sampling); with a
    *single* trace-replay source the stream — and therefore BM — is
    key-independent, so stratification degenerates to a single bucket. An
    arrival-side tail then only exists *across* traces: bucket a trace
    ensemble instead via ``make_trace_ensemble_plan``/``stream_badness``.
    """
    cfg = stream_config(cfg)
    k_stream, k_scan = jax.random.split(key)
    k_life = jax.random.fold_in(k_scan, 99)
    stream = (draw_arrival_stream(k_stream, cfg) if source is None
              else source.stream(k_stream, cfg))
    return stream_badness(k_life, stream, cfg, grid)


def stream_badness(k_life: jax.Array, stream: ArrivalStream, cfg: SimConfig,
                   grid: jax.Array) -> jax.Array:
    """Def.-5 badness of a *given* pre-drawn arrival stream.

    ``k_life`` draws only the simplified schedule's max-lifetime clocks; the
    arrival side (who arrives when, how large, with what true parameters) is
    entirely the stream's. This is the primitive trace-level bucketing
    builds on: replay streams are arrival-deterministic per trace, so BM
    computed here ranks *traces*, not run keys.

    ``cfg`` may be a ``FleetConfig``: the badness measure **reduces over
    clusters** — the simplified greedy schedule admits against the fleet's
    *total* capacity (``stream_config``), because BM describes the
    arrival-side tail of the whole pre-drawn stream, before any routing.
    Importance plans built on it therefore bucket fleet runs exactly like
    single-cluster runs of the same total capacity.
    """
    cfg = stream_config(cfg)
    t_steps, a_max = stream.c0.shape
    n_dep = t_steps * a_max

    params = jax.tree.map(lambda x: x.reshape(-1), stream.params)
    c0 = stream.c0.reshape(-1)
    # only arrivals that actually occur participate
    occurs = (jnp.arange(a_max)[None, :] < stream.n_arrivals[:, None]).reshape(-1)

    curves = moment_curves_fused(_point_mass(params), c0, grid, cfg.priors,
                                 d_points=8)
    i_x = jnp.max(curves.EL + jnp.sqrt(99.0 * curves.VL), axis=-1)
    i_x = jnp.where(occurs, i_x, 0.0)

    arr_hours = (
        jnp.repeat(jnp.arange(t_steps, dtype=jnp.float32) * cfg.dt, a_max)
    )
    maxlife = jax.random.exponential(k_life, (n_dep,)) / (
        cfg.priors.delta * params.mu
    )
    n_months = int(np.ceil(cfg.horizon_hours / HOURS_PER_MONTH))
    m_arr = jnp.floor(arr_hours / HOURS_PER_MONTH).astype(jnp.int32)
    m_die = jnp.ceil((arr_hours + maxlife) / HOURS_PER_MONTH).astype(jnp.int32)
    months = jnp.arange(n_months)
    thresh = 1.1 * cfg.capacity

    def admit(month_mass, x):
        ix, ma, md, ok = x
        live_months = (months >= ma) & (months < md)
        # paper-literal gate: admit while the *current* mass is below the
        # threshold — the admitted deployment may overshoot it, which is what
        # spreads BM across the paper's buckets (22k gate, 25k/30k edges).
        accept = ok & (month_mass[ma] < thresh)
        month_mass = month_mass + jnp.where(accept & live_months, ix, 0.0)
        return month_mass, None

    month_mass, _ = jax.lax.scan(
        admit, jnp.zeros(n_months), (i_x, m_arr, m_die, occurs)
    )
    return jnp.max(month_mass)


def rejection_q(p: Sequence[float], p_r: Sequence[float]) -> np.ndarray:
    """Importance distribution q(I_i) of the paper's rejection scheme (Prop. 6).

    ``p``: nominal bucket probabilities; ``p_r``: redraw probabilities (the
    top bucket must have p_r = 0). Buckets are ordered worst-last.
    """
    p = np.asarray(p, dtype=np.float64)
    p_r = np.asarray(p_r, dtype=np.float64)
    k = len(p)
    assert p_r[-1] == 0.0, "top bucket is never redrawn"
    q = np.zeros(k)
    for i in range(k):
        tail = p[i:].sum()  # P(union of buckets >= i)
        p_cond = p[i] / tail  # p(I_i | union_{k>=i} I_k)
        q[i] = p_cond * (1.0 - p_r[i]) / (1.0 - p_cond * p_r[i])
        for j in range(i):
            tail_j = p[j:].sum()
            pj = p[j] / tail_j
            q[i] *= (1.0 - pj) / (1.0 - pj * p_r[j])
    return q


def _probe_fn(cfg: SimConfig, grid: jax.Array, devices=None, source=None):
    """Batched badness-measure evaluator, sharded across local devices.

    The probe loop is the importance sampler's own hot path (hundreds of BM
    evaluations per plan); each probe is independent, so the key batch is
    split over a 1-d device mesh exactly like ``run_batch`` (via the shared
    ``shard_batch_over_devices``). Single-device (or non-divisible batch)
    falls back to the plain vmap.
    """
    batched = jax.vmap(lambda k: badness_measure(k, cfg, grid, source))
    fallback = jax.jit(batched)
    devices = tuple(jax.devices() if devices is None else devices)
    n_dev = len(devices)
    if n_dev <= 1:
        return fallback

    sharded = shard_batch_over_devices(batched, devices, "probe")

    def dispatch(keys):
        if keys.shape[0] % n_dev == 0:
            return sharded(keys)
        return fallback(keys)

    return dispatch


class ImportancePlan(NamedTuple):
    keys: np.ndarray       # [R, 2] uint32 PRNG keys to simulate (full runs)
    weights: np.ndarray    # [R] stratified weights (sum to ~1)
    buckets: np.ndarray    # [R] bucket index per selected run
    p_bucket: np.ndarray   # [K] estimated nominal bucket probabilities
    bm_probe: np.ndarray   # [n_probe] BM values of the probe (diagnostics)


def make_importance_plan(
    key: jax.Array,
    cfg: SimConfig,
    grid: jax.Array,
    quotas: Sequence[int] = (8, 8, 8),
    edges_frac: Sequence[float] = (1.25, 1.5),
    n_probe: int = 512,
    probe_batch: int = 64,
    source: Optional[ArrivalSource] = None,
) -> ImportancePlan:
    """Stratified importance plan over BM buckets.

    Bucket edges are ``edges_frac * capacity`` (the paper used 25k/30k at
    c = 20k, i.e. 1.25c / 1.5c). Probes ``n_probe`` cheap BM evaluations to
    estimate p(I_i); selects runs until each bucket quota is met (buckets that
    the probe never hits keep weight 0).

    With a ``FleetConfig`` the bucket edges scale with the fleet's *total*
    capacity and BM reduces over clusters (see ``stream_badness``), so the
    plan's keys feed ``make_fleet_run`` runs unchanged —
    ``estimate_from_plan`` consumes the fleet-level ``FleetMetrics`` fields.
    """
    cfg = stream_config(cfg)
    edges = np.asarray(edges_frac) * cfg.capacity
    bm_fn = _probe_fn(cfg, grid, source=source)
    keys = jax.random.split(key, n_probe)
    bms = []
    for i in range(0, n_probe, probe_batch):
        bms.append(np.asarray(bm_fn(keys[i:i + probe_batch])))
    bm = np.concatenate(bms)
    bucket = np.digitize(bm, edges)
    k_buckets = len(edges) + 1
    p_hat = np.array([(bucket == i).mean() for i in range(k_buckets)])

    sel_keys, sel_w, sel_b = [], [], []
    for i in range(k_buckets):
        idx = np.nonzero(bucket == i)[0][: quotas[i]]
        if len(idx) == 0:
            continue
        for j in idx:
            sel_keys.append(np.asarray(keys[j]))
            sel_w.append(p_hat[i] / len(idx))
            sel_b.append(i)
    counts = np.bincount(np.asarray(sel_b), minlength=k_buckets)
    log.debug("importance plan: %d runs over buckets=%s p_hat=%s "
              "(probed %d)", len(sel_keys), counts.tolist(),
              np.round(p_hat, 4).tolist(), n_probe)
    return ImportancePlan(
        keys=np.stack(sel_keys),
        weights=np.asarray(sel_w),
        buckets=np.asarray(sel_b),
        p_bucket=p_hat,
        bm_probe=bm,
    )


def simulate_plan(run_fn, plan: ImportancePlan, policy, *,
                  devices=None) -> RunMetrics:
    """Simulate every selected run of a plan through the sharded batch path.

    The plan's keys are an explicit batch (selected by BM bucket, not split
    from one root key), so they route through ``run_keyed_batch`` — the same
    device-sharded vmap as ordinary batches — instead of the serial per-run
    loop callers previously hand-rolled. Returns per-run ``RunMetrics`` in
    plan order; combine with ``plan.weights`` via ``estimate_from_plan``.
    """
    return run_keyed_batch(run_fn, jnp.asarray(plan.keys), policy,
                           devices=devices)


# ---------------------------------------------------------------------------
# Trace-ensemble importance sampling
#
# Replay is arrival-stream-deterministic per trace: every run key sees the
# same arrivals, so key-level BM bucketing (the prior-sampled scheme above)
# collapses to one bucket. The arrival-side tail lives *across* traces —
# a few ensemble members carry the early heavy arrivals that drive SLA
# failures — so stratification moves up a level: bucket the ensemble by
# per-trace BM, oversample the bad traces, and spread each bucket's
# probability mass over its selected (trace, run-key) pairs.
# ---------------------------------------------------------------------------


class TraceEnsemblePlan(NamedTuple):
    trace_idx: np.ndarray  # [R] ensemble index of each selected run's trace
    keys: np.ndarray       # [R, 2] uint32 run keys (within-run randomness)
    weights: np.ndarray    # [R] stratified weights (sum to ~1)
    buckets: np.ndarray    # [R] bucket index per selected run
    p_bucket: np.ndarray   # [K] estimated bucket probabilities over traces
    bm_trace: np.ndarray   # [n_traces] BM per ensemble member (diagnostics)


def _stack_streams(streams: Sequence[ArrivalStream],
                   idx=None) -> ArrivalStream:
    picked = streams if idx is None else [streams[i] for i in idx]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *picked)


def make_trace_ensemble_plan(
    key: jax.Array,
    cfg: SimConfig,
    grid: jax.Array,
    streams: Sequence[ArrivalStream],
    *,
    quotas: Sequence[int] = (8, 4, 4),
    edges_frac: Sequence[float] = (1.25, 1.5),
    runs_per_trace: int = 1,
) -> TraceEnsemblePlan:
    """Stratified plan over a *trace ensemble*'s BM buckets.

    ``streams`` are pre-built arrival streams (``traces.trace_to_stream``
    output), one per ensemble member; each is an iid draw of the arrival
    process, so the empirical bucket frequencies estimate p(I_i) exactly as
    the key probe does in ``make_importance_plan``. Up to ``quotas[i]``
    traces are selected per bucket and each gets ``runs_per_trace``
    independent run keys (within-run randomness still varies per key even
    though arrivals do not); a run's weight is
    ``p_bucket / (n_selected_traces * runs_per_trace)``. Buckets the
    ensemble never hits keep weight 0, as in the key-level plan.

    The whole ensemble is BM-probed in one vmapped pass (per-trace keys
    drive only the simplified schedule's lifetime clocks).
    """
    cfg = stream_config(cfg)
    edges = np.asarray(edges_frac) * cfg.capacity
    n_traces = len(streams)
    if n_traces == 0:
        raise ValueError("trace ensemble is empty")
    k_bm, k_run = jax.random.split(key)
    bm_fn = jax.jit(jax.vmap(
        lambda k, s: stream_badness(k, s, cfg, grid)))
    bm = np.asarray(bm_fn(jax.random.split(k_bm, n_traces),
                          _stack_streams(streams)))
    bucket = np.digitize(bm, edges)
    k_buckets = len(edges) + 1
    p_hat = np.array([(bucket == i).mean() for i in range(k_buckets)])

    run_keys = np.asarray(
        jax.random.split(k_run, n_traces * runs_per_trace)
    ).reshape(n_traces, runs_per_trace, -1)
    sel_idx, sel_keys, sel_w, sel_b = [], [], [], []
    for i in range(k_buckets):
        idx = np.nonzero(bucket == i)[0][: quotas[i]]
        if len(idx) == 0:
            continue
        w = p_hat[i] / (len(idx) * runs_per_trace)
        for j in idx:
            for r in range(runs_per_trace):
                sel_idx.append(int(j))
                sel_keys.append(run_keys[j, r])
                sel_w.append(w)
                sel_b.append(i)
    counts = np.bincount(np.asarray(sel_b), minlength=k_buckets)
    log.debug("trace-ensemble plan: %d runs (%d traces x %d keys) over "
              "buckets=%s p_hat=%s", len(sel_keys), len(set(sel_idx)),
              runs_per_trace, counts.tolist(), np.round(p_hat, 4).tolist())
    return TraceEnsemblePlan(
        trace_idx=np.asarray(sel_idx),
        keys=np.stack(sel_keys),
        weights=np.asarray(sel_w),
        buckets=np.asarray(sel_b),
        p_bucket=p_hat,
        bm_trace=bm,
    )


def simulate_trace_plan(run_fn, plan: TraceEnsemblePlan,
                        streams: Sequence[ArrivalStream], policy, *,
                        devices=None) -> RunMetrics:
    """Simulate a trace-ensemble plan through the sharded keyed batch.

    Pairs each selected run key with its trace's pre-built stream and routes
    the whole batch through ``run_keyed_batch`` (keys and streams sharded
    together over the device mesh). Returns per-run metrics in plan order;
    combine with ``plan.weights`` via ``estimate_from_plan``.
    """
    batch = _stack_streams(streams, plan.trace_idx)
    return run_keyed_batch(run_fn, jnp.asarray(plan.keys), policy,
                           streams=batch, devices=devices)


def estimate_from_plan(plan, metrics: RunMetrics) -> dict:
    """Stratified estimates from a simulated plan (key-level
    ``ImportancePlan`` or trace-level ``TraceEnsemblePlan`` — only the
    weights are consumed): weighted utilization and the aggregate SLA
    failure rate (weights are the estimated bucket masses spread over each
    bucket's runs, so rare bad runs count at their true probability).
    ``metrics`` may equally be a ``FleetMetrics`` batch — its fleet-level
    utilization/failure fields are already reduced over clusters."""
    w = plan.weights
    return {
        "utilization": weighted_mean(np.asarray(metrics.utilization), w),
        "sla_fail": sla_failure_rate(np.asarray(metrics.failed_requests),
                                     np.asarray(metrics.total_requests),
                                     weights=w),
        "n_runs": int(len(w)),
        "weight_mass": float(np.sum(w)),
    }
