"""Trace replay: a ``WorkloadTrace`` as the simulator's ``ArrivalSource``.

``trace_to_stream`` buckets the trace's (sorted) arrivals into simulator
steps, caps each step at ``cfg.max_arrivals`` (overflow arrivals are
dropped and counted — widen ``max_arrivals`` or shrink ``dt`` if the count
is material), and scatters deployments into the ``[n_steps, max_arrivals]``
pre-drawn layout of ``ArrivalStream`` in one vectorized pass. The scan body
then treats replayed and prior-sampled runs identically: run-to-run
randomness (deaths, scale-out timing) still comes from the run key, while
*who arrives when, asking for how much, with what latent parameters* comes
from the trace.

Latent parameters drive the within-run event sampling. When the trace
lacks them (a real observed trace), per-deployment conjugate posterior
means under ``cfg.priors`` are imputed from the trace's observables —
exactly the Gamma updates of ``core.belief``, applied trace-side.

Provider beliefs are the population prior plus the C0 size observation,
i.e. the paper's GLOBAL information model; the richer §6/§7 modes encode
provider-side knowledge that a bare trace does not carry, so replay
rejects those configs loudly rather than silently degrading.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.belief import belief_from_prior, observe_initial_size
from ..core.processes import DeploymentParams, PopulationPriors
from ..sim.simulator import (GLOBAL, ArrivalSource, ArrivalStream, SimConfig,
                             _validate_config)
from .schema import WorkloadTrace, validate_trace


def params_from_trace(trace: WorkloadTrace,
                      priors: PopulationPriors) -> DeploymentParams:
    """Per-deployment latents; conjugate posterior means where missing.

    mu | data  ~ Gamma(a + deaths, b + core-hours);  sig from the size
    observations (C0 plus scale-out sizes); lam from the scale-out counts
    with the E[mu**nu]-style exposure approximated at the posterior-mean mu
    (same E-step shortcut as ``core.belief``).
    """
    deaths = trace.n_core_deaths
    mu_post = (priors.mu_shape + deaths) / (priors.mu_rate + trace.core_hours)
    sig_post = (priors.sig_shape + (trace.c0 - 1.0)
                + (trace.scaleout_cores - trace.n_scaleouts)) / (
                    priors.sig_rate + 1.0 + trace.n_scaleouts)
    lam_post = (priors.lam_shape + trace.n_scaleouts) / (
        priors.lam_rate + mu_post ** priors.nu * trace.obs_window)
    pick = lambda latent, post: jnp.where(
        jnp.isfinite(latent) & (latent > 0.0), latent, post)
    return DeploymentParams(
        lam=pick(trace.lam, lam_post),
        mu=pick(trace.mu, mu_post),
        sig=jnp.where(jnp.isfinite(trace.sig), trace.sig, sig_post),
    )


def trace_to_stream(trace: WorkloadTrace,
                    cfg: SimConfig) -> tuple[ArrivalStream, jax.Array]:
    """Scatter a trace into the simulator's pre-drawn arrival layout.

    Returns ``(stream, n_dropped)`` where ``n_dropped`` counts arrivals lost
    to the per-step ``max_arrivals`` cap (arrivals beyond ``cfg``'s horizon
    are simply outside the replayed window and not counted as drops).
    """
    _validate_config(cfg)
    # the cumulative-rank scatter below assumes sorted valid arrivals; a
    # hand-built trace that skipped sorting would otherwise be corrupted
    # silently. Concrete arrays only — under vmap/tracing the caller is
    # responsible (TraceArrivalSource validates at construction).
    if not isinstance(trace.arrival_hours, jax.core.Tracer):
        validate_trace(trace)
    if cfg.prior_mode != GLOBAL:
        raise ValueError(
            f"trace replay supports prior_mode={GLOBAL!r} only (a trace does "
            f"not carry the provider-side knowledge of {cfg.prior_mode!r})")
    t_steps, a_max = cfg.n_steps, cfg.max_arrivals
    step = jnp.floor(trace.arrival_hours / cfg.dt).astype(jnp.int32)
    ok = trace.valid & (trace.arrival_hours < cfg.horizon_hours) & (step >= 0)
    step_c = jnp.clip(step, 0, t_steps - 1)

    occ = ok.astype(jnp.int32)
    counts = jax.ops.segment_sum(occ, step_c, num_segments=t_steps)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = (jnp.cumsum(occ) - 1) - starts[step_c]   # order within the step
    placed = ok & (rank < a_max)
    n_dropped = jnp.sum(ok & ~placed)
    flat = jnp.where(placed, step_c * a_max + rank, t_steps * a_max)

    def scatter(x, fill):
        out = jnp.full((t_steps * a_max,), fill, x.dtype)
        return out.at[flat].set(x, mode="drop").reshape(t_steps, a_max)

    params = params_from_trace(trace, cfg.priors)
    params = DeploymentParams(lam=scatter(params.lam, 0.0),
                              mu=scatter(params.mu, 1.0),
                              sig=scatter(params.sig, 0.0))
    c0 = scatter(trace.c0.astype(jnp.float32), 1.0)
    n_arrivals = jnp.minimum(counts, a_max)

    bel = belief_from_prior(cfg.priors, (t_steps, a_max))
    bel = observe_initial_size(bel, c0)
    return ArrivalStream(params=params, c0=c0, bel=bel, bel_alt=bel,
                         n_arrivals=n_arrivals), n_dropped


class TraceArrivalSource(ArrivalSource):
    """Replay a fixed ``WorkloadTrace`` through ``make_run``.

    The run key no longer influences arrivals (they are the trace), only the
    within-run event randomness; two runs with different keys against the
    same source share an arrival stream, which is exactly the trace-driven
    evaluation mode of the benchmarks.
    """

    def __init__(self, trace: WorkloadTrace):
        self.trace = validate_trace(trace)

    def stream(self, key: jax.Array, cfg: SimConfig) -> ArrivalStream:
        del key  # arrivals are the trace; the run key drives the scan only
        stream, _ = trace_to_stream(self.trace, cfg)
        return stream

    def n_dropped(self, cfg: SimConfig) -> int:
        """Arrivals lost to the max_arrivals cap under ``cfg`` (host value)."""
        return int(trace_to_stream(self.trace, cfg)[1])
