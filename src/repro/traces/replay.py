"""Trace replay: a ``WorkloadTrace`` as the simulator's ``ArrivalSource``.

``trace_to_stream`` buckets the trace's (sorted) arrivals into simulator
steps, caps each step at ``cfg.max_arrivals`` (overflow arrivals are
dropped and counted — widen ``max_arrivals`` or shrink ``dt`` if the count
is material), and scatters deployments into the ``[n_steps, max_arrivals]``
pre-drawn layout of ``ArrivalStream`` in one vectorized pass. The scan body
then treats replayed and prior-sampled runs identically: run-to-run
randomness (deaths, scale-out timing) still comes from the run key, while
*who arrives when, asking for how much, with what latent parameters* comes
from the trace.

Latent parameters drive the within-run event sampling. When the trace
lacks them (a real observed trace), per-deployment conjugate posterior
means under ``cfg.priors`` are imputed from the trace's observables —
exactly the Gamma updates of ``core.belief``, applied trace-side.

Information models (``cfg.prior_mode``) are all supported on replay:

  * GLOBAL — belief = population prior + the C0 size observation (the
    paper's baseline; no per-deployment key randomness, so the stream is
    fully determined by the trace).
  * PSEUDO (§6) — the provider holds deployment-specific prior knowledge.
    Two constructions, selected by ``pseudo_source``:
      - ``"latent"`` (synthetic traces): sample ``cfg.n_pseudo_obs``
        pseudo observations from the trace's own latent parameters with
        ``core.processes.sample_pseudo_observations`` — distributionally
        identical to ``draw_arrival_stream``'s PSEUDO path, which is what
        makes replayed and prior-sampled PSEUDO runs statistically
        equivalent on matched arrivals (tested in test_traces.py).
      - ``"observed"`` (real traces): form deterministic pseudo-counts
        from the trace's logged observables — death counts, core-hour
        exposure, scale-out counts/sizes, observation window — via
        ``core.belief.pseudo_counts_from_observables`` and the existing
        conjugate updates. This models a provider who had previously
        watched exactly the history the trace records; ``n_pseudo_obs``
        is ignored because the trace defines its own information content
        (``_validate_config`` still requires it >= 1 under PSEUDO — the
        sampled-observation footgun check cannot see the arrival source).
  * MIX_LABELED / MIX_UNLABELED (§7) — the submitted deployment is the
    trace row (belief as in PSEUDO); the alternative user type, which a
    bare trace cannot carry, is imputed as an independent draw from
    ``cfg.priors`` with its own ``n_pseudo_obs`` pseudo observations —
    the same imputation ``draw_arrival_stream`` uses for its alt type.

PSEUDO-latent and the §7 modes consume the ``key`` passed to
``trace_to_stream`` / ``ArrivalSource.stream`` for the belief-side
randomness only: arrivals remain trace-determined, but two runs with
different keys see (correctly) different provider beliefs, exactly as in
prior-sampled mode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core.belief import (apply_pseudo_observations, belief_from_prior,
                           observe_initial_size,
                           pseudo_counts_from_observables)
from ..core.processes import (DeploymentParams, PopulationPriors,
                              PseudoObservations, sample_params,
                              sample_pseudo_observations)
from ..sim.simulator import (GLOBAL, MIX_LABELED, MIX_UNLABELED, PSEUDO,
                             ArrivalSource, ArrivalStream, SimConfig,
                             _validate_config, stream_config)
from .schema import WorkloadTrace, has_latents, validate_trace

PSEUDO_LATENT, PSEUDO_OBSERVED, PSEUDO_AUTO = "latent", "observed", "auto"


def params_from_trace(trace: WorkloadTrace,
                      priors: PopulationPriors) -> DeploymentParams:
    """Per-deployment latents; conjugate posterior means where missing.

    mu | data  ~ Gamma(a + deaths, b + core-hours);  sig from the size
    observations (C0 plus scale-out sizes); lam from the scale-out counts
    with the E[mu**nu]-style exposure approximated at the posterior-mean mu
    (same E-step shortcut as ``core.belief``).
    """
    deaths = trace.n_core_deaths
    mu_post = (priors.mu_shape + deaths) / (priors.mu_rate + trace.core_hours)
    sig_post = (priors.sig_shape + (trace.c0 - 1.0)
                + (trace.scaleout_cores - trace.n_scaleouts)) / (
                    priors.sig_rate + 1.0 + trace.n_scaleouts)
    lam_post = (priors.lam_shape + trace.n_scaleouts) / (
        priors.lam_rate + mu_post ** priors.nu * trace.obs_window)
    pick = lambda latent, post: jnp.where(
        jnp.isfinite(latent) & (latent > 0.0), latent, post)
    return DeploymentParams(
        lam=pick(trace.lam, lam_post),
        mu=pick(trace.mu, mu_post),
        sig=jnp.where(jnp.isfinite(trace.sig), trace.sig, sig_post),
    )


def _resolve_pseudo_source(trace: WorkloadTrace, pseudo_source: str) -> str:
    if pseudo_source not in (PSEUDO_LATENT, PSEUDO_OBSERVED, PSEUDO_AUTO):
        raise ValueError(f"unknown pseudo_source {pseudo_source!r}")
    if pseudo_source != PSEUDO_AUTO:
        return pseudo_source
    if isinstance(trace.arrival_hours, jax.core.Tracer):
        raise ValueError(
            "pseudo_source='auto' cannot inspect a traced trace; pass "
            "pseudo_source='latent' or 'observed' explicitly")
    return PSEUDO_LATENT if has_latents(trace) else PSEUDO_OBSERVED


def _trace_pseudo_obs(trace: WorkloadTrace, cfg: SimConfig, source: str,
                      key: Optional[jax.Array]) -> PseudoObservations:
    """[D]-shaped pseudo observations for the trace's own deployments."""
    if source == PSEUDO_OBSERVED:
        return pseudo_counts_from_observables(
            core_deaths=trace.n_core_deaths,
            exposure_core_hours=trace.core_hours,
            n_scaleouts=trace.n_scaleouts,
            scaleout_cores=trace.scaleout_cores,
            window_hours=trace.obs_window,
        )
    if key is None:
        raise ValueError(
            f"prior_mode={cfg.prior_mode!r} with pseudo_source='latent' "
            "samples pseudo observations and needs a PRNG key: pass key= to "
            "trace_to_stream (TraceArrivalSource forwards its stream key)")
    params = DeploymentParams(lam=trace.lam, mu=trace.mu, sig=trace.sig)
    return sample_pseudo_observations(key, params, cfg.priors,
                                      cfg.n_pseudo_obs)


def trace_to_stream(trace: WorkloadTrace, cfg: SimConfig,
                    key: Optional[jax.Array] = None,
                    pseudo_source: str = PSEUDO_AUTO,
                    ) -> tuple[ArrivalStream, jax.Array]:
    """Scatter a trace into the simulator's pre-drawn arrival layout.

    Returns ``(stream, n_dropped)`` where ``n_dropped`` counts arrivals lost
    to the per-step ``max_arrivals`` cap (arrivals beyond ``cfg``'s horizon
    are simply outside the replayed window and not counted as drops).

    ``key`` feeds the belief-side sampling of the PSEUDO-latent and §7
    modes (see the module docstring); GLOBAL and PSEUDO-observed replay is
    deterministic and ignores it.

    ``cfg`` may be a ``FleetConfig``: the trace is scattered into ONE
    fleet-wide stream (the fleet's base layout via ``stream_config``) and
    arrivals are *routed* to clusters at simulation time by
    ``make_fleet_run``'s router — a trace never pre-assigns clusters.
    """
    cfg = stream_config(cfg)
    _validate_config(cfg)
    # the cumulative-rank scatter below assumes sorted valid arrivals; a
    # hand-built trace that skipped sorting would otherwise be corrupted
    # silently. Concrete arrays only — under vmap/tracing the caller is
    # responsible (TraceArrivalSource validates at construction).
    if not isinstance(trace.arrival_hours, jax.core.Tracer):
        validate_trace(trace)
    t_steps, a_max = cfg.n_steps, cfg.max_arrivals
    step = jnp.floor(trace.arrival_hours / cfg.dt).astype(jnp.int32)
    ok = trace.valid & (trace.arrival_hours < cfg.horizon_hours) & (step >= 0)
    step_c = jnp.clip(step, 0, t_steps - 1)

    occ = ok.astype(jnp.int32)
    counts = jax.ops.segment_sum(occ, step_c, num_segments=t_steps)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = (jnp.cumsum(occ) - 1) - starts[step_c]   # order within the step
    placed = ok & (rank < a_max)
    n_dropped = jnp.sum(ok & ~placed)
    flat = jnp.where(placed, step_c * a_max + rank, t_steps * a_max)

    def scatter(x, fill):
        out = jnp.full((t_steps * a_max,), fill, x.dtype)
        return out.at[flat].set(x, mode="drop").reshape(t_steps, a_max)

    params = params_from_trace(trace, cfg.priors)
    params = DeploymentParams(lam=scatter(params.lam, 0.0),
                              mu=scatter(params.mu, 1.0),
                              sig=scatter(params.sig, 0.0))
    c0 = scatter(trace.c0.astype(jnp.float32), 1.0)
    n_arrivals = jnp.minimum(counts, a_max)

    prior = belief_from_prior(cfg.priors, (t_steps, a_max))
    if cfg.prior_mode == GLOBAL:
        bel = prior
        bel_alt = bel
    else:
        source = _resolve_pseudo_source(trace, pseudo_source)
        k_own = k_alt_par = k_alt_obs = None
        if key is not None:
            k_own, k_alt_par, k_alt_obs = jax.random.split(key, 3)
        obs = _trace_pseudo_obs(trace, cfg, source, k_own)
        # scatter the [D] pseudo-counts into the [T, A] layout (empty slots
        # get zero counts, i.e. the bare prior) and fold them in through the
        # conjugate update — the same path draw_arrival_stream takes.
        obs = PseudoObservations(*(scatter(jnp.asarray(f, jnp.float32), 0.0)
                                   for f in obs))
        bel = apply_pseudo_observations(prior, obs, cfg.priors)
        if cfg.prior_mode == PSEUDO:
            bel_alt = bel
        else:
            # §7: the alternative user type is not in the trace; impute it
            # as an independent prior draw with its own pseudo observations,
            # mirroring draw_arrival_stream's alt-type construction.
            if key is None:
                raise ValueError(
                    f"prior_mode={cfg.prior_mode!r} imputes the §7 "
                    "alternative type and needs a PRNG key: pass key= to "
                    "trace_to_stream (TraceArrivalSource forwards its "
                    "stream key)")
            alt = sample_params(k_alt_par, cfg.priors, (t_steps, a_max))
            obs_alt = sample_pseudo_observations(k_alt_obs, alt, cfg.priors,
                                                 cfg.n_pseudo_obs)
            bel_alt = apply_pseudo_observations(prior, obs_alt, cfg.priors)
    bel = observe_initial_size(bel, c0)
    return ArrivalStream(params=params, c0=c0, bel=bel, bel_alt=bel_alt,
                         n_arrivals=n_arrivals), n_dropped


class TraceArrivalSource(ArrivalSource):
    """Replay a fixed ``WorkloadTrace`` through ``make_run``.

    The run key no longer influences *arrivals* (they are the trace) — under
    GLOBAL and PSEUDO-observed replay two runs with different keys share the
    whole arrival stream, which is exactly the trace-driven evaluation mode
    of the benchmarks. Under PSEUDO-latent and the §7 modes the key still
    drives the belief-side sampling (pseudo observations, imputed alt
    type), matching ``PriorArrivalSource``'s per-run belief randomness.

    ``pseudo_source`` (default ``"auto"``) picks how PSEUDO/§7 beliefs are
    built: ``"latent"`` samples from the trace's latent parameters,
    ``"observed"`` forms conjugate pseudo-counts from the logged
    observables; ``"auto"`` resolves at construction from
    ``has_latents(trace)``.
    """

    def __init__(self, trace: WorkloadTrace, pseudo_source: str = PSEUDO_AUTO):
        self.trace = validate_trace(trace)
        self.pseudo_source = _resolve_pseudo_source(trace, pseudo_source)

    def stream(self, key: jax.Array, cfg: SimConfig) -> ArrivalStream:
        stream, _ = trace_to_stream(self.trace, cfg, key=key,
                                    pseudo_source=self.pseudo_source)
        return stream

    def n_dropped(self, cfg: SimConfig) -> int:
        """Arrivals lost to the max_arrivals cap under ``cfg`` (host value).

        Drops depend only on arrival placement, never on beliefs, so the
        count is taken under GLOBAL — skipping the pseudo-observation and
        §7 alt-type sampling the real information model would pay for.
        ``cfg`` may be a ``FleetConfig`` (drops are a property of the
        fleet-wide stream layout, before routing).
        """
        cfg = stream_config(cfg)._replace(prior_mode=GLOBAL)
        return int(trace_to_stream(self.trace, cfg)[1])
