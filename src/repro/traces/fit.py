"""Recover ``PopulationPriors`` from a ``WorkloadTrace`` (generate→fit loop).

Two estimation paths, chosen by ``source``:

  * ``"latent"`` — the trace carries per-deployment (lam, mu, sig) (any
    synthetic trace does). Gamma hyperparameters come from the standard
    two-parameter Gamma MLE (Newton on the shape with the log-mean
    sufficient statistic); ``nu`` from the 1-d Poisson profile likelihood of
    the scale-out counts; ``delta`` from the censored-exponential MLE of the
    spontaneous-shutdown clock. This is the tight round-trip used by the
    acceptance test.
  * ``"observed"`` — only provider-visible observables are used, as with a
    real trace. Per-deployment point estimates (mu_hat = deaths/exposure,
    sig_hat from size observations, scale-out intensities N/(mu_hat**nu w))
    are *noisy*, so plain Gamma fits of them overestimate the population
    variance; the moment-matching here subtracts the known sampling-noise
    component (E Var[x_hat | x] has closed form for Poisson/exponential
    estimates) before converting moments to (shape, rate). ``nu`` comes from
    the log-log regression of binned scale-out intensity against mu_hat —
    E[N/w | mu] = E[lam] mu**nu is linear in log mu with slope nu.

Both return a fitted ``PopulationPriors`` plus a diagnostics dict. Fitting
is a cold path and runs in numpy/scipy on host.
"""
from __future__ import annotations

import numpy as np
from scipy.special import polygamma, psi

from ..core.processes import PopulationPriors
from ..obs.log import get_logger
from .schema import WorkloadTrace, has_latents

log = get_logger(__name__)

_MIN_SAMPLES = 8


def fit_gamma_mle(x: np.ndarray, n_iter: int = 40) -> tuple[float, float]:
    """Two-parameter Gamma(shape, rate) MLE via Newton on the shape.

    Uses s = log(mean) - mean(log); the Greenwood–Durand-style initializer
    followed by Newton steps on  f(k) = log k - psi(k) - s.
    """
    x = np.asarray(x, np.float64)
    x = x[np.isfinite(x) & (x > 0)]
    if x.size < _MIN_SAMPLES:
        raise ValueError(f"gamma MLE needs >= {_MIN_SAMPLES} samples, got {x.size}")
    mean = x.mean()
    s = np.log(mean) - np.log(x).mean()
    s = max(s, 1e-9)
    k = (3.0 - s + np.sqrt((s - 3.0) ** 2 + 24.0 * s)) / (12.0 * s)
    for _ in range(n_iter):
        f = np.log(k) - psi(k) - s
        df = 1.0 / k - polygamma(1, k)
        step = f / df
        k_new = k - step
        if not np.isfinite(k_new) or k_new <= 0:
            k_new = k / 2.0
        if abs(k_new - k) < 1e-12 * k:
            k = k_new
            break
        k = k_new
    return float(k), float(k / mean)


def fit_gamma_moments(x: np.ndarray, noise_var: float = 0.0
                      ) -> tuple[float, float]:
    """Gamma(shape, rate) by moment matching, with the average *sampling*
    variance of the per-deployment estimates subtracted from the empirical
    variance (law of total variance: Var(x_hat) = Var(x) + E Var[x_hat|x])."""
    x = np.asarray(x, np.float64)
    x = x[np.isfinite(x)]
    if x.size < _MIN_SAMPLES:
        raise ValueError(f"moment fit needs >= {_MIN_SAMPLES} samples, got {x.size}")
    mean = x.mean()
    var = max(x.var() - noise_var, 1e-3 * mean * mean + 1e-12)
    return float(mean * mean / var), float(mean / var)


# ---------------------------------------------------------------------------
# nu / delta estimators
# ---------------------------------------------------------------------------

def _fit_nu_profile(n_so, lam, mu, w, nu_grid) -> tuple[float, np.ndarray]:
    """Poisson profile log-likelihood of nu given true (lam, mu):
    N_i ~ Poisson(lam_i mu_i**nu w_i); terms without nu dropped."""
    logmu = np.log(mu)
    scores = np.array([
        np.sum(n_so * nu * logmu - lam * np.power(mu, nu) * w)
        for nu in nu_grid])
    return float(nu_grid[int(np.argmax(scores))]), scores


def _fit_nu_binned(n_so, mu_hat, w, n_bins: int = 10) -> float:
    """Slope of log(mean scale-out intensity) vs log(mu_hat) over quantile
    bins: E[N/w | mu] = E[lam] * mu**nu."""
    ok = np.isfinite(mu_hat) & (mu_hat > 0) & (w > 0)
    lm, rate = np.log(mu_hat[ok]), (n_so[ok] / w[ok])
    if lm.size < _MIN_SAMPLES * n_bins:
        n_bins = max(3, lm.size // _MIN_SAMPLES)
    edges = np.quantile(lm, np.linspace(0, 1, n_bins + 1))
    xs, ys, ws = [], [], []
    for b in range(n_bins):
        m = (lm >= edges[b]) & (lm <= edges[b + 1] if b == n_bins - 1
                                else lm < edges[b + 1])
        if m.sum() < 4 or rate[m].mean() <= 0:
            continue
        xs.append(lm[m].mean())
        ys.append(np.log(rate[m].mean()))
        ws.append(float(m.sum()))
    if len(xs) < 3:
        return float("nan")
    xs, ys, ws = map(np.asarray, (xs, ys, ws))
    xm = np.average(xs, weights=ws)
    ym = np.average(ys, weights=ws)
    return float(np.sum(ws * (xs - xm) * (ys - ym))
                 / np.sum(ws * (xs - xm) ** 2))


def _fit_delta(spont: np.ndarray, mu: np.ndarray, w: np.ndarray) -> float:
    """Censored-exponential MLE of the spontaneous-shutdown multiplier:
    T ~ Exp(delta * mu), observed exposure is mu-weighted window hours."""
    exposure = np.sum(mu * w)
    return float(spont.sum() / max(exposure, 1e-12))


# ---------------------------------------------------------------------------
# The main entry point
# ---------------------------------------------------------------------------

def fit_priors(trace: WorkloadTrace, *, source: str = "auto",
               nu: float | None = None,
               nu_grid: np.ndarray | None = None,
               min_deaths: int = 2) -> tuple[PopulationPriors, dict]:
    """Fit ``PopulationPriors`` to a trace; returns (priors, diagnostics).

    ``source``: "latent" (requires latent columns), "observed" (uses only
    provider-visible observables), or "auto" (latent when available).
    ``nu`` fixes the power-law exponent instead of estimating it.
    """
    if source == "auto":
        source = "latent" if has_latents(trace) else "observed"
    if source not in ("latent", "observed"):
        raise ValueError(f"unknown fit source {source!r}")
    if nu_grid is None:
        nu_grid = np.linspace(0.0, 1.5, 151)

    v = np.asarray(trace.valid)
    w = np.asarray(trace.obs_window, np.float64)[v]
    n_so = np.asarray(trace.n_scaleouts, np.float64)[v]
    so_cores = np.asarray(trace.scaleout_cores, np.float64)[v]
    c0 = np.asarray(trace.c0, np.float64)[v]
    spont = np.asarray(trace.spont_death)[v]
    deaths = np.asarray(trace.n_core_deaths, np.float64)[v]
    core_hours = np.asarray(trace.core_hours, np.float64)[v]
    diag: dict = {"source": source, "n_deployments": int(v.sum())}

    if source == "latent":
        lam = np.asarray(trace.lam, np.float64)[v]
        mu = np.asarray(trace.mu, np.float64)[v]
        sig = np.asarray(trace.sig, np.float64)[v]
        mu_shape, mu_rate = fit_gamma_mle(mu)
        lam_shape, lam_rate = fit_gamma_mle(lam)
        sig_shape, sig_rate = fit_gamma_mle(sig)
        if nu is None:
            nu, nu_scores = _fit_nu_profile(n_so, lam, mu, w, nu_grid)
            diag["nu_scores"] = nu_scores
        delta = _fit_delta(spont, mu, w)
    else:
        # mu: censored-exponential MLE per deployment; Gamma MLE across the
        # population restricted to informative deployments (>= min_deaths).
        ok_mu = (deaths >= min_deaths) & (core_hours > 0)
        mu_hat = np.where(core_hours > 0, deaths / np.maximum(core_hours, 1e-12),
                          np.nan)
        mu_shape, mu_rate = fit_gamma_mle(mu_hat[ok_mu])
        diag["n_mu"] = int(ok_mu.sum())

        # sig: sizes-minus-one are Poisson(sig) with m = 1 + n_scaleouts
        # observations (C0 counts); noise E Var[sig_hat|sig] = E[sig/m].
        m_obs = 1.0 + n_so
        sig_hat = (c0 - 1.0 + (so_cores - n_so)) / m_obs
        sig_noise = float(sig_hat.mean() * (1.0 / m_obs).mean())
        sig_shape, sig_rate = fit_gamma_moments(sig_hat, noise_var=sig_noise)

        if nu is None:
            nu = _fit_nu_binned(n_so, mu_hat, w)
            if not np.isfinite(nu):
                nu = 0.5
        # lam: N_i/(mu_hat**nu w_i) is conditionally unbiased for lam_i;
        # noise E Var = E[lam] * E[1/a]. Uses *all* deployments (no
        # zero-count truncation, which would bias the shape up).
        a = np.power(np.where(np.isfinite(mu_hat) & (mu_hat > 0), mu_hat,
                              mu_shape / mu_rate), nu) * w
        ok_lam = a > 1e-3
        lam_hat = n_so[ok_lam] / a[ok_lam]
        lam_noise = float(lam_hat.mean() * (1.0 / a[ok_lam]).mean())
        lam_shape, lam_rate = fit_gamma_moments(lam_hat, noise_var=lam_noise)
        diag["n_lam"] = int(ok_lam.sum())

        # delta exposure needs a mu estimate for *every* deployment, including
        # the death-free ones (tiny mu, long windows) — the conjugate
        # posterior mean under the fitted Gamma prior handles those, where a
        # population-mean fallback would overstate exposure by orders of
        # magnitude (mu is heavy-tailed: mean >> typical).
        mu_post = (mu_shape + deaths) / (mu_rate + core_hours)
        delta = _fit_delta(spont, mu_post, w)

    fitted = PopulationPriors(
        mu_shape=mu_shape, mu_rate=mu_rate,
        lam_shape=lam_shape, lam_rate=lam_rate,
        sig_shape=sig_shape, sig_rate=sig_rate,
        delta=delta, nu=float(nu),
    )
    diag["nu"] = float(nu)
    log.debug(
        "fit_priors source=%s n=%d: mu=(%.4g,%.4g) lam=(%.4g,%.4g) "
        "sig=(%.4g,%.4g) delta=%.4g nu=%.3f", source,
        diag["n_deployments"], mu_shape, mu_rate, lam_shape, lam_rate,
        sig_shape, sig_rate, delta, nu)
    return fitted, diag


def prior_relative_errors(fitted: PopulationPriors,
                          reference: PopulationPriors) -> dict:
    """Per-field relative error |fit - ref| / |ref| (diagnostic/tests)."""
    return {f: abs(getattr(fitted, f) - getattr(reference, f))
            / max(abs(getattr(reference, f)), 1e-12)
            for f in PopulationPriors._fields}
