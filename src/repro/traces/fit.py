"""Recover ``PopulationPriors`` from a ``WorkloadTrace`` (generate→fit loop).

Two estimation paths, chosen by ``source``:

  * ``"latent"`` — the trace carries per-deployment (lam, mu, sig) (any
    synthetic trace does). Gamma hyperparameters come from the standard
    two-parameter Gamma MLE (Newton on the shape with the log-mean
    sufficient statistic); ``nu`` from the 1-d Poisson profile likelihood of
    the scale-out counts; ``delta`` from the censored-exponential MLE of the
    spontaneous-shutdown clock. This is the tight round-trip used by the
    acceptance test.
  * ``"observed"`` — only provider-visible observables are used, as with a
    real trace. Per-deployment point estimates (mu_hat = deaths/exposure,
    sig_hat from size observations, scale-out intensities N/(mu_hat**nu w))
    are *noisy*, so plain Gamma fits of them overestimate the population
    variance; the moment-matching here subtracts the known sampling-noise
    component (E Var[x_hat | x] has closed form for Poisson/exponential
    estimates) before converting moments to (shape, rate). ``nu`` comes from
    the log-log regression of binned scale-out intensity against mu_hat —
    E[N/w | mu] = E[lam] mu**nu is linear in log mu with slope nu.

The observed path is factored through an explicit **sufficient-statistics
layer** so it runs windowed/streaming over any trace (or the live engine's
telemetry stream):

  * ``window_stats(trace, t0, t1)`` reduces the deployments *arriving* in
    [t0, t1) to a ``FitStats`` record — counts, moment sums, and censoring
    tallies, all mergeable by addition. Windows that partition the horizon
    partition the deployments, so merging is exact (no approximation from
    windowing, only float summation order).
  * ``merge_stats(*stats)`` folds any number of windows into one record
    (associative, window-order-invariant up to float rounding).
  * ``stats_to_priors(stats)`` runs every population-level estimator on the
    merged record. ``fit_priors(source="observed")`` is literally
    ``stats_to_priors(window_stats(trace, 0, inf))`` — one window over the
    whole trace is bit-for-bit the batch fit.

Three estimators were restated in sufficient-statistic form to make the
record finite-dimensional (the round-trip accuracy test pins them):

  * nu's binned regression uses **fixed** log-mu bin edges instead of
    population quantiles (quantiles don't merge);
  * the scale-out-intensity (lam) moments are tabulated on the fixed
    ``NU_GRID`` — the fitted nu is snapped to the nearest grid point
    (0.01 resolution) — and restricted to deployments with an informative
    mu_hat, rather than imputing the population-mean fallback for
    death-free deployments (the fallback depends on the *merged* mu fit);
  * delta's exposure uses the ratio-of-sums Σ deaths·w/core_hours, which is
    unbiased for Σ mu·w under the generator (E[deaths] = mu · core_hours
    exactly) without needing the fitted mu prior per deployment.

A window too small for an estimator (``< _MIN_SAMPLES`` informative rows,
e.g. an empty window) **warns and continues** with a weakly-informative
exponential fallback for that channel — recorded under
``diag["degenerate"]`` — instead of raising, so streaming consumers survive
quiet windows.

Both paths return a fitted ``PopulationPriors`` plus a diagnostics dict.
Fitting is a cold path and runs in numpy/scipy on host.
"""
from __future__ import annotations

import warnings
from typing import NamedTuple

import numpy as np
from scipy.special import polygamma, psi

from ..core.processes import PopulationPriors
from ..obs.log import get_logger
from .schema import WorkloadTrace, has_latents

log = get_logger(__name__)

_MIN_SAMPLES = 8

#: fixed nu grid for the streaming lam moments (and the latent-path profile
#: default): 0.01 resolution over the physically sensible [0, 1.5] range
NU_GRID = np.linspace(0.0, 1.5, 151)

#: fixed log(mu_hat) bin edges for the streaming nu regression (out-of-range
#: values clip into the end bins); spans death rates 1e-4..30 per core-hour
_N_NU_BINS = 12
_NU_BIN_EDGES = np.linspace(np.log(1e-4), np.log(30.0), _N_NU_BINS + 1)


def fit_gamma_mle(x: np.ndarray, n_iter: int = 40) -> tuple[float, float]:
    """Two-parameter Gamma(shape, rate) MLE via Newton on the shape.

    Uses s = log(mean) - mean(log); the Greenwood–Durand-style initializer
    followed by Newton steps on  f(k) = log k - psi(k) - s.
    """
    x = np.asarray(x, np.float64)
    x = x[np.isfinite(x) & (x > 0)]
    if x.size < _MIN_SAMPLES:
        raise ValueError(f"gamma MLE needs >= {_MIN_SAMPLES} samples, got {x.size}")
    return _gamma_mle_from_moments(x.mean(), np.log(x).mean(), n_iter)


def _gamma_mle_from_moments(mean: float, meanlog: float,
                            n_iter: int = 40) -> tuple[float, float]:
    """The Gamma MLE Newton iteration from its two sufficient statistics."""
    s = np.log(mean) - meanlog
    s = max(s, 1e-9)
    k = (3.0 - s + np.sqrt((s - 3.0) ** 2 + 24.0 * s)) / (12.0 * s)
    for _ in range(n_iter):
        f = np.log(k) - psi(k) - s
        df = 1.0 / k - polygamma(1, k)
        step = f / df
        k_new = k - step
        if not np.isfinite(k_new) or k_new <= 0:
            k_new = k / 2.0
        if abs(k_new - k) < 1e-12 * k:
            k = k_new
            break
        k = k_new
    return float(k), float(k / mean)


def fit_gamma_moments(x: np.ndarray, noise_var: float = 0.0
                      ) -> tuple[float, float]:
    """Gamma(shape, rate) by moment matching, with the average *sampling*
    variance of the per-deployment estimates subtracted from the empirical
    variance (law of total variance: Var(x_hat) = Var(x) + E Var[x_hat|x])."""
    x = np.asarray(x, np.float64)
    x = x[np.isfinite(x)]
    if x.size < _MIN_SAMPLES:
        raise ValueError(f"moment fit needs >= {_MIN_SAMPLES} samples, got {x.size}")
    mean = x.mean()
    var = max(x.var() - noise_var, 1e-3 * mean * mean + 1e-12)
    return float(mean * mean / var), float(mean / var)


def _gamma_moments_from_sums(n: float, total: float, total_sq: float,
                             noise_var: float) -> tuple[float, float]:
    """``fit_gamma_moments`` from (count, sum, sum of squares)."""
    mean = total / n
    var = max(total_sq / n - mean * mean - noise_var,
              1e-3 * mean * mean + 1e-12)
    return float(mean * mean / var), float(mean / var)


# ---------------------------------------------------------------------------
# nu / delta estimators
# ---------------------------------------------------------------------------

def _fit_nu_profile(n_so, lam, mu, w, nu_grid) -> tuple[float, np.ndarray]:
    """Poisson profile log-likelihood of nu given true (lam, mu):
    N_i ~ Poisson(lam_i mu_i**nu w_i); terms without nu dropped."""
    logmu = np.log(mu)
    scores = np.array([
        np.sum(n_so * nu * logmu - lam * np.power(mu, nu) * w)
        for nu in nu_grid])
    return float(nu_grid[int(np.argmax(scores))]), scores


def _fit_delta(spont: np.ndarray, mu: np.ndarray, w: np.ndarray) -> float:
    """Censored-exponential MLE of the spontaneous-shutdown multiplier:
    T ~ Exp(delta * mu), observed exposure is mu-weighted window hours."""
    exposure = np.sum(mu * w)
    return float(spont.sum() / max(exposure, 1e-12))


# ---------------------------------------------------------------------------
# The sufficient-statistics layer (observed path, windowed/streaming)
# ---------------------------------------------------------------------------

class FitStats(NamedTuple):
    """Mergeable sufficient statistics of the observed-path prior fit over
    one arrival window. Every field is a float or a fixed-shape float64
    array and merges by addition (``t0``/``t1`` by min/max; ``min_deaths``
    is a parameter and must agree across merged windows)."""

    n: float              # valid deployments arriving in the window
    t0: float             # window bounds (arrival hours; diagnostics only)
    t1: float
    min_deaths: float     # informative-mu threshold the stats were built with
    # window observable totals (the drift-detector channels; keys mirror
    # obs.counters.telemetry_summary()["obs"])
    deaths_sum: float     # core deaths
    core_hours_sum: float  # core-hour exposure behind those deaths
    n_so_sum: float       # scale-out events
    so_cores_sum: float   # cores requested by scale-outs
    w_sum: float          # observation-window (alive) hours
    spont_sum: float      # spontaneous shutdowns
    dwh_sum: float        # Σ deaths·w/core_hours — delta's mu·w exposure
    # drift-detector channels: *unweighted* sums of per-deployment unbiased
    # estimates. E[deaths/core_hours | mu, window] = mu for any censoring, so
    # these window means are stationary across arrival windows of a
    # stationary trace — unlike the pooled ratio deaths_sum/core_hours_sum,
    # which horizon censoring tilts toward high-mu deployments near the end
    # of the trace (tuning.drift builds on this).
    ch_mu_sum: float      # Σ deaths/core_hours over rows with exposure
    ch_mu_n: float
    ch_so_sum: float      # Σ n_scaleouts/w over rows with alive hours
    ch_so_n: float
    # mu channel: censored-exponential per-deployment MLEs, informative rows
    mu_n: float
    mu_sum: float
    mu_logsum: float
    # sig channel: size-minus-one means over 1 + n_scaleouts observations
    sig_n: float
    sig_sum: float
    sig_sumsq: float
    inv_m_sum: float
    # nu channel: fixed log(mu_hat) bins of the scale-out intensity n_so/w
    nu_count: np.ndarray     # [_N_NU_BINS]
    nu_lm_sum: np.ndarray    # [_N_NU_BINS]
    nu_rate_sum: np.ndarray  # [_N_NU_BINS]
    # lam channel: intensity moments tabulated on the fixed NU_GRID
    lam_n: np.ndarray        # [len(NU_GRID)]
    lam_sum: np.ndarray      # [len(NU_GRID)]
    lam_sumsq: np.ndarray    # [len(NU_GRID)]
    inv_a_sum: np.ndarray    # [len(NU_GRID)]

    def observables(self) -> dict:
        """The window's observable totals under the same keys as
        ``obs.counters.telemetry_summary()["obs"]`` — the drift-detector
        input, whichever side (offline trace window / live telemetry delta)
        produced it."""
        return {
            "core_deaths": float(self.deaths_sum),
            "exposure_core_hours": float(self.core_hours_sum),
            "n_scaleouts": float(self.n_so_sum),
            "scaleout_cores": float(self.so_cores_sum),
            "alive_hours": float(self.w_sum),
            "spont_deaths": float(self.spont_sum),
        }

    def drift_channels(self) -> dict:
        """Censoring-robust per-window channel means for drift detection:
        ``mu`` (mean per-deployment death rate), ``scaleout`` (mean
        per-deployment scale-out intensity), ``size`` (mean size-minus-one).
        Channels with no contributing rows are NaN (the detector skips
        them)."""
        return {
            "mu": (self.ch_mu_sum / self.ch_mu_n if self.ch_mu_n > 0
                   else float("nan")),
            "scaleout": (self.ch_so_sum / self.ch_so_n if self.ch_so_n > 0
                         else float("nan")),
            "size": (self.sig_sum / self.sig_n if self.sig_n > 0
                     else float("nan")),
        }


def window_stats(trace: WorkloadTrace, t0: float = 0.0,
                 t1: float = np.inf, *, min_deaths: int = 2) -> FitStats:
    """Sufficient statistics over the deployments **arriving** in [t0, t1).

    Selecting by arrival time makes disjoint windows partition the valid
    deployments, so ``stats_to_priors(merge_stats(*windows))`` equals the
    batch fit over the concatenated trace (up to float summation order).
    Each deployment contributes its *whole* observation record to the window
    it arrives in — the streaming consumer sees a deployment once, when it
    shows up.
    """
    v = np.asarray(trace.valid)
    t = np.asarray(trace.arrival_hours, np.float64)
    sel = v & (t >= t0) & (t < t1)
    w = np.asarray(trace.obs_window, np.float64)[sel]
    n_so = np.asarray(trace.n_scaleouts, np.float64)[sel]
    so_cores = np.asarray(trace.scaleout_cores, np.float64)[sel]
    c0 = np.asarray(trace.c0, np.float64)[sel]
    spont = np.asarray(trace.spont_death)[sel]
    deaths = np.asarray(trace.n_core_deaths, np.float64)[sel]
    core_hours = np.asarray(trace.core_hours, np.float64)[sel]

    mu_hat = np.where(core_hours > 0,
                      deaths / np.maximum(core_hours, 1e-12), np.nan)
    informative = np.isfinite(mu_hat) & (mu_hat > 0)
    ok_mu = (deaths >= min_deaths) & (core_hours > 0) & informative

    m_obs = 1.0 + n_so
    sig_hat = (c0 - 1.0 + (so_cores - n_so)) / m_obs

    # nu: fixed-edge bins over log(mu_hat) of the intensity n_so/w
    ok_nu = informative & (w > 0)
    lm = np.log(mu_hat[ok_nu])
    rate = n_so[ok_nu] / w[ok_nu]
    bins = np.clip(np.digitize(lm, _NU_BIN_EDGES) - 1, 0, _N_NU_BINS - 1)
    nu_count = np.bincount(bins, minlength=_N_NU_BINS).astype(np.float64)
    nu_lm_sum = np.bincount(bins, weights=lm, minlength=_N_NU_BINS)
    nu_rate_sum = np.bincount(bins, weights=rate, minlength=_N_NU_BINS)

    # lam: N/(mu_hat**nu w) moments for every candidate nu on the fixed grid
    mh, wv, ns = mu_hat[informative], w[informative], n_so[informative]
    a = np.power(mh[None, :], NU_GRID[:, None]) * wv[None, :]   # [G, M]
    ok_a = a > 1e-3
    inv_a = np.where(ok_a, 1.0 / np.maximum(a, 1e-12), 0.0)
    lam_hat = ns[None, :] * inv_a

    hpos = core_hours > 0
    dwh_sum = float(np.sum(deaths[hpos] * w[hpos] / core_hours[hpos]))

    wpos = w > 0
    ch_mu_sum = float(np.sum(deaths[hpos] / core_hours[hpos]))
    ch_so_sum = float(np.sum(n_so[wpos] / w[wpos]))

    return FitStats(
        n=float(sel.sum()), t0=float(t0), t1=float(t1),
        min_deaths=float(min_deaths),
        deaths_sum=float(deaths.sum()), core_hours_sum=float(core_hours.sum()),
        n_so_sum=float(n_so.sum()), so_cores_sum=float(so_cores.sum()),
        w_sum=float(w.sum()), spont_sum=float(spont.sum()), dwh_sum=dwh_sum,
        ch_mu_sum=ch_mu_sum, ch_mu_n=float(hpos.sum()),
        ch_so_sum=ch_so_sum, ch_so_n=float(wpos.sum()),
        mu_n=float(ok_mu.sum()), mu_sum=float(mu_hat[ok_mu].sum()),
        mu_logsum=float(np.log(mu_hat[ok_mu]).sum()) if ok_mu.any() else 0.0,
        sig_n=float(sig_hat.size), sig_sum=float(sig_hat.sum()),
        sig_sumsq=float(np.sum(sig_hat * sig_hat)),
        inv_m_sum=float(np.sum(1.0 / m_obs)),
        nu_count=nu_count, nu_lm_sum=nu_lm_sum, nu_rate_sum=nu_rate_sum,
        lam_n=ok_a.sum(axis=1).astype(np.float64),
        lam_sum=(lam_hat * ok_a).sum(axis=1),
        lam_sumsq=(lam_hat * lam_hat * ok_a).sum(axis=1),
        inv_a_sum=inv_a.sum(axis=1),
    )


def merge_stats(*stats: FitStats) -> FitStats:
    """Fold any number of window records into one (associative, and — since
    every field is a sum/min/max — invariant to window order up to float
    rounding). Windows must share ``min_deaths``."""
    if not stats:
        raise ValueError("merge_stats needs at least one FitStats")
    out = stats[0]
    for s in stats[1:]:
        if s.min_deaths != out.min_deaths:
            raise ValueError(
                f"cannot merge FitStats built with min_deaths="
                f"{out.min_deaths:g} and {s.min_deaths:g}")
        out = FitStats(
            n=out.n + s.n, t0=min(out.t0, s.t0), t1=max(out.t1, s.t1),
            min_deaths=out.min_deaths,
            **{f: getattr(out, f) + getattr(s, f)
               for f in FitStats._fields
               if f not in ("n", "t0", "t1", "min_deaths")})
    return out


def _degenerate_gamma(label: str, n: float, total: float,
                      diag: dict) -> tuple[float, float]:
    """Warn-and-continue fallback for a channel with too few informative
    samples (empty/quiet windows): a weakly-informative exponential
    (shape 1) matching the channel mean when one exists."""
    mean = total / n if n > 0 else float("nan")
    warnings.warn(
        f"observed fit: {label} channel has {int(n)} informative samples "
        f"(< {_MIN_SAMPLES}); continuing with a weakly-informative fallback",
        RuntimeWarning, stacklevel=3)
    log.warning("observed fit: %s channel degenerate (n=%d)", label, int(n))
    diag.setdefault("degenerate", []).append(label)
    rate = 1.0 / mean if np.isfinite(mean) and mean > 0 else 1.0
    return 1.0, float(rate)


def _nu_from_bins(stats: FitStats) -> float:
    """Weighted log-log regression slope over the fixed mu_hat bins
    (bins with < 4 rows or nonpositive mean intensity are dropped; fewer
    than 3 usable bins yields NaN → caller falls back)."""
    ok = (stats.nu_count >= 4) & (stats.nu_rate_sum > 0)
    if ok.sum() < 3:
        return float("nan")
    ws = stats.nu_count[ok]
    xs = stats.nu_lm_sum[ok] / ws
    ys = np.log(stats.nu_rate_sum[ok] / ws)
    xm = np.average(xs, weights=ws)
    ym = np.average(ys, weights=ws)
    return float(np.sum(ws * (xs - xm) * (ys - ym))
                 / np.sum(ws * (xs - xm) ** 2))


def stats_to_priors(stats: FitStats, *,
                    nu: float | None = None) -> tuple[PopulationPriors, dict]:
    """Run the observed-path population estimators on a (merged) record.

    ``nu`` fixes the exponent instead of estimating it; either way the value
    is snapped to the nearest ``NU_GRID`` point (0.01 resolution), where the
    lam moments were tabulated. Channels with fewer than ``_MIN_SAMPLES``
    informative rows warn and fall back (see ``_degenerate_gamma``).
    """
    diag: dict = {"source": "observed", "n_deployments": int(stats.n),
                  "n_mu": int(stats.mu_n)}

    if stats.mu_n >= _MIN_SAMPLES:
        mu_shape, mu_rate = _gamma_mle_from_moments(
            stats.mu_sum / stats.mu_n, stats.mu_logsum / stats.mu_n)
    else:
        mu_shape, mu_rate = _degenerate_gamma("mu", stats.mu_n, stats.mu_sum,
                                              diag)

    # sizes-minus-one are Poisson(sig) with m = 1 + n_scaleouts observations
    # (C0 counts); noise E Var[sig_hat|sig] = E[sig/m].
    if stats.sig_n >= _MIN_SAMPLES:
        sig_noise = (stats.sig_sum / stats.sig_n) * (stats.inv_m_sum
                                                     / stats.sig_n)
        sig_shape, sig_rate = _gamma_moments_from_sums(
            stats.sig_n, stats.sig_sum, stats.sig_sumsq, sig_noise)
    else:
        sig_shape, sig_rate = _degenerate_gamma("sig", stats.sig_n,
                                                stats.sig_sum, diag)

    nu_raw = _nu_from_bins(stats) if nu is None else float(nu)
    if not np.isfinite(nu_raw):
        nu_raw = 0.5
    gi = int(np.argmin(np.abs(NU_GRID - nu_raw)))
    nu_used = float(NU_GRID[gi])
    diag["nu_raw"] = float(nu_raw)

    # lam: N_i/(mu_hat**nu w_i) is conditionally unbiased for lam_i;
    # noise E Var = E[lam] * E[1/a].
    n_lam = float(stats.lam_n[gi])
    diag["n_lam"] = int(n_lam)
    if n_lam >= _MIN_SAMPLES:
        lam_noise = (stats.lam_sum[gi] / n_lam) * (stats.inv_a_sum[gi]
                                                   / n_lam)
        lam_shape, lam_rate = _gamma_moments_from_sums(
            n_lam, stats.lam_sum[gi], stats.lam_sumsq[gi], lam_noise)
    else:
        lam_shape, lam_rate = _degenerate_gamma("lam", n_lam,
                                                stats.lam_sum[gi], diag)

    delta = float(stats.spont_sum / max(stats.dwh_sum, 1e-12))

    fitted = PopulationPriors(
        mu_shape=mu_shape, mu_rate=mu_rate,
        lam_shape=lam_shape, lam_rate=lam_rate,
        sig_shape=sig_shape, sig_rate=sig_rate,
        delta=delta, nu=nu_used,
    )
    diag["nu"] = nu_used
    return fitted, diag


# ---------------------------------------------------------------------------
# The main entry point
# ---------------------------------------------------------------------------

def fit_priors(trace: WorkloadTrace, *, source: str = "auto",
               nu: float | None = None,
               nu_grid: np.ndarray | None = None,
               min_deaths: int = 2) -> tuple[PopulationPriors, dict]:
    """Fit ``PopulationPriors`` to a trace; returns (priors, diagnostics).

    ``source``: "latent" (requires latent columns), "observed" (uses only
    provider-visible observables), or "auto" (latent when available).
    ``nu`` fixes the power-law exponent instead of estimating it.
    ``nu_grid`` overrides the latent-path profile grid (the observed path
    always uses the module-level ``NU_GRID`` its lam moments are tabulated
    on).
    """
    if source == "auto":
        source = "latent" if has_latents(trace) else "observed"
    if source not in ("latent", "observed"):
        raise ValueError(f"unknown fit source {source!r}")

    if source == "observed":
        stats = window_stats(trace, 0.0, np.inf, min_deaths=min_deaths)
        fitted, diag = stats_to_priors(stats, nu=nu)
        log.debug(
            "fit_priors source=observed n=%d: mu=(%.4g,%.4g) lam=(%.4g,%.4g) "
            "sig=(%.4g,%.4g) delta=%.4g nu=%.3f", diag["n_deployments"],
            fitted.mu_shape, fitted.mu_rate, fitted.lam_shape,
            fitted.lam_rate, fitted.sig_shape, fitted.sig_rate,
            fitted.delta, fitted.nu)
        return fitted, diag

    if nu_grid is None:
        nu_grid = NU_GRID
    v = np.asarray(trace.valid)
    w = np.asarray(trace.obs_window, np.float64)[v]
    n_so = np.asarray(trace.n_scaleouts, np.float64)[v]
    spont = np.asarray(trace.spont_death)[v]
    diag = {"source": source, "n_deployments": int(v.sum())}

    lam = np.asarray(trace.lam, np.float64)[v]
    mu = np.asarray(trace.mu, np.float64)[v]
    sig = np.asarray(trace.sig, np.float64)[v]
    mu_shape, mu_rate = fit_gamma_mle(mu)
    lam_shape, lam_rate = fit_gamma_mle(lam)
    sig_shape, sig_rate = fit_gamma_mle(sig)
    if nu is None:
        nu, nu_scores = _fit_nu_profile(n_so, lam, mu, w, nu_grid)
        diag["nu_scores"] = nu_scores
    delta = _fit_delta(spont, mu, w)

    fitted = PopulationPriors(
        mu_shape=mu_shape, mu_rate=mu_rate,
        lam_shape=lam_shape, lam_rate=lam_rate,
        sig_shape=sig_shape, sig_rate=sig_rate,
        delta=delta, nu=float(nu),
    )
    diag["nu"] = float(nu)
    log.debug(
        "fit_priors source=%s n=%d: mu=(%.4g,%.4g) lam=(%.4g,%.4g) "
        "sig=(%.4g,%.4g) delta=%.4g nu=%.3f", source,
        diag["n_deployments"], mu_shape, mu_rate, lam_shape, lam_rate,
        sig_shape, sig_rate, delta, nu)
    return fitted, diag


def prior_relative_errors(fitted: PopulationPriors,
                          reference: PopulationPriors) -> dict:
    """Per-field relative error |fit - ref| / |ref| (diagnostic/tests)."""
    return {f: abs(getattr(fitted, f) - getattr(reference, f))
            / max(abs(getattr(reference, f)), 1e-12)
            for f in PopulationPriors._fields}
