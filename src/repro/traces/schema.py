"""Columnar workload-trace schema + CSV/NPZ persistence.

A ``WorkloadTrace`` is the trace-driven counterpart of the paper's Azure
trace (Cortez et al. [2017]): one row per deployment, fixed-capacity arrays
(``max_deployments`` rows, ``valid`` mask for the unused tail) so the whole
trace is a jit/vmap-friendly pytree. Columns split into three groups:

  * arrival stream     — ``arrival_hours`` (sorted), ``c0``, ``valid``
  * latent parameters  — ``lam``/``mu``/``sig`` per deployment; NaN when the
    trace came from real observations rather than a generator
  * observables        — what a provider actually logs: the observation
    window (censored at spontaneous shutdown / horizon), core-death counts
    and core-hour exposure, scale-out counts and total requested cores, and
    a per-deployment scale-out *event stream* (first ``max_events`` events;
    the scalar totals are authoritative beyond the buffer)

``fit.py`` recovers ``PopulationPriors`` from either group; ``replay.py``
turns any trace into the simulator's pre-drawn ``ArrivalStream``.

Persistence: ``save_npz``/``load_npz`` are lossless. ``save_csv`` writes two
human-readable tables (``<path>`` deployments, ``<path>.events.csv`` event
stream) holding only valid rows, so a CSV round-trip compacts the trace.
"""
from __future__ import annotations

import csv
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ScaleoutEvents(NamedTuple):
    """Per-deployment scale-out event buffer. All fields [D, E]-shaped."""

    t_offset: jax.Array   # hours since the deployment's arrival
    cores: jax.Array      # cores requested by the event
    valid: jax.Array      # bool; first min(n_scaleouts, E) events are real


class WorkloadTrace(NamedTuple):
    """One workload trace: [D] deployment columns + [D, E] event buffer."""

    arrival_hours: jax.Array   # [D] sorted arrival times (hours)
    c0: jax.Array              # [D] initial core request
    valid: jax.Array           # [D] bool — row holds a real deployment
    # latent parameters (synthetic traces; NaN when unknown)
    lam: jax.Array             # [D]
    mu: jax.Array              # [D]
    sig: jax.Array             # [D]
    # observables over the deployment's observation window
    obs_window: jax.Array      # [D] hours observed (censored)
    spont_death: jax.Array     # [D] bool — window ended by spontaneous death
    n_core_deaths: jax.Array   # [D] core deaths observed in the window
    core_hours: jax.Array      # [D] core-hour exposure behind those deaths
    n_scaleouts: jax.Array     # [D] scale-out events (may exceed the buffer)
    scaleout_cores: jax.Array  # [D] total cores across all scale-outs
    events: ScaleoutEvents     # [D, E] first max_events events
    horizon_hours: jax.Array   # scalar — trace duration


def n_deployments(trace: WorkloadTrace) -> int:
    """Number of valid deployments (concrete; pulls the mask to host)."""
    return int(np.asarray(trace.valid).sum())


def has_latents(trace: WorkloadTrace) -> bool:
    """True when every valid row carries finite latent parameters."""
    v = np.asarray(trace.valid)
    if not v.any():
        return False
    ok = np.isfinite(np.asarray(trace.lam)) & np.isfinite(
        np.asarray(trace.mu)) & np.isfinite(np.asarray(trace.sig))
    return bool(ok[v].all())


def validate_trace(trace: WorkloadTrace) -> WorkloadTrace:
    """Shape/ordering sanity checks; returns the trace for chaining."""
    d = trace.arrival_hours.shape[0]
    for name in ("c0", "valid", "lam", "mu", "sig", "obs_window",
                 "spont_death", "n_core_deaths", "core_hours", "n_scaleouts",
                 "scaleout_cores"):
        arr = getattr(trace, name)
        if arr.shape != (d,):
            raise ValueError(f"trace.{name} has shape {arr.shape}, want ({d},)")
    ev = trace.events
    if not (ev.t_offset.shape == ev.cores.shape == ev.valid.shape):
        raise ValueError("event buffer fields disagree on shape")
    if ev.t_offset.ndim != 2 or ev.t_offset.shape[0] != d:
        raise ValueError(f"event buffer leading dim {ev.t_offset.shape} != {d}")
    t = np.asarray(trace.arrival_hours)
    v = np.asarray(trace.valid)
    if v.any():
        tv = t[v]
        if np.any(np.diff(tv) < 0):
            raise ValueError("valid arrival_hours must be sorted")
        if np.any(tv < 0) or np.any(tv > float(np.asarray(trace.horizon_hours))):
            raise ValueError("arrival_hours outside [0, horizon_hours]")
    return trace


# ---------------------------------------------------------------------------
# NPZ persistence (lossless)
# ---------------------------------------------------------------------------

_EVENT_PREFIX = "events_"


def save_npz(trace: WorkloadTrace, path: str) -> None:
    """Lossless archive of every column (including the invalid tail)."""
    arrays = {k: np.asarray(v) for k, v in trace._asdict().items()
              if k != "events"}
    for k, v in trace.events._asdict().items():
        arrays[_EVENT_PREFIX + k] = np.asarray(v)
    np.savez(path, **arrays)


def load_npz(path: str) -> WorkloadTrace:
    with np.load(path) as z:
        events = ScaleoutEvents(**{
            k: jnp.asarray(z[_EVENT_PREFIX + k])
            for k in ScaleoutEvents._fields})
        cols = {k: jnp.asarray(z[k]) for k in WorkloadTrace._fields
                if k != "events"}
    return validate_trace(WorkloadTrace(events=events, **cols))


# ---------------------------------------------------------------------------
# CSV persistence (valid rows only; two tables)
# ---------------------------------------------------------------------------

_DEP_COLS = ("arrival_hours", "c0", "lam", "mu", "sig", "obs_window",
             "spont_death", "n_core_deaths", "core_hours", "n_scaleouts",
             "scaleout_cores")


def events_csv_path(path: str) -> str:
    return path + ".events.csv"


def save_csv(trace: WorkloadTrace, path: str) -> None:
    """Two tables: ``path`` (deployments, valid rows) and
    ``path.events.csv`` (long-format event stream keyed by deployment row).
    Compacts the trace — invalid rows/events are dropped."""
    v = np.asarray(trace.valid)
    idx = np.nonzero(v)[0]
    cols = {k: np.asarray(getattr(trace, k)) for k in _DEP_COLS}
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(("deployment",) + _DEP_COLS +
                   (f"horizon_hours={float(np.asarray(trace.horizon_hours))!r}",))
        for new_i, i in enumerate(idx):
            w.writerow([new_i] + [repr(float(cols[k][i])) for k in _DEP_COLS])
    ev = trace.events
    ev_valid = np.asarray(ev.valid)
    ev_t = np.asarray(ev.t_offset)
    ev_c = np.asarray(ev.cores)
    with open(events_csv_path(path), "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(("deployment", "t_offset", "cores"))
        for new_i, i in enumerate(idx):
            for j in np.nonzero(ev_valid[i])[0]:
                w.writerow((new_i, repr(float(ev_t[i, j])),
                            repr(float(ev_c[i, j]))))


def load_csv(path: str, max_events: int | None = None) -> WorkloadTrace:
    """Inverse of ``save_csv``. The event buffer width defaults to the
    largest per-deployment event count found in the events table."""
    with open(path, newline="") as f:
        rows = list(csv.reader(f))
    header, rows = rows[0], rows[1:]
    horizon = float(header[-1].split("=", 1)[1])
    d = len(rows)
    cols = {k: np.empty(d, np.float64) for k in _DEP_COLS}
    for r, row in enumerate(rows):
        for k, cell in zip(_DEP_COLS, row[1:]):
            cols[k][r] = float(cell)

    ev_by_dep: dict[int, list[tuple[float, float]]] = {}
    ev_path = events_csv_path(path)
    if os.path.exists(ev_path):
        with open(ev_path, newline="") as f:
            for row in list(csv.reader(f))[1:]:
                ev_by_dep.setdefault(int(row[0]), []).append(
                    (float(row[1]), float(row[2])))
    e = max_events if max_events is not None else max(
        [len(v) for v in ev_by_dep.values()], default=1)
    e = max(e, 1)
    ev_t = np.zeros((d, e), np.float32)
    ev_c = np.zeros((d, e), np.float32)
    ev_v = np.zeros((d, e), bool)
    for i, evs in ev_by_dep.items():
        for j, (t, c) in enumerate(evs[:e]):
            ev_t[i, j], ev_c[i, j], ev_v[i, j] = t, c, True

    f32 = lambda k: jnp.asarray(cols[k], jnp.float32)
    return validate_trace(WorkloadTrace(
        arrival_hours=f32("arrival_hours"),
        c0=f32("c0"),
        valid=jnp.ones(d, bool),
        lam=f32("lam"), mu=f32("mu"), sig=f32("sig"),
        obs_window=f32("obs_window"),
        spont_death=jnp.asarray(cols["spont_death"] > 0.5),
        n_core_deaths=f32("n_core_deaths"),
        core_hours=f32("core_hours"),
        n_scaleouts=f32("n_scaleouts"),
        scaleout_cores=f32("scaleout_cores"),
        events=ScaleoutEvents(t_offset=jnp.asarray(ev_t),
                              cores=jnp.asarray(ev_c),
                              valid=jnp.asarray(ev_v)),
        horizon_hours=jnp.asarray(horizon, jnp.float32),
    ))
