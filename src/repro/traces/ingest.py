"""Cortez/Azure-format trace ingestion: VM-level CSV -> ``WorkloadTrace``.

The paper grounds its Table-1 priors in the Azure trace of Cortez et
al. [2017] (the AzurePublicDataset "VM table"): one row per VM with a
deployment id, create/delete timestamps in seconds, and a bucketed core
count. ``ingest_cortez_csv`` converts that row format into the repo's
columnar ``WorkloadTrace`` so ``fit_priors(source="observed")`` and trace
replay run on real data:

  * **schema mapping** — ``CortezSchema`` names the columns either by
    header name (the dataset's published schema) or by position (the raw
    files ship headerless); ``AZURE_2017_POSITIONAL`` matches the original
    11-column layout.
  * **unit normalization** — timestamps are converted from the source unit
    (seconds by default) to hours and origin-shifted so the first VM
    creation is t = 0; bucketed core counts parse ``"1"``/``"4"`` and the
    open bucket ``">24"`` (taken at its lower bound times
    ``open_bucket_scale``).
  * **dt re-bucketing** — ``rebucket_dt_hours`` optionally snaps all
    timestamps down to a coarser grid (the raw 5-minute resolution is far
    below any simulator ``dt``); VMs created within
    ``c0_window_hours`` of their deployment's first creation fold into the
    initial request C0 instead of registering as instant scale-outs.
  * **malformed-row accounting** — rows with missing fields, unparsable
    numbers, negative times, or deletion-before-creation are counted in
    the diagnostics (``n_malformed``) and skipped, never silently guessed.

Model mapping (paper §2.1): a deployment's arrival is its first VM
creation; later VM creations are scale-out events; a VM deletion before
the deployment's last is a core death (the deletion of the final VM(s) is
the deployment's spontaneous shutdown, the paper's M process, not a core
death); deployments whose last VM outlives the trace are right-censored.
Latent columns (lam, mu, sig) are NaN — real traces carry observables
only — so replay imputes conjugate posterior means and
``fit_priors(source="observed")`` is the fitting path.
"""
from __future__ import annotations

import csv
import math
from typing import NamedTuple, Optional, Union

import jax.numpy as jnp
import numpy as np

from .schema import ScaleoutEvents, WorkloadTrace, validate_trace

Column = Union[str, int]

SECONDS_PER_HOUR = 3600.0


class CortezSchema(NamedTuple):
    """Column mapping for a Cortez-format VM table.

    Each field is a header name (str) or a 0-based position (int); a file
    is read positionally when every field is an int, otherwise its first
    row must be a header containing every named column.
    """

    vm_id: Column = "vmid"
    deployment_id: Column = "deploymentid"
    created: Column = "vmcreated"
    deleted: Column = "vmdeleted"
    cores: Column = "vmcorecountbucket"
    time_unit_seconds: float = 1.0   # raw timestamp unit, in seconds


#: The original AzurePublicDataset 2017 vmtable.csv layout (headerless):
#: vmid, subscriptionid, deploymentid, vmcreated, vmdeleted, maxcpu,
#: avgcpu, p95maxcpu, vmcategory, vmcorecountbucket, vmmemorybucket.
AZURE_2017_POSITIONAL = CortezSchema(vm_id=0, deployment_id=2, created=3,
                                     deleted=4, cores=9)


def parse_core_bucket(cell: str, open_bucket_scale: float = 1.0) -> float:
    """Parse a core-count cell: plain numbers plus the ``">24"`` open
    bucket, valued at its lower bound times ``open_bucket_scale``."""
    cell = cell.strip()
    if cell.startswith(">"):
        return float(cell[1:]) * open_bucket_scale
    return float(cell)


class _VMRow(NamedTuple):
    dep: str
    created: float    # hours since trace origin
    deleted: float    # hours; +inf when censored (empty/missing cell)
    cores: float


def _resolve_columns(schema: CortezSchema, first_row: list[str]
                     ) -> tuple[dict, bool]:
    """Map schema fields to column indices; returns (mapping, has_header)."""
    named = {f: c for f, c in zip(schema._fields, schema)
             if isinstance(c, str)}
    if not named:
        return {f: int(getattr(schema, f)) for f in
                ("vm_id", "deployment_id", "created", "deleted", "cores")}, \
            False
    header = [c.strip().lower() for c in first_row]
    idx = {}
    for field in ("vm_id", "deployment_id", "created", "deleted", "cores"):
        col = getattr(schema, field)
        if isinstance(col, int):
            idx[field] = col
            continue
        try:
            idx[field] = header.index(col.lower())
        except ValueError:
            raise ValueError(
                f"column {col!r} (schema field {field!r}) not found in "
                f"header {first_row!r}; for headerless files use a "
                "positional schema such as AZURE_2017_POSITIONAL")
    return idx, True


def _parse_rows(path: str, schema: CortezSchema, open_bucket_scale: float,
                diag: dict) -> list[_VMRow]:
    """Read and normalize the VM rows; malformed rows counted, not kept."""
    to_hours = schema.time_unit_seconds / SECONDS_PER_HOUR
    rows: list[_VMRow] = []
    with open(path, newline="") as f:
        reader = csv.reader(f)
        try:
            first = next(reader)
        except StopIteration:
            raise ValueError(f"{path}: empty trace file")
        idx, has_header = _resolve_columns(schema, first)
        n_cols = max(idx.values()) + 1
        raw = [] if has_header else [first]
        raw.extend(reader)
    diag["has_header"] = has_header
    diag["n_rows"] = len(raw)
    n_bad = 0
    for row in raw:
        if len(row) < n_cols:
            n_bad += 1
            continue
        try:
            dep = row[idx["deployment_id"]].strip()
            created = float(row[idx["created"]]) * to_hours
            del_cell = row[idx["deleted"]].strip()
            deleted = (math.inf if del_cell == ""
                       else float(del_cell) * to_hours)
            cores = parse_core_bucket(row[idx["cores"]], open_bucket_scale)
        except ValueError:
            n_bad += 1
            continue
        if (not dep or not math.isfinite(created) or created < 0.0
                or deleted < created or not (math.isfinite(cores)
                                             and cores > 0.0)
                or math.isnan(deleted)):
            n_bad += 1
            continue
        rows.append(_VMRow(dep, created, deleted, cores))
    diag["n_malformed"] = n_bad
    diag["n_vms"] = len(rows)
    if not rows:
        raise ValueError(
            f"{path}: no well-formed VM rows "
            f"({n_bad} malformed out of {len(raw)})")
    return rows


def ingest_cortez_csv(
    path: str,
    *,
    schema: CortezSchema = CortezSchema(),
    horizon_hours: Optional[float] = None,
    max_deployments: Optional[int] = None,
    max_events: int = 16,
    rebucket_dt_hours: float = 0.0,
    c0_window_hours: Optional[float] = None,
    open_bucket_scale: float = 1.0,
) -> tuple[WorkloadTrace, dict]:
    """Convert a Cortez/Azure-format VM CSV into a ``WorkloadTrace``.

    Returns ``(trace, diagnostics)``. ``horizon_hours`` defaults to the
    last observed event (after origin shift); VMs arriving beyond an
    explicit horizon are dropped (counted in ``n_vms_beyond_horizon``).
    ``c0_window_hours`` (default: ``rebucket_dt_hours``) folds VM
    creations that close to the deployment's first into the initial
    request C0. See the module docstring for the full model mapping.
    """
    diag: dict = {"path": path}
    rows = _parse_rows(path, schema, open_bucket_scale, diag)

    t0 = min(r.created for r in rows)
    if rebucket_dt_hours > 0.0:
        snap = lambda t: (math.floor((t - t0) / rebucket_dt_hours)
                          * rebucket_dt_hours if math.isfinite(t)
                          else math.inf)
    else:
        snap = lambda t: t - t0
    rows = [r._replace(created=snap(r.created), deleted=snap(r.deleted))
            for r in rows]
    data_end = max(max(r.created for r in rows),
                   max((r.deleted for r in rows if math.isfinite(r.deleted)),
                       default=0.0))
    horizon = data_end if horizon_hours is None else float(horizon_hours)
    horizon = max(horizon, 1e-9)
    c0_win = rebucket_dt_hours if c0_window_hours is None else c0_window_hours
    diag["t0_hours_raw"] = t0
    diag["horizon_hours"] = horizon

    by_dep: dict[str, list[_VMRow]] = {}
    n_beyond = 0
    for r in rows:
        if r.created >= horizon:
            n_beyond += 1
            continue
        by_dep.setdefault(r.dep, []).append(r)
    diag["n_vms_beyond_horizon"] = n_beyond

    deps = sorted(by_dep.values(), key=lambda ms: min(m.created for m in ms))
    n_found = len(deps)
    cap = n_found if max_deployments is None else int(max_deployments)
    diag["n_deployments"] = min(n_found, cap)
    diag["n_deployments_dropped"] = max(n_found - cap, 0)
    deps = deps[:cap]
    d = max(len(deps), 1)
    e = max(max_events, 1)

    cols = {k: np.zeros(d, np.float32) for k in
            ("arrival_hours", "c0", "obs_window", "n_core_deaths",
             "core_hours", "n_scaleouts", "scaleout_cores")}
    spont = np.zeros(d, bool)
    ev_t = np.zeros((d, e), np.float32)
    ev_c = np.zeros((d, e), np.float32)
    ev_v = np.zeros((d, e), bool)
    n_tail_events = 0

    for i, members in enumerate(deps):
        members = sorted(members, key=lambda m: m.created)
        arrival = members[0].created
        end = max(m.deleted for m in members)        # inf when censored
        spont_i = math.isfinite(end) and end < horizon
        window_end = min(end, horizon)

        c0 = deaths = core_hours = so_n = so_cores = 0.0
        n_ev = 0
        for m in members:
            life_end = min(m.deleted, horizon)
            core_hours += m.cores * max(life_end - m.created, 0.0)
            is_initial = m.created <= arrival + c0_win
            if is_initial:
                c0 += m.cores
            else:
                so_n += 1.0
                so_cores += m.cores
                if n_ev < e:
                    ev_t[i, n_ev] = m.created - arrival
                    ev_c[i, n_ev] = m.cores
                    ev_v[i, n_ev] = True
                    n_ev += 1
                else:
                    n_tail_events += 1
            # a deletion strictly before the deployment's end is a core
            # death; deletions at the end are the spontaneous shutdown
            # (or censoring), the M process, not the death process
            if math.isfinite(m.deleted) and m.deleted < window_end:
                deaths += m.cores

        cols["arrival_hours"][i] = arrival
        cols["c0"][i] = max(c0, 1.0)
        cols["obs_window"][i] = max(window_end - arrival, 0.0)
        cols["n_core_deaths"][i] = deaths
        cols["core_hours"][i] = core_hours
        cols["n_scaleouts"][i] = so_n
        cols["scaleout_cores"][i] = so_cores
        spont[i] = spont_i
    diag["n_events_beyond_buffer"] = n_tail_events

    valid = np.zeros(d, bool)
    valid[:len(deps)] = True
    nan = np.full(d, np.nan, np.float32)
    trace = WorkloadTrace(
        arrival_hours=jnp.asarray(cols["arrival_hours"]),
        c0=jnp.asarray(cols["c0"]),
        valid=jnp.asarray(valid),
        lam=jnp.asarray(nan), mu=jnp.asarray(nan), sig=jnp.asarray(nan),
        obs_window=jnp.asarray(cols["obs_window"]),
        spont_death=jnp.asarray(spont),
        n_core_deaths=jnp.asarray(cols["n_core_deaths"]),
        core_hours=jnp.asarray(cols["core_hours"]),
        n_scaleouts=jnp.asarray(cols["n_scaleouts"]),
        scaleout_cores=jnp.asarray(cols["scaleout_cores"]),
        events=ScaleoutEvents(t_offset=jnp.asarray(ev_t),
                              cores=jnp.asarray(ev_c),
                              valid=jnp.asarray(ev_v)),
        horizon_hours=jnp.asarray(horizon, jnp.float32),
    )
    return validate_trace(trace), diag
