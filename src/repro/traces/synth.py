"""Vectorized JAX trace generators + the scenario registry.

``synthesize_trace`` draws an Azure-like workload trace from
``PopulationPriors`` in one fully-vectorized pass (no python loop over
deployments), following the paper's §2.1 generative model:

  * arrivals: an inhomogeneous Poisson process via time-warping — the count
    comes from the integrated rate, and sorted uniforms on [0, Λ(horizon)]
    map through the inverse cumulative rate (a dense ``jnp.interp`` table),
    which is exact up to interpolation and, unlike thinning at the peak
    rate, wastes no trace capacity on bursty profiles;
  * latents (lam, mu, sig) ~ the Gamma priors; C0 ~ 1 + Poisson(sig);
  * observation window = min(Exp(delta * mu), horizon - arrival) (exact,
    memoryless), with the spontaneous-death indicator recorded;
  * scale-outs ~ Poisson(lam * mu**nu * window); the first ``max_events``
    events land in the trace's event buffer (times iid uniform over the
    window — exact for a Poisson process conditioned on its count), sizes
    1 + Poisson(sig); the scalar totals include the tail beyond the buffer;
  * core-death observables: initial cores use exact binomial thinning over
    the full window; scale-out cores are thinned with the *marginal* death
    probability under a per-core independent U(0, window) remaining window
    — an approximation (cores of one event really share that event's
    window, which would correlate their deaths and widen the count
    variance), paired with the Rao-Blackwellized expected exposure
    E[min(lifetime, window)] for ``core_hours``, so the censored
    exponential MLE mu_hat = deaths / core_hours stays consistent at the
    mean level while the generator never materializes a per-core array.

Scenario modifiers compose on top: ``rate_profile`` (arrival-rate
modulation), ``heavy_frac``/``heavy_mu_scale`` (heavy-tail lifetime
inflation via a mu-mixture), ``batch_size``/``batch_share_params``
(correlated batch arrivals that share an arrival instant and, optionally,
latent parameters), and ``param_drift`` (non-stationary priors: per-arrival
multiplicative factors on the sampled latents as a function of arrival
time — the drift scenarios ramp/step mu with it). Named combinations are
registered in ``_SCENARIOS``
(à la ``models/registry.py``): ``register_scenario`` / ``get_scenario`` /
``scenario_names`` / ``synthesize_scenario``.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core.processes import (AZURE_PRIORS, DeploymentParams,
                              PopulationPriors, fast_binomial, fast_poisson,
                              sample_params, scaleout_rate)
from ..obs.log import get_logger
from .schema import ScaleoutEvents, WorkloadTrace

log = get_logger(__name__)


class TraceSpec(NamedTuple):
    """Static shape/rate parameters of a synthetic trace."""

    horizon_hours: float = 365 * 24.0
    arrival_rate: float = 0.25       # deployments/hour (base, pre-modulation)
    max_deployments: int = 4096      # trace capacity D (Poisson tail clipped)
    max_events: int = 16             # event-buffer width E per deployment
    priors: PopulationPriors = AZURE_PRIORS


def _expected_exposure_frac(mu: jax.Array, w: jax.Array,
                            uniform_window: bool) -> tuple[jax.Array, jax.Array]:
    """(P(death), E[min(lifetime, W)]) for Exp(mu) lifetimes.

    ``uniform_window=False``: fixed window W = w. ``True``: W ~ U(0, w) —
    the remaining window of a core added at a uniform event time. Both are
    exact; the small-mu*w branch avoids 0/0 in float32.
    """
    mw = mu * w
    ems = -jnp.expm1(-mw)                       # 1 - exp(-mu w)
    if not uniform_window:
        p_die = ems
        exposure = jnp.where(mw > 1e-6, ems / jnp.maximum(mu, 1e-30), w)
        return p_die, exposure
    # W ~ U(0, w): P(T < W) = 1 - (1 - e^{-mu w})/(mu w); E[min(T,W)] = P/mu
    p_die = jnp.where(mw > 1e-6, 1.0 - ems / jnp.maximum(mw, 1e-30), mw / 2.0)
    exposure = jnp.where(mw > 1e-6, p_die / jnp.maximum(mu, 1e-30), w / 2.0)
    return p_die, exposure


_WARP_POINTS = 4096  # inverse-cumulative-rate interpolation table density


def synthesize_trace(
    key: jax.Array,
    spec: TraceSpec,
    *,
    rate_profile: Optional[Callable[[jax.Array], jax.Array]] = None,
    heavy_frac: float = 0.0,
    heavy_mu_scale: float = 1.0,
    batch_size: int = 1,
    batch_share_params: bool = False,
    param_drift: Optional[Callable[[jax.Array], DeploymentParams]] = None,
) -> WorkloadTrace:
    """One synthetic ``WorkloadTrace`` from the population priors.

    ``rate_profile(t_hours)`` returns the relative (nonnegative) arrival-rate
    multiplier at time t; arrivals form the inhomogeneous Poisson process
    with intensity ``arrival_rate * rate_profile(t)`` via exact time-warping.
    ``heavy_frac`` of deployments get ``mu *= heavy_mu_scale`` (lifetime
    inflation for ``heavy_mu_scale < 1``). ``batch_size > 1`` snaps blocks of
    consecutive arrivals to their leader's arrival instant (correlated
    batches), sharing the leader's latent parameters when
    ``batch_share_params``. ``param_drift(t_arr)`` returns per-deployment
    multiplicative factors (a ``DeploymentParams`` of multipliers) applied
    to the sampled latents as a function of arrival time — the population
    priors become piecewise/ramped in time, the drift setting.
    """
    priors = spec.priors
    d, e = spec.max_deployments, spec.max_events
    horizon = spec.horizon_hours
    (k_n, k_t, k_par, k_heavy, k_c0, k_spont, k_nso, k_toff, k_szb,
     k_szt, k_d0, k_ds) = jax.random.split(key, 12)

    # -- arrival stream (inhomogeneous Poisson via time-warping) ------------
    if rate_profile is None:
        total_mass = horizon                       # multiplier-hours
        warp = None
    else:
        t_grid = jnp.linspace(0.0, horizon, _WARP_POINTS + 1)
        r_grid = jnp.maximum(rate_profile(t_grid), 0.0)
        dt_g = horizon / _WARP_POINTS
        lam_grid = jnp.concatenate([
            jnp.zeros((1,)),
            jnp.cumsum(0.5 * (r_grid[1:] + r_grid[:-1]) * dt_g)])
        total_mass = lam_grid[-1]
        warp = lambda m: jnp.interp(m, lam_grid, t_grid)
    n = jnp.minimum(
        jax.random.poisson(k_n, spec.arrival_rate * total_mass), d
    ).astype(jnp.int32)
    valid = jnp.arange(d) < n
    # event "masses" of a Poisson process given its count are n iid uniforms
    # on [0, Λ(horizon)]: mask the unused tail *before* sorting (2*mass sorts
    # after every real arrival) so the valid prefix is exactly n sorted
    # uniforms — sorting all d rows and keeping the smallest n would instead
    # pile every arrival into the first n/d of the horizon.
    u = jnp.where(valid,
                  jax.random.uniform(k_t, (d,)) * total_mass,
                  2.0 * total_mass)
    masses = jnp.sort(u)
    t_arr = masses if warp is None else jnp.where(
        valid, warp(masses), 2.0 * horizon)

    # -- latent parameters + modifiers --------------------------------------
    params = sample_params(k_par, priors, (d,))
    if heavy_frac > 0.0:
        is_heavy = jax.random.bernoulli(k_heavy, heavy_frac, (d,))
        params = params._replace(
            mu=jnp.where(is_heavy, params.mu * heavy_mu_scale, params.mu))
    if batch_size > 1:
        leader = (jnp.arange(d) // batch_size) * batch_size
        t_arr = t_arr[leader]
        if batch_share_params:
            params = jax.tree.map(lambda a: a[leader], params)
    if param_drift is not None:
        # factors are evaluated at the final (post-batch-snap) arrival times;
        # invalid rows carry out-of-horizon sentinels but are masked out of
        # every trace column below, so their factors are irrelevant
        f = param_drift(jnp.minimum(t_arr, horizon))
        params = DeploymentParams(lam=params.lam * f.lam,
                                  mu=params.mu * f.mu,
                                  sig=params.sig * f.sig)
    lam, mu, sig = params.lam, params.mu, params.sig

    c0 = (1.0 + fast_poisson(k_c0, sig)).astype(jnp.float32)

    # -- observation window (censored spontaneous-shutdown clock) -----------
    t_spont = jax.random.exponential(k_spont, (d,)) / (priors.delta * mu)
    t_left = jnp.maximum(horizon - t_arr, 0.0)
    obs_window = jnp.minimum(t_spont, t_left)
    spont_death = (t_spont < t_left) & valid

    # -- scale-out event stream ---------------------------------------------
    so_rate = scaleout_rate(DeploymentParams(lam, mu, sig), priors)
    n_so = fast_poisson(k_nso, so_rate * obs_window * valid)
    n_buf = jnp.minimum(n_so, float(e))
    ev_valid = jnp.arange(e)[None, :] < n_buf[:, None]
    # mask the unused buffer tail before sorting (same trick as the arrival
    # times): the valid prefix is then n_buf sorted iid uniforms — sorting
    # all e draws and keeping the first n_buf would yield the smallest-of-e
    # order statistics, biasing event times ~e/n_buf-fold early.
    u_ev = jnp.where(ev_valid, jax.random.uniform(k_toff, (d, e)), 2.0)
    ev_offsets = jnp.sort(u_ev, axis=1) * obs_window[:, None]
    ev_sizes = (1.0 + fast_poisson(k_szb, jnp.broadcast_to(sig[:, None],
                                                           (d, e)))) * ev_valid
    buf_cores = jnp.sum(ev_sizes, axis=1)
    tail = n_so - n_buf                       # events beyond the buffer
    tail_cores = tail + fast_poisson(k_szt, tail * sig)
    scaleout_cores = buf_cores + tail_cores

    # -- core-death observables (counts exact, exposure Rao-Blackwellized) --
    valid_f = valid.astype(jnp.float32)
    p0, x0 = _expected_exposure_frac(mu, obs_window, uniform_window=False)
    d0 = fast_binomial(k_d0, c0 * valid_f, p0)
    ps, xs = _expected_exposure_frac(mu, obs_window, uniform_window=True)
    ds = fast_binomial(k_ds, scaleout_cores * valid_f, ps)
    n_core_deaths = d0 + ds
    core_hours = (c0 * x0 + scaleout_cores * xs) * valid_f

    z = lambda a: jnp.where(valid, a, 0.0).astype(jnp.float32)
    return WorkloadTrace(
        arrival_hours=jnp.where(valid, t_arr, horizon).astype(jnp.float32),
        c0=z(c0),
        valid=valid,
        lam=z(lam), mu=jnp.where(valid, mu, 1.0).astype(jnp.float32),
        sig=z(sig),
        obs_window=z(obs_window),
        spont_death=spont_death,
        n_core_deaths=z(n_core_deaths),
        core_hours=z(core_hours),
        n_scaleouts=z(n_so),
        scaleout_cores=z(scaleout_cores),
        events=ScaleoutEvents(
            t_offset=(ev_offsets * ev_valid).astype(jnp.float32),
            cores=ev_sizes.astype(jnp.float32),
            valid=ev_valid & valid[:, None]),
        horizon_hours=jnp.asarray(horizon, jnp.float32),
    )


# ---------------------------------------------------------------------------
# Scenario registry (à la models/registry.py): name -> synthesis recipe
# ---------------------------------------------------------------------------

class Scenario(NamedTuple):
    name: str
    describe: str
    synth: Callable[[jax.Array, TraceSpec], WorkloadTrace]


_SCENARIOS: dict[str, Scenario] = {}


def register_scenario(name: str, describe: str = ""):
    """Decorator: register ``fn(key, spec) -> WorkloadTrace`` under ``name``."""
    def deco(fn):
        if name in _SCENARIOS:
            raise ValueError(f"scenario {name!r} already registered")
        _SCENARIOS[name] = Scenario(name, describe or (fn.__doc__ or "").strip(),
                                    fn)
        return fn
    return deco


def get_scenario(name: str) -> Scenario:
    if name not in _SCENARIOS:
        raise KeyError(
            f"unknown scenario {name!r}; known: {scenario_names()}")
    return _SCENARIOS[name]


def scenario_names() -> tuple[str, ...]:
    return tuple(_SCENARIOS)


def synthesize_scenario(key: jax.Array, name: str,
                        spec: TraceSpec) -> WorkloadTrace:
    log.debug("synthesize_scenario %r: horizon=%gh rate=%g max_deployments=%d",
              name, spec.horizon_hours, spec.arrival_rate,
              spec.max_deployments)
    return get_scenario(name).synth(key, spec)


@register_scenario("baseline")
def _baseline(key, spec):
    """Stationary Azure-like workload straight from the priors."""
    return synthesize_trace(key, spec)


_DIURNAL_DEPTH = 0.75


@register_scenario("diurnal")
def _diurnal(key, spec):
    """Sinusoidal day/night arrival-rate modulation (same average rate)."""
    depth = _DIURNAL_DEPTH
    profile = lambda t: 1.0 + depth * jnp.sin(2.0 * math.pi * t / 24.0)
    return synthesize_trace(key, spec, rate_profile=profile)


_FLASH_MULT = 8.0
_FLASH_WINDOWS = ((0.30, 24.0), (0.70, 24.0))  # (start frac, duration hours)


@register_scenario("flash_crowd")
def _flash_crowd(key, spec):
    """Two 24h flash-crowd bursts at 8x the base arrival rate."""
    def profile(t):
        m = jnp.ones_like(t)
        for frac, dur in _FLASH_WINDOWS:
            start = frac * spec.horizon_hours
            m = jnp.where((t >= start) & (t < start + dur), _FLASH_MULT, m)
        return m
    return synthesize_trace(key, spec, rate_profile=profile)


@register_scenario("heavy_tail")
def _heavy_tail(key, spec):
    """10% of deployments live 10x longer (mu scaled down) — lifetime
    inflation à la the heavy-tail regimes of Psychas & Ghaderi."""
    return synthesize_trace(key, spec, heavy_frac=0.1, heavy_mu_scale=0.1)


@register_scenario("batched")
def _batched(key, spec):
    """Correlated batch arrivals: groups of 4 deployments submitted at the
    same instant with shared latent parameters."""
    return synthesize_trace(key, spec, batch_size=4, batch_share_params=True)


# -- drifting (non-stationary-prior) scenarios ------------------------------
#
# Both drift scenarios modulate mu DOWNWARD (deployments live longer), the
# dangerous direction: offered load grows, so a stationary-tuned operating
# point silently slides past its SLA — the regime tuning/drift.py detects
# and re-tunes out of. The terminal regime is itself a stationary prior
# (mu scaled by the constant below), recoverable via ``drifted_priors``.

#: terminal mu multiplier of the drift scenarios (lifetimes 1/scale longer)
DRIFT_MU_SCALE = 0.4
#: drift_ramp: mu ramps linearly between these horizon fractions
DRIFT_RAMP_FRACS = (0.25, 0.55)
#: drift_step: mu steps at this horizon fraction
DRIFT_STEP_FRAC = 0.5


def drift_mu_ramp(t: jax.Array, horizon_hours: float) -> jax.Array:
    """The drift_ramp mu multiplier at time t: 1 → DRIFT_MU_SCALE linearly
    over the DRIFT_RAMP_FRACS span, constant outside it."""
    a, b = DRIFT_RAMP_FRACS
    frac = jnp.clip((t / horizon_hours - a) / (b - a), 0.0, 1.0)
    return 1.0 + (DRIFT_MU_SCALE - 1.0) * frac


def drift_mu_step(t: jax.Array, horizon_hours: float) -> jax.Array:
    """The drift_step mu multiplier at time t: 1 before the step fraction,
    DRIFT_MU_SCALE after."""
    return jnp.where(t >= DRIFT_STEP_FRAC * horizon_hours,
                     DRIFT_MU_SCALE, 1.0)


def drifted_priors(priors: PopulationPriors,
                   mu_scale: float = DRIFT_MU_SCALE) -> PopulationPriors:
    """The stationary priors of the fully-drifted regime: mu scaled by
    ``mu_scale`` means Gamma(shape, rate / mu_scale)."""
    return priors._replace(mu_rate=priors.mu_rate / mu_scale)


def _mu_only(factor: jax.Array) -> DeploymentParams:
    one = jnp.ones_like(factor)
    return DeploymentParams(lam=one, mu=factor, sig=one)


@register_scenario("drift_ramp")
def _drift_ramp(key, spec):
    """Slow multi-month prior drift: mu ramps down to DRIFT_MU_SCALE over
    the middle of the horizon (deployments arriving later live ~2.5x
    longer), holding the drifted regime thereafter."""
    return synthesize_trace(
        key, spec,
        param_drift=lambda t: _mu_only(drift_mu_ramp(t, spec.horizon_hours)))


@register_scenario("drift_step")
def _drift_step(key, spec):
    """Abrupt prior change: mu steps down to DRIFT_MU_SCALE at mid-horizon
    — the detection-delay scenario."""
    return synthesize_trace(
        key, spec,
        param_drift=lambda t: _mu_only(drift_mu_step(t, spec.horizon_hours)))
