"""Trace-driven workload subsystem: schema, generators, fitting, replay.

The paper's evaluation is grounded in a Microsoft Azure trace; this package
makes the repo trace-driven end to end:

  * ``schema``  — ``WorkloadTrace``: a columnar, fixed-capacity, jit-friendly
    record of one workload (arrivals, latents, observables, scale-out event
    streams) with lossless NPZ and human-readable CSV persistence.
  * ``synth``   — vectorized JAX generators that synthesize Azure-like
    traces from ``PopulationPriors``, plus composable scenario modifiers
    (diurnal rate modulation, flash-crowd bursts, heavy-tail lifetime
    inflation, correlated batch arrivals) behind a scenario registry.
  * ``fit``     — moment-matching + Gamma-MLE recovery of
    ``PopulationPriors`` from any trace (latent or observables-only),
    closing the generate → fit → Table-1 loop.
  * ``replay``  — ``TraceArrivalSource``: any trace as a simulator arrival
    backend, under every information model — GLOBAL, §6 pseudo
    observations (sampled from trace latents or formed from the logged
    observables), and the §7 labeled/unlabeled type mixtures.
  * ``ingest``  — Cortez/Azure-format VM-table CSV → ``WorkloadTrace``
    (schema mapping, unit normalization, dt re-bucketing, malformed-row
    accounting), so fitting and replay run on real trace data.

ArrivalSource contract (see ``sim.simulator.ArrivalSource``): a source's
``stream(key, cfg)`` returns the same pre-drawn ``[n_steps, max_arrivals]``
``ArrivalStream`` that ``draw_arrival_stream`` produces — true latent
parameters, initial request sizes, provider beliefs, and the per-step
arrival counts. Because the scan body, admission policies, and importance
sampling consume only that stream, prior sampling and trace replay are
interchangeable backends: ``make_run(cfg, grid, kind, arrival_source=...)``
is the single switch, and an explicit ``stream=`` argument to the built
run() still overrides both.

Scenario registry: ``synth.register_scenario(name)`` registers a
``fn(key, spec) -> WorkloadTrace`` recipe (à la ``models/registry.py``);
``scenario_names()`` / ``get_scenario(name)`` / ``synthesize_scenario``
enumerate and invoke them. Shipped scenarios: ``baseline``, ``diurnal``,
``flash_crowd``, ``heavy_tail``, ``batched``, plus the non-stationary-prior
``drift_ramp``/``drift_step`` pair consumed by ``tuning/drift.py`` — all
runnable through ``benchmarks/scenarios.py``.
"""
from .schema import (ScaleoutEvents, WorkloadTrace, events_csv_path,
                     has_latents, load_csv, load_npz, n_deployments, save_csv,
                     save_npz, validate_trace)
from .synth import (DRIFT_MU_SCALE, DRIFT_RAMP_FRACS, DRIFT_STEP_FRAC,
                    Scenario, TraceSpec, drift_mu_ramp, drift_mu_step,
                    drifted_priors, get_scenario, register_scenario,
                    scenario_names, synthesize_scenario, synthesize_trace)
from .fit import (NU_GRID, FitStats, fit_gamma_mle, fit_gamma_moments,
                  fit_priors, merge_stats, prior_relative_errors,
                  stats_to_priors, window_stats)
from .replay import (PSEUDO_AUTO, PSEUDO_LATENT, PSEUDO_OBSERVED,
                     TraceArrivalSource, params_from_trace, trace_to_stream)
from .ingest import (AZURE_2017_POSITIONAL, CortezSchema, ingest_cortez_csv,
                     parse_core_bucket)

__all__ = [
    "ScaleoutEvents", "WorkloadTrace", "events_csv_path", "has_latents",
    "load_csv", "load_npz", "n_deployments", "save_csv", "save_npz",
    "validate_trace",
    "DRIFT_MU_SCALE", "DRIFT_RAMP_FRACS", "DRIFT_STEP_FRAC",
    "Scenario", "TraceSpec", "drift_mu_ramp", "drift_mu_step",
    "drifted_priors", "get_scenario", "register_scenario",
    "scenario_names", "synthesize_scenario", "synthesize_trace",
    "NU_GRID", "FitStats", "fit_gamma_mle", "fit_gamma_moments", "fit_priors",
    "merge_stats", "prior_relative_errors", "stats_to_priors", "window_stats",
    "PSEUDO_AUTO", "PSEUDO_LATENT", "PSEUDO_OBSERVED",
    "TraceArrivalSource", "params_from_trace", "trace_to_stream",
    "AZURE_2017_POSITIONAL", "CortezSchema", "ingest_cortez_csv",
    "parse_core_bucket",
]
