"""Training driver: end-to-end loop with checkpointing, fault tolerance,
straggler watchdog and elastic restart.

Runs REAL steps on whatever devices exist (the container's CPU for the
examples/tests; a pod when launched on one). The production mesh path is
exercised structurally by launch/dryrun.py.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 50 --reduced --ckpt-dir /tmp/ckpt [--resume] [--fail-at 20]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint import checkpointer
from ..data.pipeline import PipelineConfig, Prefetcher
from ..models import build_model, get_config, reduced_config
from ..optim.adamw import AdamWConfig
from ..runtime.fault_tolerance import (FailureInjector, StragglerWatchdog,
                                       run_with_restarts)
from ..train.step import init_train_state, make_train_step
from .mesh import make_host_mesh


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg, mesh,
                                      microbatches=args.microbatches,
                                      compress=args.compress))
    pipe_cfg = PipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.batch,
        frames_dim=cfg.d_model if cfg.family == "audio" else 0,
        enc_seq=cfg.enc_seq if cfg.family == "audio" else 0)
    return cfg, model, mesh, step_fn, pipe_cfg


def train(args) -> int:
    cfg, model, mesh, step_fn, pipe_cfg = build(args)
    ckpt = checkpointer.AsyncCheckpointer(args.ckpt_dir)
    injector = FailureInjector(tuple(args.fail_at))
    watchdog = StragglerWatchdog()

    def loop(_start_hint: int) -> int:
        start = 0
        state = None
        if args.resume or _start_hint != 0:
            latest = checkpointer.latest_step(args.ckpt_dir)
            if latest is not None:
                target = jax.eval_shape(
                    lambda: init_train_state(model, jax.random.PRNGKey(0),
                                             compress=args.compress))
                state = checkpointer.restore(args.ckpt_dir, latest, target)
                start = latest
                print(f"[train] resumed from step {latest}")
        if state is None:
            state = init_train_state(model, jax.random.PRNGKey(args.seed),
                                     compress=args.compress)
        pipe = Prefetcher(pipe_cfg, start_step=start)
        try:
            for step in range(start, args.steps):
                batch = next(pipe)
                t0 = time.time()
                injector.maybe_fail(step)
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                straggler = watchdog.observe(step, dt)
                if step % args.log_every == 0 or step == args.steps - 1:
                    print(f"[train] step={step} loss={loss:.4f} "
                          f"{dt*1e3:.0f}ms"
                          + (" STRAGGLER" if straggler else ""))
                if (step + 1) % args.ckpt_every == 0:
                    ckpt.save_async(step + 1, state)
            ckpt.wait()
            checkpointer.save(args.ckpt_dir, args.steps, state)
            return args.steps
        finally:
            pipe.close()

    final = run_with_restarts(
        loop, max_restarts=3,
        on_restart=lambda i, e: print(f"[train] restart #{i + 1}: {e}"))
    print(f"[train] done at step {final}; straggler events: "
          f"{len(watchdog.events)}")
    return final


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression w/ error feedback")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject node failures at these steps (FT test)")
    ap.add_argument("--seed", type=int, default=0)
    train(ap.parse_args())


if __name__ == "__main__":
    main()
