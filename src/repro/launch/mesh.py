"""Production mesh construction (TPU v5e pods).

Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods = 512 chips
as (pod=2, data=16, model=16) — the leading 'pod' axis carries pure data
parallelism across the inter-pod DCN/ICI links (cheapest collective), while
'model' (tensor/expert parallel, all-reduce heavy) stays inside a pod's dense
ICI torus.

Functions only — importing this module never touches jax device state (the
dry-run must set XLA_FLAGS before any jax initialization).

XLA flags for real runs (documented here, applied by launch/train.py):
  --xla_tpu_enable_latency_hiding_scheduler=true   # overlap collectives
  --xla_tpu_enable_async_collective_permute=true
  --xla_tpu_spmd_rng_bit_generator_unsafe=true     # cheap dropout RNG
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever this host has (1 CPU device in the container) — used by smoke
    tests and examples; same axis names so sharding rules still resolve."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
