"""Serving driver: batched requests through the continuous-batching engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --requests 12 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..models import build_model, get_config, reduced_config
from ..serve.engine import Request, ServeEngine
from .mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, max_batch=args.max_batch,
                         max_seq=args.max_seq, mesh=make_host_mesh())

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(2, cfg.vocab, size=rng.integers(4, 12))
                .astype(np.int32),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    done = engine.run_until_drained()
    dt = time.time() - t0
    tokens = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)}/{len(reqs)} requests, {tokens} tokens in "
          f"{dt:.1f}s ({tokens / max(dt, 1e-9):.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req{r.rid}: prompt={r.prompt.tolist()} -> "
              f"{r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
