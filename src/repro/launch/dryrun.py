import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the sharding config is coherent end-to-end:
  * jit(step).lower(**ShapeDtypeStruct inputs) succeeds (no allocation),
  * .compile() succeeds under GSPMD on the production mesh,
  * memory_analysis() shows the per-device footprint,
  * cost_analysis() + a collective parse of the partitioned HLO feed the
    roofline table (benchmarks/roofline.py reads the JSON artifacts).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.shapes import SHAPES, applicable
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, get_config, input_specs
from repro.models.registry import ARCH_NAMES
from repro.models.spec import resolve_spec
from repro.optim.adamw import AdamWConfig
from repro.train.step import (abstract_train_state, batch_shardings,
                              make_train_step, state_shardings)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")

#: per-arch gradient-accumulation microbatches for train_4k (memory fit);
#: revisited during §Perf iteration.
TRAIN_MICROBATCHES = {
    "dbrx-132b": 8, "chameleon-34b": 8, "granite-20b": 4, "qwen3-14b": 4,
    "moonshot-v1-16b-a3b": 4, "starcoder2-3b": 2, "hymba-1.5b": 2,
    "llama3.2-1b": 2, "xlstm-125m": 1, "whisper-small": 1,
}

#: §Perf optimization variants (EXPERIMENTS.md hypothesis->change->measure):
#:   opt = chunked flash-style attention (kills S² logits memory) +
#:         shard_map local-dispatch MoE (kills data-axis dispatch gathers)
VARIANTS = {
    "baseline": {},
    "opt": {"attn_chunk": 512, "moe_local_dispatch": True},
    "opt_chunk_only": {"attn_chunk": 512},
    "opt_moe_only": {"moe_local_dispatch": True},
    "opt_chunk256": {"attn_chunk": 256, "moe_local_dispatch": True},
    "opt_chunk1024": {"attn_chunk": 1024, "moe_local_dispatch": True},
    # serving variant: bf16 params replicated over the data axes (EP/TP only)
    # so decode pays no per-step FSDP weight gathers; wider MoE capacity.
    "opt_serve": {"attn_chunk": 512, "moe_local_dispatch": True,
                  "moe_capacity_factor": 4.0,
                  "_serve_params": True},
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type, e.g. 'f32[8,128]{1,0}' or a tuple."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    return n_devices


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Per-chip wire-byte cost model over the partitioned module.

    ring costs: all-reduce 2X(g-1)/g; all-gather/reduce-scatter/all-to-all
    X(g-1)/g (X = full logical bytes touched per chip); permute X.
    """
    out = {k: {"count": 0, "wire_bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+ = (\(?.+?\)?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)", ls)
        if not m:
            continue
        kind = m.group(2)
        if f" {kind}(" not in ls and f"{kind}-start(" not in ls:
            # avoid matching fusions mentioning the name
            pass
        rb = _shape_bytes(m.group(1))
        g = _group_size(ls, n_devices)
        if kind == "all-reduce":
            wire = 2.0 * rb * (g - 1) / max(g, 1)
        elif kind == "all-gather":
            wire = rb * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            wire = rb * (g - 1)          # result is the scattered shard
        elif kind == "all-to-all":
            wire = rb * (g - 1) / max(g, 1)
        else:
            wire = float(rb)
        out[kind]["count"] += 1
        out[kind]["wire_bytes"] += wire
    out["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or k in ("transcendentals",))}


def _lower_for(cfg, model, shape, mesh, microbatches: int):
    """Build the lowered computation for one cell (no compile)."""
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        mb = microbatches
        state = abstract_train_state(model)
        st_sh = state_shardings(model, mesh)
        b_sh = batch_shardings(specs, mesh)
        step = make_train_step(model, AdamWConfig(), mesh, microbatches=mb)
        fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, None), donate_argnums=(0,))
        lowered = fn.lower(state, specs)
    elif shape.kind == "prefill":
        p_abs = model.abstract_params()
        p_sh = model.param_shardings(mesh)
        tok_sh = NamedSharding(mesh, resolve_spec(
            specs["tokens"].shape, ("batch", None), mesh))
        args_sh = {"tokens": tok_sh}
        if "frames" in specs:
            args_sh["frames"] = NamedSharding(mesh, resolve_spec(
                specs["frames"].shape, ("batch", None, None), mesh))

        if cfg.family == "audio":
            def prefill_fn(params, tokens, frames):
                return model.prefill(params, tokens, mesh, frames=frames)
            fn = jax.jit(prefill_fn,
                         in_shardings=(p_sh, args_sh["tokens"], args_sh["frames"]))
            lowered = fn.lower(p_abs, specs["tokens"], specs["frames"])
        else:
            def prefill_fn(params, tokens):
                return model.prefill(params, tokens, mesh)
            fn = jax.jit(prefill_fn, in_shardings=(p_sh, args_sh["tokens"]))
            lowered = fn.lower(p_abs, specs["tokens"])
    else:  # decode
        b, s = shape.global_batch, shape.seq_len
        serve = getattr(model, "_serve_params", False)
        p_abs = model.abstract_params(jnp.bfloat16 if serve else jnp.float32)
        p_sh = model.param_shardings(mesh,
                                     drop_axes=("embed",) if serve else ())
        cache_abs = jax.eval_shape(
            functools.partial(model.init_cache, b, s, jnp.bfloat16))
        c_sh = model.cache_shardings(mesh, b, s)
        tok_sh = NamedSharding(mesh, resolve_spec((b,), ("batch",), mesh))

        def decode_fn(params, tokens, cache):
            return model.decode_step(params, tokens, cache, mesh)

        fn = jax.jit(decode_fn, in_shardings=(p_sh, tok_sh, c_sh),
                     out_shardings=(None, c_sh), donate_argnums=(2,))
        lowered = fn.lower(p_abs, specs["tokens"], cache_abs)
    return lowered


def _probe_metrics(compiled, n_dev) -> dict:
    cost = _cost_dict(compiled)
    coll = parse_collectives(compiled.as_text(), n_dev)
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "transcendentals": cost.get("transcendentals", 0.0),
        "wire_bytes": coll["total_wire_bytes"],
    }


def extrapolate_depth(cfg, model, shape, mesh) -> dict:  # noqa: C901
    """XLA cost analysis counts while-loop (scan) bodies ONCE. Compile
    unrolled depth-k and depth-2k probes and extrapolate linearly to the true
    depth: m(L) = m(k) + (L-k)/k * (m(2k) - m(k)). Fixes flops, bytes and
    collective counts for the scanned-layer (and grad-accum) loops. Known
    caveat (DESIGN.md): inner *sequence* scans (SSD chunk loops, sLSTM time
    loop) are still body-once; their contribution is bounded analytically in
    benchmarks/roofline.py.
    """
    import dataclasses as dc

    k = 2 if cfg.family == "ssm" else 1  # ssm alternates mlstm/slstm blocks
    n_dev = mesh.devices.size
    out = {}
    for depth in (k, 2 * k):
        c = dc.replace(cfg, n_layers=depth, scan_layers=False,
                       enc_layers=depth if cfg.enc_layers else 0)
        m = build_model(c)
        m._serve_params = getattr(model, "_serve_params", False)
        lowered = _lower_for(c, m, shape, mesh, microbatches=1)
        out[depth] = _probe_metrics(lowered.compile(), n_dev)
    el = cfg.n_layers
    extrap = {
        key: out[k][key] + (el - k) / k * (out[2 * k][key] - out[k][key])
        for key in out[k]
    }
    extrap["probe_depths"] = [k, 2 * k]
    extrap["probe_metrics"] = {str(d): out[d] for d in out}
    return extrap


def lower_cell(arch: str, shape_name: str, mesh, *, verbose: bool = True,
               probes: bool = True, variant: str = "baseline") -> dict:
    import dataclasses as dc
    overrides = dict(VARIANTS[variant])
    serve_params = overrides.pop("_serve_params", False)
    cfg = dc.replace(get_config(arch), **overrides)
    model = build_model(cfg)
    model._serve_params = serve_params
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}
    n_dev = mesh.devices.size
    t0 = time.time()
    mb = TRAIN_MICROBATCHES.get(arch, 1) if shape.kind == "train" else 1
    lowered = _lower_for(cfg, model, shape, mesh, microbatches=mb)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = _mem_dict(compiled)
    cost = _cost_dict(compiled)
    coll = parse_collectives(compiled.as_text(), n_dev)
    extrap = extrapolate_depth(cfg, model, shape, mesh) if probes else {}
    extrap["variant"] = variant
    result = {
        "variant": variant,
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": list(mesh.devices.shape), "axis_names": list(mesh.axis_names),
        "n_devices": int(n_dev),
        "n_params": model.n_params(),
        "n_active_params": model.n_active_params(),
        "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "microbatches": mb,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem, "cost": cost, "collectives": coll,
        "extrapolated": extrap,
    }
    if verbose:
        flops = cost.get("flops", 0)
        print(f"  {arch} × {shape_name} [{'x'.join(map(str, mesh.devices.shape))}]"
              f" OK lower={t_lower:.1f}s compile={t_compile:.1f}s"
              f" flops/dev={flops:.3g}"
              f" temp/dev={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB"
              f" wire/dev={coll['total_wire_bytes']/2**30:.3f}GiB")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = []
    if not args.multi_pod_only:
        meshes.append(("single_pod", make_production_mesh(multi_pod=False)))
    if not args.single_pod_only:
        meshes.append(("multi_pod", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else list(ARCH_NAMES)
    shapes = [args.shape] if args.shape else list(SHAPES)

    suffix = "" if args.variant == "baseline" else f"_{args.variant}"
    failures = 0
    for mesh_name, mesh in meshes:
        print(f"=== mesh {mesh_name} {dict(zip(mesh.axis_names, mesh.devices.shape))}")
        for arch in archs:
            for shape in shapes:
                out_path = os.path.join(
                    args.out, f"{arch}_{shape}_{mesh_name}{suffix}.json")
                try:
                    res = lower_cell(arch, shape, mesh,
                                     variant=args.variant)
                except Exception as e:
                    failures += 1
                    res = {"arch": arch, "shape": shape, "status": "error",
                           "mesh": mesh_name, "error": str(e),
                           "traceback": traceback.format_exc()}
                    print(f"  {arch} × {shape} [{mesh_name}] FAILED: {e}")
                with open(out_path, "w") as f:
                    json.dump(res, f, indent=1)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
