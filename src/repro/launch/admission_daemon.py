"""The integration point: the paper's admission controller gating a TPU
cluster's job queue.

Each *deployment* is an elastic model-serving/training job (one of the 10
assigned architectures); its "cores" are accelerator chips that scale out
with load following the paper's processes (fitted per arch family from the
job's own telemetry via the conjugate belief). The daemon holds a slot table
of admitted jobs, re-evaluates the aggregate moment curves on every arrival,
and admits iff the second-moment (Cantelli) condition keeps
Pr(sum of chip demand > cluster capacity) under the SLA — i.e. the paper's
Corollary 1 applied to a model-serving fleet.

Usage:
  PYTHONPATH=src python -m repro.launch.admission_daemon --hours 2000 \
      --capacity 4096 [--policy second|first|zeroth]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (AZURE_PRIORS, FIRST, SECOND, ZEROTH, belief_from_prior,
                    geometric_grid, make_policy)
from ..core.belief import observe_initial_size
from ..core.moments import moment_curves
from ..core.policies import admit_sequential
from ..models.registry import ARCH_NAMES, get_config

#: chips per replica of each servable arch (model-parallel footprint at bf16)
CHIPS_PER_REPLICA = {
    "hymba-1.5b": 1, "llama3.2-1b": 1, "xlstm-125m": 1, "whisper-small": 1,
    "starcoder2-3b": 1, "qwen3-14b": 4, "granite-20b": 4,
    "chameleon-34b": 8, "moonshot-v1-16b-a3b": 8, "dbrx-132b": 32,
}

POLICY_KINDS = {"zeroth": ZEROTH, "first": FIRST, "second": SECOND}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--capacity", type=float, default=4096.0)
    ap.add_argument("--hours", type=float, default=2000.0)
    ap.add_argument("--dt", type=float, default=6.0)
    ap.add_argument("--arrival-rate", type=float, default=0.2)
    ap.add_argument("--policy", default="second", choices=POLICY_KINDS)
    ap.add_argument("--param", type=float, default=None,
                    help="threshold (zeroth/first, chips) or rho (second)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..sim import SimConfig, make_run
    kind = POLICY_KINDS[args.policy]
    param = args.param
    if param is None:
        param = 0.15 if kind == SECOND else 0.7 * args.capacity
    cfg = SimConfig(capacity=args.capacity, arrival_rate=args.arrival_rate,
                    horizon_hours=args.hours, dt=args.dt, max_slots=512,
                    max_arrivals=4, priors=AZURE_PRIORS)
    grid = geometric_grid(args.dt, args.hours * 3, 32)
    pol = make_policy(kind, threshold=param, rho=param,
                      capacity=args.capacity)
    run = make_run(cfg, grid, kind)
    m = run(jax.random.PRNGKey(args.seed), pol)

    rng = np.random.default_rng(args.seed)
    arch_mix = rng.choice(len(ARCH_NAMES), size=8)
    print(f"[admission-daemon] policy={args.policy} param={param:g} "
          f"capacity={args.capacity:.0f} chips")
    print(f"  sample of admitted job types: "
          f"{[ARCH_NAMES[i] for i in arch_mix]}")
    print(f"  chips/replica table: {CHIPS_PER_REPLICA}")
    print(f"  utilization={float(m.utilization):.3f} "
          f"scaleout_failures={int(m.failed_requests)}/"
          f"{int(m.total_requests)} "
          f"admitted={int(m.arrivals_accepted)} "
          f"rejected={int(m.arrivals_rejected)}")


if __name__ == "__main__":
    main()
