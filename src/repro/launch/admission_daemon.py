"""The live integration point: the paper's admission controller running as a
long-lived service gating a TPU cluster's job queue.

Each *deployment* is an elastic model-serving/training job (one of the 10
assigned architectures); its "cores" are accelerator chips that scale out
with load following the paper's processes. The daemon is a thin driver of
``serve.admission.OnlineAdmissionEngine``: one device-resident slot table +
maintained aggregate moment curves, advanced ``dt`` hours per tick, with
every arriving job submitted through the micro-batching front-end and
admitted iff the configured policy (default: the second-moment / Cantelli
condition of Corollary 1) keeps Pr(chip demand > capacity) under the SLA.

Default thresholds are the **tuned operating points** recorded in the
committed ``BENCH_quick.json`` calibration rows (rescaled to the daemon's
capacity); the legacy hand-picked constants remain only as a warned
fallback when no row exists.

Observability: ``--metrics-port`` serves the engine's non-blocking
``metrics_snapshot()`` as Prometheus text on ``GET /metrics`` (device
telemetry counters + decision-latency/batch-size histograms; port 0 binds an
ephemeral port and logs it). SIGTERM/SIGINT shut down gracefully: the serve
loop stops at the next tick boundary, pending futures are flushed, and the
final metrics snapshot is logged before exit 0.

Scaling: ``--shards N`` shards the slot table over N devices (one engine,
bit-for-bit the single-device decisions — see ``sim.core.slot_mesh``);
``--flush-slo-ms L`` switches from per-tick caller-driven flushing to the
engine's deadline scheduler, which fires partial micro-batches before any
pending request exceeds its L-millisecond decision SLO (misses surface as
``repro_admission_deadline_misses_total`` on ``/metrics``).

Usage:
  PYTHONPATH=src python -m repro.launch.admission_daemon --hours 2000 \
      --capacity 4096 [--policy second|first|zeroth] [--fleet 2048,2048] \
      [--param RHO_OR_THRESHOLD] [--micro-batch 8] [--metrics-port 9109] \
      [--throttle 0.05] [--shards 8] [--flush-slo-ms 50]
"""
from __future__ import annotations

import argparse
import json
import signal
import threading
import time

import jax
import numpy as np

from ..core import AZURE_PRIORS, FIRST, SECOND, ZEROTH, geometric_grid, \
    make_policy
from ..core.policies import fleet_policy
from ..models.registry import ARCH_NAMES
from ..obs import get_logger, set_level

log = get_logger("launch.admission_daemon")  # stable name under python -m

#: chips per replica of each servable arch (model-parallel footprint at bf16)
CHIPS_PER_REPLICA = {
    "hymba-1.5b": 1, "llama3.2-1b": 1, "xlstm-125m": 1, "whisper-small": 1,
    "starcoder2-3b": 1, "qwen3-14b": 4, "granite-20b": 4,
    "chameleon-34b": 8, "moonshot-v1-16b-a3b": 8, "dbrx-132b": 32,
}

POLICY_KINDS = {"zeroth": ZEROTH, "first": FIRST, "second": SECOND}


def build_engine(args):
    """CLI args -> (engine, stream, keys): the configured online engine plus
    the synthetic arrival stream and per-tick event keys driving it."""
    from ..sim import (FleetConfig, SimConfig, draw_arrival_stream,
                      stream_config)
    from ..serve import OnlineAdmissionEngine, default_policy_param

    kind_name = args.policy
    kind = POLICY_KINDS[kind_name]
    telemetry = bool(getattr(args, "telemetry", False)
                     or getattr(args, "metrics_port", None) is not None)
    base = SimConfig(capacity=args.capacity, arrival_rate=args.arrival_rate,
                     horizon_hours=args.hours, dt=args.dt,
                     max_slots=args.max_slots, max_arrivals=args.micro_batch,
                     priors=AZURE_PRIORS, telemetry=telemetry)
    grid = geometric_grid(args.dt, args.hours * 3, 32)

    param = args.param
    if param is None:
        param = default_policy_param(kind_name, args.capacity,
                                     scale_name=args.scale)
    if args.fleet:
        caps = tuple(float(c) for c in args.fleet.split(","))
        if abs(sum(caps) - args.capacity) > 1e-6:
            base = base._replace(capacity=float(sum(caps)))
        cfg = FleetConfig(base=base, capacities=caps)
        pol = fleet_policy(kind, capacities=caps, threshold=param, rho=param)
    else:
        cfg = base
        pol = make_policy(kind, threshold=param, rho=param,
                          capacity=base.capacity)

    engine = OnlineAdmissionEngine(cfg, grid, kind, pol,
                                   micro_batch=args.micro_batch,
                                   scale=args.scale,
                                   shards=getattr(args, "shards", None),
                                   flush_slo_ms=getattr(args, "flush_slo_ms",
                                                        None),
                                   seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    k_stream, k_scan = jax.random.split(key)
    stream = draw_arrival_stream(k_stream, stream_config(cfg))
    keys = jax.random.split(k_scan, base.n_steps)
    return engine, stream, keys, param


def serve_loop(engine, stream, keys, *, log_every: int = 0,
               stop: threading.Event | None = None,
               throttle_s: float = 0.0) -> dict:
    """Drive the engine tick-by-tick: dynamics, then this window's arrivals
    through the micro-batching submit/flush front-end. Returns summary
    counters (the engine itself holds the metrics).

    ``stop`` (checked at each tick boundary) ends the loop early — the
    graceful-shutdown path; pending futures are still flushed and resolved.
    ``throttle_s`` sleeps between ticks so a scraper can watch ``/metrics``
    evolve (CI uses this to curl a live daemon).

    With a flush SLO configured on the engine, the deadline scheduler owns
    flushing: the loop only submits and awaits futures (resolved by the
    scheduler thread within the SLO); otherwise it drives the legacy
    caller-flushed protocol, one full flush per tick."""
    from ..serve import Arrival

    slo_mode = getattr(engine, "flush_slo_s", None) is not None
    if slo_mode:
        engine.start()
    n_steps = keys.shape[0]
    max_a = int(np.asarray(stream.c0.shape[1]))
    n_arr = np.asarray(stream.n_arrivals)
    admitted = 0
    t0 = time.time()
    ticks = 0
    for t in range(n_steps):
        if stop is not None and stop.is_set():
            log.info("stop requested at tick %d/%d", t, n_steps)
            break
        engine.tick(keys[t])
        ticks += 1
        futs = [engine.submit(Arrival.from_stream(stream, t, a))
                for a in range(min(int(n_arr[t]), max_a))]
        if not slo_mode:
            engine.flush()
        admitted += sum(f.result() for f in futs)
        if log_every and (t + 1) % log_every == 0:
            m = engine.metrics()
            log.info("t=%d/%d util=%.3f admitted=%d/%d", t + 1, n_steps,
                     float(m.utilization), admitted, engine.decisions)
        if throttle_s > 0.0:
            time.sleep(throttle_s)
    if slo_mode:
        engine.stop()      # joins the scheduler; final drain inside
    else:
        engine.flush()     # resolve anything a racing submitter queued
    return {"admitted": admitted, "decisions": engine.decisions,
            "ticks": ticks, "seconds": time.time() - t0}


def snapshot_log_line(snap: dict) -> str:
    """One JSON line of the scalar snapshot fields (histograms reduced to
    p50/p99 and counts) — what the daemon logs at shutdown."""
    eng = dict(snap.get("engine", {}))
    lat = eng.pop("decision_latency_seconds", None)
    batch = eng.pop("flush_batch_size", None)
    if lat is not None:
        eng["latency_p50_s"] = round(lat.percentile(0.5), 6)
        eng["latency_p99_s"] = round(lat.percentile(0.99), 6)
    if batch is not None:
        eng["mean_batch"] = round(batch.sum / max(batch.total, 1), 3)
    out = {"engine": eng}
    tel = snap.get("telemetry")
    if tel:
        out["telemetry"] = {k: v for k, v in tel.items()
                            if isinstance(v, (int, float))}
        out["telemetry"]["obs_departed"] = tel["obs"]["departed"]
    return json.dumps(out, sort_keys=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--capacity", type=float, default=4096.0)
    ap.add_argument("--hours", type=float, default=2000.0)
    ap.add_argument("--dt", type=float, default=6.0)
    ap.add_argument("--arrival-rate", type=float, default=0.2)
    ap.add_argument("--max-slots", type=int, default=512)
    ap.add_argument("--micro-batch", type=int, default=8)
    ap.add_argument("--policy", default="second", choices=POLICY_KINDS)
    ap.add_argument("--param", type=float, default=None,
                    help="threshold (zeroth/first, chips) or rho (second); "
                         "default: tuned operating point from BENCH_<scale>")
    ap.add_argument("--fleet", default=None, metavar="C1,C2,...",
                    help="serve a fleet of clusters with these capacities "
                         "(overrides --capacity with their sum)")
    ap.add_argument("--scale", default="quick",
                    help="BENCH_<scale>.json supplying tuned operating "
                         "points and the measured agg-refresh K-curve")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus text on GET /metrics at this "
                         "port (0 = ephemeral; enables device telemetry)")
    ap.add_argument("--telemetry", action="store_true",
                    help="carry the device telemetry rider even without a "
                         "metrics port")
    ap.add_argument("--throttle", type=float, default=0.0, metavar="SECONDS",
                    help="sleep between ticks so /metrics can be watched "
                         "while the daemon runs")
    ap.add_argument("--shards", type=int, default=None, metavar="N",
                    help="shard the slot table over N devices (single "
                         "cluster only; decisions stay bit-for-bit equal "
                         "to the unsharded engine)")
    ap.add_argument("--flush-slo-ms", type=float, default=None, metavar="MS",
                    help="decision-latency SLO: run the deadline-aware "
                         "flush scheduler instead of per-tick flushing")
    args = ap.parse_args()
    set_level("INFO")  # the daemon is a CLI: its operational log is output

    engine, stream, keys, param = build_engine(args)
    mode = f"fleet[{args.fleet}]" if args.fleet else "single"
    log.info("policy=%s param=%g capacity=%.0f chips %s micro_batch=%d "
             "agg_refresh_K=%d telemetry=%s shards=%d flush_slo_ms=%s",
             args.policy, param, args.capacity, mode, engine.width,
             engine.k_refresh, engine.base.telemetry, engine.n_shards,
             args.flush_slo_ms)
    rng = np.random.default_rng(args.seed)
    arch_mix = rng.choice(len(ARCH_NAMES), size=8)
    log.info("sample of admitted job types: %s",
             [ARCH_NAMES[i] for i in arch_mix])
    log.info("chips/replica table: %s", CHIPS_PER_REPLICA)

    server = None
    if args.metrics_port is not None:
        from ..obs import MetricsServer, snapshot_to_prometheus
        server = MetricsServer(
            lambda: snapshot_to_prometheus(engine.metrics_snapshot()),
            port=args.metrics_port)
        log.info("metrics: http://127.0.0.1:%d/metrics", server.port)

    stop = threading.Event()

    def _on_signal(signum, frame):
        log.info("received %s; shutting down gracefully",
                 signal.Signals(signum).name)
        stop.set()

    signal.signal(signal.SIGTERM, _on_signal)
    signal.signal(signal.SIGINT, _on_signal)

    summary = serve_loop(engine, stream, keys, log_every=args.log_every,
                         stop=stop, throttle_s=args.throttle)
    m = engine.metrics()
    rate = summary["decisions"] / max(summary["seconds"], 1e-9)
    log.info("utilization=%.3f scaleout_failures=%d/%d admitted=%d "
             "rejected=%d", float(m.utilization), int(m.failed_requests),
             int(m.total_requests), int(m.arrivals_accepted),
             int(m.arrivals_rejected))
    log.info("served %d admission decisions over %d ticks in %.1fs "
             "(%.1f decisions/s)", summary["decisions"], summary["ticks"],
             summary["seconds"], rate)
    log.info("final snapshot %s", snapshot_log_line(engine.metrics_snapshot()))
    if server is not None:
        server.close()


if __name__ == "__main__":
    main()
