"""The live integration point: the paper's admission controller running as a
long-lived service gating a TPU cluster's job queue.

Each *deployment* is an elastic model-serving/training job (one of the 10
assigned architectures); its "cores" are accelerator chips that scale out
with load following the paper's processes. The daemon is a thin driver of
``serve.admission.OnlineAdmissionEngine``: one device-resident slot table +
maintained aggregate moment curves, advanced ``dt`` hours per tick, with
every arriving job submitted through the micro-batching front-end and
admitted iff the configured policy (default: the second-moment / Cantelli
condition of Corollary 1) keeps Pr(chip demand > capacity) under the SLA.

Default thresholds are the **tuned operating points** recorded in the
committed ``BENCH_quick.json`` calibration rows (rescaled to the daemon's
capacity); the legacy hand-picked constants remain only as a warned
fallback when no row exists.

Usage:
  PYTHONPATH=src python -m repro.launch.admission_daemon --hours 2000 \
      --capacity 4096 [--policy second|first|zeroth] [--fleet 2048,2048] \
      [--param RHO_OR_THRESHOLD] [--micro-batch 8]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..core import AZURE_PRIORS, FIRST, SECOND, ZEROTH, geometric_grid, \
    make_policy
from ..core.policies import fleet_policy
from ..models.registry import ARCH_NAMES

#: chips per replica of each servable arch (model-parallel footprint at bf16)
CHIPS_PER_REPLICA = {
    "hymba-1.5b": 1, "llama3.2-1b": 1, "xlstm-125m": 1, "whisper-small": 1,
    "starcoder2-3b": 1, "qwen3-14b": 4, "granite-20b": 4,
    "chameleon-34b": 8, "moonshot-v1-16b-a3b": 8, "dbrx-132b": 32,
}

POLICY_KINDS = {"zeroth": ZEROTH, "first": FIRST, "second": SECOND}


def build_engine(args):
    """CLI args -> (engine, stream, keys): the configured online engine plus
    the synthetic arrival stream and per-tick event keys driving it."""
    from ..sim import (FleetConfig, SimConfig, draw_arrival_stream,
                       stream_config)
    from ..serve import OnlineAdmissionEngine, default_policy_param

    kind_name = args.policy
    kind = POLICY_KINDS[kind_name]
    base = SimConfig(capacity=args.capacity, arrival_rate=args.arrival_rate,
                     horizon_hours=args.hours, dt=args.dt,
                     max_slots=args.max_slots, max_arrivals=args.micro_batch,
                     priors=AZURE_PRIORS)
    grid = geometric_grid(args.dt, args.hours * 3, 32)

    param = args.param
    if param is None:
        param = default_policy_param(kind_name, args.capacity,
                                     scale_name=args.scale)
    if args.fleet:
        caps = tuple(float(c) for c in args.fleet.split(","))
        if abs(sum(caps) - args.capacity) > 1e-6:
            base = base._replace(capacity=float(sum(caps)))
        cfg = FleetConfig(base=base, capacities=caps)
        pol = fleet_policy(kind, capacities=caps, threshold=param, rho=param)
    else:
        cfg = base
        pol = make_policy(kind, threshold=param, rho=param,
                          capacity=base.capacity)

    engine = OnlineAdmissionEngine(cfg, grid, kind, pol,
                                   micro_batch=args.micro_batch,
                                   scale=args.scale)
    key = jax.random.PRNGKey(args.seed)
    k_stream, k_scan = jax.random.split(key)
    stream = draw_arrival_stream(k_stream, stream_config(cfg))
    keys = jax.random.split(k_scan, base.n_steps)
    return engine, stream, keys, param


def serve_loop(engine, stream, keys, *, log_every: int = 0) -> dict:
    """Drive the engine tick-by-tick: dynamics, then this window's arrivals
    through the micro-batching submit/flush front-end. Returns summary
    counters (the engine itself holds the metrics)."""
    from ..serve import Arrival

    n_steps = keys.shape[0]
    max_a = int(np.asarray(stream.c0.shape[1]))
    n_arr = np.asarray(stream.n_arrivals)
    admitted = 0
    t0 = time.time()
    for t in range(n_steps):
        engine.tick(keys[t])
        futs = [engine.submit(Arrival.from_stream(stream, t, a))
                for a in range(min(int(n_arr[t]), max_a))]
        engine.flush()
        admitted += sum(f.result() for f in futs)
        if log_every and (t + 1) % log_every == 0:
            m = engine.metrics()
            print(f"  t={t + 1}/{n_steps} util={float(m.utilization):.3f} "
                  f"admitted={admitted}/{engine.decisions}")
    return {"admitted": admitted, "decisions": engine.decisions,
            "seconds": time.time() - t0}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--capacity", type=float, default=4096.0)
    ap.add_argument("--hours", type=float, default=2000.0)
    ap.add_argument("--dt", type=float, default=6.0)
    ap.add_argument("--arrival-rate", type=float, default=0.2)
    ap.add_argument("--max-slots", type=int, default=512)
    ap.add_argument("--micro-batch", type=int, default=8)
    ap.add_argument("--policy", default="second", choices=POLICY_KINDS)
    ap.add_argument("--param", type=float, default=None,
                    help="threshold (zeroth/first, chips) or rho (second); "
                         "default: tuned operating point from BENCH_<scale>")
    ap.add_argument("--fleet", default=None, metavar="C1,C2,...",
                    help="serve a fleet of clusters with these capacities "
                         "(overrides --capacity with their sum)")
    ap.add_argument("--scale", default="quick",
                    help="BENCH_<scale>.json supplying tuned operating "
                         "points and the measured agg-refresh K-curve")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=0)
    args = ap.parse_args()

    engine, stream, keys, param = build_engine(args)
    mode = f"fleet[{args.fleet}]" if args.fleet else "single"
    print(f"[admission-daemon] policy={args.policy} param={param:g} "
          f"capacity={args.capacity:.0f} chips {mode} "
          f"micro_batch={engine.width} agg_refresh_K={engine.k_refresh}")
    rng = np.random.default_rng(args.seed)
    arch_mix = rng.choice(len(ARCH_NAMES), size=8)
    print(f"  sample of admitted job types: "
          f"{[ARCH_NAMES[i] for i in arch_mix]}")
    print(f"  chips/replica table: {CHIPS_PER_REPLICA}")

    summary = serve_loop(engine, stream, keys, log_every=args.log_every)
    m = engine.metrics()
    rate = summary["decisions"] / max(summary["seconds"], 1e-9)
    print(f"  utilization={float(m.utilization):.3f} "
          f"scaleout_failures={int(m.failed_requests)}/"
          f"{int(m.total_requests)} "
          f"admitted={int(m.arrivals_accepted)} "
          f"rejected={int(m.arrivals_rejected)}")
    print(f"  served {summary['decisions']} admission decisions in "
          f"{summary['seconds']:.1f}s ({rate:.1f} decisions/s)")


if __name__ == "__main__":
    main()
