"""Sharded AdamW with gradient clipping and warmup-cosine schedule.

Optimizer states (m, v) are plain pytrees with the SAME structure and
sharding as the parameters (ZeRO: each device holds only its parameter shard
plus the matching m/v shards — no optimizer-state replication). Implemented
directly (optax is not available offline) with an optional gradient
compression hook (optim.compression) applied before the moment updates.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init_opt_state(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step.astype(jnp.float32) - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(tree)))


def apply_updates(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    state: OptState,
    compress: Optional[Callable[[Any], Any]] = None,
) -> tuple[Any, OptState, dict]:
    """One AdamW step. Returns (new params, new state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    if compress is not None:
        grads = compress(grads)

    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state.m, grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state.v, grads)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, OptState(new_m, new_v, step), {
        "grad_norm": gnorm, "lr": lr}
