"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized gradients cut all-reduce bytes 4x. The quantize ->
dequantize round-trip runs *before* the (GSPMD-inserted) gradient reduction so
the collective moves int8-precision values; the residual is carried in an
error-feedback buffer so compression noise does not bias convergence
(Karimireddy et al., 2019 style). Enabled via TrainerConfig.compress_grads.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class ErrorFeedback(NamedTuple):
    residual: Any  # same pytree as grads


def init_error_feedback(params: Any) -> ErrorFeedback:
    return ErrorFeedback(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def _quantize_dequantize(g: jax.Array) -> jax.Array:
    """Blockwise symmetric int8 quantize->dequantize (simulates the wire
    format; the dequantized values are what the all-reduce sees)."""
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    out = deq.reshape(-1)[: g.size].reshape(g.shape)
    return out


def compress_with_feedback(grads: Any, ef: ErrorFeedback) -> tuple[Any, ErrorFeedback]:
    """grads + residual -> int8 round-trip; new residual = quantization error."""
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, ef.residual)
    compressed = jax.tree.map(_quantize_dequantize, corrected)
    new_resid = jax.tree.map(lambda c, q: c - q, corrected, compressed)
    return compressed, ErrorFeedback(residual=new_resid)
