"""Observability: device-side telemetry, decision tracing, live export.

Three layers over the admission stack (see ``docs/observability.md``):

  counters — the ``TelemetryState`` pytree rider carried inside
             ``CoreState`` through simulator scans and engine steps
             (``SimConfig(telemetry=True)``; statically compiled out by
             default, decisions/metrics bit-identical either way)
  tracing  — buffered per-decision JSONL records + ``jax.profiler`` spans
  export   — host histograms, Prometheus text rendering, and the
             ``/metrics`` HTTP server the admission daemon mounts
  log      — the shared ``repro``-rooted stdlib logger
             (``REPRO_LOG_LEVEL`` env var; silent by default)
"""
from .counters import (N_OCC_BINS, N_STALENESS_BINS, TelemetryState,
                       WindowStats, fold_decisions, fold_window,
                       init_telemetry, mark_refresh, telemetry_summary)
from .export import (LATENCY_BUCKETS_S, HostHistogram, Metric, MetricsServer,
                     log_buckets, render_prometheus, snapshot_to_prometheus)
from .log import get_logger, set_level
from .tracing import DecisionTracer, annotate

__all__ = [
    "N_OCC_BINS", "N_STALENESS_BINS", "TelemetryState", "WindowStats",
    "fold_decisions", "fold_window", "init_telemetry", "mark_refresh",
    "telemetry_summary",
    "LATENCY_BUCKETS_S", "HostHistogram", "Metric", "MetricsServer",
    "log_buckets", "render_prometheus", "snapshot_to_prometheus",
    "get_logger", "set_level",
    "DecisionTracer", "annotate",
]
