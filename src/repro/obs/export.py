"""Live metrics export: host histograms + Prometheus text + /metrics HTTP.

Three dependency-free pieces (stdlib only — no ``prometheus_client``):

  * ``HostHistogram`` — a fixed-bucket streaming histogram for host-side
    latencies/sizes (decision latency, flush batch size): O(1) observe,
    cumulative bucket counts, and p50/p99 estimates by linear interpolation
    within the landing bucket.
  * ``render_prometheus(metrics)`` — render a list of ``Metric`` families to
    the Prometheus text exposition format (version 0.0.4): ``# HELP`` /
    ``# TYPE`` headers, ``{label="v"}`` samples, and for histograms the
    cumulative ``_bucket{le=...}`` series plus ``_sum``/``_count``.
  * ``MetricsServer`` — a ``ThreadingHTTPServer`` on a daemon thread serving
    ``GET /metrics`` from a caller-provided ``render_fn`` (anything else is
    404). ``port=0`` binds an ephemeral port, exposed as ``.port``.

``snapshot_to_prometheus`` maps the online engine's ``metrics_snapshot()``
dict (see ``serve.admission``) onto ``repro_admission_*`` metric families;
the admission daemon serves it under ``--metrics-port``.
"""
from __future__ import annotations

import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, NamedTuple, Sequence

from .log import get_logger

log = get_logger(__name__)


def log_buckets(lo: float, hi: float, n: int) -> tuple:
    """``n`` log-spaced bucket upper bounds from ``lo`` to ``hi``."""
    ratio = (hi / lo) ** (1.0 / (n - 1))
    return tuple(lo * ratio ** i for i in range(n))


#: default latency buckets: 10µs .. 10s
LATENCY_BUCKETS_S = log_buckets(1e-5, 10.0, 19)


class HostHistogram:
    """Fixed-bucket streaming histogram (host side, not thread-safe —
    callers serialize through their own lock)."""

    def __init__(self, buckets: Sequence[float] = LATENCY_BUCKETS_S):
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(self.buckets):
            raise ValueError("histogram buckets must be sorted")
        self.counts = [0] * (len(self.buckets) + 1)  # +1: the +Inf bucket
        self.total = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        value = float(value)
        # linear scan: bucket counts are small and observe is not the hot
        # path's inner loop (one call per flush / per decision batch)
        idx = len(self.buckets)
        for i, edge in enumerate(self.buckets):
            if value <= edge:
                idx = i
                break
        self.counts[idx] += 1
        self.total += 1
        self.sum += value

    def percentile(self, p: float) -> float:
        """Estimate the ``p``-quantile (0..1) by linear interpolation inside
        the landing bucket; 0.0 when empty."""
        if self.total == 0:
            return 0.0
        target = p * self.total
        cum = 0
        lo = 0.0
        for i, edge in enumerate(self.buckets):
            prev = cum
            cum += self.counts[i]
            if cum >= target:
                frac = (target - prev) / max(self.counts[i], 1)
                return lo + frac * (edge - lo)
            lo = edge
        return self.buckets[-1] if self.buckets else 0.0

    def snapshot(self) -> "HostHistogram":
        """A detached copy (callers hold their lock only for this)."""
        h = HostHistogram(self.buckets)
        h.counts = list(self.counts)
        h.total = self.total
        h.sum = self.sum
        return h


class Metric(NamedTuple):
    """One Prometheus metric family: samples are ``(labels_dict, value)``
    pairs; a histogram family's values are ``HostHistogram`` instances."""

    name: str
    mtype: str          # "counter" | "gauge" | "histogram"
    help: str
    samples: list


def _fmt_value(v: float) -> str:
    v = float(v)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v)


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(
        '%s="%s"' % (k, str(v).replace("\\", r"\\").replace('"', r"\"")
                     .replace("\n", r"\n"))
        for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def render_prometheus(metrics: Sequence[Metric]) -> str:
    """Render metric families to the Prometheus text exposition format."""
    out = []
    for m in metrics:
        if m.mtype not in ("counter", "gauge", "histogram"):
            raise ValueError(f"unknown metric type {m.mtype!r}")
        out.append(f"# HELP {m.name} {m.help}")
        out.append(f"# TYPE {m.name} {m.mtype}")
        for labels, value in m.samples:
            if m.mtype != "histogram":
                out.append(f"{m.name}{_fmt_labels(labels)} "
                           f"{_fmt_value(value)}")
                continue
            hist: HostHistogram = value
            cum = 0
            for edge, count in zip(hist.buckets, hist.counts):
                cum += count
                lab = dict(labels, le=_fmt_value(edge))
                out.append(f"{m.name}_bucket{_fmt_labels(lab)} {cum}")
            lab = dict(labels, le="+Inf")
            out.append(f"{m.name}_bucket{_fmt_labels(lab)} {hist.total}")
            out.append(f"{m.name}_sum{_fmt_labels(labels)} "
                       f"{_fmt_value(hist.sum)}")
            out.append(f"{m.name}_count{_fmt_labels(labels)} {hist.total}")
    return "\n".join(out) + "\n"


def snapshot_to_prometheus(snap: dict) -> str:
    """Render an engine ``metrics_snapshot()`` dict as Prometheus text.

    Device-side telemetry counters become ``repro_admission_*`` counters and
    the occupancy/staleness histograms become gauges per bin; the host-side
    engine histograms (decision latency, flush batch size) are exposed as
    native Prometheus histograms plus queue-depth / pump-idle gauges.
    """
    mets: list[Metric] = []

    def counter(name, help_, value, **labels):
        mets.append(Metric(f"repro_admission_{name}", "counter", help_,
                           [(labels, value)]))

    def gauge(name, help_, samples):
        mets.append(Metric(f"repro_admission_{name}", "gauge", help_,
                           samples))

    eng = snap.get("engine", {})
    counter("requests_total", "Admission requests decided",
            eng.get("n_requests", 0))
    counter("flushes_total", "Micro-batch flushes", eng.get("n_flushes", 0))
    counter("refreshes_total", "Full aggregate refreshes",
            eng.get("n_refreshes", 0))
    counter("ticks_total", "Engine dt-window ticks", eng.get("n_ticks", 0))
    counter("deadline_misses_total",
            "Decisions whose submit->decision latency exceeded the flush SLO",
            eng.get("deadline_misses", 0))
    gauge("queue_depth", "Pending requests in the micro-batch queue",
          [({}, eng.get("queue_depth", 0))])
    gauge("pump_idle_fraction", "Fraction of pump loop time spent idle",
          [({}, eng.get("pump_idle_fraction", 0.0))])
    gauge("shard_count", "Devices the slot table is sharded over",
          [({}, eng.get("n_shards", 1))])
    gauge("flush_slo_seconds",
          "Configured decision-latency SLO (0 = caller-driven flushing)",
          [({}, eng.get("flush_slo_ms", 0.0) / 1e3)])
    for hname, help_ in (("decision_latency_seconds",
                          "submit->decision latency"),
                         ("flush_batch_size", "Decisions per flush")):
        hist = eng.get(hname)
        if isinstance(hist, HostHistogram):
            mets.append(Metric(f"repro_admission_{hname}", "histogram",
                               help_, [({}, hist)]))

    tel = snap.get("telemetry")
    if tel:
        counter("admitted_total", "Deployments admitted", tel["n_admit"])
        counter("rejected_total", "Rejected: physically did not fit",
                tel["n_reject_capacity"], reason="capacity")
        counter("rejected_total", "Rejected: moment condition",
                tel["n_reject_policy"], reason="policy")
        counter("windows_total", "Simulated dt windows", tel["n_windows"])
        counter("observed_departures_total", "Deployments departed",
                tel["obs"]["departed"])
        gauge("occupancy_window_count",
              "Windows by occupancy fraction bin (device histogram)",
              [({"bin": i}, v) for i, v in enumerate(tel["occupancy_hist"])])
        gauge("decision_staleness_count",
              "Decisions by aggregate staleness (windows since refresh)",
              [({"bin": i}, v) for i, v in enumerate(tel["staleness_hist"])])
        pc = tel.get("per_cluster")
        if pc:
            gauge("cluster_routed_count", "Candidates routed per cluster",
                  [({"cluster": c}, v)
                   for c, v in enumerate(pc["n_routed"])])
            gauge("cluster_admitted_count", "Admissions per cluster",
                  [({"cluster": c}, v)
                   for c, v in enumerate(pc["n_admit"])])
    return render_prometheus(mets)


class MetricsServer:
    """``GET /metrics`` over stdlib HTTP, rendered by ``render_fn``.

    The server runs on a daemon thread (``ThreadingHTTPServer``, so a slow
    scraper cannot wedge a second one); ``render_fn`` must therefore be
    thread-safe — the engine's ``metrics_snapshot`` is. ``port=0`` binds an
    ephemeral port; read ``.port`` after construction.
    """

    def __init__(self, render_fn: Callable[[], str], port: int = 0,
                 host: str = "127.0.0.1"):
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802  (stdlib API name)
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                try:
                    body = render_fn().encode()
                except Exception as exc:  # surface render bugs to the scraper
                    self.send_error(500, explain=str(exc))
                    server.log_exc = exc
                    return
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4; charset=utf-8")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, fmt, *args):
                log.debug("metrics http: " + fmt, *args)

        self.log_exc = None
        self._httpd = ThreadingHTTPServer((host, int(port)), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="repro-metrics", daemon=True)
        self._thread.start()
        log.info("metrics server listening on %s:%d", host, self.port)

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)
