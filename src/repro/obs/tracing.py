"""Decision tracing: structured per-decision records + profiler spans.

``DecisionTracer`` is the host-side half of the observability layer: the
engine (or any driver) hands it one structured record per admission decision
— step, deployment id, policy kind, threshold, moment-curve score, verdict,
submit→flush→decision latency, batch size — with values that may still be
device arrays. Records are buffered as-is (no blocking ``device_get`` on the
hot path; JAX async dispatch keeps running) and only materialized when the
buffer is drained to the JSONL sink, so tracing costs the decision path a
list append.

``annotate(name)`` wraps ``jax.profiler.TraceAnnotation`` (falling back to a
no-op when unavailable) so engine step/refresh/flush regions show up as named
spans in a captured ``jax.profiler`` trace.
"""
from __future__ import annotations

import contextlib
import json
import os
from typing import IO, Optional

import jax
import numpy as np

from .log import get_logger

log = get_logger(__name__)

#: buffered records before an automatic drain
DEFAULT_CAPACITY = 4096


def _jsonable(value):
    """Convert one drained field to a JSON-serializable python value."""
    if isinstance(value, (np.ndarray, np.generic)):
        if value.ndim == 0:
            value = value.item()
        else:
            return np.asarray(value).tolist()
    if isinstance(value, float):
        return round(value, 6)
    if isinstance(value, (bool, int, str)) or value is None:
        return value
    return repr(value)


class DecisionTracer:
    """Buffered JSONL sink for per-decision trace records.

    ``record(**fields)`` appends one structured record; field values may be
    scalars, numpy values, or (possibly unready) JAX arrays — they are kept
    unmaterialized until ``drain()``, which does one batched
    ``jax.device_get`` and writes one JSON object per line to the sink.
    The buffer drains itself at ``capacity``; ``close()`` drains and closes
    a sink the tracer opened (a caller-provided file object stays open).

    A tracer is also a context manager: ``with DecisionTracer(path) as tr:``.
    """

    def __init__(self, sink: str | os.PathLike | IO[str],
                 capacity: int = DEFAULT_CAPACITY):
        if hasattr(sink, "write"):
            self._fh: Optional[IO[str]] = sink  # caller-owned
            self._owns = False
        else:
            self._fh = open(os.fspath(sink), "a", encoding="utf-8")
            self._owns = True
        self.capacity = int(capacity)
        self._buf: list[dict] = []
        self.n_recorded = 0
        self.n_written = 0

    def record(self, **fields) -> None:
        """Buffer one decision record (non-blocking; values stay on device
        until the next ``drain``)."""
        self._buf.append(fields)
        self.n_recorded += 1
        if len(self._buf) >= self.capacity:
            self.drain()

    def drain(self) -> int:
        """Materialize and write every buffered record; returns the count."""
        if not self._buf or self._fh is None:
            n, self._buf = len(self._buf), []
            return n
        buf, self._buf = self._buf, []
        host = jax.device_get(buf)  # one transfer for the whole batch
        for rec in host:
            line = {k: _jsonable(v) for k, v in rec.items()}
            self._fh.write(json.dumps(line, separators=(",", ":")) + "\n")
        self._fh.flush()
        self.n_written += len(buf)
        return len(buf)

    def close(self) -> None:
        """Drain, then close the sink if this tracer opened it."""
        self.drain()
        if self._owns and self._fh is not None:
            self._fh.close()
            self._fh = None
        log.debug("tracer closed: %d records written", self.n_written)

    def __enter__(self) -> "DecisionTracer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def annotate(name: str):
    """Named ``jax.profiler`` span context (no-op if the API is missing).

    Wrap engine step / aggregate-refresh / flush regions so a captured
    profiler trace attributes device time to admission phases::

        with annotate("repro.engine.flush"):
            cs, accept, util = self._j_decide(...)
    """
    trace_annotation = getattr(jax.profiler, "TraceAnnotation", None)
    if trace_annotation is None:  # pragma: no cover - old jax
        return contextlib.nullcontext()
    return trace_annotation(name)
